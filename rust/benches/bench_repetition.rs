//! `cargo bench` target for Figure 7 / §5.1 plus the parallel-backend
//! scaling studies (executor thread scaling *and* plan-build cold-start
//! scaling).
//!
//! criterion is not in the offline vendor set; this is a `harness = false`
//! bench binary using the repo's min-of-N harness (paper supp. A
//! methodology: unloaded machine, report the minimum).
//!
//! Emits `BENCH_current.json` (op, shape, threads, min_ns, GFLOP/s)
//! so the perf trajectory is tracked across commits — CI uploads it as
//! an artifact and gates on `plum bench compare` against the committed
//! `BENCH_repetition.json` baseline (overwrite that one only
//! deliberately). Knobs (flag first, env fallback): `--reps N` /
//! `PLUM_BENCH_REPS` (default 10), `--threads N` / `PLUM_BENCH_THREADS`
//! (max pool width for the scaling ladders; default = available
//! parallelism). Example:
//!
//! ```text
//! cargo bench --bench bench_repetition -- --threads 4 --reps 20
//! ```

use std::path::Path;

use plum::cli::args::Args;
use plum::config::RunConfig;
use plum::experiments::figures;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = RunConfig {
        bench_reps: args.get_usize("reps", env_usize("PLUM_BENCH_REPS", 10)),
        ..RunConfig::default()
    };

    // Figure 7 workload (runs on the process-wide pool, like serving)
    println!("# bench_repetition — Figure 7 workload (reps={})", cfg.bench_reps);
    let rows = figures::fig7(&cfg, 1, 8, None).expect("fig7");
    let b: f64 = rows.iter().map(|r| r.t_binary_ms).sum();
    let s: f64 = rows.iter().map(|r| r.t_sb_sp_ms).sum();
    let t: f64 = rows.iter().map(|r| r.t_ternary_sp_ms).sum();

    // dense-vs-engine executor scaling + plan-build cold-start scaling
    // (byte-identical outputs/arenas at every width, or the harness
    // errors out) — the same orchestration `plum bench repetition` runs
    let cap = args.get_usize("threads", env_usize("PLUM_BENCH_THREADS", 0));
    let (threads, points) = figures::repetition_study(&cfg, 1, cap).expect("repetition_study");

    // BENCH_current.json, not BENCH_repetition.json: the latter is the
    // committed CI regression baseline — overwrite it only deliberately
    // (`plum bench repetition --out BENCH_repetition.json`)
    let out = Path::new("BENCH_current.json");
    let n = figures::write_scaling_records(&points, out).expect("write BENCH_current.json");
    println!("wrote {n} records to {}", out.display());

    let op_ns = |op: &str, th: usize| {
        points
            .iter()
            .find(|p| p.op == op && p.threads == th)
            .map(|p| p.min_ns)
    };
    let max_t = *threads.last().unwrap();
    let ratio = |op: &str| match (op_ns(op, 1), op_ns(op, max_t)) {
        (Some(t1), Some(tn)) if tn > 0 => t1 as f64 / tn as f64,
        _ => 1.0,
    };
    let scale = ratio("engine_sb");
    let plan_scale = ratio("plan_build");
    // machine-readable summary line for EXPERIMENTS.md tooling
    println!(
        "RESULT bench_repetition aggregate_speedup_sb={:.3} aggregate_speedup_ternary={:.3} engine_thread_scaling_{max_t}t={scale:.3} plan_build_scaling_{max_t}t={plan_scale:.3}",
        b / s,
        b / t
    );
}
