//! `cargo bench` target for Figure 7 / §5.1 plus the parallel-backend
//! scaling study.
//!
//! criterion is not in the offline vendor set; this is a `harness = false`
//! bench binary using the repo's min-of-N harness (paper supp. A
//! methodology: unloaded machine, report the minimum).
//!
//! Emits `BENCH_repetition.json` (op, shape, threads, min_ns, GFLOP/s)
//! so the perf trajectory is tracked across commits. Env knobs:
//! `PLUM_BENCH_REPS` (default 10), `PLUM_BENCH_THREADS` (max pool width
//! for the scaling ladder; default = available parallelism).

use std::path::Path;

use plum::config::RunConfig;
use plum::experiments::figures;
use plum::util::bench::{write_bench_json, BenchRecord};

fn main() {
    let mut cfg = RunConfig::default();
    cfg.bench_reps = std::env::var("PLUM_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);

    // Figure 7 workload (runs on the process-wide pool, like serving)
    println!("# bench_repetition — Figure 7 workload (reps={})", cfg.bench_reps);
    let rows = figures::fig7(&cfg, 1, 8, None).expect("fig7");
    let b: f64 = rows.iter().map(|r| r.t_binary_ms).sum();
    let s: f64 = rows.iter().map(|r| r.t_sb_sp_ms).sum();
    let t: f64 = rows.iter().map(|r| r.t_ternary_sp_ms).sum();

    // dense-vs-engine, 1-thread-vs-N-thread scaling on the ResNet block
    let cap = std::env::var("PLUM_BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let geom = figures::resnet_block_geometry(1);
    let threads = figures::default_thread_ladder(cap);
    let points = figures::engine_scaling(&cfg, geom, &threads).expect("engine_scaling");

    let records: Vec<BenchRecord> = points
        .iter()
        .map(|p| BenchRecord {
            op: p.op.clone(),
            shape: p.shape.clone(),
            threads: p.threads,
            min_ns: p.min_ns,
            gflops: p.gflops,
        })
        .collect();
    let out = Path::new("BENCH_repetition.json");
    write_bench_json(out, &records).expect("write BENCH_repetition.json");
    println!("wrote {} records to {}", records.len(), out.display());

    let engine_ns = |th: usize| {
        points
            .iter()
            .find(|p| p.op == "engine_sb" && p.threads == th)
            .map(|p| p.min_ns)
    };
    let max_t = *threads.last().unwrap();
    let scale = match (engine_ns(1), engine_ns(max_t)) {
        (Some(t1), Some(tn)) if tn > 0 => t1 as f64 / tn as f64,
        _ => 1.0,
    };
    // machine-readable summary line for EXPERIMENTS.md tooling
    println!(
        "RESULT bench_repetition aggregate_speedup_sb={:.3} aggregate_speedup_ternary={:.3} engine_thread_scaling_{max_t}t={scale:.3}",
        b / s,
        b / t
    );
}
