//! `cargo bench` target for Figure 7 / §5.1: the repetition-sparsity
//! engine on the ResNet-18 conv workload, B/T/SB x sparsity on/off.
//!
//! criterion is not in the offline vendor set; this is a `harness = false`
//! bench binary using the repo's min-of-N harness (paper supp. A
//! methodology: unloaded machine, report the minimum).

use plum::config::RunConfig;
use plum::experiments::figures;

fn main() {
    let mut cfg = RunConfig::default();
    cfg.bench_reps = std::env::var("PLUM_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    println!("# bench_repetition — Figure 7 workload (reps={})", cfg.bench_reps);
    let rows = figures::fig7(&cfg, 1, 8, None).expect("fig7");
    // machine-readable summary line for EXPERIMENTS.md tooling
    let b: f64 = rows.iter().map(|r| r.t_binary_ms).sum();
    let s: f64 = rows.iter().map(|r| r.t_sb_sp_ms).sum();
    let t: f64 = rows.iter().map(|r| r.t_ternary_sp_ms).sum();
    println!(
        "RESULT bench_repetition aggregate_speedup_sb={:.3} aggregate_speedup_ternary={:.3}",
        b / s,
        b / t
    );
}
