//! `cargo bench` target: the PJRT hot path — standalone L1 sb_matmul
//! kernel artifact, full infer artifact, and one train step. Skips
//! gracefully when artifacts are absent.

use std::path::PathBuf;

use plum::data::SyntheticDataset;
use plum::runtime::{execute_tuple, literal_f32, Runtime};
use plum::training::Trainer;
use plum::util::bench::{bench, black_box};
use plum::util::Rng;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("index.json").exists() {
        println!("# bench_runtime — artifacts not built, skipping (run `make artifacts`)");
        return;
    }
    println!("# bench_runtime — PJRT executables");
    let rt = Runtime::cpu().expect("pjrt client");

    // L1 kernel artifact
    if dir.join("sb_matmul.hlo.txt").exists() {
        let exe = rt.compile_hlo_file(&dir.join("sb_matmul.hlo.txt")).unwrap();
        let (m, k, n) = (256usize, 1152usize, 128usize);
        let mut rng = Rng::new(5);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let u: Vec<f32> = (0..k * n).map(|_| if rng.coin(0.5) { 0.4 } else { 0.0 }).collect();
        let beta: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let al = literal_f32(&[m, k], &a).unwrap();
        let ul = literal_f32(&[k, n], &u).unwrap();
        let bl = literal_f32(&[n], &beta).unwrap();
        let r = bench("sb_matmul kernel 256x1152x128", 2, 20, || {
            black_box(execute_tuple(&exe, &[&al, &ul, &bl]).unwrap());
        });
        let flops = 2.0 * (m * k * n) as f64;
        println!("{}   {:.2} GFLOP/s", r.row(), flops / r.min_ns as f64);
    }

    // infer + train step of the e2e model
    let mut tr = match Trainer::new(&rt, &dir, "resnet20_sb") {
        Ok(t) => t,
        Err(e) => {
            println!("resnet20_sb unavailable: {e:#}");
            return;
        }
    };
    let ds = SyntheticDataset::cifar_like(3);
    let bs = tr.batch_size();
    let (xs, ys) = ds.batch(0, bs);
    // keep the infer and train measurements in separate bindings: the
    // RESULT line reports both, so neither may overwrite the other
    let r_infer = bench("resnet20_sb infer (pallas path) bs32", 1, 10, || {
        black_box(tr.infer_logits(&xs).unwrap());
    });
    println!("{}", r_infer.row());
    let r_train = bench("resnet20_sb train step bs32", 1, 10, || {
        black_box(tr.train_step(&xs, &ys, 1e-3, 0.5).unwrap());
    });
    println!("{}", r_train.row());
    println!(
        "RESULT bench_runtime train_step_ms={:.2} infer_ms={:.2}",
        r_train.min_ms(),
        r_infer.min_ms()
    );
}
