//! `cargo bench` target: serving coordinator overhead and batching
//! behaviour with a mock backend (no PJRT) — isolates router/batcher
//! costs from model compute — plus a chaos smoke (supervised respawn
//! under injected faults), the open-loop load harness on the repetition
//! engine, and an optional end-to-end PJRT serve if artifacts exist
//! (kept tiny so `cargo bench` stays fast).

use std::time::{Duration, Instant};

use plum::coordinator::{
    flaky_factory, spawn_worker, BatchPolicy, MockBackend, Router, ServeError, ServePolicy,
};

fn bench_policy(max_batch: usize) -> ServePolicy {
    ServePolicy {
        batch: BatchPolicy { max_batch, max_wait: Duration::from_micros(500) },
        default_deadline: Duration::from_secs(60),
        ..ServePolicy::default()
    }
}

fn mock_roundtrip(replicas: usize, n_req: usize, max_batch: usize) -> (f64, f64) {
    let workers = (0..replicas)
        .map(|_| {
            spawn_worker(
                move || {
                    Ok(MockBackend {
                        bs: max_batch,
                        sample: 64,
                        classes: 10,
                        delay: Duration::from_micros(200), // pretend-model
                    })
                },
                bench_policy(max_batch),
            )
            .unwrap()
        })
        .collect();
    let router = Router::new(workers);
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n_req);
    for i in 0..n_req {
        // closed-loop with backpressure: admission is bounded now, so a
        // full fleet is waited out instead of panicking the bench
        let mut x = vec![i as f32; 64];
        let rx = loop {
            match router.submit(x) {
                Ok((rx, _)) => break rx,
                Err(ServeError::Overloaded { .. }) => {
                    std::thread::sleep(Duration::from_micros(50));
                    x = vec![i as f32; 64];
                }
                Err(e) => panic!("untyped admission failure: {e}"),
            }
        };
        rxs.push(rx);
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let mean_us = router.stats(0).latency.mean_us();
    router.shutdown().unwrap();
    (n_req as f64 / wall, mean_us)
}

/// Chaos smoke: supervised replicas under an injected fault schedule —
/// reports goodput and how many generations the supervisor replaced.
fn chaos_roundtrip(replicas: usize, n_req: usize) -> (f64, u64, usize) {
    let policy = ServePolicy {
        queue_depth: 32,
        breaker_threshold: 1000,
        backoff_base: Duration::from_micros(500),
        backoff_cap: Duration::from_millis(2),
        ..bench_policy(8)
    };
    let router = Router::spawn(
        replicas,
        flaky_factory(
            move || {
                Ok(MockBackend {
                    bs: 8,
                    sample: 64,
                    classes: 10,
                    delay: Duration::from_micros(200),
                })
            },
            9, // panic every 9th batch of each generation
            0,
            Duration::ZERO,
            11,
        ),
        policy,
    )
    .unwrap();
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n_req);
    let mut shed = 0usize;
    for i in 0..n_req {
        match router.submit(vec![i as f32; 64]) {
            Ok((rx, _)) => rxs.push(rx),
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("untyped admission failure: {e}"),
        }
    }
    let mut ok = 0usize;
    for rx in rxs {
        if rx.recv().expect("typed reply required").is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let crashes: u64 = (0..replicas).map(|i| router.stats(i).crashes.get()).sum();
    router.shutdown().unwrap();
    (ok as f64 / wall, crashes, shed)
}

fn main() {
    println!("# bench_coordinator — router + dynamic batcher");
    for (replicas, max_batch) in [(1, 1), (1, 8), (2, 8), (4, 8)] {
        let (rps, mean_us) = mock_roundtrip(replicas, 2000, max_batch);
        println!(
            "mock replicas={replicas} max_batch={max_batch}: {rps:>10.0} req/s  worker-mean {mean_us:.0} us"
        );
    }

    // chaos: same mock, panics injected — goodput with supervision on
    {
        let (rps, crashes, shed) = chaos_roundtrip(2, 2000);
        println!(
            "RESULT bench_coordinator chaos_rps={rps:.0} crashes={crashes} shed={shed}"
        );
    }

    // end-to-end on the repetition engine — always available, no
    // features, no artifacts (tiny resnet8 keeps `cargo bench` fast)
    {
        let cfg = plum::config::RunConfig {
            replicas: 2,
            max_batch: 4,
            ..plum::config::RunConfig::default()
        };
        match plum::experiments::serving::drive_engine(&cfg, "resnet8", 128) {
            Ok(r) => println!(
                "RESULT bench_coordinator engine_rps={:.1} mean_ms={:.1} p95_ms={:.1}",
                r.throughput_rps, r.mean_ms, r.p95_ms
            ),
            Err(e) => println!("engine serve failed: {e:#}"),
        }
    }

    // the open-loop load harness (the `plum bench serve` path)
    {
        let cfg = plum::config::RunConfig {
            replicas: 2,
            max_batch: 4,
            max_wait_ms: 1,
            ..plum::config::RunConfig::default()
        };
        match plum::experiments::serving::bench_serve_engine(&cfg, "resnet8", 8, 200.0, 0.5) {
            Ok(r) => println!(
                "RESULT bench_coordinator serve_rps={:.1} p50_us={} p95_us={} p99_us={} \
                 shed_ppm={}",
                r.achieved_rps, r.p50_us, r.p95_us, r.p99_us, r.shed_ppm
            ),
            Err(e) => println!("open-loop serve failed: {e:#}"),
        }
    }

    // end-to-end with PJRT if the feature is on and artifacts are present
    #[cfg(feature = "pjrt")]
    {
        let cfg = plum::config::RunConfig::default();
        if cfg.artifacts.join("resnet20_sb.manifest.json").exists() {
            match plum::experiments::serving::drive(&cfg, "resnet20_sb", 64, None) {
                Ok(r) => println!(
                    "RESULT bench_coordinator pjrt_rps={:.1} mean_ms={:.1} p95_ms={:.1}",
                    r.throughput_rps, r.mean_ms, r.p95_ms
                ),
                Err(e) => println!("pjrt serve skipped: {e:#}"),
            }
        } else {
            println!("pjrt serve skipped: artifacts not built");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("pjrt serve skipped: built without the `pjrt` feature");
}
