//! `cargo bench` target: serving coordinator overhead and batching
//! behaviour with a mock backend (no PJRT) — isolates router/batcher
//! costs from model compute — plus an optional end-to-end PJRT serve if
//! artifacts exist (kept tiny so `cargo bench` stays fast).

use std::time::{Duration, Instant};

use plum::coordinator::{spawn_worker, BatchPolicy, MockBackend, Router};

fn mock_roundtrip(replicas: usize, n_req: usize, max_batch: usize) -> (f64, f64) {
    let workers = (0..replicas)
        .map(|_| {
            spawn_worker(
                move || {
                    Ok(MockBackend {
                        bs: max_batch,
                        sample: 64,
                        classes: 10,
                        delay: Duration::from_micros(200), // pretend-model
                    })
                },
                BatchPolicy { max_batch, max_wait: Duration::from_micros(500) },
            )
            .unwrap()
        })
        .collect();
    let router = Router::new(workers);
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n_req);
    for i in 0..n_req {
        let x = vec![i as f32; 64];
        rxs.push(router.submit(x).unwrap().0);
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let mean_us = router.worker(0).latency.mean_us();
    router.shutdown().unwrap();
    (n_req as f64 / wall, mean_us)
}

fn main() {
    println!("# bench_coordinator — router + dynamic batcher");
    for (replicas, max_batch) in [(1, 1), (1, 8), (2, 8), (4, 8)] {
        let (rps, mean_us) = mock_roundtrip(replicas, 2000, max_batch);
        println!(
            "mock replicas={replicas} max_batch={max_batch}: {rps:>10.0} req/s  worker-mean {mean_us:.0} us"
        );
    }

    // end-to-end on the repetition engine — always available, no
    // features, no artifacts (tiny resnet8 keeps `cargo bench` fast)
    {
        let cfg = plum::config::RunConfig {
            replicas: 2,
            max_batch: 4,
            ..plum::config::RunConfig::default()
        };
        match plum::experiments::serving::drive_engine(&cfg, "resnet8", 128) {
            Ok(r) => println!(
                "RESULT bench_coordinator engine_rps={:.1} mean_ms={:.1} p95_ms={:.1}",
                r.throughput_rps, r.mean_ms, r.p95_ms
            ),
            Err(e) => println!("engine serve failed: {e:#}"),
        }
    }

    // end-to-end with PJRT if the feature is on and artifacts are present
    #[cfg(feature = "pjrt")]
    {
        let cfg = plum::config::RunConfig::default();
        if cfg.artifacts.join("resnet20_sb.manifest.json").exists() {
            match plum::experiments::serving::drive(&cfg, "resnet20_sb", 64, None) {
                Ok(r) => println!(
                    "RESULT bench_coordinator pjrt_rps={:.1} mean_ms={:.1} p95_ms={:.1}",
                    r.throughput_rps, r.mean_ms, r.p95_ms
                ),
                Err(e) => println!("pjrt serve skipped: {e:#}"),
            }
        } else {
            println!("pjrt serve skipped: artifacts not built");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("pjrt serve skipped: built without the `pjrt` feature");
}
