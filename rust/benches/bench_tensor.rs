//! `cargo bench` target: dense substrate baselines (GEMM, im2col conv)
//! that the repetition engine is compared against — the "naive dense"
//! denominator of the paper's arithmetic-reduction metric, timed.

use plum::tensor::{conv2d_gemm, conv2d_naive, gemm, Tensor};
use plum::util::bench::{bench, black_box};
use plum::util::Rng;

fn main() {
    println!("# bench_tensor — dense baselines");
    let mut rng = Rng::new(11);

    for (m, k, n) in [(64, 576, 64), (256, 1152, 128), (1024, 2304, 256)] {
        let a = Tensor::rand_normal(&[m, k], 1.0, &mut rng);
        let b = Tensor::rand_normal(&[k, n], 1.0, &mut rng);
        let r = bench(&format!("gemm {m}x{k}x{n}"), 1, 10, || {
            black_box(gemm(&a, &b));
        });
        let flops = 2.0 * (m * k * n) as f64;
        println!("{}   {:.2} GFLOP/s", r.row(), flops / r.min_ns as f64);
    }

    let x = Tensor::rand_normal(&[1, 64, 32, 32], 1.0, &mut rng);
    let w = Tensor::rand_normal(&[64, 64, 3, 3], 0.5, &mut rng);
    let r = bench("conv2d_gemm 64x64x3x3@32", 1, 10, || {
        black_box(conv2d_gemm(&x, &w, 1, 1));
    });
    println!("{}", r.row());
    let xs = Tensor::rand_normal(&[1, 16, 16, 16], 1.0, &mut rng);
    let ws = Tensor::rand_normal(&[16, 16, 3, 3], 0.5, &mut rng);
    let r = bench("conv2d_naive 16x16x3x3@16", 1, 5, || {
        black_box(conv2d_naive(&xs, &ws, 1, 1));
    });
    println!("{}", r.row());
}
