//! `cargo bench` target: dense substrate baselines (GEMM, im2col conv)
//! that the repetition engine is compared against — the "naive dense"
//! denominator of the paper's arithmetic-reduction metric, timed at
//! 1 thread and at full pool width (the GEMM row dimension is
//! parallelized through the shared worker pool).

use plum::tensor::{conv2d_gemm_pool, conv2d_naive, gemm_into_pool, Tensor};
use plum::util::bench::{bench, black_box};
use plum::util::{Pool, Rng};

fn main() {
    println!("# bench_tensor — dense baselines (1 thread vs N threads)");
    let mut rng = Rng::new(11);
    let nthreads = Pool::global().threads();
    let widths: Vec<usize> = if nthreads > 1 { vec![1, nthreads] } else { vec![1] };

    for (m, k, n) in [(64, 576, 64), (256, 1152, 128), (1024, 2304, 256)] {
        let a = Tensor::rand_normal(&[m, k], 1.0, &mut rng);
        let b = Tensor::rand_normal(&[k, n], 1.0, &mut rng);
        let flops = 2.0 * (m * k * n) as f64;
        let mut ns_1t = 0u64;
        for &threads in &widths {
            let pool = Pool::new(threads);
            let mut c = vec![0.0f32; m * n];
            let r = bench(&format!("gemm {m}x{k}x{n} t{threads}"), 1, 10, || {
                c.fill(0.0);
                gemm_into_pool(a.data(), b.data(), &mut c, m, k, n, &pool);
                black_box(&c);
            });
            if threads == 1 {
                ns_1t = r.min_ns;
            }
            println!(
                "{}   {:.2} GFLOP/s   speedup {:.2}x",
                r.row(),
                flops / r.min_ns as f64,
                ns_1t as f64 / r.min_ns as f64
            );
        }
    }

    let x = Tensor::rand_normal(&[1, 64, 32, 32], 1.0, &mut rng);
    let w = Tensor::rand_normal(&[64, 64, 3, 3], 0.5, &mut rng);
    for &threads in &widths {
        let pool = Pool::new(threads);
        let r = bench(&format!("conv2d_gemm 64x64x3x3@32 t{threads}"), 1, 10, || {
            black_box(conv2d_gemm_pool(&x, &w, 1, 1, &pool));
        });
        println!("{}", r.row());
    }
    let xs = Tensor::rand_normal(&[1, 16, 16, 16], 1.0, &mut rng);
    let ws = Tensor::rand_normal(&[16, 16, 3, 3], 0.5, &mut rng);
    let r = bench("conv2d_naive 16x16x3x3@16", 1, 5, || {
        black_box(conv2d_naive(&xs, &ws, 1, 1));
    });
    println!("{}", r.row());
}
