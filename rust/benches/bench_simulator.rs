//! `cargo bench` target for §5.2: SIGMA-like simulator energy/throughput
//! on dense vs sparse conv layers (Figure-level series + Figure 9/10 op
//! analyses, which are analytical and cheap).

use plum::config::RunConfig;
use plum::experiments::figures;
use plum::models;
use plum::simulator::{energy_reduction, AcceleratorConfig};

fn main() {
    let cfg = RunConfig::default();
    println!("# bench_simulator — §5.2 energy + Figures 9/10");
    figures::energy(&cfg, 0.65).expect("energy");
    figures::fig9(&cfg, 8).expect("fig9");
    figures::fig10(&cfg, 8, 20).expect("fig10");

    let acc = AcceleratorConfig::default();
    let mean: f64 = {
        let ls: Vec<_> = models::resnet18_layers(1.0, 64, 1)
            .into_iter()
            .filter(|l| l.quantized && l.geom.r == 3)
            .collect();
        ls.iter().map(|l| energy_reduction(&l.geom, 0.65, &acc)).sum::<f64>() / ls.len() as f64
    };
    println!("RESULT bench_simulator mean_energy_reduction={mean:.3} paper=2.0");
}
