//! Cross-language golden test: rust quantizers must reproduce the python
//! reference (`ref.py`) bit-for-bit on fixtures emitted by `make
//! artifacts` (artifacts/golden_quant.json).

use std::path::PathBuf;

use plum::quant::{quantize_binary, quantize_signed_binary, quantize_ternary};
use plum::tensor::Tensor;
use plum::util::Json;

fn golden() -> Option<Json> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden_quant.json");
    let text = std::fs::read_to_string(p).ok()?;
    Some(Json::parse(&text).expect("golden_quant.json parses"))
}

#[test]
fn rust_quantizers_match_python_reference() {
    let Some(g) = golden() else {
        eprintln!("artifacts not built; skipping golden test");
        return;
    };
    let cases = g.req_arr("cases").unwrap();
    assert!(!cases.is_empty());
    let mut checked = 0;
    for case in cases {
        let scheme = case.req_str("scheme").unwrap();
        let shape: Vec<usize> = case
            .req_arr("shape")
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        let w: Vec<f32> = case
            .req_arr("w")
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let beta: Vec<f32> = case
            .req_arr("beta")
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let expected: Vec<f32> = case
            .req_arr("wq")
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let wt = Tensor::new(&shape, w);
        let got = match scheme {
            "binary" => quantize_binary(&wt),
            "ternary" => quantize_ternary(&wt, 0.05),
            "sb" => quantize_signed_binary(&wt, &beta, 0.05, 1),
            other => panic!("unknown scheme {other}"),
        };
        let mut max_err = 0.0f32;
        for (a, b) in got.values.data().iter().zip(&expected) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(
            max_err < 1e-5,
            "{scheme} {shape:?}: max err {max_err} vs python reference"
        );
        // sparsity pattern must match exactly (not just numerically close)
        for (i, (a, b)) in got.values.data().iter().zip(&expected).enumerate() {
            assert_eq!(
                *a == 0.0,
                *b == 0.0,
                "{scheme} {shape:?}: effectuality mismatch at {i}"
            );
        }
        checked += 1;
    }
    assert!(checked >= 6, "expected >= 6 golden cases, got {checked}");
}
