//! Every `unsafe` site in `src/` must carry a nearby `// SAFETY:`
//! argument.
//!
//! The crate's soundness story is split in two: the static audit
//! (`plum::analysis`) proves the data-dependent preconditions, and the
//! `// SAFETY:` comment at each site names which invariant — and which
//! audit check — justifies it. This test makes the comments mandatory,
//! so a new `unsafe` block without a written argument fails CI rather
//! than review.
//!
//! Matching is deliberately dumb (line-based, word-boundary token
//! scan): it can over-approximate — a string literal containing the
//! word would be flagged — and that is fine; the fix is to reword the
//! string, never to weaken the scanner.

use std::fs;
use std::path::{Path, PathBuf};

/// How many lines above an `unsafe` token we search for "SAFETY". Large
/// enough for a multi-line argument above `unsafe impl`, small enough
/// that a comment cannot justify an unrelated site further down.
const WINDOW: usize = 12;

/// Lower bound on sites the scanner must find. If a refactor drops the
/// count below this, the likeliest cause is broken matching, not a
/// genuinely safer codebase — update it deliberately either way.
const MIN_SITES: usize = 15;

fn rust_sources(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in fs::read_dir(dir).expect("readable src dir") {
        let path = entry.expect("dir entry").path();
        if path.is_dir() {
            rust_sources(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// True when `line` contains `unsafe` as a standalone token (not as a
/// fragment of an identifier like `unsafe_slice_disjoint_writes`).
fn has_unsafe_token(line: &str) -> bool {
    let bytes = line.as_bytes();
    let mut from = 0;
    while let Some(rel) = line[from..].find("unsafe") {
        let start = from + rel;
        let end = start + "unsafe".len();
        let before_ok = start == 0 || {
            let c = bytes[start - 1];
            !c.is_ascii_alphanumeric() && c != b'_'
        };
        let after_ok = end == bytes.len() || {
            let c = bytes[end];
            !c.is_ascii_alphanumeric() && c != b'_'
        };
        if before_ok && after_ok {
            return true;
        }
        from = end;
    }
    false
}

#[test]
fn every_unsafe_site_has_a_safety_comment() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut files = Vec::new();
    rust_sources(&src, &mut files);
    files.sort();
    assert!(!files.is_empty(), "no sources under {}", src.display());

    let mut sites = 0usize;
    let mut violations = Vec::new();
    for file in &files {
        let text = fs::read_to_string(file).expect("readable source file");
        let lines: Vec<&str> = text.lines().collect();
        for (i, line) in lines.iter().enumerate() {
            let trimmed = line.trim_start();
            // comments and attributes may mention the keyword freely
            // (e.g. the lint name in `#![deny(unsafe_op_in_unsafe_fn)]`)
            if trimmed.starts_with("//") || trimmed.starts_with("#!") || trimmed.starts_with("#[") {
                continue;
            }
            if !has_unsafe_token(line) {
                continue;
            }
            sites += 1;
            let window = &lines[i.saturating_sub(WINDOW)..=i];
            let justified =
                window.iter().any(|l| l.to_ascii_uppercase().contains("SAFETY"));
            if !justified {
                let rel = file.strip_prefix(&src).unwrap_or(file);
                violations.push(format!("{}:{}: {}", rel.display(), i + 1, line.trim()));
            }
        }
    }

    assert!(
        sites >= MIN_SITES,
        "scanner found only {sites} unsafe sites (expected >= {MIN_SITES}) — did matching break?"
    );
    assert!(
        violations.is_empty(),
        "unsafe sites missing a // SAFETY: comment within {WINDOW} lines:\n{}",
        violations.join("\n")
    );
}
