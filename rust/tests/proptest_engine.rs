//! Randomized-geometry property tests for the pixel-major (transposed)
//! executor: for arbitrary n/c/k/r/s/stride/padding/sub-tile/tile draws,
//! the engine must (a) match the dense im2col+GEMM reference, (b) be
//! bit-identical across pool widths, and (c) agree per pixel with the
//! literal SumMerge CSE DAG (`CseDag::eval_row`) — three independently
//! built evaluators of the same quantized conv.

use plum::quant::{self, quantize_pruned, Scheme, SparsityPattern};
use plum::repetition::{build_cse, execute_conv2d_tiled, plan_layer, EngineConfig, LayerPlan};
use plum::tensor::{conv2d_gemm_pool, im2col, Conv2dGeometry, Tensor};
use plum::util::{Pool, Rng};

/// Random-case budgets. Under Miri each conv costs minutes, so the
/// sweeps shrink to smoke passes — the full grids run natively in CI.
const GEOMETRY_CASES: usize = if cfg!(miri) { 2 } else { 24 };
const ELISION_CASES: usize = if cfg!(miri) { 2 } else { 16 };

fn random_geometry(rng: &mut Rng) -> Conv2dGeometry {
    let r = [1, 2, 3, 5][rng.below(4)];
    let s = [1, 2, 3][rng.below(3)];
    Conv2dGeometry {
        n: 1 + rng.below(2),
        c: 1 + rng.below(8),
        h: r + rng.below(8), // h >= r keeps out_h >= 1 for any padding
        w: s + rng.below(8),
        k: 1 + rng.below(12),
        r,
        s,
        stride: 1 + rng.below(2),
        padding: rng.below(3),
    }
}

#[test]
fn random_geometries_match_gemm_and_cse_dag() {
    let mut rng = Rng::new(0xD1CE);
    let serial = Pool::new(1);
    let wide = Pool::new(3);
    let schemes = [Scheme::Binary, Scheme::ternary_default(), Scheme::sb_default()];
    for case in 0..GEOMETRY_CASES {
        let g = random_geometry(&mut rng);
        let scheme = schemes[rng.below(schemes.len())];
        let subtile = [3, 5, 8, 17][rng.below(4)];
        let tile = [1, 5, 32, 100][rng.below(4)];
        let sparsity_support = case % 2 == 0;
        let ctx = format!(
            "case {case}: {g:?} scheme {} subtile {subtile} tile {tile} sp {sparsity_support}",
            scheme.name()
        );

        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quant::quantize(&w, scheme, None);
        let plan = plan_layer(&q, g, EngineConfig { subtile, sparsity_support });

        // (a) engine == dense reference
        let dense = conv2d_gemm_pool(&x, &q.values, g.stride, g.padding, &serial);
        let out = execute_conv2d_tiled(&plan, &x, &serial, tile);
        assert!(dense.max_abs_diff(&out) < 1e-3, "engine vs dense: {ctx}");

        // (b) transposed path is bit-identical across pool widths
        let out_wide = execute_conv2d_tiled(&plan, &x, &wide, tile);
        assert!(out.data() == out_wide.data(), "thread bits: {ctx}");

        // (c) engine == SumMerge CSE DAG, pixel by pixel
        let dag = build_cse(&q, g, 120);
        let patches = im2col(&x, g.r, g.s, g.stride, g.padding);
        let (oh, ow) = (g.out_h(), g.out_w());
        let pixels = g.n * oh * ow;
        let e = g.c * g.r * g.s;
        let step = (pixels / 5).max(1); // sample ~5 pixels per case
        let mut px = 0;
        while px < pixels {
            let row = &patches.data()[px * e..(px + 1) * e];
            let per_filter = dag.eval_row(row);
            let ni = px / (oh * ow);
            let oy = (px % (oh * ow)) / ow;
            let ox = px % ow;
            for fi in 0..g.k {
                let got = out.at4(ni, fi, oy, ox);
                assert!(
                    (got - per_filter[fi]).abs() < 2e-3,
                    "engine {got} vs dag {} at px {px} filter {fi}: {ctx}",
                    per_filter[fi]
                );
            }
            px += step;
        }
    }
}

/// Plan-time elision is a pure representation change: for arbitrary
/// geometries, structured-sparsity patterns and sub-tile draws, the
/// elided plan (zero columns dropped from the arena, all-zero patterns
/// mapped to the shared no-op slot) must produce bit-identical forwards
/// to the unelided reference plan (`LayerPlan::build_pool_unelided`) at
/// every pool width — the executor under sparsity support never reads
/// zero columns, so the bits cannot move.
#[test]
fn elided_plans_bit_match_the_unelided_reference() {
    let mut rng = Rng::new(0xE11D);
    let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
    let schemes = [Scheme::ternary_default(), Scheme::sb_default()];
    for case in 0..ELISION_CASES {
        let g = random_geometry(&mut rng);
        let scheme = schemes[rng.below(schemes.len())];
        let subtile = [3, 5, 8, 17][rng.below(4)];
        let tile = [1, 5, 32, 100][rng.below(4)];
        let pattern = match rng.below(4) {
            0 => SparsityPattern::Unstructured,
            1 => SparsityPattern::NM { n: 1, m: 2 + rng.below(4) },
            2 => SparsityPattern::NM { n: 2, m: 4 },
            _ => SparsityPattern::Block { s: 1 + rng.below(3) },
        };
        let ctx = format!(
            "case {case}: {g:?} scheme {} subtile {subtile} tile {tile} pattern {:?}",
            scheme.name(),
            pattern
        );

        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize_pruned(&w, scheme, None, pattern);
        let cfg = EngineConfig { subtile, sparsity_support: true };
        let elided = plan_layer(&q, g, cfg);
        let reference = LayerPlan::build_pool_unelided(&q, g, cfg, &Pool::new(1));
        assert!(
            elided.arena.cols.len() <= reference.arena.cols.len(),
            "elided arena must never be larger: {ctx}"
        );
        let widths: &[usize] = if cfg!(miri) { &[2] } else { &[1, 2, ncpu] };
        for &t in widths {
            let pool = Pool::new(t);
            let got = execute_conv2d_tiled(&elided, &x, &pool, tile);
            let want = execute_conv2d_tiled(&reference, &x, &pool, tile);
            assert!(got.data() == want.data(), "elided vs unelided bits at {t} threads: {ctx}");
        }
    }
}
