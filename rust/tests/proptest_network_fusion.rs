//! Randomized-geometry property tests for generalized cross-layer patch
//! reuse: for arbitrary consumer geometries (r, s ∈ {1, 3}, stride ∈
//! {1, 2}, padding ∈ {0, 1}) over ragged pixel counts, a fused network
//! forward (producer scatters pixel-major patch blocks; consumers read
//! them in place or through the blocked gather) must be **bitwise
//! identical** to the fusion-disabled twin at pool widths {1, 2, ncpu}.

use std::sync::Arc;

use plum::models::ConvLayerDesc;
use plum::network::{chain_wiring, seeded_latents, NetworkExecutor, NetworkPlan};
use plum::quant::Scheme;
use plum::repetition::EngineConfig;
use plum::tensor::Conv2dGeometry;
use plum::util::{Pool, Rng};

fn desc(name: &str, g: Conv2dGeometry) -> ConvLayerDesc {
    ConvLayerDesc { name: name.into(), geom: g, quantized: true }
}

/// Random-case budget. Under Miri each chain forward costs minutes, so
/// the sweep shrinks to a smoke pass — the full grid runs natively.
const CASES: usize = if cfg!(miri) { 2 } else { 16 };

#[test]
fn random_fused_chains_bit_match_unfused_at_every_width() {
    let mut rng = Rng::new(0xF0_5E);
    let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    for case in 0..CASES {
        // producer: 3x3 / stride-1 / pad-1 (keeps the spatial size), so
        // its output feeds an arbitrary consumer geometry below
        let n = 1 + rng.below(2);
        let c0 = 1 + rng.below(4);
        let k0 = 1 + rng.below(6);
        // 4..=9 px: odd sizes force ragged PIXEL_BLOCK tails everywhere
        let h = 4 + rng.below(6);
        let w = 4 + rng.below(6);
        let g0 = Conv2dGeometry { n, c: c0, h, w, k: k0, r: 3, s: 3, stride: 1, padding: 1 };

        // consumer: the satellite's grid — r/s ∈ {1,3}, stride ∈ {1,2},
        // padding ∈ {0,1} — reading the producer's blocked activation
        let r = [1, 3][rng.below(2)];
        let s = [1, 3][rng.below(2)];
        let stride = 1 + rng.below(2);
        let padding = rng.below(2);
        let k1 = 1 + rng.below(6);
        let g1 = Conv2dGeometry { n, c: k0, h, w, k: k1, r, s, stride, padding };

        // tail consumer: 1x1/s1/p0 over the (possibly subsampled) plane,
        // so the middle activation exercises blocked output AND input
        let g2 = Conv2dGeometry {
            n,
            c: k1,
            h: g1.out_h(),
            w: g1.out_w(),
            k: 1 + rng.below(4),
            r: 1,
            s: 1,
            stride: 1,
            padding: 0,
        };
        let descs = vec![desc("p", g0), desc("m", g1), desc("t", g2)];
        let latents = seeded_latents(&descs, 0x1000 + case as u64);
        let wiring = chain_wiring(3);
        let cfg = EngineConfig { subtile: [5, 8, 16][rng.below(3)], sparsity_support: true };
        let pool1 = Pool::new(1);
        let ctx = format!("case {case}: g0 {g0:?} g1 {g1:?} g2 {g2:?} subtile {}", cfg.subtile);

        let fused = Arc::new(
            NetworkPlan::compile_with_wiring(
                &descs,
                &latents,
                &wiring,
                cfg,
                Scheme::sb_default(),
                &pool1,
            )
            .unwrap_or_else(|e| panic!("compile failed ({ctx}): {e}")),
        );
        // every quantized chain fuses all intermediate edges
        assert_eq!(fused.patch_fused_edges(), 2, "{ctx}");
        let unfused = Arc::new(fused.without_patch_fusion());
        assert_eq!(unfused.patch_fused_edges(), 0);

        let mut input = vec![0.0f32; fused.input_elems()];
        rng.fill_normal(&mut input, 1.0);
        let base = {
            let mut exec = NetworkExecutor::new(Arc::clone(&unfused));
            exec.forward_pool(&input, &pool1).to_vec()
        };
        let widths: &[usize] = if cfg!(miri) { &[2] } else { &[1, 2, ncpu] };
        for &threads in widths {
            let pool = Pool::new(threads);
            let mut exec = NetworkExecutor::new(Arc::clone(&fused));
            let out = exec.forward_pool(&input, &pool);
            assert!(out == base, "fused != unfused at {threads} threads ({ctx})");
            let mut uexec = NetworkExecutor::new(Arc::clone(&unfused));
            let uout = uexec.forward_pool(&input, &pool);
            assert!(uout == base, "unfused differs across widths at {threads} threads ({ctx})");
        }
    }
}
