//! Batch-axis bit-equality pyramid: `forward_batch(b images)` must be
//! **bitwise identical** to `b` independent single-image forwards
//! through an equivalently-compiled b=1 plan — over random geometries,
//! b ∈ {1, 2, 5, 8}, pool widths {1, 2, ncpu}, with cross-layer patch
//! fusion on and off, and with sparsity elision on and off (the
//! `without_patch_fusion` / `without_elision` twins). Edge cases ride
//! along: ragged `PIXEL_BLOCK` tails spanning image boundaries, the
//! b=1 degenerate batch, oversized-batch rejection, and
//! `validate_blocked_tile` behavior for batched fused edges.
//!
//! Per-layer plans never depend on `geom.n` (weights + subtile only),
//! so a plan compiled at batch 1 and one compiled at batch 8 hold
//! bit-identical arenas — which is what makes the cross-plan reference
//! comparison exact rather than approximate.

use std::sync::Arc;

use plum::models::ConvLayerDesc;
use plum::network::{chain_wiring, seeded_latents, NetworkExecutor, NetworkPlan};
use plum::quant::Scheme;
use plum::repetition::{EngineConfig, PIXEL_BLOCK};
use plum::tensor::Conv2dGeometry;
use plum::util::{Pool, Rng};

fn desc(name: &str, g: Conv2dGeometry) -> ConvLayerDesc {
    ConvLayerDesc { name: name.into(), geom: g, quantized: true }
}

/// Compile a quantized chain of `geoms` (each geometry's `n` overridden
/// to `batch`) with deterministic latents from `seed`. Because latents
/// and per-layer plans are independent of `n`, two calls with different
/// `batch` produce bit-compatible plans.
fn compile_chain(
    geoms: &[Conv2dGeometry],
    batch: usize,
    seed: u64,
    cfg: EngineConfig,
    pool: &Pool,
) -> Arc<NetworkPlan> {
    let descs: Vec<ConvLayerDesc> = geoms
        .iter()
        .enumerate()
        .map(|(i, g)| desc(&format!("l{i}"), Conv2dGeometry { n: batch, ..*g }))
        .collect();
    let latents = seeded_latents(&descs, seed);
    let wiring = chain_wiring(descs.len());
    Arc::new(
        NetworkPlan::compile_with_wiring(&descs, &latents, &wiring, cfg, Scheme::sb_default(), pool)
            .expect("chain compile"),
    )
}

/// Concatenated single-image forwards through a b=1 plan — the
/// reference every batched variant must reproduce bit for bit.
fn independent_singles(plan_1: &Arc<NetworkPlan>, input: &[f32], b: usize) -> Vec<f32> {
    let pool1 = Pool::new(1);
    let sample = plan_1.input_elems();
    let mut exec = NetworkExecutor::new(Arc::clone(plan_1));
    let mut out = Vec::with_capacity(b * plan_1.output_elems());
    for i in 0..b {
        out.extend_from_slice(exec.forward_pool(&input[i * sample..(i + 1) * sample], &pool1));
    }
    out
}

/// Random-case budget. Under Miri each forward costs minutes, not
/// microseconds, so the sweep shrinks to a smoke pass — the full grid
/// still runs natively in the regular CI job.
const CASES: usize = if cfg!(miri) { 2 } else { 8 };

#[test]
fn random_batched_forwards_bit_match_independent_singles() {
    const BMAX: usize = 8;
    let mut rng = Rng::new(0xBA7C);
    let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let pool1 = Pool::new(1);
    for case in 0..CASES {
        // producer: 3x3 / stride-1 / pad-1; middle consumer random over
        // the fusion grid; 1x1 tail — same family as the fusion
        // proptests, now swept over runtime batch sizes
        let c0 = 1 + rng.below(4);
        let k0 = 1 + rng.below(6);
        // 4..=9 px: odd planes force ragged PIXEL_BLOCK tails and
        // image boundaries that fall mid-block at every b > 1
        let h = 4 + rng.below(6);
        let w = 4 + rng.below(6);
        let g0 = Conv2dGeometry { n: 1, c: c0, h, w, k: k0, r: 3, s: 3, stride: 1, padding: 1 };
        let r = [1, 3][rng.below(2)];
        let s = [1, 3][rng.below(2)];
        let stride = 1 + rng.below(2);
        let padding = rng.below(2);
        let k1 = 1 + rng.below(6);
        let g1 = Conv2dGeometry { n: 1, c: k0, h, w, k: k1, r, s, stride, padding };
        let g2 = Conv2dGeometry {
            n: 1,
            c: k1,
            h: g1.out_h(),
            w: g1.out_w(),
            k: 1 + rng.below(4),
            r: 1,
            s: 1,
            stride: 1,
            padding: 0,
        };
        let geoms = [g0, g1, g2];
        let cfg = EngineConfig { subtile: [5, 8, 16][rng.below(3)], sparsity_support: true };
        let seed = 0x2000 + case as u64;
        let ctx = format!("case {case}: g0 {g0:?} g1 {g1:?} g2 {g2:?} subtile {}", cfg.subtile);

        let plan_b = compile_chain(&geoms, BMAX, seed, cfg, &pool1);
        let plan_1 = compile_chain(&geoms, 1, seed, cfg, &pool1);
        assert_eq!(plan_b.patch_fused_edges(), 2, "{ctx}");
        // the four fusion x elision twins of the batched plan — every
        // one must land on the same bits as the singles reference
        let variants: Vec<(Arc<NetworkPlan>, &str)> = vec![
            (Arc::clone(&plan_b), "fused+elided"),
            (Arc::new(plan_b.without_patch_fusion()), "unfused+elided"),
            (Arc::new(plan_b.without_elision(&pool1)), "fused+unelided"),
            (Arc::new(plan_b.without_patch_fusion().without_elision(&pool1)), "unfused+unelided"),
        ];

        let sample = plan_1.input_elems();
        let out_sample = plan_1.output_elems();
        let mut input = vec![0.0f32; BMAX * sample];
        rng.fill_normal(&mut input, 1.0);
        let singles = independent_singles(&plan_1, &input, BMAX);

        let bs: &[usize] = if cfg!(miri) { &[1, 5] } else { &[1, 2, 5, 8] };
        let widths: &[usize] = if cfg!(miri) { &[2] } else { &[1, 2, ncpu] };
        for &b in bs {
            let xb = &input[..b * sample];
            let want = &singles[..b * out_sample];
            for &threads in widths {
                let pool = Pool::new(threads);
                for (plan, label) in &variants {
                    let mut exec = NetworkExecutor::new(Arc::clone(plan));
                    let got = exec.forward_batch_pool(xb, b, &pool);
                    assert!(
                        got == want,
                        "{label} forward_batch(b={b}) != {b} singles at {threads} threads ({ctx})"
                    );
                }
            }
        }
    }
}

#[test]
fn ragged_batch_blocks_span_image_boundaries_bitwise() {
    // a 3x3 output plane is 9 pixels: for every b > 1 some PIXEL_BLOCK
    // holds pixels of two different images, and b = 5 leaves a ragged
    // tail (45 % 8 = 5) — the fused edge's blocked layout must still
    // zero-pad and gather exactly like the single-image case
    const BMAX: usize = 5;
    let g0 = Conv2dGeometry { n: 1, c: 3, h: 3, w: 3, k: 6, r: 3, s: 3, stride: 1, padding: 1 };
    let g1 = Conv2dGeometry { n: 1, c: 6, h: 3, w: 3, k: 4, r: 1, s: 1, stride: 1, padding: 0 };
    let plane = g0.out_h() * g0.out_w();
    assert_ne!(plane % PIXEL_BLOCK, 0, "plane must not align to blocks");
    assert_ne!((BMAX * plane) % PIXEL_BLOCK, 0, "batched tail must stay ragged");
    let cfg = EngineConfig { subtile: 8, sparsity_support: true };
    let pool1 = Pool::new(1);
    let plan_b = compile_chain(&[g0, g1], BMAX, 0x3001, cfg, &pool1);
    let plan_1 = compile_chain(&[g0, g1], 1, 0x3001, cfg, &pool1);
    assert_eq!(plan_b.patch_fused_edges(), 1);
    let unfused = Arc::new(plan_b.without_patch_fusion());

    let sample = plan_1.input_elems();
    let out_sample = plan_1.output_elems();
    let mut rng = Rng::new(0x3002);
    let mut input = vec![0.0f32; BMAX * sample];
    rng.fill_normal(&mut input, 1.0);
    let singles = independent_singles(&plan_1, &input, BMAX);
    for &b in &[2usize, 5] {
        for threads in [1, 2, 4] {
            let pool = Pool::new(threads);
            for (plan, label) in [(&plan_b, "fused"), (&unfused, "unfused")] {
                let mut exec = NetworkExecutor::new(Arc::clone(plan));
                let got = exec.forward_batch_pool(&input[..b * sample], b, &pool);
                assert!(
                    got == &singles[..b * out_sample],
                    "{label} ragged batch b={b} differs at {threads} threads"
                );
            }
        }
    }
}

#[test]
fn b1_runtime_batch_is_byte_identical_to_the_plain_forward() {
    // the b=1 degenerate case: forward_batch(x, 1) through a plan
    // compiled at batch 1 must return the exact bytes forward(x) does,
    // and a partial b=1 forward through a batch-4 plan must match both
    let g0 = Conv2dGeometry { n: 1, c: 4, h: 6, w: 6, k: 8, r: 3, s: 3, stride: 1, padding: 1 };
    let g1 = Conv2dGeometry { n: 1, c: 8, h: 6, w: 6, k: 5, r: 1, s: 1, stride: 1, padding: 0 };
    let cfg = EngineConfig { subtile: 8, sparsity_support: true };
    let pool = Pool::new(2);
    let pool1 = Pool::new(1);
    let plan_1 = compile_chain(&[g0, g1], 1, 0x4001, cfg, &pool1);
    let plan_4 = compile_chain(&[g0, g1], 4, 0x4001, cfg, &pool1);

    let mut rng = Rng::new(0x4002);
    let mut input = vec![0.0f32; plan_1.input_elems()];
    rng.fill_normal(&mut input, 1.0);

    let mut exec_fw = NetworkExecutor::new(Arc::clone(&plan_1));
    let want = exec_fw.forward_pool(&input, &pool).to_vec();
    let mut exec_b1 = NetworkExecutor::new(Arc::clone(&plan_1));
    assert!(
        exec_b1.forward_batch_pool(&input, 1, &pool) == &want[..],
        "forward_batch(1) differs from forward on a b=1 plan"
    );
    let mut exec_p4 = NetworkExecutor::new(Arc::clone(&plan_4));
    assert!(
        exec_p4.forward_batch_pool(&input, 1, &pool) == &want[..],
        "partial b=1 forward through a batch-4 plan differs"
    );
}

#[test]
fn blocked_tile_validation_governs_batched_fused_plans() {
    // the documented tile contract is batch-independent: a fused plan
    // rejects a non-PIXEL_BLOCK tile up front, an aligned tile keeps
    // the bit-contract at every rung, and the unfused twin accepts the
    // misaligned tile even for partial batches
    const BMAX: usize = 4;
    let g0 = Conv2dGeometry { n: 1, c: 3, h: 5, w: 5, k: 6, r: 3, s: 3, stride: 1, padding: 1 };
    let g1 = Conv2dGeometry { n: 1, c: 6, h: 5, w: 5, k: 4, r: 1, s: 1, stride: 1, padding: 0 };
    let cfg = EngineConfig { subtile: 8, sparsity_support: true };
    let pool1 = Pool::new(1);
    let plan_b = compile_chain(&[g0, g1], BMAX, 0x5001, cfg, &pool1);
    let plan_1 = compile_chain(&[g0, g1], 1, 0x5001, cfg, &pool1);
    assert!(plan_b.patch_fused_edges() > 0);

    // tile 12 cannot carry blocked patch I/O: rejected before any work
    assert!(NetworkExecutor::with_tile(Arc::clone(&plan_b), 12).is_err());

    let sample = plan_1.input_elems();
    let out_sample = plan_1.output_elems();
    let mut rng = Rng::new(0x5002);
    let mut input = vec![0.0f32; BMAX * sample];
    rng.fill_normal(&mut input, 1.0);
    let singles = independent_singles(&plan_1, &input, BMAX);

    // an aligned tile (16) carries the fused batched forward at every b
    for b in 1..=BMAX {
        let mut exec = NetworkExecutor::with_tile(Arc::clone(&plan_b), 16).unwrap();
        assert!(
            exec.forward_batch_pool(&input[..b * sample], b, &pool1)
                == &singles[..b * out_sample],
            "fused tile-16 batch b={b} differs from singles"
        );
    }
    // the unfused twin takes the misaligned tile, partial batches included
    let unfused = Arc::new(plan_b.without_patch_fusion());
    for b in [1usize, 3] {
        let mut exec = NetworkExecutor::with_tile(Arc::clone(&unfused), 12).unwrap();
        assert!(
            exec.forward_batch_pool(&input[..b * sample], b, &pool1)
                == &singles[..b * out_sample],
            "unfused tile-12 batch b={b} differs from singles"
        );
    }
}

#[test]
#[should_panic(expected = "runtime batch")]
fn oversized_runtime_batch_is_rejected() {
    // arena slots are sized for the compiled batch: running more images
    // than that must fail loudly, never read out of bounds
    let g = Conv2dGeometry { n: 1, c: 2, h: 4, w: 4, k: 3, r: 3, s: 3, stride: 1, padding: 1 };
    let pool1 = Pool::new(1);
    let plan =
        compile_chain(&[g], 2, 0x6001, EngineConfig { subtile: 8, sparsity_support: true }, &pool1);
    let input = vec![0.0f32; 3 * plan.sample_elems()];
    let mut exec = NetworkExecutor::new(plan);
    exec.forward_batch_pool(&input, 3, &pool1);
}
