//! Integration: trained-checkpoint latent weights -> rust quantizer ->
//! repetition engine, cross-checked against the AOT infer path where the
//! shapes line up, plus the §5.1 op-count shape claims on the real model
//! geometry.

use std::path::PathBuf;

use plum::quant::{self, Scheme};
use plum::repetition::{arithmetic_reduction, execute_conv2d, plan_layer, EngineConfig};
use plum::tensor::{conv2d_gemm, Tensor};
use plum::util::Rng;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("resnet20_sb.manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts not built; skipping");
        None
    }
}

/// Run the quantized conv layers of resnet20_sb's *initial* latent
/// weights (params.bin) through the engine and compare to dense GEMM.
#[test]
fn engine_runs_real_model_weights() {
    let Some(dir) = artifacts() else { return };
    let man = plum::runtime::Manifest::load(&dir, "resnet20_sb").unwrap();
    let state = man.load_initial_state().unwrap();
    let mut rng = Rng::new(99);
    let mut tested = 0;
    for layer in man.conv_layers.iter().filter(|l| l.quantized).take(4) {
        let wname = format!("{}.w", layer.name);
        let bname = format!("{}.beta", layer.name);
        let (wspec, wdata) = state
            .iter()
            .find(|(s, _)| s.name == wname)
            .expect("weight in state");
        let beta = state
            .iter()
            .find(|(s, _)| s.name == bname)
            .map(|(_, d)| d.clone())
            .expect("beta in state");
        let w = Tensor::new(&wspec.shape, wdata.clone());
        let q = quant::quantize_signed_binary(
            &w,
            &beta,
            man.config.delta_frac as f32,
            man.config.regions_per_filter,
        );
        let mut geom = layer.geom;
        geom.n = 1;
        let x = Tensor::rand_normal(&[1, geom.c, geom.h, geom.w], 1.0, &mut rng);
        let dense = conv2d_gemm(&x, &q.values, geom.stride, geom.padding);
        let plan = plan_layer(&q, geom, EngineConfig::default());
        let out = execute_conv2d(&plan, &x);
        assert!(
            dense.max_abs_diff(&out) < 1e-3,
            "layer {} diverges",
            layer.name
        );
        // signed-binary invariant on the real model's quantized weights
        assert!(q.sparsity() > 0.1, "layer {} unexpectedly dense", layer.name);
        tested += 1;
    }
    assert!(tested >= 3);
}

/// §5.1 shape on the real resnet20 geometry: SB (w/ sparsity) needs fewer
/// ops than binary; ternary needs more than SB.
#[test]
fn op_shape_on_model_geometry() {
    let Some(dir) = artifacts() else { return };
    let man = plum::runtime::Manifest::load(&dir, "resnet20_sb").unwrap();
    let mut rng = Rng::new(7);
    let cfg = EngineConfig { subtile: 8, sparsity_support: true };
    let (mut ops_b, mut ops_t, mut ops_s) = (0u64, 0u64, 0u64);
    for layer in man.conv_layers.iter().filter(|l| l.quantized) {
        let g = layer.geom;
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        ops_b += plan_layer(&quant::quantize(&w, Scheme::Binary, None), g, cfg)
            .op_counts()
            .total();
        ops_t += plan_layer(&quant::quantize(&w, Scheme::ternary_default(), None), g, cfg)
            .op_counts()
            .total();
        ops_s += plan_layer(&quant::quantize(&w, Scheme::sb_default(), None), g, cfg)
            .op_counts()
            .total();
    }
    assert!(ops_s < ops_b, "SB {ops_s} !< B {ops_b}");
    assert!(ops_t > ops_s, "T {ops_t} !> SB {ops_s}");
}

/// Arithmetic reduction is meaningful (>1x) on every quantized layer of
/// the real model geometry for SB.
#[test]
fn reduction_positive_across_model() {
    let Some(dir) = artifacts() else { return };
    let man = plum::runtime::Manifest::load(&dir, "resnet20_sb").unwrap();
    let mut rng = Rng::new(8);
    for layer in man.conv_layers.iter().filter(|l| l.quantized) {
        let g = layer.geom;
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let q = quant::quantize(&w, Scheme::sb_default(), None);
        let red = arithmetic_reduction(&plan_layer(&q, g, EngineConfig::default()));
        assert!(red > 1.0, "{}: reduction {red}", layer.name);
    }
}
