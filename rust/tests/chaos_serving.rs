//! Chaos tests for the hardened serving stack: deterministic fault
//! injection ([`FlakyBackend`] schedules) against supervised replicas,
//! checking the conservation contract end to end —
//!
//! * every *admitted* request receives exactly one **typed** reply
//!   (`Ok` / `Overloaded` at admission / `DeadlineExceeded` /
//!   `ReplicaFailed`), never a bare dropped channel;
//! * shedding is never silent (per-replica counters see it);
//! * the supervisor respawns crashed generations (service revives);
//! * repeated crashes trip the per-replica circuit breaker, after which
//!   replies stay typed and the router routes around the slot;
//! * the conservation invariant holds *across a hot swap*: a versioned
//!   redeploy under chaos drains the old generation gracefully, a
//!   failed warmup aborts the swap with the old version still serving,
//!   and a bounded drain fails stragglers typed — never silently.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use plum::coordinator::{
    flaky_factory, BatchPolicy, CircuitState, InferBackend, MockBackend, Router, ServeError,
    ServePolicy,
};

/// Batching + robustness knobs shared by the chaos runs: small bounded
/// queues (shedding reachable), real deadlines, fast supervisor backoff,
/// and a breaker threshold high enough that the conservation run probes
/// pure respawn behavior.
fn chaos_policy() -> ServePolicy {
    ServePolicy {
        batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(500) },
        queue_depth: 16,
        default_deadline: Duration::from_secs(2),
        breaker_threshold: 50,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(5),
        drain_timeout: Duration::from_secs(5),
    }
}

/// The acceptance invariant, at three pool widths: with panics every 4th
/// batch and soft errors every 3rd, every admitted request still gets
/// exactly one typed reply and the fleet keeps serving.
#[test]
fn chaos_every_admitted_request_gets_exactly_one_typed_reply() {
    for replicas in [1usize, 2, 4] {
        let router = Router::spawn(
            replicas,
            flaky_factory(
                move || {
                    Ok(MockBackend {
                        bs: 4,
                        sample: 2,
                        classes: 1,
                        delay: Duration::from_micros(150),
                    })
                },
                4, // panic every 4th batch of each generation
                3, // soft error every 3rd
                Duration::from_micros(200),
                42,
            ),
            chaos_policy(),
        )
        .unwrap();
        let n = 160usize;
        let mut admitted = Vec::new();
        let mut shed = 0usize;
        for i in 0..n {
            match router.submit(vec![i as f32, 0.5]) {
                Ok((rx, _)) => admitted.push((i, rx)),
                Err(ServeError::Overloaded { .. }) => shed += 1,
                Err(e) => panic!("[{replicas} wide] untyped admission failure: {e}"),
            }
            std::thread::sleep(Duration::from_micros(250));
        }
        let n_adm = admitted.len();
        let (mut ok, mut failed, mut expired) = (0usize, 0usize, 0usize);
        for (i, rx) in admitted {
            match rx.recv().unwrap_or_else(|_| {
                panic!("[{replicas} wide] request {i}: reply channel dropped")
            }) {
                Ok(v) => {
                    assert_eq!(v[0], i as f32 + 0.5, "[{replicas} wide] cross-wired reply");
                    ok += 1;
                }
                Err(ServeError::ReplicaFailed { .. }) => failed += 1,
                Err(ServeError::DeadlineExceeded { .. }) => expired += 1,
                Err(e) => panic!("[{replicas} wide] unexpected typed reply: {e}"),
            }
        }
        // conservation: typed outcomes partition the offered load
        assert_eq!(ok + failed + expired, n_adm, "[{replicas} wide]");
        assert_eq!(n_adm + shed, n, "[{replicas} wide]");
        assert!(ok > 0, "[{replicas} wide] nothing ever served under chaos");
        // the fault schedule really fired
        let crashes: u64 = (0..replicas).map(|i| router.stats(i).crashes.get()).sum();
        assert!(crashes > 0, "[{replicas} wide] no generation ever crashed");
        // shedding is never silent: the counters see every shed request
        // (a submit may probe several full queues, hence >=)
        let counted: u64 = (0..replicas).map(|i| router.stats(i).shed.get()).sum();
        assert!(counted >= shed as u64, "[{replicas} wide] silent shed");
        // the supervisor keeps reviving: a fresh request must succeed
        let mut revived = false;
        for _ in 0..500 {
            if let Ok((rx, _)) = router.submit(vec![1.0, 1.0]) {
                if let Ok(Ok(_)) = rx.recv() {
                    revived = true;
                    break;
                }
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(revived, "[{replicas} wide] supervisor failed to revive the fleet");
        let log = router.shutdown().unwrap();
        assert!(!log.is_empty(), "[{replicas} wide] crashes occurred but the log is empty");
    }
}

/// An always-panicking replica must trip its breaker after
/// `breaker_threshold` consecutive crash generations; from then on
/// admission fails typed (`ReplicaFailed`: every circuit open) and no
/// reply channel is ever just dropped.
#[test]
fn breaker_trips_after_repeated_crashes_and_replies_stay_typed() {
    let policy = ServePolicy {
        batch: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(100) },
        queue_depth: 4,
        default_deadline: Duration::from_secs(5),
        breaker_threshold: 2,
        backoff_base: Duration::from_micros(500),
        backoff_cap: Duration::from_millis(2),
        drain_timeout: Duration::from_secs(2),
    };
    let router = Router::spawn(
        1,
        flaky_factory(
            move || Ok(MockBackend { bs: 1, sample: 1, classes: 1, delay: Duration::ZERO }),
            1, // every batch of every generation panics
            0,
            Duration::ZERO,
            7,
        ),
        policy,
    )
    .unwrap();
    let mut opened = false;
    for _ in 0..200 {
        match router.submit(vec![1.0]) {
            Ok((rx, _)) => match rx.recv().expect("typed reply required, channel dropped") {
                Ok(v) => panic!("an always-panicking backend served {v:?}"),
                Err(ServeError::ReplicaFailed { .. } | ServeError::DeadlineExceeded { .. }) => {}
                Err(e) => panic!("unexpected typed reply: {e}"),
            },
            Err(ServeError::ReplicaFailed { .. }) => {
                // every circuit open: the breaker tripped
                opened = true;
                break;
            }
            Err(ServeError::Overloaded { .. }) => {
                std::thread::sleep(Duration::from_micros(500));
            }
            Err(e) => panic!("unexpected admission error: {e}"),
        }
        std::thread::sleep(Duration::from_micros(300));
    }
    assert!(opened, "circuit breaker never tripped");
    assert_eq!(router.stats(0).circuit(), CircuitState::Open);
    assert!(router.stats(0).crashes.get() >= 2);
    let log = router.shutdown().unwrap();
    assert!(!log.is_empty());
}

/// Deterministic backend whose logit is shifted by a constant, so a
/// reply's *plan of origin* is readable off the bits: an old generation
/// built on [`MockBackend`] serves `sum(x)`, while a swapped-in
/// `OffsetBackend` with `offset: 1000.0` serves `sum(x) + 1000`.
struct OffsetBackend {
    bs: usize,
    sample: usize,
    offset: f32,
    delay: Duration,
}

impl InferBackend for OffsetBackend {
    fn batch_size(&self) -> usize {
        self.bs
    }
    fn sample_elems(&self) -> usize {
        self.sample
    }
    fn out_elems(&self) -> usize {
        1
    }
    fn infer_batch(&self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(x.chunks(self.sample).map(|s| s.iter().sum::<f32>() + self.offset).collect())
    }
}

/// Tentpole acceptance: hot-swap while the old generation is mid-crash,
/// at three pool widths. Build a backlog on a crashing v1, deploy a v2
/// whose logits are bit-distinguishable, and check that (a) conservation
/// holds *across* the swap — every admitted request gets exactly one
/// typed reply; (b) every backlog reply that succeeded was served by the
/// old plan; (c) every post-swap reply bit-matches the new plan, i.e.
/// the retired version never answers after the flip.
#[test]
fn hot_swap_under_chaos_conserves_and_routes_to_the_new_plan() {
    for replicas in [1usize, 2, 4] {
        let router = Router::empty(chaos_policy());
        router
            .deploy(
                "m",
                replicas,
                flaky_factory(
                    move || {
                        Ok(MockBackend {
                            bs: 4,
                            sample: 2,
                            classes: 1,
                            delay: Duration::from_micros(200),
                        })
                    },
                    3, // panic every 3rd batch: v1 is crashing while it drains
                    0,
                    Duration::from_micros(200),
                    42,
                ),
            )
            .unwrap();
        // backlog on the crashing v1
        let mut admitted = Vec::new();
        let mut shed = 0usize;
        for i in 0..64 {
            match router.submit_to("m", vec![i as f32, 0.25]) {
                Ok((rx, _)) => admitted.push((i, rx)),
                Err(ServeError::Overloaded { .. }) => shed += 1,
                Err(e) => panic!("[{replicas} wide] untyped admission failure: {e}"),
            }
        }
        // swap to the offset plan; deploy returns only after v1 drained
        let swap = router
            .deploy("m", replicas, move || {
                Ok(OffsetBackend { bs: 4, sample: 2, offset: 1000.0, delay: Duration::ZERO })
            })
            .unwrap();
        assert_eq!(swap.version, 2, "[{replicas} wide]");
        let drained = swap.drained.expect("v1 existed, so the swap must report its drain");
        assert_eq!(drained.version, 1, "[{replicas} wide]");
        assert!(drained.clean, "[{replicas} wide] a 5s budget must cover this backlog");
        assert!(
            !drained.crashes.is_empty(),
            "[{replicas} wide] the fault schedule never fired: swap was not mid-crash"
        );
        // conservation across the swap
        let n_adm = admitted.len();
        let (mut ok, mut failed, mut expired) = (0usize, 0usize, 0usize);
        for (i, rx) in admitted {
            match rx.recv().unwrap_or_else(|_| {
                panic!("[{replicas} wide] request {i}: reply dropped across the swap")
            }) {
                Ok(v) => {
                    // the backlog lives on v1's queues: only the old
                    // plan may ever serve it
                    assert_eq!(
                        v[0],
                        i as f32 + 0.25,
                        "[{replicas} wide] backlog reply not from the old plan"
                    );
                    ok += 1;
                }
                Err(ServeError::ReplicaFailed { .. }) => failed += 1,
                Err(ServeError::DeadlineExceeded { .. }) => expired += 1,
                Err(e) => panic!("[{replicas} wide] unexpected typed reply: {e}"),
            }
        }
        assert_eq!(ok + failed + expired, n_adm, "[{replicas} wide] swap lost replies");
        assert_eq!(n_adm + shed, 64, "[{replicas} wide]");
        assert!(ok > 0, "[{replicas} wide] v1 never served anything");
        // post-swap traffic must bit-match the new plan, every time
        for i in 0..12 {
            let (rx, _) = router.submit_to("m", vec![i as f32, 0.25]).unwrap();
            match rx.recv().expect("post-swap reply dropped") {
                Ok(v) => assert_eq!(
                    v[0],
                    i as f32 + 0.25 + 1000.0,
                    "[{replicas} wide] post-swap reply not from v2"
                ),
                Err(e) => panic!("[{replicas} wide] fault-free v2 replied {e}"),
            }
        }
        router.shutdown().unwrap();
    }
}

/// A v2 whose warmup forward fails must abort the swap: the deploy
/// returns typed `WarmupFailed`, the served version stays v1, and the
/// (chaotic) old fleet keeps serving as if nothing happened.
#[test]
fn failed_warmup_aborts_swap_and_chaotic_old_version_keeps_serving() {
    struct WarmupBomb;
    impl InferBackend for WarmupBomb {
        fn batch_size(&self) -> usize {
            1
        }
        fn sample_elems(&self) -> usize {
            2
        }
        fn out_elems(&self) -> usize {
            1
        }
        fn infer_batch(&self, _x: &[f32]) -> anyhow::Result<Vec<f32>> {
            anyhow::bail!("device rejected the plan")
        }
    }
    let router = Router::empty(chaos_policy());
    router
        .deploy(
            "m",
            2,
            flaky_factory(
                move || {
                    Ok(MockBackend {
                        bs: 4,
                        sample: 2,
                        classes: 1,
                        delay: Duration::from_micros(150),
                    })
                },
                4,
                3,
                Duration::from_micros(150),
                11,
            ),
        )
        .unwrap();
    match router.deploy("m", 2, || Ok(WarmupBomb)) {
        Err(ServeError::WarmupFailed { model, reason }) => {
            assert_eq!(model, "m");
            assert!(reason.contains("device rejected the plan"), "{reason}");
        }
        Ok(r) => panic!("swap succeeded with a warmup bomb: {r:?}"),
        Err(e) => panic!("wrong error type for a failed warmup: {e}"),
    }
    assert_eq!(router.version("m"), Some(1), "failed swap must not bump the served version");
    let mut served = false;
    for _ in 0..500 {
        if let Ok((rx, _)) = router.submit_to("m", vec![2.0, 0.5]) {
            if let Ok(Ok(v)) = rx.recv() {
                assert_eq!(v[0], 2.5, "old plan answered with wrong logits");
                served = true;
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(served, "old version stopped serving after an aborted swap");
    router.shutdown().unwrap();
}

/// A drain that cannot finish inside its budget must still answer every
/// queued request typed: stragglers come back `ReplicaFailed` with a
/// drain reason, the report says the drain was forced, and nothing is
/// silently dropped.
#[test]
fn bounded_drain_answers_stragglers_typed_never_silently() {
    let policy = ServePolicy {
        batch: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(100) },
        queue_depth: 32,
        default_deadline: Duration::from_secs(30),
        breaker_threshold: 50,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(5),
        drain_timeout: Duration::from_millis(30),
    };
    let router = Router::empty(policy);
    router
        .deploy("m", 1, || {
            Ok(OffsetBackend { bs: 1, sample: 1, offset: 0.0, delay: Duration::from_millis(50) })
        })
        .unwrap();
    let admitted: Vec<_> =
        (0..8).map(|i| router.submit_to("m", vec![i as f32]).unwrap().0).collect();
    let swap = router
        .deploy("m", 1, || {
            Ok(OffsetBackend { bs: 1, sample: 1, offset: 0.0, delay: Duration::ZERO })
        })
        .unwrap();
    let drained = swap.drained.expect("v1 existed, so the swap must report its drain");
    assert!(!drained.clean, "a 30ms budget cannot cover a ~400ms backlog");
    assert!(drained.stragglers >= 1, "the forced drain saw no stragglers");
    let (mut ok, mut failed) = (0usize, 0usize);
    for rx in admitted {
        match rx.recv().expect("straggler reply silently dropped") {
            Ok(_) => ok += 1,
            Err(ServeError::ReplicaFailed { reason }) => {
                assert!(reason.contains("drain"), "untyped straggler reason: {reason}");
                failed += 1;
            }
            Err(e) => panic!("unexpected typed reply during a forced drain: {e}"),
        }
    }
    assert_eq!(ok + failed, 8, "conservation across a forced drain");
    assert!(failed >= 1);
    router.shutdown().unwrap();
}

/// A device-log backend for the batch-axis chaos tests: every sample
/// value shipped to the device and the live-batch size of every forward
/// are recorded, so a test can read *exactly* what reached the device.
/// The identity logit (`out = x`) makes per-request replies
/// bit-checkable. The logs are shared `Arc`s so respawned generations
/// append to the same history.
struct RecordingBackend {
    bs: usize,
    delay: Duration,
    /// every sample value the device was ever asked to run
    seen: Arc<Mutex<Vec<f32>>>,
    /// the live-batch size of every forward (one entry per forward)
    sizes: Arc<Mutex<Vec<usize>>>,
}

impl InferBackend for RecordingBackend {
    fn batch_size(&self) -> usize {
        self.bs
    }
    fn sample_elems(&self) -> usize {
        1
    }
    fn out_elems(&self) -> usize {
        1
    }
    fn infer_batch(&self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.infer_n(x, self.bs)
    }
    fn infer_n(&self, x: &[f32], n: usize) -> anyhow::Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        self.seen.lock().unwrap().extend_from_slice(x);
        self.sizes.lock().unwrap().push(n);
        Ok(x.to_vec())
    }
}

/// Batch-axis acceptance, half one: requests that expire in the queue
/// are partitioned out *before* the batch buffer is built, so their
/// bytes never reach the device. A slow first forward pins the worker,
/// a burst of tight-deadline sentinels expires behind it, and the
/// device log must show the sentinels were never shipped — while every
/// sentinel still gets its typed `DeadlineExceeded` reply.
#[test]
fn batched_worker_never_ships_expired_requests_to_the_device() {
    let seen = Arc::new(Mutex::new(Vec::new()));
    let sizes = Arc::new(Mutex::new(Vec::new()));
    let (seen_f, sizes_f) = (Arc::clone(&seen), Arc::clone(&sizes));
    let policy = ServePolicy {
        batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(500) },
        queue_depth: 32,
        default_deadline: Duration::from_secs(2),
        breaker_threshold: 50,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(5),
        drain_timeout: Duration::from_secs(5),
    };
    // jitter-only fault schedule: the batch-native entry point still
    // goes through FlakyBackend, with deterministic timing noise
    let router = Router::spawn(
        1,
        flaky_factory(
            move || {
                Ok(RecordingBackend {
                    bs: 4,
                    delay: Duration::from_millis(100),
                    seen: Arc::clone(&seen_f),
                    sizes: Arc::clone(&sizes_f),
                })
            },
            0,
            0,
            Duration::from_micros(200),
            7,
        ),
        policy,
    )
    .unwrap();
    // pin the device: one generous-deadline request, flushed alone
    // (max_wait is 500us; the burst comes well after)
    let (pin_rx, _) = router.submit(vec![1.0]).unwrap();
    std::thread::sleep(Duration::from_millis(5));
    // burst of tight-deadline sentinels: admitted (no latency signal
    // yet, so feasibility passes), then expired long before the worker
    // frees up ~95ms later
    let sentinels: Vec<_> = (0..8)
        .map(|i| {
            let v = 100.0 + i as f32;
            let deadline = Instant::now() + Duration::from_millis(20);
            let (rx, _) = router
                .submit_with_deadline(vec![v], deadline)
                .expect("no latency signal yet: the sentinel must be admitted");
            (v, rx)
        })
        .collect();
    for (v, rx) in sentinels {
        match rx.recv().expect("sentinel reply channel dropped") {
            Err(ServeError::DeadlineExceeded { waited }) => {
                assert!(waited >= Duration::from_millis(20), "expired early after {waited:?}")
            }
            Ok(out) => panic!("expired sentinel {v} was served: {out:?}"),
            Err(e) => panic!("sentinel {v}: unexpected typed reply: {e}"),
        }
    }
    assert_eq!(pin_rx.recv().unwrap().unwrap(), vec![1.0], "the pinning request was served");
    // the device keeps serving after the expiry wave
    let (rx, _) = router.submit(vec![2.0]).unwrap();
    assert_eq!(rx.recv().unwrap().unwrap(), vec![2.0]);
    router.shutdown().unwrap();
    // the device log is the proof: only the two served values were ever
    // shipped — no sentinel, no padding, in live-batches of size 1
    let seen = seen.lock().unwrap();
    assert_eq!(*seen, vec![1.0, 2.0], "expired request bytes reached the device");
    assert!(sizes.lock().unwrap().iter().all(|&n| n == 1));
}

/// Batch-axis acceptance, half two: under a real fault schedule and
/// burst traffic, multi-request batches form and run as ONE batch-native
/// forward (`infer_n` with n > 1 — no zero-padding to the device batch),
/// per-request replies stay bit-correct, and the conservation contract
/// holds: every admitted request gets exactly one typed reply.
#[test]
fn batched_chaos_conserves_replies_and_runs_live_batches_as_one_forward() {
    let seen = Arc::new(Mutex::new(Vec::new()));
    let sizes = Arc::new(Mutex::new(Vec::new()));
    let (seen_f, sizes_f) = (Arc::clone(&seen), Arc::clone(&sizes));
    let router = Router::spawn(
        1,
        flaky_factory(
            move || {
                Ok(RecordingBackend {
                    bs: 4,
                    delay: Duration::from_micros(200),
                    seen: Arc::clone(&seen_f),
                    sizes: Arc::clone(&sizes_f),
                })
            },
            5, // panic every 5th batch of each generation
            3, // soft error every 3rd
            Duration::from_micros(100),
            9,
        ),
        chaos_policy(),
    )
    .unwrap();
    // 40 bursts of 4 back-to-back submits: each burst lands inside one
    // max_wait window, so the batcher keeps forming real multi-request
    // batches under the fault schedule
    let n = 160usize;
    let mut admitted = Vec::new();
    let mut shed = 0usize;
    for i in 0..n {
        let v = 100.0 + i as f32;
        match router.submit(vec![v]) {
            Ok((rx, _)) => admitted.push((v, rx)),
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("untyped admission failure: {e}"),
        }
        if i % 4 == 3 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    let n_adm = admitted.len();
    let (mut ok, mut failed, mut expired) = (0usize, 0usize, 0usize);
    let mut served = Vec::new();
    for (v, rx) in admitted {
        match rx.recv().unwrap_or_else(|_| panic!("request {v}: reply channel dropped")) {
            Ok(out) => {
                assert_eq!(out, vec![v], "cross-wired batched reply");
                served.push(v);
                ok += 1;
            }
            Err(ServeError::ReplicaFailed { .. }) => failed += 1,
            Err(ServeError::DeadlineExceeded { .. }) => {
                assert!(
                    !seen.lock().unwrap().contains(&v),
                    "expired request {v} reached the device"
                );
                expired += 1;
            }
            Err(e) => panic!("unexpected typed reply: {e}"),
        }
    }
    // conservation: typed outcomes partition the offered load
    assert_eq!(ok + failed + expired, n_adm);
    assert_eq!(n_adm + shed, n);
    assert!(ok > 0, "nothing ever served under chaos");
    assert!(router.stats(0).crashes.get() > 0, "the fault schedule never fired");
    let seen = seen.lock().unwrap();
    let sizes = sizes.lock().unwrap();
    // every served value was really shipped, and the device log holds
    // *only* admitted sample values: the batch-native path sends live
    // requests verbatim, never zero-padding to the device batch
    for v in &served {
        assert!(seen.contains(v), "served value {v} missing from the device log");
    }
    for v in seen.iter() {
        assert!(
            (100.0..100.0 + n as f32).contains(v),
            "non-request value {v} (padding?) reached the device"
        );
    }
    assert!(sizes.iter().all(|&b| (1..=4).contains(&b)), "live batch outside 1..=4");
    assert!(
        sizes.iter().any(|&b| b > 1),
        "burst traffic never formed a multi-request batch: {sizes:?}"
    );
    router.shutdown().unwrap();
}
