//! Chaos tests for the hardened serving stack: deterministic fault
//! injection ([`FlakyBackend`] schedules) against supervised replicas,
//! checking the conservation contract end to end —
//!
//! * every *admitted* request receives exactly one **typed** reply
//!   (`Ok` / `Overloaded` at admission / `DeadlineExceeded` /
//!   `ReplicaFailed`), never a bare dropped channel;
//! * shedding is never silent (per-replica counters see it);
//! * the supervisor respawns crashed generations (service revives);
//! * repeated crashes trip the per-replica circuit breaker, after which
//!   replies stay typed and the router routes around the slot.

use std::time::Duration;

use plum::coordinator::{
    flaky_factory, BatchPolicy, CircuitState, MockBackend, Router, ServeError, ServePolicy,
};

/// Batching + robustness knobs shared by the chaos runs: small bounded
/// queues (shedding reachable), real deadlines, fast supervisor backoff,
/// and a breaker threshold high enough that the conservation run probes
/// pure respawn behavior.
fn chaos_policy() -> ServePolicy {
    ServePolicy {
        batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(500) },
        queue_depth: 16,
        default_deadline: Duration::from_secs(2),
        breaker_threshold: 50,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(5),
    }
}

/// The acceptance invariant, at three pool widths: with panics every 4th
/// batch and soft errors every 3rd, every admitted request still gets
/// exactly one typed reply and the fleet keeps serving.
#[test]
fn chaos_every_admitted_request_gets_exactly_one_typed_reply() {
    for replicas in [1usize, 2, 4] {
        let router = Router::spawn(
            replicas,
            flaky_factory(
                move || {
                    Ok(MockBackend {
                        bs: 4,
                        sample: 2,
                        classes: 1,
                        delay: Duration::from_micros(150),
                    })
                },
                4, // panic every 4th batch of each generation
                3, // soft error every 3rd
                Duration::from_micros(200),
                42,
            ),
            chaos_policy(),
        )
        .unwrap();
        let n = 160usize;
        let mut admitted = Vec::new();
        let mut shed = 0usize;
        for i in 0..n {
            match router.submit(vec![i as f32, 0.5]) {
                Ok((rx, _)) => admitted.push((i, rx)),
                Err(ServeError::Overloaded { .. }) => shed += 1,
                Err(e) => panic!("[{replicas} wide] untyped admission failure: {e}"),
            }
            std::thread::sleep(Duration::from_micros(250));
        }
        let n_adm = admitted.len();
        let (mut ok, mut failed, mut expired) = (0usize, 0usize, 0usize);
        for (i, rx) in admitted {
            match rx.recv().unwrap_or_else(|_| {
                panic!("[{replicas} wide] request {i}: reply channel dropped")
            }) {
                Ok(v) => {
                    assert_eq!(v[0], i as f32 + 0.5, "[{replicas} wide] cross-wired reply");
                    ok += 1;
                }
                Err(ServeError::ReplicaFailed { .. }) => failed += 1,
                Err(ServeError::DeadlineExceeded { .. }) => expired += 1,
                Err(e) => panic!("[{replicas} wide] unexpected typed reply: {e}"),
            }
        }
        // conservation: typed outcomes partition the offered load
        assert_eq!(ok + failed + expired, n_adm, "[{replicas} wide]");
        assert_eq!(n_adm + shed, n, "[{replicas} wide]");
        assert!(ok > 0, "[{replicas} wide] nothing ever served under chaos");
        // the fault schedule really fired
        let crashes: u64 = (0..replicas).map(|i| router.stats(i).crashes.get()).sum();
        assert!(crashes > 0, "[{replicas} wide] no generation ever crashed");
        // shedding is never silent: the counters see every shed request
        // (a submit may probe several full queues, hence >=)
        let counted: u64 = (0..replicas).map(|i| router.stats(i).shed.get()).sum();
        assert!(counted >= shed as u64, "[{replicas} wide] silent shed");
        // the supervisor keeps reviving: a fresh request must succeed
        let mut revived = false;
        for _ in 0..500 {
            if let Ok((rx, _)) = router.submit(vec![1.0, 1.0]) {
                if let Ok(Ok(_)) = rx.recv() {
                    revived = true;
                    break;
                }
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(revived, "[{replicas} wide] supervisor failed to revive the fleet");
        let log = router.shutdown().unwrap();
        assert!(!log.is_empty(), "[{replicas} wide] crashes occurred but the log is empty");
    }
}

/// An always-panicking replica must trip its breaker after
/// `breaker_threshold` consecutive crash generations; from then on
/// admission fails typed (`ReplicaFailed`: every circuit open) and no
/// reply channel is ever just dropped.
#[test]
fn breaker_trips_after_repeated_crashes_and_replies_stay_typed() {
    let policy = ServePolicy {
        batch: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(100) },
        queue_depth: 4,
        default_deadline: Duration::from_secs(5),
        breaker_threshold: 2,
        backoff_base: Duration::from_micros(500),
        backoff_cap: Duration::from_millis(2),
    };
    let router = Router::spawn(
        1,
        flaky_factory(
            move || Ok(MockBackend { bs: 1, sample: 1, classes: 1, delay: Duration::ZERO }),
            1, // every batch of every generation panics
            0,
            Duration::ZERO,
            7,
        ),
        policy,
    )
    .unwrap();
    let mut opened = false;
    for _ in 0..200 {
        match router.submit(vec![1.0]) {
            Ok((rx, _)) => match rx.recv().expect("typed reply required, channel dropped") {
                Ok(v) => panic!("an always-panicking backend served {v:?}"),
                Err(ServeError::ReplicaFailed { .. } | ServeError::DeadlineExceeded { .. }) => {}
                Err(e) => panic!("unexpected typed reply: {e}"),
            },
            Err(ServeError::ReplicaFailed { .. }) => {
                // every circuit open: the breaker tripped
                opened = true;
                break;
            }
            Err(ServeError::Overloaded { .. }) => {
                std::thread::sleep(Duration::from_micros(500));
            }
            Err(e) => panic!("unexpected admission error: {e}"),
        }
        std::thread::sleep(Duration::from_micros(300));
    }
    assert!(opened, "circuit breaker never tripped");
    assert_eq!(router.stats(0).circuit(), CircuitState::Open);
    assert!(router.stats(0).crashes.get() >= 2);
    let log = router.shutdown().unwrap();
    assert!(!log.is_empty());
}
