//! Cross-check: the rust model-zoo descriptors (S9) must agree with the
//! conv-layer geometry the python Tape recorded into the manifests —
//! guarding against the two sides drifting apart.

use std::path::PathBuf;

use plum::models;
use plum::runtime::Manifest;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("resnet20_sb.manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts not built; skipping");
        None
    }
}

#[test]
fn cifar_resnet20_descriptor_matches_manifest() {
    let Some(dir) = artifacts() else { return };
    let man = Manifest::load(&dir, "resnet20_sb").unwrap();
    let desc = models::cifar_resnet_layers(20, 1.0, man.config.image_size, 1);
    assert_eq!(desc.len(), man.conv_layers.len(), "layer count");
    for (d, m) in desc.iter().zip(&man.conv_layers) {
        assert_eq!(d.geom.k, m.geom.k, "{}: K", m.name);
        assert_eq!(d.geom.c, m.geom.c, "{}: C", m.name);
        assert_eq!(d.geom.h, m.geom.h, "{}: H", m.name);
        assert_eq!(d.geom.stride, m.geom.stride, "{}: stride", m.name);
        assert_eq!(d.quantized, m.quantized, "{}: quantized", m.name);
    }
}

#[test]
fn resnet18_descriptor_matches_manifest() {
    let Some(dir) = artifacts() else { return };
    if !dir.join("resnet18sb.manifest.json").exists() {
        return;
    }
    let man = Manifest::load(&dir, "resnet18sb").unwrap();
    let desc = models::resnet18_layers(man.config.width_mult, man.config.image_size, 1);
    assert_eq!(desc.len(), man.conv_layers.len(), "layer count");
    for (d, m) in desc.iter().zip(&man.conv_layers) {
        assert_eq!(d.geom.k, m.geom.k, "{}: K", m.name);
        assert_eq!(d.geom.c, m.geom.c, "{}: C", m.name);
        assert_eq!(d.geom.h, m.geom.h, "{}: H", m.name);
    }
}

#[test]
fn manifest_param_counts_consistent() {
    let Some(dir) = artifacts() else { return };
    let man = Manifest::load(&dir, "resnet20_sb").unwrap();
    // params.bin must slice exactly into the state specs
    let state = man.load_initial_state().unwrap();
    let total: usize = state
        .iter()
        .filter(|(s, _)| s.group == "params")
        .map(|(s, _)| s.elements())
        .sum();
    assert_eq!(total, man.param_count);
    // effectual <= quantized weight count
    let qtotal: usize = man
        .conv_layers
        .iter()
        .filter(|l| l.quantized)
        .map(|l| l.geom.weight_count())
        .sum();
    assert!(man.effectual_params_init <= qtotal);
    assert!(man.effectual_params_init > 0);
}

#[test]
fn vgg_alexnet_descriptors_match_manifests() {
    let Some(dir) = artifacts() else { return };
    for (name, layers) in [
        ("vgg_small_cifar_sb", models::vgg_small_layers(0.5, 32, 1)),
        ("alexnet_small_svhn_sb", models::alexnet_small_layers(0.5, 32, 1)),
    ] {
        if !dir.join(format!("{name}.manifest.json")).exists() {
            continue;
        }
        let man = Manifest::load(&dir, name).unwrap();
        assert_eq!(layers.len(), man.conv_layers.len(), "{name}: layer count");
        for (d, m) in layers.iter().zip(&man.conv_layers) {
            assert_eq!(d.geom.k, m.geom.k, "{name}/{}: K", m.name);
            assert_eq!(d.geom.c, m.geom.c, "{name}/{}: C", m.name);
            assert_eq!(d.geom.h, m.geom.h, "{name}/{}: H", m.name);
            assert_eq!(d.quantized, m.quantized, "{name}/{}", m.name);
        }
    }
}

// ---------------------------------------------------------------------------
// failure injection: corrupt/missing artifacts must error, not panic
// ---------------------------------------------------------------------------

#[test]
fn missing_manifest_is_an_error() {
    let dir = std::env::temp_dir();
    assert!(Manifest::load(&dir, "no_such_model").is_err());
}

#[test]
fn corrupt_manifest_is_an_error() {
    let dir = std::env::temp_dir().join("plum_corrupt_test");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bad.manifest.json"), b"{ not json").unwrap();
    assert!(Manifest::load(&dir, "bad").is_err());
    std::fs::write(dir.join("bad2.manifest.json"), b"{\"name\": \"bad2\"}").unwrap();
    assert!(Manifest::load(&dir, "bad2").is_err(), "missing fields must error");
}

#[test]
fn truncated_params_bin_is_an_error() {
    let Some(src) = artifacts() else { return };
    let dir = std::env::temp_dir().join("plum_trunc_test");
    std::fs::create_dir_all(&dir).unwrap();
    for f in std::fs::read_dir(&src).unwrap().flatten() {
        let name = f.file_name().into_string().unwrap();
        if name.starts_with("r8sb_p050.") {
            std::fs::copy(f.path(), dir.join(&name)).unwrap();
        }
    }
    // truncate the params blob
    let pb = dir.join("r8sb_p050.params.bin");
    let bytes = std::fs::read(&pb).unwrap();
    std::fs::write(&pb, &bytes[..bytes.len() / 2]).unwrap();
    let man = Manifest::load(&dir, "r8sb_p050").unwrap();
    assert!(man.load_initial_state().is_err());
}
