//! End-to-end network-executor equality on a small CIFAR ResNet
//! (depth 8):
//!
//! * the fused, arena-based `NetworkExecutor` forward pass must
//!   **bit-match** a layer-by-layer reference built from the public
//!   single-layer primitives (`execute_conv2d_pool` for engine layers,
//!   `conv2d_naive` for the fp stem) with separate ReLU / option-A
//!   residual passes — at thread counts {1, 2, ncpu};
//! * a fully `conv2d_naive` reference (quantized dense weights) must
//!   agree within a small relative tolerance — the engine re-associates
//!   f32 sums (shared pattern partial sums), so exact bit equality
//!   against the naive order is not defined there.

use std::sync::Arc;

use plum::models::{self, ConvLayerDesc};
use plum::network::{seeded_latents, NetworkExecutor, NetworkPlan};
use plum::repetition::{execute_conv2d_pool, EngineConfig};
use plum::tensor::{conv2d_naive, Tensor};
use plum::util::{Pool, Rng};

fn relu(t: &mut Tensor) {
    for v in t.data_mut() {
        *v = v.max(0.0);
    }
}

/// Option-A shortcut: spatial subsample by the stride ratio, zero-pad
/// extra channels — applied before the block's final ReLU.
fn add_option_a(out: &mut Tensor, src: &Tensor) {
    let (n, k, oh, ow) = (out.dim(0), out.dim(1), out.dim(2), out.dim(3));
    let (_, c, h, _) = (src.dim(0), src.dim(1), src.dim(2), src.dim(3));
    let st = h / oh;
    assert_eq!(h, oh * st, "shortcut stride must divide evenly");
    for ni in 0..n {
        for ci in 0..c.min(k) {
            for oy in 0..oh {
                for ox in 0..ow {
                    let v = out.at4(ni, ci, oy, ox) + src.at4(ni, ci, oy * st, ox * st);
                    out.set4(ni, ci, oy, ox, v);
                }
            }
        }
    }
}

/// Layer-by-layer reference over the compiled plan: engine layers run
/// unfused through `execute_conv2d_pool`, the fp stem through
/// `conv2d_naive`; residual and ReLU are separate passes in the same
/// elementwise order the fused executor uses.
fn reference_forward(plan: &NetworkPlan, x: &Tensor, pool: &Pool) -> Tensor {
    let mut acts: Vec<Tensor> = vec![x.clone()];
    for layer in &plan.layers {
        let xin = acts.last().unwrap();
        let mut y = match &layer.plan {
            Some(lp) => execute_conv2d_pool(lp, xin, pool),
            None => conv2d_naive(xin, &layer.weights, layer.geom.stride, layer.geom.padding),
        };
        if let Some(ai) = layer.residual_from {
            add_option_a(&mut y, &acts[ai]);
        }
        if layer.relu {
            relu(&mut y);
        }
        acts.push(y);
    }
    acts.pop().unwrap()
}

/// Fully-naive reference: every conv through `conv2d_naive` on the
/// quantized dense weights (engine layers) / latents (stem).
fn naive_forward(plan: &NetworkPlan, x: &Tensor) -> Tensor {
    let mut acts: Vec<Tensor> = vec![x.clone()];
    for layer in &plan.layers {
        let xin = acts.last().unwrap();
        let mut y = conv2d_naive(xin, &layer.weights, layer.geom.stride, layer.geom.padding);
        if let Some(ai) = layer.residual_from {
            add_option_a(&mut y, &acts[ai]);
        }
        if layer.relu {
            relu(&mut y);
        }
        acts.push(y);
    }
    acts.pop().unwrap()
}

fn compile_resnet8(batch: usize, image: usize) -> (Arc<NetworkPlan>, Vec<ConvLayerDesc>) {
    let descs = models::cifar_resnet_layers(8, 0.5, image, batch);
    let latents = seeded_latents(&descs, 0xBEEF);
    let cfg = EngineConfig { subtile: 8, sparsity_support: true };
    let plan = NetworkPlan::compile_with_weights(
        &descs,
        &latents,
        cfg,
        plum::quant::Scheme::sb_default(),
        &Pool::new(1),
    )
    .unwrap();
    (Arc::new(plan), descs)
}

#[test]
fn network_forward_bit_matches_layer_reference_at_every_width() {
    let (plan, _) = compile_resnet8(2, 16);
    let mut rng = Rng::new(99);
    let x = Tensor::rand_normal(&[2, 3, 16, 16], 1.0, &mut rng);

    let reference = reference_forward(&plan, &x, &Pool::new(1));
    assert_eq!(reference.len(), plan.output_elems());

    let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    for threads in [1, 2, ncpu] {
        let pool = Pool::new(threads);
        let mut exec = NetworkExecutor::new(Arc::clone(&plan));
        let out = exec.forward_pool(x.data(), &pool);
        assert!(
            out == reference.data(),
            "{threads}-thread fused forward differs from the layer-by-layer reference"
        );
    }
}

#[test]
fn network_forward_agrees_with_naive_chain() {
    let (plan, _) = compile_resnet8(1, 16);
    let mut rng = Rng::new(100);
    let x = Tensor::rand_normal(&[1, 3, 16, 16], 1.0, &mut rng);

    let naive = naive_forward(&plan, &x);
    let mut exec = NetworkExecutor::new(Arc::clone(&plan));
    let out = exec.forward_pool(x.data(), &Pool::new(2));

    let scale = naive.data().iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1.0);
    let max_diff = out
        .iter()
        .zip(naive.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff < 1e-3 * scale,
        "fused network diverged from naive chain: {max_diff} (scale {scale})"
    );
}

#[test]
fn plans_are_built_once_and_reused_across_requests() {
    // the compiled plan is shared; repeated forwards on one executor are
    // bit-identical and land in the same arena storage (no per-request
    // activation allocation)
    let (plan, descs) = compile_resnet8(2, 16);
    assert_eq!(plan.num_layers(), descs.len());
    let pool = Pool::new(2);
    let mut exec = NetworkExecutor::new(Arc::clone(&plan));
    let mut rng = Rng::new(101);
    let mut a = vec![0.0f32; plan.input_elems()];
    let mut b = vec![0.0f32; plan.input_elems()];
    rng.fill_normal(&mut a, 1.0);
    rng.fill_normal(&mut b, 1.0);

    let (ptr_a, out_a) = {
        let o = exec.forward_pool(&a, &pool);
        (o.as_ptr(), o.to_vec())
    };
    let ptr_b = exec.forward_pool(&b, &pool).as_ptr();
    assert_eq!(ptr_a, ptr_b, "requests must reuse the same activation arena");
    let out_a2 = exec.forward_pool(&a, &pool).to_vec();
    assert!(out_a == out_a2, "same input must reproduce the same bits");
}
