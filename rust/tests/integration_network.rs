//! End-to-end network-executor equality on small CIFAR models:
//!
//! * the fused, arena-based `NetworkExecutor` forward pass must
//!   **bit-match** a layer-by-layer reference built from the public
//!   single-layer primitives (`execute_conv2d_pool` for engine layers,
//!   `conv2d_naive` for the fp stem) with separate ReLU / residual
//!   passes — at thread counts {1, 2, ncpu}; this covers the option-A
//!   CIFAR ResNet **and** the projection-shortcut (resnet18-style)
//!   branching topology, with cross-layer patch reuse both on and off;
//! * a fully `conv2d_naive` reference (quantized dense weights) must
//!   agree within a small relative tolerance — the engine re-associates
//!   f32 sums (shared pattern partial sums), so exact bit equality
//!   against the naive order is not defined there.

use std::sync::Arc;

use plum::models::{self, ConvLayerDesc};
use plum::network::{seeded_latents, NetworkExecutor, NetworkPlan};
use plum::repetition::{execute_conv2d_pool, option_a_stride, EngineConfig};
use plum::tensor::{conv2d_naive, Tensor};
use plum::util::{Pool, Rng};

fn relu(t: &mut Tensor) {
    for v in t.data_mut() {
        *v = v.max(0.0);
    }
}

/// Residual shortcut add: identity when shapes match exactly, otherwise
/// the option-A view (spatial subsample by the stride ratio, zero-pad
/// extra channels) — applied before the block's final ReLU. The stride
/// *covers* the source rather than dividing it exactly, so odd sizes
/// (7 -> 4 at stride 2) work like the executor's fused epilogue.
fn add_shortcut(out: &mut Tensor, src: &Tensor) {
    let (n, k, oh, ow) = (out.dim(0), out.dim(1), out.dim(2), out.dim(3));
    let (_, c, h, _) = (src.dim(0), src.dim(1), src.dim(2), src.dim(3));
    let st = option_a_stride(h, oh);
    assert_eq!(oh, (h - 1) / st + 1, "shortcut stride must cover the source");
    for ni in 0..n {
        for ci in 0..c.min(k) {
            for oy in 0..oh {
                for ox in 0..ow {
                    let v = out.at4(ni, ci, oy, ox) + src.at4(ni, ci, oy * st, ox * st);
                    out.set4(ni, ci, oy, ox, v);
                }
            }
        }
    }
}

/// Layer-by-layer reference over the compiled plan: engine layers run
/// unfused through `execute_conv2d_pool`, the fp stem through
/// `conv2d_naive`; each layer reads the activation its wiring names
/// (branching included), and residual / ReLU are separate passes in the
/// same elementwise order the fused executor uses.
fn reference_forward(plan: &NetworkPlan, x: &Tensor, pool: &Pool) -> Tensor {
    let mut acts: Vec<Tensor> = vec![x.clone()];
    for layer in &plan.layers {
        let xin = &acts[layer.input];
        let mut y = match &layer.plan {
            Some(lp) => execute_conv2d_pool(lp, xin, pool),
            None => conv2d_naive(xin, &layer.weights, layer.geom.stride, layer.geom.padding),
        };
        if let Some(ai) = layer.residual_from {
            add_shortcut(&mut y, &acts[ai]);
        }
        if layer.relu {
            relu(&mut y);
        }
        acts.push(y);
    }
    acts.pop().unwrap()
}

/// Fully-naive reference: every conv through `conv2d_naive` on the
/// quantized dense weights (engine layers) / latents (stem).
fn naive_forward(plan: &NetworkPlan, x: &Tensor) -> Tensor {
    let mut acts: Vec<Tensor> = vec![x.clone()];
    for layer in &plan.layers {
        let xin = &acts[layer.input];
        let mut y = conv2d_naive(xin, &layer.weights, layer.geom.stride, layer.geom.padding);
        if let Some(ai) = layer.residual_from {
            add_shortcut(&mut y, &acts[ai]);
        }
        if layer.relu {
            relu(&mut y);
        }
        acts.push(y);
    }
    acts.pop().unwrap()
}

fn compile_descs(descs: &[ConvLayerDesc], seed: u64) -> Arc<NetworkPlan> {
    let latents = seeded_latents(descs, seed);
    let cfg = EngineConfig { subtile: 8, sparsity_support: true };
    let plan = NetworkPlan::compile_with_weights(
        descs,
        &latents,
        cfg,
        plum::quant::Scheme::sb_default(),
        &Pool::new(1),
    )
    .unwrap();
    Arc::new(plan)
}

fn compile_resnet8(batch: usize, image: usize) -> (Arc<NetworkPlan>, Vec<ConvLayerDesc>) {
    let descs = models::cifar_resnet_layers(8, 0.5, image, batch);
    (compile_descs(&descs, 0xBEEF), descs)
}

/// Shared bit-equality harness: fused executor vs layer-by-layer
/// reference at threads {1, 2, ncpu}.
fn assert_bit_matches_reference(plan: &Arc<NetworkPlan>, x: &Tensor, what: &str) {
    let reference = reference_forward(plan, x, &Pool::new(1));
    assert_eq!(reference.len(), plan.output_elems());
    let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    for threads in [1, 2, ncpu] {
        let pool = Pool::new(threads);
        let mut exec = NetworkExecutor::new(Arc::clone(plan));
        let out = exec.forward_pool(x.data(), &pool);
        assert!(
            out == reference.data(),
            "{what}: {threads}-thread fused forward differs from the layer-by-layer reference"
        );
    }
}

#[test]
fn network_forward_bit_matches_layer_reference_at_every_width() {
    let (plan, _) = compile_resnet8(2, 16);
    let mut rng = Rng::new(99);
    let x = Tensor::rand_normal(&[2, 3, 16, 16], 1.0, &mut rng);
    assert_bit_matches_reference(&plan, &x, "resnet8");
}

#[test]
fn projection_shortcut_forward_bit_matches_reference_at_every_width() {
    // resnet18-style branching: 1x1 projection layers ride the residual
    // edges; the executor's live-range arena must reproduce the
    // layer-by-layer reference bit for bit at every pool width
    let descs = models::cifar_resnet18_layers(0.5, 16, 2);
    let plan = compile_descs(&descs, 0xD00D);
    assert!(plan.layers.iter().any(|l| l.geom.r == 1), "plan must carry projections");
    let mut rng = Rng::new(102);
    let x = Tensor::rand_normal(&[2, 3, 16, 16], 1.0, &mut rng);
    assert_bit_matches_reference(&plan, &x, "resnet18c");
}

#[test]
fn patch_reuse_chain_bit_matches_reference_at_every_width() {
    // consecutive-1x1 chain: every inter-1x1 edge fuses (producer
    // scatters patch blocks, consumers skip im2col); the fused plan and
    // its fusion-disabled twin must both bit-match the reference
    // 11px image -> 242 output pixels: a ragged final PIXEL_BLOCK, so
    // the zero-padded blocked tail is exercised end to end
    let descs = models::conv1x1_chain_layers(6, 16, 11, 2);
    let plan = compile_descs(&descs, 0xFACE);
    assert!(plan.patch_fused_edges() >= 4, "1x1 chain must fuse its inner edges");
    let mut rng = Rng::new(103);
    let x = Tensor::rand_normal(&[2, 3, 11, 11], 1.0, &mut rng);
    assert_bit_matches_reference(&plan, &x, "chain1x1 fused");
    let unfused = Arc::new(plan.without_patch_fusion());
    assert_eq!(unfused.patch_fused_edges(), 0);
    assert_bit_matches_reference(&unfused, &x, "chain1x1 unfused");
}

#[test]
fn generalized_patch_reuse_bit_matches_reference_on_resnets() {
    // with the generalized blocked gather, resnet block-internal 3x3
    // edges fuse; fused and fusion-disabled plans must both bit-match
    // the layer-by-layer NCHW reference
    let (plan, _) = compile_resnet8(2, 16);
    assert!(plan.patch_fused_edges() > 0, "resnet8 must fuse its block-internal edges");
    let mut rng = Rng::new(105);
    let x = Tensor::rand_normal(&[2, 3, 16, 16], 1.0, &mut rng);
    assert_bit_matches_reference(&plan, &x, "resnet8 fused");
    let unfused = Arc::new(plan.without_patch_fusion());
    assert_eq!(unfused.patch_fused_edges(), 0);
    assert_bit_matches_reference(&unfused, &x, "resnet8 unfused");
}

#[test]
fn odd_size_resnet_bit_matches_reference() {
    // image 7: stride-2 stages output 4 then 2 (no exact division
    // anywhere) — compile, run fused, and bit-match the reference;
    // this used to panic in PostOp::validate / fail wiring validation
    let descs = models::cifar_resnet_layers(8, 1.0, 7, 2);
    let plan = compile_descs(&descs, 0x0DD);
    assert!(plan.layers.iter().any(|l| l.residual_from.is_some()));
    let mut rng = Rng::new(106);
    let x = Tensor::rand_normal(&[2, 3, 7, 7], 1.0, &mut rng);
    assert_bit_matches_reference(&plan, &x, "resnet8@7px");
}

#[test]
fn network_forward_agrees_with_naive_chain() {
    let (plan, _) = compile_resnet8(1, 16);
    let mut rng = Rng::new(100);
    let x = Tensor::rand_normal(&[1, 3, 16, 16], 1.0, &mut rng);

    let naive = naive_forward(&plan, &x);
    let mut exec = NetworkExecutor::new(Arc::clone(&plan));
    let out = exec.forward_pool(x.data(), &Pool::new(2));

    let scale = naive.data().iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1.0);
    let max_diff = out
        .iter()
        .zip(naive.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff < 1e-3 * scale,
        "fused network diverged from naive chain: {max_diff} (scale {scale})"
    );
}

#[test]
fn projection_network_agrees_with_naive_chain() {
    let descs = models::cifar_resnet18_layers(0.5, 16, 1);
    let plan = compile_descs(&descs, 0xD00D);
    let mut rng = Rng::new(104);
    let x = Tensor::rand_normal(&[1, 3, 16, 16], 1.0, &mut rng);

    let naive = naive_forward(&plan, &x);
    let mut exec = NetworkExecutor::new(Arc::clone(&plan));
    let out = exec.forward_pool(x.data(), &Pool::new(2));

    let scale = naive.data().iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1.0);
    let max_diff = out
        .iter()
        .zip(naive.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff < 1e-3 * scale,
        "projection network diverged from naive chain: {max_diff} (scale {scale})"
    );
}

#[test]
fn plans_are_built_once_and_reused_across_requests() {
    // the compiled plan is shared; repeated forwards on one executor are
    // bit-identical and land in the same arena storage (no per-request
    // activation allocation)
    let (plan, descs) = compile_resnet8(2, 16);
    assert_eq!(plan.num_layers(), descs.len());
    let pool = Pool::new(2);
    let mut exec = NetworkExecutor::new(Arc::clone(&plan));
    let mut rng = Rng::new(101);
    let mut a = vec![0.0f32; plan.input_elems()];
    let mut b = vec![0.0f32; plan.input_elems()];
    rng.fill_normal(&mut a, 1.0);
    rng.fill_normal(&mut b, 1.0);

    let (ptr_a, out_a) = {
        let o = exec.forward_pool(&a, &pool);
        (o.as_ptr(), o.to_vec())
    };
    let ptr_b = exec.forward_pool(&b, &pool).as_ptr();
    assert_eq!(ptr_a, ptr_b, "requests must reuse the same activation arena");
    let out_a2 = exec.forward_pool(&a, &pool).to_vec();
    assert!(out_a == out_a2, "same input must reproduce the same bits");
}
