//! Property-style tests on coordinator invariants (routing, batching,
//! response integrity, conservation under injected faults) and on
//! quantizer/engine invariants.
//!
//! proptest is not in the offline vendor set, so this uses the same
//! technique with the repo's deterministic RNG: many seeded random
//! configurations per property, with the failing seed printed on assert.

use std::sync::Arc;
use std::time::Duration;

use plum::coordinator::{
    flaky_factory, spawn_worker, BatchPolicy, InferBackend, MockBackend, Router, ServeError,
    ServePolicy,
};
use plum::models;
use plum::network::{EngineBackend, NetworkPlan};
use plum::quant::{self, default_beta, Scheme};
use plum::repetition::{execute_conv2d, plan_layer, EngineConfig};
use plum::tensor::{conv2d_gemm, Conv2dGeometry, Tensor};
use plum::util::Rng;

const CASES: usize = 25;

/// Test policy: the given batching knobs plus generous deadlines (these
/// properties probe conservation and wiring, not expiry) and fast
/// supervisor backoff so chaos cases converge quickly.
fn test_policy(max_batch: usize, max_wait: Duration) -> ServePolicy {
    ServePolicy {
        batch: BatchPolicy { max_batch, max_wait },
        default_deadline: Duration::from_secs(60),
        backoff_base: Duration::from_micros(500),
        backoff_cap: Duration::from_millis(2),
        ..ServePolicy::default()
    }
}

/// Property: for any (bs, #requests, batching policy), every request is
/// answered exactly once with its own payload's logits.
#[test]
fn prop_every_request_answered_with_own_result() {
    for case in 0..CASES {
        let mut rng = Rng::new(1000 + case as u64);
        let bs = 1 + rng.below(8);
        let sample = 1 + rng.below(6);
        let classes = 1 + rng.below(4);
        let n_req = 1 + rng.below(60);
        let max_batch = 1 + rng.below(12);
        let max_wait = Duration::from_micros(rng.below(3000) as u64);
        let delay = Duration::from_micros(rng.below(300) as u64);
        let w = spawn_worker(
            move || Ok(MockBackend { bs, sample, classes, delay }),
            test_policy(max_batch, max_wait),
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..n_req {
            let x: Vec<f32> = (0..sample).map(|j| (i * 31 + j) as f32).collect();
            let expect: f32 = x.iter().sum();
            rxs.push((expect, w.submit(x).unwrap()));
        }
        for (expect, rx) in rxs {
            let logits = rx
                .recv()
                .unwrap_or_else(|_| panic!("case {case}: dropped reply"))
                .unwrap_or_else(|e| panic!("case {case}: error reply {e}"));
            assert_eq!(logits.len(), classes, "case {case}");
            assert_eq!(logits[0], expect, "case {case}: cross-wired response");
        }
        w.shutdown().unwrap();
    }
}

/// Property: the router never loses requests and completes them all,
/// for any replica count and load pattern.
#[test]
fn prop_router_conserves_requests() {
    for case in 0..10 {
        let mut rng = Rng::new(2000 + case as u64);
        let replicas = 1 + rng.below(4);
        let n_req = 1 + rng.below(80);
        let workers = (0..replicas)
            .map(|_| {
                spawn_worker(
                    move || {
                        Ok(MockBackend {
                            bs: 4,
                            sample: 2,
                            classes: 1,
                            delay: Duration::from_micros(200),
                        })
                    },
                    test_policy(4, Duration::from_millis(1)),
                )
                .unwrap()
            })
            .collect();
        let router = Router::new(workers);
        let mut rxs = Vec::new();
        for i in 0..n_req {
            let (rx, _) = router.submit(vec![i as f32, 1.0]).unwrap();
            rxs.push((i, rx));
        }
        for (i, rx) in rxs {
            let v = rx.recv().unwrap().unwrap();
            assert_eq!(v[0], i as f32 + 1.0, "case {case}");
        }
        assert_eq!(router.completed(), n_req as u64, "case {case}");
        router.shutdown().unwrap();
    }
}

/// Property: conservation holds under *injected faults*. Supervised
/// replicas panic and error on a deterministic schedule; still, every
/// admitted request gets exactly one typed reply (Ok / ReplicaFailed /
/// DeadlineExceeded), nothing hangs, and shedding is never silent (the
/// per-replica counters account for every shed).
#[test]
fn prop_chaos_conservation_under_injected_faults() {
    for case in 0..5u64 {
        let mut rng = Rng::new(7000 + case);
        let replicas = 1 + rng.below(3);
        let n_req = 30 + rng.below(40);
        let policy = ServePolicy {
            queue_depth: 16,
            breaker_threshold: 1000, // never trip: probe pure respawn
            ..test_policy(4, Duration::from_micros(500))
        };
        let router = Router::spawn(
            replicas,
            flaky_factory(
                move || {
                    Ok(MockBackend {
                        bs: 4,
                        sample: 2,
                        classes: 1,
                        delay: Duration::from_micros(100),
                    })
                },
                4, // panic every 4th batch of each generation
                3, // soft error every 3rd
                Duration::from_micros(200),
                900 + case,
            ),
            policy,
        )
        .unwrap();
        let mut admitted = Vec::new();
        let mut shed = 0usize;
        for i in 0..n_req {
            match router.submit(vec![i as f32, 1.0]) {
                Ok((rx, _)) => admitted.push((i, rx)),
                Err(ServeError::Overloaded { .. }) => shed += 1,
                Err(e) => panic!("case {case}: untyped admission failure: {e}"),
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        let n_adm = admitted.len();
        let (mut ok, mut failed) = (0usize, 0usize);
        for (i, rx) in admitted {
            match rx
                .recv()
                .unwrap_or_else(|_| panic!("case {case}: request {i} reply dropped"))
            {
                Ok(v) => {
                    assert_eq!(v[0], i as f32 + 1.0, "case {case}: cross-wired reply");
                    ok += 1;
                }
                Err(ServeError::ReplicaFailed { .. } | ServeError::DeadlineExceeded { .. }) => {
                    failed += 1;
                }
                Err(e) => panic!("case {case}: unexpected typed reply: {e}"),
            }
        }
        assert_eq!(ok + failed, n_adm, "case {case}");
        assert_eq!(n_adm + shed, n_req, "case {case}");
        // shed is never silent: a submit may probe several full queues,
        // so the counters see at least one increment per shed request
        let counted: u64 = (0..replicas).map(|i| router.stats(i).shed.get()).sum();
        assert!(counted >= shed as u64, "case {case}: silent shed ({counted} < {shed})");
        router.shutdown().unwrap();
    }
}

/// One tiny engine-compiled network (resnet8 on 8px images), shared by
/// the EngineBackend properties below.
fn tiny_engine_plan(batch: usize) -> Arc<NetworkPlan> {
    let descs = models::cifar_resnet_layers(8, 0.5, 8, batch);
    Arc::new(NetworkPlan::compile(&descs, EngineConfig::default(), Scheme::sb_default()).unwrap())
}

/// Expected logits for one sample under a plan: run it alone in slot 0
/// of a zero-padded device batch. Convs are per-sample independent and
/// pixel-block lanes never mix samples, so the slot-0 logits of any
/// co-batched run must be bit-identical to this.
fn expected_logits(plan: &Arc<NetworkPlan>, sample: &[f32]) -> Vec<f32> {
    let backend = EngineBackend::new(Arc::clone(plan));
    let mut batch = vec![0.0f32; backend.batch_size() * backend.sample_elems()];
    batch[..sample.len()].copy_from_slice(sample);
    backend.infer_batch(&batch).unwrap()[..backend.out_elems()].to_vec()
}

/// Property: the server/batcher invariants hold against the *real*
/// repetition-engine backend — every request answered exactly once with
/// its own logits (bit-exact vs a direct executor run), wrong-size
/// requests get a typed `BadRequest` instead of hanging, all without the
/// `pjrt` feature.
#[test]
fn prop_engine_backend_every_request_answered_with_own_result() {
    for case in 0..4 {
        let mut rng = Rng::new(6000 + case as u64);
        let batch = 1 + rng.below(4);
        let plan = tiny_engine_plan(batch);
        let sample = plan.sample_elems();
        let n_req = 1 + rng.below(12);
        let max_wait = Duration::from_micros(rng.below(2000) as u64);
        let mut samples = Vec::new();
        for _ in 0..n_req {
            let mut x = vec![0.0f32; sample];
            rng.fill_normal(&mut x, 1.0);
            samples.push(x);
        }
        let expects: Vec<Vec<f32>> = samples.iter().map(|x| expected_logits(&plan, x)).collect();

        let w = spawn_worker(
            EngineBackend::factory(Arc::clone(&plan)),
            test_policy(batch, max_wait),
        )
        .unwrap();
        let mut rxs = Vec::new();
        for x in &samples {
            rxs.push(w.submit(x.clone()).unwrap());
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let logits = rx
                .recv()
                .unwrap_or_else(|_| panic!("case {case}: dropped reply"))
                .unwrap_or_else(|e| panic!("case {case}: error reply {e}"));
            assert!(
                logits == expects[i],
                "case {case}: request {i} got another sample's logits"
            );
        }
        // wrong-size request gets a typed error, never hangs
        let bad = w.submit(vec![0.0; sample + 1]).unwrap();
        assert!(
            matches!(bad.recv().unwrap(), Err(ServeError::BadRequest { .. })),
            "case {case}"
        );
        w.shutdown().unwrap();
    }
}

/// Property: the router conserves requests across engine replicas, and
/// replies stay bit-exact regardless of which replica/batch served them.
#[test]
fn prop_router_with_engine_backend_conserves_requests() {
    let mut rng = Rng::new(6100);
    let batch = 2;
    let plan = tiny_engine_plan(batch);
    let sample = plan.sample_elems();
    let n_req = 19;
    let workers = (0..2)
        .map(|_| {
            spawn_worker(
                EngineBackend::factory(Arc::clone(&plan)),
                test_policy(batch, Duration::from_millis(1)),
            )
            .unwrap()
        })
        .collect();
    let router = Router::new(workers);
    let mut pending = Vec::new();
    for i in 0..n_req {
        let mut x = vec![0.0f32; sample];
        rng.fill_normal(&mut x, 1.0);
        let expect = expected_logits(&plan, &x);
        let (rx, _) = router.submit(x).unwrap();
        pending.push((i, expect, rx));
    }
    for (i, expect, rx) in pending {
        let logits = rx.recv().unwrap().unwrap();
        assert!(logits == expect, "request {i} cross-wired or non-deterministic");
    }
    assert_eq!(router.completed(), n_req as u64);
    router.shutdown().unwrap();
}

/// Property: a *respawned* engine replica serves bit-identical logits.
/// Every generation's 2nd batch panics, so the supervisor rebuilds the
/// backend over and over; each successor must produce exactly the same
/// bits for the same sample (the plan is shared, the arena is rebuilt).
#[test]
fn prop_respawned_engine_replicas_serve_bit_identical_logits() {
    let plan = tiny_engine_plan(1);
    let sample = plan.sample_elems();
    let mut rng = Rng::new(6200);
    let mut x = vec![0.0f32; sample];
    rng.fill_normal(&mut x, 1.0);
    let expect = expected_logits(&plan, &x);
    let policy = ServePolicy {
        queue_depth: 8,
        breaker_threshold: 1000, // never trip: probe pure respawn
        ..test_policy(1, Duration::from_micros(200))
    };
    let router = Router::spawn(
        1,
        flaky_factory(EngineBackend::factory(Arc::clone(&plan)), 2, 0, Duration::ZERO, 1),
        policy,
    )
    .unwrap();
    let (mut ok, mut crashed) = (0usize, 0usize);
    for round in 0..12 {
        // retry admission across respawn gaps (the queue stays bounded)
        let rx = loop {
            match router.submit(x.clone()) {
                Ok((rx, _)) => break rx,
                Err(ServeError::Overloaded { .. }) => {
                    std::thread::sleep(Duration::from_micros(500));
                }
                Err(e) => panic!("round {round}: {e}"),
            }
        };
        match rx.recv().expect("typed reply required") {
            Ok(logits) => {
                assert!(logits == expect, "round {round}: respawned replica diverged");
                ok += 1;
            }
            Err(ServeError::ReplicaFailed { .. }) => crashed += 1,
            Err(e) => panic!("round {round}: unexpected reply {e}"),
        }
    }
    // the alternating schedule (ok, panic, ok, panic, ...) must have
    // produced both successes and typed crash replies across respawns
    assert!(ok >= 3, "too few successes across respawns: {ok}");
    assert!(crashed >= 3, "fault schedule never fired: {crashed}");
    assert!(router.stats(0).crashes.get() >= 3);
    router.shutdown().unwrap();
}

/// Property: signed-binary quantization never mixes signs within a
/// region and its packed form round-trips, for random shapes/p_pos/delta.
#[test]
fn prop_sb_quantization_invariants() {
    for case in 0..CASES {
        let mut rng = Rng::new(3000 + case as u64);
        let k = 1 + rng.below(12);
        let c = 1 + rng.below(12);
        let r = 1 + 2 * rng.below(2); // 1 or 3
        let p_pos = [0.0, 0.25, 0.5, 1.0][rng.below(4)];
        let delta = [0.01f32, 0.05, 0.2][rng.below(3)];
        let w = Tensor::rand_normal(&[k, c, r, r], 1.0, &mut rng);
        let beta = default_beta(k, p_pos);
        let q = quant::quantize_signed_binary(&w, &beta, delta, 1);
        let e = c * r * r;
        for fi in 0..k {
            let row = &q.values.data()[fi * e..(fi + 1) * e];
            let pos = row.iter().any(|v| *v > 0.0);
            let neg = row.iter().any(|v| *v < 0.0);
            assert!(!(pos && neg), "case {case}: mixed signs in filter {fi}");
            if beta[fi] >= 0.0 {
                assert!(!neg, "case {case}");
            } else {
                assert!(!pos, "case {case}");
            }
        }
        let packed = quant::PackedSignedBinary::pack(&q);
        assert_eq!(packed.effectual(), q.effectual(), "case {case}");
        assert_eq!(packed.unpack(), q.values.data(), "case {case}");
    }
}

/// Property: the repetition engine equals dense GEMM for random
/// geometry / scheme / subtile / sparsity-support combinations.
#[test]
fn prop_engine_matches_dense() {
    for case in 0..15 {
        let mut rng = Rng::new(4000 + case as u64);
        let g = Conv2dGeometry {
            n: 1 + rng.below(2),
            c: 1 + rng.below(10),
            h: 3 + rng.below(6),
            w: 3 + rng.below(6),
            k: 1 + rng.below(16),
            r: 3,
            s: 3,
            stride: 1 + rng.below(2),
            padding: 1,
        };
        let scheme = [Scheme::Binary, Scheme::ternary_default(), Scheme::sb_default()]
            [rng.below(3)];
        let subtile = [3usize, 8, 16, 64][rng.below(4)];
        let sparsity_support = rng.coin(0.5);
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.6, &mut rng);
        let q = quant::quantize(&w, scheme, None);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let dense = conv2d_gemm(&x, &q.values, g.stride, g.padding);
        let plan = plan_layer(&q, g, EngineConfig { subtile, sparsity_support });
        let out = execute_conv2d(&plan, &x);
        let diff = dense.max_abs_diff(&out);
        assert!(
            diff < 1e-3,
            "case {case}: {} subtile={subtile} sp={sparsity_support} diff={diff}",
            scheme.name()
        );
    }
}

/// Property: op accounting — sparsity support never increases ops.
#[test]
fn prop_opcount_monotonicity() {
    for case in 0..CASES {
        let mut rng = Rng::new(5000 + case as u64);
        let g = Conv2dGeometry {
            n: 1,
            c: 4 + rng.below(28),
            h: 6,
            w: 6,
            k: 4 + rng.below(60),
            r: 3,
            s: 3,
            stride: 1,
            padding: 1,
        };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.6, &mut rng);
        let q = quant::quantize(&w, Scheme::sb_default(), None);
        let st = 4 + rng.below(16);
        let on = plan_layer(&q, g, EngineConfig { subtile: st, sparsity_support: true });
        let off = plan_layer(&q, g, EngineConfig { subtile: st, sparsity_support: false });
        assert!(
            on.op_counts().total() <= off.op_counts().total(),
            "case {case}: sparsity support increased ops"
        );
    }
}
