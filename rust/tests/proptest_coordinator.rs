//! Property-style tests on coordinator invariants (routing, batching,
//! response integrity) and on quantizer/engine invariants.
//!
//! proptest is not in the offline vendor set, so this uses the same
//! technique with the repo's deterministic RNG: many seeded random
//! configurations per property, with the failing seed printed on assert.

use std::time::Duration;

use plum::coordinator::{spawn_worker, BatchPolicy, MockBackend, Router};
use plum::quant::{self, default_beta, Scheme};
use plum::repetition::{execute_conv2d, plan_layer, EngineConfig};
use plum::tensor::{conv2d_gemm, Conv2dGeometry, Tensor};
use plum::util::Rng;

const CASES: usize = 25;

/// Property: for any (bs, #requests, batching policy), every request is
/// answered exactly once with its own payload's logits.
#[test]
fn prop_every_request_answered_with_own_result() {
    for case in 0..CASES {
        let mut rng = Rng::new(1000 + case as u64);
        let bs = 1 + rng.below(8);
        let sample = 1 + rng.below(6);
        let classes = 1 + rng.below(4);
        let n_req = 1 + rng.below(60);
        let max_batch = 1 + rng.below(12);
        let max_wait = Duration::from_micros(rng.below(3000) as u64);
        let delay = Duration::from_micros(rng.below(300) as u64);
        let w = spawn_worker(
            move || Ok(MockBackend { bs, sample, classes, delay }),
            BatchPolicy { max_batch, max_wait },
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..n_req {
            let x: Vec<f32> = (0..sample).map(|j| (i * 31 + j) as f32).collect();
            let expect: f32 = x.iter().sum();
            rxs.push((expect, w.submit(x).unwrap()));
        }
        for (expect, rx) in rxs {
            let logits = rx
                .recv()
                .unwrap_or_else(|_| panic!("case {case}: dropped reply"))
                .unwrap_or_else(|e| panic!("case {case}: error reply {e}"));
            assert_eq!(logits.len(), classes, "case {case}");
            assert_eq!(logits[0], expect, "case {case}: cross-wired response");
        }
        drop(w.tx);
        w.join.join().unwrap();
    }
}

/// Property: the router never loses requests and completes them all,
/// for any replica count and load pattern.
#[test]
fn prop_router_conserves_requests() {
    for case in 0..10 {
        let mut rng = Rng::new(2000 + case as u64);
        let replicas = 1 + rng.below(4);
        let n_req = 1 + rng.below(80);
        let workers = (0..replicas)
            .map(|_| {
                spawn_worker(
                    move || {
                        Ok(MockBackend {
                            bs: 4,
                            sample: 2,
                            classes: 1,
                            delay: Duration::from_micros(200),
                        })
                    },
                    BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                )
                .unwrap()
            })
            .collect();
        let router = Router::new(workers);
        let mut rxs = Vec::new();
        for i in 0..n_req {
            let (rx, _) = router.submit(vec![i as f32, 1.0]).unwrap();
            rxs.push((i, rx));
        }
        for (i, rx) in rxs {
            let v = rx.recv().unwrap().unwrap();
            assert_eq!(v[0], i as f32 + 1.0, "case {case}");
        }
        assert_eq!(router.completed(), n_req as u64, "case {case}");
        router.shutdown().unwrap();
    }
}

/// Property: signed-binary quantization never mixes signs within a
/// region and its packed form round-trips, for random shapes/p_pos/delta.
#[test]
fn prop_sb_quantization_invariants() {
    for case in 0..CASES {
        let mut rng = Rng::new(3000 + case as u64);
        let k = 1 + rng.below(12);
        let c = 1 + rng.below(12);
        let r = 1 + 2 * rng.below(2); // 1 or 3
        let p_pos = [0.0, 0.25, 0.5, 1.0][rng.below(4)];
        let delta = [0.01f32, 0.05, 0.2][rng.below(3)];
        let w = Tensor::rand_normal(&[k, c, r, r], 1.0, &mut rng);
        let beta = default_beta(k, p_pos);
        let q = quant::quantize_signed_binary(&w, &beta, delta, 1);
        let e = c * r * r;
        for fi in 0..k {
            let row = &q.values.data()[fi * e..(fi + 1) * e];
            let pos = row.iter().any(|v| *v > 0.0);
            let neg = row.iter().any(|v| *v < 0.0);
            assert!(!(pos && neg), "case {case}: mixed signs in filter {fi}");
            if beta[fi] >= 0.0 {
                assert!(!neg, "case {case}");
            } else {
                assert!(!pos, "case {case}");
            }
        }
        let packed = quant::PackedSignedBinary::pack(&q);
        assert_eq!(packed.effectual(), q.effectual(), "case {case}");
        assert_eq!(packed.unpack(), q.values.data(), "case {case}");
    }
}

/// Property: the repetition engine equals dense GEMM for random
/// geometry / scheme / subtile / sparsity-support combinations.
#[test]
fn prop_engine_matches_dense() {
    for case in 0..15 {
        let mut rng = Rng::new(4000 + case as u64);
        let g = Conv2dGeometry {
            n: 1 + rng.below(2),
            c: 1 + rng.below(10),
            h: 3 + rng.below(6),
            w: 3 + rng.below(6),
            k: 1 + rng.below(16),
            r: 3,
            s: 3,
            stride: 1 + rng.below(2),
            padding: 1,
        };
        let scheme = [Scheme::Binary, Scheme::ternary_default(), Scheme::sb_default()]
            [rng.below(3)];
        let subtile = [3usize, 8, 16, 64][rng.below(4)];
        let sparsity_support = rng.coin(0.5);
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.6, &mut rng);
        let q = quant::quantize(&w, scheme, None);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let dense = conv2d_gemm(&x, &q.values, g.stride, g.padding);
        let plan = plan_layer(&q, g, EngineConfig { subtile, sparsity_support });
        let out = execute_conv2d(&plan, &x);
        let diff = dense.max_abs_diff(&out);
        assert!(
            diff < 1e-3,
            "case {case}: {} subtile={subtile} sp={sparsity_support} diff={diff}",
            scheme.name()
        );
    }
}

/// Property: op accounting — sparsity support never increases ops.
#[test]
fn prop_opcount_monotonicity() {
    for case in 0..CASES {
        let mut rng = Rng::new(5000 + case as u64);
        let g = Conv2dGeometry {
            n: 1,
            c: 4 + rng.below(28),
            h: 6,
            w: 6,
            k: 4 + rng.below(60),
            r: 3,
            s: 3,
            stride: 1,
            padding: 1,
        };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.6, &mut rng);
        let q = quant::quantize(&w, Scheme::sb_default(), None);
        let st = 4 + rng.below(16);
        let on = plan_layer(&q, g, EngineConfig { subtile: st, sparsity_support: true });
        let off = plan_layer(&q, g, EngineConfig { subtile: st, sparsity_support: false });
        assert!(
            on.op_counts().total() <= off.op_counts().total(),
            "case {case}: sparsity support increased ops"
        );
    }
}
