//! Integration: AOT artifacts (python-built) -> rust PJRT load/compile ->
//! train steps + inference. Skips (with a notice) if artifacts are absent.

use std::path::PathBuf;

use plum::data::SyntheticDataset;
use plum::runtime::Runtime;
use plum::training::{load_checkpoint, save_checkpoint, Schedule, Trainer};

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("r8sb_p050.manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts not built — run `make artifacts`; skipping");
        None
    }
}

#[test]
fn train_steps_reduce_loss_and_checkpoint_roundtrips() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut tr = Trainer::new(&rt, &dir, "r8sb_p050").unwrap();
    let ds = SyntheticDataset::new("cifar", 10, 3, tr.image_size(), 1);

    let log = tr
        .train(&ds, 40, &Schedule::Constant { lr: 5e-3 }, 10, 2, true)
        .unwrap();
    let first = log.curve.first().unwrap().loss;
    let last = log.final_train_loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(last.is_finite());

    // quantized export: every sb filter single-signed, density < 1
    let layers = tr.export_quantized().unwrap();
    assert!(!layers.is_empty());
    let density = tr.quantized_density().unwrap();
    assert!(density > 0.05 && density < 0.95, "density {density}");

    // checkpoint roundtrip preserves logits exactly
    let (xs, _) = ds.batch(0, tr.batch_size());
    let logits_before = tr.infer_logits(&xs).unwrap();
    let tmp = std::env::temp_dir().join("plum_it_ckpt.bin");
    let state = tr.state_to_host().unwrap();
    save_checkpoint(&tmp, tr.step, &state).unwrap();
    let (step, loaded) = load_checkpoint(&tmp).unwrap();
    assert_eq!(step, tr.step);
    let mut tr2 = Trainer::new(&rt, &dir, "r8sb_p050").unwrap();
    tr2.state_from_host(&loaded).unwrap();
    let logits_after = tr2.infer_logits(&xs).unwrap();
    assert_eq!(logits_before, logits_after);
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn eval_accuracy_better_than_chance_after_short_training() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut tr = Trainer::new(&rt, &dir, "r8sb_p050").unwrap();
    let ds = SyntheticDataset::new("cifar", 10, 3, tr.image_size(), 2);
    tr.train(&ds, 120, &Schedule::Constant { lr: 5e-3 }, 50, 0, true)
        .unwrap();
    let acc = tr.evaluate(&ds, 4).unwrap();
    assert!(acc > 0.2, "eval acc {acc} not above chance (0.1)");
}

#[test]
fn sb_matmul_kernel_artifact_runs() {
    let Some(dir) = artifacts() else { return };
    if !dir.join("sb_matmul.hlo.txt").exists() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let exe = rt.compile_hlo_file(&dir.join("sb_matmul.hlo.txt")).unwrap();
    let (m, k, n) = (256usize, 1152usize, 128usize);
    let mut rng = plum::util::Rng::new(3);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let u: Vec<f32> = (0..k * n).map(|_| if rng.coin(0.5) { 0.4 } else { 0.0 }).collect();
    let beta: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let al = plum::runtime::literal_f32(&[m, k], &a).unwrap();
    let ul = plum::runtime::literal_f32(&[k, n], &u).unwrap();
    let bl = plum::runtime::literal_f32(&[n], &beta).unwrap();
    let out = plum::runtime::execute_tuple(&exe, &[al, ul, bl]).unwrap();
    let o = plum::runtime::literal_to_f32(&out[0]).unwrap();
    assert_eq!(o.len(), m * n);
    // spot check one element against a host dot product
    let (i, j) = (3usize, 5usize);
    let mut acc = 0.0f32;
    for p in 0..k {
        acc += a[i * k + p] * u[p * n + j];
    }
    acc *= beta[j];
    assert!((acc - o[i * n + j]).abs() < 1e-2 * acc.abs().max(1.0));
}
