//! Integration tests for the tile-fused parallel execution backend (now
//! the pixel-major / transposed layout): ragged tiles, degenerate tile
//! sizes, thread-count sweeps, and the exact-equality guarantee
//! (N-thread output == 1-thread output, bit for bit), plus parallel-GEMM
//! determinism of the dense baseline.

use plum::quant::{self, default_beta, quantize_signed_binary, Scheme};
use plum::repetition::{
    execute_conv2d_pool, execute_conv2d_tiled, plan_layer, plan_layer_auto, EngineConfig,
    DEFAULT_TILE,
};
use plum::tensor::{conv2d_gemm_pool, Conv2dGeometry, Tensor};
use plum::util::{Pool, Rng};

fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn workload(g: Conv2dGeometry, seed: u64) -> (Tensor, quant::QuantizedWeights) {
    let mut rng = Rng::new(seed);
    let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
    let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
    (x, quant::quantize(&w, Scheme::sb_default(), None))
}

#[test]
fn pixel_count_not_multiple_of_tile() {
    // 1x6x11x7 with 3x3/pad 1 -> 77 output pixels: prime-ish, never a
    // multiple of the default tile or of PIXEL_BLOCK
    let g = Conv2dGeometry { n: 1, c: 6, h: 11, w: 7, k: 10, r: 3, s: 3, stride: 1, padding: 1 };
    let (x, q) = workload(g, 40);
    let plan = plan_layer(&q, g, EngineConfig::default());
    let pool = Pool::new(2);
    let dense = conv2d_gemm_pool(&x, &q.values, g.stride, g.padding, &pool);
    assert_eq!(g.out_h() * g.out_w(), 77);
    for tile in [DEFAULT_TILE, 5, 76, 77, 78, 1000] {
        let out = execute_conv2d_tiled(&plan, &x, &pool, tile);
        assert!(dense.max_abs_diff(&out) < 1e-3, "tile {tile}");
    }
}

#[test]
fn tile_size_one() {
    let g = Conv2dGeometry { n: 2, c: 4, h: 6, w: 6, k: 8, r: 3, s: 3, stride: 1, padding: 1 };
    let (x, q) = workload(g, 41);
    let plan = plan_layer(&q, g, EngineConfig::default());
    let dense = conv2d_gemm_pool(&x, &q.values, g.stride, g.padding, &Pool::new(1));
    for threads in [1, 2, num_cpus()] {
        let out = execute_conv2d_tiled(&plan, &x, &Pool::new(threads), 1);
        assert!(dense.max_abs_diff(&out) < 1e-3, "{threads} threads, tile 1");
    }
}

#[test]
fn thread_counts_one_two_numcpus_match_dense() {
    let g = Conv2dGeometry { n: 1, c: 16, h: 14, w: 14, k: 32, r: 3, s: 3, stride: 1, padding: 1 };
    let (x, q) = workload(g, 42);
    let plan = plan_layer_auto(&q, g, true);
    let dense = conv2d_gemm_pool(&x, &q.values, g.stride, g.padding, &Pool::new(1));
    for threads in [1, 2, num_cpus()] {
        let out = execute_conv2d_pool(&plan, &x, &Pool::new(threads));
        assert!(
            dense.max_abs_diff(&out) < 1e-3,
            "{threads} threads diverge from dense"
        );
    }
}

#[test]
fn n_thread_exactly_equals_one_thread_on_strided_conv() {
    // the acceptance-criterion case: strided conv, exact bit equality
    let g = Conv2dGeometry { n: 2, c: 12, h: 15, w: 15, k: 24, r: 3, s: 3, stride: 2, padding: 1 };
    let mut rng = Rng::new(43);
    let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
    let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
    let q = quantize_signed_binary(&w, &default_beta(g.k, 0.5), 0.05, 1);
    for sparsity in [true, false] {
        let plan = plan_layer(&q, g, EngineConfig { subtile: 8, sparsity_support: sparsity });
        let base = execute_conv2d_pool(&plan, &x, &Pool::new(1));
        for threads in [2, num_cpus(), 2 * num_cpus() + 1] {
            let out = execute_conv2d_pool(&plan, &x, &Pool::new(threads));
            assert!(
                out.data() == base.data(),
                "sparsity={sparsity}: {threads}-thread bits differ from 1-thread"
            );
        }
        // ragged tiles must preserve exactness across widths too
        let t1 = execute_conv2d_tiled(&plan, &x, &Pool::new(1), 7);
        let tn = execute_conv2d_tiled(&plan, &x, &Pool::new(num_cpus()), 7);
        assert!(t1.data() == tn.data(), "sparsity={sparsity}: tile-7 widths differ");
    }
}

#[test]
fn transposed_path_bit_exact_across_widths_and_ragged_blocks() {
    // tile sizes chosen to force every PIXEL_BLOCK shape the transposed
    // layout can produce: sub-block tiles, block-aligned tiles, ragged
    // final blocks inside a tile, and ragged final tiles
    use plum::repetition::PIXEL_BLOCK;
    let g = Conv2dGeometry { n: 1, c: 6, h: 11, w: 7, k: 10, r: 3, s: 3, stride: 1, padding: 1 };
    let (x, q) = workload(g, 45);
    let plan = plan_layer(&q, g, EngineConfig::default());
    for tile in [1, PIXEL_BLOCK - 1, PIXEL_BLOCK, PIXEL_BLOCK + 3, 3 * PIXEL_BLOCK, 77] {
        let base = execute_conv2d_tiled(&plan, &x, &Pool::new(1), tile);
        for threads in [2, num_cpus(), num_cpus() + 3] {
            let out = execute_conv2d_tiled(&plan, &x, &Pool::new(threads), tile);
            assert!(
                out.data() == base.data(),
                "tile {tile}: {threads}-thread bits differ from 1-thread"
            );
        }
    }
}

#[test]
fn dense_baseline_deterministic_across_threads() {
    let g = Conv2dGeometry { n: 1, c: 8, h: 20, w: 20, k: 160, r: 3, s: 3, stride: 1, padding: 1 };
    let (x, q) = workload(g, 44);
    let base = conv2d_gemm_pool(&x, &q.values, g.stride, g.padding, &Pool::new(1));
    for threads in [2, num_cpus()] {
        let out = conv2d_gemm_pool(&x, &q.values, g.stride, g.padding, &Pool::new(threads));
        assert!(
            out.data() == base.data(),
            "{threads}-thread dense conv differs from serial"
        );
    }
}
