//! Negative-path proof that `plum audit` catches corrupt plans.
//!
//! A verifier that only ever passes green plans proves nothing, so
//! every invariant family gets a hand-seeded corruption here: compile a
//! real zoo plan, break exactly one plan property through the public
//! API, and assert the audit reports the matching typed
//! [`AuditFinding`] variant. Corruptions happen *after* compile, so the
//! debug-build compile gate (which audits every fresh plan) stays
//! green. Slot-table corruptions (`slot_of_act` / `slot_elems` are
//! crate-private) live in `analysis::tests` instead.

use plum::analysis::{audit_layer_plan, audit_network_plan, AuditFinding};
use plum::models;
use plum::network::NetworkPlan;
use plum::quant::Scheme;
use plum::repetition::{EngineConfig, DEFAULT_TILE};

fn compiled(model: &str, bmax: usize) -> NetworkPlan {
    let descs = models::engine_model_layers(model, 16, bmax).expect("zoo model");
    let cfg = EngineConfig { subtile: 8, sparsity_support: true };
    NetworkPlan::compile(&descs, cfg, Scheme::sb_default()).expect("compile")
}

fn first_engine_layer(plan: &NetworkPlan) -> usize {
    plan.layers.iter().position(|l| l.plan.is_some()).expect("an engine layer")
}

#[test]
fn green_zoo_plans_audit_clean_fused_and_unfused() {
    // residual pins (resnetN), projection shortcuts (resnet18c) and a
    // pure fused chain (chain1x1), each at bmax 1 and 2
    for model in ["resnet8", "resnet18c", "chain1x1"] {
        for bmax in [1, 2] {
            let plan = compiled(model, bmax);
            let fused = audit_network_plan(&plan, DEFAULT_TILE);
            assert_eq!(fused, vec![], "{model} bmax {bmax} fused");
            let unfused = audit_network_plan(&plan.without_patch_fusion(), DEFAULT_TILE);
            assert_eq!(unfused, vec![], "{model} bmax {bmax} unfused");
        }
    }
}

#[test]
fn out_of_bounds_combine_index_is_caught() {
    let mut plan = compiled("resnet8", 1);
    let li = first_engine_layer(&plan);
    plan.layers[li].plan.as_mut().unwrap().combine[0] = u32::MAX;
    let findings = audit_network_plan(&plan, DEFAULT_TILE);
    assert!(
        findings.iter().any(|f| matches!(
            f,
            AuditFinding::CombineSlotOutOfBounds { layer, .. } if *layer == li
        )),
        "expected CombineSlotOutOfBounds at layer {li}, got {findings:?}"
    );
}

#[test]
fn non_monotone_table_base_is_caught() {
    let mut plan = compiled("resnet8", 1);
    let li = first_engine_layer(&plan);
    let lp = plan.layers[li].plan.as_mut().unwrap();
    assert!(lp.num_tables >= 2, "need two sub-tiles to break monotonicity");
    lp.arena.table_base[1] = u32::MAX;
    let findings = audit_network_plan(&plan, DEFAULT_TILE);
    assert!(
        findings
            .iter()
            .any(|f| matches!(f, AuditFinding::TableBaseNotMonotone { layer, .. } if *layer == li)),
        "expected TableBaseNotMonotone at layer {li}, got {findings:?}"
    );
}

#[test]
fn column_outside_patch_matrix_is_caught() {
    let mut plan = compiled("resnet8", 1);
    let li = first_engine_layer(&plan);
    plan.layers[li].plan.as_mut().unwrap().arena.cols[0] = u32::MAX;
    let findings = audit_network_plan(&plan, DEFAULT_TILE);
    assert!(
        findings
            .iter()
            .any(|f| matches!(f, AuditFinding::ColumnOutOfRange { layer, .. } if *layer == li)),
        "expected ColumnOutOfRange at layer {li}, got {findings:?}"
    );
}

#[test]
fn broken_span_contiguity_is_caught() {
    let mut plan = compiled("resnet8", 1);
    let li = first_engine_layer(&plan);
    let lp = plan.layers[li].plan.as_mut().unwrap();
    assert!(lp.arena.spans.len() >= 2);
    lp.arena.spans[1].start += 1;
    let findings = audit_network_plan(&plan, DEFAULT_TILE);
    assert!(
        findings.iter().any(|f| matches!(
            f,
            AuditFinding::SpanNotContiguous { layer, span: 1, .. } if *layer == li
        )),
        "expected SpanNotContiguous at layer {li} span 1, got {findings:?}"
    );
}

#[test]
fn density_stats_drift_is_caught() {
    // per-layer API: the stats cross-check works without a network
    let mut plan = compiled("resnet8", 1);
    let li = first_engine_layer(&plan);
    let lp = plan.layers[li].plan.as_mut().unwrap();
    lp.stats.effectual_cols += 1;
    let findings = audit_layer_plan(li, lp);
    assert!(
        findings.iter().any(|f| matches!(
            f,
            AuditFinding::DensityStatsMismatch { layer, field: "effectual_cols", .. }
                if *layer == li
        )),
        "expected DensityStatsMismatch at layer {li}, got {findings:?}"
    );
}

#[test]
fn missing_noop_slot_on_elided_arena_is_caught() {
    let mut plan = compiled("resnet8", 1);
    let li = first_engine_layer(&plan);
    let lp = plan.layers[li].plan.as_mut().unwrap();
    assert!(!lp.arena.zeros_materialized, "sparsity-on plans elide");
    lp.arena.noop_slot = None;
    let findings = audit_network_plan(&plan, DEFAULT_TILE);
    assert!(
        findings
            .iter()
            .any(|f| matches!(f, AuditFinding::NoopSlotMalformed { layer, .. } if *layer == li)),
        "expected NoopSlotMalformed at layer {li}, got {findings:?}"
    );
}

#[test]
fn misaligned_blocked_tile_is_caught() {
    let plan = compiled("resnet20", 1);
    assert!(plan.patch_fused_edges() > 0, "resnet20 must fuse edges");
    // tile 12 splits PIXEL_BLOCK lanes across jobs on blocked layers
    let findings = audit_network_plan(&plan, 12);
    assert!(
        findings
            .iter()
            .any(|f| matches!(f, AuditFinding::MisalignedBlockedTile { tile: 12, .. })),
        "expected MisalignedBlockedTile, got {findings:?}"
    );
    // the unfused twin hands off NCHW everywhere — tile 12 is then
    // sound, and the write-interval proof must still close exactly
    assert_eq!(audit_network_plan(&plan.without_patch_fusion(), 12), vec![]);
}
