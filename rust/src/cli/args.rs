//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Grammar: `plum <subcommand> [positionals...] [--flag value | --switch]`.

use std::collections::BTreeMap;

/// Parsed command-line arguments (everything after the subcommand).
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// bare tokens in order (bench targets, report kinds, ...)
    pub positionals: Vec<String>,
    /// `--flag value` / `--flag=value` / `--switch` (stored as "true")
    pub flags: BTreeMap<String, String>,
}

/// A token opens a new flag (rather than serving as the pending flag's
/// value) only when it carries the `--` prefix *and* the rest is not a
/// number. Bare `-`-prefixed tokens — negative values like `-0.05`
/// after `--delta` — are never switches, and a numeric tail (`--0.5`)
/// never names a flag.
fn opens_flag(tok: &str) -> bool {
    match tok.strip_prefix("--") {
        Some(rest) => rest.parse::<f64>().is_err(),
        None => false,
    }
}

impl Args {
    /// Parse everything after the subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if opens_flag(&tok) {
                let name = tok.strip_prefix("--").expect("flag tokens carry the -- prefix");
                // --k=v or --k v or --switch
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !opens_flag(n)).unwrap_or(false) {
                    let v = it.next().unwrap();
                    // a double-dashed number reaching the value slot is a
                    // negative number with a doubled dash — store the
                    // parseable form so numeric getters see it
                    let v = if v.starts_with("--") && v[1..].parse::<f64>().is_ok() {
                        v[1..].to_string()
                    } else {
                        v
                    };
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positionals.push(tok);
            }
        }
        out
    }

    /// The flag's raw value, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// The flag's raw value, or `default` when absent.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// The flag parsed as `usize` (`default` when absent or unparsable).
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// The flag parsed as `u64` (`default` when absent or unparsable).
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// The flag parsed as `f32` (`default` when absent or unparsable).
    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// True when the flag or switch was passed at all.
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn positionals_and_flags() {
        // grammar is greedy: a flag consumes the next non-flag token, so
        // switches must come last or use --flag=value
        let a = parse("table1 extra --steps 200 --quiet");
        assert_eq!(a.positionals, vec!["table1", "extra"]);
        assert_eq!(a.get_usize("steps", 0), 200);
        assert!(a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--lr=0.01 --name=resnet20_sb");
        assert_eq!(a.get_f32("lr", 0.0), 0.01);
        assert_eq!(a.get("name"), Some("resnet20_sb"));
    }

    #[test]
    fn trailing_switch() {
        let a = parse("--verbose");
        assert!(a.has("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_u64("n", 7), 7);
    }

    #[test]
    fn negative_number_values_are_not_switches() {
        let a = parse("--delta -0.05 --quiet");
        assert_eq!(a.get_f32("delta", 0.0), -0.05);
        assert!(a.has("quiet"));
        // equals form too
        let b = parse("--delta=-0.05");
        assert_eq!(b.get_f32("delta", 0.0), -0.05);
        // negative integers
        let c = parse("--offset -3 --steps 10");
        assert_eq!(c.get("offset"), Some("-3"));
        assert_eq!(c.get_usize("steps", 0), 10);
        // a numeric tail never names a flag, even with the -- prefix;
        // the doubled dash is normalized so numeric getters parse it
        let d = parse("--delta --0.5");
        assert!(!d.has("0.5"));
        assert_eq!(d.get("delta"), Some("-0.5"));
        assert_eq!(d.get_f32("delta", 0.0), -0.5);
    }

    #[test]
    fn flag_followed_by_flag_stays_a_switch() {
        let a = parse("--fresh --steps 5");
        assert!(a.has("fresh"));
        assert_eq!(a.get("fresh"), Some("true"));
        assert_eq!(a.get_usize("steps", 0), 5);
    }
}
