//! Launcher CLI (S10): subcommand dispatch for the `plum` binary.
//!
//! Commands that execute through PJRT (train, quantize, the accuracy
//! tables, `serve --backend pjrt`) require the `pjrt` feature; on a
//! default build they fail with a pointer to the build matrix in
//! rust/README.md. Engine and simulator harnesses (fig7/fig9/fig10,
//! energy, cse, scaling, repetition, network, pareto, registry, report)
//! and engine-backed serving (`serve`, default backend) are always
//! available.

pub mod args;

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::coordinator::ModelRegistry;
use crate::experiments::{self, figures, serving, tables};
#[cfg(feature = "pjrt")]
use crate::quant::PackedSignedBinary;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
#[cfg(feature = "pjrt")]
use crate::training::{save_checkpoint, Schedule, Trainer};

use args::Args;

/// Usage text printed by `plum help` (and on unknown commands).
pub const HELP: &str = "\
plum — PLUM repetition-sparsity co-design framework (paper reproduction)

USAGE:
  plum <command> [options]

COMMANDS:
  train --model NAME [--steps N] [--lr F]   train one artifact, save ckpt [pjrt]
  bench <target> [--steps N] [--fresh]      regenerate a paper table/figure:
         table1..table12 | tables | all  [pjrt]
         pareto | fig7 | fig9 | fig10 | energy | cse | scaling
         repetition [--out FILE]            scaling studies -> BENCH_current.json
         network [--depth N] [--batch N] [--tile N] [--out FILE]
                                            full-network forward scaling on the
                                            repetition engine: CIFAR ResNet,
                                            resnet18c and a 1x1 chain, each with
                                            patch reuse off/on (the
                                            network_forward_fused series), plus
                                            the always-on batch ladder
                                            (forward_batch at b 1/4/16/64,
                                            network_forward_b{N} records, each
                                            rung gated bitwise against N
                                            independent b=1 forwards before
                                            timing; --batch only sets the base
                                            workloads' compile batch);
                                            --tile 0 (default) auto-tunes the
                                            execution tile, skipping candidates
                                            blocked I/O cannot carry
         density [--batch N] [--subtile N] [--tile N] [--out FILE]
                                            repetition-sparsity trade-off curve:
                                            resnet20 + resnet18c across the
                                            density ladder (binary, ternary, sb,
                                            sb-nm2:4, sb-nm1:4), sparsity
                                            support on vs off, forward time +
                                            effectual density ->
                                            BENCH_density_current.json; every
                                            sparsity-on forward is gated
                                            bit-identical to the unelided
                                            reference plan
         serve [--model NAME] [--image N] [--rps F] [--duration S] [--out FILE]
               [--swap-at S]                open-loop serving load harness on the
                                            engine backend: p50/p95/p99, goodput
                                            and shed rate ->
                                            BENCH_serving_current.json;
                                            --swap-at S hot-swaps a fresh model
                                            version S seconds into the window
                                            (the zero-downtime swap drill:
                                            swap_drain_ms / swap_p99 /
                                            swap_dropped records); with
                                            --max-batch > 1 a second short run
                                            caps the batcher at one sample per
                                            forward (serve_throughput_b1) so
                                            the batched-goodput win is recorded
         compare --current FILE [--baseline FILE] [--tolerance F]
                                            fail on perf regression vs baseline
  audit --all | --model NAME [--scheme binary|ternary|sb] [--batch N]
        [--image N] [--tile N] [--subtile N] [--no-sparsity] [--unfused]
                                            static plan-soundness verifier: prove
                                            the executor's soundness preconditions
                                            (arena CSR bounds, tile-disjoint
                                            writes, slot live ranges, blocked
                                            tile alignment, batch-prefix fit)
                                            by symbolic range analysis over
                                            compiled plans — no forward runs.
                                            --all sweeps the zoo (resnet8/20/32,
                                            resnet18c, chain1x1) x schemes x
                                            sparsity on/off x bmax {1,64}, fused
                                            and unfused; any finding exits
                                            nonzero (the CI hard gate)
  serve [--backend engine|pjrt] --model NAME [--requests N] [--replicas R]
        [--ckpt PATH]                       engine (default, plain CPU): resnetN,
                                            resnet18c (projection shortcuts) or
                                            chain1x1; pjrt needs the feature
        [--models a,b]                      engine only: deploy each named model
                                            into its own catalog slot (warmed)
                                            and round-robin the burst by name
  report weights --model NAME               figure 6/11 distributions
  quantize --model NAME                     density/repetition/bit report [pjrt]
  registry                                  list artifacts + footprints
  help

Commands marked [pjrt] need `cargo build --features pjrt` (see rust/README.md).

GLOBAL OPTIONS:
  --artifacts DIR (default artifacts)   --out-dir DIR (default out)
  --config FILE  --steps N  --seed N  --reps N  --eval-batches N
  --threads N   pin the worker-pool width for this run (engine, GEMM and
                plan build; equivalent to the PLUM_THREADS env var; for
                the scaling studies it also caps the thread ladder)

SERVING OPTIONS (serve, bench serve):
  --replicas R          worker replicas behind the router (default 1)
  --max-batch N         device batch per replica (default 8)
  --max-wait-ms MS      batcher fill deadline (default 2)
  --queue-depth N       bounded admission queue per replica; beyond it
                        requests shed with a typed Overloaded (default 256)
  --deadline-ms MS      default request deadline; expired requests answer
                        DeadlineExceeded without costing a batch (default 1000)
  --breaker-threshold N consecutive replica failures that trip the circuit
                        breaker (until then the supervisor respawns; default 3)
  --drain-timeout-ms MS graceful-drain budget at a hot swap / retirement /
                        shutdown: the old generation gets this long to finish
                        queued work, then stragglers are answered typed
                        (default 5000)
";

/// Entry point of the `plum` binary: parse `argv` (everything after the
/// program name), resolve the run configuration, pin the worker pool,
/// and dispatch the subcommand.
pub fn run(argv: Vec<String>) -> Result<()> {
    let mut it = argv.into_iter();
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let args = Args::parse(it);
    let cfg = RunConfig::resolve(&args)?;
    if cfg.threads > 0 {
        // pin the process-wide pool before anything dispatches on it
        if let Err(e) = crate::util::Pool::init_global(cfg.threads) {
            eprintln!("warning: --threads {} ignored: {e}", cfg.threads);
        }
    }
    match cmd.as_str() {
        "train" => cmd_train(&cfg, &args),
        "bench" => cmd_bench(&cfg, &args),
        "audit" => cmd_audit(&args),
        "serve" => cmd_serve(&cfg, &args),
        "report" => cmd_report(&cfg, &args),
        "quantize" => cmd_quantize(&cfg, &args),
        "registry" => cmd_registry(&cfg),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}' — try `plum help`")),
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_required(what: &str) -> anyhow::Error {
    anyhow!(
        "`{what}` needs the PJRT runtime — rebuild with `cargo build --release \
         --features pjrt` (requires xla_extension; see rust/README.md)"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_train(cfg: &RunConfig, args: &Args) -> Result<()> {
    let model = args
        .get("model")
        .ok_or_else(|| anyhow!("--model required"))?;
    let rt = Runtime::cpu()?;
    let mut tr = Trainer::new(&rt, &cfg.artifacts, model)?;
    let ds = experiments::dataset_for_run(cfg, &tr.model.manifest);
    let schedule = Schedule::Step {
        init: args.get_f32("lr", 5e-3),
        milestones: vec![0.5, 0.8],
    };
    println!(
        "training {model}: {} params, {} steps, bs {}",
        tr.model.manifest.param_count,
        cfg.steps,
        tr.batch_size()
    );
    let log =
        tr.train(&ds, cfg.steps, &schedule, (cfg.steps / 20).max(1), cfg.eval_batches, false)?;
    println!(
        "final: loss {:.4}, eval acc {:.3}, density {:.2}, {:.1}s ({:.0} ms/step)",
        log.final_train_loss,
        log.eval_acc,
        tr.quantized_density()?,
        log.wall_secs,
        1e3 * log.wall_secs / log.steps as f64
    );
    std::fs::create_dir_all(&cfg.out_dir).ok();
    let ckpt = cfg.out_dir.join(format!("{model}.ckpt"));
    save_checkpoint(&ckpt, tr.step, &tr.state_to_host()?)?;
    println!("checkpoint: {}", ckpt.display());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_cfg: &RunConfig, _args: &Args) -> Result<()> {
    Err(pjrt_required("plum train"))
}

fn cmd_bench(cfg: &RunConfig, args: &Args) -> Result<()> {
    let target = args
        .positionals
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow!("bench target required — see `plum help`"))?;
    let subtile = args.get_usize("subtile", 0); // 0 = auto-tuned
    match target {
        "pareto" => tables::pareto(cfg),
        "fig7" => figures::fig7(cfg, args.get_usize("batch", 1), subtile, None).map(drop),
        "fig9" => figures::fig9(cfg, subtile),
        "fig10" => figures::fig10(cfg, subtile, args.get_usize("points", 20)),
        "energy" => figures::energy(cfg, args.get_f32("sparsity", 0.65) as f64),
        "cse" => figures::cse_ablation(cfg, args.get_usize("rounds", 3000)),
        "scaling" => {
            let geom = figures::resnet_block_geometry(args.get_usize("batch", 1));
            let threads = figures::default_thread_ladder(args.get_usize("threads", 0));
            figures::engine_scaling(cfg, geom, &threads).map(drop)
        }
        // the full perf-trajectory run CI gates on: executor scaling +
        // plan-build scaling, persisted as BENCH_repetition.json
        "repetition" => bench_repetition(cfg, args),
        // whole-network forward through the network executor — the
        // `network_forward` series, gated like the repetition series
        "network" => bench_network(cfg, args),
        // the repetition-sparsity trade-off curve — the `BENCH_density`
        // series (paper Fig. 10 measured on the real engine)
        "density" => bench_density(cfg, args),
        // open-loop serving load harness — the `BENCH_serving` series
        "serve" => bench_serve(cfg, args),
        "compare" => bench_compare(args),
        other => bench_trained(cfg, args, other, subtile),
    }
}

fn bench_repetition(cfg: &RunConfig, args: &Args) -> Result<()> {
    let (_, points) =
        figures::repetition_study(cfg, args.get_usize("batch", 1), args.get_usize("threads", 0))?;
    // default away from BENCH_repetition.json: that path is the
    // committed CI baseline, and re-baselining should be an explicit act
    let out = std::path::PathBuf::from(args.get_or("out", "BENCH_current.json"));
    let n = figures::write_scaling_records(&points, &out)?;
    println!("wrote {n} records to {}", out.display());
    Ok(())
}

fn bench_network(cfg: &RunConfig, args: &Args) -> Result<()> {
    let depth = args.get_usize("depth", 20);
    let batch = args.get_usize("batch", 1);
    let subtile = args.get_usize("subtile", 0); // 0 = auto-tuned
    let threads = args.get_usize("threads", 0);
    // 0 = auto-tune the execution tile per workload; with patch fusion
    // on, non-PIXEL_BLOCK-aligned candidates are skipped up front
    let tile = args.get_usize("tile", 0);
    let (_, points) = figures::network_forward_study(cfg, depth, batch, subtile, threads, tile)?;
    // like `bench repetition`, default away from the committed baseline
    // (BENCH_network.json) so re-baselining stays an explicit act
    let out = std::path::PathBuf::from(args.get_or("out", "BENCH_network_current.json"));
    let n = figures::write_scaling_records(&points, &out)?;
    println!("wrote {n} records to {}", out.display());
    Ok(())
}

/// `plum bench density`: the repetition-sparsity trade-off curve
/// (resnet20 + resnet18c across the density ladder, sparsity support
/// on vs off), persisted as the `BENCH_density` series for the CI
/// compare gate. `--threads` pins the pool width (CI pins 2 so the
/// committed baseline's record keys stay stable).
fn bench_density(cfg: &RunConfig, args: &Args) -> Result<()> {
    let batch = args.get_usize("batch", 1);
    let subtile = args.get_usize("subtile", 0); // 0 = auto-tuned
    let threads = args.get_usize("threads", 0);
    let tile = args.get_usize("tile", 0); // 0 = DEFAULT_TILE
    let points = figures::density_study(cfg, batch, subtile, threads, tile)?;
    // like the other bench targets, default away from the committed
    // baseline (BENCH_density.json) so re-baselining stays explicit
    let out = std::path::PathBuf::from(args.get_or("out", "BENCH_density_current.json"));
    let n = figures::write_scaling_records(&points, &out)?;
    println!("wrote {n} records to {}", out.display());
    Ok(())
}

/// `plum bench serve`: one open-loop load run against supervised engine
/// replicas, persisted as the `BENCH_serving` series (p50/p95/p99,
/// goodput, shed rate) for the CI compare gate. `--swap-at S` turns the
/// run into the hot-swap drill: a fresh model version is deployed `S`
/// seconds into the window under load and the series gains
/// swap_drain_ms / swap_p99 / swap_dropped records. With
/// `--max-batch > 1` a second short run caps the batcher at one sample
/// per engine forward and records it as `serve_throughput_b1`, so the
/// batched-goodput win stays measured.
fn bench_serve(cfg: &RunConfig, args: &Args) -> Result<()> {
    let model = args.get_or("model", "resnet8");
    let image = args.get_usize("image", 16);
    let rps = args.get_f32("rps", 40.0) as f64;
    let duration = args.get_f32("duration", 2.0) as f64;
    let swap_at = args.get("swap-at").map(|v| {
        v.parse::<f64>()
            .map_err(|_| anyhow!("--swap-at wants seconds into the window, got '{v}'"))
    });
    let swap_at = swap_at.transpose()?;
    let (report, points) = figures::serving_study(cfg, model, image, rps, duration, swap_at)?;
    println!(
        "\noffered {} req @ {:.0} rps over {:.2}s: {} ok, {} shed, {} expired, {} failed, \
         {} crash(es), {} dropped",
        report.offered,
        report.target_rps,
        report.wall_secs,
        report.completed,
        report.shed,
        report.expired,
        report.failed,
        report.crashes,
        report.dropped
    );
    println!(
        "goodput {:.1} req/s, e2e p50<={}us p95<={}us p99<={}us, shed {} ppm",
        report.achieved_rps, report.p50_us, report.p95_us, report.p99_us, report.shed_ppm
    );
    if let Some(swap) = &report.swap {
        println!(
            "hot swap at {:.2}s -> v{}: warmup {:.1} ms, drain {:.1} ms ({}, {} straggler(s)); \
             p99 across the swap {}us",
            swap.at_s,
            swap.version,
            swap.warmup_ms,
            swap.drain_ms,
            if swap.drained_clean { "clean" } else { "forced" },
            swap.stragglers,
            report.p99_us
        );
    }
    // like the other bench targets, default away from the committed
    // baseline (BENCH_serving.json) so re-baselining stays explicit
    let out = std::path::PathBuf::from(args.get_or("out", "BENCH_serving_current.json"));
    let n = figures::write_scaling_records(&points, &out)?;
    println!("wrote {n} records to {}", out.display());
    Ok(())
}

fn bench_compare(args: &Args) -> Result<()> {
    use crate::util::bench::{compare_bench, read_bench_json};
    let current_path = args.get("current").ok_or_else(|| {
        anyhow!("usage: plum bench compare --current FILE [--baseline FILE] [--tolerance F]")
    })?;
    let baseline_path = args.get_or("baseline", "BENCH_repetition.json");
    let tolerance = args.get_f32("tolerance", 0.25) as f64;
    let baseline = read_bench_json(std::path::Path::new(baseline_path))?;
    let current = read_bench_json(std::path::Path::new(current_path))?;
    let regressions = compare_bench(&baseline, &current, tolerance);
    if regressions.is_empty() {
        println!(
            "bench compare: {} baseline records within {:.0}% ({} vs {})",
            baseline.len(),
            tolerance * 100.0,
            current_path,
            baseline_path
        );
        Ok(())
    } else {
        for r in &regressions {
            eprintln!("REGRESSION {r}");
        }
        Err(anyhow!(
            "{} perf regression(s) vs {} (tolerance {:.0}%)",
            regressions.len(),
            baseline_path,
            tolerance * 100.0
        ))
    }
}

/// `plum audit`: the static plan-soundness verifier
/// ([`crate::analysis`]). Compiles plans from zoo geometry and proves
/// the unsafe executor's preconditions by symbolic range analysis — no
/// forward is executed, so the gate is cheap enough to run on every CI
/// build. `--all` sweeps the whole zoo across schemes, sparsity
/// support on/off and bmax ∈ {1, 64}, auditing the fused plan and its
/// unfused twin from each compile; any finding exits nonzero.
fn cmd_audit(args: &Args) -> Result<()> {
    use crate::analysis::audit_network_plan;
    use crate::network::NetworkPlan;
    use crate::quant::Scheme;
    use crate::repetition::{EngineConfig, DEFAULT_TILE};

    fn parse_scheme(name: &str) -> Result<Scheme> {
        match name {
            "binary" => Ok(Scheme::Binary),
            "ternary" => Ok(Scheme::ternary_default()),
            "sb" | "signed-binary" => Ok(Scheme::sb_default()),
            other => Err(anyhow!("unknown audit scheme '{other}' — binary | ternary | sb")),
        }
    }

    let image = args.get_usize("image", 32);
    let tile = args.get_usize("tile", DEFAULT_TILE);
    // fixed sub-tile: auto-tuning (subtile 0) only moves perf, not
    // soundness, and a fixed value keeps the sweep fast + deterministic
    let subtile = args.get_usize("subtile", 8);
    let combos: Vec<(&str, String, bool, usize)> = if args.has("all") {
        let mut v = Vec::new();
        for model in ["resnet8", "resnet20", "resnet32", "resnet18c", "chain1x1"] {
            for scheme in ["binary", "ternary", "sb"] {
                for sparsity in [true, false] {
                    for bmax in [1usize, 64] {
                        v.push((model, scheme.to_string(), sparsity, bmax));
                    }
                }
            }
        }
        v
    } else {
        let model = args.get("model").ok_or_else(|| {
            anyhow!("usage: plum audit --all | --model NAME [--scheme S] [--batch N]")
        })?;
        vec![(
            model,
            args.get_or("scheme", "sb").to_string(),
            !args.has("no-sparsity"),
            args.get_usize("batch", 1),
        )]
    };

    let unfused_only = args.has("unfused");
    let mut findings_total = 0usize;
    let mut audits = 0usize;
    for (model, scheme_name, sparsity, bmax) in &combos {
        let scheme = parse_scheme(scheme_name)?;
        let descs = crate::models::engine_model_layers(model, image, *bmax)
            .ok_or_else(|| anyhow!("unknown model '{model}' — resnetN | resnet18c | chain1x1"))?;
        let cfg = EngineConfig { subtile, sparsity_support: *sparsity };
        let plan = NetworkPlan::compile(&descs, cfg, scheme)?;
        let mut variants: Vec<(&str, NetworkPlan)> = Vec::new();
        if !unfused_only {
            variants.push(("fused", plan.clone()));
        }
        variants.push(("unfused", plan.without_patch_fusion()));
        for (variant, p) in &variants {
            let findings = audit_network_plan(p, tile);
            audits += 1;
            let label = format!(
                "{model} {scheme_name} sparsity={} bmax={bmax} {variant}",
                if *sparsity { "on" } else { "off" }
            );
            if findings.is_empty() {
                println!(
                    "audit OK   {label}: {} layers, {} arena slots, {} fused edges",
                    p.num_layers(),
                    p.num_arena_slots(),
                    p.patch_fused_edges()
                );
            } else {
                findings_total += findings.len();
                println!("audit FAIL {label}: {} finding(s)", findings.len());
                for f in &findings {
                    println!("  {f}");
                }
            }
        }
    }
    if findings_total == 0 {
        println!("{audits} plan audit(s) clean — the executor's soundness preconditions hold");
        Ok(())
    } else {
        Err(anyhow!("{findings_total} soundness finding(s) across {audits} plan audit(s)"))
    }
}

/// Table targets (and `all`) train through PJRT.
#[cfg(feature = "pjrt")]
fn bench_trained(cfg: &RunConfig, args: &Args, target: &str, subtile: usize) -> Result<()> {
    let fresh = args.has("fresh");
    let rt = Runtime::cpu()?;
    let rt = &rt;
    match target {
        "table1" => drop(tables::table1(cfg, rt, fresh)?),
        "table2" => drop(tables::table_mix(cfg, rt, fresh, false)?),
        "table3" => drop(tables::table_ede(cfg, rt, fresh, false)?),
        "table4" => drop(tables::table4(cfg, rt, fresh)?),
        "table5" => drop(tables::table_delta(cfg, rt, fresh, false)?),
        "table6" => drop(tables::table6(cfg, rt, fresh)?),
        "table7" => drop(tables::table7(cfg, rt, fresh)?),
        "table8" => drop(tables::table8(cfg, rt, fresh)?),
        "table9" => drop(tables::table9(cfg, rt, fresh)?),
        "table10" => drop(tables::table_mix(cfg, rt, fresh, true)?),
        "table11" => drop(tables::table_ede(cfg, rt, fresh, true)?),
        "table12" => drop(tables::table_delta(cfg, rt, fresh, true)?),
        "tables" => {
            tables::table1(cfg, rt, fresh)?;
            tables::table_mix(cfg, rt, fresh, false)?;
            tables::table_ede(cfg, rt, fresh, false)?;
            tables::table4(cfg, rt, fresh)?;
            tables::table_delta(cfg, rt, fresh, false)?;
            tables::table6(cfg, rt, fresh)?;
            tables::table7(cfg, rt, fresh)?;
            tables::table8(cfg, rt, fresh)?;
            tables::table9(cfg, rt, fresh)?;
            tables::pareto(cfg)?;
        }
        "all" => {
            tables::table1(cfg, rt, fresh)?;
            tables::table_mix(cfg, rt, fresh, false)?;
            tables::table_ede(cfg, rt, fresh, false)?;
            tables::table4(cfg, rt, fresh)?;
            tables::table_delta(cfg, rt, fresh, false)?;
            tables::table6(cfg, rt, fresh)?;
            tables::table7(cfg, rt, fresh)?;
            tables::table8(cfg, rt, fresh)?;
            tables::table9(cfg, rt, fresh)?;
            tables::table_mix(cfg, rt, fresh, true)?;
            tables::table_ede(cfg, rt, fresh, true)?;
            tables::table_delta(cfg, rt, fresh, true)?;
            tables::pareto(cfg)?;
            figures::fig7(cfg, 1, subtile, None)?;
            figures::fig9(cfg, subtile)?;
            figures::fig10(cfg, subtile, 20)?;
            figures::energy(cfg, 0.65)?;
        }
        other => return Err(anyhow!("unknown bench target '{other}'")),
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn bench_trained(_cfg: &RunConfig, _args: &Args, target: &str, _subtile: usize) -> Result<()> {
    match target {
        "table1" | "table2" | "table3" | "table4" | "table5" | "table6" | "table7"
        | "table8" | "table9" | "table10" | "table11" | "table12" | "tables" | "all" => {
            Err(pjrt_required(&format!("plum bench {target}")))
        }
        other => Err(anyhow!("unknown bench target '{other}'")),
    }
}

/// Serve on the repetition engine by default (plain CPU, no features);
/// `--backend pjrt` routes to the AOT runtime when it is compiled in.
/// Default model is per backend: the engine compiles zoo geometry
/// ("resnet20"), pjrt loads the artifact by name ("resnet20_sb").
fn cmd_serve(cfg: &RunConfig, args: &Args) -> Result<()> {
    let requests = args.get_usize("requests", 256);
    let report = match args.get_or("backend", "engine") {
        "engine" => {
            if let Some(csv) = args.get("models") {
                // multi-model: each name gets its own warmed catalog
                // slot; the burst round-robins across them by name
                let names: Vec<String> = csv
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(str::to_string)
                    .collect();
                serving::drive_engine_multi(cfg, &names, 32, requests)?
            } else {
                let model = args.get_or("model", "resnet20");
                serving::drive_engine(cfg, model, requests)?
            }
        }
        "pjrt" => {
            let model = args.get_or("model", "resnet20_sb").to_string();
            serve_pjrt(cfg, args, &model, requests)?
        }
        other => return Err(anyhow!("unknown serve backend '{other}' — engine | pjrt")),
    };
    println!(
        "\nserved {}/{} requests on {} replica(s): {:.1} req/s, mean {:.1} ms, p95 {:.1} ms \
         ({} shed, {} expired, {} failed)",
        report.completed,
        report.requests,
        report.replicas,
        report.throughput_rps,
        report.mean_ms,
        report.p95_ms,
        report.shed,
        report.expired,
        report.failed
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn serve_pjrt(
    cfg: &RunConfig,
    args: &Args,
    model: &str,
    requests: usize,
) -> Result<serving::ServeReport> {
    let ckpt = args.get("ckpt").map(std::path::PathBuf::from);
    serving::drive(cfg, model, requests, ckpt)
}

#[cfg(not(feature = "pjrt"))]
fn serve_pjrt(
    _cfg: &RunConfig,
    _args: &Args,
    _model: &str,
    _requests: usize,
) -> Result<serving::ServeReport> {
    Err(pjrt_required("plum serve --backend pjrt"))
}

fn cmd_report(cfg: &RunConfig, args: &Args) -> Result<()> {
    match args.positionals.first().map(String::as_str) {
        Some("weights") => {
            let model = args.get_or("model", "resnet20_sb");
            figures::report_weights(cfg, model)
        }
        _ => Err(anyhow!("usage: plum report weights --model NAME")),
    }
}

#[cfg(feature = "pjrt")]
fn cmd_quantize(cfg: &RunConfig, args: &Args) -> Result<()> {
    let model = args
        .get("model")
        .ok_or_else(|| anyhow!("--model required"))?;
    let rt = Runtime::cpu()?;
    let tr = Trainer::new(&rt, &cfg.artifacts, model)?;
    let layers = tr.export_quantized()?;
    let mut rows = Vec::new();
    let (mut bits, mut eff, mut tot) = (0usize, 0usize, 0usize);
    for (info, q) in &layers {
        let st = crate::quant::filter_repetition_stats(&q.values, info.geom.k);
        if !q.beta.is_empty() && q.scheme.values_per_filter() == 2 {
            bits += PackedSignedBinary::pack(q).weight_bits();
        }
        eff += q.effectual();
        tot += q.values.len();
        rows.push(vec![
            info.name.clone(),
            format!("{}x{}x{}x{}", info.geom.k, info.geom.c, info.geom.r, info.geom.s),
            format!("{:.2}", st.density),
            format!("{:.2}", st.mean_unique_values),
            format!("{:.2}", st.unique_filter_fraction),
        ]);
    }
    experiments::print_table(
        &format!("quantization report — {model} ({})", tr.model.manifest.config.scheme),
        &["Layer", "KxCxRxS", "density", "uniq vals/filter", "uniq filters"],
        &rows,
    );
    println!(
        "\naggregate: density {:.2} ({} / {} effectual), packed sb footprint {} bits ({} KiB)",
        eff as f64 / tot.max(1) as f64,
        eff,
        tot,
        bits,
        bits / 8 / 1024
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_quantize(_cfg: &RunConfig, _args: &Args) -> Result<()> {
    Err(pjrt_required("plum quantize"))
}

fn cmd_registry(cfg: &RunConfig) -> Result<()> {
    let reg = ModelRegistry::scan(&cfg.artifacts)?;
    let rows: Vec<Vec<String>> = reg
        .entries
        .iter()
        .map(|e| {
            vec![
                e.name.clone(),
                e.arch.clone(),
                e.scheme.clone(),
                format!("{}", e.batch_size),
                format!("{:.2}M", e.param_count as f64 / 1e6),
                format!("{:.0}k", e.effectual_params_init as f64 / 1e3),
                format!("{} KiB", e.weight_bits / 8 / 1024),
            ]
        })
        .collect();
    experiments::print_table(
        &format!("model registry — {} ({} artifacts)", cfg.artifacts.display(), rows.len()),
        &["Name", "Arch", "Scheme", "BS", "Params", "Eff(init)", "Weight bits"],
        &rows,
    );
    for (name, err) in &reg.errors {
        eprintln!("warning: manifest '{name}' failed to load: {err}");
    }
    Ok(())
}
