//! Greedy pairwise common-subexpression elimination over weight groups —
//! the *literal* SumMerge algorithm (Prabhakar et al. 2021 §4), kept
//! alongside the pattern-memoized engine as a fidelity ablation.
//!
//! SumMerge represents each filter's dot product as a set of signed
//! operands (activations to add/subtract) and repeatedly extracts the
//! most frequent signed operand *pair* into a new node, shrinking total
//! operand count until no pair repeats. The resulting DAG is evaluated
//! per output pixel: each node is one add; arithmetic reduction =
//! dense ops / DAG ops.
//!
//! The pattern-memoized planner (plan.rs) approximates this DAG with
//! fixed-width sub-tiles; `bench: plum simulate cse` and the unit tests
//! here quantify how close the approximation gets (DESIGN.md lists this
//! as a design-choice ablation).

use std::collections::HashMap;

use crate::quant::QuantizedWeights;
use crate::tensor::Conv2dGeometry;

/// A signed reference to either an input activation (by reduction-axis
/// index) or an internal DAG node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Operand {
    /// input activation by reduction-axis (C*R*S) index
    Input(u32),
    /// internal DAG node by index
    Node(u32),
}

/// One CSE node: left + sign*right.
#[derive(Debug, Clone, Copy)]
pub struct Node {
    /// left operand (always added)
    pub a: Operand,
    /// right operand
    pub b: Operand,
    /// sign applied to b (+1 / -1); a is always positive within a node —
    /// group signs are normalized before pairing.
    pub b_neg: bool,
}

/// The DAG for one conv layer.
#[derive(Debug)]
pub struct CseDag {
    /// internal nodes, topologically ordered
    pub nodes: Vec<Node>,
    /// per original filter: (alpha, signed roots) — the filter output is
    /// alpha * sum(sign * root).
    pub filters: Vec<(f32, Vec<(Operand, bool)>)>,
    /// the conv geometry the DAG was built for
    pub geom: Conv2dGeometry,
}

impl CseDag {
    /// Total adds per output pixel: one per node + (roots-1) per filter.
    pub fn adds_per_pixel(&self) -> u64 {
        let node_adds = self.nodes.len() as u64;
        let root_adds: u64 = self
            .filters
            .iter()
            .map(|(_, r)| (r.len() as u64).saturating_sub(1))
            .sum();
        node_adds + root_adds
    }

    /// Muls per pixel: one alpha scale per filter with any effectual root.
    pub fn muls_per_pixel(&self) -> u64 {
        self.filters.iter().filter(|(_, r)| !r.is_empty()).count() as u64
    }

    /// Arithmetic reduction vs dense (2 ops per MAC), whole layer.
    pub fn arithmetic_reduction(&self) -> f64 {
        let dense = 2.0 * self.geom.dense_macs() as f64;
        let pixels = (self.geom.n * self.geom.out_h() * self.geom.out_w()) as u64;
        dense / (pixels * (self.adds_per_pixel() + self.muls_per_pixel())).max(1) as f64
    }

    /// Evaluate the DAG for one im2col patch row (testing / reference).
    pub fn eval_row(&self, row: &[f32]) -> Vec<f32> {
        let mut vals = vec![0.0f32; self.nodes.len()];
        let get = |vals: &Vec<f32>, op: Operand| -> f32 {
            match op {
                Operand::Input(i) => row[i as usize],
                Operand::Node(i) => vals[i as usize],
            }
        };
        for (i, n) in self.nodes.iter().enumerate() {
            let b = get(&vals, n.b);
            vals[i] = get(&vals, n.a) + if n.b_neg { -b } else { b };
        }
        self.filters
            .iter()
            .map(|(alpha, roots)| {
                let s: f32 = roots
                    .iter()
                    .map(|(op, neg)| {
                        let v = get(&vals, *op);
                        if *neg {
                            -v
                        } else {
                            v
                        }
                    })
                    .sum();
                alpha * s
            })
            .collect()
    }
}

/// Build the SumMerge DAG for one quantized layer.
///
/// `max_rounds` caps greedy pairing work (the paper's implementation
/// likewise bounds optimization time); 0 means unbounded.
pub fn build_cse(q: &QuantizedWeights, geom: Conv2dGeometry, max_rounds: usize) -> CseDag {
    let e = geom.c * geom.r * geom.s;
    let k = geom.k;
    assert_eq!(q.values.len(), k * e);

    // per filter: signed operand list over inputs (sign folded from the
    // quantized value; alpha = |value|)
    let mut filter_ops: Vec<Vec<(Operand, bool)>> = Vec::with_capacity(k);
    let mut alphas = Vec::with_capacity(k);
    for fi in 0..k {
        let row = &q.values.data()[fi * e..(fi + 1) * e];
        let alpha = row
            .iter()
            .find(|v| **v != 0.0)
            .map(|v| v.abs())
            .unwrap_or(0.0);
        alphas.push(alpha);
        let ops: Vec<(Operand, bool)> = row
            .iter()
            .enumerate()
            .filter(|(_, v)| **v != 0.0)
            .map(|(i, v)| (Operand::Input(i as u32), *v < 0.0))
            .collect();
        filter_ops.push(ops);
    }

    let mut nodes: Vec<Node> = Vec::new();
    let mut round = 0usize;
    loop {
        if max_rounds > 0 && round >= max_rounds {
            break;
        }
        round += 1;
        // count signed pairs across all filters (canonical order so
        // (a,+b) and (b,+a) coincide; relative sign matters)
        let mut pair_count: HashMap<(Operand, bool, Operand, bool), u32> = HashMap::new();
        for ops in &filter_ops {
            // operands are kept sorted for canonical adjacent-agnostic pairs
            for i in 0..ops.len() {
                for j in (i + 1)..ops.len().min(i + 9) {
                    // window cap keeps this O(n) per filter like SumMerge's
                    // neighbourhood heuristic
                    let key = (ops[i].0, ops[i].1, ops[j].0, ops[j].1);
                    *pair_count.entry(key).or_insert(0) += 1;
                }
            }
        }
        let Some((best_key, best_n)) = pair_count
            .into_iter()
            .max_by_key(|(k2, n)| (*n, std::cmp::Reverse(*k2)))
        else {
            break;
        };
        if best_n < 2 {
            break; // no pair repeats — DAG is dry
        }
        let (a, a_neg, b, b_neg) = best_key;
        // new node computes a + b with signs normalized so the node's own
        // sign is a_neg (factored out at use sites)
        let node = Node { a, b, b_neg: a_neg != b_neg };
        let node_op = Operand::Node(nodes.len() as u32);
        nodes.push(node);
        for ops in filter_ops.iter_mut() {
            // replace occurrences of the signed pair (also the globally
            // negated pair, which equals -(node))
            let pos_i = ops.iter().position(|o| *o == (a, a_neg));
            let pos_j = ops.iter().position(|o| *o == (b, b_neg));
            if let (Some(i), Some(j)) = (pos_i, pos_j) {
                let (lo, hi) = if i < j { (i, j) } else { (j, i) };
                ops.remove(hi);
                ops.remove(lo);
                ops.push((node_op, a_neg));
                continue;
            }
            let neg_i = ops.iter().position(|o| *o == (a, !a_neg));
            let neg_j = ops.iter().position(|o| *o == (b, !b_neg));
            if let (Some(i), Some(j)) = (neg_i, neg_j) {
                let (lo, hi) = if i < j { (i, j) } else { (j, i) };
                ops.remove(hi);
                ops.remove(lo);
                ops.push((node_op, !a_neg));
            }
        }
    }

    CseDag {
        nodes,
        filters: alphas.into_iter().zip(filter_ops).collect(),
        geom,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{self, Scheme};
    use crate::tensor::{im2col, Tensor};
    use crate::util::Rng;

    fn geom(c: usize, k: usize) -> Conv2dGeometry {
        Conv2dGeometry { n: 1, c, h: 4, w: 4, k, r: 3, s: 3, stride: 1, padding: 1 }
    }

    #[test]
    fn dag_matches_dense_dot() {
        let mut rng = Rng::new(70);
        let g = geom(4, 8);
        let w = Tensor::rand_normal(&[g.k, g.c, 3, 3], 0.6, &mut rng);
        let q = quant::quantize(&w, Scheme::sb_default(), None);
        let dag = build_cse(&q, g, 0);
        let x = Tensor::rand_normal(&[1, g.c, 4, 4], 1.0, &mut rng);
        let patches = im2col(&x, 3, 3, 1, 1);
        let e = g.c * 9;
        for px in 0..4 {
            let row = &patches.data()[px * e..(px + 1) * e];
            let got = dag.eval_row(row);
            for fi in 0..g.k {
                let want: f32 = row
                    .iter()
                    .zip(&q.values.data()[fi * e..(fi + 1) * e])
                    .map(|(a, w)| a * w)
                    .sum();
                assert!(
                    (got[fi] - want).abs() < 1e-3,
                    "px {px} filter {fi}: {} vs {want}",
                    got[fi]
                );
            }
        }
    }

    #[test]
    fn cse_reduces_ops_vs_flat_groups() {
        let mut rng = Rng::new(71);
        let g = geom(16, 64);
        let w = Tensor::rand_normal(&[g.k, g.c, 3, 3], 0.6, &mut rng);
        let q = quant::quantize(&w, Scheme::Binary, None);
        let flat_adds: u64 = (g.k * (g.c * 9 - 1)) as u64; // dense per-filter adds
        let dag = build_cse(&q, g, 2000);
        assert!(
            dag.adds_per_pixel() < flat_adds,
            "cse {} !< flat {flat_adds}",
            dag.adds_per_pixel()
        );
    }

    #[test]
    fn sb_dag_cheaper_than_binary_dag() {
        let mut rng = Rng::new(72);
        let g = geom(32, 64);
        let w = Tensor::rand_normal(&[g.k, g.c, 3, 3], 0.6, &mut rng);
        let db = build_cse(&quant::quantize(&w, Scheme::Binary, None), g, 500);
        let ds = build_cse(&quant::quantize(&w, Scheme::sb_default(), None), g, 500);
        assert!(
            ds.adds_per_pixel() < db.adds_per_pixel(),
            "sb {} !< binary {}",
            ds.adds_per_pixel(),
            db.adds_per_pixel()
        );
    }

    #[test]
    fn all_zero_filter_has_no_roots() {
        let g = geom(2, 2);
        let mut w = Tensor::filled(&[2, 2, 3, 3], 0.9);
        for i in 0..18 {
            w.data_mut()[i] = -0.9; // filter 0 all negative, beta=+1 -> zero
        }
        let q = quant::quantize_signed_binary(&w, &[1.0, 1.0], 0.05, 1);
        let dag = build_cse(&q, g, 0);
        assert!(dag.filters[0].1.is_empty());
        assert_eq!(dag.muls_per_pixel(), 1);
    }

    #[test]
    fn round_cap_respected() {
        let mut rng = Rng::new(73);
        let g = geom(8, 16);
        let w = Tensor::rand_normal(&[g.k, g.c, 3, 3], 0.6, &mut rng);
        let q = quant::quantize(&w, Scheme::Binary, None);
        let capped = build_cse(&q, g, 3);
        assert!(capped.nodes.len() <= 3);
    }
}
