//! Tiled, parallel, **pixel-major** executor for a `LayerPlan`.
//!
//! The output-pixel axis is cut into fixed tiles ([`DEFAULT_TILE`]
//! pixels); tiles are distributed over the persistent worker pool
//! (`util::pool`). Per tile, one worker:
//!
//!   1. **fuses im2col, transposed**: builds just the tile's patch rows
//!      into its own scratch buffer via `im2col_rows_transposed` — as
//!      `[C*R*S, PIXEL_BLOCK]` blocks with pixels minor, so the full
//!      `[N*OH*OW, C*R*S]` patch matrix is never materialized *and*
//!      every later column access is a contiguous SIMD-width run;
//!   2. walks the plan's CSR index arena once per pixel block: every
//!      *distinct* pattern's partial sum is evaluated once into a
//!      thread-local psum arena (this is where repetition pays — the sum
//!      is shared by all filters using the pattern). A pattern column's
//!      gather is now a contiguous `PIXEL_BLOCK`-wide f32 load + add
//!      (`[f32; PIXEL_BLOCK]` array windows, which LLVM lowers to one
//!      AVX2 vector op), where the row-major layout forced a
//!      stride-`C*R*S` walk that defeated vectorization exactly where
//!      repetition pays;
//!   3. combines per *unique* filter through the flat `combine` table on
//!      the same block layout and multiplies by alpha once;
//!   4. scatters unique-filter results to the original filter slots
//!      (inter-filter dedup) — each tile owns a disjoint set of output
//!      pixels, so workers write without synchronization.
//!
//! Tile and block partitioning depend only on the tile size, never on
//! the thread count, each worker owns its psum/usum/patch arenas, and
//! ragged final blocks are zero-padded to full SIMD width, so per-lane
//! f32 accumulation order is fixed and N-thread output is
//! **bit-identical** to 1-thread output (asserted in tests and the
//! scaling harness).
//!
//! With sparsity support ON, zero entries never enter a sum and all-zero
//! patterns are skipped. OFF, the zero group is summed and multiplied by
//! zero — faithfully modelling a repetition-only system (paper §5.1
//! config 1).

use crate::tensor::{im2col_rows_transposed, Tensor};
use crate::util::{Pool, UnsafeSlice};

pub use crate::tensor::PIXEL_BLOCK;

use super::plan::LayerPlan;

/// Output pixels per parallel work item. A multiple of [`PIXEL_BLOCK`]
/// so block boundaries (and therefore f32 accumulation order) match the
/// pre-tiling executor; small enough that a tile's patch scratch
/// (`tile * C*R*S` floats) stays cache-resident.
pub const DEFAULT_TILE: usize = 32;

/// Execute one conv layer through the repetition engine on the
/// process-wide pool.
pub fn execute_conv2d(plan: &LayerPlan, x: &Tensor) -> Tensor {
    execute_conv2d_pool(plan, x, Pool::global())
}

/// Execute on an explicit pool (benchmarks pin 1-thread vs N-thread).
pub fn execute_conv2d_pool(plan: &LayerPlan, x: &Tensor, pool: &Pool) -> Tensor {
    execute_conv2d_tiled(plan, x, pool, DEFAULT_TILE)
}

/// Fully-parameterized entry point: `tile` output pixels per work item.
pub fn execute_conv2d_tiled(
    plan: &LayerPlan,
    x: &Tensor,
    pool: &Pool,
    tile: usize,
) -> Tensor {
    assert!(tile > 0, "tile size must be positive");
    let g = plan.geom;
    assert_eq!(x.shape(), &[g.n, g.c, g.h, g.w], "input does not match plan geometry");
    let e = g.c * g.r * g.s;
    let (oh, ow) = (g.out_h(), g.out_w());
    let pixels = g.n * oh * ow;
    let plane = oh * ow;
    let nu = plan.num_unique_filters;
    let np = plan.arena.num_patterns();
    let nt = plan.num_tables;
    const PB: usize = PIXEL_BLOCK;

    let mut out = Tensor::zeros(&[g.n, g.k, oh, ow]);
    if pixels == 0 {
        return out;
    }
    let od = UnsafeSlice::new(out.data_mut());
    let jobs = pixels.div_ceil(tile);
    let blocks_per_tile = tile.div_ceil(PB);

    struct Scratch {
        patch: Vec<f32>,
        psums: Vec<f32>,
        usums: Vec<f32>,
    }
    let cols = &plan.arena.cols;
    let spans = &plan.arena.spans;

    pool.run_with(
        jobs,
        || Scratch {
            patch: vec![0.0; blocks_per_tile * e * PB],
            psums: vec![0.0; np * PB],
            usums: vec![0.0; nu * PB],
        },
        |scr, job| {
            let px0 = job * tile;
            let tp = tile.min(pixels - px0);
            // 0. fused transposed im2col: only this tile's patch rows,
            // pixel-major ([e][PB] blocks, ragged lanes zeroed)
            im2col_rows_transposed(x, g.r, g.s, g.stride, g.padding, px0, tp, &mut scr.patch);

            for blk in 0..tp.div_ceil(PB) {
                let b0 = blk * PB;
                let pb = PB.min(tp - b0);
                let bpatch = &scr.patch[blk * e * PB..(blk + 1) * e * PB];

                // 1. distinct-pattern partial sums — one streaming pass
                // over the CSR arena; each column gather is a contiguous
                // PB-wide load + add (ragged lanes are zero-padded, so
                // full-width ops are safe and deterministic)
                for (gp, sp) in spans.iter().enumerate() {
                    let acc: &mut [f32; PB] =
                        (&mut scr.psums[gp * PB..gp * PB + PB]).try_into().unwrap();
                    *acc = [0.0; PB];
                    let s = sp.start as usize;
                    let p_end = s + sp.pos as usize;
                    let n_end = p_end + sp.neg as usize;
                    for &col in &cols[s..p_end] {
                        let src: &[f32; PB] = bpatch[col as usize * PB..col as usize * PB + PB]
                            .try_into()
                            .unwrap();
                        for b in 0..PB {
                            acc[b] += src[b];
                        }
                    }
                    for &col in &cols[p_end..n_end] {
                        let src: &[f32; PB] = bpatch[col as usize * PB..col as usize * PB + PB]
                            .try_into()
                            .unwrap();
                        for b in 0..PB {
                            acc[b] -= src[b];
                        }
                    }
                    if !plan.cfg.sparsity_support {
                        // repetition-only mode: the zero group is summed
                        // like any other repeated value, then multiplied
                        // by 0.
                        let z_end = n_end + sp.zero as usize;
                        let mut z = [0.0f32; PB];
                        for &col in &cols[n_end..z_end] {
                            let src: &[f32; PB] = bpatch
                                [col as usize * PB..col as usize * PB + PB]
                                .try_into()
                                .unwrap();
                            for b in 0..PB {
                                z[b] += src[b];
                            }
                        }
                        for b in 0..PB {
                            acc[b] += z[b] * 0.0;
                        }
                    }
                }

                // 2. combine per unique filter (same block layout): each
                // filter's pattern slots are adjacent in the flat table
                for ui in 0..nu {
                    let dst: &mut [f32; PB] =
                        (&mut scr.usums[ui * PB..ui * PB + PB]).try_into().unwrap();
                    *dst = [0.0; PB];
                    for &gp in &plan.combine[ui * nt..(ui + 1) * nt] {
                        let src: &[f32; PB] = scr.psums
                            [gp as usize * PB..gp as usize * PB + PB]
                            .try_into()
                            .unwrap();
                        for b in 0..PB {
                            dst[b] += src[b];
                        }
                    }
                }

                // 3. scatter to original filters with per-filter alpha;
                // this tile's pixels are disjoint from every other tile's
                for (fi, &uslot) in plan.unique_of_filter.iter().enumerate() {
                    let a = plan.alpha[fi];
                    let src = &scr.usums[uslot as usize * PB..uslot as usize * PB + PB];
                    for (b, sv) in src.iter().enumerate().take(pb) {
                        let px = px0 + b0 + b;
                        let ni = px / plane;
                        let pix = px % plane;
                        unsafe { od.write((ni * g.k + fi) * plane + pix, a * sv) };
                    }
                }
            }
        },
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{default_beta, quantize, quantize_signed_binary, Scheme};
    use crate::repetition::{plan_layer, EngineConfig};
    use crate::tensor::{conv2d_gemm, Conv2dGeometry};
    use crate::util::Rng;

    #[test]
    fn strided_conv_matches_dense() {
        let mut rng = Rng::new(30);
        let g = Conv2dGeometry { n: 1, c: 8, h: 8, w: 8, k: 16, r: 3, s: 3, stride: 2, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize_signed_binary(&w, &default_beta(g.k, 0.5), 0.05, 1);
        let dense = conv2d_gemm(&x, &q.values, g.stride, g.padding);
        let out = execute_conv2d(&plan_layer(&q, g, EngineConfig::default()), &x);
        assert!(dense.max_abs_diff(&out) < 1e-3);
    }

    #[test]
    fn one_by_one_conv() {
        let mut rng = Rng::new(31);
        let g = Conv2dGeometry { n: 2, c: 8, h: 5, w: 5, k: 4, r: 1, s: 1, stride: 1, padding: 0 };
        let w = Tensor::rand_normal(&[g.k, g.c, 1, 1], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::Binary, None);
        let dense = conv2d_gemm(&x, &q.values, 1, 0);
        let out = execute_conv2d(&plan_layer(&q, g, EngineConfig::default()), &x);
        assert!(dense.max_abs_diff(&out) < 1e-3);
    }

    #[test]
    fn all_zero_filter_outputs_zero() {
        let g = Conv2dGeometry { n: 1, c: 2, h: 3, w: 3, k: 2, r: 3, s: 3, stride: 1, padding: 1 };
        // filter 0 all below threshold (-> all zero under SB with beta=+1)
        let mut w = Tensor::filled(&[2, 2, 3, 3], -0.001);
        for i in 18..36 {
            w.data_mut()[i] = 0.9; // filter 1 all positive
        }
        let q = quantize_signed_binary(&w, &[1.0, 1.0], 0.05, 1);
        let mut rng = Rng::new(32);
        let x = Tensor::rand_normal(&[1, 2, 3, 3], 1.0, &mut rng);
        let out = execute_conv2d(&plan_layer(&q, g, EngineConfig::default()), &x);
        let plane = 9;
        for i in 0..plane {
            assert_eq!(out.data()[i], 0.0, "filter 0 must be silent");
        }
    }

    #[test]
    fn ragged_pixel_counts_and_tiny_tiles() {
        // 5x5 output = 25 pixels: not a multiple of any default tile, and
        // odd tiles force ragged PIXEL_BLOCK tails inside tiles too
        let mut rng = Rng::new(33);
        let g = Conv2dGeometry { n: 1, c: 4, h: 5, w: 5, k: 6, r: 3, s: 3, stride: 1, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let plan = plan_layer(&q, g, EngineConfig::default());
        let dense = conv2d_gemm(&x, &q.values, g.stride, g.padding);
        let pool = Pool::new(2);
        for tile in [1, 3, 7, 25, 100] {
            let out = execute_conv2d_tiled(&plan, &x, &pool, tile);
            assert!(dense.max_abs_diff(&out) < 1e-3, "tile {tile}");
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let mut rng = Rng::new(34);
        let g = Conv2dGeometry { n: 2, c: 8, h: 9, w: 9, k: 12, r: 3, s: 3, stride: 2, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let plan = plan_layer(&q, g, EngineConfig::default());
        let base = execute_conv2d_pool(&plan, &x, &Pool::new(1));
        for threads in [2, 3, 8] {
            let out = execute_conv2d_pool(&plan, &x, &Pool::new(threads));
            assert!(
                out.data() == base.data(),
                "{threads}-thread output differs from 1-thread"
            );
        }
    }
}
