//! Timed executor for a `LayerPlan`.
//!
//! Per output pixel (one im2col patch row):
//!   1. for every sub-tile, evaluate each *distinct* pattern's partial sum
//!      once into an arena (this is where repetition pays: the sum is
//!      shared by all filters using the pattern);
//!   2. for every *unique* filter, combine its per-sub-tile partial sums
//!      and multiply by alpha once;
//!   3. scatter unique-filter results to the original filter slots
//!      (inter-filter dedup).
//!
//! With sparsity support ON, zero entries never enter a sum and all-zero
//! patterns are skipped. OFF, the zero group is summed and multiplied by
//! zero — faithfully modelling a repetition-only system (paper §5.1
//! config 1).

use crate::tensor::{im2col, Tensor};

use super::plan::LayerPlan;

/// Output pixels processed together. Amortizes the plan walk (pattern
/// index loads, slot lookups) across a block and lets the inner
/// accumulations vectorize — the §Perf pixel-blocking optimization
/// (EXPERIMENTS.md §Perf records the before/after).
pub const PIXEL_BLOCK: usize = 8;

/// Execute one conv layer through the repetition engine.
pub fn execute_conv2d(plan: &LayerPlan, x: &Tensor) -> Tensor {
    let g = plan.geom;
    assert_eq!(x.shape(), &[g.n, g.c, g.h, g.w], "input does not match plan geometry");
    let patches = im2col(x, g.r, g.s, g.stride, g.padding);
    let e = g.c * g.r * g.s;
    let (oh, ow) = (g.out_h(), g.out_w());
    let pixels = g.n * oh * ow;
    let nu = plan.num_unique_filters;

    // arena: partial sums of distinct patterns x pixel block
    let slots: Vec<usize> = plan
        .tables
        .iter()
        .scan(0usize, |acc, t| {
            let base = *acc;
            *acc += t.patterns.len();
            Some(base)
        })
        .collect();
    let total_patterns: usize = plan.tables.iter().map(|t| t.patterns.len()).sum();
    const PB: usize = PIXEL_BLOCK;
    let mut psums = vec![0.0f32; total_patterns * PB];
    let mut usums = vec![0.0f32; nu * PB];

    let mut out = Tensor::zeros(&[g.n, g.k, oh, ow]);
    let od = out.data_mut();
    let plane = oh * ow;
    let pdata = patches.data();

    let mut px0 = 0usize;
    while px0 < pixels {
        let pb = PB.min(pixels - px0);

        // 1. distinct-pattern partial sums, blocked over pixels
        for (ti, t) in plan.tables.iter().enumerate() {
            let base = slots[ti] * PB;
            let tb = t.base;
            for (pi, p) in t.patterns.iter().enumerate() {
                let acc = &mut psums[base + pi * PB..base + pi * PB + PB];
                acc.fill(0.0);
                for &off in &p.pos {
                    let col = tb + off as usize;
                    for (b, a) in acc.iter_mut().enumerate().take(pb) {
                        *a += pdata[(px0 + b) * e + col];
                    }
                }
                for &off in &p.neg {
                    let col = tb + off as usize;
                    for (b, a) in acc.iter_mut().enumerate().take(pb) {
                        *a -= pdata[(px0 + b) * e + col];
                    }
                }
                if !plan.cfg.sparsity_support {
                    // repetition-only mode: the zero group is summed like
                    // any other repeated value, then multiplied by 0.
                    let mut z = [0.0f32; PB];
                    for &off in &p.zero {
                        let col = tb + off as usize;
                        for (b, zz) in z.iter_mut().enumerate().take(pb) {
                            *zz += pdata[(px0 + b) * e + col];
                        }
                    }
                    for (a, zz) in acc.iter_mut().zip(z.iter()) {
                        *a += zz * 0.0;
                    }
                }
            }
        }

        // 2. combine per unique filter (blocked)
        usums[..nu * PB].fill(0.0);
        for (ti, t) in plan.tables.iter().enumerate() {
            let base = slots[ti] * PB;
            for (ui, &slot) in t.slot_of_filter.iter().enumerate() {
                let src = &psums[base + slot as usize * PB..base + slot as usize * PB + PB];
                let dst = &mut usums[ui * PB..ui * PB + PB];
                for (d, s) in dst.iter_mut().zip(src) {
                    *d += s;
                }
            }
        }

        // 3. scatter to original filters with per-filter alpha
        for (fi, &uslot) in plan.unique_of_filter.iter().enumerate() {
            let a = plan.alpha[fi];
            let src = &usums[uslot as usize * PB..uslot as usize * PB + PB];
            for b in 0..pb {
                let px = px0 + b;
                let ni = px / plane;
                let pix = px % plane;
                od[(ni * g.k + fi) * plane + pix] = a * src[b];
            }
        }

        px0 += pb;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{default_beta, quantize, quantize_signed_binary, Scheme};
    use crate::repetition::{plan_layer, EngineConfig};
    use crate::tensor::{conv2d_gemm, Conv2dGeometry};
    use crate::util::Rng;

    #[test]
    fn strided_conv_matches_dense() {
        let mut rng = Rng::new(30);
        let g = Conv2dGeometry { n: 1, c: 8, h: 8, w: 8, k: 16, r: 3, s: 3, stride: 2, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize_signed_binary(&w, &default_beta(g.k, 0.5), 0.05, 1);
        let dense = conv2d_gemm(&x, &q.values, g.stride, g.padding);
        let out = execute_conv2d(&plan_layer(&q, g, EngineConfig::default()), &x);
        assert!(dense.max_abs_diff(&out) < 1e-3);
    }

    #[test]
    fn one_by_one_conv() {
        let mut rng = Rng::new(31);
        let g = Conv2dGeometry { n: 2, c: 8, h: 5, w: 5, k: 4, r: 1, s: 1, stride: 1, padding: 0 };
        let w = Tensor::rand_normal(&[g.k, g.c, 1, 1], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::Binary, None);
        let dense = conv2d_gemm(&x, &q.values, 1, 0);
        let out = execute_conv2d(&plan_layer(&q, g, EngineConfig::default()), &x);
        assert!(dense.max_abs_diff(&out) < 1e-3);
    }

    #[test]
    fn all_zero_filter_outputs_zero() {
        let g = Conv2dGeometry { n: 1, c: 2, h: 3, w: 3, k: 2, r: 3, s: 3, stride: 1, padding: 1 };
        // filter 0 all below threshold (-> all zero under SB with beta=+1)
        let mut w = Tensor::filled(&[2, 2, 3, 3], -0.001);
        for i in 18..36 {
            w.data_mut()[i] = 0.9; // filter 1 all positive
        }
        let q = quantize_signed_binary(&w, &[1.0, 1.0], 0.05, 1);
        let mut rng = Rng::new(32);
        let x = Tensor::rand_normal(&[1, 2, 3, 3], 1.0, &mut rng);
        let out = execute_conv2d(&plan_layer(&q, g, EngineConfig::default()), &x);
        let plane = 9;
        for i in 0..plane {
            assert_eq!(out.data()[i], 0.0, "filter 0 must be silent");
        }
    }
}
