//! Tiled, parallel, **pixel-major** executor for a `LayerPlan`.
//!
//! The output-pixel axis is cut into fixed tiles ([`DEFAULT_TILE`]
//! pixels); tiles are distributed over the persistent worker pool
//! (`util::pool`). Per tile, one worker:
//!
//!   1. **fuses im2col, transposed**: builds just the tile's patch rows
//!      into its own scratch buffer via `im2col_rows_transposed_into` —
//!      as `[C*R*S, PIXEL_BLOCK]` blocks with pixels minor, so the full
//!      `[N*OH*OW, C*R*S]` patch matrix is never materialized *and*
//!      every later column access is a contiguous SIMD-width run;
//!   2. walks the plan's CSR index arena once per pixel block: every
//!      *distinct* pattern's partial sum is evaluated once into a
//!      thread-local psum arena (this is where repetition pays — the sum
//!      is shared by all filters using the pattern). A pattern column's
//!      gather is now a contiguous `PIXEL_BLOCK`-wide f32 load + add
//!      (`[f32; PIXEL_BLOCK]` array windows, which LLVM lowers to one
//!      AVX2 vector op), where the row-major layout forced a
//!      stride-`C*R*S` walk that defeated vectorization exactly where
//!      repetition pays;
//!   3. combines per *unique* filter through the flat `combine` table on
//!      the same block layout and multiplies by alpha once;
//!   4. scatters unique-filter results to the original filter slots
//!      (inter-filter dedup) — each tile owns a disjoint set of output
//!      pixels, so workers write without synchronization. The optional
//!      [`PostOp`] epilogue (residual add, ReLU — the network executor's
//!      inter-layer fusion) is applied elementwise right here, so a
//!      fused multi-layer forward never makes a second pass over the
//!      activations.
//!
//! Tile and block partitioning depend only on the tile size, never on
//! the thread count, each worker owns its psum/usum/patch arenas, and
//! ragged final blocks are zero-padded to full SIMD width, so per-lane
//! f32 accumulation order is fixed and N-thread output is
//! **bit-identical** to 1-thread output (asserted in tests and the
//! scaling harness). Worker scratch is drawn from the thread-local
//! [`ScratchVec`] cache: the pool's workers are persistent, so a
//! steady-state serving loop performs no per-layer heap allocation.
//!
//! **Cross-layer patch reuse** ([`TileIo`], [`execute_conv2d_layout`]):
//! when the network plan marks an edge as fusable, the *producer*
//! scatters its fused PostOp output straight into
//! `[ceil(pixels/PB)][K][PB]` block layout (`output_blocked`) and the
//! *consumer* reads those blocks instead of NCHW (`input_blocked`). A
//! 1x1 / stride-1 / pad-0 consumer's patch matrix IS that layout, so it
//! skips the transform entirely and reads blocks in place; a 3x3 or
//! strided consumer gathers its patch blocks directly out of the
//! producer's block layout (`im2col_rows_transposed_from_blocked_into`
//! — neighborhoods, subsampling, zero-padded borders), so the NCHW
//! round-trip disappears for every engine-to-engine edge. The values
//! and their accumulation order are unchanged, so fused output stays
//! bit-identical to the unfused path.
//!
//! Sparsity support is a **plan-time property**: with support ON the
//! plan's arena is elided — zero columns were never materialized and
//! all-zero patterns share one no-op span — so step 1 walks pos/neg
//! runs only and there is no zero branch anywhere in the hot loop. OFF,
//! the plan materializes zero runs and a separate whole-loop variant
//! sums each zero group and multiplies it by zero — faithfully
//! modelling a repetition-only system (paper §5.1 config 1).

use crate::tensor::{
    im2col_rows_transposed_from_blocked_into, im2col_rows_transposed_into, Conv2dGeometry, Tensor,
};
use crate::util::{Pool, ScratchVec, UnsafeSlice};

pub use crate::tensor::PIXEL_BLOCK;

use super::plan::LayerPlan;

/// Output pixels per parallel work item. A multiple of [`PIXEL_BLOCK`]
/// so block boundaries (and therefore f32 accumulation order) match the
/// pre-tiling executor; small enough that a tile's patch scratch
/// (`tile * C*R*S` floats) stays cache-resident.
pub const DEFAULT_TILE: usize = 32;

/// The option-A subsampling stride that maps a source plane of `src`
/// rows onto `out` output rows: the smallest `st` with
/// `(src - 1) / st + 1 == out`, i.e. the stride of the conv whose
/// output the shortcut accompanies. Unlike a plain `src / out` ratio
/// this is exact on **odd** sizes too (`src = 7, out = 4 -> 2`: the
/// subsample reads rows 0/2/4/6 and row 7 simply does not exist).
/// Callers must still verify the formula holds for their shapes —
/// `PostOp::validate` and the network compiler's wiring checks do.
pub fn option_a_stride(src: usize, out: usize) -> usize {
    src.saturating_sub(1) / out.max(1) + 1
}

/// An option-A residual shortcut fused into the output scatter: before
/// the epilogue's ReLU, channel `fi < c` of each output pixel gains the
/// spatially-subsampled source value. Channels `>= c` are zero-padded
/// (He et al. option A — no projection weights).
#[derive(Debug, Clone, Copy)]
pub struct Residual<'a> {
    /// source activation, NCHW `[n, c, h, w]`
    pub src: &'a [f32],
    /// source channels (`<=` the output's K; extra channels zero-pad)
    pub c: usize,
    /// source height
    pub h: usize,
    /// source width
    pub w: usize,
    /// spatial subsampling factor (1 for identity): the consumer reads
    /// source row `oy * stride`, so `out_h == (h - 1) / stride + 1`
    /// must hold — see [`option_a_stride`]. On odd sizes the source is
    /// *covered*, not exactly divided (h = 7, stride = 2 -> out_h = 4).
    pub stride: usize,
}

/// Elementwise epilogue fused into the executor's scatter stage —
/// applied per output element in `residual → ReLU` order, matching a
/// separate-pass reference bit for bit.
#[derive(Debug, Clone, Copy, Default)]
pub struct PostOp<'a> {
    /// clamp each output element at zero (after the residual add)
    pub relu: bool,
    /// optional shortcut source added before the ReLU
    pub residual: Option<Residual<'a>>,
}

impl PostOp<'_> {
    /// Assert the epilogue is consistent with an `[n, k, oh, ow]`
    /// output — shared by every kernel that fuses this epilogue.
    pub(crate) fn validate(&self, n: usize, k: usize, oh: usize, ow: usize) {
        if let Some(res) = &self.residual {
            assert!(res.stride >= 1, "residual stride must be positive");
            assert_eq!(res.src.len(), n * res.c * res.h * res.w, "residual buffer mismatch");
            // `apply` reads source row `oy * stride` for `oy < oh`, so
            // the source must cover exactly that index range: `oh ==
            // (h - 1) / stride + 1`. Requiring `h == oh * stride`
            // instead would reject legitimate odd-size shortcuts
            // (h = 7, stride = 2 -> oh = 4 reads at most row 6).
            assert_eq!(oh, (res.h - 1) / res.stride + 1, "residual height / stride mismatch");
            assert_eq!(ow, (res.w - 1) / res.stride + 1, "residual width / stride mismatch");
            assert!(res.c <= k, "residual has more channels than the output");
        }
    }

    /// Apply to one output element (channel `fi` of pixel `pix` within
    /// sample `ni`, output width `ow`): residual add, then ReLU — one
    /// definition of the option-A index math for every kernel.
    #[inline]
    pub(crate) fn apply(&self, v: f32, ni: usize, fi: usize, pix: usize, ow: usize) -> f32 {
        let mut v = v;
        if let Some(res) = &self.residual {
            if fi < res.c {
                let oy = pix / ow;
                let ox = pix % ow;
                v += res.src
                    [((ni * res.c + fi) * res.h + oy * res.stride) * res.w + ox * res.stride];
            }
        }
        if self.relu {
            v = v.max(0.0);
        }
        v
    }
}

/// Per-worker scratch, drawn from (and returned to) the thread-local
/// [`ScratchVec`] cache. Every element read is written first within the
/// same tile, so recycled stale contents are harmless.
struct Scratch {
    patch: ScratchVec,
    psums: ScratchVec,
    usums: ScratchVec,
}

/// I/O layout of one [`execute_conv2d_layout`] call — the network
/// executor's cross-layer patch-reuse contract.
///
/// The pixel-major block layout is the one `im2col_rows_transposed`
/// produces over the *whole* pixel range starting at pixel 0:
/// `buf[(px / PB) * C * PB + c * PB + px % PB]`, with lanes past the
/// final pixel zero-filled. Any engine layer can consume it: a 1x1 /
/// stride-1 / pad-0 layer reads the blocks **in place** (they *are* its
/// patch matrix), every other geometry gathers its patch blocks out of
/// them per tile (`im2col_rows_transposed_from_blocked_into` — r/s > 1
/// neighborhoods, strided subsampling and zero-padded borders), still
/// skipping the NCHW round-trip. Both directions require the tile size
/// to be a multiple of [`PIXEL_BLOCK`] so every tile starts on a block
/// boundary ([`DEFAULT_TILE`] is; see [`validate_blocked_tile`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TileIo {
    /// the input buffer already holds `[ceil(pixels/PB)][C][PB]`
    /// pixel-major blocks over the layer's *input* pixels (a fused
    /// producer wrote them) instead of NCHW
    pub input_blocked: bool,
    /// scatter the output as `[ceil(pixels/PB)][K][PB]` pixel-major
    /// blocks — the next layer's patch source — instead of NCHW; lanes
    /// past the final pixel are written as zero, mirroring im2col's
    /// ragged-block padding
    pub output_blocked: bool,
}

/// True when `tile` can carry blocked patch I/O: positive and
/// [`PIXEL_BLOCK`]-aligned, so every tile starts on a block boundary.
pub fn tile_supports_blocked_io(tile: usize) -> bool {
    tile > 0 && tile % PIXEL_BLOCK == 0
}

/// The documented early check for custom execution tiles: blocked patch
/// I/O ([`TileIo`]) requires every tile to start on a [`PIXEL_BLOCK`]
/// boundary. Callers that pick their own tile (auto-tuners,
/// `NetworkExecutor::with_tile`) should check
/// [`tile_supports_blocked_io`] — or call this — *before* dispatching
/// work, rather than hitting the same assert mid-execution.
pub fn validate_blocked_tile(tile: usize, direction: &str) {
    assert!(
        tile_supports_blocked_io(tile),
        "blocked {direction} requires a PIXEL_BLOCK-aligned tile: {tile} is not a multiple of \
         {PIXEL_BLOCK} — pick a multiple (e.g. DEFAULT_TILE = {DEFAULT_TILE}) or run with \
         patch fusion disabled"
    );
}

/// Execute one conv layer through the repetition engine on the
/// process-wide pool.
pub fn execute_conv2d(plan: &LayerPlan, x: &Tensor) -> Tensor {
    execute_conv2d_pool(plan, x, Pool::global())
}

/// Execute on an explicit pool (benchmarks pin 1-thread vs N-thread).
pub fn execute_conv2d_pool(plan: &LayerPlan, x: &Tensor, pool: &Pool) -> Tensor {
    execute_conv2d_tiled(plan, x, pool, DEFAULT_TILE)
}

/// Tensor-in/Tensor-out entry point: `tile` output pixels per work item.
pub fn execute_conv2d_tiled(plan: &LayerPlan, x: &Tensor, pool: &Pool, tile: usize) -> Tensor {
    let g = plan.geom;
    assert_eq!(x.shape(), &[g.n, g.c, g.h, g.w], "input does not match plan geometry");
    let mut out = Tensor::zeros(&[g.n, g.k, g.out_h(), g.out_w()]);
    execute_conv2d_into(plan, x.data(), out.data_mut(), pool, tile, PostOp::default());
    out
}

/// Slice core: run the plan over an NCHW activation buffer and write
/// every output element of `out` (callers may hand in a recycled arena
/// slice — no zeroing required). `post` fuses the elementwise epilogue
/// into the scatter. This is the network executor's per-layer kernel:
/// activations stay in the caller's ping-pong arena and no `Tensor` is
/// allocated per layer.
pub fn execute_conv2d_into(
    plan: &LayerPlan,
    x: &[f32],
    out: &mut [f32],
    pool: &Pool,
    tile: usize,
    post: PostOp<'_>,
) {
    execute_conv2d_layout(plan, x, out, pool, tile, post, TileIo::default());
}

/// [`execute_conv2d_into`] with explicit I/O layouts ([`TileIo`]) — the
/// cross-layer patch-reuse entry point. With `io.input_blocked` the
/// NCHW `im2col_rows_transposed` pass (step 0) is replaced: a 1x1 /
/// stride-1 / pad-0 layer reads the producer's blocks **in place**
/// (zero transform work), any other geometry gathers its patch blocks
/// straight out of the blocked input per tile (no NCHW round-trip).
/// With `io.output_blocked` step 3 scatters pixel-major blocks (the
/// next layer's patch source) instead of NCHW. Either direction changes
/// neither the values nor their accumulation order, so the output is
/// bit-identical to the unfused layout at every pool width.
pub fn execute_conv2d_layout(
    plan: &LayerPlan,
    x: &[f32],
    out: &mut [f32],
    pool: &Pool,
    tile: usize,
    post: PostOp<'_>,
    io: TileIo,
) {
    execute_conv2d_layout_batch(plan, plan.geom.n, x, out, pool, tile, post, io);
}

/// [`execute_conv2d_layout`] over an explicit runtime batch of `batch`
/// images. A `LayerPlan` depends only on the quantized weights and the
/// per-layer geometry *shape* — never on `geom.n` — so one plan serves
/// any batch size: the pixel axis simply grows to `batch * oh * ow`
/// batch-major pixels (global pixel `px = (ni * oh + oy) * ow + ox`)
/// and everything downstream — tiling, `PIXEL_BLOCK` gathers, blocked
/// patch I/O, the `PostOp` epilogue's per-image residual indexing —
/// already walks that global pixel axis. Ragged final blocks (including
/// blocks straddling an image boundary) zero-pad exactly like the
/// single-image path, and per-lane f32 accumulation order is unchanged,
/// so a batched forward is bit-identical to `batch` independent
/// single-image forwards at every pool width.
#[allow(clippy::too_many_arguments)]
pub fn execute_conv2d_layout_batch(
    plan: &LayerPlan,
    batch: usize,
    x: &[f32],
    out: &mut [f32],
    pool: &Pool,
    tile: usize,
    post: PostOp<'_>,
    io: TileIo,
) {
    assert!(tile > 0, "tile size must be positive");
    assert!(batch > 0, "runtime batch must be positive");
    let g = Conv2dGeometry { n: batch, ..plan.geom };
    let e = g.c * g.r * g.s;
    let (oh, ow) = (g.out_h(), g.out_w());
    let pixels = g.n * oh * ow;
    let plane = oh * ow;
    const PB: usize = PIXEL_BLOCK;
    let total_blocks = pixels.div_ceil(PB);
    // a 1x1/s1/p0 consumer's patch matrix IS the blocked input (same
    // pixels, e == c), so its tiles read the producer's blocks in place;
    // every other geometry gathers per tile from the blocked layout
    let direct_input =
        io.input_blocked && g.r == 1 && g.s == 1 && g.stride == 1 && g.padding == 0;
    if io.input_blocked {
        validate_blocked_tile(tile, "input");
        let in_pixels = g.n * g.h * g.w;
        assert_eq!(
            x.len(),
            in_pixels.div_ceil(PB) * g.c * PB,
            "blocked input does not match plan geometry"
        );
    } else {
        assert_eq!(x.len(), g.n * g.c * g.h * g.w, "input does not match plan geometry");
    }
    if io.output_blocked {
        validate_blocked_tile(tile, "output");
        assert_eq!(
            out.len(),
            total_blocks * g.k * PB,
            "blocked output buffer does not match plan geometry"
        );
    } else {
        assert_eq!(out.len(), g.n * g.k * plane, "output buffer does not match plan geometry");
    }
    post.validate(g.n, g.k, oh, ow);
    let nu = plan.num_unique_filters;
    let np = plan.arena.num_patterns();
    let nt = plan.num_tables;

    if pixels == 0 {
        return;
    }
    let od = UnsafeSlice::new(out);
    let jobs = pixels.div_ceil(tile);
    let blocks_per_tile = tile.div_ceil(PB);

    let cols = &plan.arena.cols;
    let spans = &plan.arena.spans;

    pool.run_with(
        jobs,
        || Scratch {
            // direct blocked input: the patch matrix already exists in
            // `x`, no per-tile transform scratch is needed
            patch: ScratchVec::take(if direct_input { 0 } else { blocks_per_tile * e * PB }),
            psums: ScratchVec::take(np * PB),
            usums: ScratchVec::take(nu * PB),
        },
        |scr, job| {
            let px0 = job * tile;
            let tp = tile.min(pixels - px0);
            // 0. fused transposed im2col: only this tile's patch rows,
            // pixel-major ([e][PB] blocks, ragged lanes zeroed). NCHW
            // input transforms as before; blocked input either skips
            // this entirely (1x1/s1/p0: the blocks ARE the patches) or
            // gathers the patch blocks straight out of the producer's
            // block layout — same values, same accumulation order.
            if !io.input_blocked {
                im2col_rows_transposed_into(x, &g, px0, tp, &mut scr.patch);
            } else if !direct_input {
                im2col_rows_transposed_from_blocked_into(x, &g, px0, tp, &mut scr.patch);
            }

            for blk in 0..tp.div_ceil(PB) {
                let b0 = blk * PB;
                let pb = PB.min(tp - b0);
                let bpatch: &[f32] = if direct_input {
                    // tiles are PB-aligned, so this tile's blocks sit at
                    // global block indices px0/PB + blk
                    let gb = px0 / PB + blk;
                    &x[gb * e * PB..(gb + 1) * e * PB]
                } else {
                    &scr.patch[blk * e * PB..(blk + 1) * e * PB]
                };

                // 1. distinct-pattern partial sums — one streaming pass
                // over the CSR arena; each column gather is a contiguous
                // PB-wide load + add (ragged lanes are zero-padded, so
                // full-width ops are safe and deterministic). Sparsity
                // support is a plan-time property, so the zero handling
                // is a whole-loop variant, never a per-pattern branch:
                // with support the elided arena holds only pos/neg runs
                // (zero columns do not exist); without it the
                // repetition-only arm sums each materialized zero group
                // and multiplies by 0.
                if plan.cfg.sparsity_support {
                    for (gp, sp) in spans.iter().enumerate() {
                        let acc: &mut [f32; PB] =
                            (&mut scr.psums[gp * PB..gp * PB + PB]).try_into().unwrap();
                        *acc = [0.0; PB];
                        let s = sp.start as usize;
                        let p_end = s + sp.pos as usize;
                        let n_end = p_end + sp.neg as usize;
                        for &col in &cols[s..p_end] {
                            let src: &[f32; PB] = bpatch
                                [col as usize * PB..col as usize * PB + PB]
                                .try_into()
                                .unwrap();
                            for b in 0..PB {
                                acc[b] += src[b];
                            }
                        }
                        for &col in &cols[p_end..n_end] {
                            let src: &[f32; PB] = bpatch
                                [col as usize * PB..col as usize * PB + PB]
                                .try_into()
                                .unwrap();
                            for b in 0..PB {
                                acc[b] -= src[b];
                            }
                        }
                    }
                } else {
                    for (gp, sp) in spans.iter().enumerate() {
                        let acc: &mut [f32; PB] =
                            (&mut scr.psums[gp * PB..gp * PB + PB]).try_into().unwrap();
                        *acc = [0.0; PB];
                        let s = sp.start as usize;
                        let p_end = s + sp.pos as usize;
                        let n_end = p_end + sp.neg as usize;
                        for &col in &cols[s..p_end] {
                            let src: &[f32; PB] = bpatch
                                [col as usize * PB..col as usize * PB + PB]
                                .try_into()
                                .unwrap();
                            for b in 0..PB {
                                acc[b] += src[b];
                            }
                        }
                        for &col in &cols[p_end..n_end] {
                            let src: &[f32; PB] = bpatch
                                [col as usize * PB..col as usize * PB + PB]
                                .try_into()
                                .unwrap();
                            for b in 0..PB {
                                acc[b] -= src[b];
                            }
                        }
                        // repetition-only mode: the zero group is summed
                        // like any other repeated value, then multiplied
                        // by 0.
                        let z_end = n_end + sp.zero as usize;
                        let mut z = [0.0f32; PB];
                        for &col in &cols[n_end..z_end] {
                            let src: &[f32; PB] = bpatch
                                [col as usize * PB..col as usize * PB + PB]
                                .try_into()
                                .unwrap();
                            for b in 0..PB {
                                z[b] += src[b];
                            }
                        }
                        for b in 0..PB {
                            acc[b] += z[b] * 0.0;
                        }
                    }
                }

                // 2. combine per unique filter (same block layout): each
                // filter's pattern slots are adjacent in the flat table
                for ui in 0..nu {
                    let dst: &mut [f32; PB] =
                        (&mut scr.usums[ui * PB..ui * PB + PB]).try_into().unwrap();
                    *dst = [0.0; PB];
                    for &gp in &plan.combine[ui * nt..(ui + 1) * nt] {
                        let src: &[f32; PB] = scr.psums
                            [gp as usize * PB..gp as usize * PB + PB]
                            .try_into()
                            .unwrap();
                        for b in 0..PB {
                            dst[b] += src[b];
                        }
                    }
                }

                // 3. scatter to original filters with per-filter alpha and
                // the fused epilogue (residual, then ReLU — elementwise,
                // so thread count still cannot change bits); this tile's
                // pixels are disjoint from every other tile's. Blocked
                // output lands pixel-major (the next layer's patch
                // blocks), with the ragged tail zeroed like im2col's.
                for (fi, &uslot) in plan.unique_of_filter.iter().enumerate() {
                    let a = plan.alpha[fi];
                    let src = &scr.usums[uslot as usize * PB..uslot as usize * PB + PB];
                    if io.output_blocked {
                        let obase = ((px0 / PB + blk) * g.k + fi) * PB;
                        for (b, sv) in src.iter().enumerate() {
                            let v = if b < pb {
                                let px = px0 + b0 + b;
                                let ni = px / plane;
                                let pix = px % plane;
                                post.apply(a * sv, ni, fi, pix, ow)
                            } else {
                                0.0
                            };
                            // SAFETY: the tile is a PIXEL_BLOCK multiple
                            // (validate_blocked_tile above), so this job
                            // owns blocks [px0/PB, px0/PB + ceil(tp/PB))
                            // outright and obase + b < total_blocks*K*PB
                            // == out.len(). Disjointness and bounds are
                            // proven per layer schedule by the blocked
                            // write-interval check in
                            // analysis::audit_network_plan (WriteOverlap /
                            // WriteOutOfBounds / MisalignedBlockedTile).
                            unsafe { od.write(obase + b, v) };
                        }
                    } else {
                        for (b, sv) in src.iter().enumerate().take(pb) {
                            let px = px0 + b0 + b;
                            let ni = px / plane;
                            let pix = px % plane;
                            let v = post.apply(a * sv, ni, fi, pix, ow);
                            // SAFETY: this job owns output pixels
                            // [px0, px0+tp), so (ni*K + fi)*plane + pix is
                            // written by no other job and stays
                            // < n*K*plane == out.len(). Proven statically
                            // per layer schedule by the NCHW
                            // write-interval check in
                            // analysis::audit_network_plan.
                            unsafe { od.write((ni * g.k + fi) * plane + pix, v) };
                        }
                    }
                }
            }
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{default_beta, quantize, quantize_signed_binary, Scheme};
    use crate::repetition::{plan_layer, EngineConfig, LayerPlan};
    use crate::tensor::{conv2d_gemm, Conv2dGeometry};
    use crate::util::Rng;

    // Miri (the CI `miri` job) interprets every instruction, so the
    // sweep dimensions — pool widths, tile probes — shrink under
    // `cfg(miri)` while the assertions stay identical. Pattern: pick
    // the probe list through one of these helpers instead of inlining
    // a literal array.
    fn probe_widths() -> &'static [usize] {
        if cfg!(miri) {
            &[1, 2]
        } else {
            &[1, 2, 4]
        }
    }

    #[test]
    fn strided_conv_matches_dense() {
        let mut rng = Rng::new(30);
        let g = Conv2dGeometry { n: 1, c: 8, h: 8, w: 8, k: 16, r: 3, s: 3, stride: 2, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize_signed_binary(&w, &default_beta(g.k, 0.5), 0.05, 1);
        let dense = conv2d_gemm(&x, &q.values, g.stride, g.padding);
        let out = execute_conv2d(&plan_layer(&q, g, EngineConfig::default()), &x);
        assert!(dense.max_abs_diff(&out) < 1e-3);
    }

    #[test]
    fn one_by_one_conv() {
        let mut rng = Rng::new(31);
        let g = Conv2dGeometry { n: 2, c: 8, h: 5, w: 5, k: 4, r: 1, s: 1, stride: 1, padding: 0 };
        let w = Tensor::rand_normal(&[g.k, g.c, 1, 1], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::Binary, None);
        let dense = conv2d_gemm(&x, &q.values, 1, 0);
        let out = execute_conv2d(&plan_layer(&q, g, EngineConfig::default()), &x);
        assert!(dense.max_abs_diff(&out) < 1e-3);
    }

    #[test]
    fn all_zero_filter_outputs_zero() {
        let g = Conv2dGeometry { n: 1, c: 2, h: 3, w: 3, k: 2, r: 3, s: 3, stride: 1, padding: 1 };
        // filter 0 all below threshold (-> all zero under SB with beta=+1)
        let mut w = Tensor::filled(&[2, 2, 3, 3], -0.001);
        for i in 18..36 {
            w.data_mut()[i] = 0.9; // filter 1 all positive
        }
        let q = quantize_signed_binary(&w, &[1.0, 1.0], 0.05, 1);
        let mut rng = Rng::new(32);
        let x = Tensor::rand_normal(&[1, 2, 3, 3], 1.0, &mut rng);
        let out = execute_conv2d(&plan_layer(&q, g, EngineConfig::default()), &x);
        let plane = 9;
        for i in 0..plane {
            assert_eq!(out.data()[i], 0.0, "filter 0 must be silent");
        }
    }

    #[test]
    fn elided_plan_bits_match_unelided_reference() {
        // plan-time elision must not change a single bit: the unelided
        // reference arena (zero runs materialized, all-zero patterns
        // owning real spans) executes through the same sparsity-on loop
        let mut rng = Rng::new(48);
        let g = Conv2dGeometry { n: 2, c: 8, h: 7, w: 7, k: 12, r: 3, s: 3, stride: 1, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let cfg = EngineConfig { subtile: 8, sparsity_support: true };
        let elided = plan_layer(&q, g, cfg);
        let reference = LayerPlan::build_pool_unelided(&q, g, cfg, &Pool::new(1));
        assert!(elided.arena.cols.len() < reference.arena.cols.len(), "nothing was elided");
        // both builders account the same columns, elided or not
        assert_eq!(elided.stats.total_cols, reference.stats.total_cols);
        assert_eq!(elided.stats.effectual_cols, reference.stats.effectual_cols);
        for &threads in probe_widths() {
            let pool = Pool::new(threads);
            let a = execute_conv2d_pool(&elided, &x, &pool);
            let b = execute_conv2d_pool(&reference, &x, &pool);
            assert!(a.data() == b.data(), "{threads}-thread elided forward differs");
        }
    }

    #[test]
    fn ragged_pixel_counts_and_tiny_tiles() {
        // 5x5 output = 25 pixels: not a multiple of any default tile, and
        // odd tiles force ragged PIXEL_BLOCK tails inside tiles too
        let mut rng = Rng::new(33);
        let g = Conv2dGeometry { n: 1, c: 4, h: 5, w: 5, k: 6, r: 3, s: 3, stride: 1, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let plan = plan_layer(&q, g, EngineConfig::default());
        let dense = conv2d_gemm(&x, &q.values, g.stride, g.padding);
        let pool = Pool::new(2);
        let tiles: &[usize] = if cfg!(miri) { &[3, 25] } else { &[1, 3, 7, 25, 100] };
        for &tile in tiles {
            let out = execute_conv2d_tiled(&plan, &x, &pool, tile);
            assert!(dense.max_abs_diff(&out) < 1e-3, "tile {tile}");
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let mut rng = Rng::new(34);
        let g = Conv2dGeometry { n: 2, c: 8, h: 9, w: 9, k: 12, r: 3, s: 3, stride: 2, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let plan = plan_layer(&q, g, EngineConfig::default());
        let base = execute_conv2d_pool(&plan, &x, &Pool::new(1));
        let widths: &[usize] = if cfg!(miri) { &[2] } else { &[2, 3, 8] };
        for &threads in widths {
            let out = execute_conv2d_pool(&plan, &x, &Pool::new(threads));
            assert!(
                out.data() == base.data(),
                "{threads}-thread output differs from 1-thread"
            );
        }
    }

    #[test]
    fn runtime_batch_override_bits_match_independent_singles() {
        // one plan (geom.n = 1) run at batch 3 must bit-match three
        // independent single-image executions at every pool width — a
        // 3x3 output plane (9 pixels) makes every PIXEL_BLOCK straddle
        // an image boundary and leaves a ragged tail (27 % 8 = 3)
        let mut rng = Rng::new(49);
        let g = Conv2dGeometry { n: 1, c: 4, h: 3, w: 3, k: 6, r: 3, s: 3, stride: 1, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let plan = plan_layer(&q, g, EngineConfig::default());
        let b = 3;
        let xs = Tensor::rand_normal(&[b, g.c, g.h, g.w], 1.0, &mut rng);
        let sample = g.c * g.h * g.w;
        let plane = g.out_h() * g.out_w();
        let mut want = Vec::new();
        for i in 0..b {
            let mut one = vec![f32::NAN; g.k * plane];
            execute_conv2d_into(
                &plan,
                &xs.data()[i * sample..(i + 1) * sample],
                &mut one,
                &Pool::new(1),
                DEFAULT_TILE,
                PostOp::default(),
            );
            want.extend_from_slice(&one);
        }
        for &threads in probe_widths() {
            let pool = Pool::new(threads);
            let mut got = vec![f32::NAN; b * g.k * plane];
            execute_conv2d_layout_batch(
                &plan,
                b,
                xs.data(),
                &mut got,
                &pool,
                DEFAULT_TILE,
                PostOp::default(),
                TileIo::default(),
            );
            assert!(got == want, "{threads}-thread batched execution differs");
        }
    }

    #[test]
    fn into_writes_every_element_over_stale_buffer() {
        let mut rng = Rng::new(35);
        let g = Conv2dGeometry { n: 1, c: 3, h: 6, w: 6, k: 5, r: 3, s: 3, stride: 1, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let plan = plan_layer(&q, g, EngineConfig::default());
        let fresh = execute_conv2d(&plan, &x);
        // recycled arena full of NaN sentinels: every element must be
        // overwritten, never read
        let mut out = vec![f32::NAN; fresh.len()];
        let pool = Pool::new(2);
        execute_conv2d_into(&plan, x.data(), &mut out, &pool, DEFAULT_TILE, PostOp::default());
        assert!(out == fresh.data(), "into-variant differs from allocating variant");
    }

    #[test]
    fn fused_postop_matches_separate_passes() {
        let mut rng = Rng::new(36);
        // stride-2 conv doubling channels — the option-A shortcut case
        let g = Conv2dGeometry { n: 2, c: 4, h: 8, w: 8, k: 8, r: 3, s: 3, stride: 2, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let plan = plan_layer(&q, g, EngineConfig::default());
        let pool = Pool::new(3);
        let (oh, ow) = (g.out_h(), g.out_w());

        // reference: unfused conv, then residual add, then relu
        let mut reference = execute_conv2d_pool(&plan, &x, &pool);
        for ni in 0..g.n {
            for fi in 0..g.c.min(g.k) {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let v = reference.at4(ni, fi, oy, ox)
                            + x.at4(ni, fi, oy * g.stride, ox * g.stride);
                        reference.set4(ni, fi, oy, ox, v);
                    }
                }
            }
        }
        for v in reference.data_mut() {
            *v = v.max(0.0);
        }

        let mut out = vec![f32::NAN; g.n * g.k * oh * ow];
        let post = PostOp {
            relu: true,
            residual: Some(Residual { src: x.data(), c: g.c, h: g.h, w: g.w, stride: g.stride }),
        };
        execute_conv2d_into(&plan, x.data(), &mut out, &pool, DEFAULT_TILE, post);
        assert!(out == reference.data(), "fused epilogue differs from separate passes");
    }

    #[test]
    fn blocked_output_is_the_next_layers_patch_matrix() {
        // a blocked scatter must equal the transposed im2col a 1x1 /
        // stride-1 / pad-0 consumer would run over the NCHW output,
        // including the zeroed ragged tail (25 pixels -> 4 blocks)
        const PB: usize = PIXEL_BLOCK;
        let mut rng = Rng::new(37);
        let g = Conv2dGeometry { n: 1, c: 4, h: 5, w: 5, k: 6, r: 3, s: 3, stride: 1, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let plan = plan_layer(&q, g, EngineConfig::default());
        let pool = Pool::new(2);
        let pixels = g.n * g.out_h() * g.out_w();
        let blocks = pixels.div_ceil(PB);

        let nchw = execute_conv2d_pool(&plan, &x, &pool);
        let mut blocked = vec![f32::NAN; blocks * g.k * PB];
        let io = TileIo { input_blocked: false, output_blocked: true };
        execute_conv2d_layout(
            &plan,
            x.data(),
            &mut blocked,
            &pool,
            DEFAULT_TILE,
            PostOp::default(),
            io,
        );

        let cg = Conv2dGeometry {
            n: g.n,
            c: g.k,
            h: g.out_h(),
            w: g.out_w(),
            k: 0,
            r: 1,
            s: 1,
            stride: 1,
            padding: 0,
        };
        let mut want = vec![f32::NAN; blocks * g.k * PB];
        im2col_rows_transposed_into(nchw.data(), &cg, 0, pixels, &mut want);
        assert!(blocked == want, "blocked scatter differs from transposed im2col");
    }

    #[test]
    fn blocked_input_bits_match_unblocked_at_every_width() {
        const PB: usize = PIXEL_BLOCK;
        let mut rng = Rng::new(38);
        // 1x1 / stride-1 / pad-0 consumer on a ragged pixel count (50)
        let g = Conv2dGeometry { n: 2, c: 6, h: 5, w: 5, k: 4, r: 1, s: 1, stride: 1, padding: 0 };
        let w = Tensor::rand_normal(&[g.k, g.c, 1, 1], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let plan = plan_layer(&q, g, EngineConfig::default());
        let pixels = g.n * g.h * g.w;
        let blocks = pixels.div_ceil(PB);
        let mut patches = vec![f32::NAN; blocks * g.c * PB];
        im2col_rows_transposed_into(x.data(), &g, 0, pixels, &mut patches);
        let want = execute_conv2d_pool(&plan, &x, &Pool::new(1));
        for &threads in probe_widths() {
            let pool = Pool::new(threads);
            let mut out = vec![f32::NAN; g.n * g.k * g.h * g.w];
            let io = TileIo { input_blocked: true, output_blocked: false };
            execute_conv2d_layout(
                &plan,
                &patches,
                &mut out,
                &pool,
                DEFAULT_TILE,
                PostOp::default(),
                io,
            );
            assert!(out == want.data(), "{threads}-thread blocked input differs");
        }
    }

    #[test]
    fn fused_edge_chain_matches_unfused_chain_bitwise() {
        // 3x3 producer (blocked scatter, fused ReLU) -> 1x1 consumer
        // (blocked read): final output must bit-match the unfused
        // NCHW-handoff chain
        const PB: usize = PIXEL_BLOCK;
        let mut rng = Rng::new(39);
        let g1 = Conv2dGeometry { n: 1, c: 3, h: 7, w: 7, k: 8, r: 3, s: 3, stride: 1, padding: 1 };
        let g2 = Conv2dGeometry { n: 1, c: 8, h: 7, w: 7, k: 5, r: 1, s: 1, stride: 1, padding: 0 };
        let w1 = Tensor::rand_normal(&[g1.k, g1.c, g1.r, g1.s], 0.5, &mut rng);
        let w2 = Tensor::rand_normal(&[g2.k, g2.c, 1, 1], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g1.n, g1.c, g1.h, g1.w], 1.0, &mut rng);
        let q1 = quantize(&w1, Scheme::sb_default(), None);
        let q2 = quantize(&w2, Scheme::sb_default(), None);
        let p1 = plan_layer(&q1, g1, EngineConfig::default());
        let p2 = plan_layer(&q2, g2, EngineConfig::default());
        let pool = Pool::new(2);
        let relu = PostOp { relu: true, residual: None };
        let pixels = g1.n * g1.out_h() * g1.out_w();
        let blocks = pixels.div_ceil(PB);

        // unfused reference: NCHW handoff
        let mut mid = vec![f32::NAN; g1.n * g1.k * g1.out_h() * g1.out_w()];
        execute_conv2d_into(&p1, x.data(), &mut mid, &pool, DEFAULT_TILE, relu);
        let mut want = vec![f32::NAN; g2.n * g2.k * g2.h * g2.w];
        execute_conv2d_into(&p2, &mid, &mut want, &pool, DEFAULT_TILE, PostOp::default());

        // fused: producer scatters patch blocks, consumer skips im2col
        let mut mid_blocks = vec![f32::NAN; blocks * g1.k * PB];
        let out_io = TileIo { input_blocked: false, output_blocked: true };
        execute_conv2d_layout(&p1, x.data(), &mut mid_blocks, &pool, DEFAULT_TILE, relu, out_io);
        let mut got = vec![f32::NAN; g2.n * g2.k * g2.h * g2.w];
        let in_io = TileIo { input_blocked: true, output_blocked: false };
        execute_conv2d_layout(
            &p2,
            &mid_blocks,
            &mut got,
            &pool,
            DEFAULT_TILE,
            PostOp::default(),
            in_io,
        );
        assert!(got == want, "fused patch handoff differs from NCHW handoff");
    }

    /// The generalized reuse path: 3x3 and strided consumers read a
    /// producer's blocked activation through the per-tile gather and
    /// must match their NCHW-input execution bit for bit at every pool
    /// width.
    #[test]
    fn blocked_input_gather_matches_nchw_for_3x3_and_strided_consumers() {
        const PB: usize = PIXEL_BLOCK;
        let mut rng = Rng::new(40);
        // 7x7 -> 49 input pixels: ragged final input block
        let geoms = [
            Conv2dGeometry { n: 1, c: 6, h: 7, w: 7, k: 8, r: 3, s: 3, stride: 1, padding: 1 },
            Conv2dGeometry { n: 2, c: 4, h: 7, w: 7, k: 6, r: 3, s: 3, stride: 2, padding: 1 },
            Conv2dGeometry { n: 1, c: 5, h: 8, w: 8, k: 7, r: 1, s: 1, stride: 2, padding: 0 },
            Conv2dGeometry { n: 1, c: 3, h: 6, w: 6, k: 4, r: 3, s: 3, stride: 1, padding: 0 },
        ];
        for g in geoms {
            let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
            let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
            let q = quantize(&w, Scheme::sb_default(), None);
            let plan = plan_layer(&q, g, EngineConfig::default());
            let in_pixels = g.n * g.h * g.w;
            let unit = Conv2dGeometry { k: 0, r: 1, s: 1, stride: 1, padding: 0, ..g };
            let mut blocked = vec![f32::NAN; in_pixels.div_ceil(PB) * g.c * PB];
            im2col_rows_transposed_into(x.data(), &unit, 0, in_pixels, &mut blocked);
            let want = execute_conv2d_pool(&plan, &x, &Pool::new(1));
            for &threads in probe_widths() {
                let pool = Pool::new(threads);
                let mut out = vec![f32::NAN; g.n * g.k * g.out_h() * g.out_w()];
                let io = TileIo { input_blocked: true, output_blocked: false };
                execute_conv2d_layout(
                    &plan,
                    &blocked,
                    &mut out,
                    &pool,
                    DEFAULT_TILE,
                    PostOp::default(),
                    io,
                );
                assert!(
                    out == want.data(),
                    "{threads}-thread blocked-gather input differs for {g:?}"
                );
            }
        }
    }

    #[test]
    fn option_a_stride_covers_even_and_odd_sizes() {
        assert_eq!(option_a_stride(8, 8), 1);
        assert_eq!(option_a_stride(8, 4), 2);
        assert_eq!(option_a_stride(7, 4), 2); // odd source, stride-2 conv
        assert_eq!(option_a_stride(9, 3), 3);
        assert_eq!(option_a_stride(1, 1), 1);
        assert_eq!(option_a_stride(5, 1), 5);
        // every returned stride satisfies the subsample equation
        for (src, out) in [(8, 4), (7, 4), (9, 5), (9, 3), (32, 16), (5, 3)] {
            let st = option_a_stride(src, out);
            assert_eq!((src - 1) / st + 1, out, "src {src} out {out} st {st}");
        }
    }

    /// Regression: an option-A shortcut over an odd spatial size used to
    /// panic in `PostOp::validate` (`res.h == oh * stride` with h = 7,
    /// stride = 2, oh = 4) even though `apply` reads at most row
    /// `(oh-1)*stride = 6`. The fused epilogue must accept it and match
    /// separate passes exactly.
    #[test]
    fn odd_size_strided_residual_is_accepted_and_correct() {
        let mut rng = Rng::new(46);
        // stride-2 conv on a 7x7 input: oh = (7+2-3)/2+1 = 4, 4*2 != 7
        let g = Conv2dGeometry { n: 2, c: 4, h: 7, w: 7, k: 8, r: 3, s: 3, stride: 2, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let plan = plan_layer(&q, g, EngineConfig::default());
        let pool = Pool::new(2);
        let (oh, ow) = (g.out_h(), g.out_w());
        assert_eq!(oh, 4);

        let mut reference = execute_conv2d_pool(&plan, &x, &pool);
        for ni in 0..g.n {
            for fi in 0..g.c.min(g.k) {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let v = reference.at4(ni, fi, oy, ox) + x.at4(ni, fi, 2 * oy, 2 * ox);
                        reference.set4(ni, fi, oy, ox, v);
                    }
                }
            }
        }
        for v in reference.data_mut() {
            *v = v.max(0.0);
        }

        let st = option_a_stride(g.h, oh);
        assert_eq!(st, 2);
        let post = PostOp {
            relu: true,
            residual: Some(Residual { src: x.data(), c: g.c, h: g.h, w: g.w, stride: st }),
        };
        let mut out = vec![f32::NAN; g.n * g.k * oh * ow];
        execute_conv2d_into(&plan, x.data(), &mut out, &pool, DEFAULT_TILE, post);
        assert!(out == reference.data(), "odd-size strided residual differs");
    }

    #[test]
    #[should_panic(expected = "PIXEL_BLOCK-aligned tile")]
    fn misaligned_tile_with_blocked_io_fails_the_early_check() {
        let mut rng = Rng::new(47);
        let g = Conv2dGeometry { n: 1, c: 4, h: 6, w: 6, k: 4, r: 3, s: 3, stride: 1, padding: 1 };
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let plan = plan_layer(&q, g, EngineConfig::default());
        let pixels = g.n * g.out_h() * g.out_w();
        let mut out = vec![f32::NAN; pixels.div_ceil(PIXEL_BLOCK) * g.k * PIXEL_BLOCK];
        let io = TileIo { input_blocked: false, output_blocked: true };
        // tile 12 is not a PIXEL_BLOCK multiple: must fail up front
        execute_conv2d_layout(&plan, x.data(), &mut out, &Pool::new(1), 12, PostOp::default(), io);
    }

    #[test]
    fn tile_support_predicate() {
        assert!(tile_supports_blocked_io(DEFAULT_TILE));
        assert!(tile_supports_blocked_io(PIXEL_BLOCK));
        assert!(!tile_supports_blocked_io(0));
        assert!(!tile_supports_blocked_io(12));
    }
}
