//! Layer plan: pattern extraction, memoization and operation accounting.
//!
//! The plan's index data lives in one contiguous CSR-style arena
//! ([`PatternArena`]): a single `cols` buffer holds every distinct
//! pattern's absolute C*R*S column indices (+1 run, then -1 run, then
//! zero run), and fixed-size [`PatternSpan`] records delimit each
//! pattern. The executor's inner loop therefore streams two flat arrays
//! instead of chasing per-pattern `Vec` allocations scattered across the
//! heap — the cache-contiguity lesson of SparseDNN-style sparse-CPU
//! engines. A flattened `combine` table (`[unique_filter][sub_tile] ->
//! global pattern slot`) replaces the per-table slot lookups.
//!
//! Plan *construction* is parallel: sub-tiles are memoized independently
//! (each is a self-contained pattern-dedup problem), fanned over the
//! worker pool, and merged into the arena in sub-tile order — so a
//! multi-layer cold start scales with cores while the resulting
//! [`PatternArena`] stays **byte-identical for every thread count**.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::quant::QuantizedWeights;
use crate::tensor::Conv2dGeometry;
use crate::util::Pool;

use super::EngineConfig;

/// Auto-tuner cost-model constant (ops-equivalents) per pattern visit;
/// calibrated against measured per-layer times (EXPERIMENTS.md §Perf).
pub const PATTERN_OVERHEAD: f64 = 2.0;
/// Auto-tuner cost-model constant per combine-table slot visit.
pub const SLOT_OVERHEAD: f64 = 1.0;

/// One distinct pattern's run inside the arena: `cols[start..]` holds
/// `pos` columns with +1 sign, then `neg` columns with -1 sign, then
/// `zero` zero-weight columns (materialized only when sparsity support
/// is OFF — the engine then sums that group and multiplies by 0,
/// faithfully "not distinguishing zero from non-zero").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PatternSpan {
    /// start offset of this pattern's run in `PatternArena::cols`
    pub start: u32,
    /// number of +1 columns
    pub pos: u32,
    /// number of -1 columns
    pub neg: u32,
    /// number of zero columns
    pub zero: u32,
}

impl PatternSpan {
    /// Non-zero columns (the effectual weights of the pattern).
    pub fn nnz(&self) -> u64 {
        (self.pos + self.neg) as u64
    }

    /// True when every column of the pattern is zero.
    pub fn is_all_zero(&self) -> bool {
        self.pos == 0 && self.neg == 0
    }

    /// Total columns (the sub-tile length).
    pub fn len(&self) -> usize {
        (self.pos + self.neg + self.zero) as usize
    }

    /// True for zero-length patterns (degenerate sub-tiles).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Adds needed to evaluate this pattern's partial sum once.
    pub fn adds(&self, sparsity_support: bool) -> u64 {
        if sparsity_support {
            self.nnz().saturating_sub(1)
        } else {
            // zero group summed too (then multiplied by 0)
            (self.nnz() + self.zero as u64).saturating_sub(1)
        }
    }
}

/// Contiguous index arena over every distinct pattern of every sub-tile.
///
/// `PartialEq`/`Eq` compare the raw buffers — used by tests and the
/// plan-build scaling harness to assert the arena is byte-identical
/// regardless of how many threads built it.
///
/// **Elision** (sparsity support ON): zero columns are never
/// materialized — each span's `zero` field survives as a *count* for
/// accounting, but `cols` holds only the effectual pos/neg runs, and
/// every all-zero (ineffectual) pattern shares one no-op span
/// ([`PatternArena::noop_slot`]) instead of owning arena storage. The
/// executor's hot loop therefore never touches a zero column. Sparsity
/// OFF (and [`LayerPlan::build_pool_unelided`]) materializes the zero
/// runs as before.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PatternArena {
    /// absolute C*R*S column indices, pattern-contiguous (pos|neg|zero
    /// runs back to back — zero runs only when `zeros_materialized`);
    /// the sub-tile base is already folded in
    pub cols: Vec<u32>,
    /// one span per distinct pattern, in sub-tile order
    pub spans: Vec<PatternSpan>,
    /// `spans` index where each sub-tile's patterns begin;
    /// `len == num_tables + 1` (CSR row pointers). An elided arena's
    /// shared no-op span sits at slot 0, *before* `table_base[0]`.
    pub table_base: Vec<u32>,
    /// zero runs are materialized in `cols` (repetition-only builds and
    /// the unelided reference builder); elided arenas keep only the
    /// `zero` count on each span
    pub zeros_materialized: bool,
    /// global span slot shared by every all-zero pattern (elided
    /// arenas); `None` when all-zero patterns own real spans
    pub noop_slot: Option<u32>,
}

impl PatternArena {
    /// Distinct patterns across every sub-tile.
    pub fn num_patterns(&self) -> usize {
        self.spans.len()
    }

    /// Number of sub-tiles the arena covers.
    pub fn num_tables(&self) -> usize {
        self.table_base.len().saturating_sub(1)
    }

    /// Distinct patterns in sub-tile `ti`.
    pub fn patterns_in_table(&self, ti: usize) -> usize {
        (self.table_base[ti + 1] - self.table_base[ti]) as usize
    }

    /// The (pos, neg, zero) column slices of pattern `gp`. An elided
    /// arena does not materialize zero runs, so its zero slice is empty
    /// even when `spans[gp].zero > 0` (the count survives for
    /// accounting).
    pub fn pattern_cols(&self, gp: usize) -> (&[u32], &[u32], &[u32]) {
        let sp = self.spans[gp];
        let s = sp.start as usize;
        let p = s + sp.pos as usize;
        let n = p + sp.neg as usize;
        let z = if self.zeros_materialized { n + sp.zero as usize } else { n };
        (&self.cols[s..p], &self.cols[p..n], &self.cols[n..z])
    }
}

/// Per-layer effectual-density accounting recorded at plan-build time —
/// the numbers the `plum bench density` sweep reports (the paper's
/// repetition-sparsity trade-off curve).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DensityStats {
    /// weight columns over all original filters (effectual + zero)
    pub total_cols: u64,
    /// non-zero weight columns (what the elided arena materializes,
    /// weighted by original-filter usage)
    pub effectual_cols: u64,
    /// distinct all-zero patterns folded into the shared no-op slot
    /// (0 when the build did not elide)
    pub elided_spans: u64,
}

impl DensityStats {
    /// Effectual / total columns (1.0 for an empty layer).
    pub fn density(&self) -> f64 {
        if self.total_cols == 0 {
            1.0
        } else {
            self.effectual_cols as f64 / self.total_cols as f64
        }
    }
}

/// Operation counts for one inference pass (all output pixels).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCounts {
    /// additions / subtractions
    pub adds: u64,
    /// multiplications
    pub muls: u64,
}

impl OpCounts {
    /// Adds + muls (the paper counts each as one operation).
    pub fn total(&self) -> u64 {
        self.adds + self.muls
    }
}

/// A fully-built plan for one conv layer.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    /// conv geometry the plan executes
    pub geom: Conv2dGeometry,
    /// engine configuration the plan was built under
    pub cfg: EngineConfig,
    /// CSR pattern arena (one flat buffer for the whole layer)
    pub arena: PatternArena,
    /// combine table: `combine[ui * num_tables + ti]` is the global
    /// pattern slot feeding unique filter `ui` from sub-tile `ti` —
    /// per-filter accumulation walks it contiguously
    pub combine: Vec<u32>,
    /// number of sub-tiles along the C*R*S axis
    pub num_tables: usize,
    /// sub-tile lengths (the last may be short)
    pub table_len: Vec<usize>,
    /// per-filter scale (original filter index -> alpha)
    pub alpha: Vec<f32>,
    /// original filter -> unique filter slot (inter-filter dedup)
    pub unique_of_filter: Vec<u32>,
    /// distinct structural filters after dedup
    pub num_unique_filters: usize,
    /// effectual-density accounting recorded at build time
    pub stats: DensityStats,
}

/// One sub-tile's memoization result, built independently of every
/// other sub-tile: pattern columns are absolute (the sub-tile base is
/// folded in), span starts and combine slots are fragment-local until
/// the deterministic merge offsets them.
struct SubtileFragment {
    len: usize,
    cols: Vec<u32>,
    spans: Vec<PatternSpan>,
    /// per unique filter, fragment-local pattern slot
    slots: Vec<u32>,
}

impl LayerPlan {
    /// Build on the process-wide pool (see [`LayerPlan::build_pool`]).
    pub fn build(q: &QuantizedWeights, geom: Conv2dGeometry, cfg: EngineConfig) -> LayerPlan {
        Self::build_pool(q, geom, cfg, Pool::global())
    }

    /// Build a plan, fanning per-sub-tile pattern memoization over
    /// `pool`. Each fragment depends only on its sub-tile index and the
    /// merge walks fragments in sub-tile order, so the resulting arena,
    /// combine table and span layout are byte-identical for every pool
    /// width (asserted by `arena_identical_for_every_thread_count` and
    /// the `bench_repetition` plan-build study).
    ///
    /// With `cfg.sparsity_support` the arena is **elided**: zero
    /// columns get no arena slots and all-zero patterns fold into one
    /// shared no-op span (see [`PatternArena`]).
    pub fn build_pool(
        q: &QuantizedWeights,
        geom: Conv2dGeometry,
        cfg: EngineConfig,
        pool: &Pool,
    ) -> LayerPlan {
        Self::build_pool_impl(q, geom, cfg, pool, cfg.sparsity_support)
    }

    /// Reference builder for tests and benches: sparsity-ON execution
    /// semantics *without* plan-time elision — zero runs materialized,
    /// all-zero patterns owning real spans, exactly the arena every
    /// build produced before elision landed. The executor never reads
    /// zero columns when `sparsity_support` is on, so this plan's
    /// forward must stay bit-identical to the elided plan's at every
    /// pool width; the property tests and the `bench density` sweep
    /// assert exactly that invariant.
    pub fn build_pool_unelided(
        q: &QuantizedWeights,
        geom: Conv2dGeometry,
        cfg: EngineConfig,
        pool: &Pool,
    ) -> LayerPlan {
        Self::build_pool_impl(q, geom, cfg, pool, false)
    }

    fn build_pool_impl(
        q: &QuantizedWeights,
        geom: Conv2dGeometry,
        cfg: EngineConfig,
        pool: &Pool,
        elide: bool,
    ) -> LayerPlan {
        // fragment-local slot marking an all-zero window the merge maps
        // to the shared no-op span
        const ELIDED: u32 = u32::MAX;
        assert!(cfg.subtile > 0);
        let k = geom.k;
        let e = geom.c * geom.r * geom.s;
        assert_eq!(q.values.len(), k * e, "weights do not match geometry");

        // ---- inter-filter dedup on the full structural signature --------
        // signature: sign class per element (alpha factored out)
        let sig_of = |fi: usize| -> Vec<i8> {
            q.values.data()[fi * e..(fi + 1) * e]
                .iter()
                .map(|v| {
                    if *v > 0.0 {
                        1
                    } else if *v < 0.0 {
                        -1
                    } else {
                        0
                    }
                })
                .collect()
        };
        let mut canon: HashMap<Vec<i8>, u32> = HashMap::new();
        let mut unique_of_filter = Vec::with_capacity(k);
        let mut unique_sigs: Vec<Vec<i8>> = Vec::new();
        for fi in 0..k {
            let sig = sig_of(fi);
            let slot = *canon.entry(sig.clone()).or_insert_with(|| {
                unique_sigs.push(sig);
                (unique_sigs.len() - 1) as u32
            });
            unique_of_filter.push(slot);
        }
        let nu = unique_sigs.len();

        // ---- per-sub-tile pattern memoization, fanned over the pool ----
        // Sub-tiles are independent pattern-dedup problems; fragment `ti`
        // depends only on `ti`, so the parallel fill is deterministic.
        let num_tables = e.div_ceil(cfg.subtile);
        let frags: Vec<Mutex<Option<SubtileFragment>>> =
            (0..num_tables).map(|_| Mutex::new(None)).collect();
        let sigs = &unique_sigs;
        pool.run(num_tables, |ti| {
            let base = ti * cfg.subtile;
            let len = cfg.subtile.min(e - base);
            let mut frag = SubtileFragment {
                len,
                cols: Vec::new(),
                spans: Vec::new(),
                slots: Vec::with_capacity(nu),
            };
            let mut pat_map: HashMap<&[i8], u32> = HashMap::new();
            for sig in sigs {
                let window = &sig[base..base + len];
                let slot = *pat_map.entry(window).or_insert_with(|| {
                    if elide && window.iter().all(|sgn| *sgn == 0) {
                        // ineffectual pattern: no span, no columns — the
                        // merge maps it to the shared no-op slot
                        return ELIDED;
                    }
                    // new distinct pattern: append its pos/neg (and,
                    // unelided, zero) column runs and a span; elided
                    // builds keep the zero run as a count only
                    let start = frag.cols.len() as u32;
                    let mut pos = 0u32;
                    let mut neg = 0u32;
                    let mut zero = 0u32;
                    for (off, sgn) in window.iter().enumerate() {
                        if *sgn == 1 {
                            frag.cols.push((base + off) as u32);
                            pos += 1;
                        }
                    }
                    for (off, sgn) in window.iter().enumerate() {
                        if *sgn == -1 {
                            frag.cols.push((base + off) as u32);
                            neg += 1;
                        }
                    }
                    for (off, sgn) in window.iter().enumerate() {
                        if *sgn == 0 {
                            if !elide {
                                frag.cols.push((base + off) as u32);
                            }
                            zero += 1;
                        }
                    }
                    frag.spans.push(PatternSpan { start, pos, neg, zero });
                    (frag.spans.len() - 1) as u32
                });
                frag.slots.push(slot);
            }
            *frags[ti].lock().unwrap() = Some(frag);
        });

        // ---- deterministic merge: walk fragments in sub-tile order and
        // offset their local span starts / pattern slots into the one
        // contiguous CSR arena ------------------------------------------
        let mut arena = PatternArena {
            cols: Vec::new(),
            spans: Vec::new(),
            table_base: vec![0],
            zeros_materialized: !elide,
            noop_slot: None,
        };
        if elide {
            // global slot 0: the shared no-op span every ineffectual
            // (all-zero) pattern combines through. Its partial sum is
            // always [0.0; PIXEL_BLOCK], so a filter combining through
            // it adds exactly +0.0 — value-preserving by construction.
            arena.spans.push(PatternSpan { start: 0, pos: 0, neg: 0, zero: 0 });
            arena.table_base[0] = 1;
            arena.noop_slot = Some(0);
        }
        let mut table_len = Vec::with_capacity(num_tables);
        let mut combine = vec![0u32; nu * num_tables];
        let mut elided_spans = 0u64;
        for (ti, cell) in frags.iter().enumerate() {
            let frag = cell
                .lock()
                .unwrap()
                .take()
                .expect("every sub-tile fragment is filled by the pool run");
            let col_off = arena.cols.len() as u32;
            let span_off = arena.spans.len() as u32;
            arena.cols.extend_from_slice(&frag.cols);
            arena.spans.extend(
                frag.spans
                    .iter()
                    .map(|sp| PatternSpan { start: sp.start + col_off, ..*sp }),
            );
            arena.table_base.push(arena.spans.len() as u32);
            // per unique filter, its pattern slots across sub-tiles are
            // adjacent — the executor's combine layout
            let mut saw_elided = false;
            for (ui, &slot) in frag.slots.iter().enumerate() {
                combine[ui * num_tables + ti] = if slot == ELIDED {
                    saw_elided = true;
                    0 // the shared no-op slot
                } else {
                    span_off + slot
                };
            }
            if saw_elided {
                elided_spans += 1;
            }
            table_len.push(frag.len);
        }

        // effectual-density accounting over *original* filters (so the
        // numbers match the weight tensor's count_nonzero exactly)
        let mut effectual_cols = 0u64;
        for &ui in &unique_of_filter {
            let row = &combine[ui as usize * num_tables..(ui as usize + 1) * num_tables];
            for &gp in row {
                effectual_cols += arena.spans[gp as usize].nnz();
            }
        }
        let stats = DensityStats { total_cols: (k * e) as u64, effectual_cols, elided_spans };

        LayerPlan {
            geom,
            cfg,
            arena,
            combine,
            num_tables,
            table_len,
            alpha: per_filter_alpha(q, k, e),
            unique_of_filter,
            num_unique_filters: nu,
            stats,
        }
    }

    /// Total adds/muls for one full inference pass of this layer.
    ///
    /// Per output pixel:
    ///   * each distinct pattern per sub-tile: its partial sum
    ///     (nnz-1 adds with sparsity support, len-1 without; all-zero
    ///     patterns are free with support, len-1 adds + nothing without —
    ///     their product with 0 is dropped either way in accounting
    ///     because SumMerge also never multiplies the zero group);
    ///   * each unique filter: (num_subtiles - 1) adds to combine partial
    ///     sums + 1 mul by alpha.
    pub fn op_counts(&self) -> OpCounts {
        let pixels = (self.geom.n * self.geom.out_h() * self.geom.out_w()) as u64;
        let adds_per_pixel: u64 = self
            .arena
            .spans
            .iter()
            .map(|sp| sp.adds(self.cfg.sparsity_support))
            .sum();
        let nt = self.num_tables as u64;
        let per_filter_adds = nt.saturating_sub(1);
        let nu = self.num_unique_filters as u64;
        OpCounts {
            adds: pixels * (adds_per_pixel + nu * per_filter_adds),
            muls: pixels * nu,
        }
    }

    /// Estimated execution cost used by the auto-tuner: accounted ops
    /// plus a fixed per-pattern-visit overhead (loop control, arena
    /// store) and a per-combine-slot overhead — calibrated once against
    /// measured layer timings (§Perf).
    pub fn estimated_cost(&self) -> f64 {
        let pixels = (self.geom.n * self.geom.out_h() * self.geom.out_w()) as f64;
        let total_patterns = self.arena.num_patterns() as f64;
        let slots = self.combine.len() as f64;
        let ops = self.op_counts();
        (ops.adds + ops.muls) as f64
            + pixels * (PATTERN_OVERHEAD * total_patterns + SLOT_OVERHEAD * slots)
    }

    /// Mean distinct patterns per sub-tile — the repetition diagnostic
    /// (binary << ternary; Figure 3's exponential argument).
    pub fn mean_distinct_patterns(&self) -> f64 {
        self.arena.num_patterns() as f64 / self.num_tables.max(1) as f64
    }

    /// Weight density seen by the plan (nnz / total over unique filters).
    pub fn density(&self) -> f64 {
        let mut nnz = 0u64;
        let mut tot = 0u64;
        for ti in 0..self.num_tables {
            for ui in 0..self.num_unique_filters {
                let sp = self.arena.spans[self.combine[ui * self.num_tables + ti] as usize];
                nnz += sp.nnz();
                tot += self.table_len[ti] as u64;
            }
        }
        nnz as f64 / tot.max(1) as f64
    }
}

fn per_filter_alpha(q: &QuantizedWeights, k: usize, e: usize) -> Vec<f32> {
    // alpha per original filter: magnitude of the filter's non-zero value.
    (0..k)
        .map(|fi| {
            q.values.data()[fi * e..(fi + 1) * e]
                .iter()
                .find(|v| **v != 0.0)
                .map(|v| v.abs())
                .unwrap_or(0.0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{default_beta, quantize, quantize_signed_binary, Scheme};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn geom(c: usize, k: usize) -> Conv2dGeometry {
        Conv2dGeometry { n: 1, c, h: 4, w: 4, k, r: 3, s: 3, stride: 1, padding: 1 }
    }

    #[test]
    fn dedup_finds_identical_filters() {
        // two identical filters by construction
        let mut rng = Rng::new(20);
        let mut w = Tensor::rand_normal(&[4, 2, 3, 3], 0.5, &mut rng);
        let e = 18;
        let (first, rest) = w.data_mut().split_at_mut(e);
        rest[..e].copy_from_slice(first);
        let q = quantize(&w, Scheme::Binary, None);
        let plan = LayerPlan::build(&q, geom(2, 4), EngineConfig::default());
        assert!(plan.num_unique_filters < 4);
        assert_eq!(plan.unique_of_filter[0], plan.unique_of_filter[1]);
    }

    #[test]
    fn binary_has_fewer_patterns_than_ternary() {
        let mut rng = Rng::new(21);
        let w = Tensor::rand_normal(&[64, 16, 3, 3], 0.5, &mut rng);
        let g = geom(16, 64);
        let cfg = EngineConfig::default();
        let pb = LayerPlan::build(&quantize(&w, Scheme::Binary, None), g, cfg);
        let pt = LayerPlan::build(&quantize(&w, Scheme::ternary_default(), None), g, cfg);
        assert!(
            pb.mean_distinct_patterns() < pt.mean_distinct_patterns(),
            "binary {} vs ternary {}",
            pb.mean_distinct_patterns(),
            pt.mean_distinct_patterns()
        );
    }

    #[test]
    fn sparsity_toggle_changes_adds_only_for_sparse_schemes() {
        let mut rng = Rng::new(22);
        let w = Tensor::rand_normal(&[16, 8, 3, 3], 0.5, &mut rng);
        let g = geom(8, 16);
        let q = quantize_signed_binary(&w, &default_beta(16, 0.5), 0.05, 1);
        let on = LayerPlan::build(&q, g, EngineConfig { subtile: 8, sparsity_support: true });
        let off = LayerPlan::build(&q, g, EngineConfig { subtile: 8, sparsity_support: false });
        assert!(on.op_counts().adds < off.op_counts().adds);
        // binary is dense: toggle is a no-op on adds
        let qb = quantize(&w, Scheme::Binary, None);
        let bon = LayerPlan::build(&qb, g, EngineConfig { subtile: 8, sparsity_support: true });
        let boff = LayerPlan::build(&qb, g, EngineConfig { subtile: 8, sparsity_support: false });
        assert_eq!(bon.op_counts().adds, boff.op_counts().adds);
    }

    #[test]
    fn op_counts_scale_with_pixels() {
        let mut rng = Rng::new(23);
        let w = Tensor::rand_normal(&[8, 4, 3, 3], 0.5, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let g1 = Conv2dGeometry { n: 1, c: 4, h: 4, w: 4, k: 8, r: 3, s: 3, stride: 1, padding: 1 };
        let g2 = Conv2dGeometry { n: 2, c: 4, h: 4, w: 4, k: 8, r: 3, s: 3, stride: 1, padding: 1 };
        let c1 = LayerPlan::build(&q, g1, EngineConfig::default()).op_counts();
        let c2 = LayerPlan::build(&q, g2, EngineConfig::default()).op_counts();
        assert_eq!(c2.adds, 2 * c1.adds);
        assert_eq!(c2.muls, 2 * c1.muls);
    }

    #[test]
    fn alpha_extracted_per_filter() {
        let mut rng = Rng::new(24);
        let w = Tensor::rand_normal(&[4, 4, 3, 3], 0.5, &mut rng);
        let q = quantize(&w, Scheme::Binary, None);
        let plan = LayerPlan::build(&q, geom(4, 4), EngineConfig::default());
        for (fi, a) in plan.alpha.iter().enumerate() {
            assert!((a - q.alpha[fi]).abs() < 1e-6);
        }
    }

    #[test]
    fn arena_is_contiguous_and_consistent() {
        // repetition-only builds (and the unelided reference builder)
        // materialize every column, so the strict CSR invariants hold
        let mut rng = Rng::new(25);
        let w = Tensor::rand_normal(&[12, 6, 3, 3], 0.5, &mut rng);
        let g = geom(6, 12);
        let cfg_off = EngineConfig { subtile: 8, sparsity_support: false };
        let cfg_on = EngineConfig { subtile: 8, sparsity_support: true };
        for scheme in [Scheme::Binary, Scheme::ternary_default(), Scheme::sb_default()] {
            let q = quantize(&w, scheme, None);
            let pool = crate::util::Pool::new(1);
            let off = LayerPlan::build(&q, g, cfg_off);
            let unelided = LayerPlan::build_pool_unelided(&q, g, cfg_on, &pool);
            for plan in [&off, &unelided] {
                let e = g.c * g.r * g.s;
                let a = &plan.arena;
                assert!(a.zeros_materialized);
                assert_eq!(a.noop_slot, None);
                // spans tile `cols` exactly, back to back
                let mut cursor = 0u32;
                for sp in &a.spans {
                    assert_eq!(sp.start, cursor, "spans must be contiguous");
                    cursor += sp.pos + sp.neg + sp.zero;
                }
                assert_eq!(cursor as usize, a.cols.len());
                // every pattern covers its whole sub-tile once
                assert_eq!(a.table_base.len(), plan.num_tables + 1);
                for ti in 0..plan.num_tables {
                    for gp in a.table_base[ti] as usize..a.table_base[ti + 1] as usize {
                        assert_eq!(a.spans[gp].len(), plan.table_len[ti]);
                    }
                }
                // columns are absolute and in range; combine indexes valid slots
                assert!(a.cols.iter().all(|c| (*c as usize) < e));
                assert_eq!(plan.combine.len(), plan.num_unique_filters * plan.num_tables);
                assert!(plan.combine.iter().all(|s| (*s as usize) < a.num_patterns()));
                // combine's per-table slots stay inside that table's span range
                for ui in 0..plan.num_unique_filters {
                    for ti in 0..plan.num_tables {
                        let gp = plan.combine[ui * plan.num_tables + ti];
                        assert!(gp >= a.table_base[ti] && gp < a.table_base[ti + 1]);
                    }
                }
            }
        }
    }

    #[test]
    fn elided_arena_invariants() {
        // sparsity-on builds elide: no zero columns in the arena, no
        // all-zero spans except the shared no-op at slot 0, combine
        // slots either in-table or the no-op
        let mut rng = Rng::new(25);
        let w = Tensor::rand_normal(&[12, 6, 3, 3], 0.5, &mut rng);
        let g = geom(6, 12);
        for scheme in [Scheme::ternary_default(), Scheme::sb_default()] {
            let q = quantize(&w, scheme, None);
            let plan = LayerPlan::build(&q, g, EngineConfig { subtile: 8, sparsity_support: true });
            let a = &plan.arena;
            assert!(!a.zeros_materialized);
            assert_eq!(a.noop_slot, Some(0));
            assert!(a.spans[0].is_all_zero() && a.spans[0].len() == 0);
            assert_eq!(a.table_base[0], 1, "tables start after the no-op span");
            // spans tile `cols` back to back by their *effectual* runs
            let mut cursor = 0u32;
            for sp in &a.spans {
                assert_eq!(sp.start, cursor, "spans must be contiguous");
                cursor += sp.pos + sp.neg;
            }
            assert_eq!(cursor as usize, a.cols.len());
            for (gp, sp) in a.spans.iter().enumerate() {
                if gp > 0 {
                    assert!(sp.nnz() > 0, "span {gp} is ineffectual but owns a slot");
                }
                // zero *counts* survive: in-table spans still cover the
                // whole sub-tile by len()
            }
            for ti in 0..plan.num_tables {
                for gp in a.table_base[ti] as usize..a.table_base[ti + 1] as usize {
                    assert_eq!(a.spans[gp].len(), plan.table_len[ti]);
                }
                for ui in 0..plan.num_unique_filters {
                    let gp = plan.combine[ui * plan.num_tables + ti];
                    let in_table = gp >= a.table_base[ti] && gp < a.table_base[ti + 1];
                    assert!(in_table || gp == 0, "combine slot {gp} outside table {ti}");
                }
            }
            // the zero slice of every pattern is empty (not materialized)
            for gp in 0..a.num_patterns() {
                let (_, _, zero) = a.pattern_cols(gp);
                assert!(zero.is_empty());
            }
            // density stats match the quantized tensor exactly
            assert_eq!(plan.stats.total_cols as usize, q.values.len());
            assert_eq!(plan.stats.effectual_cols as usize, q.values.count_nonzero());
            assert!((plan.stats.density() - q.density()).abs() < 1e-12);
        }
    }

    #[test]
    fn all_zero_filter_costs_nothing_in_the_elided_arena() {
        // regression (the pre-elision engine gave all-zero patterns a
        // real span and combine slots each): filter 0 quantizes to
        // all-zero under SB beta=+1, and with sparsity support its
        // patterns must occupy zero arena storage
        let mut w = Tensor::filled(&[2, 2, 3, 3], -0.001);
        for i in 18..36 {
            w.data_mut()[i] = 0.9; // filter 1 all positive
        }
        let q = quantize_signed_binary(&w, &[1.0, 1.0], 0.05, 1);
        let g = geom(2, 2);
        let plan = LayerPlan::build(&q, g, EngineConfig { subtile: 8, sparsity_support: true });
        let noop = plan.arena.noop_slot.expect("elided arena has a no-op slot");
        let ui0 = plan.unique_of_filter[0] as usize;
        for ti in 0..plan.num_tables {
            assert_eq!(
                plan.combine[ui0 * plan.num_tables + ti],
                noop,
                "all-zero filter must combine through the shared no-op slot"
            );
        }
        // no span besides the shared no-op is ineffectual, and the
        // no-op itself is free
        for (gp, sp) in plan.arena.spans.iter().enumerate() {
            if gp as u32 != noop {
                assert!(sp.nnz() > 0, "span {gp} is ineffectual but kept");
            }
        }
        assert_eq!(plan.arena.spans[noop as usize].adds(true), 0);
        // one elided pattern per sub-tile; filter 1's 18 weights are
        // the only effectual columns
        assert_eq!(plan.stats.elided_spans, plan.num_tables as u64);
        assert_eq!(plan.stats.effectual_cols, 18);
        assert_eq!(plan.stats.total_cols, 36);
    }

    #[test]
    fn arena_identical_for_every_thread_count() {
        // the parallel build's merge must be deterministic: any pool
        // width produces byte-identical plan data
        let mut rng = Rng::new(27);
        let w = Tensor::rand_normal(&[24, 8, 3, 3], 0.5, &mut rng);
        let g = geom(8, 24);
        for scheme in [Scheme::Binary, Scheme::ternary_default(), Scheme::sb_default()] {
            let q = quantize(&w, scheme, None);
            for subtile in [5, 8, 16] {
                let cfg = EngineConfig { subtile, sparsity_support: true };
                let base = LayerPlan::build_pool(&q, g, cfg, &crate::util::Pool::new(1));
                for threads in [2, 3, 7] {
                    let plan = LayerPlan::build_pool(&q, g, cfg, &crate::util::Pool::new(threads));
                    assert!(plan.arena == base.arena, "arena differs at {threads} threads");
                    assert_eq!(plan.combine, base.combine, "{threads} threads");
                    assert_eq!(plan.table_len, base.table_len, "{threads} threads");
                    assert_eq!(plan.unique_of_filter, base.unique_of_filter, "{threads} threads");
                    assert_eq!(plan.alpha, base.alpha, "{threads} threads");
                }
            }
        }
    }

    #[test]
    fn arena_columns_match_signatures() {
        // reconstruct each unique filter's sign vector from the arena and
        // compare against the quantized weights directly
        let mut rng = Rng::new(26);
        let w = Tensor::rand_normal(&[6, 4, 3, 3], 0.5, &mut rng);
        let g = geom(4, 6);
        let q = quantize(&w, Scheme::ternary_default(), None);
        let plan = LayerPlan::build(&q, g, EngineConfig { subtile: 7, sparsity_support: false });
        let e = g.c * g.r * g.s;
        for fi in 0..g.k {
            let ui = plan.unique_of_filter[fi] as usize;
            let mut sig = vec![0i8; e];
            for ti in 0..plan.num_tables {
                let gp = plan.combine[ui * plan.num_tables + ti] as usize;
                let (pos, neg, zero) = plan.arena.pattern_cols(gp);
                for &c in pos {
                    sig[c as usize] = 1;
                }
                for &c in neg {
                    sig[c as usize] = -1;
                }
                for &c in zero {
                    sig[c as usize] = 0;
                }
            }
            for (ei, s) in sig.iter().enumerate() {
                let v = q.values.data()[fi * e + ei];
                let expect = if v > 0.0 {
                    1
                } else if v < 0.0 {
                    -1
                } else {
                    0
                };
                assert_eq!(*s, expect, "filter {fi} elem {ei}");
            }
        }
    }
}
