//! Layer plan: pattern extraction, memoization and operation accounting.

use std::collections::HashMap;

use crate::quant::QuantizedWeights;
use crate::tensor::Conv2dGeometry;

use super::EngineConfig;

/// Auto-tuner cost-model constants (ops-equivalents); calibrated against
/// measured per-layer times on this CPU (EXPERIMENTS.md §Perf).
pub const PATTERN_OVERHEAD: f64 = 2.0;
pub const SLOT_OVERHEAD: f64 = 1.0;

/// Sign class of a quantized weight relative to its filter's alpha.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SignClass {
    Neg,
    Zero,
    Pos,
}

/// One distinct weight pattern within a sub-tile: the list of
/// (offset-in-subtile, sign) for non-zero entries plus the zero group.
#[derive(Debug, Clone)]
pub struct Pattern {
    /// offsets with +1 sign (relative to subtile start)
    pub pos: Vec<u16>,
    /// offsets with -1 sign
    pub neg: Vec<u16>,
    /// offsets with zero weight (only materialized when sparsity support
    /// is OFF — the engine then sums this group and multiplies by 0,
    /// faithfully "not distinguishing zero from non-zero")
    pub zero: Vec<u16>,
}

impl Pattern {
    pub fn is_all_zero(&self) -> bool {
        self.pos.is_empty() && self.neg.is_empty()
    }

    /// Adds needed to evaluate this pattern's partial sum once.
    pub fn adds(&self, sparsity_support: bool) -> u64 {
        let nnz = (self.pos.len() + self.neg.len()) as u64;
        if sparsity_support {
            nnz.saturating_sub(1)
        } else {
            // zero group summed too (then multiplied by 0)
            (nnz + self.zero.len() as u64).saturating_sub(1)
        }
    }
}

/// Per-sub-tile table of distinct patterns + each filter's pattern slot.
#[derive(Debug, Clone)]
pub struct PatternTable {
    /// distinct patterns in this sub-tile
    pub patterns: Vec<Pattern>,
    /// filter (unique-filter index) -> pattern slot
    pub slot_of_filter: Vec<u32>,
    /// absolute element offset of this sub-tile in the C*R*S axis
    pub base: usize,
    /// sub-tile length (last tile may be short)
    pub len: usize,
}

/// Operation counts for one inference pass (all output pixels).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCounts {
    pub adds: u64,
    pub muls: u64,
}

impl OpCounts {
    pub fn total(&self) -> u64 {
        self.adds + self.muls
    }
}

/// A fully-built plan for one conv layer.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub geom: Conv2dGeometry,
    pub cfg: EngineConfig,
    /// per sub-tile pattern tables (indexed over unique filters)
    pub tables: Vec<PatternTable>,
    /// per-filter scale (original filter index -> alpha)
    pub alpha: Vec<f32>,
    /// original filter -> unique filter slot (inter-filter dedup)
    pub unique_of_filter: Vec<u32>,
    pub num_unique_filters: usize,
}

impl LayerPlan {
    pub fn build(q: &QuantizedWeights, geom: Conv2dGeometry, cfg: EngineConfig) -> LayerPlan {
        assert!(cfg.subtile > 0);
        let k = geom.k;
        let e = geom.c * geom.r * geom.s;
        assert_eq!(q.values.len(), k * e, "weights do not match geometry");

        // ---- inter-filter dedup on the full structural signature --------
        // signature: sign class per element (alpha factored out)
        let sig_of = |fi: usize| -> Vec<i8> {
            q.values.data()[fi * e..(fi + 1) * e]
                .iter()
                .map(|v| {
                    if *v > 0.0 {
                        1
                    } else if *v < 0.0 {
                        -1
                    } else {
                        0
                    }
                })
                .collect()
        };
        let mut canon: HashMap<Vec<i8>, u32> = HashMap::new();
        let mut unique_of_filter = Vec::with_capacity(k);
        let mut unique_sigs: Vec<Vec<i8>> = Vec::new();
        for fi in 0..k {
            let sig = sig_of(fi);
            let slot = *canon.entry(sig.clone()).or_insert_with(|| {
                unique_sigs.push(sig);
                (unique_sigs.len() - 1) as u32
            });
            unique_of_filter.push(slot);
        }
        let nu = unique_sigs.len();

        // ---- per-sub-tile pattern memoization ----------------------------
        let mut tables = Vec::new();
        let mut base = 0usize;
        while base < e {
            let len = cfg.subtile.min(e - base);
            let mut pat_map: HashMap<Vec<i8>, u32> = HashMap::new();
            let mut patterns: Vec<Pattern> = Vec::new();
            let mut slot_of_filter = Vec::with_capacity(nu);
            for sig in &unique_sigs {
                let window = &sig[base..base + len];
                let slot = *pat_map.entry(window.to_vec()).or_insert_with(|| {
                    let mut p = Pattern { pos: vec![], neg: vec![], zero: vec![] };
                    for (off, s) in window.iter().enumerate() {
                        match s {
                            1 => p.pos.push(off as u16),
                            -1 => p.neg.push(off as u16),
                            _ => p.zero.push(off as u16),
                        }
                    }
                    patterns.push(p);
                    (patterns.len() - 1) as u32
                });
                slot_of_filter.push(slot);
            }
            tables.push(PatternTable { patterns, slot_of_filter, base, len });
            base += len;
        }

        LayerPlan {
            geom,
            cfg,
            tables,
            alpha: per_filter_alpha(q, k, e),
            unique_of_filter,
            num_unique_filters: nu,
        }
    }

    /// Total adds/muls for one full inference pass of this layer.
    ///
    /// Per output pixel:
    ///   * each distinct pattern per sub-tile: its partial sum
    ///     (nnz-1 adds with sparsity support, len-1 without; all-zero
    ///     patterns are free with support, len-1 adds + nothing without —
    ///     their product with 0 is dropped either way in accounting
    ///     because SumMerge also never multiplies the zero group);
    ///   * each unique filter: (num_subtiles - 1) adds to combine partial
    ///     sums + 1 mul by alpha.
    pub fn op_counts(&self) -> OpCounts {
        let pixels = (self.geom.n * self.geom.out_h() * self.geom.out_w()) as u64;
        let mut adds_per_pixel: u64 = 0;
        for t in &self.tables {
            for p in &t.patterns {
                let nnz = (p.pos.len() + p.neg.len()) as u64;
                if self.cfg.sparsity_support {
                    adds_per_pixel += nnz.saturating_sub(1);
                } else {
                    let total = nnz + p.zero.len() as u64;
                    adds_per_pixel += total.saturating_sub(1);
                }
            }
        }
        let nt = self.tables.len() as u64;
        let per_filter_adds = nt.saturating_sub(1);
        let nu = self.num_unique_filters as u64;
        OpCounts {
            adds: pixels * (adds_per_pixel + nu * per_filter_adds),
            muls: pixels * nu,
        }
    }

    /// Estimated execution cost used by the auto-tuner: accounted ops
    /// plus a fixed per-pattern-visit overhead (loop control, arena
    /// store) and a per-combine-slot overhead — calibrated once against
    /// measured layer timings (§Perf).
    pub fn estimated_cost(&self) -> f64 {
        let pixels = (self.geom.n * self.geom.out_h() * self.geom.out_w()) as f64;
        let total_patterns: usize = self.tables.iter().map(|t| t.patterns.len()).sum();
        let slots = (self.num_unique_filters * self.tables.len()) as f64;
        let ops = self.op_counts();
        (ops.adds + ops.muls) as f64
            + pixels * (PATTERN_OVERHEAD * total_patterns as f64 + SLOT_OVERHEAD * slots)
    }

    /// Mean distinct patterns per sub-tile — the repetition diagnostic
    /// (binary << ternary; Figure 3's exponential argument).
    pub fn mean_distinct_patterns(&self) -> f64 {
        let s: usize = self.tables.iter().map(|t| t.patterns.len()).sum();
        s as f64 / self.tables.len().max(1) as f64
    }

    /// Weight density seen by the plan (nnz / total over unique filters).
    pub fn density(&self) -> f64 {
        let mut nnz = 0usize;
        let mut tot = 0usize;
        for t in &self.tables {
            for (ui, &slot) in t.slot_of_filter.iter().enumerate() {
                let _ = ui;
                let p = &t.patterns[slot as usize];
                nnz += p.pos.len() + p.neg.len();
                tot += t.len;
            }
        }
        nnz as f64 / tot.max(1) as f64
    }
}

fn per_filter_alpha(q: &QuantizedWeights, k: usize, e: usize) -> Vec<f32> {
    // alpha per original filter: magnitude of the filter's non-zero value.
    (0..k)
        .map(|fi| {
            q.values.data()[fi * e..(fi + 1) * e]
                .iter()
                .find(|v| **v != 0.0)
                .map(|v| v.abs())
                .unwrap_or(0.0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{default_beta, quantize, quantize_signed_binary, Scheme};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn geom(c: usize, k: usize) -> Conv2dGeometry {
        Conv2dGeometry { n: 1, c, h: 4, w: 4, k, r: 3, s: 3, stride: 1, padding: 1 }
    }

    #[test]
    fn dedup_finds_identical_filters() {
        // two identical filters by construction
        let mut rng = Rng::new(20);
        let mut w = Tensor::rand_normal(&[4, 2, 3, 3], 0.5, &mut rng);
        let e = 18;
        let (first, rest) = w.data_mut().split_at_mut(e);
        rest[..e].copy_from_slice(first);
        let q = quantize(&w, Scheme::Binary, None);
        let plan = LayerPlan::build(&q, geom(2, 4), EngineConfig::default());
        assert!(plan.num_unique_filters < 4);
        assert_eq!(plan.unique_of_filter[0], plan.unique_of_filter[1]);
    }

    #[test]
    fn binary_has_fewer_patterns_than_ternary() {
        let mut rng = Rng::new(21);
        let w = Tensor::rand_normal(&[64, 16, 3, 3], 0.5, &mut rng);
        let g = geom(16, 64);
        let cfg = EngineConfig::default();
        let pb = LayerPlan::build(&quantize(&w, Scheme::Binary, None), g, cfg);
        let pt = LayerPlan::build(&quantize(&w, Scheme::ternary_default(), None), g, cfg);
        assert!(
            pb.mean_distinct_patterns() < pt.mean_distinct_patterns(),
            "binary {} vs ternary {}",
            pb.mean_distinct_patterns(),
            pt.mean_distinct_patterns()
        );
    }

    #[test]
    fn sparsity_toggle_changes_adds_only_for_sparse_schemes() {
        let mut rng = Rng::new(22);
        let w = Tensor::rand_normal(&[16, 8, 3, 3], 0.5, &mut rng);
        let g = geom(8, 16);
        let q = quantize_signed_binary(&w, &default_beta(16, 0.5), 0.05, 1);
        let on = LayerPlan::build(&q, g, EngineConfig { subtile: 8, sparsity_support: true });
        let off = LayerPlan::build(&q, g, EngineConfig { subtile: 8, sparsity_support: false });
        assert!(on.op_counts().adds < off.op_counts().adds);
        // binary is dense: toggle is a no-op on adds
        let qb = quantize(&w, Scheme::Binary, None);
        let bon = LayerPlan::build(&qb, g, EngineConfig { subtile: 8, sparsity_support: true });
        let boff = LayerPlan::build(&qb, g, EngineConfig { subtile: 8, sparsity_support: false });
        assert_eq!(bon.op_counts().adds, boff.op_counts().adds);
    }

    #[test]
    fn op_counts_scale_with_pixels() {
        let mut rng = Rng::new(23);
        let w = Tensor::rand_normal(&[8, 4, 3, 3], 0.5, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let g1 = Conv2dGeometry { n: 1, c: 4, h: 4, w: 4, k: 8, r: 3, s: 3, stride: 1, padding: 1 };
        let g2 = Conv2dGeometry { n: 2, c: 4, h: 4, w: 4, k: 8, r: 3, s: 3, stride: 1, padding: 1 };
        let c1 = LayerPlan::build(&q, g1, EngineConfig::default()).op_counts();
        let c2 = LayerPlan::build(&q, g2, EngineConfig::default()).op_counts();
        assert_eq!(c2.adds, 2 * c1.adds);
        assert_eq!(c2.muls, 2 * c1.muls);
    }

    #[test]
    fn alpha_extracted_per_filter() {
        let mut rng = Rng::new(24);
        let w = Tensor::rand_normal(&[4, 4, 3, 3], 0.5, &mut rng);
        let q = quantize(&w, Scheme::Binary, None);
        let plan = LayerPlan::build(&q, geom(4, 4), EngineConfig::default());
        for (fi, a) in plan.alpha.iter().enumerate() {
            assert!((a - q.alpha[fi]).abs() < 1e-6);
        }
    }
}
