//! Repetition-sparsity-aware inference engine (S3).
//!
//! A from-scratch reproduction of the *mechanisms* of SumMerge
//! (Prabhakar et al. 2021) / UCNN (Hegde et al. 2018), the systems the
//! paper deploys on Intel CPUs:
//!
//! 1. **Tiling**: each filter's C*R*S reduction axis is split into
//!    sub-tiles (the paper's `C*`); one processing step sees one sub-tile.
//! 2. **Weight-repetition factorization**: within a sub-tile a filter's
//!    weights form a *pattern* over a tiny alphabet (sign classes
//!    {-1, 0, +1}; the per-filter scale alpha is factored out). Distinct
//!    patterns are *memoized per sub-tile*: their partial sums are
//!    computed once per output pixel and shared by every filter that uses
//!    them. Fewer distinct patterns == more repetition == less work. This
//!    is why binary (2^T possible patterns) beats ternary (3^T) — the
//!    paper's exponential-repetition-loss argument made concrete.
//! 3. **Sparsity support** (on/off, paper §5.1): a *plan-time*
//!    property, not an execute-time branch. ON, the plan **elides**
//!    ineffectual work outright — zero columns are dropped from the
//!    pattern arena and all-zero patterns fold into one shared no-op
//!    span — so the hot loop never even sees a zero weight; per-layer
//!    [`DensityStats`] record what was elided. OFF, the engine treats 0
//!    as just another repeated value and sums its group like any other
//!    (the repetition-only baseline arm).
//! 4. **Filter dedup**: structurally identical quantized filters are
//!    computed once (inter-filter repetition, BNN's 42% observation).
//!
//! The engine both *executes* (timed, correctness-checked against the
//! dense GEMM path) and *accounts* (adds/muls), powering Figures 7/9/10
//! and the §5.1 arithmetic-operation claims.
//!
//! Execution backend: plans store their indices in a contiguous
//! CSR-style arena (`plan::PatternArena`, built in parallel per
//! sub-tile) and the executor (`exec`) runs tile-fused and parallel —
//! im2col fused per output-pixel tile in the pixel-major (transposed)
//! layout so pattern gathers are contiguous SIMD-width loads, tiles
//! spread over the persistent `util::pool` workers, bit-identical for
//! every thread count. Consecutive layers skip the NCHW round-trip
//! entirely: [`execute_conv2d_layout`] scatters a producer's output
//! straight into pixel-major patch blocks and reads such blocks back as
//! input ([`TileIo`]) — in place for 1x1 / stride-1 / pad-0 consumers,
//! through a per-tile blocked gather for 3x3 and strided ones — the
//! network executor's cross-layer patch reuse.
//!
//! # Plan and execute one layer
//!
//! ```
//! use plum::quant::{quantize, Scheme};
//! use plum::repetition::{execute_conv2d, plan_layer, EngineConfig};
//! use plum::tensor::{conv2d_gemm, Conv2dGeometry, Tensor};
//! use plum::util::Rng;
//!
//! let g = Conv2dGeometry { n: 1, c: 4, h: 5, w: 5, k: 6, r: 3, s: 3, stride: 1, padding: 1 };
//! let mut rng = Rng::new(7);
//! let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
//! let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
//! let q = quantize(&w, Scheme::sb_default(), None);
//!
//! let plan = plan_layer(&q, g, EngineConfig::default());
//! let out = execute_conv2d(&plan, &x);
//! let dense = conv2d_gemm(&x, &q.values, g.stride, g.padding);
//! assert!(dense.max_abs_diff(&out) < 1e-3);
//! ```

pub mod cse;
mod exec;
mod plan;

pub use cse::{build_cse, CseDag};
pub use exec::{
    execute_conv2d, execute_conv2d_into, execute_conv2d_layout, execute_conv2d_layout_batch,
    execute_conv2d_pool, execute_conv2d_tiled, option_a_stride, tile_supports_blocked_io,
    validate_blocked_tile, PostOp, Residual, TileIo, DEFAULT_TILE, PIXEL_BLOCK,
};
pub use plan::{DensityStats, LayerPlan, OpCounts, PatternArena, PatternSpan};

use crate::quant::QuantizedWeights;
use crate::tensor::Conv2dGeometry;

/// Engine configuration (paper supp. A: `C*` tile size; §5.1: sparsity
/// support toggle).
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Sub-tile length along the C*R*S reduction axis (the paper's C*).
    pub subtile: usize,
    /// When false the engine ignores zero-ness (repetition only).
    pub sparsity_support: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { subtile: 8, sparsity_support: true }
    }
}

/// Build a plan for one conv layer from its quantized weights
/// (per-sub-tile memoization runs on the process-wide pool).
pub fn plan_layer(
    q: &QuantizedWeights,
    geom: Conv2dGeometry,
    cfg: EngineConfig,
) -> LayerPlan {
    LayerPlan::build(q, geom, cfg)
}

/// [`plan_layer`] on an explicit pool — benchmarks pin the build's
/// 1-thread vs N-thread cold-start cost; the resulting plan is
/// byte-identical at every width.
pub fn plan_layer_pool(
    q: &QuantizedWeights,
    geom: Conv2dGeometry,
    cfg: EngineConfig,
    pool: &crate::util::Pool,
) -> LayerPlan {
    LayerPlan::build_pool(q, geom, cfg, pool)
}

/// Candidate sub-tile sizes searched by the auto-tuner. Sizes below 8
/// are excluded: there the per-filter combine stage dominates for every
/// scheme (the plan degenerates into a dense re-accumulation), the cost
/// model's overhead constants stop being trustworthy, and the measured
/// times regress across the board.
pub const SUBTILE_CANDIDATES: &[usize] = &[8, 12, 16, 24, 32, 48, 64];

/// Build the cheapest plan over `SUBTILE_CANDIDATES` per the plan cost
/// model — the engine-side realization of the paper's §6 requirement
/// that "the tile size of the modern inference system should be set"
/// per configuration (SumMerge likewise tunes its tiling per network).
pub fn plan_layer_auto(
    q: &QuantizedWeights,
    geom: Conv2dGeometry,
    sparsity_support: bool,
) -> LayerPlan {
    plan_layer_auto_pool(q, geom, sparsity_support, crate::util::Pool::global())
}

/// [`plan_layer_auto`] on an explicit pool.
pub fn plan_layer_auto_pool(
    q: &QuantizedWeights,
    geom: Conv2dGeometry,
    sparsity_support: bool,
    pool: &crate::util::Pool,
) -> LayerPlan {
    let e = geom.c * geom.r * geom.s;
    let mut best: Option<LayerPlan> = None;
    for &st in SUBTILE_CANDIDATES {
        if st > e && best.is_some() {
            break;
        }
        let plan = LayerPlan::build_pool(
            q,
            geom,
            EngineConfig { subtile: st.min(e), sparsity_support },
            pool,
        );
        if best
            .as_ref()
            .map(|b| plan.estimated_cost() < b.estimated_cost())
            .unwrap_or(true)
        {
            best = Some(plan);
        }
    }
    best.unwrap()
}

/// The paper's arithmetic-reduction metric (supp. G): dense MACs divided
/// by the plan's repetition-sparsity-aware operation count, counting an
/// add and a mul each as one operation (dense: 2 ops per MAC).
pub fn arithmetic_reduction(plan: &LayerPlan) -> f64 {
    let dense_ops = 2.0 * plan.geom.dense_macs() as f64;
    let c = plan.op_counts();
    dense_ops / (c.adds + c.muls).max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{default_beta, quantize, quantize_signed_binary, Scheme};
    use crate::tensor::{conv2d_gemm, Tensor};
    use crate::util::Rng;

    fn geom(n: usize, c: usize, hw: usize, k: usize) -> Conv2dGeometry {
        Conv2dGeometry { n, c, h: hw, w: hw, k, r: 3, s: 3, stride: 1, padding: 1 }
    }

    #[test]
    fn engine_matches_dense_gemm_sb() {
        let mut rng = Rng::new(11);
        let g = geom(2, 8, 6, 12);
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let q = quantize_signed_binary(&w, &default_beta(g.k, 0.5), 0.05, 1);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let dense = conv2d_gemm(&x, &q.values, 1, 1);
        for sparsity in [true, false] {
            let plan = plan_layer(&q, g, EngineConfig { subtile: 8, sparsity_support: sparsity });
            let out = execute_conv2d(&plan, &x);
            assert!(
                dense.max_abs_diff(&out) < 1e-3,
                "sparsity={sparsity} diff {}",
                dense.max_abs_diff(&out)
            );
        }
    }

    #[test]
    fn engine_matches_dense_gemm_all_schemes() {
        let mut rng = Rng::new(12);
        let g = geom(1, 6, 5, 8);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        for scheme in [Scheme::Binary, Scheme::ternary_default(), Scheme::sb_default()] {
            let q = quantize(&w, scheme, None);
            let dense = conv2d_gemm(&x, &q.values, 1, 1);
            let plan = plan_layer(&q, g, EngineConfig::default());
            let out = execute_conv2d(&plan, &x);
            assert!(
                dense.max_abs_diff(&out) < 1e-3,
                "{}: diff {}",
                scheme.name(),
                dense.max_abs_diff(&out)
            );
        }
    }

    #[test]
    fn subtile_sizes_all_correct() {
        let mut rng = Rng::new(13);
        let g = geom(1, 8, 5, 6);
        let x = Tensor::rand_normal(&[g.n, g.c, g.h, g.w], 1.0, &mut rng);
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let dense = conv2d_gemm(&x, &q.values, 1, 1);
        for st in [4, 8, 16, 72, 100] {
            let plan = plan_layer(&q, g, EngineConfig { subtile: st, sparsity_support: true });
            let out = execute_conv2d(&plan, &x);
            assert!(dense.max_abs_diff(&out) < 1e-3, "subtile {st}");
        }
    }

    #[test]
    fn sb_reduces_ops_vs_binary_with_sparsity() {
        // the §5.1 claim in miniature: SB w/ sparsity does fewer ops than
        // binary; ternary w/ sparsity does more than binary (repetition
        // loss dominates).
        let mut rng = Rng::new(14);
        let g = geom(1, 64, 8, 128);
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let cfg = EngineConfig { subtile: 8, sparsity_support: true };

        let qb = quantize(&w, Scheme::Binary, None);
        let qt = quantize(&w, Scheme::ternary_default(), None);
        let qs = quantize(&w, Scheme::sb_default(), None);
        let ops_b = plan_layer(&qb, g, cfg).op_counts().total();
        let ops_t = plan_layer(&qt, g, cfg).op_counts().total();
        let ops_s = plan_layer(&qs, g, cfg).op_counts().total();
        assert!(ops_s < ops_b, "sb {ops_s} !< binary {ops_b}");
        assert!(ops_t > ops_s, "ternary {ops_t} !> sb {ops_s}");
    }

    #[test]
    fn arithmetic_reduction_above_one() {
        let mut rng = Rng::new(15);
        let g = geom(1, 32, 8, 64);
        let w = Tensor::rand_normal(&[g.k, g.c, g.r, g.s], 0.5, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        let plan = plan_layer(&q, g, EngineConfig::default());
        let red = arithmetic_reduction(&plan);
        assert!(red > 1.0, "reduction {red}");
    }
}
