//! `plum` — launcher binary. See `plum help` / README.md.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = plum::cli::run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
