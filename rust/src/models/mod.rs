//! Model zoo descriptors (S9).
//!
//! Architecture geometry mirrored from `python/compile/model.py` (an
//! integration test cross-checks these against the conv_layers recorded
//! in the AOT manifests). Used for:
//!
//! * the Figure 7 / Figure 9 workloads (per-layer conv shapes of
//!   ResNet-18 without having to load an artifact);
//! * parameter / effectual-parameter accounting for the Pareto plots
//!   (Figures 2 & 5) and Table 7's equal-effectual comparisons.

use crate::quant::Scheme;
use crate::tensor::Conv2dGeometry;

/// One conv layer of a described network.
#[derive(Debug, Clone)]
pub struct ConvLayerDesc {
    /// layer name, e.g. `003.conv` / `005.proj`
    pub name: String,
    /// full conv geometry (batch included)
    pub geom: Conv2dGeometry,
    /// false for full-precision layers (the stem)
    pub quantized: bool,
}

impl ConvLayerDesc {
    /// Weight count of this layer (K*C*R*S).
    pub fn weights(&self) -> usize {
        self.geom.weight_count()
    }

    /// Output shape `(channels, height, width)` — what a chained next
    /// layer must accept as input. The network compiler
    /// (`network::NetworkPlan`) validates whole descriptor lists with
    /// this; descriptor builders that insert pooling (vgg/alexnet
    /// trunks) intentionally break the chain.
    pub fn out_shape(&self) -> (usize, usize, usize) {
        (self.geom.k, self.geom.out_h(), self.geom.out_w())
    }

    /// Input activation elements (batch included).
    pub fn input_elems(&self) -> usize {
        self.geom.n * self.geom.c * self.geom.h * self.geom.w
    }

    /// Output activation elements (batch included).
    pub fn output_elems(&self) -> usize {
        self.geom.n * self.geom.k * self.geom.out_h() * self.geom.out_w()
    }
}

#[allow(clippy::too_many_arguments)]
fn conv(
    name: String,
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    ks: usize,
    stride: usize,
    quantized: bool,
) -> ConvLayerDesc {
    ConvLayerDesc {
        name,
        geom: Conv2dGeometry { n, c, h, w, k, r: ks, s: ks, stride, padding: ks / 2 },
        quantized,
    }
}

fn scaled(widths: &[usize], mult: f64, floor: usize) -> Vec<usize> {
    widths
        .iter()
        .map(|w| ((*w as f64 * mult).round() as usize).max(floor))
        .collect()
}

/// CIFAR ResNet (He et al.): depth = 6n+2, option-A shortcuts (no conv),
/// stem unquantized. Mirrors `model.Tape.forward`'s cifar_resnet branch.
pub fn cifar_resnet_layers(
    depth: usize,
    width_mult: f64,
    image: usize,
    batch: usize,
) -> Vec<ConvLayerDesc> {
    assert_eq!((depth - 2) % 6, 0, "depth must be 6n+2");
    let n = (depth - 2) / 6;
    let widths = scaled(&[16, 32, 64], width_mult, 4);
    let mut layers = Vec::new();
    let mut idx = 0usize;
    let mut push = |c, h, w, k, ks, st, q, idx: &mut usize| {
        layers.push(conv(format!("{idx:03}.conv"), batch, c, h, w, k, ks, st, q));
        *idx += 1;
    };
    let (mut h, mut w) = (image, image);
    push(3, h, w, widths[0], 3, 1, false, &mut idx);
    let mut in_ch = widths[0];
    for (si, &wd) in widths.iter().enumerate() {
        for bi in 0..n {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            push(in_ch, h, w, wd, 3, stride, true, &mut idx);
            if stride == 2 {
                // real conv output arithmetic, not h/2: identical for
                // even sizes, correct for odd ones (7 -> 4, not 3)
                h = strided_out(h);
                w = strided_out(w);
            }
            push(wd, h, w, wd, 3, 1, true, &mut idx);
            in_ch = wd;
        }
    }
    layers
}

/// Output size of the zoo's stride-2 3x3 pad-1 convs: `(d - 1) / 2 + 1`
/// — equals `d / 2` for even `d` and stays exact for odd `d` (7 -> 4),
/// so descriptor lists chain correctly at any image size.
fn strided_out(d: usize) -> usize {
    (d + 2 - 3) / 2 + 1
}

/// ResNet-18-shaped CIFAR variant, **network-compile order**: each
/// stage holds 2 blocks; stage-boundary blocks carry a quantized 1x1
/// *projection* shortcut (option B) emitted **between** the block's two
/// convs — `[conv1, proj, conv2]` — so the list is executable in order
/// (the projection's output exists before the conv that adds it).
/// `network::resnet18_wiring` derives the branching wiring from this
/// shape. First-stage blocks (stride 1, equal channels) use identity
/// shortcuts and emit no projection. Stem unquantized, widths
/// `[16, 32, 64, 128] * width_mult`.
pub fn cifar_resnet18_layers(width_mult: f64, image: usize, batch: usize) -> Vec<ConvLayerDesc> {
    let widths = scaled(&[16, 32, 64, 128], width_mult, 8);
    let mut layers = Vec::new();
    let mut idx = 0usize;
    let mut push = |c, h, w, k, ks, st, q, name: &str, idx: &mut usize| {
        layers.push(conv(format!("{idx:03}.{name}"), batch, c, h, w, k, ks, st, q));
        *idx += 1;
    };
    let (mut h, mut w) = (image, image);
    push(3, h, w, widths[0], 3, 1, false, "conv", &mut idx);
    let mut in_ch = widths[0];
    for (si, &wd) in widths.iter().enumerate() {
        for bi in 0..2 {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            push(in_ch, h, w, wd, 3, stride, true, "conv", &mut idx);
            if stride != 1 || in_ch != wd {
                // projection shortcut 1x1 (quantized), reading the same
                // activation as the block's first conv
                push(in_ch, h, w, wd, 1, stride, true, "proj", &mut idx);
            }
            if stride == 2 {
                h = strided_out(h);
                w = strided_out(w);
            }
            push(wd, h, w, wd, 3, 1, true, "conv", &mut idx);
            in_ch = wd;
        }
    }
    layers
}

/// Canonical depth of the `chain1x1` model — shared by
/// [`engine_model_layers`] (serving) and `plum bench network` (the
/// `network_forward_fused` series), so the benched and served shapes
/// can never diverge.
pub const CHAIN1X1_DEPTH: usize = 12;
/// Canonical channel width of the `chain1x1` model (see
/// [`CHAIN1X1_DEPTH`]).
pub const CHAIN1X1_WIDTH: usize = 64;

/// Fp 3x3 stem + a contiguous chain of `depth - 1` quantized 1x1
/// convs (`width` channels, stride 1) — the consecutive-1x1 workload
/// where the network executor's cross-layer patch reuse pays: every
/// inter-1x1 edge is fusable, so one patch scatter replaces each
/// per-layer im2col pass.
pub fn conv1x1_chain_layers(
    depth: usize,
    width: usize,
    image: usize,
    batch: usize,
) -> Vec<ConvLayerDesc> {
    assert!(depth >= 2, "chain needs a stem plus at least one 1x1 conv");
    let mut layers = vec![conv("000.conv".into(), batch, 3, image, image, width, 3, 1, false)];
    for i in 1..depth {
        layers.push(conv(format!("{i:03}.conv"), batch, width, image, image, width, 1, 1, true));
    }
    layers
}

/// CIFAR ResNet depth from a model name like `resnet20` / `resnet20_sb`.
pub fn cifar_resnet_depth(model: &str) -> Option<usize> {
    let rest = model.strip_prefix("resnet")?;
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok().filter(|d| *d >= 8 && (*d - 2) % 6 == 0)
}

/// Engine-servable zoo lookup by name — the models `plum serve
/// --backend engine` and `plum bench network` accept:
///
/// * `resnetN` (N = 6n+2, e.g. `resnet20`): CIFAR ResNet with option-A
///   shortcuts; a trailing suffix is tolerated (`resnet20_sb`);
/// * `resnet18c`: the CIFAR-scaled resnet18-shaped net with 1x1
///   projection shortcuts ([`cifar_resnet18_layers`]);
/// * `chain1x1`: fp stem + a [`CHAIN1X1_DEPTH`]-deep 1x1 chain — the
///   cross-layer patch-reuse showcase ([`conv1x1_chain_layers`]).
pub fn engine_model_layers(name: &str, image: usize, batch: usize) -> Option<Vec<ConvLayerDesc>> {
    match name {
        "resnet18c" => Some(cifar_resnet18_layers(1.0, image, batch)),
        "chain1x1" => Some(conv1x1_chain_layers(CHAIN1X1_DEPTH, CHAIN1X1_WIDTH, image, batch)),
        _ => cifar_resnet_depth(name).map(|d| cifar_resnet_layers(d, 1.0, image, batch)),
    }
}

/// ResNet-18 for `image`px inputs, projection shortcuts (quantized),
/// mirrors the `resnet18` branch of `model.Tape.forward`.
pub fn resnet18_layers(width_mult: f64, image: usize, batch: usize) -> Vec<ConvLayerDesc> {
    let widths = scaled(&[64, 128, 256, 512], width_mult, 8);
    let mut layers = Vec::new();
    let mut idx = 0usize;
    let mut push = |c, h, w, k, ks, st, q, idx: &mut usize| {
        layers.push(conv(format!("{idx:03}.conv"), batch, c, h, w, k, ks, st, q));
        *idx += 1;
    };
    let (mut h, mut w) = (image, image);
    push(3, h, w, widths[0], 3, 1, false, &mut idx);
    let mut in_ch = widths[0];
    for (si, &wd) in widths.iter().enumerate() {
        for bi in 0..2 {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            push(in_ch, h, w, wd, 3, stride, true, &mut idx);
            let (h2, w2) = if stride == 2 { (strided_out(h), strided_out(w)) } else { (h, w) };
            push(wd, h2, w2, wd, 3, 1, true, &mut idx);
            if stride != 1 || in_ch != wd {
                // projection shortcut 1x1 (quantized)
                push(in_ch, h, w, wd, 1, stride, true, &mut idx);
            }
            h = h2;
            w = w2;
            in_ch = wd;
        }
    }
    layers
}

/// VGG** derivative (Cai et al. 2017; paper Table 6): conv pairs with
/// 2x2 max-pools between stages; first conv full precision. Mirrors
/// `common.vgg_small_plan`.
pub fn vgg_small_layers(width_mult: f64, image: usize, batch: usize) -> Vec<ConvLayerDesc> {
    plan_layers(
        &[(128, false), (128, true), (0, false), (256, true), (256, true), (0, false),
          (512, true), (512, true), (0, false)],
        width_mult, image, batch,
    )
}

/// AlexNet* derivative (DoReFa svhn-digit; paper Table 6). Mirrors
/// `common.alexnet_small_plan`.
pub fn alexnet_small_layers(width_mult: f64, image: usize, batch: usize) -> Vec<ConvLayerDesc> {
    plan_layers(
        &[(48, false), (0, false), (64, true), (64, true), (0, false),
          (128, true), (128, true), (0, false)],
        width_mult, image, batch,
    )
}

/// Shared builder for plain conv-pool trunks: entries are (channels,
/// quantized); channels == 0 marks a 2x2 pool.
fn plan_layers(
    plan: &[(usize, bool)],
    width_mult: f64,
    image: usize,
    batch: usize,
) -> Vec<ConvLayerDesc> {
    let mut layers = Vec::new();
    let (mut h, mut w) = (image, image);
    let mut in_ch = 3usize;
    let mut idx = 0usize;
    for &(ch, quantized) in plan {
        if ch == 0 {
            h /= 2;
            w /= 2;
            continue;
        }
        let k = ((ch as f64 * width_mult).round() as usize).max(8);
        layers.push(conv(format!("{idx:03}.conv"), batch, in_ch, h, w, k, 3, 1, quantized));
        in_ch = k;
        idx += 1;
    }
    layers
}

/// Total weights across quantized conv layers.
pub fn quantized_weight_count(layers: &[ConvLayerDesc]) -> usize {
    layers.iter().filter(|l| l.quantized).map(|l| l.weights()).sum()
}

/// Expected effectual parameters under a scheme with the given sparsity
/// (binary: dense; ternary/sb: (1 - sparsity) of quantized weights).
pub fn effectual_estimate(layers: &[ConvLayerDesc], scheme: Scheme, sparsity: f64) -> usize {
    let q = quantized_weight_count(layers) as f64;
    match scheme {
        Scheme::Fp | Scheme::Binary => q as usize,
        _ => (q * (1.0 - sparsity)).round() as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet20_has_19_quantized_convs() {
        // 6n+2 with n=3: 18 block convs quantized + 1 unquantized stem
        let layers = cifar_resnet_layers(20, 1.0, 32, 1);
        assert_eq!(layers.len(), 19);
        assert_eq!(layers.iter().filter(|l| l.quantized).count(), 18);
    }

    #[test]
    fn depth_scaling() {
        let l20 = cifar_resnet_layers(20, 1.0, 32, 1);
        let l32 = cifar_resnet_layers(32, 1.0, 32, 1);
        assert_eq!(l32.len() - l20.len(), 12); // +2n per stage * 3 stages
    }

    #[test]
    fn width_scaling_reduces_params() {
        let full = quantized_weight_count(&cifar_resnet_layers(20, 1.0, 32, 1));
        let thin = quantized_weight_count(&cifar_resnet_layers(20, 0.7, 32, 1));
        assert!(thin < full);
        let ratio = thin as f64 / full as f64;
        assert!((0.4..0.6).contains(&ratio), "ratio {ratio}"); // ~0.49
    }

    #[test]
    fn resnet18_spatial_dims_consistent() {
        let layers = resnet18_layers(1.0, 64, 1);
        // stage outputs: 64 -> 32 -> 16 -> 8
        let last = layers.last().unwrap();
        assert_eq!(last.geom.h, 8);
        assert_eq!(last.geom.k, 512);
    }

    #[test]
    fn effectual_binary_vs_sb() {
        let layers = cifar_resnet_layers(20, 1.0, 32, 1);
        let b = effectual_estimate(&layers, Scheme::Binary, 0.0);
        let s = effectual_estimate(&layers, Scheme::sb_default(), 0.5);
        assert_eq!(b, 2 * s);
    }

    #[test]
    #[should_panic]
    fn bad_depth_panics() {
        cifar_resnet_layers(21, 1.0, 32, 1);
    }

    #[test]
    fn vgg_small_structure() {
        let layers = vgg_small_layers(0.5, 32, 1);
        assert_eq!(layers.len(), 6);
        assert!(!layers[0].quantized);
        assert!(layers[1..].iter().all(|l| l.quantized));
        // pools halve spatial dims between stages
        assert_eq!(layers[2].geom.h, 16);
        assert_eq!(layers[4].geom.h, 8);
    }

    #[test]
    fn cifar_resnet_layers_chain_contiguously() {
        // the invariant the network compiler builds on: every layer's
        // input shape is exactly its predecessor's out_shape()
        for depth in [8, 20, 32] {
            let layers = cifar_resnet_layers(depth, 1.0, 32, 2);
            for i in 1..layers.len() {
                let (k, oh, ow) = layers[i - 1].out_shape();
                let g = layers[i].geom;
                assert_eq!((g.c, g.h, g.w), (k, oh, ow), "depth {depth} layer {i}");
                assert_eq!(layers[i].output_elems(), 2 * k_next_elems(&layers[i]));
            }
        }
    }

    fn k_next_elems(l: &ConvLayerDesc) -> usize {
        l.geom.k * l.geom.out_h() * l.geom.out_w()
    }

    #[test]
    fn odd_image_sizes_chain_contiguously() {
        // 7 -> 4 -> 2 under stride-2 3x3 pad-1 convs; the old h/2
        // arithmetic produced 3 and broke the chain invariant
        for image in [7, 9, 11] {
            let layers = cifar_resnet_layers(8, 1.0, image, 1);
            for i in 1..layers.len() {
                let (k, oh, ow) = layers[i - 1].out_shape();
                let g = layers[i].geom;
                assert_eq!((g.c, g.h, g.w), (k, oh, ow), "image {image} layer {i}");
            }
            let layers = cifar_resnet18_layers(1.0, image, 1);
            for i in 1..layers.len() {
                let g = layers[i].geom;
                if layers[i].name.ends_with(".proj") || layers[i - 1].name.ends_with(".proj") {
                    continue; // projections branch; wiring covers them
                }
                let (k, oh, ow) = layers[i - 1].out_shape();
                assert_eq!((g.c, g.h, g.w), (k, oh, ow), "r18c image {image} layer {i}");
            }
        }
    }

    #[test]
    fn alexnet_small_structure() {
        let layers = alexnet_small_layers(0.5, 32, 1);
        assert_eq!(layers.len(), 5);
        assert_eq!(layers[1].geom.h, 16); // after first pool
        assert_eq!(layers.last().unwrap().geom.h, 8);
    }

    #[test]
    fn cifar_resnet18_block_structure() {
        let layers = cifar_resnet18_layers(1.0, 32, 1);
        // stem + 8 blocks of 2 convs + 3 stage-boundary projections
        assert_eq!(layers.len(), 1 + 16 + 3);
        assert!(!layers[0].quantized);
        assert!(layers[1..].iter().all(|l| l.quantized));
        let projs: Vec<&ConvLayerDesc> =
            layers.iter().filter(|l| l.name.ends_with(".proj")).collect();
        assert_eq!(projs.len(), 3);
        for p in &projs {
            assert_eq!((p.geom.r, p.geom.s, p.geom.stride), (1, 1, 2));
        }
        // final stage: 128 channels at 4px
        let last = layers.last().unwrap();
        assert_eq!((last.geom.k, last.geom.h), (128, 4));
        // a projection reads the same activation as its block's first
        // conv and produces its block's output shape
        for (i, l) in layers.iter().enumerate() {
            if l.name.ends_with(".proj") {
                let a = layers[i - 1].geom;
                assert_eq!((l.geom.c, l.geom.h, l.geom.w), (a.c, a.h, a.w));
                assert_eq!(l.out_shape(), layers[i + 1].out_shape());
            }
        }
    }

    #[test]
    fn conv1x1_chain_is_contiguous() {
        let layers = conv1x1_chain_layers(12, 64, 32, 2);
        assert_eq!(layers.len(), 12);
        assert!(!layers[0].quantized);
        for i in 1..layers.len() {
            let g = layers[i].geom;
            assert_eq!((g.r, g.s, g.stride, g.padding), (1, 1, 1, 0));
            let (k, oh, ow) = layers[i - 1].out_shape();
            assert_eq!((g.c, g.h, g.w), (k, oh, ow), "layer {i}");
        }
    }

    #[test]
    fn engine_model_lookup() {
        assert_eq!(cifar_resnet_depth("resnet20"), Some(20));
        assert_eq!(cifar_resnet_depth("resnet8"), Some(8));
        assert_eq!(cifar_resnet_depth("resnet20_sb"), Some(20));
        assert_eq!(cifar_resnet_depth("resnet21"), None); // not 6n+2
        assert_eq!(cifar_resnet_depth("vgg_small"), None);
        assert_eq!(cifar_resnet_depth("resnet"), None);
        assert_eq!(engine_model_layers("resnet20", 32, 1).unwrap().len(), 19);
        assert!(engine_model_layers("resnet18c", 32, 1).unwrap().len() > 16);
        assert_eq!(engine_model_layers("chain1x1", 32, 1).unwrap().len(), 12);
        assert!(engine_model_layers("resnet18", 32, 1).is_none()); // not 6n+2
        assert!(engine_model_layers("mlp", 32, 1).is_none());
    }
}
