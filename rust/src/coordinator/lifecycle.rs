//! Zero-downtime model lifecycle: the versioned model catalog behind
//! the router, and the warmup → flip → drain machinery of a hot swap.
//!
//! The catalog maps model *names* to slots; each slot holds at most one
//! live [`Deployment`] — a versioned replica fleet (handles + stats +
//! backing threads). A deploy builds and *warms* the next version off to
//! the side (one real forward must succeed per replica; any failure
//! aborts the swap with a typed [`ServeError::WarmupFailed`] and the old
//! version keeps serving), atomically flips the slot's admission pointer
//! to the new fleet, then gracefully drains the old one:
//!
//! * requests already queued on the old version finish on the old plan
//!   (its supervisor keeps respawning crashes mid-drain, so the PR 6
//!   conservation invariant holds *across* the swap);
//! * the drain is bounded by [`ServePolicy::drain_timeout`]; when the
//!   budget is exceeded the fleet's shared drain flag trips, workers
//!   answer every remaining request with typed `ReplicaFailed`, and the
//!   supervisor stops respawning in favor of channel drainers;
//! * nothing is ever silently dropped — every admitted request still
//!   receives exactly one typed reply.
//!
//! `retire` reuses the same drain path without a replacement, and
//! router shutdown is a drain of every slot.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::error::{ServeError, ServePolicy, ServeResult};
use super::server::{
    drain_unserved, CircuitState, InferBackend, InferRequest, ReplicaHandle, ReplicaStats,
    WorkerExit,
};
use super::supervisor::spawn_supervised;

/// The model slot every single-model constructor (`Router::new`,
/// `Router::spawn`) deploys into, and the slot `Router::submit` routes
/// to when no model name is given.
pub const DEFAULT_MODEL: &str = "default";

/// What stands behind one deployment's replica slots.
pub(crate) enum Backing {
    /// caller-spawned workers; drain joins each generation directly
    Unsupervised(Vec<JoinHandle<WorkerExit>>),
    /// supervisor thread owns the generations; drain joins it and
    /// recovers its crash log
    Supervised(JoinHandle<Vec<String>>),
}

/// Result of draining one deployment (swap, retire, or shutdown).
#[derive(Debug, Clone)]
pub struct DrainReport {
    /// the version that was drained
    pub version: u64,
    /// wall-clock milliseconds from unhooking admission to the backing
    /// being joined (or to giving up, when `clean` is false)
    pub drain_ms: f64,
    /// true when every queued request was answered and the backing
    /// joined within the drain budget without tripping the fail-fast
    /// flag; false when stragglers had to be failed typed (or, in the
    /// worst case, a hung backend batch outlived even the grace window)
    pub clean: bool,
    /// requests answered with a typed failure while the drain ran
    /// (stragglers past the budget, plus any crash-path failures)
    pub stragglers: u64,
    /// crash log recovered from the backing (empty on a quiet drain)
    pub crashes: Vec<String>,
}

/// Result of one `Router::deploy`: the new version that went live, how
/// long warmup took, and — when a previous version existed — how its
/// drain went.
#[derive(Debug, Clone)]
pub struct SwapReport {
    /// model slot that was deployed into
    pub model: String,
    /// version number of the now-live deployment
    pub version: u64,
    /// replica count of the new fleet
    pub replicas: usize,
    /// wall-clock milliseconds to spawn + warm the new fleet (every
    /// replica completed one real forward before admission flipped)
    pub warmup_ms: f64,
    /// drain outcome of the replaced version (None on first deploy)
    pub drained: Option<DrainReport>,
}

/// One versioned replica fleet: the unit a hot swap replaces. Admission
/// goes through `handles` (emptied when the deployment is unhooked —
/// dropping the senders is what lets the workers drain and exit); the
/// per-slot stats outlive the drain so accounting spans the swap.
pub(crate) struct Deployment {
    version: u64,
    /// admission handles; a drain write-locks and clears this, which
    /// both stops new submits and drops the queue senders
    handles: RwLock<Vec<ReplicaHandle>>,
    /// per-slot stats, cloned out of the handles so they stay readable
    /// after the drain empties `handles`
    stats: Vec<Arc<ReplicaStats>>,
    /// shared fail-fast flag: tripped when a bounded drain exceeds its
    /// budget; workers and the supervisor then answer queued requests
    /// with typed `ReplicaFailed` instead of device work
    drain_now: Arc<AtomicBool>,
    /// joinable backing threads, taken exactly once by the drain
    backing: Mutex<Option<Backing>>,
    policy: ServePolicy,
}

impl Deployment {
    pub(crate) fn new(
        version: u64,
        handles: Vec<ReplicaHandle>,
        backing: Backing,
        drain_now: Arc<AtomicBool>,
        policy: ServePolicy,
    ) -> Self {
        let stats = handles.iter().map(|h| Arc::clone(&h.stats)).collect();
        Deployment {
            version,
            handles: RwLock::new(handles),
            stats,
            drain_now,
            backing: Mutex::new(Some(backing)),
            policy,
        }
    }

    pub(crate) fn version(&self) -> u64 {
        self.version
    }

    pub(crate) fn replicas(&self) -> usize {
        self.stats.len()
    }

    pub(crate) fn stats(&self, i: usize) -> Arc<ReplicaStats> {
        Arc::clone(&self.stats[i])
    }

    pub(crate) fn all_stats(&self) -> Vec<Arc<ReplicaStats>> {
        self.stats.iter().map(Arc::clone).collect()
    }

    /// Least-loaded replica whose circuit is not open; None when every
    /// breaker has tripped (or the deployment is already drained).
    pub(crate) fn pick(&self) -> Option<usize> {
        let handles = self.handles.read().expect("deployment handles lock poisoned");
        handles
            .iter()
            .enumerate()
            .filter(|(_, r)| r.stats.circuit() != CircuitState::Open)
            .min_by_key(|(_, r)| r.stats.outstanding.load(Ordering::SeqCst))
            .map(|(i, _)| i)
    }

    /// Queue-age feasibility: with `outstanding` requests ahead and the
    /// replica's observed mean batch time, can this deadline still be
    /// met? Replicas with no latency signal yet are assumed feasible.
    fn can_meet(&self, r: &ReplicaHandle, deadline: Instant, now: Instant) -> bool {
        let mean_us = r.stats.latency.mean_us();
        if mean_us <= 0.0 {
            return true;
        }
        let queued = r.stats.outstanding.load(Ordering::SeqCst);
        let batches = queued.div_ceil(self.policy.batch.max_batch.max(1)) + 1;
        let est = Duration::from_secs_f64(mean_us * 1e-6 * batches as f64)
            + self.policy.batch.max_wait;
        now + est <= deadline
    }

    /// Least-outstanding admission walk over this deployment's replicas
    /// (circuit filter → load sort → deadline feasibility → bounded
    /// `try_send`). Typed shed errors exactly as the router documents.
    pub(crate) fn submit_with_deadline(
        &self,
        mut x: Vec<f32>,
        deadline: Instant,
    ) -> Result<(Receiver<ServeResult>, usize), ServeError> {
        let now = Instant::now();
        if deadline <= now {
            return Err(ServeError::DeadlineExceeded { waited: Duration::ZERO });
        }
        let handles = self.handles.read().expect("deployment handles lock poisoned");
        if handles.is_empty() {
            return Err(ServeError::ReplicaFailed {
                reason: format!("model version v{} was drained", self.version),
            });
        }
        let mut order: Vec<usize> = (0..handles.len())
            .filter(|&i| handles[i].stats.circuit() != CircuitState::Open)
            .collect();
        if order.is_empty() {
            return Err(ServeError::ReplicaFailed {
                reason: "every replica circuit is open".into(),
            });
        }
        order.sort_by_key(|&i| handles[i].stats.outstanding.load(Ordering::SeqCst));
        let feasible: Vec<usize> = order
            .iter()
            .copied()
            .filter(|&i| self.can_meet(&handles[i], deadline, now))
            .collect();
        if feasible.is_empty() {
            // no backlog can meet this deadline: shed at the replica
            // that would otherwise have been picked, so the shed count
            // lands somewhere observable
            handles[order[0]].stats.shed.inc();
            return Err(ServeError::Overloaded { replicas: handles.len() });
        }
        for &i in &feasible {
            let r = &handles[i];
            let (rtx, rrx) = sync_channel(1);
            r.stats.outstanding.fetch_add(1, Ordering::SeqCst);
            match r.tx.try_send(InferRequest { x, deadline, submitted: now, resp: rtx }) {
                Ok(()) => return Ok((rrx, i)),
                Err(TrySendError::Full(req)) => {
                    r.stats.outstanding.fetch_sub(1, Ordering::SeqCst);
                    r.stats.shed.inc();
                    x = req.x;
                }
                Err(TrySendError::Disconnected(req)) => {
                    // never counted as load (the PR 6 leak fix)
                    r.stats.outstanding.fetch_sub(1, Ordering::SeqCst);
                    x = req.x;
                }
            }
        }
        Err(ServeError::Overloaded { replicas: handles.len() })
    }

    /// Gracefully drain this deployment, bounded by `timeout`:
    /// 1. unhook admission and drop the queue senders (in-flight submits
    ///    finish first — the write lock waits for them);
    /// 2. join the backing on a helper thread; queued requests finish on
    ///    the old plan, crashes still respawn;
    /// 3. past the budget, trip the fail-fast flag so workers and the
    ///    supervisor answer stragglers with typed `ReplicaFailed`, and
    ///    wait one more grace window;
    /// 4. if even that passes (a backend batch is hung), detach the
    ///    joiner — stragglers are still answered typed whenever the hung
    ///    batch returns, but the drain reports `clean: false`.
    ///
    /// Returns the report plus the count of *hard* failures (crashed
    /// unsupervised workers / a panicked supervisor) that legacy
    /// `shutdown` must surface as an error.
    pub(crate) fn drain(&self, timeout: Duration) -> (DrainReport, usize) {
        let t0 = Instant::now();
        let failed_before: u64 = self.stats.iter().map(|s| s.failed.get()).sum();
        self.handles.write().expect("deployment handles lock poisoned").clear();
        let backing = self.backing.lock().expect("deployment backing lock poisoned").take();
        let stragglers = |before: u64| -> u64 {
            let after: u64 = self.stats.iter().map(|s| s.failed.get()).sum();
            after.saturating_sub(before)
        };
        let Some(backing) = backing else {
            // already drained (e.g. retire after retire)
            let report = DrainReport {
                version: self.version,
                drain_ms: t0.elapsed().as_secs_f64() * 1e3,
                clean: true,
                stragglers: 0,
                crashes: Vec::new(),
            };
            return (report, 0);
        };
        let stats = self.all_stats();
        let (done_tx, done_rx) = channel();
        let joiner = std::thread::spawn(move || {
            let out = join_backing(backing, &stats);
            let _ = done_tx.send(out);
        });
        let grace = timeout.max(Duration::from_millis(50));
        let (outcome, clean) = match done_rx.recv_timeout(timeout) {
            Ok(out) => {
                let _ = joiner.join();
                (Some(out), true)
            }
            Err(_) => {
                // budget exceeded: fail-fast the rest, typed
                self.drain_now.store(true, Ordering::SeqCst);
                match done_rx.recv_timeout(grace) {
                    Ok(out) => {
                        let _ = joiner.join();
                        (Some(out), false)
                    }
                    Err(_) => (None, false), // detached: joiner keeps running
                }
            }
        };
        let (crashes, hard) = match outcome {
            Some((log, hard)) => (log, hard),
            None => (
                vec![format!(
                    "v{}: drain detached after {:?} + {:?} grace (hung backend batch?)",
                    self.version, timeout, grace
                )],
                0,
            ),
        };
        let report = DrainReport {
            version: self.version,
            drain_ms: t0.elapsed().as_secs_f64() * 1e3,
            clean,
            stragglers: stragglers(failed_before),
            crashes,
        };
        (report, hard)
    }
}

/// Join a deployment's backing threads. Returns the crash log and the
/// number of *hard* failures: unsupervised worker crashes (legacy
/// `Router::new` contract surfaces these as an error from `shutdown`)
/// or a panicked supervisor. Supervised crash-log entries are soft —
/// the supervisor already handled them.
fn join_backing(backing: Backing, stats: &[Arc<ReplicaStats>]) -> (Vec<String>, usize) {
    match backing {
        Backing::Supervised(sup) => match sup.join() {
            Ok(log) => (log, 0),
            Err(_) => (vec!["supervisor thread panicked".to_string()], 1),
        },
        Backing::Unsupervised(joins) => {
            let mut log = Vec::new();
            let mut hard = 0usize;
            for (i, j) in joins.into_iter().enumerate() {
                match j.join() {
                    Ok(exit) => {
                        if let Some(rx) = exit.rx {
                            let reason =
                                exit.crash.clone().unwrap_or_else(|| "replica crashed".into());
                            drain_unserved(rx, &stats[i], &reason);
                        }
                        if let Some(c) = exit.crash {
                            log.push(format!("replica {i}: {c}"));
                            hard += 1;
                        }
                    }
                    Err(_) => {
                        log.push(format!("replica {i}: thread panicked"));
                        hard += 1;
                    }
                }
            }
            (log, hard)
        }
    }
}

/// One named slot of the catalog: at most one live deployment plus the
/// slot's monotone version counter.
struct ModelSlot {
    current: RwLock<Option<Arc<Deployment>>>,
    next_version: AtomicU64,
    /// serializes deploys/retires on this slot (spawn+warm+flip+drain
    /// is not atomic; two racing deploys would drain each other)
    swap_lock: Mutex<()>,
}

impl ModelSlot {
    fn new() -> Self {
        ModelSlot {
            current: RwLock::new(None),
            next_version: AtomicU64::new(1),
            swap_lock: Mutex::new(()),
        }
    }

    fn current(&self) -> Option<Arc<Deployment>> {
        self.current.read().expect("slot lock poisoned").clone()
    }
}

/// Everything drained out of the catalog so far: stats stay absorbable
/// (bench aggregation, conservation accounting across swaps) and hard
/// failures stay reportable at shutdown.
#[derive(Default)]
struct RetiredLedger {
    stats: Vec<Arc<ReplicaStats>>,
    log: Vec<String>,
    hard_failures: usize,
}

/// Named model slots, each holding an `Arc`'d versioned deployment.
/// The router owns one catalog; every admission path resolves through
/// it, so flipping a slot's pointer atomically moves admission to the
/// new version.
pub(crate) struct ModelCatalog {
    slots: RwLock<BTreeMap<String, Arc<ModelSlot>>>,
    /// first model ever deployed — the target of unnamed `submit`s
    default_model: Mutex<Option<String>>,
    retired: Mutex<RetiredLedger>,
}

impl ModelCatalog {
    pub(crate) fn new() -> Self {
        ModelCatalog {
            slots: RwLock::new(BTreeMap::new()),
            default_model: Mutex::new(None),
            retired: Mutex::new(RetiredLedger::default()),
        }
    }

    fn slot_or_create(&self, name: &str) -> Arc<ModelSlot> {
        if let Some(s) = self.slots.read().expect("catalog lock poisoned").get(name) {
            return Arc::clone(s);
        }
        let mut slots = self.slots.write().expect("catalog lock poisoned");
        let slot = slots.entry(name.to_string()).or_insert_with(|| Arc::new(ModelSlot::new()));
        let mut def = self.default_model.lock().expect("default lock poisoned");
        if def.is_none() {
            *def = Some(name.to_string());
        }
        Arc::clone(slot)
    }

    fn slot(&self, name: &str) -> Option<Arc<ModelSlot>> {
        self.slots.read().expect("catalog lock poisoned").get(name).cloned()
    }

    /// The deployment `submit_to(name)` admits into right now.
    pub(crate) fn deployment(&self, name: &str) -> Result<Arc<Deployment>, ServeError> {
        let slot = self
            .slot(name)
            .ok_or_else(|| ServeError::UnknownModel { model: name.to_string() })?;
        slot.current().ok_or_else(|| ServeError::ReplicaFailed {
            reason: format!("model '{name}' is retired"),
        })
    }

    /// The default slot's deployment (legacy single-model API).
    pub(crate) fn default_deployment(&self) -> Result<Arc<Deployment>, ServeError> {
        let name = self
            .default_model
            .lock()
            .expect("default lock poisoned")
            .clone()
            .ok_or_else(|| ServeError::UnknownModel { model: "<none deployed>".to_string() })?;
        self.deployment(&name)
    }

    /// Install a pre-built deployment (the legacy constructors' path:
    /// no warmup, no old version to drain).
    pub(crate) fn install(&self, name: &str, dep: Deployment) {
        let slot = self.slot_or_create(name);
        slot.next_version.fetch_max(dep.version + 1, Ordering::SeqCst);
        *slot.current.write().expect("slot lock poisoned") = Some(Arc::new(dep));
    }

    /// Deploy a new version into `name`: spawn + warm the fleet off to
    /// the side, flip admission, drain the old version (bounded). The
    /// typed error contract: any construction/warmup failure aborts
    /// *before* the flip, so the old version never stops serving.
    pub(crate) fn deploy<B, F>(
        &self,
        name: &str,
        replicas: usize,
        factory: F,
        policy: ServePolicy,
    ) -> Result<SwapReport, ServeError>
    where
        B: InferBackend,
        F: Fn() -> Result<B> + Send + Sync + 'static,
    {
        if replicas == 0 {
            return Err(ServeError::WarmupFailed {
                model: name.to_string(),
                reason: "deploy needs at least one replica".into(),
            });
        }
        let slot = self.slot_or_create(name);
        let _swap = slot.swap_lock.lock().expect("swap lock poisoned");
        let version = slot.next_version.fetch_add(1, Ordering::SeqCst);
        let t0 = Instant::now();
        let drain_flag = Arc::new(AtomicBool::new(false));
        let (handles, sup) =
            spawn_supervised(replicas, factory, policy, true, Arc::clone(&drain_flag)).map_err(
                |e| ServeError::WarmupFailed { model: name.to_string(), reason: format!("{e:#}") },
            )?;
        let warmup_ms = t0.elapsed().as_secs_f64() * 1e3;
        let dep = Arc::new(Deployment::new(
            version,
            handles,
            Backing::Supervised(sup),
            drain_flag,
            policy,
        ));
        // the flip: admission atomically moves to the new version
        let old = slot.current.write().expect("slot lock poisoned").replace(dep);
        let drained = old.map(|old| self.drain_and_retire(&old, policy.drain_timeout));
        Ok(SwapReport { model: name.to_string(), version, replicas, warmup_ms, drained })
    }

    /// Drain `name`'s live deployment without a replacement. Subsequent
    /// submits to the slot answer typed `ReplicaFailed` ("retired").
    pub(crate) fn retire(
        &self,
        name: &str,
        timeout: Duration,
    ) -> Result<DrainReport, ServeError> {
        let slot = self
            .slot(name)
            .ok_or_else(|| ServeError::UnknownModel { model: name.to_string() })?;
        let _swap = slot.swap_lock.lock().expect("swap lock poisoned");
        let old = slot.current.write().expect("slot lock poisoned").take();
        let old = old.ok_or_else(|| ServeError::ReplicaFailed {
            reason: format!("model '{name}' is already retired"),
        })?;
        Ok(self.drain_and_retire(&old, timeout))
    }

    fn drain_and_retire(&self, old: &Arc<Deployment>, timeout: Duration) -> DrainReport {
        let (report, hard) = old.drain(timeout);
        let mut ledger = self.retired.lock().expect("ledger lock poisoned");
        ledger.stats.extend(old.all_stats());
        ledger.log.extend(report.crashes.iter().cloned());
        ledger.hard_failures += hard;
        report
    }

    /// Every deployed model name with its live version (None = retired).
    pub(crate) fn models(&self) -> Vec<(String, Option<u64>)> {
        self.slots
            .read()
            .expect("catalog lock poisoned")
            .iter()
            .map(|(name, slot)| (name.clone(), slot.current().map(|d| d.version())))
            .collect()
    }

    /// Stats of every live replica plus everything already retired —
    /// the set bench aggregation absorbs so accounting spans swaps.
    pub(crate) fn all_stats(&self) -> Vec<Arc<ReplicaStats>> {
        let mut out: Vec<Arc<ReplicaStats>> = Vec::new();
        for slot in self.slots.read().expect("catalog lock poisoned").values() {
            if let Some(dep) = slot.current() {
                out.extend(dep.all_stats());
            }
        }
        out.extend(
            self.retired.lock().expect("ledger lock poisoned").stats.iter().map(Arc::clone),
        );
        out
    }

    /// Drain every live deployment and fold in the retired ledger.
    /// Returns the full crash log and the hard-failure count the router
    /// turns into `shutdown`'s error contract.
    pub(crate) fn shutdown(self, timeout: Duration) -> (Vec<String>, usize) {
        let slots = std::mem::take(&mut *self.slots.write().expect("catalog lock poisoned"));
        let mut log = Vec::new();
        let mut hard = 0usize;
        for slot in slots.into_values() {
            let old = slot.current.write().expect("slot lock poisoned").take();
            if let Some(dep) = old {
                let (report, h) = dep.drain(timeout);
                log.extend(report.crashes);
                hard += h;
            }
        }
        let ledger = std::mem::take(&mut *self.retired.lock().expect("ledger lock poisoned"));
        // retired-ledger entries precede this shutdown chronologically
        let mut full = ledger.log;
        full.extend(log);
        (full, hard + ledger.hard_failures)
    }
}
