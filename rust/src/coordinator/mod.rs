//! Serving coordinator (S7): request router + dynamic batcher + model
//! workers over the PJRT runtime. Pure std threads/channels (tokio is not
//! in the offline vendor set); the architecture mirrors a vLLM-style
//! router: clients submit single-sample requests, a batcher groups them
//! under a size/deadline policy, workers run the AOT infer executable,
//! and a router spreads load across replicas.
//!
//! PLUM integration: each worker serves a *quantized* model artifact —
//! the signed-binary infer HLO whose hot path is the L1 Pallas kernel —
//! and the registry reports the packed one-bit footprint (S2's
//! `PackedSignedBinary`) so deployment density matches the paper's
//! bit-accounting.

mod batcher;
#[cfg(feature = "pjrt")]
mod pjrt;
mod registry;
mod router;
mod server;

pub use batcher::{BatchPolicy, Batcher};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use registry::{ModelEntry, ModelRegistry};
pub use router::Router;
pub use server::{spawn_worker, InferBackend, InferRequest, MockBackend, WorkerHandle};
