//! Serving coordinator (S7): request router + dynamic batcher + model
//! workers over a pluggable [`InferBackend`]. Pure std threads/channels
//! (tokio is not in the offline vendor set); the architecture mirrors a
//! vLLM-style router: clients submit single-sample requests, a batcher
//! groups them under a size/deadline policy, workers run the backend,
//! and a router spreads load across replicas.
//!
//! Backends: `network::EngineBackend` serves whole models compiled onto
//! the repetition engine on plain CPU (the default, no features);
//! [`PjrtBackend`] (feature `pjrt`) runs the AOT infer executable;
//! [`MockBackend`] keeps the batching/routing invariants property-
//! testable in isolation.
//!
//! PLUM integration: each worker serves a *quantized* model — the
//! engine path executes the signed-binary plans directly — and the
//! registry reports the packed one-bit footprint (S2's
//! `PackedSignedBinary`) so deployment density matches the paper's
//! bit-accounting.

mod batcher;
#[cfg(feature = "pjrt")]
mod pjrt;
mod registry;
mod router;
mod server;

pub use batcher::{BatchPolicy, Batcher};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use registry::{ModelEntry, ModelRegistry};
pub use router::Router;
pub use server::{spawn_worker, InferBackend, InferRequest, MockBackend, WorkerHandle};
