//! Serving coordinator (S7): request router + dynamic batcher + model
//! workers over a pluggable [`InferBackend`]. Pure std threads/channels
//! (tokio is not in the offline vendor set); the architecture mirrors a
//! vLLM-style router: clients submit single-sample requests, a batcher
//! groups them under a size/deadline policy, workers run the backend,
//! and a router spreads load across replicas.
//!
//! Backends: `network::EngineBackend` serves whole models compiled onto
//! the repetition engine on plain CPU (the default, no features);
//! [`PjrtBackend`] (feature `pjrt`) runs the AOT infer executable;
//! [`MockBackend`] keeps the batching/routing invariants property-
//! testable in isolation.
//!
//! PLUM integration: each worker serves a *quantized* model — the
//! engine path executes the signed-binary plans directly — and the
//! registry reports the packed one-bit footprint (S2's
//! `PackedSignedBinary`) so deployment density matches the paper's
//! bit-accounting.
//!
//! Serving hardening (see ARCHITECTURE.md "Serving robustness"):
//! admission is *bounded* (per-replica queues of
//! [`ServePolicy::queue_depth`]; saturation sheds typed
//! [`ServeError::Overloaded`]), every request carries an absolute
//! *deadline* (expired requests are answered
//! [`ServeError::DeadlineExceeded`] before costing a device batch), and
//! `Router::spawn` runs replicas under a *supervisor* that respawns
//! crashed generations on the same queue with capped exponential
//! backoff, tripping a per-replica circuit breaker after repeated
//! failures. [`FlakyBackend`] injects deterministic faults to chaos-test
//! the whole stack (rust/tests/chaos_serving.rs).
//!
//! Model lifecycle (see ARCHITECTURE.md "Model lifecycle"): the router
//! fronts a versioned model catalog — named slots, each holding an
//! `Arc`'d deployment. [`Router::deploy`] hot-swaps a slot with zero
//! downtime: the next version is spawned and *warmed* off to the side
//! (a failed warmup aborts with [`ServeError::WarmupFailed`] and the
//! old version keeps serving), admission flips atomically, and the old
//! generation drains gracefully bounded by
//! [`ServePolicy::drain_timeout`] — stragglers are answered typed,
//! never silently dropped, so the conservation invariant holds *across*
//! a swap. The deadline-aware [`Batcher`] orders each device batch
//! earliest-deadline-first and re-checks expiry at flush time, so a
//! retiring or busy replica never spends device time on a request that
//! is already past its deadline.

mod batcher;
mod error;
mod fault;
mod lifecycle;
#[cfg(feature = "pjrt")]
mod pjrt;
mod registry;
mod router;
mod server;
mod supervisor;

pub use batcher::{BatchPolicy, Batcher, Urgent};
pub use error::{ServeError, ServePolicy, ServeResult};
pub use fault::{flaky_factory, FlakyBackend};
pub use lifecycle::{DrainReport, SwapReport, DEFAULT_MODEL};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use registry::{ModelEntry, ModelRegistry};
pub use router::Router;
pub use server::{
    spawn_worker, CircuitState, InferBackend, InferRequest, MockBackend, ReplicaStats,
    WorkerExit, WorkerHandle,
};
