//! Dynamic batching: collect requests up to `max_batch` or until
//! `max_wait` has elapsed since the oldest live request was *enqueued* —
//! the standard size-or-deadline policy (vLLM/Triton style), made
//! deadline-aware: the live batch is ordered earliest-deadline-first and
//! expiry is re-checked at flush time, so a request that aged out inside
//! the fill window never reaches the device.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// flush when this many requests are queued
    pub max_batch: usize,
    /// flush when the oldest queued request has waited this long
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// What the deadline-aware batcher needs to know about a request:
/// when it must be answered by and when it entered the queue.
pub trait Urgent {
    /// Absolute deadline; at or past it the request is expired.
    fn deadline(&self) -> Instant;
    /// When the request was enqueued. The flush timer is anchored here,
    /// not at pull time, so queue time counts against `max_wait`.
    fn enqueued(&self) -> Instant;
}

/// Pulls batches off an mpsc receiver under the policy.
pub struct Batcher<T> {
    rx: Receiver<T>,
    /// the size-or-deadline policy this batcher flushes under
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    /// Batcher over a request receiver (`policy.max_batch` must be > 0).
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        assert!(policy.max_batch > 0);
        Batcher { rx, policy }
    }

    /// Block for the next batch with plain FIFO size-or-wait semantics
    /// (no deadlines; the flush timer starts at pull). Returns None when
    /// all senders dropped and the queue is drained. Production serving
    /// uses [`Batcher::next_batch_partitioned`], which is deadline-aware.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        let first = self.rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(v) => batch.push(v),
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }

    /// Give the receiver back (used when a crashed worker generation
    /// hands its queue to the supervisor for respawn-in-place).
    pub fn into_inner(self) -> Receiver<T> {
        self.rx
    }
}

impl<T: Urgent> Batcher<T> {
    /// Block for the next batch, splitting expired requests (deadline at
    /// or past now) off so the caller can answer them without spending
    /// device time. Only *live* requests count toward `max_batch`; the
    /// returned live set may be empty when everything pulled this round
    /// had already expired. Returns None when all senders dropped and
    /// the queue is drained.
    ///
    /// Deadline-aware semantics:
    /// * the flush timer is anchored at the oldest live request's
    ///   *enqueue* instant (`enqueued() + max_wait`), so a request never
    ///   waits queue-time *plus* `max_wait` — once its window has passed,
    ///   whatever is instantly available is swept and flushed;
    /// * expiry is re-checked at flush time: a request that aged out
    ///   while the batch was filling moves to the dead set;
    /// * the live batch is ordered earliest-deadline-first (stable, so
    ///   equal deadlines keep arrival order).
    pub fn next_batch_partitioned(&self) -> Option<(Vec<T>, Vec<T>)> {
        let first = match self.rx.recv() {
            Ok(v) => v,
            Err(_) => return None,
        };
        let mut live: Vec<T> = Vec::new();
        let mut dead: Vec<T> = Vec::new();
        // provisional anchor: the first pulled request is the oldest in
        // the FIFO channel; re-anchored to the first *live* request when
        // one appears (enqueue times are non-decreasing, so that only
        // extends the window)
        let mut flush = first.enqueued() + self.policy.max_wait;
        let mut have_live = false;
        fn classify<T: Urgent>(v: T, live: &mut Vec<T>, dead: &mut Vec<T>) -> bool {
            if v.deadline() <= Instant::now() {
                dead.push(v);
                false
            } else {
                live.push(v);
                true
            }
        }
        if classify(first, &mut live, &mut dead) {
            have_live = true;
        }
        while live.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= flush {
                // window over: sweep whatever is instantly available
                // (fills the batch when the queue aged past max_wait
                // before we ever pulled), then flush without waiting
                while live.len() < self.policy.max_batch {
                    match self.rx.try_recv() {
                        Ok(v) => {
                            classify(v, &mut live, &mut dead);
                        }
                        Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                    }
                }
                break;
            }
            match self.rx.recv_timeout(flush - now) {
                Ok(v) => {
                    if classify(v, &mut live, &mut dead) && !have_live {
                        have_live = true;
                        flush = live[0].enqueued() + self.policy.max_wait;
                    }
                }
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // flush-time re-check: requests that aged out while the batch
        // filled must not reach the device
        let now = Instant::now();
        let mut i = 0;
        while i < live.len() {
            if live[i].deadline() <= now {
                dead.push(live.remove(i));
            } else {
                i += 1;
            }
        }
        // earliest deadline first into the device batch (stable: equal
        // deadlines keep arrival order)
        live.sort_by_key(Urgent::deadline);
        Some((live, dead))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    /// Minimal deadline-carrying request for batcher tests.
    #[derive(Debug, PartialEq)]
    struct Req {
        id: u32,
        enqueued: Instant,
        deadline: Instant,
    }

    impl Req {
        fn live(id: u32) -> Req {
            let now = Instant::now();
            Req { id, enqueued: now, deadline: now + Duration::from_secs(60) }
        }

        fn expired(id: u32) -> Req {
            let now = Instant::now();
            Req { id, enqueued: now, deadline: now - Duration::from_millis(1) }
        }
    }

    impl Urgent for Req {
        fn deadline(&self) -> Instant {
            self.deadline
        }
        fn enqueued(&self) -> Instant {
            self.enqueued
        }
    }

    fn ids(v: &[Req]) -> Vec<u32> {
        v.iter().map(|r| r.id).collect()
    }

    #[test]
    fn batches_respect_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) });
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let b = Batcher::new(rx, BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn none_after_disconnect() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
        let (tx, rx) = channel::<Req>();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch_partitioned().is_none());
    }

    #[test]
    fn partitioned_splits_expired_without_counting_them() {
        let (tx, rx) = channel();
        for i in 0..8 {
            // odd ids expired: they must not occupy live batch slots
            tx.send(if i % 2 == 1 { Req::expired(i) } else { Req::live(i) }).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) });
        let (live, dead) = b.next_batch_partitioned().unwrap();
        assert_eq!(ids(&live), vec![0, 2, 4, 6]);
        assert_eq!(ids(&dead), vec![1, 3, 5]);
    }

    #[test]
    fn partitioned_returns_even_when_all_expired() {
        let (tx, rx) = channel();
        tx.send(Req::expired(1)).unwrap();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) });
        let (live, dead) = b.next_batch_partitioned().unwrap();
        assert!(live.is_empty());
        assert_eq!(ids(&dead), vec![1]);
        assert!(b.next_batch_partitioned().is_none());
    }

    #[test]
    fn into_inner_returns_the_queue() {
        let (tx, rx) = channel();
        tx.send(5).unwrap();
        let b = Batcher::new(rx, BatchPolicy::default());
        let rx = b.into_inner();
        assert_eq!(rx.recv().unwrap(), 5);
    }

    #[test]
    fn drains_everything() {
        let (tx, rx) = channel();
        for i in 0..23 {
            tx.send(Req::live(i)).unwrap();
        }
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy { max_batch: 5, max_wait: Duration::from_millis(1) });
        let mut seen = 0;
        while let Some((live, dead)) = b.next_batch_partitioned() {
            assert!(live.len() <= 5);
            assert!(dead.is_empty());
            seen += live.len();
        }
        assert_eq!(seen, 23);
    }

    #[test]
    fn flush_anchored_to_enqueue_not_pull() {
        // regression: the flush timer used to start when the batcher
        // *pulled* the first element, so a pre-filled queue waited
        // queue-time + max_wait. With the anchor at enqueue, requests
        // whose window already passed flush immediately — and the sweep
        // still collects everything instantly available into one batch.
        let wait = Duration::from_millis(200);
        let (tx, rx) = channel();
        let old = Instant::now() - 10 * wait; // enqueued long ago
        for i in 0..5 {
            tx.send(Req { id: i, enqueued: old, deadline: old + Duration::from_secs(60) })
                .unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 64, max_wait: wait });
        let t0 = Instant::now();
        let (live, dead) = b.next_batch_partitioned().unwrap();
        assert_eq!(ids(&live), vec![0, 1, 2, 3, 4]);
        assert!(dead.is_empty());
        assert!(
            t0.elapsed() < wait,
            "aged queue must flush immediately, waited {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn live_batch_is_earliest_deadline_first() {
        let (tx, rx) = channel();
        let now = Instant::now();
        for (id, ms) in [(0u32, 300u64), (1, 100), (2, 200)] {
            tx.send(Req { id, enqueued: now, deadline: now + Duration::from_millis(ms) })
                .unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(5) });
        let (live, dead) = b.next_batch_partitioned().unwrap();
        assert!(dead.is_empty());
        assert_eq!(ids(&live), vec![1, 2, 0], "live batch must be EDF-ordered");
    }

    #[test]
    fn expiry_rechecked_at_flush_time() {
        // one request whose deadline falls inside the fill window: by
        // the time the batch flushes (nothing else arrives) it has
        // expired and must move to the dead set, not reach the device
        let (tx, rx) = channel();
        let now = Instant::now();
        tx.send(Req { id: 9, enqueued: now, deadline: now + Duration::from_millis(5) })
            .unwrap();
        let b =
            Batcher::new(rx, BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(40) });
        let (live, dead) = b.next_batch_partitioned().unwrap();
        assert!(live.is_empty(), "request expired mid-window must not stay live");
        assert_eq!(ids(&dead), vec![9]);
    }
}
