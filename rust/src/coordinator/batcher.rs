//! Dynamic batching: collect requests up to `max_batch` or until
//! `max_wait` has elapsed since the first queued request — the standard
//! size-or-deadline policy (vLLM/Triton style).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// flush when this many requests are queued
    pub max_batch: usize,
    /// flush when the oldest queued request has waited this long
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Pulls batches off an mpsc receiver under the policy.
pub struct Batcher<T> {
    rx: Receiver<T>,
    /// the size-or-deadline policy this batcher flushes under
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    /// Batcher over a request receiver (`policy.max_batch` must be > 0).
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        assert!(policy.max_batch > 0);
        Batcher { rx, policy }
    }

    /// Block for the next batch. Returns None when all senders dropped
    /// and the queue is drained.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        self.next_batch_partitioned(|_| false).map(|(live, _)| live)
    }

    /// Block for the next batch, splitting off requests for which
    /// `expired` holds (e.g. past their deadline) so the caller can
    /// answer them without spending device time. Only *live* requests
    /// count toward `max_batch`; the returned live set may be empty when
    /// everything pulled this round had already expired. Returns None
    /// when all senders dropped and the queue is drained.
    pub fn next_batch_partitioned<F>(&self, expired: F) -> Option<(Vec<T>, Vec<T>)>
    where
        F: Fn(&T) -> bool,
    {
        // block for the first element
        let first = match self.rx.recv() {
            Ok(v) => v,
            Err(_) => return None,
        };
        let mut live = Vec::new();
        let mut dead = Vec::new();
        if expired(&first) {
            dead.push(first);
        } else {
            live.push(first);
        }
        let deadline = Instant::now() + self.policy.max_wait;
        while live.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(v) => {
                    if expired(&v) {
                        dead.push(v);
                    } else {
                        live.push(v);
                    }
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some((live, dead))
    }

    /// Give the receiver back (used when a crashed worker generation
    /// hands its queue to the supervisor for respawn-in-place).
    pub fn into_inner(self) -> Receiver<T> {
        self.rx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_respect_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) });
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let b = Batcher::new(rx, BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn none_after_disconnect() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn partitioned_splits_expired_without_counting_them() {
        let (tx, rx) = channel();
        for i in 0..8 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) });
        // odd values "expired": they must not occupy live batch slots
        let (live, dead) = b.next_batch_partitioned(|v| v % 2 == 1).unwrap();
        assert_eq!(live, vec![0, 2, 4, 6]);
        assert_eq!(dead, vec![1, 3, 5]);
    }

    #[test]
    fn partitioned_returns_even_when_all_expired() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) });
        let (live, dead) = b.next_batch_partitioned(|_| true).unwrap();
        assert!(live.is_empty());
        assert_eq!(dead, vec![1]);
        assert!(b.next_batch_partitioned(|_| true).is_none());
    }

    #[test]
    fn into_inner_returns_the_queue() {
        let (tx, rx) = channel();
        tx.send(5).unwrap();
        let b = Batcher::new(rx, BatchPolicy::default());
        let rx = b.into_inner();
        assert_eq!(rx.recv().unwrap(), 5);
    }

    #[test]
    fn drains_everything() {
        let (tx, rx) = channel();
        for i in 0..23 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy { max_batch: 5, max_wait: Duration::from_millis(1) });
        let mut seen = 0;
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() <= 5);
            seen += batch.len();
        }
        assert_eq!(seen, 23);
    }
}
