//! Model registry: discover artifacts in a directory, report deployment
//! footprints (packed one-bit weights for sb — the paper's §6 R*S*C*K+K
//! bit accounting), and select models by scheme.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::runtime::Manifest;

/// One registered model artifact set.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    /// artifact name, e.g. `resnet20_sb`
    pub name: String,
    /// quantization scheme string from the manifest
    pub scheme: String,
    /// architecture name from the manifest
    pub arch: String,
    /// device batch size the artifact was lowered at
    pub batch_size: usize,
    /// total parameter count
    pub param_count: usize,
    /// effectual (non-zero quantized) parameters at init
    pub effectual_params_init: usize,
    /// one-bit packed weight bits for sb models (paper §6 formula);
    /// 32-bit dense bits otherwise.
    pub weight_bits: usize,
}

/// Registry over an artifact directory.
#[derive(Debug)]
pub struct ModelRegistry {
    /// the scanned directory
    pub dir: PathBuf,
    /// discovered artifacts, name-sorted
    pub entries: Vec<ModelEntry>,
    /// manifests that matched the glob but failed to load, as
    /// `(name, error)` — a truncated or corrupt manifest must surface
    /// as a diagnostic, not silently shrink the catalog
    pub errors: Vec<(String, String)>,
}

impl ModelRegistry {
    /// Scan `dir` for `*.manifest.json` and build entries. Manifests
    /// that fail to parse are reported in [`ModelRegistry::errors`]
    /// (and logged to stderr) instead of being silently skipped.
    pub fn scan(dir: &Path) -> Result<ModelRegistry> {
        let mut entries = Vec::new();
        let mut errors = Vec::new();
        if dir.exists() {
            let mut names: Vec<String> = std::fs::read_dir(dir)?
                .filter_map(|e| e.ok())
                .filter_map(|e| {
                    let f = e.file_name().into_string().ok()?;
                    f.strip_suffix(".manifest.json").map(str::to_string)
                })
                .collect();
            names.sort();
            for name in names {
                match Manifest::load(dir, &name) {
                    Ok(man) => entries.push(Self::entry_from_manifest(&man)),
                    Err(e) => {
                        let msg = format!("{e:#}");
                        eprintln!(
                            "registry: skipping unloadable manifest '{name}' in {}: {msg}",
                            dir.display()
                        );
                        errors.push((name, msg));
                    }
                }
            }
        }
        Ok(ModelRegistry { dir: dir.to_path_buf(), entries, errors })
    }

    fn entry_from_manifest(man: &Manifest) -> ModelEntry {
        let quantized_weights: usize = man
            .conv_layers
            .iter()
            .filter(|l| l.quantized)
            .map(|l| l.geom.weight_count())
            .sum();
        let regions: usize = man
            .conv_layers
            .iter()
            .filter(|l| l.quantized)
            .map(|l| l.geom.k * man.config.regions_per_filter)
            .sum();
        let weight_bits = match man.config.scheme.as_str() {
            // paper §6: R*S*C*K bits + K region-sign bits
            "sb" => quantized_weights + regions,
            "binary" => quantized_weights,
            "ternary" => 2 * quantized_weights,
            _ => 32 * man.param_count,
        };
        ModelEntry {
            name: man.name.clone(),
            scheme: man.config.scheme.clone(),
            arch: man.config.arch.clone(),
            batch_size: man.config.batch_size,
            param_count: man.param_count,
            effectual_params_init: man.effectual_params_init,
            weight_bits,
        }
    }

    /// Entry with exactly this name, if registered.
    pub fn by_name(&self, name: &str) -> Option<&ModelEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All entries quantized under `scheme`.
    pub fn by_scheme(&self, scheme: &str) -> Vec<&ModelEntry> {
        self.entries.iter().filter(|e| e.scheme == scheme).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_missing_dir_is_empty() {
        let r = ModelRegistry::scan(Path::new("/nonexistent/plum")).unwrap();
        assert!(r.entries.is_empty());
    }

    #[test]
    fn scan_artifacts_if_present() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        // gate on what scan actually globs (*.manifest.json), not on a
        // legacy index.json that no artifact writer produces
        let has_manifest = std::fs::read_dir(&dir)
            .map(|d| {
                d.filter_map(|e| e.ok())
                    .any(|e| e.file_name().to_string_lossy().ends_with(".manifest.json"))
            })
            .unwrap_or(false);
        if !has_manifest {
            return;
        }
        let r = ModelRegistry::scan(&dir).unwrap();
        assert!(!r.entries.is_empty());
        let sb = r.by_scheme("sb");
        assert!(!sb.is_empty());
        // sb one-bit footprint beats ternary's 2 bits for same geometry
        if let (Some(s), Some(t)) = (r.by_name("resnet20_sb"), r.by_name("resnet20_ternary")) {
            assert!(s.weight_bits < t.weight_bits);
        }
    }

    #[test]
    fn scan_reports_unloadable_manifest_instead_of_swallowing_it() {
        let dir = std::env::temp_dir().join(format!("plum_registry_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // a truncated manifest: matches the glob, fails to parse
        std::fs::write(dir.join("broken.manifest.json"), "{\"name\": \"broken\", \"co").unwrap();
        let r = ModelRegistry::scan(&dir).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
        assert!(r.entries.is_empty());
        assert_eq!(r.errors.len(), 1, "errors: {:?}", r.errors);
        assert_eq!(r.errors[0].0, "broken");
        assert!(!r.errors[0].1.is_empty());
    }
}
