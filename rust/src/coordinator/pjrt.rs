//! PJRT-backed inference backend for the coordinator (`pjrt` feature).

use anyhow::Result;

use crate::runtime::{literal_f32, literal_to_f32, ModelHandle, Runtime, TensorSpec};

use super::server::InferBackend;

/// PJRT-backed backend: infer executable + resident state literals.
pub struct PjrtBackend {
    model: ModelHandle,
    state: Vec<xla::Literal>,
    sample: usize,
    out: usize,
}

impl PjrtBackend {
    /// A `Send` factory for `spawn_worker` / `Router::spawn`: creates
    /// the PJRT client and compiles the artifact inside the worker
    /// thread. Re-callable (`Fn`) so the supervisor can rebuild a
    /// crashed replica from the same artifacts.
    pub fn factory(
        dir: std::path::PathBuf,
        name: String,
        checkpoint: Option<std::path::PathBuf>,
    ) -> impl Fn() -> Result<PjrtBackend> + Send + Sync + 'static {
        move || {
            let rt = Runtime::cpu()?;
            PjrtBackend::load(&rt, &dir, &name, checkpoint.as_deref())
        }
    }

    /// Load from artifacts; state comes from `params.bin` or, if given,
    /// a trained checkpoint.
    pub fn load(
        rt: &Runtime,
        dir: &std::path::Path,
        name: &str,
        checkpoint: Option<&std::path::Path>,
    ) -> Result<PjrtBackend> {
        let model = ModelHandle::load(rt, dir, name, false)?;
        let host: Vec<(TensorSpec, Vec<f32>)> = match checkpoint {
            Some(p) => crate::training::load_checkpoint(p)?.1,
            None => model.manifest.load_initial_state()?,
        };
        let state = host
            .iter()
            .map(|(spec, data)| literal_f32(&spec.shape, data))
            .collect::<Result<Vec<_>>>()?;
        let cfg = &model.manifest.config;
        let sample = cfg.in_channels * cfg.image_size * cfg.image_size;
        let out = cfg.num_classes;
        Ok(PjrtBackend { model, state, sample, out })
    }
}

impl InferBackend for PjrtBackend {
    fn batch_size(&self) -> usize {
        self.model.manifest.config.batch_size
    }

    fn sample_elems(&self) -> usize {
        self.sample
    }

    fn out_elems(&self) -> usize {
        self.out
    }

    fn infer_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        let cfg = &self.model.manifest.config;
        let bs = cfg.batch_size;
        assert_eq!(x.len(), bs * self.sample);
        let xl = literal_f32(
            &[bs, cfg.in_channels, cfg.image_size, cfg.image_size],
            x,
        )?;
        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        inputs.push(&xl);
        let outs = self.model.infer(&inputs)?;
        literal_to_f32(&outs[0])
    }
}
