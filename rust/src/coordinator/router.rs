//! Least-outstanding-requests router over model replicas, with bounded
//! admission, deadline-feasibility routing, and circuit awareness.
//!
//! Admission contract: `submit` never blocks and never queues beyond
//! each replica's bounded depth. It walks the non-open replicas from
//! least to most loaded and `try_send`s; if every candidate is full the
//! request is shed with a typed [`ServeError::Overloaded`]. A replica
//! whose queue-age signal (outstanding x mean batch time) says the
//! deadline cannot be met is skipped *before* its queue is touched, so
//! doomed requests are shed at admission instead of expiring inside a
//! worker.
//!
//! Two backings: [`Router::spawn`] runs replicas under the supervisor
//! (crash respawn + breakers — the production path), while
//! [`Router::new`] wraps caller-spawned [`WorkerHandle`]s (no respawn;
//! crashes surface as an aggregate error from `shutdown`).

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::error::{ServeError, ServePolicy, ServeResult};
use super::server::{
    drain_unserved, CircuitState, InferBackend, InferRequest, ReplicaHandle, ReplicaStats,
    WorkerExit, WorkerHandle,
};
use super::supervisor::spawn_supervised;

/// What stands behind the router's replica slots.
enum Backing {
    /// caller-spawned workers; shutdown joins each generation directly
    Unsupervised(Vec<JoinHandle<WorkerExit>>),
    /// supervisor thread owns the generations; shutdown joins it and
    /// returns its crash log
    Supervised(JoinHandle<Vec<String>>),
}

/// Routes single-sample requests to the replica with the fewest
/// outstanding requests (ties -> lowest index, which keeps routing
/// deterministic for tests), skipping replicas whose circuit breaker is
/// open or whose backlog makes the request's deadline infeasible.
pub struct Router {
    replicas: Vec<ReplicaHandle>,
    policy: ServePolicy,
    backing: Backing,
}

impl Router {
    /// Router over caller-spawned workers (non-empty). All workers are
    /// assumed to share one [`ServePolicy`] (the first one's is used for
    /// default deadlines and feasibility math).
    pub fn new(workers: Vec<WorkerHandle>) -> Self {
        assert!(!workers.is_empty());
        let policy = workers[0].policy;
        let mut replicas = Vec::with_capacity(workers.len());
        let mut joins = Vec::with_capacity(workers.len());
        for w in workers {
            replicas.push(ReplicaHandle { tx: w.tx, stats: w.stats });
            joins.push(w.join);
        }
        Router { replicas, policy, backing: Backing::Unsupervised(joins) }
    }

    /// Spawn `replicas` *supervised* replica slots sharing one backend
    /// factory: crashed replicas are respawned on the same queue with
    /// capped exponential backoff, and repeated failures trip a
    /// per-replica circuit breaker the router routes around.
    pub fn spawn<B, F>(replicas: usize, factory: F, policy: ServePolicy) -> Result<Self>
    where
        B: InferBackend,
        F: Fn() -> Result<B> + Send + Sync + 'static,
    {
        anyhow::ensure!(replicas > 0, "router needs at least one replica");
        let (handles, sup) = spawn_supervised(replicas, factory, policy)?;
        Ok(Router { replicas: handles, policy, backing: Backing::Supervised(sup) })
    }

    /// Number of replicas behind this router.
    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Stats of replica `i` (load / shed / latency / circuit).
    pub fn stats(&self, i: usize) -> &ReplicaStats {
        &self.replicas[i].stats
    }

    /// The policy admission and batching run under.
    pub fn policy(&self) -> ServePolicy {
        self.policy
    }

    /// Least-loaded replica whose circuit is not open; None when every
    /// breaker has tripped.
    pub fn pick(&self) -> Option<usize> {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.stats.circuit() != CircuitState::Open)
            .min_by_key(|(_, r)| r.stats.outstanding.load(Ordering::SeqCst))
            .map(|(i, _)| i)
    }

    /// Queue-age feasibility: with `outstanding` requests ahead and the
    /// replica's observed mean batch time, can this deadline still be
    /// met? Replicas with no latency signal yet are assumed feasible.
    fn can_meet(&self, r: &ReplicaHandle, deadline: Instant, now: Instant) -> bool {
        let mean_us = r.stats.latency.mean_us();
        if mean_us <= 0.0 {
            return true;
        }
        let queued = r.stats.outstanding.load(Ordering::SeqCst);
        let batches = queued.div_ceil(self.policy.batch.max_batch.max(1)) + 1;
        let est = Duration::from_secs_f64(mean_us * 1e-6 * batches as f64)
            + self.policy.batch.max_wait;
        now + est <= deadline
    }

    /// Submit a request under the policy's default deadline; returns the
    /// reply receiver and the replica used.
    pub fn submit(&self, x: Vec<f32>) -> Result<(Receiver<ServeResult>, usize), ServeError> {
        self.submit_with_deadline(x, Instant::now() + self.policy.default_deadline)
    }

    /// Submit a request with an explicit absolute deadline. Sheds typed
    /// and synchronously when the request cannot be admitted: every
    /// circuit open -> `ReplicaFailed`; deadline already passed ->
    /// `DeadlineExceeded`; no replica can meet the deadline or every
    /// candidate queue is full -> `Overloaded` (counted per replica in
    /// [`ReplicaStats::shed`]).
    pub fn submit_with_deadline(
        &self,
        mut x: Vec<f32>,
        deadline: Instant,
    ) -> Result<(Receiver<ServeResult>, usize), ServeError> {
        let now = Instant::now();
        if deadline <= now {
            return Err(ServeError::DeadlineExceeded { waited: Duration::ZERO });
        }
        let mut order: Vec<usize> = (0..self.replicas.len())
            .filter(|&i| self.replicas[i].stats.circuit() != CircuitState::Open)
            .collect();
        if order.is_empty() {
            return Err(ServeError::ReplicaFailed {
                reason: "every replica circuit is open".into(),
            });
        }
        order.sort_by_key(|&i| self.replicas[i].stats.outstanding.load(Ordering::SeqCst));
        let feasible: Vec<usize> = order
            .iter()
            .copied()
            .filter(|&i| self.can_meet(&self.replicas[i], deadline, now))
            .collect();
        if feasible.is_empty() {
            // no backlog can meet this deadline: shed at the replica
            // that would otherwise have been picked, so the shed count
            // lands somewhere observable
            self.replicas[order[0]].stats.shed.inc();
            return Err(ServeError::Overloaded { replicas: self.replicas.len() });
        }
        for &i in &feasible {
            let r = &self.replicas[i];
            let (rtx, rrx) = sync_channel(1);
            r.stats.outstanding.fetch_add(1, Ordering::SeqCst);
            match r.tx.try_send(InferRequest { x, deadline, submitted: now, resp: rtx }) {
                Ok(()) => return Ok((rrx, i)),
                Err(TrySendError::Full(req)) => {
                    r.stats.outstanding.fetch_sub(1, Ordering::SeqCst);
                    r.stats.shed.inc();
                    x = req.x;
                }
                Err(TrySendError::Disconnected(req)) => {
                    // never counted as load (the satellite-fixed leak)
                    r.stats.outstanding.fetch_sub(1, Ordering::SeqCst);
                    x = req.x;
                }
            }
        }
        Err(ServeError::Overloaded { replicas: self.replicas.len() })
    }

    /// Total requests answered `Ok` across replicas.
    pub fn completed(&self) -> u64 {
        self.replicas.iter().map(|r| r.stats.served.get()).sum()
    }

    /// Total requests shed at admission across replicas.
    pub fn shed(&self) -> u64 {
        self.replicas.iter().map(|r| r.stats.shed.get()).sum()
    }

    /// Shut down: drop all senders, join everything, and return the
    /// crash log (supervised) or an aggregate error naming *every*
    /// crashed worker (unsupervised — all workers are joined before the
    /// error is built, so no thread leaks behind an early return).
    pub fn shutdown(self) -> Result<Vec<String>> {
        let Router { replicas, backing, .. } = self;
        let stats: Vec<Arc<ReplicaStats>> =
            replicas.iter().map(|r| Arc::clone(&r.stats)).collect();
        drop(replicas); // drops every sender: workers drain and exit
        match backing {
            Backing::Supervised(sup) => {
                sup.join().map_err(|_| anyhow!("supervisor thread panicked"))
            }
            Backing::Unsupervised(joins) => {
                let total = joins.len();
                let mut crashes = Vec::new();
                for (i, j) in joins.into_iter().enumerate() {
                    match j.join() {
                        Ok(exit) => {
                            if let Some(rx) = exit.rx {
                                let reason =
                                    exit.crash.clone().unwrap_or_else(|| "replica crashed".into());
                                drain_unserved(rx, &stats[i], &reason);
                            }
                            if let Some(c) = exit.crash {
                                crashes.push(format!("replica {i}: {c}"));
                            }
                        }
                        Err(_) => crashes.push(format!("replica {i}: thread panicked")),
                    }
                }
                if crashes.is_empty() {
                    Ok(Vec::new())
                } else {
                    Err(anyhow!(
                        "{} of {total} replica(s) failed: {}",
                        crashes.len(),
                        crashes.join("; ")
                    ))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{spawn_worker, BatchPolicy, MockBackend};

    fn slow_mock() -> MockBackend {
        MockBackend { bs: 2, sample: 1, classes: 1, delay: Duration::from_millis(5) }
    }

    fn policy(max_batch: usize, max_wait: Duration) -> ServePolicy {
        ServePolicy { batch: BatchPolicy { max_batch, max_wait }, ..ServePolicy::default() }
    }

    #[test]
    fn router_spreads_load() {
        let p = policy(2, Duration::from_millis(1));
        let workers = (0..3).map(|_| spawn_worker(move || Ok(slow_mock()), p).unwrap()).collect();
        let router = Router::new(workers);
        let mut rxs = Vec::new();
        let mut used = [0usize; 3];
        for i in 0..30 {
            let (rx, idx) = router.submit(vec![i as f32]).unwrap();
            used[idx] += 1;
            rxs.push((i, rx));
        }
        for (i, rx) in rxs {
            let v = rx.recv().unwrap().unwrap();
            assert_eq!(v[0], i as f32);
        }
        // least-loaded routing must touch every replica under backlog
        assert!(used.iter().all(|u| *u > 0), "usage {used:?}");
        assert_eq!(router.completed(), 30);
        router.shutdown().unwrap();
    }

    #[test]
    fn pick_prefers_idle_worker_and_skips_open_circuits() {
        let w0 = spawn_worker(move || Ok(slow_mock()), ServePolicy::default()).unwrap();
        let w1 = spawn_worker(move || Ok(slow_mock()), ServePolicy::default()).unwrap();
        // preload w0
        w0.stats.outstanding.store(5, Ordering::SeqCst);
        let router = Router::new(vec![w0, w1]);
        assert_eq!(router.pick(), Some(1));
        // an open circuit removes a replica from consideration entirely
        router.stats(1).set_circuit(CircuitState::Open);
        assert_eq!(router.pick(), Some(0));
        router.stats(0).set_circuit(CircuitState::Open);
        assert_eq!(router.pick(), None);
        assert!(matches!(
            router.submit(vec![0.0]),
            Err(ServeError::ReplicaFailed { .. })
        ));
        // restore so shutdown joins cleanly
        router.stats(0).outstanding.store(0, Ordering::SeqCst);
        router.shutdown().unwrap();
    }

    #[test]
    fn router_sheds_requests_whose_deadline_no_backlog_can_meet() {
        // one slow single-slot replica: after a warm-up batch teaches
        // the router ~20ms service time, a 5ms-deadline request against
        // a 3-deep backlog must shed at admission, not expire in queue
        let p = ServePolicy {
            batch: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(100) },
            ..ServePolicy::default()
        };
        let w = spawn_worker(
            move || {
                Ok(MockBackend { bs: 1, sample: 1, classes: 1, delay: Duration::from_millis(20) })
            },
            p,
        )
        .unwrap();
        let router = Router::new(vec![w]);
        let (rx, _) = router.submit(vec![1.0]).unwrap();
        rx.recv().unwrap().unwrap(); // warm-up: latency signal now known
        let backlog: Vec<_> = (0..3).map(|_| router.submit(vec![2.0]).unwrap().0).collect();
        let shed_before = router.shed();
        let tight = Instant::now() + Duration::from_millis(5);
        match router.submit_with_deadline(vec![3.0], tight) {
            Err(ServeError::Overloaded { .. }) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(router.shed(), shed_before + 1);
        // a generous deadline is still admitted
        let far = Instant::now() + Duration::from_secs(30);
        let (rx, _) = router.submit_with_deadline(vec![4.0], far).unwrap();
        for b in backlog {
            b.recv().unwrap().unwrap();
        }
        rx.recv().unwrap().unwrap();
        router.shutdown().unwrap();
    }

    #[test]
    fn unsupervised_shutdown_joins_all_workers_and_aggregates_crashes() {
        // regression: shutdown used to early-return on the first crashed
        // worker, leaking the remaining threads un-joined
        struct SlowPanicBackend;
        impl crate::coordinator::InferBackend for SlowPanicBackend {
            fn batch_size(&self) -> usize {
                2
            }
            fn sample_elems(&self) -> usize {
                1
            }
            fn out_elems(&self) -> usize {
                1
            }
            fn infer_batch(&self, _x: &[f32]) -> anyhow::Result<Vec<f32>> {
                // slow enough that both submits land before either
                // reply decrements the load signal (keeps routing to
                // distinct replicas deterministic)
                std::thread::sleep(Duration::from_millis(200));
                panic!("injected fault: slow panic");
            }
        }
        let p = ServePolicy {
            batch: BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
            ..ServePolicy::default()
        };
        let workers =
            (0..2).map(|_| spawn_worker(move || Ok(SlowPanicBackend), p).unwrap()).collect();
        let router = Router::new(workers);
        // one crash on each replica (least-loaded routing alternates
        // while both requests are outstanding)
        let (a, ia) = router.submit(vec![1.0]).unwrap();
        let (b, ib) = router.submit(vec![2.0]).unwrap();
        assert_ne!(ia, ib);
        assert!(matches!(a.recv().unwrap(), Err(ServeError::ReplicaFailed { .. })));
        assert!(matches!(b.recv().unwrap(), Err(ServeError::ReplicaFailed { .. })));
        let err = router.shutdown().unwrap_err().to_string();
        assert!(err.contains("replica 0"), "{err}");
        assert!(err.contains("replica 1"), "{err}");
    }
}
