//! Least-outstanding-requests router over model replicas.

use std::sync::atomic::Ordering;

use anyhow::{anyhow, Result};

use super::server::WorkerHandle;

/// Routes single-sample requests to the replica with the fewest
/// outstanding requests (ties -> lowest index, which keeps routing
/// deterministic for tests).
pub struct Router {
    workers: Vec<WorkerHandle>,
}

impl Router {
    /// Router over a non-empty replica set.
    pub fn new(workers: Vec<WorkerHandle>) -> Self {
        assert!(!workers.is_empty());
        Router { workers }
    }

    /// Number of replicas behind this router.
    pub fn replicas(&self) -> usize {
        self.workers.len()
    }

    /// Pick the least-loaded replica index.
    pub fn pick(&self) -> usize {
        self.workers
            .iter()
            .enumerate()
            .min_by_key(|(_, w)| w.outstanding.load(Ordering::SeqCst))
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Submit a request; returns the reply receiver and the replica used.
    pub fn submit(
        &self,
        x: Vec<f32>,
    ) -> Result<(std::sync::mpsc::Receiver<Result<Vec<f32>>>, usize)> {
        let idx = self.pick();
        let rx = self.workers[idx].submit(x)?;
        Ok((rx, idx))
    }

    /// Handle of replica `i` (load/latency introspection).
    pub fn worker(&self, i: usize) -> &WorkerHandle {
        &self.workers[i]
    }

    /// Total requests completed across replicas (from latency counters).
    pub fn completed(&self) -> u64 {
        self.workers.iter().map(|w| w.latency.count()).sum()
    }

    /// Shut down: drop senders and join all workers.
    pub fn shutdown(self) -> Result<()> {
        let mut joins = Vec::new();
        for w in self.workers {
            drop(w.tx);
            joins.push(w.join);
        }
        for j in joins {
            j.join().map_err(|_| anyhow!("worker panicked"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{spawn_worker, BatchPolicy, MockBackend};
    use std::time::Duration;

    fn slow_mock() -> MockBackend {
        MockBackend { bs: 2, sample: 1, classes: 1, delay: Duration::from_millis(5) }
    }

    #[test]
    fn router_spreads_load() {
        let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) };
        let workers = (0..3)
            .map(|_| spawn_worker(move || Ok(slow_mock()), policy).unwrap())
            .collect();
        let router = Router::new(workers);
        let mut rxs = Vec::new();
        let mut used = [0usize; 3];
        for i in 0..30 {
            let (rx, idx) = router.submit(vec![i as f32]).unwrap();
            used[idx] += 1;
            rxs.push((i, rx));
        }
        for (i, rx) in rxs {
            let v = rx.recv().unwrap().unwrap();
            assert_eq!(v[0], i as f32);
        }
        // least-loaded routing must touch every replica under backlog
        assert!(used.iter().all(|u| *u > 0), "usage {used:?}");
        assert_eq!(router.completed(), 30);
        router.shutdown().unwrap();
    }

    #[test]
    fn pick_prefers_idle_worker() {
        let w0 = spawn_worker(move || Ok(slow_mock()), BatchPolicy::default()).unwrap();
        let w1 = spawn_worker(move || Ok(slow_mock()), BatchPolicy::default()).unwrap();
        // preload w0
        w0.outstanding.store(5, Ordering::SeqCst);
        let router = Router::new(vec![w0, w1]);
        assert_eq!(router.pick(), 1);
        // restore so shutdown joins cleanly
        router.worker(0).outstanding.store(0, Ordering::SeqCst);
        router.shutdown().unwrap();
    }
}
