//! Least-outstanding-requests router over a versioned model catalog,
//! with bounded admission, deadline-feasibility routing, circuit
//! awareness, and zero-downtime hot swap.
//!
//! Admission contract: `submit` never blocks and never queues beyond
//! each replica's bounded depth. It walks the non-open replicas of the
//! target model's *live deployment* from least to most loaded and
//! `try_send`s; if every candidate is full the request is shed with a
//! typed [`ServeError::Overloaded`]. A replica whose queue-age signal
//! (outstanding x mean batch time) says the deadline cannot be met is
//! skipped *before* its queue is touched, so doomed requests are shed at
//! admission instead of expiring inside a worker.
//!
//! Lifecycle contract (`lifecycle.rs`): the router holds a
//! `ModelCatalog` of named slots, each with at most one live versioned
//! deployment. [`Router::deploy`] spawns and *warms* the next version
//! off to the side (failed warmup aborts with
//! [`ServeError::WarmupFailed`] and the old version keeps serving),
//! atomically flips admission, then gracefully drains the old
//! generation bounded by [`ServePolicy::drain_timeout`] — stragglers
//! are answered typed, never dropped. [`Router::retire`] drains a slot
//! without a replacement, and [`Router::shutdown`] is a drain of every
//! slot.
//!
//! Two backings: [`Router::spawn`] runs replicas under the supervisor
//! (crash respawn + breakers — the production path), while
//! [`Router::new`] wraps caller-spawned [`WorkerHandle`]s (no respawn;
//! crashes surface as an aggregate error from `shutdown`).

use std::sync::atomic::AtomicBool;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::error::{ServeError, ServePolicy, ServeResult};
use super::lifecycle::{Backing, Deployment, DrainReport, ModelCatalog, SwapReport, DEFAULT_MODEL};
use super::server::{InferBackend, ReplicaHandle, ReplicaStats, WorkerHandle};
use super::supervisor::spawn_supervised;

/// Routes single-sample requests to the replica with the fewest
/// outstanding requests (ties -> lowest index, which keeps routing
/// deterministic for tests), skipping replicas whose circuit breaker is
/// open or whose backlog makes the request's deadline infeasible.
/// Multi-model: requests can name a catalog slot (`submit_to`); the
/// unnamed `submit` path routes to the default slot.
pub struct Router {
    catalog: ModelCatalog,
    policy: ServePolicy,
}

impl Router {
    /// Router over caller-spawned workers (non-empty), installed as v1
    /// of the default model slot. All workers are assumed to share one
    /// [`ServePolicy`] (the first one's is used for default deadlines
    /// and feasibility math).
    pub fn new(workers: Vec<WorkerHandle>) -> Self {
        assert!(!workers.is_empty());
        let policy = workers[0].policy;
        let mut handles = Vec::with_capacity(workers.len());
        let mut joins = Vec::with_capacity(workers.len());
        for w in workers {
            handles.push(ReplicaHandle { tx: w.tx, stats: w.stats });
            joins.push(w.join);
        }
        let catalog = ModelCatalog::new();
        // unsupervised workers hold their own (inert) drain flags, so a
        // bounded drain cannot fail-fast them; test-only backing, and
        // idle workers exit as soon as their senders drop
        catalog.install(
            DEFAULT_MODEL,
            Deployment::new(
                1,
                handles,
                Backing::Unsupervised(joins),
                Arc::new(AtomicBool::new(false)),
                policy,
            ),
        );
        Router { catalog, policy }
    }

    /// Spawn `replicas` *supervised* replica slots sharing one backend
    /// factory, installed as v1 of the default model slot: crashed
    /// replicas are respawned on the same queue with capped exponential
    /// backoff, and repeated failures trip a per-replica circuit breaker
    /// the router routes around. (No warmup — use [`Router::deploy`] for
    /// the warmed hot-swap path.)
    pub fn spawn<B, F>(replicas: usize, factory: F, policy: ServePolicy) -> Result<Self>
    where
        B: InferBackend,
        F: Fn() -> Result<B> + Send + Sync + 'static,
    {
        anyhow::ensure!(replicas > 0, "router needs at least one replica");
        let drain = Arc::new(AtomicBool::new(false));
        let (handles, sup) =
            spawn_supervised(replicas, factory, policy, false, Arc::clone(&drain))?;
        let catalog = ModelCatalog::new();
        catalog.install(
            DEFAULT_MODEL,
            Deployment::new(1, handles, Backing::Supervised(sup), drain, policy),
        );
        Ok(Router { catalog, policy })
    }

    /// Router with an empty catalog: every model arrives via
    /// [`Router::deploy`]. The multi-model serving entry point.
    pub fn empty(policy: ServePolicy) -> Self {
        Router { catalog: ModelCatalog::new(), policy }
    }

    /// Deploy a new version of `model`: spawn `replicas` supervised
    /// slots, *warm* each one (a real forward must succeed before it
    /// counts), atomically flip the slot's admission to the new fleet,
    /// then gracefully drain the previous version bounded by
    /// [`ServePolicy::drain_timeout`]. Queued requests finish on the old
    /// plan; stragglers past the budget are answered typed
    /// `ReplicaFailed`. Any construction/warmup failure aborts *before*
    /// the flip with [`ServeError::WarmupFailed`] — the old version
    /// never stops serving.
    pub fn deploy<B, F>(
        &self,
        model: &str,
        replicas: usize,
        factory: F,
    ) -> Result<SwapReport, ServeError>
    where
        B: InferBackend,
        F: Fn() -> Result<B> + Send + Sync + 'static,
    {
        self.catalog.deploy(model, replicas, factory, self.policy)
    }

    /// Drain `model`'s live deployment without a replacement (bounded by
    /// the policy drain budget). Subsequent submits to the slot get a
    /// typed `ReplicaFailed` until a new version is deployed.
    pub fn retire(&self, model: &str) -> Result<DrainReport, ServeError> {
        self.catalog.retire(model, self.policy.drain_timeout)
    }

    /// Every model name the catalog has seen, with its live version
    /// (None = retired, awaiting a redeploy).
    pub fn models(&self) -> Vec<(String, Option<u64>)> {
        self.catalog.models()
    }

    /// Live version of `model` (None when unknown or retired).
    pub fn version(&self, model: &str) -> Option<u64> {
        self.catalog.deployment(model).ok().map(|d| d.version())
    }

    fn default_deployment(&self) -> Result<Arc<Deployment>, ServeError> {
        self.catalog.default_deployment()
    }

    /// Number of replicas behind the default model's live deployment
    /// (0 when nothing is deployed).
    pub fn replicas(&self) -> usize {
        self.default_deployment().map(|d| d.replicas()).unwrap_or(0)
    }

    /// Stats of the default deployment's replica `i` (load / shed /
    /// latency / circuit). The `Arc` stays valid across a hot swap —
    /// it keeps reporting on the generation it was taken from.
    pub fn stats(&self, i: usize) -> Arc<ReplicaStats> {
        self.default_deployment().expect("no model deployed").stats(i)
    }

    /// Stats of every replica the router has ever run: live deployments
    /// of every model plus retired generations. The set bench
    /// aggregation absorbs so conservation accounting spans hot swaps.
    pub fn all_stats(&self) -> Vec<Arc<ReplicaStats>> {
        self.catalog.all_stats()
    }

    /// The policy admission and batching run under.
    pub fn policy(&self) -> ServePolicy {
        self.policy
    }

    /// Least-loaded replica of the default deployment whose circuit is
    /// not open; None when every breaker has tripped (or nothing is
    /// deployed).
    pub fn pick(&self) -> Option<usize> {
        self.default_deployment().ok().and_then(|d| d.pick())
    }

    /// Submit a request to the default model under the policy's default
    /// deadline; returns the reply receiver and the replica used.
    pub fn submit(&self, x: Vec<f32>) -> Result<(Receiver<ServeResult>, usize), ServeError> {
        self.submit_with_deadline(x, Instant::now() + self.policy.default_deadline)
    }

    /// Submit a request to the default model with an explicit absolute
    /// deadline. Sheds typed and synchronously when the request cannot
    /// be admitted: every circuit open or slot retired ->
    /// `ReplicaFailed`; deadline already passed -> `DeadlineExceeded`;
    /// no replica can meet the deadline or every candidate queue is
    /// full -> `Overloaded` (counted per replica in
    /// [`ReplicaStats::shed`]).
    pub fn submit_with_deadline(
        &self,
        x: Vec<f32>,
        deadline: Instant,
    ) -> Result<(Receiver<ServeResult>, usize), ServeError> {
        self.default_deployment()?.submit_with_deadline(x, deadline)
    }

    /// Submit a request to a *named* model under the policy's default
    /// deadline. Unknown names get a typed [`ServeError::UnknownModel`].
    pub fn submit_to(
        &self,
        model: &str,
        x: Vec<f32>,
    ) -> Result<(Receiver<ServeResult>, usize), ServeError> {
        self.submit_to_with_deadline(model, x, Instant::now() + self.policy.default_deadline)
    }

    /// Submit a request to a *named* model with an explicit absolute
    /// deadline (same typed shed contract as `submit_with_deadline`).
    pub fn submit_to_with_deadline(
        &self,
        model: &str,
        x: Vec<f32>,
        deadline: Instant,
    ) -> Result<(Receiver<ServeResult>, usize), ServeError> {
        self.catalog.deployment(model)?.submit_with_deadline(x, deadline)
    }

    /// Total requests answered `Ok` across every replica ever run
    /// (live and retired generations).
    pub fn completed(&self) -> u64 {
        self.all_stats().iter().map(|s| s.served.get()).sum()
    }

    /// Total requests shed at admission across every replica ever run.
    pub fn shed(&self) -> u64 {
        self.all_stats().iter().map(|s| s.shed.get()).sum()
    }

    /// Shut down: gracefully drain every slot's live deployment
    /// (bounded by the policy drain budget), join everything, and
    /// return the crash log. Supervised crashes were already handled
    /// (respawn / breaker) and only *report* here; unsupervised worker
    /// crashes surface as an aggregate error naming every crashed
    /// worker — all workers are joined before the error is built, so no
    /// thread leaks behind an early return.
    pub fn shutdown(self) -> Result<Vec<String>> {
        let Router { catalog, policy } = self;
        let (log, hard) = catalog.shutdown(policy.drain_timeout);
        if hard == 0 {
            Ok(log)
        } else {
            Err(anyhow!("{hard} replica(s) failed: {}", log.join("; ")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{spawn_worker, BatchPolicy, CircuitState, MockBackend};
    use std::sync::atomic::Ordering;
    use std::time::Duration;

    fn slow_mock() -> MockBackend {
        MockBackend { bs: 2, sample: 1, classes: 1, delay: Duration::from_millis(5) }
    }

    fn policy(max_batch: usize, max_wait: Duration) -> ServePolicy {
        ServePolicy { batch: BatchPolicy { max_batch, max_wait }, ..ServePolicy::default() }
    }

    #[test]
    fn router_spreads_load() {
        let p = policy(2, Duration::from_millis(1));
        let workers = (0..3).map(|_| spawn_worker(move || Ok(slow_mock()), p).unwrap()).collect();
        let router = Router::new(workers);
        let mut rxs = Vec::new();
        let mut used = [0usize; 3];
        for i in 0..30 {
            let (rx, idx) = router.submit(vec![i as f32]).unwrap();
            used[idx] += 1;
            rxs.push((i, rx));
        }
        for (i, rx) in rxs {
            let v = rx.recv().unwrap().unwrap();
            assert_eq!(v[0], i as f32);
        }
        // least-loaded routing must touch every replica under backlog
        assert!(used.iter().all(|u| *u > 0), "usage {used:?}");
        assert_eq!(router.completed(), 30);
        router.shutdown().unwrap();
    }

    #[test]
    fn pick_prefers_idle_worker_and_skips_open_circuits() {
        let w0 = spawn_worker(move || Ok(slow_mock()), ServePolicy::default()).unwrap();
        let w1 = spawn_worker(move || Ok(slow_mock()), ServePolicy::default()).unwrap();
        // preload w0
        w0.stats.outstanding.store(5, Ordering::SeqCst);
        let router = Router::new(vec![w0, w1]);
        assert_eq!(router.pick(), Some(1));
        // an open circuit removes a replica from consideration entirely
        router.stats(1).set_circuit(CircuitState::Open);
        assert_eq!(router.pick(), Some(0));
        router.stats(0).set_circuit(CircuitState::Open);
        assert_eq!(router.pick(), None);
        assert!(matches!(
            router.submit(vec![0.0]),
            Err(ServeError::ReplicaFailed { .. })
        ));
        // restore so shutdown joins cleanly
        router.stats(0).outstanding.store(0, Ordering::SeqCst);
        router.shutdown().unwrap();
    }

    #[test]
    fn router_sheds_requests_whose_deadline_no_backlog_can_meet() {
        // one slow single-slot replica: after a warm-up batch teaches
        // the router ~20ms service time, a 5ms-deadline request against
        // a 3-deep backlog must shed at admission, not expire in queue
        let p = ServePolicy {
            batch: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(100) },
            ..ServePolicy::default()
        };
        let w = spawn_worker(
            move || {
                Ok(MockBackend { bs: 1, sample: 1, classes: 1, delay: Duration::from_millis(20) })
            },
            p,
        )
        .unwrap();
        let router = Router::new(vec![w]);
        let (rx, _) = router.submit(vec![1.0]).unwrap();
        rx.recv().unwrap().unwrap(); // warm-up: latency signal now known
        let backlog: Vec<_> = (0..3).map(|_| router.submit(vec![2.0]).unwrap().0).collect();
        let shed_before = router.shed();
        let tight = Instant::now() + Duration::from_millis(5);
        match router.submit_with_deadline(vec![3.0], tight) {
            Err(ServeError::Overloaded { .. }) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(router.shed(), shed_before + 1);
        // a generous deadline is still admitted
        let far = Instant::now() + Duration::from_secs(30);
        let (rx, _) = router.submit_with_deadline(vec![4.0], far).unwrap();
        for b in backlog {
            b.recv().unwrap().unwrap();
        }
        rx.recv().unwrap().unwrap();
        router.shutdown().unwrap();
    }

    #[test]
    fn unsupervised_shutdown_joins_all_workers_and_aggregates_crashes() {
        // regression: shutdown used to early-return on the first crashed
        // worker, leaking the remaining threads un-joined
        struct SlowPanicBackend;
        impl crate::coordinator::InferBackend for SlowPanicBackend {
            fn batch_size(&self) -> usize {
                2
            }
            fn sample_elems(&self) -> usize {
                1
            }
            fn out_elems(&self) -> usize {
                1
            }
            fn infer_batch(&self, _x: &[f32]) -> anyhow::Result<Vec<f32>> {
                // slow enough that both submits land before either
                // reply decrements the load signal (keeps routing to
                // distinct replicas deterministic)
                std::thread::sleep(Duration::from_millis(200));
                panic!("injected fault: slow panic");
            }
        }
        let p = ServePolicy {
            batch: BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
            ..ServePolicy::default()
        };
        let workers =
            (0..2).map(|_| spawn_worker(move || Ok(SlowPanicBackend), p).unwrap()).collect();
        let router = Router::new(workers);
        // one crash on each replica (least-loaded routing alternates
        // while both requests are outstanding)
        let (a, ia) = router.submit(vec![1.0]).unwrap();
        let (b, ib) = router.submit(vec![2.0]).unwrap();
        assert_ne!(ia, ib);
        assert!(matches!(a.recv().unwrap(), Err(ServeError::ReplicaFailed { .. })));
        assert!(matches!(b.recv().unwrap(), Err(ServeError::ReplicaFailed { .. })));
        let err = router.shutdown().unwrap_err().to_string();
        assert!(err.contains("replica 0"), "{err}");
        assert!(err.contains("replica 1"), "{err}");
    }

    #[test]
    fn deploy_flips_version_and_drains_old_generation() {
        let p = policy(2, Duration::from_millis(1));
        let router = Router::empty(p);
        assert_eq!(router.replicas(), 0);
        let r1 = router.deploy("m", 2, move || Ok(slow_mock())).unwrap();
        assert_eq!((r1.version, r1.replicas), (1, 2));
        assert!(r1.drained.is_none());
        let (rx, _) = router.submit_to("m", vec![3.0]).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap(), vec![3.0]);
        // v2: a fresh fleet (delay-free; the chaos suite covers
        // bit-distinguishing the two plans)
        let r2 = router
            .deploy("m", 2, move || {
                Ok(MockBackend { bs: 2, sample: 1, classes: 1, delay: Duration::ZERO })
            })
            .unwrap();
        assert_eq!(r2.version, 2);
        let d = r2.drained.expect("v1 must have been drained");
        assert_eq!(d.version, 1);
        assert!(d.clean, "idle v1 should drain cleanly: {d:?}");
        assert_eq!(router.version("m"), Some(2));
        // post-swap traffic lands on v2
        let (rx, _) = router.submit_to("m", vec![5.0]).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap(), vec![5.0]);
        // old generation's serve count is still visible in the aggregate
        assert_eq!(router.completed(), 2);
        router.shutdown().unwrap();
    }

    #[test]
    fn unknown_model_and_retired_model_are_typed() {
        let p = policy(2, Duration::from_millis(1));
        let router = Router::empty(p);
        assert!(matches!(
            router.submit_to("ghost", vec![1.0]),
            Err(ServeError::UnknownModel { .. })
        ));
        router.deploy("m", 1, move || Ok(slow_mock())).unwrap();
        let report = router.retire("m").unwrap();
        assert!(report.clean);
        assert!(matches!(
            router.submit_to("m", vec![1.0]),
            Err(ServeError::ReplicaFailed { .. })
        ));
        assert_eq!(router.version("m"), None);
        assert!(matches!(router.retire("m"), Err(ServeError::ReplicaFailed { .. })));
        // a redeploy revives the slot at the next version
        let r = router.deploy("m", 1, move || Ok(slow_mock())).unwrap();
        assert_eq!(r.version, 2);
        let (rx, _) = router.submit_to("m", vec![9.0]).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap(), vec![9.0]);
        router.shutdown().unwrap();
    }

    #[test]
    fn failed_warmup_aborts_swap_and_old_version_keeps_serving() {
        struct WarmupBomb;
        impl InferBackend for WarmupBomb {
            fn batch_size(&self) -> usize {
                1
            }
            fn sample_elems(&self) -> usize {
                1
            }
            fn out_elems(&self) -> usize {
                1
            }
            fn infer_batch(&self, _x: &[f32]) -> anyhow::Result<Vec<f32>> {
                anyhow::bail!("device rejected the plan");
            }
        }
        let p = policy(2, Duration::from_millis(1));
        let router = Router::empty(p);
        router.deploy("m", 1, move || Ok(slow_mock())).unwrap();
        match router.deploy("m", 1, move || Ok(WarmupBomb)) {
            Err(ServeError::WarmupFailed { model, reason }) => {
                assert_eq!(model, "m");
                assert!(reason.contains("warmup"), "{reason}");
            }
            other => panic!("expected WarmupFailed, got {other:?}"),
        }
        // the old version never stopped serving
        assert_eq!(router.version("m"), Some(1));
        let (rx, _) = router.submit_to("m", vec![4.0]).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap(), vec![4.0]);
        router.shutdown().unwrap();
    }
}
