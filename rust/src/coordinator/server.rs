//! Model worker: a thread that owns an inference backend and serves
//! batched requests from a channel.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::metrics::LatencyHistogram;

use super::batcher::{BatchPolicy, Batcher};

/// One inference request: a single sample (flattened CHW) and a reply
/// channel for its logits.
pub struct InferRequest {
    /// the sample, flattened CHW
    pub x: Vec<f32>,
    /// where this request's logits (or error) are delivered
    pub resp: SyncSender<Result<Vec<f32>>>,
}

/// Anything the worker can run a padded batch through. Abstracted so the
/// coordinator's batching/routing invariants are property-testable
/// without PJRT in the loop.
///
/// NOTE: deliberately *not* `Send` — PJRT handles hold thread-local
/// state, so each worker constructs its own backend inside its thread
/// via the factory passed to `spawn_worker` (one PJRT client + compiled
/// executable per replica, exactly like a one-process-per-replica
/// deployment).
pub trait InferBackend: 'static {
    /// Fixed device batch size (artifact-baked).
    fn batch_size(&self) -> usize;
    /// Elements per sample (C*H*W).
    fn sample_elems(&self) -> usize;
    /// Logits per sample.
    fn out_elems(&self) -> usize;
    /// Run exactly one device batch (len == batch_size * sample_elems).
    fn infer_batch(&self, x: &[f32]) -> Result<Vec<f32>>;
}

/// Deterministic mock backend for coordinator tests: logit j of sample i
/// is `sum(x_i) + j`.
pub struct MockBackend {
    /// device batch size
    pub bs: usize,
    /// elements per sample
    pub sample: usize,
    /// logits per sample
    pub classes: usize,
    /// optional artificial latency per batch
    pub delay: std::time::Duration,
}

impl InferBackend for MockBackend {
    fn batch_size(&self) -> usize {
        self.bs
    }

    fn sample_elems(&self) -> usize {
        self.sample
    }

    fn out_elems(&self) -> usize {
        self.classes
    }

    fn infer_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let mut out = vec![0.0f32; self.bs * self.classes];
        for b in 0..self.bs {
            let s: f32 = x[b * self.sample..(b + 1) * self.sample].iter().sum();
            for j in 0..self.classes {
                out[b * self.classes + j] = s + j as f32;
            }
        }
        Ok(out)
    }
}

/// Handle to a spawned worker: submit requests, inspect load, join.
pub struct WorkerHandle {
    /// request channel into the worker's batcher
    pub tx: Sender<InferRequest>,
    /// requests submitted but not yet replied to (router load signal)
    pub outstanding: Arc<AtomicUsize>,
    /// per-batch service-time histogram
    pub latency: Arc<LatencyHistogram>,
    /// worker thread handle (joins after `tx` is dropped)
    pub join: JoinHandle<()>,
}

impl WorkerHandle {
    /// Submit one sample and get a receiver for the reply.
    pub fn submit(&self, x: Vec<f32>) -> Result<std::sync::mpsc::Receiver<Result<Vec<f32>>>> {
        let (rtx, rrx) = std::sync::mpsc::sync_channel(1);
        self.outstanding.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(InferRequest { x, resp: rtx })
            .map_err(|_| anyhow!("worker channel closed"))?;
        Ok(rrx)
    }
}

/// Spawn a worker thread serving a backend built by `factory` (inside
/// the thread — PJRT handles are not `Send`) under `policy`.
///
/// Invariants (property-tested in rust/tests/proptest_coordinator.rs):
/// * every submitted request receives exactly one reply;
/// * device batches never exceed the backend batch size; short batches
///   are zero-padded and the padding's outputs are discarded;
/// * replies carry the logits of their own request (no cross-wiring).
pub fn spawn_worker<B, F>(factory: F, policy: BatchPolicy) -> Result<WorkerHandle>
where
    B: InferBackend,
    F: FnOnce() -> Result<B> + Send + 'static,
{
    let (tx, rx) = channel::<InferRequest>();
    let outstanding = Arc::new(AtomicUsize::new(0));
    let latency = Arc::new(LatencyHistogram::new());
    let out_clone = outstanding.clone();
    let lat_clone = latency.clone();
    let (ready_tx, ready_rx) = std::sync::mpsc::sync_channel::<Result<()>>(1);
    let join = std::thread::spawn(move || {
        let backend = match factory() {
            Ok(b) => {
                let _ = ready_tx.send(Ok(()));
                b
            }
            Err(e) => {
                let _ = ready_tx.send(Err(e));
                return;
            }
        };
        let device_bs = backend.batch_size();
        let policy = BatchPolicy { max_batch: policy.max_batch.min(device_bs), ..policy };
        let batcher = Batcher::new(rx, policy);
        let sample = backend.sample_elems();
        let classes = backend.out_elems();
        while let Some(batch) = batcher.next_batch() {
            let t0 = Instant::now();
            // zero-pad to the artifact's fixed batch size
            let mut xs = vec![0.0f32; device_bs * sample];
            for (i, req) in batch.iter().enumerate() {
                if req.x.len() == sample {
                    xs[i * sample..(i + 1) * sample].copy_from_slice(&req.x);
                }
            }
            let result = backend.infer_batch(&xs);
            match result {
                Ok(logits) => {
                    for (i, req) in batch.into_iter().enumerate() {
                        let reply = if req.x.len() != sample {
                            Err(anyhow!(
                                "bad request size {} != {sample}",
                                req.x.len()
                            ))
                        } else {
                            Ok(logits[i * classes..(i + 1) * classes].to_vec())
                        };
                        // record before replying so observers that join on
                        // the reply see a consistent count
                        lat_clone.record(t0.elapsed());
                        out_clone.fetch_sub(1, Ordering::SeqCst);
                        let _ = req.resp.send(reply);
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for req in batch {
                        out_clone.fetch_sub(1, Ordering::SeqCst);
                        let _ = req.resp.send(Err(anyhow!("{msg}")));
                    }
                }
            }
        }
    });
    ready_rx
        .recv()
        .map_err(|_| anyhow!("worker died before ready"))??;
    Ok(WorkerHandle { tx, outstanding, latency, join })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn mock() -> MockBackend {
        MockBackend { bs: 4, sample: 3, classes: 2, delay: Duration::ZERO }
    }

    #[test]
    fn single_request_roundtrip() {
        let w = spawn_worker(move || Ok(mock()), BatchPolicy::default()).unwrap();
        let rx = w.submit(vec![1.0, 2.0, 3.0]).unwrap();
        let logits = rx.recv().unwrap().unwrap();
        assert_eq!(logits, vec![6.0, 7.0]);
        drop(w.tx);
        w.join.join().unwrap();
    }

    #[test]
    fn many_requests_all_answered_correctly() {
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
        let w = spawn_worker(move || Ok(mock()), policy).unwrap();
        let mut rxs = Vec::new();
        for i in 0..37 {
            rxs.push((i, w.submit(vec![i as f32, 0.0, 0.0]).unwrap()));
        }
        for (i, rx) in rxs {
            let logits = rx.recv().unwrap().unwrap();
            assert_eq!(logits[0], i as f32);
            assert_eq!(logits[1], i as f32 + 1.0);
        }
        assert_eq!(w.outstanding.load(Ordering::SeqCst), 0);
        drop(w.tx);
        w.join.join().unwrap();
    }

    #[test]
    fn wrong_size_request_gets_error_not_hang() {
        let w = spawn_worker(move || Ok(mock()), BatchPolicy::default()).unwrap();
        let rx = w.submit(vec![1.0]).unwrap(); // wrong size
        assert!(rx.recv().unwrap().is_err());
        drop(w.tx);
        w.join.join().unwrap();
    }

    #[test]
    fn latency_recorded() {
        let w = spawn_worker(
            move || Ok(MockBackend { delay: Duration::from_micros(100), ..mock() }),
            BatchPolicy::default(),
        )
        .unwrap();
        let rx = w.submit(vec![0.0; 3]).unwrap();
        rx.recv().unwrap().unwrap();
        assert_eq!(w.latency.count(), 1);
        drop(w.tx);
        w.join.join().unwrap();
    }
}
