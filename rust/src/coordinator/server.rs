//! Model worker: a thread that owns an inference backend and serves
//! batched requests from a *bounded* channel, under the hardened serving
//! contract:
//!
//! * **bounded admission** — `submit` never blocks and never queues to
//!   unbounded depth; a full queue sheds with [`ServeError::Overloaded`];
//! * **deadlines** — every request carries an absolute deadline and the
//!   worker drops expired requests *before* spending a device batch on
//!   them ([`ServeError::DeadlineExceeded`]);
//! * **typed failure** — a backend panic or repeated backend errors end
//!   the worker *generation*: every in-flight request is answered
//!   [`ServeError::ReplicaFailed`] and the queue's receiver is returned
//!   through the thread's [`WorkerExit`] so a supervisor can respawn a
//!   new generation on the *same* channel — requests queued across the
//!   crash gap survive and are served by the successor.
//!
//! Conservation invariant (chaos-tested in rust/tests/chaos_serving.rs):
//! every admitted request receives exactly one typed reply, across
//! injected panics, backend errors, expiry, and shutdown.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::metrics::{Counter, LatencyHistogram};

use super::batcher::{BatchPolicy, Batcher, Urgent};
use super::error::{ServeError, ServePolicy, ServeResult};

/// One inference request: a single sample (flattened CHW), its absolute
/// deadline, and a reply channel for its logits.
pub struct InferRequest {
    /// the sample, flattened CHW
    pub x: Vec<f32>,
    /// absolute deadline; the batcher answers `DeadlineExceeded` instead
    /// of spending device time once this passes
    pub deadline: Instant,
    /// when the request was admitted (end-to-end latency anchor)
    pub submitted: Instant,
    /// where this request's logits (or typed error) are delivered
    pub resp: SyncSender<ServeResult>,
}

impl Urgent for InferRequest {
    fn deadline(&self) -> Instant {
        self.deadline
    }

    // `submitted` doubles as the enqueue stamp: admission stamps it in
    // the instant before `try_send`, so the batcher's flush window is
    // anchored to when the request entered the queue.
    fn enqueued(&self) -> Instant {
        self.submitted
    }
}

impl InferRequest {
    /// Deliver the one and only reply for this request: tallies the
    /// outcome, records end-to-end latency, releases the load signal
    /// *before* sending (so `outstanding` never over-reads), and ignores
    /// a receiver that was dropped by an abandoning client.
    pub(crate) fn finish(self, stats: &ReplicaStats, result: ServeResult) {
        match &result {
            Ok(_) => stats.served.inc(),
            Err(ServeError::DeadlineExceeded { .. }) => stats.expired.inc(),
            Err(_) => stats.failed.inc(),
        }
        stats.e2e.record(self.submitted.elapsed());
        stats.outstanding.fetch_sub(1, Ordering::SeqCst);
        let _ = self.resp.send(result);
    }
}

/// Anything the worker can run an admitted batch through. Abstracted so
/// the coordinator's batching/routing invariants are property-testable
/// without PJRT in the loop.
///
/// NOTE: deliberately *not* `Send` — PJRT handles hold thread-local
/// state, so each worker constructs its own backend inside its thread
/// via the factory passed to `spawn_worker` (one PJRT client + compiled
/// executable per replica, exactly like a one-process-per-replica
/// deployment). The factory itself is `Fn` (re-callable) so a supervisor
/// can rebuild a crashed replica's backend.
pub trait InferBackend: 'static {
    /// Fixed device batch size (artifact-baked).
    fn batch_size(&self) -> usize;
    /// Elements per sample (C*H*W).
    fn sample_elems(&self) -> usize;
    /// Logits per sample.
    fn out_elems(&self) -> usize;
    /// Run exactly one device batch (len == batch_size * sample_elems).
    fn infer_batch(&self, x: &[f32]) -> Result<Vec<f32>>;
    /// Run `n` live samples (`x.len() == n * sample_elems()`,
    /// `1 <= n <= batch_size()`) and return exactly `n * out_elems()`
    /// logits. The worker hands every admitted batch through this entry
    /// point. The default implementation zero-pads up to the fixed
    /// device batch, runs [`InferBackend::infer_batch`] once, and drops
    /// the padding's logits — artifact-baked backends keep working
    /// unchanged. Batch-native backends (e.g. the engine's
    /// `EngineBackend`) override it to run exactly `n` images as one
    /// forward, skipping the padded work entirely.
    fn infer_n(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        let bs = self.batch_size();
        let sample = self.sample_elems();
        ensure!(n >= 1 && n <= bs, "live batch {n} outside 1..={bs} (device batch)");
        ensure!(x.len() == n * sample, "live buffer {} != {n} x {sample}", x.len());
        let mut xs = vec![0.0f32; bs * sample];
        xs[..x.len()].copy_from_slice(x);
        let mut logits = self.infer_batch(&xs)?;
        logits.truncate(n * self.out_elems());
        Ok(logits)
    }
}

/// Deterministic mock backend for coordinator tests: logit j of sample i
/// is `sum(x_i) + j`.
pub struct MockBackend {
    /// device batch size
    pub bs: usize,
    /// elements per sample
    pub sample: usize,
    /// logits per sample
    pub classes: usize,
    /// optional artificial latency per batch
    pub delay: Duration,
}

impl InferBackend for MockBackend {
    fn batch_size(&self) -> usize {
        self.bs
    }

    fn sample_elems(&self) -> usize {
        self.sample
    }

    fn out_elems(&self) -> usize {
        self.classes
    }

    fn infer_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let mut out = vec![0.0f32; self.bs * self.classes];
        for b in 0..self.bs {
            let s: f32 = x[b * self.sample..(b + 1) * self.sample].iter().sum();
            for j in 0..self.classes {
                out[b * self.classes + j] = s + j as f32;
            }
        }
        Ok(out)
    }
}

/// Circuit-breaker state of one replica (stored in [`ReplicaStats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CircuitState {
    /// healthy: the router considers this replica normally
    Closed,
    /// freshly respawned after a crash; closes again on the first
    /// successful batch
    HalfOpen,
    /// tripped after `breaker_threshold` consecutive failures: the
    /// router routes around it and queued requests are drained into
    /// typed `ReplicaFailed` replies
    Open,
}

/// Per-replica serving counters and signals, shared (`Arc`) between the
/// admission side (router / handle), the worker generations, and the
/// supervisor. Survives respawns — one `ReplicaStats` per replica slot,
/// not per generation.
#[derive(Debug, Default)]
pub struct ReplicaStats {
    /// requests admitted but not yet replied to (router load signal)
    pub outstanding: AtomicUsize,
    /// requests shed at admission (queue full or deadline infeasible)
    pub shed: Counter,
    /// requests answered `DeadlineExceeded`
    pub expired: Counter,
    /// requests answered `Ok`
    pub served: Counter,
    /// requests answered `ReplicaFailed` / `BadRequest`
    pub failed: Counter,
    /// worker generations lost to panics or repeated backend errors
    pub crashes: Counter,
    /// consecutive failed batches; reset on success, trips the breaker
    /// at `ServePolicy::breaker_threshold`
    pub consecutive_failures: AtomicUsize,
    /// device-batch service time (one sample per batch) — also the
    /// router's queue-age signal for deadline feasibility
    pub latency: LatencyHistogram,
    /// end-to-end request latency, submit to reply (one sample per reply)
    pub e2e: LatencyHistogram,
    circuit: AtomicU8,
}

impl ReplicaStats {
    /// Fresh stats for one replica slot (circuit closed).
    pub fn new() -> Self {
        Self::default()
    }

    /// Current circuit-breaker state.
    pub fn circuit(&self) -> CircuitState {
        match self.circuit.load(Ordering::SeqCst) {
            0 => CircuitState::Closed,
            1 => CircuitState::HalfOpen,
            _ => CircuitState::Open,
        }
    }

    pub(crate) fn set_circuit(&self, s: CircuitState) {
        let v = match s {
            CircuitState::Closed => 0,
            CircuitState::HalfOpen => 1,
            CircuitState::Open => 2,
        };
        self.circuit.store(v, Ordering::SeqCst);
    }
}

/// What a worker generation leaves behind when its thread returns.
pub struct WorkerExit {
    /// the request receiver, returned on crash so a supervisor can
    /// respawn the next generation on the same channel (None on clean
    /// shutdown — the queue was already drained)
    pub rx: Option<Receiver<InferRequest>>,
    /// why the generation died (None = clean shutdown)
    pub crash: Option<String>,
}

/// Exit notification a generation (or drainer) sends its supervisor.
pub(crate) struct ReplicaExited {
    /// replica slot index
    pub idx: usize,
}

/// Admission-side handle to one replica slot: the bounded request
/// channel plus the slot's stats. The serving thread behind it may be
/// respawned across generations; the channel stays fixed.
pub(crate) struct ReplicaHandle {
    /// bounded request channel into the slot's batcher
    pub tx: SyncSender<InferRequest>,
    /// the slot's counters / circuit / latency signals
    pub stats: Arc<ReplicaStats>,
}

/// Render a panic payload (as recovered by `catch_unwind`) for humans.
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Answer every request still in `rx` with a typed `ReplicaFailed`.
/// Callers must guarantee the senders are (about to be) dropped — this
/// blocks until the channel disconnects.
pub(crate) fn drain_unserved(rx: Receiver<InferRequest>, stats: &ReplicaStats, reason: &str) {
    for req in rx {
        req.finish(stats, Err(ServeError::ReplicaFailed { reason: reason.to_string() }));
    }
}

/// Handle to a single unsupervised worker (one replica, no respawn).
/// Production serving goes through `Router::spawn`, which supervises;
/// this handle is the embeddable / testable building block.
pub struct WorkerHandle {
    /// bounded request channel into the worker's batcher
    pub tx: SyncSender<InferRequest>,
    /// load / outcome / latency signals for this replica
    pub stats: Arc<ReplicaStats>,
    /// the policy the worker batches and sheds under
    pub policy: ServePolicy,
    /// worker thread handle (returns after `tx` is dropped or a crash)
    pub join: JoinHandle<WorkerExit>,
}

impl WorkerHandle {
    /// Submit one sample with the policy's default deadline.
    pub fn submit(&self, x: Vec<f32>) -> Result<Receiver<ServeResult>, ServeError> {
        self.submit_with_deadline(x, Instant::now() + self.policy.default_deadline)
    }

    /// Submit one sample with an explicit absolute deadline. Never
    /// blocks: a full queue sheds `Overloaded`, a dead worker returns
    /// `ReplicaFailed` — and in both cases the load signal is released
    /// (the pre-increment is rolled back, so a dead or saturated replica
    /// can't inflate `outstanding` forever).
    pub fn submit_with_deadline(
        &self,
        x: Vec<f32>,
        deadline: Instant,
    ) -> Result<Receiver<ServeResult>, ServeError> {
        let now = Instant::now();
        if deadline <= now {
            self.stats.expired.inc();
            return Err(ServeError::DeadlineExceeded { waited: Duration::ZERO });
        }
        let (rtx, rrx) = sync_channel(1);
        self.stats.outstanding.fetch_add(1, Ordering::SeqCst);
        match self.tx.try_send(InferRequest { x, deadline, submitted: now, resp: rtx }) {
            Ok(()) => Ok(rrx),
            Err(TrySendError::Full(_)) => {
                self.stats.outstanding.fetch_sub(1, Ordering::SeqCst);
                self.stats.shed.inc();
                Err(ServeError::Overloaded { replicas: 1 })
            }
            Err(TrySendError::Disconnected(_)) => {
                self.stats.outstanding.fetch_sub(1, Ordering::SeqCst);
                Err(ServeError::ReplicaFailed { reason: "worker channel closed".into() })
            }
        }
    }

    /// Drop the sender, join the worker, and drain any requests stranded
    /// by a crash into typed replies. Returns the crash reason if the
    /// generation died instead of exiting cleanly.
    pub fn shutdown(self) -> Result<(), String> {
        let WorkerHandle { tx, stats, join, .. } = self;
        drop(tx);
        match join.join() {
            Ok(exit) => {
                if let Some(rx) = exit.rx {
                    let reason = exit.crash.clone().unwrap_or_else(|| "replica crashed".into());
                    drain_unserved(rx, &stats, &reason);
                }
                match exit.crash {
                    Some(c) => Err(c),
                    None => Ok(()),
                }
            }
            Err(p) => Err(format!("worker thread panicked: {}", panic_message(p))),
        }
    }
}

/// Spawn one worker generation: a thread that builds the backend via
/// `factory` and serves `rx` until disconnect or crash, then notifies
/// `events`. `ready` (first generation only) reports whether the backend
/// came up. With `warm` set, one real zero-batch forward must succeed
/// before the generation signals ready or takes traffic (the hot-swap
/// warmup contract). `drain` is the generation's fail-fast flag: once a
/// bounded drain trips it, queued requests are answered with typed
/// `ReplicaFailed` instead of device work. Used by `spawn_worker` and by
/// the supervisor's respawns.
#[allow(clippy::too_many_arguments)]
pub(crate) fn spawn_generation<B, F>(
    factory: Arc<F>,
    rx: Receiver<InferRequest>,
    stats: Arc<ReplicaStats>,
    policy: ServePolicy,
    idx: usize,
    events: Sender<ReplicaExited>,
    ready: Option<SyncSender<Result<()>>>,
    warm: bool,
    drain: Arc<AtomicBool>,
) -> JoinHandle<WorkerExit>
where
    B: InferBackend,
    F: Fn() -> Result<B> + Send + Sync + 'static,
{
    std::thread::spawn(move || {
        let exit = generation_body(&*factory, rx, &stats, &policy, ready, warm, &drain);
        let _ = events.send(ReplicaExited { idx });
        exit
    })
}

/// One generation's life: construct the backend (and, under `warm`, run
/// one real forward before signaling ready), serve batches, exit.
fn generation_body<B: InferBackend>(
    factory: &(dyn Fn() -> Result<B>),
    rx: Receiver<InferRequest>,
    stats: &ReplicaStats,
    policy: &ServePolicy,
    ready: Option<SyncSender<Result<()>>>,
    warm: bool,
    drain: &AtomicBool,
) -> WorkerExit {
    let fail_ready = |ready: Option<SyncSender<Result<()>>>, msg: &str| {
        stats.consecutive_failures.fetch_add(1, Ordering::SeqCst);
        stats.crashes.inc();
        if let Some(t) = ready {
            let _ = t.send(Err(anyhow!("{msg}")));
        }
    };
    let backend = match catch_unwind(AssertUnwindSafe(factory)) {
        Ok(Ok(b)) => b,
        Ok(Err(e)) => {
            let msg = format!("backend construction failed: {e:#}");
            fail_ready(ready, &msg);
            return WorkerExit { rx: Some(rx), crash: Some(msg) };
        }
        Err(p) => {
            let msg = format!("backend construction panicked: {}", panic_message(p));
            fail_ready(ready, &msg);
            return WorkerExit { rx: Some(rx), crash: Some(msg) };
        }
    };
    if warm {
        // one real forward must succeed before this generation admits
        // traffic; its timing also seeds the routing latency signal
        let zeros = vec![0.0f32; backend.batch_size() * backend.sample_elems()];
        let t0 = Instant::now();
        match catch_unwind(AssertUnwindSafe(|| backend.infer_batch(&zeros))) {
            Ok(Ok(_)) => stats.latency.record(t0.elapsed()),
            Ok(Err(e)) => {
                let msg = format!("warmup forward failed: {e:#}");
                fail_ready(ready, &msg);
                return WorkerExit { rx: Some(rx), crash: Some(msg) };
            }
            Err(p) => {
                let msg = format!("warmup forward panicked: {}", panic_message(p));
                fail_ready(ready, &msg);
                return WorkerExit { rx: Some(rx), crash: Some(msg) };
            }
        }
    }
    if let Some(t) = ready {
        let _ = t.send(Ok(()));
    }

    let device_bs = backend.batch_size();
    let batch_policy =
        BatchPolicy { max_batch: policy.batch.max_batch.min(device_bs), ..policy.batch };
    let batcher = Batcher::new(rx, batch_policy);
    let sample = backend.sample_elems();
    let classes = backend.out_elems();
    loop {
        // expired requests are answered without touching the device
        // (the batcher re-checks expiry at flush and orders live EDF)
        let Some((live, dead)) = batcher.next_batch_partitioned() else {
            return WorkerExit { rx: None, crash: None };
        };
        for req in dead {
            let waited = req.submitted.elapsed();
            req.finish(stats, Err(ServeError::DeadlineExceeded { waited }));
        }
        if drain.load(Ordering::SeqCst) {
            // bounded drain exceeded its budget: answer stragglers
            // typed instead of spending device time on a retired version
            for req in live {
                req.finish(
                    stats,
                    Err(ServeError::ReplicaFailed {
                        reason: "drained at model version swap/retirement".into(),
                    }),
                );
            }
            continue;
        }
        if live.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        // ship exactly the live requests: expired requests were
        // partitioned out above and never reach the device, and
        // batch-native backends run `n_live` images as ONE forward
        // (the default `infer_n` zero-pads for fixed-batch artifacts)
        let n_live = live.len();
        let mut xs = vec![0.0f32; n_live * sample];
        for (i, req) in live.iter().enumerate() {
            if req.x.len() == sample {
                xs[i * sample..(i + 1) * sample].copy_from_slice(&req.x);
            }
        }
        let run = || -> Result<Vec<f32>> {
            let logits = backend.infer_n(&xs, n_live)?;
            ensure!(
                logits.len() == n_live * classes,
                "backend returned {} logits for {n_live} live requests of {classes}",
                logits.len()
            );
            Ok(logits)
        };
        match catch_unwind(AssertUnwindSafe(run)) {
            Ok(Ok(logits)) => {
                stats.latency.record(t0.elapsed());
                stats.consecutive_failures.store(0, Ordering::SeqCst);
                stats.set_circuit(CircuitState::Closed);
                for (i, req) in live.into_iter().enumerate() {
                    let reply = if req.x.len() != sample {
                        Err(ServeError::BadRequest {
                            reason: format!("sample size {} != {sample}", req.x.len()),
                        })
                    } else {
                        Ok(logits[i * classes..(i + 1) * classes].to_vec())
                    };
                    req.finish(stats, reply);
                }
            }
            Ok(Err(e)) => {
                let msg = format!("backend error: {e:#}");
                let failures = stats.consecutive_failures.fetch_add(1, Ordering::SeqCst) + 1;
                for req in live {
                    req.finish(stats, Err(ServeError::ReplicaFailed { reason: msg.clone() }));
                }
                // soft errors only end the generation once they repeat
                // to the breaker threshold; a panic ends it immediately
                if failures >= policy.breaker_threshold {
                    stats.crashes.inc();
                    return WorkerExit { rx: Some(batcher.into_inner()), crash: Some(msg) };
                }
            }
            Err(p) => {
                let msg = format!("backend panicked: {}", panic_message(p));
                stats.consecutive_failures.fetch_add(1, Ordering::SeqCst);
                stats.crashes.inc();
                for req in live {
                    req.finish(stats, Err(ServeError::ReplicaFailed { reason: msg.clone() }));
                }
                return WorkerExit { rx: Some(batcher.into_inner()), crash: Some(msg) };
            }
        }
    }
}

/// Spawn a single unsupervised worker serving a backend built by
/// `factory` (inside the thread — PJRT handles are not `Send`) under
/// `policy`.
///
/// Invariants (property-tested in rust/tests/proptest_coordinator.rs and
/// chaos-tested in rust/tests/chaos_serving.rs):
/// * every admitted request receives exactly one typed reply;
/// * device batches never exceed the backend batch size; short batches
///   run through `infer_n` (batch-native backends execute exactly the
///   live requests; the default zero-pads and discards the padding's
///   outputs), and expired requests never reach the device;
/// * replies carry the logits of their own request (no cross-wiring);
/// * admission is bounded: at most `policy.queue_depth` requests queue.
pub fn spawn_worker<B, F>(factory: F, policy: ServePolicy) -> Result<WorkerHandle>
where
    B: InferBackend,
    F: Fn() -> Result<B> + Send + Sync + 'static,
{
    let (tx, rx) = sync_channel(policy.queue_depth.max(1));
    let stats = Arc::new(ReplicaStats::new());
    let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
    // unsupervised: exit events have no listener, no warmup, and no
    // lifecycle drain flag (shutdown joins the worker directly)
    let (events_tx, _events_rx) = channel();
    let join = spawn_generation(
        Arc::new(factory),
        rx,
        Arc::clone(&stats),
        policy,
        0,
        events_tx,
        Some(ready_tx),
        false,
        Arc::new(AtomicBool::new(false)),
    );
    match ready_rx.recv() {
        Ok(Ok(())) => Ok(WorkerHandle { tx, stats, policy, join }),
        Ok(Err(e)) => {
            let _ = join.join();
            Err(e)
        }
        Err(_) => {
            let _ = join.join();
            Err(anyhow!("worker died before ready"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mock() -> MockBackend {
        MockBackend { bs: 4, sample: 3, classes: 2, delay: Duration::ZERO }
    }

    #[test]
    fn single_request_roundtrip() {
        let w = spawn_worker(move || Ok(mock()), ServePolicy::default()).unwrap();
        let rx = w.submit(vec![1.0, 2.0, 3.0]).unwrap();
        let logits = rx.recv().unwrap().unwrap();
        assert_eq!(logits, vec![6.0, 7.0]);
        w.shutdown().unwrap();
    }

    #[test]
    fn many_requests_all_answered_correctly() {
        let policy = ServePolicy {
            batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            ..ServePolicy::default()
        };
        let w = spawn_worker(move || Ok(mock()), policy).unwrap();
        let mut rxs = Vec::new();
        for i in 0..37 {
            rxs.push((i, w.submit(vec![i as f32, 0.0, 0.0]).unwrap()));
        }
        for (i, rx) in rxs {
            let logits = rx.recv().unwrap().unwrap();
            assert_eq!(logits[0], i as f32);
            assert_eq!(logits[1], i as f32 + 1.0);
        }
        assert_eq!(w.stats.outstanding.load(Ordering::SeqCst), 0);
        assert_eq!(w.stats.served.get(), 37);
        w.shutdown().unwrap();
    }

    #[test]
    fn wrong_size_request_gets_typed_error_not_hang() {
        let w = spawn_worker(move || Ok(mock()), ServePolicy::default()).unwrap();
        let rx = w.submit(vec![1.0]).unwrap(); // wrong size
        match rx.recv().unwrap() {
            Err(ServeError::BadRequest { .. }) => {}
            other => panic!("expected BadRequest, got {other:?}"),
        }
        assert_eq!(w.stats.failed.get(), 1);
        w.shutdown().unwrap();
    }

    #[test]
    fn latency_recorded_per_batch_and_per_request() {
        let w = spawn_worker(
            move || Ok(MockBackend { delay: Duration::from_micros(100), ..mock() }),
            ServePolicy::default(),
        )
        .unwrap();
        let rx = w.submit(vec![0.0; 3]).unwrap();
        rx.recv().unwrap().unwrap();
        assert_eq!(w.stats.latency.count(), 1); // one device batch
        assert_eq!(w.stats.e2e.count(), 1); // one reply
        w.shutdown().unwrap();
    }

    #[test]
    fn saturated_queue_sheds_with_typed_overloaded() {
        // one-slot batches behind a slow backend + a 2-deep queue: a
        // burst must shed, typed, and release the load signal
        let policy = ServePolicy {
            batch: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(100) },
            queue_depth: 2,
            default_deadline: Duration::from_secs(10),
            ..ServePolicy::default()
        };
        let w = spawn_worker(
            move || {
                Ok(MockBackend { bs: 1, sample: 1, classes: 1, delay: Duration::from_millis(40) })
            },
            policy,
        )
        .unwrap();
        let mut admitted = Vec::new();
        let mut shed = 0usize;
        for i in 0..10 {
            match w.submit(vec![i as f32]) {
                Ok(rx) => admitted.push(rx),
                Err(ServeError::Overloaded { .. }) => shed += 1,
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        assert!(shed >= 6, "queue_depth 2 admitted too much: shed {shed}");
        assert_eq!(w.stats.shed.get(), shed as u64);
        for rx in admitted {
            rx.recv().unwrap().unwrap();
        }
        assert_eq!(w.stats.outstanding.load(Ordering::SeqCst), 0);
        w.shutdown().unwrap();
    }

    #[test]
    fn expired_requests_get_deadline_exceeded_without_a_device_batch() {
        let policy = ServePolicy {
            batch: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(50) },
            queue_depth: 32,
            ..ServePolicy::default()
        };
        let w = spawn_worker(
            move || {
                Ok(MockBackend { bs: 1, sample: 1, classes: 1, delay: Duration::from_millis(50) })
            },
            policy,
        )
        .unwrap();
        // request 0 (generous deadline) occupies the device for 50ms;
        // requests 1..=5 expire in the queue long before their turn
        let far = Instant::now() + Duration::from_secs(30);
        let first = w.submit_with_deadline(vec![7.0], far).unwrap();
        let tight = Instant::now() + Duration::from_millis(20);
        let rxs: Vec<_> =
            (0..5).map(|i| w.submit_with_deadline(vec![i as f32], tight).unwrap()).collect();
        assert_eq!(first.recv().unwrap().unwrap(), vec![7.0]);
        for rx in rxs {
            match rx.recv().unwrap() {
                Err(ServeError::DeadlineExceeded { .. }) => {}
                other => panic!("expected DeadlineExceeded, got {other:?}"),
            }
        }
        assert_eq!(w.stats.expired.get(), 5);
        // the expired five never consumed a device batch
        assert_eq!(w.stats.latency.count(), 1);
        w.shutdown().unwrap();
    }

    #[test]
    fn submit_to_dead_replica_is_typed_and_does_not_leak_outstanding() {
        // regression: the old code incremented `outstanding` before a
        // send that could fail, permanently skewing pick() toward a dead
        // replica's peers
        let (tx, rx) = sync_channel(4);
        drop(rx);
        let stats = Arc::new(ReplicaStats::new());
        let join = std::thread::spawn(|| WorkerExit { rx: None, crash: None });
        let policy = ServePolicy::default();
        let w = WorkerHandle { tx, stats: Arc::clone(&stats), policy, join };
        match w.submit(vec![1.0]) {
            Err(ServeError::ReplicaFailed { .. }) => {}
            other => panic!("expected ReplicaFailed, got {other:?}"),
        }
        assert_eq!(stats.outstanding.load(Ordering::SeqCst), 0, "load signal leaked");
        w.shutdown().unwrap();
    }

    #[test]
    fn backend_panic_yields_typed_replica_failed_and_crash_exit() {
        struct PanicBackend;
        impl InferBackend for PanicBackend {
            fn batch_size(&self) -> usize {
                1
            }
            fn sample_elems(&self) -> usize {
                1
            }
            fn out_elems(&self) -> usize {
                1
            }
            fn infer_batch(&self, _x: &[f32]) -> Result<Vec<f32>> {
                panic!("injected fault: kaboom");
            }
        }
        let w = spawn_worker(move || Ok(PanicBackend), ServePolicy::default()).unwrap();
        let rx = w.submit(vec![1.0]).unwrap();
        match rx.recv().unwrap() {
            Err(ServeError::ReplicaFailed { reason }) => {
                assert!(reason.contains("kaboom"), "{reason}");
            }
            other => panic!("expected ReplicaFailed, got {other:?}"),
        }
        assert_eq!(w.stats.crashes.get(), 1);
        let err = w.shutdown().unwrap_err();
        assert!(err.contains("kaboom"), "{err}");
    }
}
