//! Deterministic fault injection for chaos-testing the serving layer.
//!
//! [`FlakyBackend`] wraps any [`InferBackend`] and injects failures on a
//! fixed schedule — panic every Nth batch, soft error every Mth, plus
//! seeded latency jitter — so the supervisor / circuit-breaker /
//! conservation invariants can be tested reproducibly (same seed, same
//! fault sequence). The batch counter lives in the backend instance, so
//! a respawned generation (fresh backend from the factory) restarts its
//! fault schedule — each generation fails at the same point, which is
//! exactly what makes chaos tests deterministic.

use std::cell::{Cell, RefCell};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::util::Rng;

use super::server::InferBackend;

/// An [`InferBackend`] wrapper that fails on a deterministic schedule:
/// counting batches from 1, it panics when `batches % panic_every == 0`
/// and returns an error when `batches % error_every == 0` (0 disables
/// either), after sleeping a seeded jitter in `[0, jitter)`.
pub struct FlakyBackend<B: InferBackend> {
    inner: B,
    panic_every: usize,
    error_every: usize,
    jitter: Duration,
    batches: Cell<usize>,
    rng: RefCell<Rng>,
}

impl<B: InferBackend> FlakyBackend<B> {
    /// Wrap `inner` with the given fault schedule. `panic_every` /
    /// `error_every` of 0 disable that fault; `jitter` of zero disables
    /// the latency noise.
    pub fn new(
        inner: B,
        panic_every: usize,
        error_every: usize,
        jitter: Duration,
        seed: u64,
    ) -> Self {
        FlakyBackend {
            inner,
            panic_every,
            error_every,
            jitter,
            batches: Cell::new(0),
            rng: RefCell::new(Rng::new(seed)),
        }
    }

    /// Batches this instance has been asked to run (including the ones
    /// it failed).
    pub fn batches(&self) -> usize {
        self.batches.get()
    }

    /// One scheduled fault trip, shared by both inference entry points
    /// so the batch-native path (`infer_n`) counts, jitters, panics and
    /// errors exactly like the padded one.
    fn trip(&self) -> Result<()> {
        let n = self.batches.get() + 1;
        self.batches.set(n);
        if !self.jitter.is_zero() {
            let us = self.jitter.as_micros() as usize;
            let extra = self.rng.borrow_mut().below(us.max(1));
            std::thread::sleep(Duration::from_micros(extra as u64));
        }
        if self.panic_every > 0 && n % self.panic_every == 0 {
            panic!("injected fault: panic at batch {n}");
        }
        if self.error_every > 0 && n % self.error_every == 0 {
            bail!("injected fault: error at batch {n}");
        }
        Ok(())
    }
}

impl<B: InferBackend> InferBackend for FlakyBackend<B> {
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }

    fn sample_elems(&self) -> usize {
        self.inner.sample_elems()
    }

    fn out_elems(&self) -> usize {
        self.inner.out_elems()
    }

    fn infer_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        self.trip()?;
        self.inner.infer_batch(x)
    }

    fn infer_n(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        self.trip()?;
        self.inner.infer_n(x, n)
    }
}

/// Wrap a backend factory with a fault schedule: every generation built
/// by the returned factory gets a fresh [`FlakyBackend`] (fault counter
/// restarted), which keeps crash points deterministic across respawns.
pub fn flaky_factory<B, F>(
    inner: F,
    panic_every: usize,
    error_every: usize,
    jitter: Duration,
    seed: u64,
) -> impl Fn() -> Result<FlakyBackend<B>> + Send + Sync + 'static
where
    B: InferBackend,
    F: Fn() -> Result<B> + Send + Sync + 'static,
{
    move || Ok(FlakyBackend::new(inner()?, panic_every, error_every, jitter, seed))
}

#[cfg(test)]
mod tests {
    use super::super::server::MockBackend;
    use super::*;

    fn mock() -> MockBackend {
        MockBackend { bs: 2, sample: 1, classes: 1, delay: Duration::ZERO }
    }

    #[test]
    fn faults_follow_the_schedule() {
        let f = FlakyBackend::new(mock(), 0, 3, Duration::ZERO, 1);
        let x = vec![0.0; 2];
        assert!(f.infer_batch(&x).is_ok()); // 1
        assert!(f.infer_batch(&x).is_ok()); // 2
        assert!(f.infer_batch(&x).is_err()); // 3: injected error
        assert!(f.infer_batch(&x).is_ok()); // 4
        assert_eq!(f.batches(), 4);
    }

    #[test]
    fn panic_schedule_panics() {
        let f = FlakyBackend::new(mock(), 2, 0, Duration::ZERO, 1);
        let x = vec![0.0; 2];
        assert!(f.infer_batch(&x).is_ok()); // 1
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f.infer_batch(&x)));
        assert!(r.is_err(), "batch 2 should panic");
    }

    #[test]
    fn infer_n_shares_the_fault_schedule() {
        // the batch-native entry point must advance the same counter,
        // so a chaos test's fault sequence is independent of which
        // entry point the worker uses
        let f = FlakyBackend::new(mock(), 0, 3, Duration::ZERO, 1);
        assert_eq!(f.infer_n(&[5.0], 1).unwrap(), vec![5.0]); // 1
        assert!(f.infer_batch(&[0.0, 0.0]).is_ok()); // 2
        assert!(f.infer_n(&[5.0], 1).is_err()); // 3: injected error
        assert_eq!(f.batches(), 3);
    }

    #[test]
    fn shapes_delegate_to_inner() {
        let f = FlakyBackend::new(mock(), 0, 0, Duration::ZERO, 1);
        assert_eq!(f.batch_size(), 2);
        assert_eq!(f.sample_elems(), 1);
        assert_eq!(f.out_elems(), 1);
        // no faults configured: plain delegation
        assert_eq!(f.infer_batch(&[3.0, 4.0]).unwrap(), vec![3.0, 4.0]);
    }
}
