//! Typed serving errors and the serving-hardening policy knobs.
//!
//! Every request admitted into the coordinator terminates in exactly one
//! typed outcome: `Ok(logits)` or one of the [`ServeError`] variants.
//! Requests rejected *at admission* (bounded queue full, no replica can
//! meet the deadline, every circuit open) get the same typed errors
//! synchronously from `submit`, so load-shedding is never silent.

use std::time::Duration;

use super::batcher::BatchPolicy;

/// The reply type every serving client receives: logits or a typed
/// serving error. Delivered over the per-request reply channel.
pub type ServeResult = Result<Vec<f32>, ServeError>;

/// Typed serving failure. `Display` is human-readable; match on the
/// variant for programmatic handling.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Shed at admission: every candidate replica's bounded queue was
    /// full, or no replica's queue-age signal allowed the deadline.
    Overloaded {
        /// replicas behind the router when the request was shed
        replicas: usize,
    },
    /// The request's absolute deadline passed before a device batch
    /// would have run it (dropped by the batcher, or already expired at
    /// submit time).
    DeadlineExceeded {
        /// how long the request had waited when it was dropped
        waited: Duration,
    },
    /// The replica serving (or queueing) this request failed: the
    /// backend panicked or errored on its batch, or the replica's
    /// circuit breaker is open after repeated failures.
    ReplicaFailed {
        /// what brought the replica down
        reason: String,
    },
    /// The request itself was malformed (wrong sample size).
    BadRequest {
        /// what was wrong with it
        reason: String,
    },
    /// `Router::deploy` aborted: the new version's replicas failed to
    /// construct their backend or to complete one warmup forward. The
    /// previous version (if any) was never unhooked and keeps serving.
    WarmupFailed {
        /// model slot the deploy targeted
        model: String,
        /// why the new version never became ready
        reason: String,
    },
    /// The request named a model slot the catalog has never deployed.
    UnknownModel {
        /// the name that failed to resolve
        model: String,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { replicas } => {
                write!(f, "overloaded: all {replicas} replica queue(s) saturated")
            }
            ServeError::DeadlineExceeded { waited } => {
                write!(f, "deadline exceeded after {:.1} ms", waited.as_secs_f64() * 1e3)
            }
            ServeError::ReplicaFailed { reason } => write!(f, "replica failed: {reason}"),
            ServeError::BadRequest { reason } => write!(f, "bad request: {reason}"),
            ServeError::WarmupFailed { model, reason } => {
                write!(f, "warmup of model '{model}' failed (old version keeps serving): {reason}")
            }
            ServeError::UnknownModel { model } => {
                write!(f, "unknown model '{model}': not in the catalog")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Serving-hardening knobs: batching, bounded admission, deadlines,
/// supervision. One policy is shared by every replica behind a router.
#[derive(Debug, Clone, Copy)]
pub struct ServePolicy {
    /// size-or-deadline device batching (see [`BatchPolicy`])
    pub batch: BatchPolicy,
    /// bounded per-replica request queue: admission `try_send`s and
    /// sheds with [`ServeError::Overloaded`] when full (never queues to
    /// unbounded depth)
    pub queue_depth: usize,
    /// absolute deadline assigned to requests submitted without one
    /// (`deadline = now + default_deadline`)
    pub default_deadline: Duration,
    /// consecutive failures (panics or backend errors) that trip a
    /// replica's circuit breaker open; until then the supervisor
    /// respawns crashed replicas
    pub breaker_threshold: usize,
    /// supervisor backoff before the first respawn; doubles per
    /// consecutive failure
    pub backoff_base: Duration,
    /// cap on the exponential respawn backoff
    pub backoff_cap: Duration,
    /// graceful-drain budget for a version swap / retirement /
    /// shutdown: the old generation gets this long to finish its queued
    /// requests on the old plan, after which stragglers are answered
    /// with typed `ReplicaFailed` (never silently dropped)
    pub drain_timeout: Duration,
}

impl Default for ServePolicy {
    fn default() -> Self {
        ServePolicy {
            batch: BatchPolicy::default(),
            queue_depth: 256,
            default_deadline: Duration::from_secs(1),
            breaker_threshold: 3,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(500),
            drain_timeout: Duration::from_secs(5),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = ServeError::Overloaded { replicas: 3 };
        assert!(e.to_string().contains("3 replica"));
        let e = ServeError::DeadlineExceeded { waited: Duration::from_millis(5) };
        assert!(e.to_string().contains("deadline"));
        let e = ServeError::ReplicaFailed { reason: "boom".into() };
        assert!(e.to_string().contains("boom"));
        let e = ServeError::BadRequest { reason: "size".into() };
        assert!(e.to_string().contains("size"));
        let e = ServeError::WarmupFailed { model: "resnet20".into(), reason: "no plan".into() };
        assert!(e.to_string().contains("resnet20"));
        assert!(e.to_string().contains("no plan"));
        let e = ServeError::UnknownModel { model: "mystery".into() };
        assert!(e.to_string().contains("mystery"));
    }

    #[test]
    fn default_policy_is_bounded() {
        let p = ServePolicy::default();
        assert!(p.queue_depth > 0);
        assert!(p.breaker_threshold > 0);
        assert!(p.backoff_base <= p.backoff_cap);
        assert!(p.drain_timeout > Duration::ZERO);
    }
}
