//! Replica supervision: respawn crashed worker generations on the same
//! request channel, with capped exponential backoff and a per-replica
//! circuit breaker.
//!
//! The design rests on one property of the worker (`server.rs`): a
//! crashing generation *returns its queue receiver* through its thread's
//! [`WorkerExit`] value instead of dropping it. The supervisor joins the
//! dead thread, recovers the receiver, and spawns the next generation on
//! the very same channel — so the admission side (router / clients)
//! keeps a single fixed `SyncSender` per replica slot, and requests
//! queued across the crash gap are served by the successor rather than
//! surfacing as bare `RecvError`s.
//!
//! When a slot accumulates `ServePolicy::breaker_threshold` consecutive
//! failures, its circuit trips [`CircuitState::Open`]: the router routes
//! around it and a cheap drainer thread answers queued (and any late)
//! requests with typed `ReplicaFailed` until shutdown disconnects the
//! channel. The supervisor thread itself ends once every slot has exited
//! cleanly, returning the crash log.
//!
//! Lifecycle integration (`lifecycle.rs`): the fleet shares a `drain`
//! flag. While it is clear, a crash during a graceful drain is respawned
//! like any other — queued requests still finish on the old plan. Once a
//! bounded drain trips the flag, crashed slots are not respawned;
//! their queues are drained into typed replies instead.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::error::ServePolicy;
use super::server::{
    drain_unserved, spawn_generation, CircuitState, InferBackend, InferRequest, ReplicaExited,
    ReplicaHandle, ReplicaStats, WorkerExit,
};

/// Type-erased respawner: rebuilds one slot's generation on a recovered
/// queue receiver (captures the backend factory, stats, and event path).
type Respawn = Box<dyn Fn(Receiver<InferRequest>) -> JoinHandle<WorkerExit> + Send>;

/// Supervisor-side state of one replica slot.
struct Slot {
    join: Option<JoinHandle<WorkerExit>>,
    stats: Arc<ReplicaStats>,
    respawn: Respawn,
}

/// Spawn `replicas` supervised worker slots sharing one backend
/// `factory`, plus the supervisor thread that respawns them. Returns
/// the admission handles and the supervisor's join handle (which yields
/// the crash log after shutdown). With `warm` set, every first
/// generation must complete one real forward before it counts as ready
/// (respawned generations warm too, so a replica never takes traffic
/// before proving it can serve). `drain` is the fleet's shared fail-fast
/// flag (see module docs). Fails fast — tearing down any already-started
/// slots — if a first-generation backend fails to build or warm.
pub(crate) fn spawn_supervised<B, F>(
    replicas: usize,
    factory: F,
    policy: ServePolicy,
    warm: bool,
    drain: Arc<AtomicBool>,
) -> Result<(Vec<ReplicaHandle>, JoinHandle<Vec<String>>)>
where
    B: InferBackend,
    F: Fn() -> Result<B> + Send + Sync + 'static,
{
    assert!(replicas > 0, "supervisor needs at least one replica slot");
    let factory = Arc::new(factory);
    let (events_tx, events_rx) = channel::<ReplicaExited>();
    let mut handles = Vec::with_capacity(replicas);
    let mut slots = Vec::with_capacity(replicas);
    for idx in 0..replicas {
        let (tx, rx) = sync_channel::<InferRequest>(policy.queue_depth.max(1));
        let stats = Arc::new(ReplicaStats::new());
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
        let join = spawn_generation(
            Arc::clone(&factory),
            rx,
            Arc::clone(&stats),
            policy,
            idx,
            events_tx.clone(),
            Some(ready_tx),
            warm,
            Arc::clone(&drain),
        );
        let ready = match ready_rx.recv() {
            Ok(r) => r,
            Err(_) => Err(anyhow!("replica {idx} died before ready")),
        };
        if let Err(e) = ready {
            let _ = join.join();
            drop(handles); // drops earlier slots' senders -> clean exits
            for s in slots {
                let Slot { join, .. } = s;
                if let Some(j) = join {
                    let _ = j.join();
                }
            }
            return Err(e);
        }
        let respawn: Respawn = {
            let factory = Arc::clone(&factory);
            let stats = Arc::clone(&stats);
            let events = events_tx.clone();
            let drain = Arc::clone(&drain);
            Box::new(move |rx| {
                spawn_generation(
                    Arc::clone(&factory),
                    rx,
                    Arc::clone(&stats),
                    policy,
                    idx,
                    events.clone(),
                    None,
                    warm,
                    Arc::clone(&drain),
                )
            })
        };
        handles.push(ReplicaHandle { tx, stats: Arc::clone(&stats) });
        slots.push(Slot { join: Some(join), stats, respawn });
    }
    let sup = std::thread::spawn(move || supervise(slots, events_rx, events_tx, policy, drain));
    Ok((handles, sup))
}

/// The supervisor loop: join exited generations, respawn crashed ones
/// with capped exponential backoff, trip breakers, and return the crash
/// log once every slot has exited cleanly. A slot that crashes after the
/// fleet's `drain` flag tripped is not respawned — its queue is drained
/// into typed replies, because the version it serves is being retired.
fn supervise(
    mut slots: Vec<Slot>,
    events_rx: Receiver<ReplicaExited>,
    events_tx: Sender<ReplicaExited>,
    policy: ServePolicy,
    drain: Arc<AtomicBool>,
) -> Vec<String> {
    let mut crash_log = Vec::new();
    let mut live = slots.len();
    while live > 0 {
        // the supervisor holds an events_tx clone, so recv can only fail
        // if something catastrophic dropped it — bail rather than spin
        let Ok(ReplicaExited { idx }) = events_rx.recv() else { break };
        let slot = &mut slots[idx];
        let exit = match slot.join.take() {
            Some(h) => match h.join() {
                Ok(exit) => exit,
                Err(p) => WorkerExit {
                    rx: None,
                    crash: Some(format!(
                        "worker thread panicked outside the batch guard: {}",
                        super::server::panic_message(p)
                    )),
                },
            },
            None => {
                live -= 1;
                continue;
            }
        };
        let Some(reason) = exit.crash else {
            // clean exit: shutdown drained this slot
            live -= 1;
            continue;
        };
        crash_log.push(format!("replica {idx}: {reason}"));
        let failures = slot.stats.consecutive_failures.load(Ordering::SeqCst);
        let draining = drain.load(Ordering::SeqCst);
        match exit.rx {
            Some(rx) if failures < policy.breaker_threshold && !draining => {
                // respawn on the same channel after backing off
                slot.stats.set_circuit(CircuitState::HalfOpen);
                let exp = failures.saturating_sub(1).min(16) as u32;
                let delay = policy.backoff_base.saturating_mul(1u32 << exp).min(policy.backoff_cap);
                if !delay.is_zero() {
                    std::thread::sleep(delay);
                }
                slot.join = Some((slot.respawn)(rx));
            }
            Some(rx) => {
                // breaker tripped (or the version is being drained):
                // answer queued + late requests, typed, until shutdown
                // disconnects the channel
                slot.stats.set_circuit(CircuitState::Open);
                let reason = if draining {
                    "drained at model version swap/retirement".to_string()
                } else {
                    format!("circuit open: {reason}")
                };
                slot.join = Some(spawn_drainer(
                    rx,
                    Arc::clone(&slot.stats),
                    idx,
                    events_tx.clone(),
                    reason,
                ));
            }
            None => {
                // queue lost with the thread; nothing left to serve
                slot.stats.set_circuit(CircuitState::Open);
                live -= 1;
            }
        }
    }
    crash_log
}

/// Stand-in generation for a tripped (or draining) slot: answers every
/// request on the recovered queue with a typed `ReplicaFailed` until the
/// channel disconnects at shutdown.
fn spawn_drainer(
    rx: Receiver<InferRequest>,
    stats: Arc<ReplicaStats>,
    idx: usize,
    events: Sender<ReplicaExited>,
    reason: String,
) -> JoinHandle<WorkerExit> {
    std::thread::spawn(move || {
        drain_unserved(rx, &stats, &reason);
        let _ = events.send(ReplicaExited { idx });
        WorkerExit { rx: None, crash: None }
    })
}
