//! Efficiency-figure harnesses (Figures 7, 9, 10; §5.1 op counts; §5.2
//! energy/throughput) plus the weight-distribution report (Figures 6/11).

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::models::{self, CHAIN1X1_DEPTH, CHAIN1X1_WIDTH};
use crate::quant::stats::render_histogram;
use crate::quant::{
    self, default_beta, filter_repetition_stats, weight_histogram, QuantizedWeights, Scheme,
    SparsityPattern,
};
use crate::repetition::{
    arithmetic_reduction, execute_conv2d, execute_conv2d_pool, plan_layer, plan_layer_auto,
    EngineConfig, LayerPlan,
};
use crate::simulator::{energy_reduction, simulate_conv, throughput_speedup, AcceleratorConfig};
use crate::tensor::{conv2d_gemm_pool, Conv2dGeometry, Tensor};
use crate::util::bench::{bench, BenchRecord};
use crate::util::{Pool, Rng};

use super::print_table;

/// Latent-weight source for one workload layer.
fn latent_weights(geom: &Conv2dGeometry, rng: &mut Rng) -> Tensor {
    Tensor::rand_normal(&[geom.k, geom.c, geom.r, geom.s], 0.5, rng)
}

/// Per-layer row of the Figure 7 experiment.
#[derive(Debug, Clone)]
pub struct Fig7Row {
    /// layer label, e.g. `conv03 k128c64@28`
    pub layer: String,
    /// binary scheme, min layer time (ms)
    pub t_binary_ms: f64,
    /// ternary without sparsity support, min layer time (ms)
    pub t_ternary_nosp_ms: f64,
    /// ternary with sparsity support, min layer time (ms)
    pub t_ternary_sp_ms: f64,
    /// signed-binary without sparsity support, min layer time (ms)
    pub t_sb_nosp_ms: f64,
    /// signed-binary with sparsity support, min layer time (ms)
    pub t_sb_sp_ms: f64,
    /// accounted engine ops per pass, binary
    pub ops_binary: u64,
    /// accounted engine ops per pass, ternary w/ sparsity
    pub ops_ternary_sp: u64,
    /// accounted engine ops per pass, signed-binary w/ sparsity
    pub ops_sb_sp: u64,
}

/// Figure 7 + §5.1: per-layer and aggregate speedup of B/T/SB on the
/// repetition engine, with sparsity support on/off, on this CPU.
///
/// Workload: the quantized conv layers of ResNet-18 (64px geometry from
/// the model zoo descriptors) at batch `n`. Weights are seeded gaussians
/// quantized per scheme — the same synthetic-weights methodology as the
/// paper's supp. G — or a trained checkpoint's latents when provided by
/// the caller via `trained`.
pub fn fig7(
    cfg: &RunConfig,
    batch: usize,
    subtile: usize,
    trained: Option<Vec<(Conv2dGeometry, Tensor)>>,
) -> Result<Vec<Fig7Row>> {
    let layers: Vec<(Conv2dGeometry, Tensor)> = match trained {
        Some(t) => t,
        None => {
            let mut rng = Rng::new(cfg.seed);
            models::resnet18_layers(1.0, 64, batch)
                .into_iter()
                .filter(|l| l.quantized && l.geom.r == 3)
                .map(|l| {
                    let mut g = l.geom;
                    g.n = batch;
                    let w = latent_weights(&g, &mut rng);
                    (g, w)
                })
                .collect()
        }
    };

    let mut rng = Rng::new(cfg.seed ^ 0x5eed);
    let mut rows = Vec::new();
    let mut printed = Vec::new();
    let reps = cfg.bench_reps;
    for (i, (geom, w)) in layers.iter().enumerate() {
        let x = Tensor::rand_normal(&[geom.n, geom.c, geom.h, geom.w], 1.0, &mut rng);
        let qb = quant::quantize(w, Scheme::Binary, None);
        let qt = quant::quantize(w, Scheme::ternary_default(), None);
        let qs = quant::quantize(w, Scheme::sb_default(), None);
        let mk = |q: &QuantizedWeights, sp: bool| -> LayerPlan {
            if subtile == 0 {
                // auto-tuned per scheme/geometry (paper §6: pick the tile
                // size for the configuration)
                plan_layer_auto(q, *geom, sp)
            } else {
                plan_layer(q, *geom, EngineConfig { subtile, sparsity_support: sp })
            }
        };
        // binary: sparsity support is a no-op (dense); one bar (paper)
        let pb = mk(&qb, true);
        let pt_n = mk(&qt, false);
        let pt_s = mk(&qt, true);
        let ps_n = mk(&qs, false);
        let ps_s = mk(&qs, true);
        let time = |plan: &crate::repetition::LayerPlan| {
            bench("layer", 1, reps, || {
                std::hint::black_box(execute_conv2d(plan, &x));
            })
            .min_ms()
        };
        let row = Fig7Row {
            layer: format!("conv{i:02} k{}c{}@{}", geom.k, geom.c, geom.h),
            t_binary_ms: time(&pb),
            t_ternary_nosp_ms: time(&pt_n),
            t_ternary_sp_ms: time(&pt_s),
            t_sb_nosp_ms: time(&ps_n),
            t_sb_sp_ms: time(&ps_s),
            ops_binary: pb.op_counts().total(),
            ops_ternary_sp: pt_s.op_counts().total(),
            ops_sb_sp: ps_s.op_counts().total(),
        };
        printed.push(vec![
            row.layer.clone(),
            format!("{:.2}", row.t_binary_ms),
            format!("{:.2}x", row.t_binary_ms / row.t_sb_sp_ms),
            format!("{:.2}x", row.t_binary_ms / row.t_sb_nosp_ms),
            format!("{:.2}x", row.t_binary_ms / row.t_ternary_sp_ms),
            format!("{:.2}x", row.t_binary_ms / row.t_ternary_nosp_ms),
        ]);
        rows.push(row);
    }

    print_table(
        "Figure 7 — per-layer speedup vs binary (paper: SB w/ sparsity fastest everywhere)",
        &["Layer", "B ms", "SB sp", "SB nosp", "T sp", "T nosp"],
        &printed,
    );

    // aggregate (paper §5.1: SB 1.26x over binary; layer-mean 1.75x)
    let tot =
        |f: fn(&Fig7Row) -> f64| -> f64 { rows.iter().map(f).sum::<f64>() };
    let b = tot(|r| r.t_binary_ms);
    let agg_sb = b / tot(|r| r.t_sb_sp_ms);
    let mean_sb = rows
        .iter()
        .map(|r| r.t_binary_ms / r.t_sb_sp_ms)
        .sum::<f64>()
        / rows.len() as f64;
    let ops_b = tot(|r| r.ops_binary as f64);
    let ops_s = tot(|r| r.ops_sb_sp as f64);
    let ops_t = tot(|r| r.ops_ternary_sp as f64);
    println!("\naggregate model speedup SB/sparsity vs binary: {agg_sb:.2}x (paper 1.26x)");
    println!("mean per-layer speedup SB/sparsity vs binary:  {mean_sb:.2}x (paper up to 1.75x)");
    println!(
        "arithmetic ops vs binary: SB {:+.0}% (paper -20%), ternary {:+.0}% (paper +35%)",
        100.0 * (ops_s / ops_b - 1.0),
        100.0 * (ops_t / ops_b - 1.0)
    );
    Ok(rows)
}

/// Figure 9: arithmetic reduction per ResNet-18 DNN block.
pub fn fig9(cfg: &RunConfig, subtile: usize) -> Result<()> {
    let mut rng = Rng::new(cfg.seed);
    let layers = models::resnet18_layers(1.0, 64, 1);
    let mut printed = Vec::new();
    for (i, l) in layers.iter().filter(|l| l.quantized && l.geom.r == 3).enumerate() {
        let w = latent_weights(&l.geom, &mut rng);
        let red = |s: Scheme| {
            let q = quant::quantize(&w, s, None);
            let plan = if subtile == 0 {
                plan_layer_auto(&q, l.geom, true)
            } else {
                plan_layer(&q, l.geom, EngineConfig { subtile, sparsity_support: true })
            };
            arithmetic_reduction(&plan)
        };
        printed.push(vec![
            format!("block{i:02} [{},{},{},{}]", l.geom.r, l.geom.s, l.geom.c, l.geom.k),
            format!("{:.1}x", red(Scheme::Binary)),
            format!("{:.1}x", red(Scheme::ternary_default())),
            format!("{:.1}x", red(Scheme::sb_default())),
        ]);
    }
    print_table(
        "Figure 9 — arithmetic reduction per block (paper: SB highest everywhere)",
        &["Block", "Binary", "Ternary", "Signed-Binary"],
        &printed,
    );
    Ok(())
}

/// Synthesize quantized weights at an exact target sparsity with equal
/// +/- proportions (Figure 10 methodology).
pub fn synthetic_quantized(
    geom: &Conv2dGeometry,
    scheme: Scheme,
    sparsity: f64,
    rng: &mut Rng,
) -> QuantizedWeights {
    let e = geom.c * geom.r * geom.s;
    let k = geom.k;
    let beta = default_beta(k, 0.5);
    let mut values = Tensor::zeros(&[k, geom.c, geom.r, geom.s]);
    for fi in 0..k {
        for ei in 0..e {
            let zero = rng.next_f32() < sparsity as f32;
            let v = match scheme {
                // binary is dense +-1 regardless of the sweep point
                Scheme::Binary => {
                    if rng.coin(0.5) {
                        1.0
                    } else {
                        -1.0
                    }
                }
                Scheme::Ternary { .. } => {
                    if zero {
                        0.0
                    } else if rng.coin(0.5) {
                        1.0
                    } else {
                        -1.0
                    }
                }
                Scheme::SignedBinary { .. } => {
                    if zero {
                        0.0
                    } else {
                        beta[fi]
                    }
                }
                Scheme::Fp => rng.normal(),
            };
            values.data_mut()[fi * e + ei] = v;
        }
    }
    QuantizedWeights { values, alpha: vec![1.0; k], beta: beta.clone(), scheme }
}

/// Figure 10: arithmetic reduction vs sparsity for a [3,3,512,512] block.
pub fn fig10(cfg: &RunConfig, subtile: usize, points: usize) -> Result<()> {
    let geom = Conv2dGeometry {
        n: 1, c: 512, h: 7, w: 7, k: 512, r: 3, s: 3, stride: 1, padding: 1,
    };
    let mut printed = Vec::new();
    for i in 0..=points {
        let s = i as f64 / points as f64;
        let mut rng = Rng::new(cfg.seed + i as u64);
        let red = |scheme: Scheme, rng: &mut Rng| {
            let q = synthetic_quantized(&geom, scheme, s, rng);
            let plan = if subtile == 0 {
                plan_layer_auto(&q, geom, true)
            } else {
                plan_layer(&q, geom, EngineConfig { subtile, sparsity_support: true })
            };
            arithmetic_reduction(&plan)
        };
        printed.push(vec![
            format!("{s:.2}"),
            format!("{:.1}", red(Scheme::Binary, &mut rng)),
            format!("{:.1}", red(Scheme::ternary_default(), &mut rng)),
            format!("{:.1}", red(Scheme::sb_default(), &mut rng)),
        ]);
    }
    print_table(
        "Figure 10 — arithmetic reduction vs sparsity, [3,3,512,512] (paper: SB >= both; T dips then crosses B at high sparsity)",
        &["Sparsity", "Binary", "Ternary", "Signed-Binary"],
        &printed,
    );
    Ok(())
}

/// §5.2 energy + throughput: dense vs sparse on the SIGMA-like simulator.
pub fn energy(_cfg: &RunConfig, sparsity: f64) -> Result<()> {
    let acc = AcceleratorConfig::default();
    let layers = models::resnet18_layers(1.0, 64, 1);
    let mut printed = Vec::new();
    let (mut e_sum, mut t_sum, mut n) = (0.0, 0.0, 0);
    for (i, l) in layers.iter().filter(|l| l.quantized && l.geom.r == 3).enumerate() {
        let er = energy_reduction(&l.geom, sparsity, &acc);
        let ts = throughput_speedup(&l.geom, sparsity, &acc);
        let dense = simulate_conv(&l.geom, 1.0, &acc);
        printed.push(vec![
            format!("conv{i:02} k{}c{}", l.geom.k, l.geom.c),
            format!("{:.0}", dense.cycles),
            format!("{er:.2}x"),
            format!("{ts:.2}x"),
        ]);
        e_sum += er;
        t_sum += ts;
        n += 1;
    }
    print_table(
        &format!("§5.2 — SIGMA-like simulator, dense vs {:.0}% sparsity", sparsity * 100.0),
        &["Layer", "dense cycles", "energy reduction", "throughput speedup"],
        &printed,
    );
    println!(
        "\nmean energy reduction {:.2}x (paper ~2x); mean throughput speedup {:.2}x (ideal {:.2}x, paper: realized 1.26-1.75x on CPU)",
        e_sum / n as f64,
        t_sum / n as f64,
        1.0 / (1.0 - sparsity)
    );
    Ok(())
}

/// Figures 6 & 11 — weight-distribution report from a trained checkpoint.
pub fn report_weights(cfg: &RunConfig, name: &str) -> Result<()> {
    let (_, state) = super::trained_state(cfg, name).ok_or_else(|| {
        anyhow!("no checkpoint for {name} in {} — train it first", cfg.out_dir.display())
    })?;
    // group conv weights and betas
    let mut printed = Vec::new();
    let mut all_latent: Vec<f32> = Vec::new();
    for (spec, data) in &state {
        if spec.group == "params" && spec.name.ends_with(".conv.w") && spec.shape.len() == 4 {
            let k = spec.shape[0];
            let beta_name = spec.name.replace(".w", ".beta");
            let beta = state
                .iter()
                .find(|(s, _)| s.name == beta_name)
                .map(|(_, d)| d.clone());
            if beta.is_none() {
                continue; // unquantized stem
            }
            all_latent.extend_from_slice(data);
            let w = Tensor::new(&spec.shape, data.clone());
            let q = quant::quantize_signed_binary(&w, beta.as_ref().unwrap(), 0.05, 1);
            let st = filter_repetition_stats(&q.values, k);
            let pos = q.values.data().iter().filter(|v| **v > 0.0).count();
            let neg = q.values.data().iter().filter(|v| **v < 0.0).count();
            let tot = q.values.len();
            printed.push(vec![
                spec.name.clone(),
                format!("{:.0}%", 100.0 * pos as f64 / tot as f64),
                format!("{:.0}%", 100.0 * neg as f64 / tot as f64),
                format!("{:.0}%", 100.0 * (1.0 - st.density)),
                format!("{:.2}", st.mean_unique_values),
            ]);
        }
    }
    print_table(
        "Figure 6a — quantized-weight distribution per conv (paper: ~equal +/-, filters single-signed)",
        &["Layer", "+alpha", "-alpha", "zero", "uniq vals/filter"],
        &printed,
    );

    let h = weight_histogram(&all_latent, -1.05, 1.05, 42);
    println!("\nFigure 6b / 11 — latent full-precision weights over all quantized convs");
    println!(
        "mean {:.4}  std {:.4}  excess kurtosis {:.2} (Laplace ~3, Gaussian ~0)",
        h.mean, h.std, h.excess_kurtosis
    );
    println!("{}", render_histogram(&h, 60));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_quantized_hits_target_sparsity() {
        let geom =
            Conv2dGeometry { n: 1, c: 64, h: 4, w: 4, k: 64, r: 3, s: 3, stride: 1, padding: 1 };
        let mut rng = Rng::new(1);
        let q = synthetic_quantized(&geom, Scheme::sb_default(), 0.6, &mut rng);
        let sp = q.sparsity();
        assert!((sp - 0.6).abs() < 0.02, "sparsity {sp}");
        // binary stays dense
        let qb = synthetic_quantized(&geom, Scheme::Binary, 0.6, &mut rng);
        assert_eq!(qb.sparsity(), 0.0);
    }

    #[test]
    fn sb_synthetic_single_signed_per_filter() {
        let geom =
            Conv2dGeometry { n: 1, c: 16, h: 4, w: 4, k: 8, r: 3, s: 3, stride: 1, padding: 1 };
        let mut rng = Rng::new(2);
        let q = synthetic_quantized(&geom, Scheme::sb_default(), 0.3, &mut rng);
        let e = 16 * 9;
        for fi in 0..8 {
            let row = &q.values.data()[fi * e..(fi + 1) * e];
            assert!(!(row.iter().any(|v| *v > 0.0) && row.iter().any(|v| *v < 0.0)));
        }
    }
}

/// One measured point of the thread-scaling study (dense baseline or
/// repetition engine at a fixed pool width).
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    /// "dense_gemm" or "engine_sb"
    pub op: String,
    /// workload geometry, e.g. "64x64x28x28 3x3"
    pub shape: String,
    /// pool width the point was measured at
    pub threads: usize,
    /// minimum wall time over the bench reps
    pub min_ns: u64,
    /// dense-equivalent GFLOP/s (2 * dense MACs / min time) — the same
    /// numerator for both ops, so the ratio is the honest speedup
    pub gflops: f64,
}

impl ScalingPoint {
    /// The persisted (`BENCH_*.json`) form of this measurement — the one
    /// mapping shared by `plum bench repetition` and the bench binary.
    pub fn to_record(&self) -> BenchRecord {
        BenchRecord {
            op: self.op.clone(),
            shape: self.shape.clone(),
            threads: self.threads,
            min_ns: self.min_ns,
            gflops: self.gflops,
        }
    }
}

/// The full perf-trajectory study behind `BENCH_repetition.json`:
/// executor scaling (dense vs engine) plus plan-build cold-start
/// scaling on one thread ladder. The single orchestration shared by
/// `plum bench repetition` and the `bench_repetition` cargo-bench
/// binary, so the CI artifact and the local bench can never diverge.
/// Returns the ladder and every measured point.
pub fn repetition_study(
    cfg: &RunConfig,
    batch: usize,
    thread_cap: usize,
) -> Result<(Vec<usize>, Vec<ScalingPoint>)> {
    let geom = resnet_block_geometry(batch);
    let threads = default_thread_ladder(thread_cap);
    let mut points = engine_scaling(cfg, geom, &threads)?;
    points.extend(plan_build_scaling(cfg, &threads)?);
    Ok((threads, points))
}

/// The serving-robustness study behind `BENCH_serving.json`: one
/// open-loop load run ([`bench_serve_engine`]) rendered as a bench
/// series so the CI compare gate can watch serving latency quantiles,
/// goodput and shed rate the same way it watches kernel perf. Shared by
/// `plum bench serve` and CI. Latency points carry `gflops = 0` (lower
/// `min_ns` is better); the throughput point carries goodput as its
/// "gflops" (higher is better) with `min_ns = 0` sentinel.
///
/// With `swap_at = Some(s)` the run doubles as the hot-swap drill
/// (`plum bench serve --swap-at S`): a fresh model version is deployed
/// `s` seconds into the window under load, and the series additionally
/// carries `swap_drain_ms` (old-generation drain time, ns), `swap_p99`
/// (end-to-end p99 measured *across* the swap) and `swap_dropped`
/// (replies lost without a typed error — gated to zero).
///
/// When `cfg.max_batch > 1` the study appends a **batched-goodput
/// comparison**: a second short run under the same offered load with
/// the batcher capped at one sample per engine forward, recorded as
/// `serve_throughput_b1` — the gap to `serve_throughput` is the
/// batch-first serving win (one `forward_batch` per admitted batch).
pub fn serving_study(
    cfg: &RunConfig,
    model: &str,
    image: usize,
    rps: f64,
    duration_s: f64,
    swap_at: Option<f64>,
) -> Result<(crate::experiments::serving::ServeBenchReport, Vec<ScalingPoint>)> {
    let report = crate::experiments::serving::bench_serve_engine_opts(
        cfg, model, image, rps, duration_s, swap_at,
    )?;
    let shape = format!(
        "{} {}px r{} rps{}",
        report.model, image, report.replicas, report.target_rps
    );
    let threads = Pool::global().threads();
    let lat = |op: &str, us: u64| ScalingPoint {
        op: op.to_string(),
        shape: shape.clone(),
        threads,
        min_ns: us.saturating_mul(1000),
        gflops: 0.0,
    };
    let mut points = vec![
        lat("serve_p50", report.p50_us),
        lat("serve_p95", report.p95_us),
        lat("serve_p99", report.p99_us),
        ScalingPoint {
            op: "serve_throughput".to_string(),
            shape: shape.clone(),
            threads,
            min_ns: 0,
            gflops: report.achieved_rps,
        },
        ScalingPoint {
            op: "serve_shed_ppm".to_string(),
            shape: shape.clone(),
            threads,
            min_ns: report.shed_ppm,
            gflops: 0.0,
        },
    ];
    if cfg.max_batch > 1 {
        // batched-goodput comparison: same model, same offered load, but
        // the batcher capped at one sample per engine forward (a short
        // window is enough — goodput saturates in well under a second)
        let b1_cfg = RunConfig { max_batch: 1, ..cfg.clone() };
        let b1 = crate::experiments::serving::bench_serve_engine_opts(
            &b1_cfg,
            model,
            image,
            rps,
            duration_s.min(1.0),
            None,
        )?;
        println!(
            "batched goodput: max_batch {} achieved {:.0} rps vs single-sample {:.0} rps \
             ({:.2}x)",
            cfg.max_batch,
            report.achieved_rps,
            b1.achieved_rps,
            report.achieved_rps / b1.achieved_rps.max(1e-9),
        );
        points.push(ScalingPoint {
            op: "serve_throughput_b1".to_string(),
            shape: shape.clone(),
            threads,
            min_ns: 0,
            gflops: b1.achieved_rps,
        });
    }
    if let Some(swap) = &report.swap {
        points.push(ScalingPoint {
            op: "swap_drain_ms".to_string(),
            shape: shape.clone(),
            threads,
            min_ns: (swap.drain_ms.max(0.0) * 1e6) as u64,
            gflops: 0.0,
        });
        points.push(lat("swap_p99", report.p99_us));
        points.push(ScalingPoint {
            op: "swap_dropped".to_string(),
            shape,
            threads,
            min_ns: report.dropped as u64,
            gflops: 0.0,
        });
    }
    Ok((report, points))
}

/// Persist a scaling series in the `BENCH_*.json` record format;
/// returns the record count.
pub fn write_scaling_records(
    points: &[ScalingPoint],
    out: &std::path::Path,
) -> std::io::Result<usize> {
    let records: Vec<BenchRecord> = points.iter().map(ScalingPoint::to_record).collect();
    crate::util::bench::write_bench_json(out, &records)?;
    Ok(records.len())
}

/// The scaling study's default workload: a ResNet-shaped mid-network
/// block (64x64x28x28, 3x3).
pub fn resnet_block_geometry(batch: usize) -> Conv2dGeometry {
    Conv2dGeometry {
        n: batch.max(1),
        c: 64,
        h: 28,
        w: 28,
        k: 64,
        r: 3,
        s: 3,
        stride: 1,
        padding: 1,
    }
}

/// Thread ladder {1, 2, 4, ..., max}; `cap = 0` uses the machine's
/// available parallelism.
pub fn default_thread_ladder(cap: usize) -> Vec<usize> {
    let max = if cap > 0 {
        cap
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    let mut ladder = vec![1];
    let mut t = 2;
    while t < max {
        ladder.push(t);
        t *= 2;
    }
    if max > 1 {
        ladder.push(max);
    }
    ladder
}

/// Dense-vs-engine thread scaling on one conv block: times
/// `conv2d_gemm` and the repetition engine at each pool width, checks
/// that every engine output is bit-identical to the first width's, and
/// prints speedup columns. `bench_repetition` wraps this and persists
/// the points as BENCH_repetition.json.
pub fn engine_scaling(
    cfg: &RunConfig,
    geom: Conv2dGeometry,
    threads: &[usize],
) -> Result<Vec<ScalingPoint>> {
    if threads.is_empty() {
        return Err(anyhow!("no thread counts requested"));
    }
    let mut rng = Rng::new(cfg.seed);
    let w = latent_weights(&geom, &mut rng);
    let x = Tensor::rand_normal(&[geom.n, geom.c, geom.h, geom.w], 1.0, &mut rng);
    let q = quant::quantize(&w, Scheme::sb_default(), None);
    let plan = plan_layer_auto(&q, geom, true);
    let shape = format!(
        "{}x{}x{}x{} {}x{}",
        geom.k, geom.c, geom.h, geom.w, geom.r, geom.s
    );
    let flops = 2.0 * geom.dense_macs() as f64;
    let reps = cfg.bench_reps;
    let mut points = Vec::new();
    let mut printed = Vec::new();
    let mut base_out: Option<Vec<f32>> = None;
    let mut base_engine_ns = 0u64;
    let mut base_dense_ns = 0u64;
    for &t in threads {
        let pool = Pool::new(t);
        let rd = bench(&format!("dense t{t}"), 1, reps, || {
            std::hint::black_box(conv2d_gemm_pool(&x, &q.values, geom.stride, geom.padding, &pool));
        });
        let re = bench(&format!("engine t{t}"), 1, reps, || {
            std::hint::black_box(execute_conv2d_pool(&plan, &x, &pool));
        });
        // determinism guarantee: every width produces the same bits
        let out = execute_conv2d_pool(&plan, &x, &pool);
        if base_out.is_none() {
            base_out = Some(out.into_data());
            base_engine_ns = re.min_ns;
            base_dense_ns = rd.min_ns;
        } else if Some(out.data()) != base_out.as_deref() {
            return Err(anyhow!(
                "engine output at {t} threads differs from {} threads",
                threads[0]
            ));
        }
        printed.push(vec![
            format!("{t}"),
            format!("{:.2}", rd.min_ns as f64 / 1e6),
            format!("{:.2}x", base_dense_ns as f64 / rd.min_ns as f64),
            format!("{:.2}", re.min_ns as f64 / 1e6),
            format!("{:.2}x", base_engine_ns as f64 / re.min_ns as f64),
            format!("{:.2}x", rd.min_ns as f64 / re.min_ns as f64),
        ]);
        points.push(ScalingPoint {
            op: "dense_gemm".into(),
            shape: shape.clone(),
            threads: t,
            min_ns: rd.min_ns,
            gflops: flops / rd.min_ns as f64,
        });
        points.push(ScalingPoint {
            op: "engine_sb".into(),
            shape: shape.clone(),
            threads: t,
            min_ns: re.min_ns,
            gflops: flops / re.min_ns as f64,
        });
    }
    print_table(
        &format!("Thread scaling — {shape} (SB engine vs dense GEMM, min of {reps} reps)"),
        &[
            "Threads",
            "dense ms",
            "dense speedup",
            "engine ms",
            "engine speedup",
            "engine vs dense",
        ],
        &printed,
    );
    Ok(points)
}

/// Plan-construction thread scaling: builds the engine plans for every
/// quantized 3x3 conv of ResNet-18 at each pool width, asserts the
/// arenas are **byte-identical** across widths (the parallel build's
/// determinism contract), and reports cold-start build time. The
/// `gflops` field carries the dense-equivalent GFLOP/s the built plans
/// *represent* per second of planning — a machine-scaled throughput
/// number comparable across commits, like the executor records.
pub fn plan_build_scaling(cfg: &RunConfig, threads: &[usize]) -> Result<Vec<ScalingPoint>> {
    use crate::repetition::plan_layer_pool;
    if threads.is_empty() {
        return Err(anyhow!("no thread counts requested"));
    }
    let mut rng = Rng::new(cfg.seed);
    let layers: Vec<(Conv2dGeometry, QuantizedWeights)> = models::resnet18_layers(1.0, 64, 1)
        .into_iter()
        .filter(|l| l.quantized && l.geom.r == 3)
        .map(|l| {
            let w = latent_weights(&l.geom, &mut rng);
            (l.geom, quant::quantize(&w, Scheme::sb_default(), None))
        })
        .collect();
    let ecfg = EngineConfig::default();
    let shape = format!("resnet18 {}x3x3 layers", layers.len());
    let flops: f64 = layers.iter().map(|(g, _)| 2.0 * g.dense_macs() as f64).sum();
    let reps = cfg.bench_reps;
    let mut points = Vec::new();
    let mut printed = Vec::new();
    let mut base_plans: Option<Vec<LayerPlan>> = None;
    let mut base_ns = 0u64;
    for &t in threads {
        let pool = Pool::new(t);
        let r = bench(&format!("plan build t{t}"), 1, reps, || {
            for (g, q) in &layers {
                std::hint::black_box(plan_layer_pool(q, *g, ecfg, &pool));
            }
        });
        let plans: Vec<LayerPlan> = layers
            .iter()
            .map(|(g, q)| plan_layer_pool(q, *g, ecfg, &pool))
            .collect();
        match &base_plans {
            None => {
                base_plans = Some(plans);
                base_ns = r.min_ns;
            }
            Some(base) => {
                for (li, (a, b)) in base.iter().zip(&plans).enumerate() {
                    if a.arena != b.arena
                        || a.combine != b.combine
                        || a.unique_of_filter != b.unique_of_filter
                    {
                        return Err(anyhow!(
                            "plan for layer {li} at {t} threads differs from {} threads",
                            threads[0]
                        ));
                    }
                }
            }
        }
        printed.push(vec![
            format!("{t}"),
            format!("{:.2}", r.min_ns as f64 / 1e6),
            format!("{:.2}x", base_ns as f64 / r.min_ns as f64),
        ]);
        points.push(ScalingPoint {
            op: "plan_build".into(),
            shape: shape.clone(),
            threads: t,
            min_ns: r.min_ns,
            gflops: flops / r.min_ns as f64,
        });
    }
    print_table(
        &format!("Plan-build scaling — {shape} (byte-identical arena at every width)"),
        &["Threads", "build ms", "speedup"],
        &printed,
    );
    Ok(points)
}

/// Candidate execution tiles (output pixels per work item) searched by
/// `plum bench network --tile 0`. Deliberately includes sizes that are
/// NOT `PIXEL_BLOCK` multiples (20, 28): those are legal for unfused
/// plans but undefined for blocked patch I/O, so the auto-tuner must
/// skip them whenever cross-layer patch fusion is on — the documented
/// tile-alignment constraint surfaced at tuning time rather than as an
/// executor assert.
pub const EXEC_TILE_CANDIDATES: &[usize] = &[16, 20, 24, 28, 32, 48, 64];

/// Pick the fastest execution tile for one compiled network by timing a
/// forward per candidate at the widest pool. Candidates that cannot
/// carry blocked patch I/O are skipped (and reported) when the plan has
/// patch-fused edges; the tile never changes bits, only time.
fn pick_exec_tile(
    plan: &std::sync::Arc<crate::network::NetworkPlan>,
    input: &[f32],
    pool: &Pool,
    reps: usize,
) -> Result<usize> {
    use crate::network::NetworkExecutor;
    use crate::repetition::tile_supports_blocked_io;
    let fused = plan.patch_fused_edges() > 0;
    let mut skipped = Vec::new();
    let mut best: Option<(usize, u64)> = None;
    for &t in EXEC_TILE_CANDIDATES {
        if fused && !tile_supports_blocked_io(t) {
            skipped.push(t);
            continue;
        }
        let mut exec = NetworkExecutor::with_tile(std::sync::Arc::clone(plan), t)?;
        let r = bench(&format!("tile {t}"), 1, reps.clamp(1, 3), || {
            std::hint::black_box(exec.forward_pool(input, pool));
        });
        if best.map(|(_, ns)| r.min_ns < ns).unwrap_or(true) {
            best = Some((t, r.min_ns));
        }
    }
    let (tile, _) = best.expect("EXEC_TILE_CANDIDATES holds PIXEL_BLOCK multiples");
    if !skipped.is_empty() {
        println!(
            "  tile auto-tune: picked {tile}; skipped non-PIXEL_BLOCK-aligned {skipped:?} \
             (patch fusion is on)"
        );
    } else {
        println!("  tile auto-tune: picked {tile}");
    }
    Ok(tile)
}

/// Time one compiled network's full forward at every pool width,
/// asserting cross-width bit-equality (and, when `expect` is given,
/// bit-equality against that baseline — the fused-vs-unfused check).
/// Returns the measured points plus the first-width output.
#[allow(clippy::too_many_arguments)]
fn network_forward_ladder(
    plan: &std::sync::Arc<crate::network::NetworkPlan>,
    op: &str,
    shape: &str,
    threads: &[usize],
    input: &[f32],
    reps: usize,
    tile: usize,
    expect: Option<&[f32]>,
) -> Result<(Vec<ScalingPoint>, Vec<f32>)> {
    use crate::network::NetworkExecutor;
    let flops = 2.0 * plan.dense_macs() as f64;
    let batch = plan.batch();
    let mut points = Vec::new();
    let mut printed = Vec::new();
    let mut base_out: Option<Vec<f32>> = None;
    let mut base_ns = 0u64;
    for &t in threads {
        let pool = Pool::new(t);
        let mut exec = NetworkExecutor::with_tile(std::sync::Arc::clone(plan), tile)?;
        let r = bench(&format!("{op} t{t}"), 1, reps, || {
            std::hint::black_box(exec.forward_pool(input, &pool));
        });
        // determinism guarantee: every width produces the same bits
        let out = exec.forward_pool(input, &pool).to_vec();
        if let Some(e) = expect {
            if out != e {
                return Err(anyhow!("{op} at {t} threads differs from the unfused baseline"));
            }
        }
        if base_out.is_none() {
            base_out = Some(out);
            base_ns = r.min_ns;
        } else if Some(&out) != base_out.as_ref() {
            return Err(anyhow!("{op} at {t} threads differs from {} threads", threads[0]));
        }
        printed.push(vec![
            format!("{t}"),
            format!("{:.2}", r.min_ns as f64 / 1e6),
            format!("{:.2}x", base_ns as f64 / r.min_ns as f64),
            format!("{:.1}", batch as f64 * 1e9 / r.min_ns as f64),
        ]);
        points.push(ScalingPoint {
            op: op.into(),
            shape: shape.into(),
            threads: t,
            min_ns: r.min_ns,
            gflops: flops / r.min_ns as f64,
        });
    }
    print_table(
        &format!("Network forward scaling — {op} [{shape}] (bit-identical at every width)"),
        &["Threads", "forward ms", "speedup", "img/s"],
        &printed,
    );
    Ok((points, base_out.unwrap()))
}

/// The `bench network` batch ladder: runtime batch sizes every run
/// measures (and, for b4/b16, CI gates via BENCH_network.json).
pub const BATCH_LADDER: &[usize] = &[1, 4, 16, 64];

/// The always-on `bench network` batch ladder: one CIFAR ResNet-`depth`
/// plan compiled at the widest rung of [`BATCH_LADDER`] and run at
/// every `b` in it. Before any timing, each rung is **gated**:
/// `forward_batch(b)` must be bitwise-identical to `b` independent b=1
/// forwards through the same plan — at every pool width, with patch
/// fusion on AND off — so a record is only ever emitted for a
/// proven-correct batched forward (the PR-9 acceptance criterion,
/// mirrored at small geometries by `tests/proptest_batch.rs`). Records
/// land as `network_forward_b{N}` with per-image-honest GFLOP/s.
fn network_batch_ladder(
    cfg: &RunConfig,
    depth: usize,
    ecfg: EngineConfig,
    threads: &[usize],
    reps: usize,
    tile: usize,
) -> Result<Vec<ScalingPoint>> {
    use crate::network::{NetworkExecutor, NetworkPlan};
    use std::sync::Arc;

    let bmax = *BATCH_LADDER.last().unwrap();
    let layers = models::cifar_resnet_layers(depth, 1.0, 32, bmax);
    let fused =
        Arc::new(NetworkPlan::compile_seeded(&layers, ecfg, Scheme::sb_default(), cfg.seed)?);
    let unfused = Arc::new(fused.without_patch_fusion());
    let sample = fused.sample_elems();
    let shape = format!("resnet{depth} bmax{bmax} 32px");
    let macs_per_image = fused.dense_macs() as f64 / bmax as f64;
    let mut rng = Rng::new(cfg.seed ^ 0xbac4);
    let mut input = vec![0.0f32; bmax * sample];
    rng.fill_normal(&mut input, 1.0);
    let mut points = Vec::new();
    let mut printed = Vec::new();
    println!("\nbatch ladder [{shape}]: gating forward_batch == N x b1 before timing");
    for &b in BATCH_LADDER {
        let xb = &input[..b * sample];
        // pre-timing acceptance gate: the batched forward must
        // reproduce b independent single-image forwards bit for bit at
        // every pool width, fused and unfused, and all of those
        // results must agree with each other (cross-width,
        // cross-variant)
        let mut reference: Option<Vec<f32>> = None;
        for &t in threads {
            let pool = Pool::new(t);
            for (plan, label) in [(&fused, "fused"), (&unfused, "unfused")] {
                let mut exec = NetworkExecutor::with_tile(Arc::clone(plan), tile)?;
                let got = exec.forward_batch_pool(xb, b, &pool).to_vec();
                let mut singles = NetworkExecutor::with_tile(Arc::clone(plan), tile)?;
                let mut want = Vec::with_capacity(got.len());
                for i in 0..b {
                    want.extend_from_slice(
                        singles.forward_batch_pool(&xb[i * sample..(i + 1) * sample], 1, &pool),
                    );
                }
                if got != want {
                    return Err(anyhow!(
                        "batch ladder b={b}: {label} forward_batch differs from {b} \
                         independent b=1 forwards at {t} threads"
                    ));
                }
                match &reference {
                    None => reference = Some(got),
                    Some(r) if &got != r => {
                        return Err(anyhow!(
                            "batch ladder b={b}: {label} at {t} threads differs from the \
                             first width/variant"
                        ));
                    }
                    Some(_) => {}
                }
            }
        }
        // timing (fused plan): reps shrink with b so the b64 rung costs
        // about as much wall time as the b1 rung
        let breps = (reps / b).max(1);
        for &t in threads {
            let pool = Pool::new(t);
            let mut exec = NetworkExecutor::with_tile(Arc::clone(&fused), tile)?;
            let r = bench(&format!("forward_batch b{b} t{t}"), 1, breps, || {
                std::hint::black_box(exec.forward_batch_pool(xb, b, &pool));
            });
            printed.push(vec![
                format!("{b}"),
                format!("{t}"),
                format!("{:.2}", r.min_ns as f64 / 1e6),
                format!("{:.1}", b as f64 * 1e9 / r.min_ns as f64),
            ]);
            points.push(ScalingPoint {
                op: format!("network_forward_b{b}"),
                shape: shape.clone(),
                threads: t,
                min_ns: r.min_ns,
                gflops: 2.0 * macs_per_image * b as f64 / r.min_ns as f64,
            });
        }
    }
    print_table(
        &format!("Batch ladder — {shape} (each rung gated == N x b1, fused+unfused)"),
        &["b", "Threads", "forward ms", "img/s"],
        &printed,
    );
    Ok(points)
}

/// `plum bench network`: full-network forward scaling through the
/// network executor. Three workloads, compiled once each and timed
/// end-to-end at each pool width, each in two variants — cross-layer
/// patch reuse **disabled** (`network_forward`) and **enabled**
/// (`network_forward_fused`) — so the reuse win stays visible in
/// `plum bench compare` on every topology, not just the 1x1 chain:
///
/// * a whole CIFAR ResNet-`depth` (sb scheme, option-A shortcuts;
///   block-internal 3x3 edges fuse via the blocked gather);
/// * `resnet18c` (projection shortcuts; strided/3x3 fused edges);
/// * the consecutive-1x1 `chain1x1` model (the exact shape serving
///   uses: `models::{CHAIN1X1_DEPTH, CHAIN1X1_WIDTH}`).
///
/// `tile` pins the execution tile; `0` auto-tunes it over
/// [`EXEC_TILE_CANDIDATES`] per workload (skipping candidates that
/// cannot carry blocked I/O whenever the plan has fused edges). Every
/// series is verified bit-identical across pool widths, and every fused
/// run is verified bit-identical to its unfused baseline. The study
/// always finishes with the [`BATCH_LADDER`] (`network_forward_b{N}`
/// records, each rung gated bitwise against N independent b=1 forwards
/// before timing — see [`network_batch_ladder`]). Records feed the
/// perf-trajectory gate (committed baseline: BENCH_network.json).
pub fn network_forward_study(
    cfg: &RunConfig,
    depth: usize,
    batch: usize,
    subtile: usize,
    thread_cap: usize,
    tile: usize,
) -> Result<(Vec<usize>, Vec<ScalingPoint>)> {
    use crate::network::NetworkPlan;
    use std::sync::Arc;

    let batch = batch.max(1);
    // every study workload carries patch-fused edges (ensured below), so
    // an explicitly-pinned tile must be blocked-I/O-capable — reject it
    // here, before any ladder has burned bench time
    anyhow::ensure!(
        tile == 0 || crate::repetition::tile_supports_blocked_io(tile),
        "--tile {tile} cannot carry blocked patch I/O (not a PIXEL_BLOCK multiple) and every \
         bench-network workload runs patch-fused — pass a multiple of 8, or 0 to auto-tune"
    );
    let ecfg = EngineConfig { subtile, sparsity_support: true };
    let threads = default_thread_ladder(thread_cap);
    let reps = cfg.bench_reps;
    let mut rng = Rng::new(cfg.seed ^ 0x5eed);
    let mut points = Vec::new();

    let workloads: Vec<(String, Vec<models::ConvLayerDesc>)> = vec![
        (
            format!("resnet{depth} b{batch} 32px"),
            models::cifar_resnet_layers(depth, 1.0, 32, batch),
        ),
        (
            format!("resnet18c b{batch} 32px"),
            models::cifar_resnet18_layers(1.0, 32, batch),
        ),
        (
            format!("chain1x1 d{CHAIN1X1_DEPTH} w{CHAIN1X1_WIDTH} b{batch} 32px"),
            models::conv1x1_chain_layers(CHAIN1X1_DEPTH, CHAIN1X1_WIDTH, 32, batch),
        ),
    ];

    // the batch ladder reuses the resnet workload's (auto-tuned) tile
    let mut ladder_tile = tile;
    for (wi, (shape, layers)) in workloads.into_iter().enumerate() {
        let t_compile = std::time::Instant::now();
        let fused = Arc::new(NetworkPlan::compile_seeded(
            &layers,
            ecfg,
            Scheme::sb_default(),
            cfg.seed,
        )?);
        let compile_ms = t_compile.elapsed().as_secs_f64() * 1e3;
        let unfused = Arc::new(fused.without_patch_fusion());
        let ops = fused.op_counts().total();
        let dense_ops = 2 * fused.dense_macs();
        println!(
            "{}{shape}: {} layers compiled in {compile_ms:.1} ms; {} engine ops/pass vs {} \
             dense ops ({:.1}x arithmetic reduction); {} patch-fused edge(s); packed weights \
             {} KiB; effectual density {:.1}%",
            if wi == 0 { "" } else { "\n" },
            fused.num_layers(),
            ops,
            dense_ops,
            dense_ops as f64 / ops.max(1) as f64,
            fused.patch_fused_edges(),
            fused.weight_bits / 8 / 1024,
            100.0 * fused.effectual_density()
        );
        anyhow::ensure!(
            fused.patch_fused_edges() > 0,
            "{shape}: expected cross-layer patch reuse to engage"
        );
        let mut input = vec![0.0f32; fused.input_elems()];
        rng.fill_normal(&mut input, 1.0);
        let exec_tile = if tile == 0 {
            // tune on the fused plan at the widest pool; the choice only
            // moves time, never bits, so both variants share it
            pick_exec_tile(&fused, &input, &Pool::new(*threads.last().unwrap()), reps)?
        } else {
            tile
        };
        if wi == 0 {
            ladder_tile = exec_tile;
        }
        let (pts, base) = network_forward_ladder(
            &unfused,
            "network_forward",
            &shape,
            &threads,
            &input,
            reps,
            exec_tile,
            None,
        )?;
        points.extend(pts);
        // patch reuse must change the time, never the bits
        let (pts, _) = network_forward_ladder(
            &fused,
            "network_forward_fused",
            &shape,
            &threads,
            &input,
            reps,
            exec_tile,
            Some(&base),
        )?;
        points.extend(pts);
    }

    // batch-first acceptance: the always-on batch ladder (one plan at
    // the widest rung, every rung gated bitwise before timing)
    points.extend(network_batch_ladder(cfg, depth, ecfg, &threads, reps, ladder_tile)?);

    Ok((threads, points))
}

/// One rung of the repetition-sparsity density ladder: a quantization
/// scheme plus the structured-sparsity pattern pruned into the latents
/// before the scale fit.
struct DensityRung {
    label: &'static str,
    scheme: Scheme,
    pattern: SparsityPattern,
}

/// The density ladder `plum bench density` sweeps, densest first:
/// binary (dense ±1), ternary (natural zeros), signed-binary
/// (unstructured nesting sparsity), then signed-binary with 2:4 and
/// 1:4 N:M pruning.
fn density_ladder() -> Vec<DensityRung> {
    vec![
        DensityRung {
            label: "binary",
            scheme: Scheme::Binary,
            pattern: SparsityPattern::Unstructured,
        },
        DensityRung {
            label: "ternary",
            scheme: Scheme::ternary_default(),
            pattern: SparsityPattern::Unstructured,
        },
        DensityRung {
            label: "sb",
            scheme: Scheme::sb_default(),
            pattern: SparsityPattern::Unstructured,
        },
        DensityRung {
            label: "sb-nm2:4",
            scheme: Scheme::sb_default(),
            pattern: SparsityPattern::NM { n: 2, m: 4 },
        },
        DensityRung {
            label: "sb-nm1:4",
            scheme: Scheme::sb_default(),
            pattern: SparsityPattern::NM { n: 1, m: 4 },
        },
    ]
}

/// `plum bench density`: the repetition-sparsity trade-off curve
/// (paper Fig. 10 / §5), measured on the real engine instead of the
/// op-count model. For resnet20 and resnet18c, every rung of the
/// density ladder is compiled twice — sparsity support **on**
/// (zero columns elided from the arena at plan time) and **off**
/// (repetition-only baseline: zeros planned and summed like any other
/// group) — and the full-network forward is timed at one pool width.
///
/// Every sparsity-on forward is verified bit-identical to the
/// unelided reference twin ([`NetworkPlan::without_elision`]) before
/// its time is recorded. Emitted records, per (model, rung):
///
/// * `density_forward` at `... sp-on` / `... sp-off` — min forward
///   time + dense-equivalent GFLOP/s (higher is better; the FLOP
///   numerator is the *dense* MAC count at every rung, so GFLOP/s are
///   comparable across the ladder);
/// * `density_effectual_ppm` — whole-network effectual density in
///   parts-per-million (lower is better; deterministic from the
///   seed). The paper's headline is the gap between the `sb` rung and
///   `binary` here: ~2.8x density reduction at matched accuracy.
///
/// `tile` pins the execution tile (0 = [`DEFAULT_TILE`]); `threads`
/// pins the pool width (0 = available parallelism). Records feed the
/// perf-trajectory gate (committed baseline: BENCH_density.json).
///
/// [`NetworkPlan::without_elision`]: crate::network::NetworkPlan::without_elision
/// [`DEFAULT_TILE`]: crate::repetition::DEFAULT_TILE
pub fn density_study(
    cfg: &RunConfig,
    batch: usize,
    subtile: usize,
    threads: usize,
    tile: usize,
) -> Result<Vec<ScalingPoint>> {
    use crate::network::{NetworkExecutor, NetworkPlan};
    use std::sync::Arc;

    let batch = batch.max(1);
    let threads = if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    let tile = if tile == 0 { crate::repetition::DEFAULT_TILE } else { tile };
    anyhow::ensure!(
        crate::repetition::tile_supports_blocked_io(tile),
        "--tile {tile} cannot carry blocked patch I/O (not a PIXEL_BLOCK multiple) — pass a \
         multiple of 8, or 0 for the default"
    );
    let reps = cfg.bench_reps;
    let mut rng = Rng::new(cfg.seed ^ 0xd155);
    let pool = Pool::new(threads);
    let workloads: Vec<(&str, Vec<models::ConvLayerDesc>)> = vec![
        ("resnet20", models::cifar_resnet_layers(20, 1.0, 32, batch)),
        ("resnet18c", models::cifar_resnet18_layers(1.0, 32, batch)),
    ];
    let mut points = Vec::new();
    for (mname, layers) in &workloads {
        let mut printed = Vec::new();
        let mut input: Vec<f32> = Vec::new();
        let mut binary_on_ns = 0u64;
        for rung in density_ladder() {
            let mk = |sp: bool| -> Result<Arc<NetworkPlan>> {
                let ecfg = EngineConfig { subtile, sparsity_support: sp };
                Ok(Arc::new(NetworkPlan::compile_seeded_pruned(
                    layers,
                    ecfg,
                    rung.scheme,
                    rung.pattern,
                    cfg.seed,
                )?))
            };
            let on = mk(true)?;
            let off = mk(false)?;
            println!("\n{mname} {}: {}", rung.label, on.density_report());
            if input.is_empty() {
                input = vec![0.0f32; on.input_elems()];
                rng.fill_normal(&mut input, 1.0);
            }
            // gate before timing: the elided plan's forward must
            // bit-match the unelided reference twin
            let reference = Arc::new(on.without_elision(&pool));
            let mut ref_exec = NetworkExecutor::with_tile(Arc::clone(&reference), tile)?;
            let want = ref_exec.forward_pool(&input, &pool).to_vec();
            let base = format!("{mname} b{batch} 32px {}", rung.label);
            let (on_pts, _) = network_forward_ladder(
                &on,
                "density_forward",
                &format!("{base} sp-on"),
                &[threads],
                &input,
                reps,
                tile,
                Some(&want),
            )?;
            let (off_pts, _) = network_forward_ladder(
                &off,
                "density_forward",
                &format!("{base} sp-off"),
                &[threads],
                &input,
                reps,
                tile,
                None,
            )?;
            let (on_ns, on_gf) = (on_pts[0].min_ns, on_pts[0].gflops);
            let off_ns = off_pts[0].min_ns;
            points.extend(on_pts);
            points.extend(off_pts);
            points.push(ScalingPoint {
                op: "density_effectual_ppm".into(),
                shape: base,
                threads,
                min_ns: (on.effectual_density() * 1e6).round() as u64,
                gflops: 0.0,
            });
            if rung.label == "binary" {
                binary_on_ns = on_ns;
            }
            printed.push(vec![
                rung.label.to_string(),
                format!("{:.3}", on.effectual_density()),
                format!("{:.2}x", 1.0 / on.effectual_density().max(1e-9)),
                format!("{:.2}", on_ns as f64 / 1e6),
                format!("{:.2}", off_ns as f64 / 1e6),
                format!("{:.2}x", off_ns as f64 / on_ns.max(1) as f64),
                format!("{:.2}x", binary_on_ns as f64 / on_ns.max(1) as f64),
                format!("{on_gf:.2}"),
            ]);
        }
        print_table(
            &format!(
                "Repetition-sparsity trade-off — {mname} b{batch}, {threads} threads (paper: \
                 SB ~2.8x density reduction vs binary at matched accuracy; speedup grows as \
                 density falls only when sparsity support is on)"
            ),
            &[
                "Rung",
                "density",
                "reduction",
                "sp-on ms",
                "sp-off ms",
                "sp win",
                "vs binary",
                "GFLOP/s",
            ],
            &printed,
        );
    }
    Ok(points)
}

/// Design-choice ablation (DESIGN.md): pattern-memoized planner vs the
/// literal SumMerge greedy-CSE DAG, per scheme, on mid-size blocks.
/// Prints arithmetic reduction for both plus the CSE DAG size.
pub fn cse_ablation(cfg: &RunConfig, rounds: usize) -> Result<()> {
    use crate::repetition::build_cse;
    let mut rng = Rng::new(cfg.seed);
    let blocks = [
        Conv2dGeometry { n: 1, c: 64, h: 16, w: 16, k: 64, r: 3, s: 3, stride: 1, padding: 1 },
        Conv2dGeometry { n: 1, c: 128, h: 8, w: 8, k: 128, r: 3, s: 3, stride: 1, padding: 1 },
    ];
    let mut printed = Vec::new();
    for (bi, geom) in blocks.iter().enumerate() {
        let w = latent_weights(geom, &mut rng);
        for scheme in [Scheme::Binary, Scheme::ternary_default(), Scheme::sb_default()] {
            let q = quant::quantize(&w, scheme, None);
            let plan = plan_layer_auto(&q, *geom, true);
            let dag = build_cse(&q, *geom, rounds);
            printed.push(vec![
                format!("block{bi} {}", scheme.name()),
                format!("{:.1}x", arithmetic_reduction(&plan)),
                format!("{:.1}x", dag.arithmetic_reduction()),
                format!("{}", dag.nodes.len()),
            ]);
        }
    }
    print_table(
        "Ablation — pattern-memoized planner vs greedy-CSE DAG (SumMerge-literal)",
        &["Workload", "pattern engine", "CSE DAG", "DAG nodes"],
        &printed,
    );
    Ok(())
}
