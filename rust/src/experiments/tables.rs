//! Accuracy-table harnesses (paper Tables 1-12).
//!
//! Every harness trains the artifact grid emitted by `make artifacts`
//! (see python/compile/aot.py::build_config_set and index.json) and
//! prints measured rows next to the paper's reference values. Expected
//! *shapes* (FP >= T >= B ~= SB, P=0.5 best, EDE on > off, ...) are noted
//! per table; absolutes differ on the synthetic substrate.

//! The training harnesses execute through PJRT and are gated on the
//! `pjrt` feature; the Pareto report and shape checkers only read
//! persisted result rows and are always available.

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;

#[cfg(feature = "pjrt")]
use super::{load_index, train_and_measure};
use super::{print_table, TrainedRow};

fn pct(acc: f64) -> String {
    format!("{:.1}", acc * 100.0)
}

fn keff(row: &TrainedRow) -> String {
    format!("{:.1}k", row.effectual as f64 / 1e3)
}

/// Table 1: FP/T/B/SB across ResNet depths (CIFAR-family).
#[cfg(feature = "pjrt")]
pub fn table1(cfg: &RunConfig, rt: &Runtime, fresh: bool) -> Result<Vec<TrainedRow>> {
    let index = load_index(&cfg.artifacts)?;
    let entries = index.req_arr("table1")?;
    let mut rows = Vec::new();
    let mut printed = Vec::new();
    for e in entries {
        let depth = e.req_usize("depth")?;
        let mut cells = vec![format!("ResNet{depth}")];
        let mut accs = Vec::new();
        for sch in ["fp", "ternary", "binary", "sb"] {
            let name = e.req_str(match sch {
                "fp" => "fp",
                "ternary" => "ternary",
                "binary" => "binary",
                _ => "sb",
            })?;
            let r = train_and_measure(cfg, rt, name, fresh, true)?;
            accs.push(r.eval_acc);
            cells.push(pct(r.eval_acc));
            rows.push(r);
        }
        printed.push(cells);
    }
    print_table(
        "Table 1 — accuracy by scheme (paper: FP >= T >= B ~= SB; e.g. ResNet20 92.10/90.86/90.20/90.05)",
        &["Arch", "FP", "T", "B", "SB"],
        &printed,
    );
    Ok(rows)
}

/// Tables 2 / 10: {0,1} vs {0,-1} filter-mix ablation.
#[cfg(feature = "pjrt")]
pub fn table_mix(
    cfg: &RunConfig,
    rt: &Runtime,
    fresh: bool,
    imagenet: bool,
) -> Result<Vec<TrainedRow>> {
    let index = load_index(&cfg.artifacts)?;
    let mut rows = Vec::new();
    let mut printed = Vec::new();
    if imagenet {
        let t = index.get("table10").ok_or_else(|| anyhow!("no table10"))?;
        let mixes = [("1.00 / 0.00", "p100"), ("0.25 / 0.75", "p025"), ("0.50 / 0.50", "p050")];
        for (label, key) in mixes {
            let r = train_and_measure(cfg, rt, t.req_str(key)?, fresh, true)?;
            printed.push(vec![label.to_string(), pct(r.eval_acc)]);
            rows.push(r);
        }
        print_table(
            "Table 10 — filter mix, imagenet-proxy (paper: 55.23 / 61.94 / 62.29 — 0.5 best)",
            &["%{0,1} / %{0,-1}", "Acc"],
            &printed,
        );
    } else {
        for e in index.req_arr("table2")? {
            let p = e.req_f64("p_pos")?;
            let r = train_and_measure(cfg, rt, e.req_str("cfg")?, fresh, true)?;
            printed.push(vec![
                format!("{:.2} / {:.2}", p, 1.0 - p),
                pct(r.eval_acc),
                keff(&r),
            ]);
            rows.push(r);
        }
        print_table(
            "Table 2 — filter mix (paper: 88.84/89.32/90.05/89.30/89.07 — equal mix best)",
            &["%{0,1} / %{0,-1}", "Acc", "eff params"],
            &printed,
        );
    }
    Ok(rows)
}

/// Tables 3 / 11: EDE enabled vs disabled.
#[cfg(feature = "pjrt")]
pub fn table_ede(
    cfg: &RunConfig,
    rt: &Runtime,
    fresh: bool,
    imagenet: bool,
) -> Result<Vec<TrainedRow>> {
    let index = load_index(&cfg.artifacts)?;
    let key = if imagenet { "table11" } else { "table3" };
    let t = index.get(key).ok_or_else(|| anyhow!("no {key}"))?;
    let off = train_and_measure(cfg, rt, t.req_str("disabled")?, fresh, true)?;
    let on = train_and_measure(cfg, rt, t.req_str("enabled")?, fresh, true)?;
    print_table(
        &format!(
            "{} — adapted EDE (paper: enabled wins, {} vs {})",
            if imagenet { "Table 11" } else { "Table 3" },
            if imagenet { "63.17" } else { "88.7" },
            if imagenet { "62.73" } else { "88.4" },
        ),
        &["EDE", "Acc"],
        &[
            vec!["Disabled".into(), pct(off.eval_acc)],
            vec!["Enabled".into(), pct(on.eval_acc)],
        ],
    );
    Ok(vec![off, on])
}

/// Table 4: region size C_t.
#[cfg(feature = "pjrt")]
pub fn table4(cfg: &RunConfig, rt: &Runtime, fresh: bool) -> Result<Vec<TrainedRow>> {
    let index = load_index(&cfg.artifacts)?;
    let t = index.get("table4").ok_or_else(|| anyhow!("no table4"))?;
    let c = train_and_measure(cfg, rt, t.req_str("ct_c")?, fresh, true)?;
    let c2 = train_and_measure(cfg, rt, t.req_str("ct_c2")?, fresh, true)?;
    print_table(
        "Table 4 — region size (paper: C_t = C 88.6 vs C_t = C/2 87.9)",
        &["Region", "Acc"],
        &[
            vec!["C_t = C".into(), pct(c.eval_acc)],
            vec!["C_t = C/2".into(), pct(c2.eval_acc)],
        ],
    );
    Ok(vec![c, c2])
}

/// Tables 5 / 12: Delta threshold sensitivity.
#[cfg(feature = "pjrt")]
pub fn table_delta(
    cfg: &RunConfig,
    rt: &Runtime,
    fresh: bool,
    imagenet: bool,
) -> Result<Vec<TrainedRow>> {
    let index = load_index(&cfg.artifacts)?;
    let key = if imagenet { "table12" } else { "table5" };
    let t = index.get(key).ok_or_else(|| anyhow!("no {key}"))?;
    let d1 = train_and_measure(cfg, rt, t.req_str("d001")?, fresh, true)?;
    let d5 = train_and_measure(cfg, rt, t.req_str("d005")?, fresh, true)?;
    print_table(
        &format!(
            "{} — Delta sensitivity (paper: near-identical accuracy)",
            if imagenet { "Table 12" } else { "Table 5" }
        ),
        &["Delta", "Acc"],
        &[
            vec!["0.01 x max|W|".into(), pct(d1.eval_acc)],
            vec!["0.05 x max|W|".into(), pct(d5.eval_acc)],
        ],
    );
    Ok(vec![d1, d5])
}

/// Table 6: SB vs FP on additional dataset families.
#[cfg(feature = "pjrt")]
pub fn table6(cfg: &RunConfig, rt: &Runtime, fresh: bool) -> Result<Vec<TrainedRow>> {
    let index = load_index(&cfg.artifacts)?;
    let mut rows = Vec::new();
    let mut printed = Vec::new();
    for e in index.req_arr("table6")? {
        let sb = train_and_measure(cfg, rt, e.req_str("sb")?, fresh, true)?;
        let fp = train_and_measure(cfg, rt, e.req_str("fp")?, fresh, true)?;
        printed.push(vec![
            e.req_str("arch")?.to_string(),
            e.req_str("dataset")?.to_string(),
            pct(sb.eval_acc),
            pct(fp.eval_acc),
        ]);
        rows.push(sb);
        rows.push(fp);
    }
    print_table(
        "Table 6 — SB vs FP (paper: SB within ~1-3 points of FP)",
        &["Model", "Dataset", "Acc SB", "Acc FP"],
        &printed,
    );
    Ok(rows)
}

/// Table 7: SB vs B with comparable effectual params (depth & width).
#[cfg(feature = "pjrt")]
pub fn table7(cfg: &RunConfig, rt: &Runtime, fresh: bool) -> Result<Vec<TrainedRow>> {
    let index = load_index(&cfg.artifacts)?;
    let t = index.get("table7").ok_or_else(|| anyhow!("no table7"))?;
    let mut rows = Vec::new();
    for (section, keys, title) in [
        (
            "depth",
            vec![("SB", "sb_d32"), ("B (same total)", "b_d32"), ("B (same effectual)", "b_d20")],
            "Table 7a — depth-matched (paper: SB 91.55 > B-half-depth 90.16)",
        ),
        (
            "width",
            vec![("SB", "sb_w10"), ("B (same total)", "b_w10"), ("B (same effectual)", "b_w07")],
            "Table 7b — width-matched (paper: SB 90.05 > B-0.7x-width 88.5)",
        ),
    ] {
        let sec = t.get(section).ok_or_else(|| anyhow!("no table7.{section}"))?;
        let mut printed = Vec::new();
        for (label, key) in keys {
            let r = train_and_measure(cfg, rt, sec.req_str(key)?, fresh, true)?;
            printed.push(vec![
                label.to_string(),
                pct(r.eval_acc),
                keff(&r),
                format!("{:.1}k", r.quantized_total as f64 / 1e3),
            ]);
            rows.push(r);
        }
        print_table(title, &["Quant", "Acc", "effectual", "total q-params"], &printed);
    }
    Ok(rows)
}

/// Table 8: batch-size and non-linearity ablations.
#[cfg(feature = "pjrt")]
pub fn table8(cfg: &RunConfig, rt: &Runtime, fresh: bool) -> Result<Vec<TrainedRow>> {
    let index = load_index(&cfg.artifacts)?;
    let mut rows = Vec::new();
    let a = index.get("table8a").ok_or_else(|| anyhow!("no table8a"))?;
    let mut printed = Vec::new();
    for bs in ["16", "32", "64", "128"] {
        let r = train_and_measure(cfg, rt, a.req_str(bs)?, fresh, true)?;
        printed.push(vec![bs.to_string(), pct(r.eval_acc)]);
        rows.push(r);
    }
    print_table(
        "Table 8a — batch size (paper: 89.44/90.05/89.62/89.59 — bs32 best)",
        &["Batch", "Acc"],
        &printed,
    );
    let b = index.get("table8b").ok_or_else(|| anyhow!("no table8b"))?;
    let mut printed = Vec::new();
    for act in ["relu", "prelu", "tanh", "lrelu"] {
        let r = train_and_measure(cfg, rt, b.req_str(act)?, fresh, true)?;
        printed.push(vec![act.to_string(), pct(r.eval_acc)]);
        rows.push(r);
    }
    print_table(
        "Table 8b — non-linearity (paper: PReLU best, 90.05)",
        &["Non-linearity", "Acc"],
        &printed,
    );
    Ok(rows)
}

/// Table 9: latent-weight standardization strategies.
#[cfg(feature = "pjrt")]
pub fn table9(cfg: &RunConfig, rt: &Runtime, fresh: bool) -> Result<Vec<TrainedRow>> {
    let index = load_index(&cfg.artifacts)?;
    let t = index.get("table9").ok_or_else(|| anyhow!("no table9 — rebuild artifacts"))?;
    let mut rows = Vec::new();
    let mut printed = Vec::new();
    for (label, key) in [
        ("Local signed-binary regions", "local"),
        ("Global signed-binary block", "global"),
        ("No standardization", "none"),
    ] {
        let r = train_and_measure(cfg, rt, t.req_str(key)?, fresh, true)?;
        printed.push(vec![label.to_string(), pct(r.eval_acc)]);
        rows.push(r);
    }
    print_table(
        "Table 9 — standardization (paper: 59.1 / 61.2 / 61.4 — none best)",
        &["Strategy", "Acc"],
        &printed,
    );
    Ok(rows)
}

/// Figure 2/5 — Pareto front: accuracy vs trained effectual params.
pub fn pareto(cfg: &RunConfig) -> Result<()> {
    let rows = super::all_results(cfg);
    if rows.is_empty() {
        let dir = cfg.out_dir.display();
        return Err(anyhow!("no results in {dir} — run the table harnesses first"));
    }
    let mut printed = Vec::new();
    // pareto front over (effectual asc, acc desc)
    let mut sorted: Vec<&TrainedRow> = rows.iter().filter(|r| r.quantized_total > 0).collect();
    sorted.sort_by(|a, b| a.effectual.cmp(&b.effectual));
    let mut best_acc = f64::MIN;
    for r in &sorted {
        let on_front = r.eval_acc > best_acc;
        if on_front {
            best_acc = r.eval_acc;
        }
        printed.push(vec![
            r.name.clone(),
            r.scheme.clone(),
            keff(r),
            pct(r.eval_acc),
            format!("{:.2}", r.density),
            if on_front { "*".into() } else { "".into() },
        ]);
    }
    print_table(
        "Figures 2 & 5 — accuracy vs effectual params (* = Pareto front; paper: SB pushes the front)",
        &["Model", "Scheme", "Effectual", "Acc", "Density", "Front"],
        &printed,
    );
    Ok(())
}

/// Shape assertions shared with tests: given rows keyed by scheme for one
/// depth, check the paper's qualitative ordering holds loosely.
pub fn check_table1_shape(fp: f64, sb: f64, b: f64) -> bool {
    // FP should be >= both one-bit schemes; SB within 3 points of B.
    fp >= sb - 0.02 && fp >= b - 0.02 && (sb - b).abs() < 0.08
}

#[cfg(test)]
mod tests {
    #[test]
    fn shape_checker() {
        assert!(super::check_table1_shape(0.9, 0.85, 0.86));
        assert!(!super::check_table1_shape(0.7, 0.9, 0.6));
    }
}
