//! Experiment harnesses: one entry point per paper table/figure.
//!
//! Each harness prints the same rows/series the paper reports and returns
//! structured results so `EXPERIMENTS.md` and tests can assert on shapes
//! (who wins, direction of ablations) rather than absolute numbers —
//! per DESIGN.md, the substrate is synthetic data on CPU, so absolute
//! accuracy/latency differ from the paper's ImageNet/Xeon numbers.

pub mod figures;
pub mod serving;
pub mod tables;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::config::RunConfig;
use crate::data::SyntheticDataset;
use crate::runtime::Manifest;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::training::load_checkpoint;
#[cfg(feature = "pjrt")]
use crate::training::{save_checkpoint, Schedule, Trainer};
use crate::util::json::{self, Json};

/// Outcome of training + evaluating one artifact.
#[derive(Debug, Clone)]
pub struct TrainedRow {
    /// artifact name
    pub name: String,
    /// quantization scheme name
    pub scheme: String,
    /// final eval accuracy
    pub eval_acc: f64,
    /// final train loss
    pub final_loss: f64,
    /// training steps run
    pub steps: u64,
    /// quantized-layer parameter counts measured on the *trained* weights
    pub quantized_total: usize,
    /// effectual (non-zero) quantized parameters after training
    pub effectual: usize,
    /// effectual / total ratio
    pub density: f64,
    /// wall-clock seconds of the run
    pub wall_secs: f64,
}

impl TrainedRow {
    /// The persisted `<name>.result.json` form.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("scheme", json::s(&self.scheme)),
            ("eval_acc", json::num(self.eval_acc)),
            ("final_loss", json::num(self.final_loss)),
            ("steps", json::num(self.steps as f64)),
            ("quantized_total", json::num(self.quantized_total as f64)),
            ("effectual", json::num(self.effectual as f64)),
            ("density", json::num(self.density)),
            ("wall_secs", json::num(self.wall_secs)),
        ])
    }

    /// Parse a row back from its persisted JSON form.
    pub fn from_json(j: &Json) -> Result<TrainedRow> {
        Ok(TrainedRow {
            name: j.req_str("name")?.to_string(),
            scheme: j.req_str("scheme")?.to_string(),
            eval_acc: j.req_f64("eval_acc")?,
            final_loss: j.req_f64("final_loss")?,
            steps: j.req_usize("steps")? as u64,
            quantized_total: j.req_usize("quantized_total")?,
            effectual: j.req_usize("effectual")?,
            density: j.req_f64("density")?,
            wall_secs: j.req_f64("wall_secs")?,
        })
    }
}

/// Dataset kind inferred from an artifact name (Table 6 families).
pub fn dataset_kind_for(name: &str) -> &'static str {
    if name.contains("svhn") {
        "svhn"
    } else if name.contains("cifar100") {
        "cifar100"
    } else if name.contains("tinyimagenet") {
        "tinyimagenet"
    } else if name.starts_with("r18p") || name.contains("resnet18sb") {
        "imagenet-proxy"
    } else {
        "cifar"
    }
}

/// Dataset matched to an artifact's geometry.
pub fn dataset_for(man: &Manifest, seed: u64) -> SyntheticDataset {
    let c = &man.config;
    SyntheticDataset::new(
        dataset_kind_for(&man.name),
        c.num_classes,
        c.in_channels,
        c.image_size,
        seed,
    )
}

/// Harness dataset: like `dataset_for` but at the RunConfig difficulty
/// (higher noise keeps accuracies off the ceiling so scheme differences
/// stay visible at a few hundred steps).
pub fn dataset_for_run(cfg: &RunConfig, man: &Manifest) -> SyntheticDataset {
    let mut ds = dataset_for(man, cfg.seed);
    ds.noise = cfg.data_noise;
    ds
}

#[cfg(feature = "pjrt")]
fn result_path(cfg: &RunConfig, name: &str) -> PathBuf {
    cfg.out_dir.join(format!("{name}.result.json"))
}

fn ckpt_path(cfg: &RunConfig, name: &str) -> PathBuf {
    cfg.out_dir.join(format!("{name}.ckpt"))
}

/// Train (or reuse a cached result), evaluate, measure trained
/// effectual-parameter counts, persist checkpoint + result row.
#[cfg(feature = "pjrt")]
pub fn train_and_measure(
    cfg: &RunConfig,
    rt: &Runtime,
    name: &str,
    fresh: bool,
    quiet: bool,
) -> Result<TrainedRow> {
    std::fs::create_dir_all(&cfg.out_dir).ok();
    let rpath = result_path(cfg, name);
    if !fresh && rpath.exists() {
        let j = Json::parse(&std::fs::read_to_string(&rpath)?)
            .map_err(|e| anyhow!("{}: {e}", rpath.display()))?;
        let row = TrainedRow::from_json(&j)?;
        if row.steps >= cfg.steps {
            if !quiet {
                println!("  [cached] {name}: acc {:.3}", row.eval_acc);
            }
            return Ok(row);
        }
    }

    let mut tr = Trainer::new(rt, &cfg.artifacts, name)
        .with_context(|| format!("loading artifact {name}"))?;
    let ds = dataset_for_run(cfg, &tr.model.manifest);
    let schedule = Schedule::Step { init: 5e-3, milestones: vec![0.5, 0.8] };
    let log = tr.train(&ds, cfg.steps, &schedule, (cfg.steps / 8).max(1), cfg.eval_batches, quiet)?;

    let layers = tr.export_quantized()?;
    let (mut eff, mut tot) = (0usize, 0usize);
    for (_, q) in &layers {
        eff += q.effectual();
        tot += q.values.len();
    }
    let row = TrainedRow {
        name: name.to_string(),
        scheme: tr.model.manifest.config.scheme.clone(),
        eval_acc: log.eval_acc as f64,
        final_loss: log.final_train_loss as f64,
        steps: cfg.steps,
        quantized_total: tot,
        effectual: eff,
        density: if tot > 0 { eff as f64 / tot as f64 } else { 1.0 },
        wall_secs: log.wall_secs,
    };
    save_checkpoint(&ckpt_path(cfg, name), tr.step, &tr.state_to_host()?)?;
    std::fs::write(&rpath, row.to_json().to_string())?;
    Ok(row)
}

/// Load the trained checkpoint state for `name` if present.
pub fn trained_state(
    cfg: &RunConfig,
    name: &str,
) -> Option<(u64, Vec<(crate::runtime::TensorSpec, Vec<f32>)>)> {
    load_checkpoint(&ckpt_path(cfg, name)).ok()
}

/// Load the experiment index (`index.json`) from the artifact dir.
pub fn load_index(artifacts: &Path) -> Result<Json> {
    let p = artifacts.join("index.json");
    let text = std::fs::read_to_string(&p)
        .with_context(|| format!("reading {} — run `make artifacts`", p.display()))?;
    Json::parse(&text).map_err(|e| anyhow!("index.json: {e}"))
}

/// Collect all persisted result rows in out_dir (for the Pareto plot).
pub fn all_results(cfg: &RunConfig) -> Vec<TrainedRow> {
    let mut rows = Vec::new();
    if let Ok(rd) = std::fs::read_dir(&cfg.out_dir) {
        for e in rd.flatten() {
            let p = e.path();
            if p.extension().map(|x| x == "json").unwrap_or(false)
                && p.file_name()
                    .and_then(|f| f.to_str())
                    .map(|f| f.ends_with(".result.json"))
                    .unwrap_or(false)
            {
                if let Ok(text) = std::fs::read_to_string(&p) {
                    if let Ok(j) = Json::parse(&text) {
                        if let Ok(r) = TrainedRow::from_json(&j) {
                            rows.push(r);
                        }
                    }
                }
            }
        }
    }
    rows.sort_by(|a, b| a.name.cmp(&b.name));
    rows
}

/// Markdown-ish table printer used by all harnesses.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        s
    };
    println!("{}", line(headers.iter().map(|h| h.to_string()).collect()));
    println!(
        "|{}|",
        widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("|")
    );
    for r in rows {
        println!("{}", line(r.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_json_roundtrip() {
        let r = TrainedRow {
            name: "x".into(),
            scheme: "sb".into(),
            eval_acc: 0.5,
            final_loss: 1.25,
            steps: 100,
            quantized_total: 1000,
            effectual: 400,
            density: 0.4,
            wall_secs: 12.5,
        };
        let r2 = TrainedRow::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(r2.name, "x");
        assert_eq!(r2.effectual, 400);
        assert!((r2.eval_acc - 0.5).abs() < 1e-12);
    }

    #[test]
    fn dataset_kinds() {
        assert_eq!(dataset_kind_for("alexnet_small_svhn_sb"), "svhn");
        assert_eq!(dataset_kind_for("resnet18_cifar100_fp"), "cifar100");
        assert_eq!(dataset_kind_for("resnet20_sb"), "cifar");
        assert_eq!(dataset_kind_for("r18p_p050"), "imagenet-proxy");
    }
}
