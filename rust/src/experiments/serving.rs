//! Serving load drivers: drive the coordinator (router + batcher +
//! supervised workers) with synthetic request streams and report typed
//! outcomes, latency, and throughput — the end-to-end serving
//! validation.
//!
//! Two driver shapes:
//!
//! * **closed burst** ([`drive_engine`], [`drive`]) — submit `requests`
//!   samples, then collect every reply; measures drain throughput for
//!   `plum serve`. Deadlines are relaxed here (a burst is not an arrival
//!   process), so legacy behavior — every request answered — holds.
//! * **open loop** ([`bench_serve_engine`]) — submit on a fixed-rate
//!   clock for a wall-clock duration regardless of completions (the
//!   load-harness methodology SparseDNN uses): under saturation the
//!   bounded queues shed and deadlines expire, and the report carries
//!   p50/p95/p99, shed rate, and goodput. `plum bench serve` persists it
//!   as the `BENCH_serving` series.
//!
//! Backends: [`drive_engine`]/[`bench_serve_engine`] compile an
//! engine-zoo model (CIFAR `resnetN`, projection-shortcut `resnet18c`,
//! or the patch-reuse `chain1x1`) onto the repetition engine **once**,
//! share the plan across replicas, and serve on plain CPU with no
//! features and no artifacts. [`drive`] (`--features pjrt`) compiles the
//! AOT infer executable in each worker.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::RunConfig;
#[cfg(feature = "pjrt")]
use crate::coordinator::PjrtBackend;
use crate::coordinator::{Router, ServeError, ServePolicy};
use crate::data::SyntheticDataset;
use crate::metrics::LatencyHistogram;
use crate::models;
use crate::network::{EngineBackend, NetworkPlan};
use crate::quant::Scheme;
use crate::repetition::EngineConfig;
#[cfg(feature = "pjrt")]
use crate::runtime::Manifest;

/// Result of one closed-burst load run, by typed outcome.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// requests the driver attempted to submit
    pub requests: usize,
    /// requests answered `Ok(logits)`
    pub completed: usize,
    /// requests shed at admission (`Overloaded`)
    pub shed: usize,
    /// requests answered `DeadlineExceeded`
    pub expired: usize,
    /// requests answered `ReplicaFailed` / `BadRequest`
    pub failed: usize,
    /// wall-clock seconds of the run
    pub wall_secs: f64,
    /// completed requests per second (goodput)
    pub throughput_rps: f64,
    /// mean completed-request latency (ms)
    pub mean_ms: f64,
    /// 95th-percentile completed-request latency (ms)
    pub p95_ms: f64,
    /// worker replicas the run used
    pub replicas: usize,
}

/// Closed-burst driver shared by every backend: submit `requests`
/// synthetic samples through the router, collect all replies (typed),
/// report latency and throughput, then shut the replicas down. A
/// dropped reply channel is a conservation bug and fails the run.
fn drive_router(
    router: Router,
    ds: &SyntheticDataset,
    sample: usize,
    requests: usize,
) -> Result<ServeReport> {
    let replicas = router.replicas();
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    let mut shed = 0usize;
    let mut buf = vec![0.0f32; sample];
    for i in 0..requests {
        ds.render(i, &mut buf);
        match router.submit(buf.clone()) {
            Ok((rx, _)) => pending.push((Instant::now(), rx)),
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => bail!("burst submit failed: {e}"),
        }
    }
    let (mut completed, mut expired, mut failed) = (0usize, 0usize, 0usize);
    let mut lat_ms: Vec<f64> = Vec::with_capacity(pending.len());
    for (t_submit, rx) in pending {
        match rx.recv() {
            Ok(Ok(_)) => {
                completed += 1;
                lat_ms.push(t_submit.elapsed().as_secs_f64() * 1e3);
            }
            Ok(Err(ServeError::DeadlineExceeded { .. })) => expired += 1,
            Ok(Err(_)) => failed += 1,
            Err(_) => bail!("reply channel dropped — request conservation violated"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p95_ms = if lat_ms.is_empty() {
        0.0
    } else {
        lat_ms[((lat_ms.len() as f64 * 0.95) as usize).min(lat_ms.len() - 1)]
    };
    let report = ServeReport {
        requests,
        completed,
        shed,
        expired,
        failed,
        wall_secs: wall,
        throughput_rps: completed as f64 / wall,
        mean_ms: lat_ms.iter().sum::<f64>() / lat_ms.len().max(1) as f64,
        p95_ms,
        replicas,
    };
    for i in 0..router.replicas() {
        let s = router.stats(i);
        println!(
            "  {} shed={} expired={} crashes={}",
            s.latency.report(&format!("replica{i}")),
            s.shed.get(),
            s.expired.get(),
            s.crashes.get()
        );
    }
    router.shutdown()?;
    Ok(report)
}

/// A burst of `requests` is not a paced arrival process, so the closed
/// drivers relax the deadline (still bounded) — deadline behavior under
/// load is the open-loop harness's job.
fn burst_policy(cfg: &RunConfig) -> ServePolicy {
    let p = cfg.serve_policy();
    ServePolicy { default_deadline: p.default_deadline.max(Duration::from_secs(60)), ..p }
}

/// Serve `requests` synthetic samples through `cfg.replicas` supervised
/// repetition-engine workers — no `pjrt` feature, no artifacts. The
/// device batch is `cfg.max_batch`; one [`NetworkPlan`] is compiled up
/// front and shared. Models come from the engine zoo
/// (`models::engine_model_layers`): CIFAR `resnetN` (option-A),
/// `resnet18c` (projection shortcuts) and `chain1x1` (the patch-reuse
/// workload).
pub fn drive_engine(cfg: &RunConfig, model: &str, requests: usize) -> Result<ServeReport> {
    let batch = cfg.max_batch.max(1);
    let layers = models::engine_model_layers(model, 32, batch).ok_or_else(|| {
        anyhow!(
            "engine backend serves 'resnetN' (N = 6n+2), 'resnet18c' or 'chain1x1' — \
             got '{model}'"
        )
    })?;
    eprintln!(
        "compiling {model} (batch {batch}, {} conv layers) onto the repetition engine...",
        layers.len()
    );
    // subtile 0 = auto-tuned per layer: serving compiles once and then
    // runs hot, exactly where the tuner's one-time cost amortizes
    let ecfg = EngineConfig { subtile: 0, sparsity_support: true };
    let plan = Arc::new(NetworkPlan::compile_seeded(
        &layers,
        ecfg,
        Scheme::sb_default(),
        cfg.seed,
    )?);
    println!(
        "plan: {} layers, {} ops/pass vs {} dense MACs, {} KiB packed weights, \
         {} patch-fused edge(s), {} arena buffer(s)",
        plan.num_layers(),
        plan.op_counts().total(),
        plan.dense_macs(),
        plan.weight_bits / 8 / 1024,
        plan.patch_fused_edges(),
        plan.num_arena_slots()
    );
    let sample = plan.sample_elems();
    let ds = SyntheticDataset::new("serve", 10, 3, 32, cfg.seed);
    let router = Router::spawn(
        cfg.replicas.max(1),
        EngineBackend::factory(Arc::clone(&plan)),
        burst_policy(cfg),
    )?;
    drive_router(router, &ds, sample, requests)
}

/// Serve `requests` synthetic samples through `cfg.replicas` supervised
/// PJRT workers.
#[cfg(feature = "pjrt")]
pub fn drive(
    cfg: &RunConfig,
    model: &str,
    requests: usize,
    checkpoint: Option<std::path::PathBuf>,
) -> Result<ServeReport> {
    let man = Manifest::load(&cfg.artifacts, model)?;
    let ds = SyntheticDataset::new(
        "serve",
        man.config.num_classes,
        man.config.in_channels,
        man.config.image_size,
        cfg.seed,
    );
    let sample = man.config.in_channels * man.config.image_size * man.config.image_size;
    eprintln!(
        "spawning {} replica(s) of {model} (compiling artifacts in each worker)...",
        cfg.replicas
    );
    let router = Router::spawn(
        cfg.replicas.max(1),
        PjrtBackend::factory(cfg.artifacts.clone(), model.to_string(), checkpoint),
        burst_policy(cfg),
    )?;
    drive_router(router, &ds, sample, requests)
}

/// Result of one open-loop load run (`plum bench serve`).
#[derive(Debug, Clone)]
pub struct ServeBenchReport {
    /// model the run served
    pub model: String,
    /// replica count behind the router
    pub replicas: usize,
    /// target offered load (requests per second)
    pub target_rps: f64,
    /// requests the load loop offered
    pub offered: usize,
    /// requests answered `Ok(logits)`
    pub completed: usize,
    /// requests shed at admission
    pub shed: usize,
    /// requests answered `DeadlineExceeded`
    pub expired: usize,
    /// requests answered `ReplicaFailed` / `BadRequest`
    pub failed: usize,
    /// worker generations lost across the run (0 without fault injection)
    pub crashes: u64,
    /// wall-clock seconds (load window + drain)
    pub wall_secs: f64,
    /// completed requests per second (goodput, saturation throughput)
    pub achieved_rps: f64,
    /// end-to-end p50 bound (us) over every typed reply
    pub p50_us: u64,
    /// end-to-end p95 bound (us)
    pub p95_us: u64,
    /// end-to-end p99 bound (us)
    pub p99_us: u64,
    /// shed requests per million offered
    pub shed_ppm: u64,
}

/// Open-loop load harness: offer `rps` requests/second against a
/// supervised engine-backend fleet for `duration_s` seconds of wall
/// clock — submissions follow the clock, not the completions — then
/// drain and report typed outcomes, end-to-end latency quantiles
/// (p50/p95/p99 bucket bounds over all replies), shed rate, and
/// goodput. `image` shrinks the input (CIFAR geometry is 32) so CI can
/// run a short, cheap window.
pub fn bench_serve_engine(
    cfg: &RunConfig,
    model: &str,
    image: usize,
    rps: f64,
    duration_s: f64,
) -> Result<ServeBenchReport> {
    anyhow::ensure!(rps > 0.0, "--rps must be positive");
    anyhow::ensure!(duration_s > 0.0, "--duration must be positive");
    let batch = cfg.max_batch.max(1);
    let layers = models::engine_model_layers(model, image, batch)
        .ok_or_else(|| anyhow!("unknown engine model '{model}'"))?;
    let ecfg = EngineConfig { subtile: 0, sparsity_support: true };
    let plan = Arc::new(NetworkPlan::compile_seeded(
        &layers,
        ecfg,
        Scheme::sb_default(),
        cfg.seed,
    )?);
    let sample = plan.sample_elems();
    let ds = SyntheticDataset::new("serve", 10, 3, image, cfg.seed);
    let replicas = cfg.replicas.max(1);
    let router = Router::spawn(
        replicas,
        EngineBackend::factory(Arc::clone(&plan)),
        cfg.serve_policy(),
    )?;
    // pre-render a sample ring so rendering stays off the submit path
    let ring: Vec<Vec<f32>> = (0..16)
        .map(|i| {
            let mut b = vec![0.0f32; sample];
            ds.render(i, &mut b);
            b
        })
        .collect();
    let interval = Duration::from_secs_f64(1.0 / rps);
    let t0 = Instant::now();
    let end = t0 + Duration::from_secs_f64(duration_s);
    let mut next = t0;
    let mut offered = 0usize;
    let mut shed = 0usize;
    let mut pending = Vec::new();
    loop {
        let now = Instant::now();
        if now >= end {
            break;
        }
        if now < next {
            std::thread::sleep(next - now);
        }
        // open loop: if we fell behind the clock we submit immediately
        // and catch up instead of thinning the offered load
        match router.submit(ring[offered % ring.len()].clone()) {
            Ok((rx, _)) => pending.push(rx),
            Err(ServeError::Overloaded { .. } | ServeError::ReplicaFailed { .. }) => shed += 1,
            Err(e) => bail!("unexpected admission error: {e}"),
        }
        offered += 1;
        next += interval;
    }
    let (mut completed, mut expired, mut failed) = (0usize, 0usize, 0usize);
    for rx in pending {
        match rx.recv() {
            Ok(Ok(_)) => completed += 1,
            Ok(Err(ServeError::DeadlineExceeded { .. })) => expired += 1,
            Ok(Err(_)) => failed += 1,
            Err(_) => bail!("reply channel dropped — request conservation violated"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let e2e = LatencyHistogram::new();
    let mut crashes = 0u64;
    for i in 0..replicas {
        let s = router.stats(i);
        e2e.absorb(&s.e2e);
        crashes += s.crashes.get();
        println!(
            "  {} shed={} crashes={}",
            s.e2e.report(&format!("replica{i} e2e")),
            s.shed.get(),
            s.crashes.get()
        );
    }
    router.shutdown()?;
    Ok(ServeBenchReport {
        model: model.to_string(),
        replicas,
        target_rps: rps,
        offered,
        completed,
        shed,
        expired,
        failed,
        crashes,
        wall_secs: wall,
        achieved_rps: completed as f64 / wall,
        p50_us: e2e.quantile_us(0.5),
        p95_us: e2e.quantile_us(0.95),
        p99_us: e2e.quantile_us(0.99),
        shed_ppm: (shed as u64).saturating_mul(1_000_000) / (offered.max(1) as u64),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BatchPolicy;

    #[test]
    fn unknown_engine_models_error() {
        let cfg = RunConfig::default();
        assert!(drive_engine(&cfg, "resnet21", 1).is_err()); // not 6n+2
        assert!(drive_engine(&cfg, "vgg_small", 1).is_err());
        assert!(bench_serve_engine(&cfg, "vgg_small", 8, 10.0, 0.1).is_err());
    }

    #[test]
    fn engine_serving_end_to_end_smoke() {
        // tiny load run: 2 supervised replicas of a resnet8 on 8px images
        let cfg = RunConfig { replicas: 2, max_batch: 2, max_wait_ms: 1, ..RunConfig::default() };
        // compile a small plan directly (drive_engine pins 32px CIFAR
        // geometry; the smoke test shrinks the image for speed)
        let layers = models::cifar_resnet_layers(8, 0.5, 8, cfg.max_batch);
        let plan = Arc::new(
            NetworkPlan::compile(&layers, EngineConfig::default(), Scheme::sb_default()).unwrap(),
        );
        let policy = ServePolicy {
            batch: BatchPolicy {
                max_batch: cfg.max_batch,
                max_wait: Duration::from_millis(cfg.max_wait_ms),
            },
            default_deadline: Duration::from_secs(60),
            ..ServePolicy::default()
        };
        let router = Router::spawn(
            cfg.replicas,
            EngineBackend::factory(Arc::clone(&plan)),
            policy,
        )
        .unwrap();
        let ds = SyntheticDataset::new("serve", 10, 3, 8, cfg.seed);
        let report = drive_router(router, &ds, plan.sample_elems(), 17).unwrap();
        assert_eq!(report.requests, 17);
        assert_eq!(report.completed, 17);
        assert_eq!(report.shed + report.expired + report.failed, 0);
        assert_eq!(report.replicas, 2);
        assert!(report.throughput_rps > 0.0);
        assert!(report.p95_ms >= 0.0 && report.mean_ms >= 0.0);
    }

    #[test]
    fn open_loop_bench_conserves_every_offered_request() {
        let cfg = RunConfig { replicas: 1, max_batch: 2, max_wait_ms: 1, ..RunConfig::default() };
        let report = bench_serve_engine(&cfg, "resnet8", 8, 300.0, 0.25).unwrap();
        assert!(report.offered > 0);
        assert_eq!(
            report.completed + report.shed + report.expired + report.failed,
            report.offered,
            "typed outcomes must partition the offered load"
        );
        assert!(report.wall_secs > 0.0);
        if report.completed > 0 {
            assert!(report.p50_us > 0);
            assert!(report.p50_us <= report.p95_us && report.p95_us <= report.p99_us);
            assert!(report.achieved_rps > 0.0);
        }
        assert_eq!(report.crashes, 0, "no fault injection here");
    }
}
