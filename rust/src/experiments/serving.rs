//! Serving load drivers: drive the coordinator (router + batcher +
//! workers) with an open-loop synthetic request stream and report
//! latency/throughput — the end-to-end serving validation.
//!
//! Two backends share one driver:
//!
//! * [`drive_engine`] — the repetition engine ([`EngineBackend`]):
//!   compiles an engine-zoo model (CIFAR `resnetN`, projection-shortcut
//!   `resnet18c`, or the patch-reuse `chain1x1`) onto the engine
//!   **once**, shares the plan across all replicas, and serves on plain
//!   CPU with no features and no artifacts (`plum serve --backend
//!   engine`).
//! * [`drive`] — the PJRT runtime (`--features pjrt`): each worker
//!   compiles the AOT infer executable from the artifact directory
//!   (`plum serve --backend pjrt`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
#[cfg(feature = "pjrt")]
use crate::coordinator::PjrtBackend;
use crate::coordinator::{spawn_worker, BatchPolicy, Router};
use crate::data::SyntheticDataset;
use crate::models;
use crate::network::{EngineBackend, NetworkPlan};
use crate::quant::Scheme;
use crate::repetition::EngineConfig;
#[cfg(feature = "pjrt")]
use crate::runtime::Manifest;

/// Result of one load run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// requests submitted and answered
    pub requests: usize,
    /// wall-clock seconds of the run
    pub wall_secs: f64,
    /// requests per second
    pub throughput_rps: f64,
    /// mean request latency (ms)
    pub mean_ms: f64,
    /// 95th-percentile request latency (ms)
    pub p95_ms: f64,
    /// worker replicas the run used
    pub replicas: usize,
}

/// Open-loop driver shared by every backend: submit `requests` synthetic
/// samples through the router, collect all replies, report latency and
/// throughput, then shut the replicas down.
fn drive_router(
    router: Router,
    ds: &SyntheticDataset,
    sample: usize,
    requests: usize,
) -> Result<ServeReport> {
    let replicas = router.replicas();
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    let mut buf = vec![0.0f32; sample];
    for i in 0..requests {
        ds.render(i, &mut buf);
        let (rx, _) = router.submit(buf.clone())?;
        pending.push((Instant::now(), rx));
    }
    let mut lat_ms: Vec<f64> = Vec::with_capacity(requests);
    for (t_submit, rx) in pending {
        rx.recv()??;
        lat_ms.push(t_submit.elapsed().as_secs_f64() * 1e3);
    }
    let wall = t0.elapsed().as_secs_f64();
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p95_ms = if lat_ms.is_empty() {
        0.0
    } else {
        lat_ms[((lat_ms.len() as f64 * 0.95) as usize).min(lat_ms.len() - 1)]
    };
    let report = ServeReport {
        requests,
        wall_secs: wall,
        throughput_rps: requests as f64 / wall,
        mean_ms: lat_ms.iter().sum::<f64>() / lat_ms.len().max(1) as f64,
        p95_ms,
        replicas,
    };
    for i in 0..router.replicas() {
        println!("  {}", router.worker(i).latency.report(&format!("replica{i}")));
    }
    router.shutdown()?;
    Ok(report)
}

/// Serve `requests` synthetic samples through `cfg.replicas` repetition-
/// engine workers — no `pjrt` feature, no artifacts. The device batch is
/// `cfg.max_batch`; one [`NetworkPlan`] is compiled up front and shared.
/// Models come from the engine zoo (`models::engine_model_layers`):
/// CIFAR `resnetN` (option-A), `resnet18c` (projection shortcuts) and
/// `chain1x1` (the patch-reuse workload).
pub fn drive_engine(cfg: &RunConfig, model: &str, requests: usize) -> Result<ServeReport> {
    let batch = cfg.max_batch.max(1);
    let layers = models::engine_model_layers(model, 32, batch).ok_or_else(|| {
        anyhow!(
            "engine backend serves 'resnetN' (N = 6n+2), 'resnet18c' or 'chain1x1' — \
             got '{model}'"
        )
    })?;
    eprintln!(
        "compiling {model} (batch {batch}, {} conv layers) onto the repetition engine...",
        layers.len()
    );
    // subtile 0 = auto-tuned per layer: serving compiles once and then
    // runs hot, exactly where the tuner's one-time cost amortizes
    let ecfg = EngineConfig { subtile: 0, sparsity_support: true };
    let plan = Arc::new(NetworkPlan::compile_seeded(
        &layers,
        ecfg,
        Scheme::sb_default(),
        cfg.seed,
    )?);
    println!(
        "plan: {} layers, {} ops/pass vs {} dense MACs, {} KiB packed weights, \
         {} patch-fused edge(s), {} arena buffer(s)",
        plan.num_layers(),
        plan.op_counts().total(),
        plan.dense_macs(),
        plan.weight_bits / 8 / 1024,
        plan.patch_fused_edges(),
        plan.num_arena_slots()
    );
    let sample = plan.sample_elems();
    let ds = SyntheticDataset::new("serve", 10, 3, 32, cfg.seed);
    let policy = BatchPolicy { max_batch: batch, max_wait: Duration::from_millis(cfg.max_wait_ms) };
    let workers = (0..cfg.replicas.max(1))
        .map(|_| spawn_worker(EngineBackend::factory(Arc::clone(&plan)), policy))
        .collect::<Result<Vec<_>>>()?;
    drive_router(Router::new(workers), &ds, sample, requests)
}

/// Serve `requests` synthetic samples through `cfg.replicas` PJRT workers.
#[cfg(feature = "pjrt")]
pub fn drive(
    cfg: &RunConfig,
    model: &str,
    requests: usize,
    checkpoint: Option<std::path::PathBuf>,
) -> Result<ServeReport> {
    let man = Manifest::load(&cfg.artifacts, model)?;
    let ds = SyntheticDataset::new(
        "serve",
        man.config.num_classes,
        man.config.in_channels,
        man.config.image_size,
        cfg.seed,
    );
    let sample = man.config.in_channels * man.config.image_size * man.config.image_size;

    let policy = BatchPolicy {
        max_batch: cfg.max_batch,
        max_wait: Duration::from_millis(cfg.max_wait_ms),
    };
    eprintln!(
        "spawning {} replica(s) of {model} (compiling artifacts in each worker)...",
        cfg.replicas
    );
    let workers = (0..cfg.replicas)
        .map(|_| {
            spawn_worker(
                PjrtBackend::factory(cfg.artifacts.clone(), model.to_string(), checkpoint.clone()),
                policy,
            )
        })
        .collect::<Result<Vec<_>>>()?;
    drive_router(Router::new(workers), &ds, sample, requests)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_engine_models_error() {
        let cfg = RunConfig::default();
        assert!(drive_engine(&cfg, "resnet21", 1).is_err()); // not 6n+2
        assert!(drive_engine(&cfg, "vgg_small", 1).is_err());
    }

    #[test]
    fn engine_serving_end_to_end_smoke() {
        // tiny load run: 2 replicas of a resnet8 on 8px images
        let cfg = RunConfig { replicas: 2, max_batch: 2, max_wait_ms: 1, ..RunConfig::default() };
        // compile a small plan directly (drive_engine pins 32px CIFAR
        // geometry; the smoke test shrinks the image for speed)
        let layers = models::cifar_resnet_layers(8, 0.5, 8, cfg.max_batch);
        let plan = Arc::new(
            NetworkPlan::compile(&layers, EngineConfig::default(), Scheme::sb_default()).unwrap(),
        );
        let policy = BatchPolicy {
            max_batch: cfg.max_batch,
            max_wait: Duration::from_millis(cfg.max_wait_ms),
        };
        let workers = (0..cfg.replicas)
            .map(|_| spawn_worker(EngineBackend::factory(Arc::clone(&plan)), policy).unwrap())
            .collect();
        let ds = SyntheticDataset::new("serve", 10, 3, 8, cfg.seed);
        let report = drive_router(Router::new(workers), &ds, plan.sample_elems(), 17).unwrap();
        assert_eq!(report.requests, 17);
        assert_eq!(report.replicas, 2);
        assert!(report.throughput_rps > 0.0);
        assert!(report.p95_ms >= 0.0 && report.mean_ms >= 0.0);
    }
}
