//! Serving load driver: drives the coordinator (router + batcher +
//! PJRT workers) with an open-loop synthetic request stream and reports
//! latency/throughput — the end-to-end serving validation.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::{spawn_worker, BatchPolicy, PjrtBackend, Router};
use crate::data::SyntheticDataset;
use crate::runtime::Manifest;

/// Result of one load run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    pub wall_secs: f64,
    pub throughput_rps: f64,
    pub mean_ms: f64,
    pub p95_ms: f64,
    pub replicas: usize,
}

/// Serve `requests` synthetic samples through `replicas` PJRT workers.
pub fn drive(cfg: &RunConfig, model: &str, requests: usize, checkpoint: Option<std::path::PathBuf>) -> Result<ServeReport> {
    let man = Manifest::load(&cfg.artifacts, model)?;
    let ds = SyntheticDataset::new(
        "serve",
        man.config.num_classes,
        man.config.in_channels,
        man.config.image_size,
        cfg.seed,
    );
    let sample = man.config.in_channels * man.config.image_size * man.config.image_size;

    let policy = BatchPolicy {
        max_batch: cfg.max_batch,
        max_wait: Duration::from_millis(cfg.max_wait_ms),
    };
    eprintln!(
        "spawning {} replica(s) of {model} (compiling artifacts in each worker)...",
        cfg.replicas
    );
    let workers = (0..cfg.replicas)
        .map(|_| {
            spawn_worker(
                PjrtBackend::factory(cfg.artifacts.clone(), model.to_string(), checkpoint.clone()),
                policy,
            )
        })
        .collect::<Result<Vec<_>>>()?;
    let router = Router::new(workers);

    // open-loop submit, then collect
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    let mut buf = vec![0.0f32; sample];
    for i in 0..requests {
        ds.render(i, &mut buf);
        let (rx, _) = router.submit(buf.clone())?;
        pending.push((Instant::now(), rx));
    }
    let mut lat_ms: Vec<f64> = Vec::with_capacity(requests);
    for (t_submit, rx) in pending {
        let reply = rx.recv()??;
        debug_assert_eq!(reply.len(), man.config.num_classes);
        lat_ms.push(t_submit.elapsed().as_secs_f64() * 1e3);
    }
    let wall = t0.elapsed().as_secs_f64();
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let report = ServeReport {
        requests,
        wall_secs: wall,
        throughput_rps: requests as f64 / wall,
        mean_ms: lat_ms.iter().sum::<f64>() / lat_ms.len().max(1) as f64,
        p95_ms: lat_ms[((lat_ms.len() as f64 * 0.95) as usize).min(lat_ms.len() - 1)],
        replicas: cfg.replicas,
    };
    for i in 0..router.replicas() {
        println!("  {}", router.worker(i).latency.report(&format!("replica{i}")));
    }
    router.shutdown()?;
    Ok(report)
}
