//! Serving load drivers: drive the coordinator (router + batcher +
//! supervised workers) with synthetic request streams and report typed
//! outcomes, latency, and throughput — the end-to-end serving
//! validation.
//!
//! Two driver shapes:
//!
//! * **closed burst** ([`drive_engine`], [`drive`]) — submit `requests`
//!   samples, then collect every reply; measures drain throughput for
//!   `plum serve`. Deadlines are relaxed here (a burst is not an arrival
//!   process), so legacy behavior — every request answered — holds.
//! * **open loop** ([`bench_serve_engine`]) — submit on a fixed-rate
//!   clock for a wall-clock duration regardless of completions (the
//!   load-harness methodology SparseDNN uses): under saturation the
//!   bounded queues shed and deadlines expire, and the report carries
//!   p50/p95/p99, shed rate, and goodput. `plum bench serve` persists it
//!   as the `BENCH_serving` series.
//!
//! Backends: [`drive_engine`]/[`bench_serve_engine`] compile an
//! engine-zoo model (CIFAR `resnetN`, projection-shortcut `resnet18c`,
//! or the patch-reuse `chain1x1`) onto the repetition engine **once**,
//! share the plan across replicas, and serve on plain CPU with no
//! features and no artifacts. [`drive`] (`--features pjrt`) compiles the
//! AOT infer executable in each worker.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::RunConfig;
#[cfg(feature = "pjrt")]
use crate::coordinator::PjrtBackend;
use crate::coordinator::{Router, ServeError, ServePolicy, SwapReport};
use crate::data::SyntheticDataset;
use crate::metrics::LatencyHistogram;
use crate::models;
use crate::network::{EngineBackend, NetworkPlan};
use crate::quant::Scheme;
use crate::repetition::EngineConfig;
#[cfg(feature = "pjrt")]
use crate::runtime::Manifest;

/// Result of one closed-burst load run, by typed outcome.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// requests the driver attempted to submit
    pub requests: usize,
    /// requests answered `Ok(logits)`
    pub completed: usize,
    /// requests shed at admission (`Overloaded`)
    pub shed: usize,
    /// requests answered `DeadlineExceeded`
    pub expired: usize,
    /// requests answered `ReplicaFailed` / `BadRequest`
    pub failed: usize,
    /// wall-clock seconds of the run
    pub wall_secs: f64,
    /// completed requests per second (goodput)
    pub throughput_rps: f64,
    /// mean completed-request latency (ms)
    pub mean_ms: f64,
    /// 95th-percentile completed-request latency (ms)
    pub p95_ms: f64,
    /// worker replicas the run used
    pub replicas: usize,
}

/// Closed-burst driver shared by every backend: submit `requests`
/// synthetic samples through the router, collect all replies (typed),
/// report latency and throughput, then shut the replicas down. A
/// dropped reply channel is a conservation bug and fails the run.
fn drive_router(
    router: Router,
    ds: &SyntheticDataset,
    sample: usize,
    requests: usize,
) -> Result<ServeReport> {
    let replicas = router.replicas();
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    let mut shed = 0usize;
    let mut buf = vec![0.0f32; sample];
    for i in 0..requests {
        ds.render(i, &mut buf);
        match router.submit(buf.clone()) {
            Ok((rx, _)) => pending.push((Instant::now(), rx)),
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => bail!("burst submit failed: {e}"),
        }
    }
    let (mut completed, mut expired, mut failed) = (0usize, 0usize, 0usize);
    let mut lat_ms: Vec<f64> = Vec::with_capacity(pending.len());
    for (t_submit, rx) in pending {
        match rx.recv() {
            Ok(Ok(_)) => {
                completed += 1;
                lat_ms.push(t_submit.elapsed().as_secs_f64() * 1e3);
            }
            Ok(Err(ServeError::DeadlineExceeded { .. })) => expired += 1,
            Ok(Err(_)) => failed += 1,
            Err(_) => bail!("reply channel dropped — request conservation violated"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p95_ms = if lat_ms.is_empty() {
        0.0
    } else {
        lat_ms[((lat_ms.len() as f64 * 0.95) as usize).min(lat_ms.len() - 1)]
    };
    let report = ServeReport {
        requests,
        completed,
        shed,
        expired,
        failed,
        wall_secs: wall,
        throughput_rps: completed as f64 / wall,
        mean_ms: lat_ms.iter().sum::<f64>() / lat_ms.len().max(1) as f64,
        p95_ms,
        replicas,
    };
    for i in 0..router.replicas() {
        let s = router.stats(i);
        println!(
            "  {} shed={} expired={} crashes={}",
            s.latency.report(&format!("replica{i}")),
            s.shed.get(),
            s.expired.get(),
            s.crashes.get()
        );
    }
    router.shutdown()?;
    Ok(report)
}

/// A burst of `requests` is not a paced arrival process, so the closed
/// drivers relax the deadline (still bounded) — deadline behavior under
/// load is the open-loop harness's job.
fn burst_policy(cfg: &RunConfig) -> ServePolicy {
    let p = cfg.serve_policy();
    ServePolicy { default_deadline: p.default_deadline.max(Duration::from_secs(60)), ..p }
}

/// Serve `requests` synthetic samples through `cfg.replicas` supervised
/// repetition-engine workers — no `pjrt` feature, no artifacts. The
/// device batch is `cfg.max_batch`; one [`NetworkPlan`] is compiled up
/// front and shared. Models come from the engine zoo
/// (`models::engine_model_layers`): CIFAR `resnetN` (option-A),
/// `resnet18c` (projection shortcuts) and `chain1x1` (the patch-reuse
/// workload).
pub fn drive_engine(cfg: &RunConfig, model: &str, requests: usize) -> Result<ServeReport> {
    let batch = cfg.max_batch.max(1);
    let layers = models::engine_model_layers(model, 32, batch).ok_or_else(|| {
        anyhow!(
            "engine backend serves 'resnetN' (N = 6n+2), 'resnet18c' or 'chain1x1' — \
             got '{model}'"
        )
    })?;
    eprintln!(
        "compiling {model} (batch {batch}, {} conv layers) onto the repetition engine...",
        layers.len()
    );
    // subtile 0 = auto-tuned per layer: serving compiles once and then
    // runs hot, exactly where the tuner's one-time cost amortizes
    let ecfg = EngineConfig { subtile: 0, sparsity_support: true };
    let plan = Arc::new(NetworkPlan::compile_seeded(
        &layers,
        ecfg,
        Scheme::sb_default(),
        cfg.seed,
    )?);
    println!(
        "plan: {} layers, {} ops/pass vs {} dense MACs, {} KiB packed weights, \
         {} patch-fused edge(s), {} arena buffer(s)",
        plan.num_layers(),
        plan.op_counts().total(),
        plan.dense_macs(),
        plan.weight_bits / 8 / 1024,
        plan.patch_fused_edges(),
        plan.num_arena_slots()
    );
    // the executor's hot loop never touches an ineffectual column, so
    // the effectual density below is the fraction of weight work the
    // engine actually performs per pass
    println!("plan density: {}", plan.density_report());
    let sample = plan.sample_elems();
    let ds = SyntheticDataset::new("serve", 10, 3, 32, cfg.seed);
    let router = Router::spawn(
        cfg.replicas.max(1),
        EngineBackend::factory(Arc::clone(&plan)),
        burst_policy(cfg),
    )?;
    drive_router(router, &ds, sample, requests)
}

/// Serve `requests` synthetic samples through `cfg.replicas` supervised
/// PJRT workers.
#[cfg(feature = "pjrt")]
pub fn drive(
    cfg: &RunConfig,
    model: &str,
    requests: usize,
    checkpoint: Option<std::path::PathBuf>,
) -> Result<ServeReport> {
    let man = Manifest::load(&cfg.artifacts, model)?;
    let ds = SyntheticDataset::new(
        "serve",
        man.config.num_classes,
        man.config.in_channels,
        man.config.image_size,
        cfg.seed,
    );
    let sample = man.config.in_channels * man.config.image_size * man.config.image_size;
    eprintln!(
        "spawning {} replica(s) of {model} (compiling artifacts in each worker)...",
        cfg.replicas
    );
    let router = Router::spawn(
        cfg.replicas.max(1),
        PjrtBackend::factory(cfg.artifacts.clone(), model.to_string(), checkpoint),
        burst_policy(cfg),
    )?;
    drive_router(router, &ds, sample, requests)
}

/// Outcome of the hot-swap drill inside an open-loop run
/// (`plum bench serve --swap-at S`): the new version deployed while the
/// load loop kept offering, and the old generation's drain result.
#[derive(Debug, Clone)]
pub struct SwapDrill {
    /// seconds into the load window the swap was fired
    pub at_s: f64,
    /// version the swap deployed (the drill starts at v1, so this is 2)
    pub version: u64,
    /// wall-clock ms to spawn + warm the new fleet before the flip
    pub warmup_ms: f64,
    /// wall-clock ms the old generation took to drain after the flip
    pub drain_ms: f64,
    /// true when the old generation drained inside the policy budget
    /// without fail-fasting stragglers
    pub drained_clean: bool,
    /// requests answered with a typed failure while the drain ran
    pub stragglers: u64,
}

/// Result of one open-loop load run (`plum bench serve`).
#[derive(Debug, Clone)]
pub struct ServeBenchReport {
    /// model the run served
    pub model: String,
    /// replica count behind the router
    pub replicas: usize,
    /// target offered load (requests per second)
    pub target_rps: f64,
    /// requests the load loop offered
    pub offered: usize,
    /// requests answered `Ok(logits)`
    pub completed: usize,
    /// requests shed at admission
    pub shed: usize,
    /// requests answered `DeadlineExceeded`
    pub expired: usize,
    /// requests answered `ReplicaFailed` / `BadRequest`
    pub failed: usize,
    /// worker generations lost across the run (0 without fault injection)
    pub crashes: u64,
    /// wall-clock seconds (load window + drain)
    pub wall_secs: f64,
    /// completed requests per second (goodput, saturation throughput)
    pub achieved_rps: f64,
    /// end-to-end p50 bound (us) over every typed reply
    pub p50_us: u64,
    /// end-to-end p95 bound (us)
    pub p95_us: u64,
    /// end-to-end p99 bound (us)
    pub p99_us: u64,
    /// shed requests per million offered
    pub shed_ppm: u64,
    /// admitted requests whose reply channel was dropped without a
    /// typed reply — a conservation violation; must be 0 (gated in CI
    /// across the hot-swap drill)
    pub dropped: usize,
    /// hot-swap drill outcome (None for a plain run)
    pub swap: Option<SwapDrill>,
}

/// Open-loop load harness: offer `rps` requests/second against a
/// supervised engine-backend fleet for `duration_s` seconds of wall
/// clock — submissions follow the clock, not the completions — then
/// drain and report typed outcomes, end-to-end latency quantiles
/// (p50/p95/p99 bucket bounds over all replies), shed rate, and
/// goodput. `image` shrinks the input (CIFAR geometry is 32) so CI can
/// run a short, cheap window.
pub fn bench_serve_engine(
    cfg: &RunConfig,
    model: &str,
    image: usize,
    rps: f64,
    duration_s: f64,
) -> Result<ServeBenchReport> {
    bench_serve_engine_opts(cfg, model, image, rps, duration_s, None)
}

/// [`bench_serve_engine`] plus the hot-swap drill: with
/// `swap_at = Some(s)`, a side thread fires `Router::deploy` of a fresh
/// model version `s` seconds into the load window *while the open loop
/// keeps offering*. The report then carries the drain outcome and the
/// end-to-end quantiles measured across the swap (absorbed over both
/// generations), and `dropped` counts any reply channel that closed
/// without a typed reply — the zero-drop acceptance gate.
pub fn bench_serve_engine_opts(
    cfg: &RunConfig,
    model: &str,
    image: usize,
    rps: f64,
    duration_s: f64,
    swap_at: Option<f64>,
) -> Result<ServeBenchReport> {
    anyhow::ensure!(rps > 0.0, "--rps must be positive");
    anyhow::ensure!(duration_s > 0.0, "--duration must be positive");
    if let Some(at) = swap_at {
        anyhow::ensure!(at >= 0.0, "--swap-at must be non-negative");
    }
    let batch = cfg.max_batch.max(1);
    let layers = models::engine_model_layers(model, image, batch)
        .ok_or_else(|| anyhow!("unknown engine model '{model}'"))?;
    let ecfg = EngineConfig { subtile: 0, sparsity_support: true };
    let plan = Arc::new(NetworkPlan::compile_seeded(
        &layers,
        ecfg,
        Scheme::sb_default(),
        cfg.seed,
    )?);
    let sample = plan.sample_elems();
    let ds = SyntheticDataset::new("serve", 10, 3, image, cfg.seed);
    let replicas = cfg.replicas.max(1);
    // deploy v1 through the catalog (warmed) so the drill's swap is a
    // plain versioned redeploy of the same slot
    let router = Router::empty(cfg.serve_policy());
    router
        .deploy(model, replicas, EngineBackend::factory(Arc::clone(&plan)))
        .map_err(|e| anyhow!("initial deploy failed: {e}"))?;
    // pre-render a sample ring so rendering stays off the submit path
    let ring: Vec<Vec<f32>> = (0..16)
        .map(|i| {
            let mut b = vec![0.0f32; sample];
            ds.render(i, &mut b);
            b
        })
        .collect();
    let interval = Duration::from_secs_f64(1.0 / rps);
    let t0 = Instant::now();
    let end = t0 + Duration::from_secs_f64(duration_s);
    let mut offered = 0usize;
    let mut shed = 0usize;
    let mut dropped = 0usize;
    let (mut completed, mut expired, mut failed) = (0usize, 0usize, 0usize);
    let swap_result: Option<Result<SwapReport, ServeError>> = std::thread::scope(|scope| {
        let swapper = swap_at.map(|at| {
            let router = &router;
            let plan = Arc::clone(&plan);
            scope.spawn(move || {
                let fire = t0 + Duration::from_secs_f64(at);
                let now = Instant::now();
                if fire > now {
                    std::thread::sleep(fire - now);
                }
                router.deploy(model, replicas, EngineBackend::factory(plan))
            })
        });
        let mut next = t0;
        let mut pending = Vec::new();
        loop {
            let now = Instant::now();
            if now >= end {
                break;
            }
            if now < next {
                std::thread::sleep(next - now);
            }
            // open loop: if we fell behind the clock we submit
            // immediately and catch up instead of thinning the offered
            // load
            match router.submit(ring[offered % ring.len()].clone()) {
                Ok((rx, _)) => pending.push(rx),
                Err(ServeError::Overloaded { .. } | ServeError::ReplicaFailed { .. }) => {
                    shed += 1
                }
                Err(e) => {
                    // deadline-at-admission etc. would be a driver bug;
                    // count it as shed rather than losing the request
                    eprintln!("unexpected admission error (counted as shed): {e}");
                    shed += 1;
                }
            }
            offered += 1;
            next += interval;
        }
        for rx in pending {
            match rx.recv() {
                Ok(Ok(_)) => completed += 1,
                Ok(Err(ServeError::DeadlineExceeded { .. })) => expired += 1,
                Ok(Err(_)) => failed += 1,
                // a closed reply channel without a typed reply violates
                // conservation; counted (and gated to zero in CI)
                Err(_) => dropped += 1,
            }
        }
        swapper.map(|h| h.join().expect("swap thread panicked"))
    });
    let swap = match swap_result {
        None => None,
        Some(Ok(report)) => {
            let d = report.drained.as_ref();
            Some(SwapDrill {
                at_s: swap_at.unwrap_or(0.0),
                version: report.version,
                warmup_ms: report.warmup_ms,
                drain_ms: d.map(|d| d.drain_ms).unwrap_or(0.0),
                drained_clean: d.map(|d| d.clean).unwrap_or(true),
                stragglers: d.map(|d| d.stragglers).unwrap_or(0),
            })
        }
        Some(Err(e)) => bail!("hot swap failed mid-drill: {e}"),
    };
    let wall = t0.elapsed().as_secs_f64();
    let e2e = LatencyHistogram::new();
    let mut crashes = 0u64;
    // absorb over *every* generation (live + retired) so the quantiles
    // span the swap
    for (i, s) in router.all_stats().iter().enumerate() {
        e2e.absorb(&s.e2e);
        crashes += s.crashes.get();
        println!(
            "  {} shed={} crashes={}",
            s.e2e.report(&format!("replica{i} e2e")),
            s.shed.get(),
            s.crashes.get()
        );
    }
    router.shutdown()?;
    Ok(ServeBenchReport {
        model: model.to_string(),
        replicas,
        target_rps: rps,
        offered,
        completed,
        shed,
        expired,
        failed,
        crashes,
        wall_secs: wall,
        achieved_rps: completed as f64 / wall,
        p50_us: e2e.quantile_us(0.5),
        p95_us: e2e.quantile_us(0.95),
        p99_us: e2e.quantile_us(0.99),
        shed_ppm: (shed as u64).saturating_mul(1_000_000) / (offered.max(1) as u64),
        dropped,
        swap,
    })
}

/// Closed-burst driver over a *multi-model* router (`plum serve
/// --models a,b`): compile each named engine model once at `image`
/// pixels (the CLI pins 32, CIFAR geometry), deploy it (warmed) into
/// its own catalog slot, then round-robin the burst across the models
/// by name through `submit_to`.
pub fn drive_engine_multi(
    cfg: &RunConfig,
    model_names: &[String],
    image: usize,
    requests: usize,
) -> Result<ServeReport> {
    anyhow::ensure!(!model_names.is_empty(), "--models needs at least one name");
    let batch = cfg.max_batch.max(1);
    let replicas = cfg.replicas.max(1);
    let router = Router::empty(burst_policy(cfg));
    let mut samples = Vec::with_capacity(model_names.len());
    for name in model_names {
        let layers = models::engine_model_layers(name, image, batch)
            .ok_or_else(|| anyhow!("unknown engine model '{name}'"))?;
        let ecfg = EngineConfig { subtile: 0, sparsity_support: true };
        let plan = Arc::new(NetworkPlan::compile_seeded(
            &layers,
            ecfg,
            Scheme::sb_default(),
            cfg.seed,
        )?);
        eprintln!(
            "deploying {name} (batch {batch}, {} conv layers, {} replicas)...",
            plan.num_layers(),
            replicas
        );
        samples.push(plan.sample_elems());
        let swap = router
            .deploy(name, replicas, EngineBackend::factory(plan))
            .map_err(|e| anyhow!("deploy of '{name}' failed: {e}"))?;
        println!("  {name}: v{} live ({:.1} ms warmup)", swap.version, swap.warmup_ms);
    }
    let ds = SyntheticDataset::new("serve", 10, 3, image, cfg.seed);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    let mut shed = 0usize;
    for i in 0..requests {
        let m = i % model_names.len();
        let mut buf = vec![0.0f32; samples[m]];
        ds.render(i, &mut buf);
        match router.submit_to(&model_names[m], buf) {
            Ok((rx, _)) => pending.push((Instant::now(), rx)),
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => bail!("burst submit to '{}' failed: {e}", model_names[m]),
        }
    }
    let (mut completed, mut expired, mut failed) = (0usize, 0usize, 0usize);
    let mut lat_ms: Vec<f64> = Vec::with_capacity(pending.len());
    for (t_submit, rx) in pending {
        match rx.recv() {
            Ok(Ok(_)) => {
                completed += 1;
                lat_ms.push(t_submit.elapsed().as_secs_f64() * 1e3);
            }
            Ok(Err(ServeError::DeadlineExceeded { .. })) => expired += 1,
            Ok(Err(_)) => failed += 1,
            Err(_) => bail!("reply channel dropped — request conservation violated"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p95_ms = if lat_ms.is_empty() {
        0.0
    } else {
        lat_ms[((lat_ms.len() as f64 * 0.95) as usize).min(lat_ms.len() - 1)]
    };
    for (i, s) in router.all_stats().iter().enumerate() {
        println!(
            "  {} shed={} expired={} crashes={}",
            s.latency.report(&format!("replica{i}")),
            s.shed.get(),
            s.expired.get(),
            s.crashes.get()
        );
    }
    let total_replicas = replicas * model_names.len();
    router.shutdown()?;
    Ok(ServeReport {
        requests,
        completed,
        shed,
        expired,
        failed,
        wall_secs: wall,
        throughput_rps: completed as f64 / wall,
        mean_ms: lat_ms.iter().sum::<f64>() / lat_ms.len().max(1) as f64,
        p95_ms,
        replicas: total_replicas,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BatchPolicy;

    #[test]
    fn unknown_engine_models_error() {
        let cfg = RunConfig::default();
        assert!(drive_engine(&cfg, "resnet21", 1).is_err()); // not 6n+2
        assert!(drive_engine(&cfg, "vgg_small", 1).is_err());
        assert!(bench_serve_engine(&cfg, "vgg_small", 8, 10.0, 0.1).is_err());
    }

    #[test]
    fn engine_serving_end_to_end_smoke() {
        // tiny load run: 2 supervised replicas of a resnet8 on 8px images
        let cfg = RunConfig { replicas: 2, max_batch: 2, max_wait_ms: 1, ..RunConfig::default() };
        // compile a small plan directly (drive_engine pins 32px CIFAR
        // geometry; the smoke test shrinks the image for speed)
        let layers = models::cifar_resnet_layers(8, 0.5, 8, cfg.max_batch);
        let plan = Arc::new(
            NetworkPlan::compile(&layers, EngineConfig::default(), Scheme::sb_default()).unwrap(),
        );
        let policy = ServePolicy {
            batch: BatchPolicy {
                max_batch: cfg.max_batch,
                max_wait: Duration::from_millis(cfg.max_wait_ms),
            },
            default_deadline: Duration::from_secs(60),
            ..ServePolicy::default()
        };
        let router = Router::spawn(
            cfg.replicas,
            EngineBackend::factory(Arc::clone(&plan)),
            policy,
        )
        .unwrap();
        let ds = SyntheticDataset::new("serve", 10, 3, 8, cfg.seed);
        let report = drive_router(router, &ds, plan.sample_elems(), 17).unwrap();
        assert_eq!(report.requests, 17);
        assert_eq!(report.completed, 17);
        assert_eq!(report.shed + report.expired + report.failed, 0);
        assert_eq!(report.replicas, 2);
        assert!(report.throughput_rps > 0.0);
        assert!(report.p95_ms >= 0.0 && report.mean_ms >= 0.0);
    }

    #[test]
    fn open_loop_bench_conserves_every_offered_request() {
        let cfg = RunConfig { replicas: 1, max_batch: 2, max_wait_ms: 1, ..RunConfig::default() };
        let report = bench_serve_engine(&cfg, "resnet8", 8, 300.0, 0.25).unwrap();
        assert!(report.offered > 0);
        assert_eq!(
            report.completed + report.shed + report.expired + report.failed,
            report.offered,
            "typed outcomes must partition the offered load"
        );
        assert!(report.wall_secs > 0.0);
        if report.completed > 0 {
            assert!(report.p50_us > 0);
            assert!(report.p50_us <= report.p95_us && report.p95_us <= report.p99_us);
            assert!(report.achieved_rps > 0.0);
        }
        assert_eq!(report.crashes, 0, "no fault injection here");
        assert_eq!(report.dropped, 0, "reply channels must never drop");
        assert!(report.swap.is_none(), "no swap drill requested");
    }

    #[test]
    fn swap_drill_completes_with_zero_drops() {
        // hot-swap at the midpoint of a short open-loop window: the
        // drill must complete, conserve every offered request, and drop
        // nothing across the swap
        let cfg = RunConfig { replicas: 1, max_batch: 2, max_wait_ms: 1, ..RunConfig::default() };
        let report = bench_serve_engine_opts(&cfg, "resnet8", 8, 200.0, 0.4, Some(0.2)).unwrap();
        assert!(report.offered > 0);
        assert_eq!(
            report.completed + report.shed + report.expired + report.failed,
            report.offered,
            "typed outcomes must partition the offered load across the swap"
        );
        assert_eq!(report.dropped, 0, "hot swap dropped replies");
        let swap = report.swap.expect("drill must report the swap");
        assert_eq!(swap.version, 2);
        assert!(swap.warmup_ms >= 0.0);
        assert!(swap.drain_ms >= 0.0);
    }

    #[test]
    fn multi_model_burst_round_robins_by_name() {
        let cfg = RunConfig { replicas: 1, max_batch: 2, max_wait_ms: 1, ..RunConfig::default() };
        let names = vec!["resnet8".to_string(), "chain1x1".to_string()];
        let report = drive_engine_multi(&cfg, &names, 8, 10).unwrap();
        assert_eq!(report.requests, 10);
        assert_eq!(report.completed, 10);
        assert_eq!(report.replicas, 2); // one replica per model slot
    }
}
