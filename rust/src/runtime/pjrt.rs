//! PJRT execution (`pjrt` feature): load AOT HLO-text artifacts, compile
//! once per variant, execute from the rust hot path. Python is never
//! involved.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Interchange is HLO *text* because
//! xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id serialized protos.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::{Dtype, Manifest, TensorSpec};

/// Wrapper over the PJRT CPU client. One per process; executables are
/// compiled through it and cached by the caller (`ModelHandle`).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Construct the process-wide PJRT CPU client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    /// Backing platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Build a literal matching `spec` from raw f32 storage (i32 specs are
/// converted elementwise — used only for label tensors).
pub fn literal_for_spec(spec: &TensorSpec, data: &[f32]) -> Result<xla::Literal> {
    match spec.dtype {
        Dtype::F32 => literal_f32(&spec.shape, data),
        Dtype::I32 => {
            let ints: Vec<i32> = data.iter().map(|v| *v as i32).collect();
            literal_i32(&spec.shape, &ints)
        }
    }
}

/// A compiled model: manifest + executables.
pub struct ModelHandle {
    /// the artifact manifest the executables were compiled from
    pub manifest: Manifest,
    /// compiled train-step executable (absent for serve-only loads)
    pub train_exe: Option<xla::PjRtLoadedExecutable>,
    /// compiled infer executable
    pub infer_exe: xla::PjRtLoadedExecutable,
}

impl ModelHandle {
    /// Load a model's artifacts from `dir` and compile. `need_train`
    /// skips the train executable for serve-only uses.
    pub fn load(rt: &Runtime, dir: &Path, name: &str, need_train: bool) -> Result<ModelHandle> {
        let manifest = Manifest::load(dir, name)?;
        let infer_exe = rt
            .compile_hlo_file(&manifest.infer_hlo)
            .context("compiling infer artifact")?;
        let train_exe = match (&manifest.train_hlo, need_train) {
            (Some(p), true) => Some(rt.compile_hlo_file(p).context("compiling train artifact")?),
            _ => None,
        };
        Ok(ModelHandle { manifest, train_exe, infer_exe })
    }

    /// Execute the infer artifact: state literals ++ x.
    pub fn infer<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        execute_tuple(&self.infer_exe, inputs)
    }

    /// Execute one train step; returns the flat output tuple
    /// (loss, acc, params', bn', m', v').
    pub fn train_step<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .train_exe
            .as_ref()
            .ok_or_else(|| anyhow!("model loaded without train executable"))?;
        execute_tuple(exe, inputs)
    }
}

/// Execute and flatten the (always-tupled) result.
pub fn execute_tuple<L: std::borrow::Borrow<xla::Literal>>(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[L],
) -> Result<Vec<xla::Literal>> {
    let result = exe
        .execute::<L>(inputs)
        .map_err(|e| anyhow!("execute: {e:?}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetch result: {e:?}"))?;
    lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
}

/// Read back a literal as f32 (converting i32 if needed).
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    match lit.ty() {
        Ok(xla::ElementType::F32) => lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}")),
        Ok(xla::ElementType::S32) => Ok(lit
            .to_vec::<i32>()
            .map_err(|e| anyhow!("{e:?}"))?
            .into_iter()
            .map(|v| v as f32)
            .collect()),
        other => Err(anyhow!("unsupported literal type {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_shapes() {
        let l = literal_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(l.element_count(), 6);
        let back = l.to_vec::<f32>().unwrap();
        assert_eq!(back, vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn scalar_literal() {
        let l = literal_f32(&[], &[7.5]).unwrap();
        assert_eq!(l.element_count(), 1);
    }
}
