//! Runtime layer (S5).
//!
//! The artifact *manifest* contract (shapes, dtypes, conv-layer
//! geometry, initial state) is always compiled — the repetition engine,
//! registry and checkpoints only need that. The PJRT execution path
//! (load AOT HLO-text artifacts, compile once per variant, execute from
//! the rust hot path) depends on the `xla` crate / `xla_extension`
//! shared library and lives behind the off-by-default `pjrt` feature;
//! see rust/README.md for the build matrix.

pub mod manifest;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use manifest::{ConfigEcho, ConvLayerInfo, Dtype, Manifest, TensorSpec};
#[cfg(feature = "pjrt")]
pub use pjrt::{
    execute_tuple, literal_f32, literal_for_spec, literal_i32, literal_to_f32, ModelHandle,
    Runtime,
};
