//! Artifact manifest: the positional input/output contract between the
//! python AOT emitter and the rust runtime.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::tensor::Conv2dGeometry;
use crate::util::Json;

/// Element type of a marshalled tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float
    F32,
    /// 32-bit signed integer (labels)
    I32,
}

/// One positional tensor in an artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    /// signature group ("params", "bn", "consts", "x", "y", ...)
    pub group: String,
    /// tensor name, e.g. `003.conv.w`
    pub name: String,
    /// tensor shape
    pub shape: Vec<usize>,
    /// element type
    pub dtype: Dtype,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        let dtype = match j.req_str("dtype")? {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            other => return Err(anyhow!("unsupported dtype {other}")),
        };
        Ok(TensorSpec {
            group: j.req_str("group")?.to_string(),
            name: j.req_str("name")?.to_string(),
            shape: j
                .req_arr("shape")?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?,
            dtype,
        })
    }
}

/// Echo of the python ModelConfig that produced the artifact.
#[derive(Debug, Clone)]
pub struct ConfigEcho {
    /// architecture family ("cifar_resnet", "resnet18", ...)
    pub arch: String,
    /// network depth
    pub depth: usize,
    /// channel width multiplier
    pub width_mult: f64,
    /// classifier classes
    pub num_classes: usize,
    /// square input image side
    pub image_size: usize,
    /// input channels
    pub in_channels: usize,
    /// training/inference batch size the artifact was lowered at
    pub batch_size: usize,
    /// quantization scheme name ("fp", "binary", "ternary", "sb")
    pub scheme: String,
    /// Delta threshold fraction
    pub delta_frac: f64,
    /// fraction of {0,+a} regions
    pub p_pos: f64,
    /// signed-binary regions per filter
    pub regions_per_filter: usize,
    /// adapted EDE gradient estimator enabled
    pub use_ede: bool,
    /// non-linearity name ("relu", "prelu", ...)
    pub act: String,
}

impl ConfigEcho {
    fn parse(j: &Json) -> Result<ConfigEcho> {
        Ok(ConfigEcho {
            arch: j.req_str("arch")?.to_string(),
            depth: j.req_usize("depth")?,
            width_mult: j.req_f64("width_mult")?,
            num_classes: j.req_usize("num_classes")?,
            image_size: j.req_usize("image_size")?,
            in_channels: j.req_usize("in_channels")?,
            batch_size: j.req_usize("batch_size")?,
            scheme: j.req_str("scheme")?.to_string(),
            delta_frac: j.req_f64("delta_frac")?,
            p_pos: j.req_f64("p_pos")?,
            regions_per_filter: j.req_usize("regions_per_filter")?,
            use_ede: j
                .get("use_ede")
                .and_then(Json::as_bool)
                .ok_or_else(|| anyhow!("missing use_ede"))?,
            act: j.req_str("act")?.to_string(),
        })
    }
}

/// Conv layer geometry recorded at trace time (batch dim = 1 in the log;
/// scale `n` as needed for workloads).
#[derive(Debug, Clone)]
pub struct ConvLayerInfo {
    /// layer name, e.g. `003.conv`
    pub name: String,
    /// conv geometry (batch = 1 in the log)
    pub geom: Conv2dGeometry,
    /// false for full-precision layers (the stem)
    pub quantized: bool,
}

/// Parsed `<name>.manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// artifact name
    pub name: String,
    /// artifact directory the manifest was loaded from
    pub dir: PathBuf,
    /// ModelConfig echo
    pub config: ConfigEcho,
    /// train-step HLO path (absent for infer-only artifacts)
    pub train_hlo: Option<PathBuf>,
    /// infer HLO path
    pub infer_hlo: PathBuf,
    /// initial-state binary path
    pub params_bin: PathBuf,
    /// positional train-step input specs
    pub train_inputs: Vec<TensorSpec>,
    /// positional train-step output specs
    pub train_outputs: Vec<TensorSpec>,
    /// positional infer input specs
    pub infer_inputs: Vec<TensorSpec>,
    /// names of the quantized weight tensors
    pub quantized_weights: Vec<String>,
    /// conv layer geometry recorded at trace time
    pub conv_layers: Vec<ConvLayerInfo>,
    /// total trainable parameters
    pub param_count: usize,
    /// effectual parameters at initialization
    pub effectual_params_init: usize,
}

impl Manifest {
    /// Load and validate `<dir>/<name>.manifest.json`.
    pub fn load(dir: &Path, name: &str) -> Result<Manifest> {
        let path = dir.join(format!("{name}.manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;

        let files = j.get("files").ok_or_else(|| anyhow!("missing files"))?;
        let has_train = j.get("has_train").and_then(Json::as_bool).unwrap_or(false);
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.req_arr(key)?.iter().map(TensorSpec::parse).collect()
        };
        let conv_layers = j
            .req_arr("conv_layers")?
            .iter()
            .map(|c| {
                Ok(ConvLayerInfo {
                    name: c.req_str("name")?.to_string(),
                    geom: Conv2dGeometry {
                        n: 1,
                        c: c.req_usize("c")?,
                        h: c.req_usize("h")?,
                        w: c.req_usize("w")?,
                        k: c.req_usize("k")?,
                        r: c.req_usize("r")?,
                        s: c.req_usize("s")?,
                        stride: c.req_usize("stride")?,
                        padding: c.req_usize("padding")?,
                    },
                    quantized: c.get("quantized").and_then(Json::as_bool).unwrap_or(false),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            name: name.to_string(),
            dir: dir.to_path_buf(),
            config: ConfigEcho::parse(j.get("config").ok_or_else(|| anyhow!("missing config"))?)?,
            train_hlo: if has_train {
                Some(dir.join(files.req_str("train")?))
            } else {
                None
            },
            infer_hlo: dir.join(files.req_str("infer")?),
            params_bin: dir.join(files.req_str("params")?),
            train_inputs: if has_train { specs("train_inputs")? } else { vec![] },
            train_outputs: if has_train { specs("train_outputs")? } else { vec![] },
            infer_inputs: specs("infer_inputs")?,
            quantized_weights: j
                .req_arr("quantized_weights")?
                .iter()
                .filter_map(|v| v.as_str().map(str::to_string))
                .collect(),
            conv_layers,
            param_count: j.req_usize("param_count")?,
            effectual_params_init: j.req_usize("effectual_params_init")?,
        })
    }

    /// Specs of one input group, in positional order.
    pub fn group<'a>(&'a self, specs: &'a [TensorSpec], name: &str) -> Vec<&'a TensorSpec> {
        specs.iter().filter(|s| s.group == name).collect()
    }

    /// Load `<name>.params.bin` split per state spec (params ++ bn ++
    /// consts in manifest order).
    pub fn load_initial_state(&self) -> Result<Vec<(TensorSpec, Vec<f32>)>> {
        let bytes = std::fs::read(&self.params_bin)
            .with_context(|| format!("reading {}", self.params_bin.display()))?;
        let state_specs: Vec<TensorSpec> = self
            .state_specs()
            .into_iter()
            .cloned()
            .collect();
        let total: usize = state_specs.iter().map(TensorSpec::elements).sum();
        if bytes.len() != total * 4 {
            return Err(anyhow!(
                "params.bin has {} bytes, expected {}",
                bytes.len(),
                total * 4
            ));
        }
        let mut out = Vec::with_capacity(state_specs.len());
        let mut off = 0usize;
        for spec in state_specs {
            let n = spec.elements();
            let mut v = vec![0.0f32; n];
            for (i, chunk) in bytes[off..off + 4 * n].chunks_exact(4).enumerate() {
                v[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            off += 4 * n;
            out.push((spec, v));
        }
        Ok(out)
    }

    /// The persistent-state specs (params ++ bn ++ consts) in order; these
    /// lead both the train and infer signatures.
    pub fn state_specs(&self) -> Vec<&TensorSpec> {
        let src = if self.train_inputs.is_empty() {
            &self.infer_inputs
        } else {
            &self.train_inputs
        };
        src.iter()
            .filter(|s| matches!(s.group.as_str(), "params" | "bn" | "consts"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_r8sb_manifest_when_present() {
        let dir = artifacts_dir();
        if !dir.join("r8sb_p050.manifest.json").exists() {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let m = Manifest::load(&dir, "r8sb_p050").unwrap();
        assert_eq!(m.config.scheme, "sb");
        assert!(m.train_hlo.is_some());
        assert!(!m.train_inputs.is_empty());
        // signature sanity: state specs lead, x/y/hypers trail
        let last = &m.train_inputs[m.train_inputs.len() - 1];
        assert_eq!(last.name, "progress");
        let state = m.state_specs();
        assert!(!state.is_empty());
        let init = m.load_initial_state().unwrap();
        assert_eq!(init.len(), state.len());
    }
}
