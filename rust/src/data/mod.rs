//! Synthetic dataset substrate (S8).
//!
//! Stands in for CIFAR-10 / SVHN / CIFAR-100 / TinyImageNet / ImageNet
//! (repro substitution — see DESIGN.md): the paper's accuracy claims are
//! *relative* between quantization schemes trained identically, so a
//! learnable, deterministic, class-conditional image distribution
//! preserves the orderings while being reproducible from a seed.
//!
//! Each class owns a prototype texture (a small bank of random 2-D
//! sinusoids) plus a class-specific color balance; a sample is the
//! prototype under a random translation, amplitude jitter and additive
//! Gaussian pixel noise. Samples are generated *by index* so train/eval
//! splits are stable and any batch is reproducible without storing data.

use crate::util::Rng;

/// A deterministic synthetic labelled-image dataset.
#[derive(Debug, Clone)]
pub struct SyntheticDataset {
    /// number of classes
    pub classes: usize,
    /// image channels (3 for the CIFAR-like families)
    pub channels: usize,
    /// square image side in pixels
    pub image: usize,
    /// kind-mixed seed every sample derives from
    pub seed: u64,
    /// additive Gaussian pixel-noise std (difficulty knob)
    pub noise: f32,
    /// per-class sinusoid parameters: (fx, fy, phase, amp) per component
    protos: Vec<Vec<(f32, f32, f32, f32)>>,
    /// per-class per-channel gain
    gains: Vec<Vec<f32>>,
}

/// Sinusoid components per class prototype.
pub const COMPONENTS: usize = 6;

impl SyntheticDataset {
    /// `kind` gives dataset-family flavours matched to the paper's tables
    /// ("cifar", "svhn", "cifar100", "tinyimagenet") — they differ only in
    /// class count / geometry defaults chosen by the caller; the
    /// generator itself is identical, seeded differently per kind.
    pub fn new(kind: &str, classes: usize, channels: usize, image: usize, seed: u64) -> Self {
        let kind_seed = kind.bytes().fold(seed, |a, b| a.wrapping_mul(31).wrapping_add(b as u64));
        let mut rng = Rng::new(kind_seed);
        let mut protos = Vec::with_capacity(classes);
        let mut gains = Vec::with_capacity(classes);
        for _ in 0..classes {
            let comps: Vec<(f32, f32, f32, f32)> = (0..COMPONENTS)
                .map(|_| {
                    (
                        rng.range_f32(0.5, 4.0),
                        rng.range_f32(0.5, 4.0),
                        rng.range_f32(0.0, std::f32::consts::TAU),
                        rng.range_f32(0.4, 1.0),
                    )
                })
                .collect();
            protos.push(comps);
            gains.push((0..channels).map(|_| rng.range_f32(0.5, 1.5)).collect());
        }
        SyntheticDataset { classes, channels, image, seed: kind_seed, noise: 0.25, protos, gains }
    }

    /// The default CIFAR-10-shaped dataset (10 classes, 3x32x32).
    pub fn cifar_like(seed: u64) -> Self {
        Self::new("cifar", 10, 3, 32, seed)
    }

    /// Label of sample `index` (uniform round-robin keeps classes balanced).
    pub fn label(&self, index: usize) -> usize {
        index % self.classes
    }

    /// Render sample `index` into `out` (len = channels * image * image).
    pub fn render(&self, index: usize, out: &mut [f32]) {
        let c = self.label(index);
        let mut rng = Rng::new(self.seed).fork(index as u64 + 1);
        let dx = rng.range_f32(-2.0, 2.0);
        let dy = rng.range_f32(-2.0, 2.0);
        let amp = rng.range_f32(0.8, 1.2);
        let n = self.image;
        assert_eq!(out.len(), self.channels * n * n);
        let inv = 1.0 / n as f32;
        for ch in 0..self.channels {
            let gain = self.gains[c][ch] * amp;
            for y in 0..n {
                for x in 0..n {
                    let xf = (x as f32 + dx) * inv * std::f32::consts::TAU;
                    let yf = (y as f32 + dy) * inv * std::f32::consts::TAU;
                    let mut v = 0.0;
                    for (i, (fx, fy, ph, a)) in self.protos[c].iter().enumerate() {
                        // channel phase offset decorrelates channels
                        let cph = ph + ch as f32 * 0.7 + i as f32 * 0.13;
                        v += a * (fx * xf + fy * yf + cph).sin();
                    }
                    v = v * gain / COMPONENTS as f32;
                    out[(ch * n + y) * n + x] = v + self.noise * rng.normal();
                }
            }
        }
    }

    /// Fill a batch starting at sample `start` (x NCHW, y labels).
    pub fn batch(&self, start: usize, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let sample = self.channels * self.image * self.image;
        let mut xs = vec![0.0f32; batch * sample];
        let mut ys = vec![0i32; batch];
        for b in 0..batch {
            let idx = start + b;
            self.render(idx, &mut xs[b * sample..(b + 1) * sample]);
            ys[b] = self.label(idx) as i32;
        }
        (xs, ys)
    }

    /// Evaluation batches draw from a disjoint index range.
    pub fn eval_batch(
        &self,
        eval_offset: usize,
        start: usize,
        batch: usize,
    ) -> (Vec<f32>, Vec<i32>) {
        self.batch(eval_offset + start, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_index() {
        let ds = SyntheticDataset::cifar_like(42);
        let mut a = vec![0.0; 3 * 32 * 32];
        let mut b = vec![0.0; 3 * 32 * 32];
        ds.render(17, &mut a);
        ds.render(17, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn different_indices_differ() {
        let ds = SyntheticDataset::cifar_like(42);
        let mut a = vec![0.0; 3 * 32 * 32];
        let mut b = vec![0.0; 3 * 32 * 32];
        ds.render(0, &mut a);
        ds.render(10, &mut b); // same class (10 % 10 == 0), different jitter
        assert_ne!(a, b);
    }

    #[test]
    fn labels_balanced() {
        let ds = SyntheticDataset::cifar_like(1);
        let mut counts = [0usize; 10];
        for i in 0..100 {
            counts[ds.label(i)] += 1;
        }
        assert!(counts.iter().all(|c| *c == 10));
    }

    #[test]
    fn class_signal_exceeds_noise() {
        // mean same-class distance should be well below cross-class
        let ds = SyntheticDataset::cifar_like(3);
        let sample = 3 * 32 * 32;
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>() / sample as f32
        };
        let mut same = 0.0;
        let mut cross = 0.0;
        let mut x0 = vec![0.0; sample];
        let mut x1 = vec![0.0; sample];
        for i in 0..10 {
            ds.render(i * 10, &mut x0); // class 0
            ds.render(i * 10 + 100, &mut x1); // class 0 again
            same += dist(&x0, &x1);
            ds.render(i * 10 + 1, &mut x1); // class 1
            cross += dist(&x0, &x1);
        }
        assert!(cross > same * 1.15, "cross {cross} vs same {same}");
    }

    #[test]
    fn batch_layout() {
        let ds = SyntheticDataset::new("svhn", 10, 3, 16, 7);
        let (xs, ys) = ds.batch(0, 4);
        assert_eq!(xs.len(), 4 * 3 * 16 * 16);
        assert_eq!(ys, vec![0, 1, 2, 3]);
    }

    #[test]
    fn kinds_produce_different_data() {
        let a = SyntheticDataset::new("cifar", 10, 3, 16, 7);
        let b = SyntheticDataset::new("svhn", 10, 3, 16, 7);
        let mut xa = vec![0.0; 3 * 16 * 16];
        let mut xb = vec![0.0; 3 * 16 * 16];
        a.render(0, &mut xa);
        b.render(0, &mut xb);
        assert_ne!(xa, xb);
    }
}
