//! GEMM and elementwise primitives.
//!
//! `gemm` is a cache-blocked, unrolled matrix multiply — not a BLAS rival,
//! but a fair dense baseline on this CPU (the paper's SumMerge also
//! compares against straightforward dense loops, not MKL). The row
//! dimension is parallelized over `MC`-row blocks through the shared
//! worker pool so the dense baseline scales with threads exactly like
//! the repetition engine — speedup ratios between the two stay honest.
//! Block boundaries and per-row accumulation order are identical for
//! every thread count, so results are bit-identical to the serial path.

use crate::util::{Pool, UnsafeSlice};

use super::Tensor;

const MC: usize = 64; // rows of A per L2 block (also the parallel grain)
const KC: usize = 256; // depth per block
const NR: usize = 8; // columns unrolled in the micro-kernel

/// C[m,n] = A[m,k] * B[k,n].
pub fn gemm(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (k2, n) = (b.dim(0), b.dim(1));
    assert_eq!(k, k2, "gemm inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    gemm_into(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// Raw-slice GEMM used by both the Tensor API and the inference engines.
/// Runs on the process-wide pool; see [`gemm_into_pool`] for an explicit
/// thread count.
pub fn gemm_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_into_pool(a, b, c, m, k, n, Pool::global());
}

/// GEMM parallelized over `MC`-row blocks of A/C through `pool`. Each
/// block's C rows are a disjoint contiguous slice, so workers write
/// without synchronization.
pub fn gemm_into_pool(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    pool: &Pool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let blocks = m.div_ceil(MC);
    if pool.threads() <= 1 || blocks <= 1 {
        gemm_block(a, b, c, m, k, n);
        return;
    }
    let out = UnsafeSlice::new(c);
    pool.run(blocks, |bi| {
        let i0 = bi * MC;
        let rows = MC.min(m - i0);
        // SAFETY: job `bi` owns rows [i0, i0 + rows) of C exclusively —
        // MC-row blocks partition 0..m, so the [i0*n, (i0+rows)*n)
        // ranges are pairwise disjoint and end at m*n == c.len().
        let cb = unsafe { out.slice_mut(i0 * n, rows * n) };
        gemm_block(&a[i0 * k..(i0 + rows) * k], b, cb, rows, k, n);
    });
}

/// Serial cache-blocked kernel on one row block: blocking over (i, p);
/// the inner kernel walks B rows sequentially which keeps it streaming
/// from L1/L2.
fn gemm_block(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    let mut ib = 0;
    while ib < m {
        let i_end = (ib + MC).min(m);
        let mut pb = 0;
        while pb < k {
            let p_end = (pb + KC).min(k);
            for i in ib..i_end {
                let arow = &a[i * k..i * k + k];
                let crow = &mut c[i * n..i * n + n];
                for p in pb..p_end {
                    let av = arow[p];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[p * n..p * n + n];
                    let mut j = 0;
                    // unrolled by NR
                    while j + NR <= n {
                        crow[j] += av * brow[j];
                        crow[j + 1] += av * brow[j + 1];
                        crow[j + 2] += av * brow[j + 2];
                        crow[j + 3] += av * brow[j + 3];
                        crow[j + 4] += av * brow[j + 4];
                        crow[j + 5] += av * brow[j + 5];
                        crow[j + 6] += av * brow[j + 6];
                        crow[j + 7] += av * brow[j + 7];
                        j += NR;
                    }
                    while j < n {
                        crow[j] += av * brow[j];
                        j += 1;
                    }
                }
            }
            pb = p_end;
        }
        ib = i_end;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn gemm_naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.dim(0), a.dim(1), b.dim(1));
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a.data()[i * k + p] * b.data()[p * n + j];
                }
                c.data_mut()[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_small() {
        let mut rng = Rng::new(1);
        let a = Tensor::rand_normal(&[7, 13], 1.0, &mut rng);
        let b = Tensor::rand_normal(&[13, 5], 1.0, &mut rng);
        let c = gemm(&a, &b);
        let cref = gemm_naive(&a, &b);
        assert!(c.max_abs_diff(&cref) < 1e-4);
    }

    #[test]
    fn gemm_matches_naive_blocked_sizes() {
        // exceed MC and KC so the blocking paths run
        let mut rng = Rng::new(2);
        let a = Tensor::rand_normal(&[130, 300], 0.5, &mut rng);
        let b = Tensor::rand_normal(&[300, 17], 0.5, &mut rng);
        let c = gemm(&a, &b);
        let cref = gemm_naive(&a, &b);
        assert!(c.max_abs_diff(&cref) < 1e-3);
    }

    #[test]
    fn gemm_identity() {
        let n = 9;
        let eye = Tensor::from_fn(&[n, n], |i| if i / n == i % n { 1.0 } else { 0.0 });
        let mut rng = Rng::new(3);
        let a = Tensor::rand_normal(&[n, n], 1.0, &mut rng);
        assert!(gemm(&a, &eye).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn parallel_gemm_bit_identical_to_serial() {
        // multiple MC blocks so the parallel path actually engages
        let mut rng = Rng::new(4);
        let (m, k, n) = (3 * MC + 11, 70, 23);
        let a = Tensor::rand_normal(&[m, k], 0.7, &mut rng);
        let b = Tensor::rand_normal(&[k, n], 0.7, &mut rng);
        let mut serial = vec![0.0f32; m * n];
        gemm_into_pool(a.data(), b.data(), &mut serial, m, k, n, &Pool::new(1));
        for threads in [2, 3, 8] {
            let mut par = vec![0.0f32; m * n];
            gemm_into_pool(a.data(), b.data(), &mut par, m, k, n, &Pool::new(threads));
            assert!(serial == par, "{threads}-thread gemm differs from serial");
        }
    }

    #[test]
    #[should_panic]
    fn gemm_dim_mismatch() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        gemm(&a, &b);
    }
}
