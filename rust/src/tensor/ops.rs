//! GEMM and elementwise primitives.
//!
//! `gemm` is a cache-blocked, unrolled matrix multiply — not a BLAS rival,
//! but a fair dense baseline on this CPU (the paper's SumMerge also
//! compares against straightforward dense loops, not MKL).

use super::Tensor;

const MC: usize = 64; // rows of A per L2 block
const KC: usize = 256; // depth per block
const NR: usize = 8; // columns unrolled in the micro-kernel

/// C[m,n] = A[m,k] * B[k,n].
pub fn gemm(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (k2, n) = (b.dim(0), b.dim(1));
    assert_eq!(k, k2, "gemm inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    gemm_into(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// Raw-slice GEMM used by both the Tensor API and the inference engines.
pub fn gemm_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    // cache blocking over (i, p); the inner kernel walks B rows
    // sequentially which keeps it streaming from L1/L2.
    let mut ib = 0;
    while ib < m {
        let i_end = (ib + MC).min(m);
        let mut pb = 0;
        while pb < k {
            let p_end = (pb + KC).min(k);
            for i in ib..i_end {
                let arow = &a[i * k..i * k + k];
                let crow = &mut c[i * n..i * n + n];
                for p in pb..p_end {
                    let av = arow[p];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[p * n..p * n + n];
                    let mut j = 0;
                    // unrolled by NR
                    while j + NR <= n {
                        crow[j] += av * brow[j];
                        crow[j + 1] += av * brow[j + 1];
                        crow[j + 2] += av * brow[j + 2];
                        crow[j + 3] += av * brow[j + 3];
                        crow[j + 4] += av * brow[j + 4];
                        crow[j + 5] += av * brow[j + 5];
                        crow[j + 6] += av * brow[j + 6];
                        crow[j + 7] += av * brow[j + 7];
                        j += NR;
                    }
                    while j < n {
                        crow[j] += av * brow[j];
                        j += 1;
                    }
                }
            }
            pb = p_end;
        }
        ib = i_end;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn gemm_naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.dim(0), a.dim(1), b.dim(1));
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a.data()[i * k + p] * b.data()[p * n + j];
                }
                c.data_mut()[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_small() {
        let mut rng = Rng::new(1);
        let a = Tensor::rand_normal(&[7, 13], 1.0, &mut rng);
        let b = Tensor::rand_normal(&[13, 5], 1.0, &mut rng);
        let c = gemm(&a, &b);
        let cref = gemm_naive(&a, &b);
        assert!(c.max_abs_diff(&cref) < 1e-4);
    }

    #[test]
    fn gemm_matches_naive_blocked_sizes() {
        // exceed MC and KC so the blocking paths run
        let mut rng = Rng::new(2);
        let a = Tensor::rand_normal(&[130, 300], 0.5, &mut rng);
        let b = Tensor::rand_normal(&[300, 17], 0.5, &mut rng);
        let c = gemm(&a, &b);
        let cref = gemm_naive(&a, &b);
        assert!(c.max_abs_diff(&cref) < 1e-3);
    }

    #[test]
    fn gemm_identity() {
        let n = 9;
        let eye = Tensor::from_fn(&[n, n], |i| if i / n == i % n { 1.0 } else { 0.0 });
        let mut rng = Rng::new(3);
        let a = Tensor::rand_normal(&[n, n], 1.0, &mut rng);
        assert!(gemm(&a, &eye).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    #[should_panic]
    fn gemm_dim_mismatch() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        gemm(&a, &b);
    }
}
