//! Convolution: naive direct loops and im2col + GEMM.
//!
//! Layouts match the python side exactly (NCHW activations, OIHW weights,
//! im2col patch matrix [N*OH*OW, C*R*S] with the (c, r*s) minor order of
//! `ref.im2col_ref`), so artifacts and golden files cross-check 1:1.

use crate::util::Pool;

use super::{gemm_into_pool, Tensor};

/// Geometry of one conv layer — shared by the repetition engine, the
/// simulator and the model descriptors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dGeometry {
    /// batch size
    pub n: usize,
    /// input channels
    pub c: usize,
    /// input height
    pub h: usize,
    /// input width
    pub w: usize,
    /// output channels (filters)
    pub k: usize,
    /// kernel height
    pub r: usize,
    /// kernel width
    pub s: usize,
    /// spatial stride (both axes)
    pub stride: usize,
    /// zero padding (both axes)
    pub padding: usize,
}

impl Conv2dGeometry {
    /// Output height `(h + 2*padding - r) / stride + 1`.
    pub fn out_h(&self) -> usize {
        (self.h + 2 * self.padding - self.r) / self.stride + 1
    }

    /// Output width `(w + 2*padding - s) / stride + 1`.
    pub fn out_w(&self) -> usize {
        (self.w + 2 * self.padding - self.s) / self.stride + 1
    }

    /// MACs for a dense, repetition/sparsity-unaware conv — the paper's
    /// arithmetic-reduction denominator.
    pub fn dense_macs(&self) -> u64 {
        (self.n * self.k * self.out_h() * self.out_w()) as u64
            * (self.c * self.r * self.s) as u64
    }

    /// Weight elements of this layer (`k * c * r * s`).
    pub fn weight_count(&self) -> usize {
        self.k * self.c * self.r * self.s
    }
}

/// Direct convolution — the reference for everything else.
pub fn conv2d_naive(x: &Tensor, w: &Tensor, stride: usize, padding: usize) -> Tensor {
    let (n, c, h, wd) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (k, c2, r, s) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
    assert_eq!(c, c2, "in-channel mismatch");
    let oh = (h + 2 * padding - r) / stride + 1;
    let ow = (wd + 2 * padding - s) / stride + 1;
    let mut out = Tensor::zeros(&[n, k, oh, ow]);
    for ni in 0..n {
        for ki in 0..k {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0;
                    for ci in 0..c {
                        for ry in 0..r {
                            let iy = oy * stride + ry;
                            if iy < padding || iy - padding >= h {
                                continue;
                            }
                            for sx in 0..s {
                                let ix = ox * stride + sx;
                                if ix < padding || ix - padding >= wd {
                                    continue;
                                }
                                acc += x.at4(ni, ci, iy - padding, ix - padding)
                                    * w.at4(ki, ci, ry, sx);
                            }
                        }
                    }
                    out.set4(ni, ki, oy, ox, acc);
                }
            }
        }
    }
    out
}

/// NCHW -> [N*OH*OW, C*R*S] patch matrix, matching `ref.im2col_ref`.
///
/// Only the dense GEMM path materializes the full matrix; the tiled
/// repetition executor builds just the rows of its current pixel tile
/// via [`im2col_rows`].
pub fn im2col(x: &Tensor, r: usize, s: usize, stride: usize, padding: usize) -> Tensor {
    let (n, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let oh = (h + 2 * padding - r) / stride + 1;
    let ow = (w + 2 * padding - s) / stride + 1;
    let cols = c * r * s;
    let mut out = Tensor::zeros(&[n * oh * ow, cols]);
    im2col_rows(x, r, s, stride, padding, 0, n * oh * ow, out.data_mut());
    out
}

/// Fill `dst[0 .. rows * C*R*S]` with the im2col patch rows of output
/// pixels `[px0, px0 + rows)` (global pixel index `px = ((n*OH)+oy)*OW+ox`).
/// Row layout is identical to [`im2col`]; every element of the range is
/// written, so `dst` may hold stale data from a previous tile.
#[allow(clippy::too_many_arguments)]
pub fn im2col_rows(
    x: &Tensor,
    r: usize,
    s: usize,
    stride: usize,
    padding: usize,
    px0: usize,
    rows: usize,
    dst: &mut [f32],
) {
    im2col_rows_into(x.data(), &patch_geometry(x, r, s, stride, padding), px0, rows, dst);
}

/// The `Conv2dGeometry` a raw activation buffer + kernel parameters
/// describe (k is irrelevant to patch extraction and set to 0).
fn patch_geometry(
    x: &Tensor,
    r: usize,
    s: usize,
    stride: usize,
    padding: usize,
) -> Conv2dGeometry {
    Conv2dGeometry {
        n: x.dim(0),
        c: x.dim(1),
        h: x.dim(2),
        w: x.dim(3),
        k: 0,
        r,
        s,
        stride,
        padding,
    }
}

/// Slice core of [`im2col_rows`]: `x` is an NCHW activation buffer
/// described by `g` (whose `k` is ignored). The network executor
/// streams its ping-pong activation arena through this entry point — no
/// `Tensor` wrapper and no allocation on the per-request path.
pub fn im2col_rows_into(x: &[f32], g: &Conv2dGeometry, px0: usize, rows: usize, dst: &mut [f32]) {
    let (n, c, h, w) = (g.n, g.c, g.h, g.w);
    let (r, s, stride, padding) = (g.r, g.s, g.stride, g.padding);
    assert_eq!(x.len(), n * c * h * w, "activation buffer does not match dims");
    let oh = (h + 2 * padding - r) / stride + 1;
    let ow = (w + 2 * padding - s) / stride + 1;
    let plane = oh * ow;
    let cols = c * r * s;
    debug_assert!(px0 + rows <= n * plane, "pixel range out of bounds");
    assert!(dst.len() >= rows * cols, "im2col_rows scratch too small");
    for row in 0..rows {
        let px = px0 + row;
        let ni = px / plane;
        let rem = px % plane;
        let oy = rem / ow;
        let ox = rem % ow;
        let base = row * cols;
        for ci in 0..c {
            for ry in 0..r {
                let iy = oy * stride + ry;
                let in_y = iy >= padding && iy - padding < h;
                for sx in 0..s {
                    let ix = ox * stride + sx;
                    let v = if in_y && ix >= padding && ix - padding < w {
                        x[((ni * c + ci) * h + (iy - padding)) * w + (ix - padding)]
                    } else {
                        0.0
                    };
                    dst[base + ci * r * s + ry * s + sx] = v;
                }
            }
        }
    }
}

/// Output pixels per SIMD lane-block in the pixel-major (transposed)
/// patch layout: 8 f32 lanes = one AVX2 vector. Shared by
/// [`im2col_rows_transposed`] and the repetition executor so block
/// boundaries — and therefore f32 accumulation order — are identical
/// everywhere, which keeps N-thread output bit-identical to 1-thread.
pub const PIXEL_BLOCK: usize = 8;

/// Pixel-major (transposed) variant of [`im2col_rows`]: the tile's
/// patch rows are written as `ceil(rows / PIXEL_BLOCK)` blocks, each an
/// `[C*R*S, PIXEL_BLOCK]` matrix with pixels minor:
///
/// ```text
/// dst[block * e*PB + col * PB + lane] = patch(px0 + block*PB + lane, col)
/// ```
///
/// so a pattern's column gather in the repetition executor is one
/// contiguous `PIXEL_BLOCK`-wide f32 load instead of a stride-`C*R*S`
/// walk. Lanes past the end of a ragged final block are zero-filled;
/// every element of the `ceil(rows/PB) * C*R*S * PB` range is written,
/// so `dst` may hold stale data from a previous tile.
#[allow(clippy::too_many_arguments)]
pub fn im2col_rows_transposed(
    x: &Tensor,
    r: usize,
    s: usize,
    stride: usize,
    padding: usize,
    px0: usize,
    rows: usize,
    dst: &mut [f32],
) {
    let g = patch_geometry(x, r, s, stride, padding);
    im2col_rows_transposed_into(x.data(), &g, px0, rows, dst);
}

/// Slice core of [`im2col_rows_transposed`] over an NCHW activation
/// buffer described by `g` (whose `k` is ignored) — the entry point the
/// repetition executor uses so multi-layer forward passes can feed it
/// arena slices directly (no per-layer `Tensor`).
pub fn im2col_rows_transposed_into(
    x: &[f32],
    g: &Conv2dGeometry,
    px0: usize,
    rows: usize,
    dst: &mut [f32],
) {
    let (n, c, h, w) = (g.n, g.c, g.h, g.w);
    assert_eq!(x.len(), n * c * h * w, "activation buffer does not match dims");
    transposed_patch_blocks(g, px0, rows, dst, |ni, ci, iy, ix| {
        x[((ni * c + ci) * h + iy) * w + ix]
    });
}

/// Like [`im2col_rows_transposed_into`], but the source activation is
/// itself stored in the **pixel-major blocked layout** a fused producer
/// scatters (`src[(ipx / PB) * C * PB + ci * PB + ipx % PB]`, where
/// `ipx = (ni * H + iy) * W + ix` indexes input pixels, lanes past the
/// final pixel zero-filled) instead of NCHW.
///
/// This is the cross-layer patch-reuse gather for consumers whose patch
/// matrix is **not** a plain re-layout of their input — `r`/`s` > 1
/// neighborhoods, `stride` > 1 subsampling and zero-padded borders are
/// all handled — so a 3x3 or strided conv can read a fused producer's
/// blocks without the activation ever being re-materialized as NCHW.
/// Every gathered value is the same f32 the NCHW path would load (the
/// producer stores identical bits in either layout), so downstream
/// accumulation is bit-identical to the unfused path.
pub fn im2col_rows_transposed_from_blocked_into(
    src: &[f32],
    g: &Conv2dGeometry,
    px0: usize,
    rows: usize,
    dst: &mut [f32],
) {
    const PB: usize = PIXEL_BLOCK;
    let (n, c, h, w) = (g.n, g.c, g.h, g.w);
    let in_pixels = n * h * w;
    assert_eq!(
        src.len(),
        in_pixels.div_ceil(PB) * c * PB,
        "blocked activation buffer does not match dims"
    );
    transposed_patch_blocks(g, px0, rows, dst, |ni, ci, iy, ix| {
        let ipx = (ni * h + iy) * w + ix;
        src[(ipx / PB) * c * PB + ci * PB + ipx % PB]
    });
}

/// Shared core of the two transposed patch extractors: walks output
/// pixels `[px0, px0 + rows)` and writes `[C*R*S, PIXEL_BLOCK]` blocks
/// into `dst`, loading in-bounds input elements through `load(ni, ci,
/// iy, ix)` (padding-adjusted coordinates) and zero-filling padded
/// positions and ragged lanes. Both callers therefore share one
/// definition of the block layout and its zero conventions.
#[inline]
fn transposed_patch_blocks(
    g: &Conv2dGeometry,
    px0: usize,
    rows: usize,
    dst: &mut [f32],
    load: impl Fn(usize, usize, usize, usize) -> f32,
) {
    const PB: usize = PIXEL_BLOCK;
    let (n, c, h, w) = (g.n, g.c, g.h, g.w);
    let (r, s, stride, padding) = (g.r, g.s, g.stride, g.padding);
    let oh = (h + 2 * padding - r) / stride + 1;
    let ow = (w + 2 * padding - s) / stride + 1;
    let plane = oh * ow;
    let cols = c * r * s;
    let blocks = rows.div_ceil(PB);
    debug_assert!(px0 + rows <= n * plane, "pixel range out of bounds");
    assert!(
        dst.len() >= blocks * cols * PB,
        "im2col_rows_transposed scratch too small"
    );
    for blk in 0..blocks {
        let base = blk * cols * PB;
        let lanes = PB.min(rows - blk * PB);
        if lanes < PB {
            // ragged final block: zero the whole block once so the
            // executor can run full-width vector ops over every block
            dst[base..base + cols * PB].fill(0.0);
        }
        for lane in 0..lanes {
            let px = px0 + blk * PB + lane;
            let ni = px / plane;
            let rem = px % plane;
            let oy = rem / ow;
            let ox = rem % ow;
            for ci in 0..c {
                for ry in 0..r {
                    let iy = oy * stride + ry;
                    let in_y = iy >= padding && iy - padding < h;
                    for sx in 0..s {
                        let ix = ox * stride + sx;
                        let v = if in_y && ix >= padding && ix - padding < w {
                            load(ni, ci, iy - padding, ix - padding)
                        } else {
                            0.0
                        };
                        dst[base + (ci * r * s + ry * s + sx) * PB + lane] = v;
                    }
                }
            }
        }
    }
}

/// im2col + GEMM convolution. Weight is flattened filter-major to
/// [C*R*S, K] so output comes out [N*OH*OW, K], then re-laid to NCHW.
/// Runs the GEMM on the process-wide pool.
pub fn conv2d_gemm(x: &Tensor, w: &Tensor, stride: usize, padding: usize) -> Tensor {
    conv2d_gemm_pool(x, w, stride, padding, Pool::global())
}

/// [`conv2d_gemm`] with an explicit pool — used by the thread-scaling
/// benchmarks so the dense baseline is timed at a controlled width.
pub fn conv2d_gemm_pool(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    padding: usize,
    pool: &Pool,
) -> Tensor {
    let (n, c, h, wd) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (k, c2, r, s) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
    assert_eq!(c, c2);
    let oh = (h + 2 * padding - r) / stride + 1;
    let ow = (wd + 2 * padding - s) / stride + 1;
    let patches = im2col(x, r, s, stride, padding);
    // transpose OIHW -> [C*R*S, K]
    let crs = c * r * s;
    let mut wt = vec![0.0f32; crs * k];
    for ki in 0..k {
        for e in 0..crs {
            wt[e * k + ki] = w.data()[ki * crs + e];
        }
    }
    let m = n * oh * ow;
    let mut mm = vec![0.0f32; m * k];
    gemm_into_pool(patches.data(), &wt, &mut mm, m, crs, k, pool);
    // [N*OH*OW, K] -> NCHW
    let mut out = Tensor::zeros(&[n, k, oh, ow]);
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((ni * oh + oy) * ow + ox) * k;
                for ki in 0..k {
                    out.set4(ni, ki, oy, ox, mm[row + ki]);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn geometry() {
        let g =
            Conv2dGeometry { n: 1, c: 16, h: 32, w: 32, k: 32, r: 3, s: 3, stride: 2, padding: 1 };
        assert_eq!(g.out_h(), 16);
        assert_eq!(g.out_w(), 16);
        assert_eq!(g.dense_macs(), (32 * 16 * 16) as u64 * (16 * 9) as u64);
    }

    #[test]
    fn gemm_conv_matches_naive() {
        let mut rng = Rng::new(5);
        for (stride, padding) in [(1, 1), (2, 1), (1, 0)] {
            let x = Tensor::rand_normal(&[2, 3, 8, 8], 1.0, &mut rng);
            let w = Tensor::rand_normal(&[4, 3, 3, 3], 1.0, &mut rng);
            let a = conv2d_naive(&x, &w, stride, padding);
            let b = conv2d_gemm(&x, &w, stride, padding);
            assert!(a.max_abs_diff(&b) < 1e-4, "stride={stride} pad={padding}");
        }
    }

    #[test]
    fn conv_1x1() {
        let mut rng = Rng::new(6);
        let x = Tensor::rand_normal(&[1, 4, 5, 5], 1.0, &mut rng);
        let w = Tensor::rand_normal(&[2, 4, 1, 1], 1.0, &mut rng);
        let a = conv2d_naive(&x, &w, 1, 0);
        let b = conv2d_gemm(&x, &w, 1, 0);
        assert_eq!(a.shape(), &[1, 2, 5, 5]);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn im2col_shape_and_padding() {
        let x = Tensor::filled(&[1, 1, 2, 2], 1.0);
        let p = im2col(&x, 3, 3, 1, 1);
        assert_eq!(p.shape(), &[4, 9]);
        // top-left output pixel: the 3x3 patch has 4 in-bounds ones
        let row0: f32 = p.data()[0..9].iter().sum();
        assert_eq!(row0, 4.0);
    }

    #[test]
    fn im2col_rows_matches_full_matrix() {
        let mut rng = Rng::new(8);
        let x = Tensor::rand_normal(&[2, 3, 7, 6], 1.0, &mut rng);
        for (r, s, stride, padding) in [(3, 3, 1, 1), (3, 3, 2, 1), (1, 1, 1, 0)] {
            let full = im2col(&x, r, s, stride, padding);
            let pixels = full.dim(0);
            let cols = full.dim(1);
            // odd tile width exercises ragged final tiles
            let tile = 5;
            let mut scratch = vec![f32::NAN; tile * cols];
            let mut px0 = 0;
            while px0 < pixels {
                let rows = tile.min(pixels - px0);
                im2col_rows(&x, r, s, stride, padding, px0, rows, &mut scratch);
                assert_eq!(
                    &scratch[..rows * cols],
                    &full.data()[px0 * cols..(px0 + rows) * cols],
                    "rows [{px0}, {}) r{r} s{s} stride{stride} pad{padding}",
                    px0 + rows
                );
                px0 += rows;
            }
        }
    }

    #[test]
    fn im2col_rows_transposed_matches_row_major() {
        const PB: usize = PIXEL_BLOCK;
        let mut rng = Rng::new(9);
        let x = Tensor::rand_normal(&[2, 3, 7, 6], 1.0, &mut rng);
        for (r, s, stride, padding) in [(3, 3, 1, 1), (3, 3, 2, 1), (1, 1, 1, 0), (2, 3, 1, 2)] {
            let full = im2col(&x, r, s, stride, padding);
            let pixels = full.dim(0);
            let cols = full.dim(1);
            // odd tile width exercises ragged blocks inside and at the end
            for tile in [5, PB, 2 * PB + 3] {
                let blocks = tile.div_ceil(PB);
                let mut scratch = vec![f32::NAN; blocks * cols * PB];
                let mut px0 = 0;
                while px0 < pixels {
                    let rows = tile.min(pixels - px0);
                    im2col_rows_transposed(&x, r, s, stride, padding, px0, rows, &mut scratch);
                    for row in 0..rows {
                        let (blk, lane) = (row / PB, row % PB);
                        for col in 0..cols {
                            let got = scratch[blk * cols * PB + col * PB + lane];
                            let want = full.data()[(px0 + row) * cols + col];
                            assert_eq!(
                                got, want,
                                "px {} col {col} r{r} s{s} stride{stride} pad{padding}",
                                px0 + row
                            );
                        }
                    }
                    // ragged lanes are zero-filled, never stale
                    let last_rows = rows % PB;
                    if last_rows != 0 {
                        let blk = rows / PB;
                        for lane in last_rows..PB {
                            for col in 0..cols {
                                assert_eq!(scratch[blk * cols * PB + col * PB + lane], 0.0);
                            }
                        }
                    }
                    px0 += rows;
                }
            }
        }
    }

    #[test]
    fn blocked_gather_matches_nchw_transposed_extraction() {
        // re-lay x pixel-major (the fused producer's layout), then check
        // the blocked gather reproduces the NCHW transposed im2col for
        // every supported consumer geometry, including ragged tiles
        const PB: usize = PIXEL_BLOCK;
        let mut rng = Rng::new(10);
        let x = Tensor::rand_normal(&[2, 3, 7, 5], 1.0, &mut rng);
        let (n, c, h, w) = (2, 3, 7, 5);
        let pixels = n * h * w;
        let unit = Conv2dGeometry { n, c, h, w, k: 0, r: 1, s: 1, stride: 1, padding: 0 };
        let mut blocked = vec![f32::NAN; pixels.div_ceil(PB) * c * PB];
        im2col_rows_transposed_into(x.data(), &unit, 0, pixels, &mut blocked);
        for (r, s, stride, padding) in [(3, 3, 1, 1), (3, 3, 2, 1), (1, 1, 2, 0), (3, 3, 1, 0)] {
            let g = Conv2dGeometry { n, c, h, w, k: 0, r, s, stride, padding };
            let cols = c * r * s;
            let out_pixels = n * g.out_h() * g.out_w();
            for tile in [5, PB, 2 * PB + 3] {
                let blocks = tile.div_ceil(PB);
                let mut want = vec![f32::NAN; blocks * cols * PB];
                let mut got = vec![f32::NAN; blocks * cols * PB];
                let mut px0 = 0;
                while px0 < out_pixels {
                    let rows = tile.min(out_pixels - px0);
                    im2col_rows_transposed_into(x.data(), &g, px0, rows, &mut want);
                    im2col_rows_transposed_from_blocked_into(&blocked, &g, px0, rows, &mut got);
                    let n_blk = rows.div_ceil(PB) * cols * PB;
                    assert_eq!(
                        &got[..n_blk],
                        &want[..n_blk],
                        "px0 {px0} r{r} s{s} stride{stride} pad{padding} tile{tile}"
                    );
                    px0 += rows;
                }
            }
        }
    }

    #[test]
    fn identity_kernel_passthrough() {
        // 1x1 identity conv reproduces input channel
        let mut rng = Rng::new(7);
        let x = Tensor::rand_normal(&[1, 1, 6, 6], 1.0, &mut rng);
        let w = Tensor::new(&[1, 1, 1, 1], vec![1.0]);
        let y = conv2d_gemm(&x, &w, 1, 0);
        assert!(y.max_abs_diff(&x) < 1e-6);
    }
}
