//! Dense tensor substrate (S1).
//!
//! A deliberately small f32 NCHW tensor library: exactly what the
//! inference engines and the simulator need — shapes, elementwise ops,
//! GEMM, im2col convolution — with no external dependencies. The naive
//! dense conv here is the "repetition/sparsity-unaware" baseline that the
//! paper's arithmetic-reduction metric divides by (supp. G).
//!
//! Parallel layout: `gemm_into` blocks the row dimension over the shared
//! persistent worker pool (`util::pool`), and `im2col` exists in three
//! forms — the full `[N*OH*OW, C*R*S]` matrix for the dense baseline,
//! `im2col_rows`, which fills just a pixel tile's rows into caller-owned
//! scratch, and `im2col_rows_transposed`, the pixel-major layout the
//! repetition executor streams (`[C*R*S, PIXEL_BLOCK]` blocks, so a
//! column gather is one contiguous SIMD-width load). The tiled executor
//! fuses patch extraction per tile, so its peak memory is one tile of
//! patches per worker thread instead of the whole matrix. Every parallel
//! entry point partitions work identically for any thread count, keeping
//! results bit-identical to the serial path.

mod conv;
mod ops;

pub use conv::{
    conv2d_gemm, conv2d_gemm_pool, conv2d_naive, im2col, im2col_rows, im2col_rows_into,
    im2col_rows_transposed, im2col_rows_transposed_from_blocked_into, im2col_rows_transposed_into,
    Conv2dGeometry, PIXEL_BLOCK,
};
pub use ops::{gemm, gemm_into, gemm_into_pool};

/// Row-major dense f32 tensor with an explicit shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Wrap `data` with an explicit shape (element counts must match).
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match {} elements",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Tensor of the given shape with every element set to `v`.
    pub fn filled(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// Build from a function of the flat (row-major) element index.
    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(|i| f(i)).collect() }
    }

    /// Gaussian-initialized tensor (mean 0, the given std).
    pub fn rand_normal(shape: &[usize], std: f32, rng: &mut crate::util::Rng) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    /// The tensor's shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row-major element slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major element slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume the tensor, returning its element buffer.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reshape without copying; total element count must match.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Size of dimension `i`.
    pub fn dim(&self, i: usize) -> usize {
        self.shape[i]
    }

    /// Index into a rank-4 tensor (NCHW / OIHW).
    #[inline]
    pub fn at4(&self, a: usize, b: usize, c: usize, d: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 4);
        let (s1, s2, s3) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((a * s1 + b) * s2 + c) * s3 + d]
    }

    /// Write one element of a rank-4 tensor (NCHW / OIHW).
    #[inline]
    pub fn set4(&mut self, a: usize, b: usize, c: usize, d: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 4);
        let (s1, s2, s3) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((a * s1 + b) * s2 + c) * s3 + d] = v;
    }

    /// Max |a - b| over all elements (for tests).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Number of non-zero elements (effectual weights).
    pub fn count_nonzero(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dim(1), 3);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn at4_row_major() {
        let t = Tensor::from_fn(&[2, 3, 4, 5], |i| i as f32);
        assert_eq!(t.at4(0, 0, 0, 0), 0.0);
        assert_eq!(t.at4(1, 2, 3, 4), (1 * 3 * 4 * 5 + 2 * 4 * 5 + 3 * 5 + 4) as f32);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_fn(&[4, 3], |i| i as f32).reshape(&[2, 6]);
        assert_eq!(t.shape(), &[2, 6]);
        assert_eq!(t.data()[7], 7.0);
    }

    #[test]
    fn nonzero_count() {
        let t = Tensor::new(&[4], vec![0.0, 1.0, 0.0, -2.0]);
        assert_eq!(t.count_nonzero(), 2);
    }
}
