//! One-bit storage for signed-binary weights.
//!
//! The paper (§6) notes signed-binary needs `R*S*C*K + K` bits: a {0,1}
//! bitmap per weight plus one sign bit per filter (region), versus
//! ternary's two bits per weight. This module implements that packing and
//! is used by the serving coordinator's model registry to report model
//! footprints, and by tests to prove the bit-count claim.

use super::QuantizedWeights;

/// Bitmap word width (u64).
pub const BITS_PER_WORD: usize = 64;

/// Bit-packed signed-binary weight tensor.
#[derive(Debug, Clone)]
pub struct PackedSignedBinary {
    /// {0,1} effectuality bitmap, row-major over [regions, elems].
    pub bitmap: Vec<u64>,
    /// One sign bit per region (true = {0,+a}).
    pub sign_pos: Vec<bool>,
    /// Per-region scale magnitude.
    pub alpha: Vec<f32>,
    /// Number of regions (K * regions_per_filter).
    pub regions: usize,
    /// Weight elements per region.
    pub elems_per_region: usize,
}

impl PackedSignedBinary {
    /// Pack a signed-binary quantization into the bitmap form.
    pub fn pack(q: &QuantizedWeights) -> Self {
        let regions = q.beta.len();
        assert!(regions > 0, "pack() requires a signed-binary quantization");
        let total = q.values.len();
        assert_eq!(total % regions, 0);
        let elems = total / regions;
        let words_per_region = elems.div_ceil(BITS_PER_WORD);
        let mut bitmap = vec![0u64; regions * words_per_region];
        for fi in 0..regions {
            let row = &q.values.data()[fi * elems..(fi + 1) * elems];
            for (ei, v) in row.iter().enumerate() {
                if *v != 0.0 {
                    bitmap[fi * words_per_region + ei / BITS_PER_WORD] |=
                        1u64 << (ei % BITS_PER_WORD);
                }
            }
        }
        PackedSignedBinary {
            bitmap,
            sign_pos: q.beta.iter().map(|b| *b >= 0.0).collect(),
            alpha: q.alpha.clone(),
            regions,
            elems_per_region: elems,
        }
    }

    #[inline]
    fn words_per_region(&self) -> usize {
        self.elems_per_region.div_ceil(BITS_PER_WORD)
    }

    /// Value of weight (region, elem).
    pub fn get(&self, region: usize, elem: usize) -> f32 {
        let w = self.bitmap[region * self.words_per_region() + elem / BITS_PER_WORD];
        if (w >> (elem % BITS_PER_WORD)) & 1 == 1 {
            if self.sign_pos[region] {
                self.alpha[region]
            } else {
                -self.alpha[region]
            }
        } else {
            0.0
        }
    }

    /// Unpack to a dense value vector (row-major [regions, elems]).
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.regions * self.elems_per_region];
        for r in 0..self.regions {
            for e in 0..self.elems_per_region {
                out[r * self.elems_per_region + e] = self.get(r, e);
            }
        }
        out
    }

    /// Storage cost in bits, excluding alphas (which binary also carries):
    /// the paper's R*S*C*K + K accounting.
    pub fn weight_bits(&self) -> usize {
        self.regions * self.elems_per_region + self.regions
    }

    /// Effectual (non-zero) weight count via popcount.
    pub fn effectual(&self) -> usize {
        self.bitmap.iter().map(|w| w.count_ones() as usize).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{default_beta, quantize_signed_binary};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn packed_fixture() -> (QuantizedWeights, PackedSignedBinary) {
        let mut rng = Rng::new(8);
        let w = Tensor::rand_normal(&[6, 10, 3, 3], 1.0, &mut rng);
        let q = quantize_signed_binary(&w, &default_beta(6, 0.5), 0.05, 1);
        let p = PackedSignedBinary::pack(&q);
        (q, p)
    }

    #[test]
    fn roundtrip_exact() {
        let (q, p) = packed_fixture();
        assert_eq!(p.unpack(), q.values.data());
    }

    #[test]
    fn effectual_matches_dense() {
        let (q, p) = packed_fixture();
        assert_eq!(p.effectual(), q.effectual());
    }

    #[test]
    fn bit_accounting_paper_formula() {
        // K=6 filters, C=10, R=S=3: R*S*C*K + K bits.
        let (_, p) = packed_fixture();
        assert_eq!(p.weight_bits(), 3 * 3 * 10 * 6 + 6);
    }

    #[test]
    fn get_respects_region_sign() {
        let (_, p) = packed_fixture();
        for r in 0..p.regions {
            for e in 0..p.elems_per_region {
                let v = p.get(r, e);
                if p.sign_pos[r] {
                    assert!(v >= 0.0);
                } else {
                    assert!(v <= 0.0);
                }
            }
        }
    }

    #[test]
    fn non_word_aligned_elems() {
        // elems per region = 70, not a multiple of 64
        let mut rng = Rng::new(9);
        let w = Tensor::rand_normal(&[3, 70, 1, 1], 1.0, &mut rng);
        let q = quantize_signed_binary(&w, &default_beta(3, 0.5), 0.05, 1);
        let p = PackedSignedBinary::pack(&q);
        assert_eq!(p.unpack(), q.values.data());
    }
}
