//! Quantizer suite (S2): binary / ternary / signed-binary, plus the
//! repetition & sparsity statistics the paper's analysis sections use.
//!
//! Semantics mirror `python/compile/kernels/ref.py` exactly (the golden
//! fixture test in `rust/tests/` asserts bit-equality), so a latent-weight
//! checkpoint trained through the AOT path quantizes identically here.

mod pack;
pub mod stats;

pub use pack::{PackedSignedBinary, BITS_PER_WORD};
pub use stats::{filter_repetition_stats, weight_histogram, RepetitionStats};

use crate::tensor::Tensor;

/// Weight quantization scheme (paper Figure 3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scheme {
    /// Full precision (no quantization; passes latents through).
    Fp,
    /// Binary (BWN): `sign(w) * mean|w|` per filter.
    Binary,
    /// Ternary with Delta = delta_frac * max|W| per filter.
    Ternary { delta_frac: f32 },
    /// PLUM signed-binary: per-region {0,+a} or {0,-a} value sets.
    SignedBinary { delta_frac: f32, regions_per_filter: usize },
}

impl Scheme {
    /// The paper's default signed-binary configuration (Delta = 0.05,
    /// one region per filter).
    pub fn sb_default() -> Scheme {
        Scheme::SignedBinary { delta_frac: 0.05, regions_per_filter: 1 }
    }

    /// The paper's default ternary configuration (Delta = 0.05).
    pub fn ternary_default() -> Scheme {
        Scheme::Ternary { delta_frac: 0.05 }
    }

    /// Short scheme name for reports ("fp", "binary", ...).
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Fp => "fp",
            Scheme::Binary => "binary",
            Scheme::Ternary { .. } => "ternary",
            Scheme::SignedBinary { .. } => "signed-binary",
        }
    }

    /// Unique weight values per filter (drives repetition; Figure 3's
    /// 2^9 vs 3^9 unique-filter argument).
    pub fn values_per_filter(&self) -> usize {
        match self {
            Scheme::Fp => usize::MAX,
            Scheme::Binary => 2,
            Scheme::Ternary { .. } => 3,
            Scheme::SignedBinary { .. } => 2, // {0, +a} or {0, -a}
        }
    }
}

/// Output of quantizing one conv weight tensor [K, C, R, S].
#[derive(Debug, Clone)]
pub struct QuantizedWeights {
    /// Dense quantized values (same shape as input).
    pub values: Tensor,
    /// Per-region scale magnitude alpha (len = K * G; 1 entry for binary/ternary per filter).
    pub alpha: Vec<f32>,
    /// Per-region sign factor beta (+1/-1); all +1 for binary/ternary.
    pub beta: Vec<f32>,
    /// The scheme that produced these values.
    pub scheme: Scheme,
}

impl QuantizedWeights {
    /// Fraction of non-zero (effectual) weights.
    pub fn density(&self) -> f64 {
        self.values.count_nonzero() as f64 / self.values.len() as f64
    }

    /// Fraction of zero (ineffectual) weights.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.density()
    }

    /// Count of non-zero weights.
    pub fn effectual(&self) -> usize {
        self.values.count_nonzero()
    }
}

fn per_filter_view(w: &Tensor, g: usize) -> (usize, usize) {
    // returns (regions, elems_per_region) over flattened [K*G, C/G*R*S]
    let (k, c, r, s) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
    assert!(c % g == 0, "C={c} not divisible by G={g}");
    (k * g, (c / g) * r * s)
}

/// Binary (BWN): sign(w) * mean|w| per filter; sign(0) := +1.
pub fn quantize_binary(w: &Tensor) -> QuantizedWeights {
    let (regions, elems) = per_filter_view(w, 1);
    let mut values = w.clone();
    let mut alpha = vec![0.0f32; regions];
    for fi in 0..regions {
        let row = &w.data()[fi * elems..(fi + 1) * elems];
        let a = row.iter().map(|v| v.abs()).sum::<f32>() / elems as f32;
        alpha[fi] = a;
        for (o, v) in values.data_mut()[fi * elems..(fi + 1) * elems]
            .iter_mut()
            .zip(row)
        {
            *o = if *v >= 0.0 { a } else { -a };
        }
    }
    QuantizedWeights { values, alpha, beta: vec![1.0; regions], scheme: Scheme::Binary }
}

/// Ternary (TWN with the paper's Delta rule).
pub fn quantize_ternary(w: &Tensor, delta_frac: f32) -> QuantizedWeights {
    let (regions, elems) = per_filter_view(w, 1);
    let mut values = w.clone();
    let mut alpha = vec![0.0f32; regions];
    for fi in 0..regions {
        let row = &w.data()[fi * elems..(fi + 1) * elems];
        let maxabs = row.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        let delta = delta_frac * maxabs;
        let mut sum = 0.0f32;
        let mut cnt = 0usize;
        for v in row {
            if v.abs() > delta {
                sum += v.abs();
                cnt += 1;
            }
        }
        let a = sum / (cnt.max(1) as f32);
        alpha[fi] = a;
        for (o, v) in values.data_mut()[fi * elems..(fi + 1) * elems]
            .iter_mut()
            .zip(row)
        {
            *o = if *v > delta {
                a
            } else if *v < -delta {
                -a
            } else {
                0.0
            };
        }
    }
    QuantizedWeights {
        values,
        alpha,
        beta: vec![1.0; regions],
        scheme: Scheme::Ternary { delta_frac },
    }
}

/// PLUM signed-binary (paper eq. 3): per-region one of {0,+a} / {0,-a}.
pub fn quantize_signed_binary(
    w: &Tensor,
    beta: &[f32],
    delta_frac: f32,
    regions_per_filter: usize,
) -> QuantizedWeights {
    let (regions, elems) = per_filter_view(w, regions_per_filter);
    assert_eq!(beta.len(), regions, "beta len vs regions");
    let mut values = w.clone();
    let mut alpha = vec![0.0f32; regions];
    for fi in 0..regions {
        let row = &w.data()[fi * elems..(fi + 1) * elems];
        let b = beta[fi];
        let maxabs = row.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        let delta = delta_frac * maxabs;
        let mut sum = 0.0f32;
        let mut cnt = 0usize;
        for v in row {
            let eff = (b >= 0.0 && *v >= delta) || (b < 0.0 && *v <= -delta);
            if eff {
                sum += v.abs();
                cnt += 1;
            }
        }
        let a = sum / (cnt.max(1) as f32);
        alpha[fi] = a;
        for (o, v) in values.data_mut()[fi * elems..(fi + 1) * elems]
            .iter_mut()
            .zip(row)
        {
            *o = if b >= 0.0 && *v >= delta {
                a
            } else if b < 0.0 && *v <= -delta {
                -a
            } else {
                0.0
            };
        }
    }
    QuantizedWeights {
        values,
        alpha,
        beta: beta.to_vec(),
        scheme: Scheme::SignedBinary { delta_frac, regions_per_filter },
    }
}

/// Structured-sparsity mask mode applied to latent weights before
/// quantization — the density knob of the repetition-sparsity trade-off
/// curve. Masked latents are forced to zero *before* the alpha/beta fit
/// (so they are excluded from every effectual-magnitude mean) and
/// always quantize to exactly 0.
///
/// Group layout follows PLINIO's KHWC convention: for each filter `k`
/// and spatial tap `(r, s)`, mask groups run along the input-channel
/// axis `C`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SparsityPattern {
    /// No mask: density is whatever the scheme produces on its own.
    #[default]
    Unstructured,
    /// At most `n` non-zero latents per group of `m` consecutive input
    /// channels (N:M pruning): the `m - n` smallest-magnitude latents of
    /// each group are masked, ties broken toward keeping the lower
    /// channel index.
    NM {
        /// kept (non-zero) latents per group
        n: usize,
        /// group size along the input-channel axis
        m: usize,
    },
    /// Block-wise pruning (Intel neural-compressor style): input
    /// channels are split into blocks of `s`; within each adjacent pair
    /// of blocks, the block with the smaller L1 magnitude is masked
    /// whole (ties mask the later block).
    Block {
        /// block length along the input-channel axis
        s: usize,
    },
}

impl SparsityPattern {
    /// Short label for bench shapes ("unstructured", "nm1:4", "block4").
    pub fn label(&self) -> String {
        match self {
            SparsityPattern::Unstructured => "unstructured".to_string(),
            SparsityPattern::NM { n, m } => format!("nm{n}:{m}"),
            SparsityPattern::Block { s } => format!("block{s}"),
        }
    }
}

/// Keep-mask for `w` (latents, `[K, C, R, S]`) under `pattern`: `true`
/// entries survive, `false` entries are pruned. Selection is
/// deterministic — magnitudes compare by `f32` total order and ties
/// keep the lower channel index — so the mask is a pure function of the
/// latents (byte-identical across runs and thread counts).
pub fn sparsity_mask(w: &Tensor, pattern: SparsityPattern) -> Vec<bool> {
    let (k, c, r, s) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
    let taps = r * s;
    let d = w.data();
    let mut keep = vec![true; d.len()];
    // KHWC grouping: fixed (k, r, s), the group axis is C — element
    // (k, c, r, s) lives at ((k * C + c) * R + r) * S + s in KCRS.
    let idx = |ki: usize, ci: usize, t: usize| (ki * c + ci) * taps + t;
    match pattern {
        SparsityPattern::Unstructured => {}
        SparsityPattern::NM { n, m } => {
            assert!(m > 0 && n <= m, "N:M needs 0 < M and N <= M, got {n}:{m}");
            for ki in 0..k {
                for t in 0..taps {
                    let mut c0 = 0;
                    while c0 < c {
                        let g = m.min(c - c0);
                        // rank the group's channels: larger |latent|
                        // first, lower channel index on ties
                        let mut order: Vec<usize> = (c0..c0 + g).collect();
                        order.sort_by(|a, b| {
                            let (va, vb) = (d[idx(ki, *a, t)].abs(), d[idx(ki, *b, t)].abs());
                            vb.total_cmp(&va).then(a.cmp(b))
                        });
                        for &ci in &order[n.min(g)..] {
                            keep[idx(ki, ci, t)] = false;
                        }
                        c0 += g;
                    }
                }
            }
        }
        SparsityPattern::Block { s: bs } => {
            assert!(bs > 0, "block size must be positive");
            for ki in 0..k {
                for t in 0..taps {
                    let mut b0 = 0;
                    // walk complete block pairs; a ragged / unpaired
                    // tail survives unmasked
                    while b0 + 2 * bs <= c {
                        let l1 = |start: usize| -> f32 {
                            (start..start + bs).map(|ci| d[idx(ki, ci, t)].abs()).sum()
                        };
                        let (sa, sb) = (l1(b0), l1(b0 + bs));
                        let victim = if sa < sb { b0 } else { b0 + bs };
                        for ci in victim..victim + bs {
                            keep[idx(ki, ci, t)] = false;
                        }
                        b0 += 2 * bs;
                    }
                }
            }
        }
    }
    keep
}

/// Quantize `w` under `scheme` with a structured-sparsity mask applied
/// first: masked latents are zeroed before the fit (a zeroed latent
/// falls below every positive Delta, so it is excluded from the
/// effectual mean) and forced to exactly 0 in the output — the
/// unconditional re-mask covers the `delta == 0` edge. `Fp` and
/// `Binary` cannot represent a zero weight, so they only accept
/// [`SparsityPattern::Unstructured`].
pub fn quantize_pruned(
    w: &Tensor,
    scheme: Scheme,
    beta: Option<&[f32]>,
    pattern: SparsityPattern,
) -> QuantizedWeights {
    if pattern == SparsityPattern::Unstructured {
        return quantize(w, scheme, beta);
    }
    assert!(
        !matches!(scheme, Scheme::Fp | Scheme::Binary),
        "{} cannot represent pruned (zero) weights — use ternary or signed-binary",
        scheme.name()
    );
    let keep = sparsity_mask(w, pattern);
    let mut masked = w.clone();
    for (v, kp) in masked.data_mut().iter_mut().zip(&keep) {
        if !*kp {
            *v = 0.0;
        }
    }
    let mut q = quantize(&masked, scheme, beta);
    for (v, kp) in q.values.data_mut().iter_mut().zip(&keep) {
        if !*kp {
            *v = 0.0;
        }
    }
    q
}

/// Deterministic region sign assignment: first p_pos fraction +1 —
/// matches `ref.default_beta` on the python side.
pub fn default_beta(num_regions: usize, p_pos: f64) -> Vec<f32> {
    let n_pos = (num_regions as f64 * p_pos).round() as usize;
    (0..num_regions)
        .map(|i| if i < n_pos { 1.0 } else { -1.0 })
        .collect()
}

/// Quantize with any scheme (fp passes through).
pub fn quantize(w: &Tensor, scheme: Scheme, beta: Option<&[f32]>) -> QuantizedWeights {
    match scheme {
        Scheme::Fp => QuantizedWeights {
            values: w.clone(),
            alpha: vec![],
            beta: vec![],
            scheme,
        },
        Scheme::Binary => quantize_binary(w),
        Scheme::Ternary { delta_frac } => quantize_ternary(w, delta_frac),
        Scheme::SignedBinary { delta_frac, regions_per_filter } => {
            let regions = w.dim(0) * regions_per_filter;
            let owned;
            let b = match beta {
                Some(b) => b,
                None => {
                    owned = default_beta(regions, 0.5);
                    &owned
                }
            };
            quantize_signed_binary(w, b, delta_frac, regions_per_filter)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn w_fixture(seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::rand_normal(&[4, 8, 3, 3], 0.5, &mut rng)
    }

    #[test]
    fn binary_is_dense_two_valued() {
        let q = quantize_binary(&w_fixture(1));
        assert_eq!(q.effectual(), q.values.len());
        for fi in 0..4 {
            let row = &q.values.data()[fi * 72..(fi + 1) * 72];
            let mut uniq: Vec<i32> = row.iter().map(|v| (v * 1e6) as i32).collect();
            uniq.sort();
            uniq.dedup();
            assert!(uniq.len() <= 2, "filter {fi} has {} uniques", uniq.len());
        }
    }

    #[test]
    fn ternary_three_valued_sparse() {
        let q = quantize_ternary(&w_fixture(2), 0.5); // large delta -> sparse
        assert!(q.sparsity() > 0.2, "sparsity {}", q.sparsity());
        for v in q.values.data() {
            assert!(*v == 0.0 || v.abs() > 0.0);
        }
    }

    #[test]
    fn sb_regions_single_signed_value() {
        let w = w_fixture(3);
        let beta = default_beta(4, 0.5);
        let q = quantize_signed_binary(&w, &beta, 0.05, 1);
        for fi in 0..4 {
            let row = &q.values.data()[fi * 72..(fi + 1) * 72];
            let has_pos = row.iter().any(|v| *v > 0.0);
            let has_neg = row.iter().any(|v| *v < 0.0);
            assert!(
                !(has_pos && has_neg),
                "filter {fi} mixes signs — violates signed-binary"
            );
            if beta[fi] >= 0.0 {
                assert!(!has_neg);
            } else {
                assert!(!has_pos);
            }
        }
    }

    #[test]
    fn sb_sparsity_near_half_for_gaussian() {
        // with delta small and beta masking one sign, ~half the weights
        // become ineffectual (paper: 50-65% sparsity).
        let mut rng = Rng::new(4);
        let w = Tensor::rand_normal(&[16, 16, 3, 3], 1.0, &mut rng);
        let q = quantize(&w, Scheme::sb_default(), None);
        assert!(
            q.sparsity() > 0.4 && q.sparsity() < 0.65,
            "sparsity {}",
            q.sparsity()
        );
    }

    #[test]
    fn sb_intra_filter_regions() {
        let w = w_fixture(5);
        let beta = default_beta(8, 0.5); // G=2 -> 8 regions
        let q = quantize_signed_binary(&w, &beta, 0.05, 2);
        assert_eq!(q.alpha.len(), 8);
        assert_eq!(q.values.shape(), w.shape());
    }

    #[test]
    fn default_beta_prefix() {
        let b = default_beta(8, 0.25);
        assert_eq!(b.iter().filter(|v| **v > 0.0).count(), 2);
    }

    #[test]
    fn nm_mask_keeps_at_most_n_per_group() {
        let w = w_fixture(7); // [4, 8, 3, 3]
        for (n, m) in [(1usize, 4usize), (2, 4), (2, 8), (3, 5)] {
            let q = quantize_pruned(&w, Scheme::sb_default(), None, SparsityPattern::NM { n, m });
            let (c, taps) = (8usize, 9usize);
            for ki in 0..4 {
                for t in 0..taps {
                    let mut c0 = 0;
                    while c0 < c {
                        let g = m.min(c - c0);
                        let nnz = (c0..c0 + g)
                            .filter(|ci| q.values.data()[(ki * c + ci) * taps + t] != 0.0)
                            .count();
                        assert!(nnz <= n, "{n}:{m} group (k{ki} t{t} c{c0}) has {nnz} nonzero");
                        c0 += g;
                    }
                }
            }
        }
    }

    #[test]
    fn nm_ties_break_to_lower_channel() {
        // every latent identical: the deterministic tie-break must keep
        // exactly the first n channels of each group
        let w = Tensor::filled(&[1, 8, 1, 1], 0.5);
        let keep = sparsity_mask(&w, SparsityPattern::NM { n: 1, m: 4 });
        assert_eq!(keep, [true, false, false, false, true, false, false, false]);
        let keep2 = sparsity_mask(&w, SparsityPattern::NM { n: 2, m: 4 });
        assert_eq!(keep2, [true, true, false, false, true, true, false, false]);
    }

    #[test]
    fn masked_latents_are_excluded_from_the_alpha_fit() {
        // beta = +1, latents [0.9, 0.5, 0.4, 0.3]: 2:4 masks the two
        // smallest, so alpha must be mean(0.9, 0.5), not the mean over
        // all four effectual latents
        let mut w = Tensor::filled(&[1, 4, 1, 1], 0.0);
        w.data_mut().copy_from_slice(&[0.9, 0.5, 0.4, 0.3]);
        let scheme = Scheme::SignedBinary { delta_frac: 0.05, regions_per_filter: 1 };
        let q = quantize_pruned(&w, scheme, Some(&[1.0]), SparsityPattern::NM { n: 2, m: 4 });
        assert!((q.alpha[0] - 0.7).abs() < 1e-6, "alpha {} includes masked latents", q.alpha[0]);
        assert_eq!(q.values.data()[2], 0.0);
        assert_eq!(q.values.data()[3], 0.0);
    }

    #[test]
    fn block_mask_prunes_the_smaller_block_of_each_pair() {
        let mut w = Tensor::filled(&[1, 4, 1, 1], 0.0);
        w.data_mut().copy_from_slice(&[0.1, 0.1, 0.9, 0.9]);
        let keep = sparsity_mask(&w, SparsityPattern::Block { s: 2 });
        assert_eq!(keep, [false, false, true, true]);
        // tie: the later block is pruned, keeping lower channels
        let tied = Tensor::filled(&[1, 4, 1, 1], 0.5);
        let tied_keep = sparsity_mask(&tied, SparsityPattern::Block { s: 2 });
        assert_eq!(tied_keep, [true, true, false, false]);
    }

    #[test]
    fn pattern_labels_and_default() {
        assert_eq!(SparsityPattern::default(), SparsityPattern::Unstructured);
        assert_eq!(SparsityPattern::NM { n: 2, m: 4 }.label(), "nm2:4");
        assert_eq!(SparsityPattern::Block { s: 4 }.label(), "block4");
    }

    #[test]
    fn p_pos_extremes() {
        let w = w_fixture(6);
        let q0 = quantize_signed_binary(&w, &default_beta(4, 0.0), 0.05, 1);
        assert!(q0.values.data().iter().all(|v| *v <= 0.0));
        let q1 = quantize_signed_binary(&w, &default_beta(4, 1.0), 0.05, 1);
        assert!(q1.values.data().iter().all(|v| *v >= 0.0));
    }
}
