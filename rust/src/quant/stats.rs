//! Repetition & distribution statistics (paper §2, §4.3, Figures 3/6/11).
//!
//! * `filter_repetition_stats` — unique values per filter, unique filters
//!   per layer (BNN's "42% of filters are unique" observation), density.
//! * `weight_histogram` — latent-weight distributions for the Figure 6b /
//!   Figure 11 reproduction (`plum report weights`), including the
//!   Laplace-resemblance diagnostic used in §4.3.

use crate::tensor::Tensor;
use std::collections::HashSet;

/// Per-layer repetition/sparsity statistics (paper §2 / Figure 3).
#[derive(Debug, Clone)]
pub struct RepetitionStats {
    /// Filters (K) in the layer.
    pub filters: usize,
    /// Weight elements per filter (C*R*S / regions).
    pub elems_per_filter: usize,
    /// Mean count of distinct values within a filter.
    pub mean_unique_values: f64,
    /// Fraction of structurally distinct filters in the layer.
    pub unique_filter_fraction: f64,
    /// Fraction of non-zero weights.
    pub density: f64,
}

fn quantize_key(v: f32) -> i64 {
    // stable key for float comparison of quantized values
    (v as f64 * 1e7).round() as i64
}

/// Stats over quantized weights [K, C, R, S] (flattened per filter).
pub fn filter_repetition_stats(values: &Tensor, filters: usize) -> RepetitionStats {
    assert!(filters > 0 && values.len() % filters == 0);
    let elems = values.len() / filters;
    let mut uniq_counts = 0usize;
    let mut filter_sigs: HashSet<Vec<i64>> = HashSet::new();
    let mut nonzero = 0usize;
    for fi in 0..filters {
        let row = &values.data()[fi * elems..(fi + 1) * elems];
        let sig: Vec<i64> = row.iter().map(|v| quantize_key(*v)).collect();
        let mut vals: Vec<i64> = sig.clone();
        vals.sort_unstable();
        vals.dedup();
        uniq_counts += vals.len();
        nonzero += row.iter().filter(|v| **v != 0.0).count();
        filter_sigs.insert(sig);
    }
    RepetitionStats {
        filters,
        elems_per_filter: elems,
        mean_unique_values: uniq_counts as f64 / filters as f64,
        unique_filter_fraction: filter_sigs.len() as f64 / filters as f64,
        density: nonzero as f64 / values.len() as f64,
    }
}

/// Histogram of weight values over [lo, hi] with `bins` buckets, plus the
/// summary moments used to eyeball Laplace-ness (Figure 6b): for a
/// Laplace distribution kurtosis ≈ 6, for a Gaussian ≈ 3.
#[derive(Debug, Clone)]
pub struct WeightHistogram {
    /// Lower bound of the histogram range.
    pub lo: f32,
    /// Upper bound of the histogram range.
    pub hi: f32,
    /// Per-bucket sample counts (out-of-range values clamp to the ends).
    pub counts: Vec<u64>,
    /// Sample mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
    /// Excess kurtosis (Laplace ~3, Gaussian ~0).
    pub excess_kurtosis: f64,
    /// Total samples.
    pub total: usize,
}

/// Histogram `values` over `[lo, hi]` with `bins` buckets and compute
/// the moment summary ([`WeightHistogram`]).
pub fn weight_histogram(values: &[f32], lo: f32, hi: f32, bins: usize) -> WeightHistogram {
    assert!(bins > 0 && hi > lo);
    let mut counts = vec![0u64; bins];
    let scale = bins as f32 / (hi - lo);
    let (mut s1, mut s2, mut s3, mut s4) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for v in values {
        let b = (((v - lo) * scale) as isize).clamp(0, bins as isize - 1) as usize;
        counts[b] += 1;
        let x = *v as f64;
        s1 += x;
        s2 += x * x;
        s3 += x * x * x;
        s4 += x * x * x * x;
    }
    let n = values.len().max(1) as f64;
    let mean = s1 / n;
    let var = (s2 / n - mean * mean).max(1e-12);
    let m4 = s4 / n - 4.0 * mean * s3 / n + 6.0 * mean * mean * s2 / n
        - 3.0 * mean.powi(4);
    WeightHistogram {
        lo,
        hi,
        counts,
        mean,
        std: var.sqrt(),
        excess_kurtosis: m4 / (var * var) - 3.0,
        total: values.len(),
    }
}

/// Render a histogram as ASCII rows (for `plum report weights`).
pub fn render_histogram(h: &WeightHistogram, width: usize) -> String {
    let max = h.counts.iter().copied().max().unwrap_or(1).max(1);
    let mut out = String::new();
    for (i, c) in h.counts.iter().enumerate() {
        let x0 = h.lo + (h.hi - h.lo) * i as f32 / h.counts.len() as f32;
        let bar = "#".repeat((*c as usize * width / max as usize).min(width));
        out.push_str(&format!("{x0:>7.3} | {bar} {c}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{default_beta, quantize_binary, quantize_signed_binary, quantize_ternary};
    use crate::util::Rng;

    fn w(seed: u64, k: usize) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::rand_normal(&[k, 4, 3, 3], 1.0, &mut rng)
    }

    #[test]
    fn binary_filters_have_two_values() {
        let q = quantize_binary(&w(1, 8));
        let st = filter_repetition_stats(&q.values, 8);
        assert!(st.mean_unique_values <= 2.0 + 1e-9);
        assert!((st.density - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ternary_filters_have_up_to_three_values() {
        let q = quantize_ternary(&w(2, 8), 0.05);
        let st = filter_repetition_stats(&q.values, 8);
        assert!(st.mean_unique_values <= 3.0 + 1e-9);
        assert!(st.density < 1.0);
    }

    #[test]
    fn sb_filters_have_two_values_and_sparsity() {
        let q = quantize_signed_binary(&w(3, 8), &default_beta(8, 0.5), 0.05, 1);
        let st = filter_repetition_stats(&q.values, 8);
        assert!(st.mean_unique_values <= 2.0 + 1e-9, "{}", st.mean_unique_values);
        assert!(st.density < 0.7, "density {}", st.density);
    }

    #[test]
    fn histogram_mass_conserved() {
        let mut rng = Rng::new(4);
        let vals: Vec<f32> = (0..5000).map(|_| rng.normal()).collect();
        let h = weight_histogram(&vals, -4.0, 4.0, 32);
        assert_eq!(h.counts.iter().sum::<u64>() as usize, vals.len());
        assert!(h.mean.abs() < 0.1);
        // gaussian: excess kurtosis ~ 0
        assert!(h.excess_kurtosis.abs() < 0.5, "{}", h.excess_kurtosis);
    }

    #[test]
    fn laplace_has_heavier_tails() {
        // laplace via difference of exponentials
        let mut rng = Rng::new(5);
        let vals: Vec<f32> = (0..20000)
            .map(|_| {
                let u: f32 = rng.next_f32().max(1e-6);
                let e = -u.ln();
                if rng.coin(0.5) {
                    e
                } else {
                    -e
                }
            })
            .collect();
        let h = weight_histogram(&vals, -8.0, 8.0, 32);
        assert!(h.excess_kurtosis > 1.5, "laplace kurtosis {}", h.excess_kurtosis);
    }

    #[test]
    fn render_is_nonempty() {
        let h = weight_histogram(&[0.0, 0.5, 0.5, -0.5], -1.0, 1.0, 4);
        let s = render_histogram(&h, 20);
        assert!(s.lines().count() == 4);
    }
}
