//! Metrics (S11): latency histograms and throughput counters for the
//! serving coordinator and benchmark harnesses.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-bucketed latency histogram (microseconds, powers of two), safe for
/// concurrent recording.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    sum_us: AtomicU64,
    count: AtomicU64,
    max_us: AtomicU64,
}

const NUM_BUCKETS: usize = 32;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(NUM_BUCKETS - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Recorded sample count.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    /// Largest recorded latency in microseconds.
    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th sample).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }

    /// Fold another histogram's samples into this one (bucket-wise add;
    /// max takes the larger). Used to aggregate per-replica histograms
    /// into a fleet-level distribution for the serving bench report.
    pub fn absorb(&self, other: &LatencyHistogram) {
        for (b, o) in self.buckets.iter().zip(other.buckets.iter()) {
            b.fetch_add(o.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.sum_us.fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_us.fetch_max(other.max_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// One-line summary (count, mean, p50/p95/p99 bounds, max).
    pub fn report(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.1}us p50<={}us p95<={}us p99<={}us max={}us",
            self.count(),
            self.mean_us(),
            self.quantile_us(0.5),
            self.quantile_us(0.95),
            self.quantile_us(0.99),
            self.max_us()
        )
    }
}

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHistogram::new();
        for us in [10u64, 100, 1000, 10000, 100000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.95));
        assert!(h.quantile_us(0.95) <= h.quantile_us(1.0).max(h.max_us()));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn zero_duration_handled() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(0));
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn absorb_merges_distributions() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        a.record(Duration::from_micros(10));
        a.record(Duration::from_micros(100));
        b.record(Duration::from_micros(1000));
        b.record(Duration::from_micros(50_000));
        a.absorb(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.max_us(), 50_000);
        assert!(a.quantile_us(0.99) >= 50_000);
        // b is untouched
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn counter_adds() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }
}
