//! Cycle-level sparse-accelerator simulator (S4).
//!
//! Stands in for STONNE simulating the SIGMA accelerator (paper §5.2 and
//! supp. A): the paper only uses that stack to measure the *energy ratio*
//! of a dense (0% sparsity) vs sparse (65% sparsity) run of each conv
//! layer, so this module models the mechanism that produces the ratio:
//!
//! * a grid of `mult_switches` multiplier switches (SIGMA default 256)
//!   consuming only *effectual* (non-zero-weight) MACs — SIGMA's
//!   bitmap-based sparse GEMM controller (`SIGMA_SPARSE_GEMM`);
//! * a pipelined adder/reduction network (`ASNETWORK`) whose switch count
//!   scales with the multiplier count;
//! * an SDMemory with `rd_ports`/`wr_ports` that streams compressed
//!   (bitmap) weights — reads scale with density plus a metadata tax —
//!   and dense activations/outputs;
//! * per-component energy weights in arbitrary units with SIGMA-like
//!   relative costs (SRAM access >> network hop > MAC).
//!
//! Energies are reported per layer for a dense and a sparse configuration
//! of the same GEMM; their ratio is the experiment. Like SIGMA, energy
//! is *not* a function of operand bit-width here (supp. A note).

use crate::tensor::Conv2dGeometry;

/// Hardware configuration (defaults = the paper's SIGMA setup).
#[derive(Debug, Clone, Copy)]
pub struct AcceleratorConfig {
    /// multiplier switches in the compute grid (SIGMA default 256)
    pub mult_switches: usize,
    /// SDMemory read ports
    pub rd_ports: usize,
    /// SDMemory write ports
    pub wr_ports: usize,
    /// elements per port per cycle
    pub port_width: usize,
    /// output columns served by one activation fetch (multicast width of
    /// the distribution network): activation SRAM traffic scales with
    /// ceil(N / multicast) *independent of weight sparsity* — the term
    /// that keeps measured energy reduction below the 1/density ideal.
    pub multicast: usize,
    // energy per event, arbitrary units (relative costs follow
    // Horowitz-style tallies used by STONNE's energy tables)
    /// energy per effectual MAC
    pub e_mac: f64,
    /// energy per reduction-network hop
    pub e_reduce_hop: f64,
    /// energy per distribution-network hop
    pub e_dist_hop: f64,
    /// energy per SRAM element read
    pub e_sram_read: f64,
    /// energy per SRAM element write
    pub e_sram_write: f64,
    /// control/clocking energy per cycle
    pub e_ctrl_per_cycle: f64,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        AcceleratorConfig {
            mult_switches: 256,
            rd_ports: 256,
            wr_ports: 256,
            port_width: 1,
            multicast: 16,
            e_mac: 1.0,
            e_reduce_hop: 0.6,
            e_dist_hop: 0.4,
            e_sram_read: 8.0,
            e_sram_write: 4.5,
            e_ctrl_per_cycle: 8.0,
        }
    }
}

/// One simulated GEMM / conv run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// modelled run length in cycles
    pub cycles: u64,
    /// MACs actually executed (non-zero weights)
    pub effectual_macs: u64,
    /// dense MAC count of the GEMM
    pub total_macs: u64,
    /// total energy (arbitrary units)
    pub energy: f64,
    /// compute (MAC) energy component
    pub energy_compute: f64,
    /// distribution + reduction network energy component
    pub energy_network: f64,
    /// SRAM read/write energy component
    pub energy_sram: f64,
    /// control/clocking energy component
    pub energy_ctrl: f64,
}

impl SimReport {
    /// Effectual / total MAC ratio of the simulated run.
    pub fn density(&self) -> f64 {
        self.effectual_macs as f64 / self.total_macs.max(1) as f64
    }
}

/// Simulate `C[M,N] = A[M,K] x B[K,N]` where B (weights) has the given
/// density in [0, 1]. Dense runs use density = 1.0.
pub fn simulate_gemm(
    m: usize,
    k: usize,
    n: usize,
    density: f64,
    cfg: &AcceleratorConfig,
) -> SimReport {
    assert!((0.0..=1.0).contains(&density));
    let total_macs = (m as u64) * (k as u64) * (n as u64);
    let effectual_macs = ((total_macs as f64) * density).round() as u64;

    // --- cycles -----------------------------------------------------------
    // compute: effectual MACs spread over the multiplier switches, plus the
    // reduction-tree fill latency once per output tile.
    let compute_cycles = effectual_macs.div_ceil(cfg.mult_switches as u64);
    let tree_depth = (cfg.mult_switches as f64).log2().ceil() as u64;
    // memory: weights stream compressed (density + 1/32 bitmap metadata);
    // activations are re-fetched once per multicast-wide column tile
    // regardless of weight sparsity (weight-stationary dataflow); outputs
    // written once.
    let col_passes = (n as u64).div_ceil(cfg.multicast as u64);
    let weight_elems = ((k * n) as f64 * (density + 1.0 / 32.0)).ceil() as u64;
    let act_elems = (m as u64) * (k as u64) * col_passes;
    let out_elems = (m as u64) * (n as u64);
    let rd_bw = (cfg.rd_ports * cfg.port_width) as u64;
    let wr_bw = (cfg.wr_ports * cfg.port_width) as u64;
    let mem_cycles = (weight_elems + act_elems).div_ceil(rd_bw) + out_elems.div_ceil(wr_bw);
    // compute and memory overlap (double-buffered SDMemory): the run is
    // bound by the slower of the two, plus pipeline fill.
    let cycles = compute_cycles.max(mem_cycles) + tree_depth;

    // --- energy -----------------------------------------------------------
    let energy_compute = effectual_macs as f64 * cfg.e_mac;
    // each effectual operand traverses the distribution network once and
    // each partial product climbs the reduction tree (log2 hops amortized
    // to ~1 hop per MAC in a balanced FAN/AS network).
    let energy_network =
        effectual_macs as f64 * (cfg.e_dist_hop + cfg.e_reduce_hop);
    let energy_sram = (weight_elems + act_elems) as f64 * cfg.e_sram_read
        + out_elems as f64 * cfg.e_sram_write;
    let energy_ctrl = cycles as f64 * cfg.e_ctrl_per_cycle;
    let energy = energy_compute + energy_network + energy_sram + energy_ctrl;

    SimReport {
        cycles,
        effectual_macs,
        total_macs,
        energy,
        energy_compute,
        energy_network,
        energy_sram,
        energy_ctrl,
    }
}

/// Map a conv layer to the accelerator GEMM (im2col view) and simulate.
pub fn simulate_conv(geom: &Conv2dGeometry, density: f64, cfg: &AcceleratorConfig) -> SimReport {
    let m = geom.n * geom.out_h() * geom.out_w();
    let k = geom.c * geom.r * geom.s;
    let n = geom.k;
    simulate_gemm(m, k, n, density, cfg)
}

/// The paper's §5.2 experiment: energy(dense) / energy(sparse) for one
/// layer at the given sparsity (0.65 for signed-binary ResNet-18).
pub fn energy_reduction(geom: &Conv2dGeometry, sparsity: f64, cfg: &AcceleratorConfig) -> f64 {
    let dense = simulate_conv(geom, 1.0, cfg);
    let sparse = simulate_conv(geom, 1.0 - sparsity, cfg);
    dense.energy / sparse.energy
}

/// §5.2 throughput potential: 1/density ideal, cycles ratio as modelled.
pub fn throughput_speedup(geom: &Conv2dGeometry, sparsity: f64, cfg: &AcceleratorConfig) -> f64 {
    let dense = simulate_conv(geom, 1.0, cfg);
    let sparse = simulate_conv(geom, 1.0 - sparsity, cfg);
    dense.cycles as f64 / sparse.cycles as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resnet_layer() -> Conv2dGeometry {
        // a mid resnet18 layer: 128x128 3x3 on 28x28
        Conv2dGeometry { n: 1, c: 128, h: 28, w: 28, k: 128, r: 3, s: 3, stride: 1, padding: 1 }
    }

    #[test]
    fn dense_run_has_all_macs_effectual() {
        let r = simulate_conv(&resnet_layer(), 1.0, &AcceleratorConfig::default());
        assert_eq!(r.effectual_macs, r.total_macs);
        assert!(r.cycles > 0);
    }

    #[test]
    fn energy_decreases_monotonically_with_sparsity() {
        let cfg = AcceleratorConfig::default();
        let g = resnet_layer();
        let mut last = f64::INFINITY;
        for s in [0.0, 0.25, 0.5, 0.65, 0.9] {
            let e = simulate_conv(&g, 1.0 - s, &cfg).energy;
            assert!(e < last, "energy not monotone at sparsity {s}");
            last = e;
        }
    }

    #[test]
    fn paper_ratio_65pct_sparsity_about_2x() {
        // §5.2: decreasing density from 100% to 35% -> ~2x energy reduction
        let cfg = AcceleratorConfig::default();
        let ratio = energy_reduction(&resnet_layer(), 0.65, &cfg);
        assert!(
            (1.6..=2.6).contains(&ratio),
            "energy reduction {ratio} outside the paper's ~2x band"
        );
    }

    #[test]
    fn throughput_bounded_by_ideal() {
        let cfg = AcceleratorConfig::default();
        let g = resnet_layer();
        let sp = throughput_speedup(&g, 0.65, &cfg);
        let ideal = 1.0 / 0.35;
        assert!(sp > 1.2 && sp <= ideal + 1e-9, "speedup {sp}, ideal {ideal}");
    }

    #[test]
    fn cycles_scale_with_work() {
        let cfg = AcceleratorConfig::default();
        let a = simulate_gemm(64, 512, 64, 1.0, &cfg);
        let b = simulate_gemm(128, 512, 64, 1.0, &cfg);
        assert!(b.cycles > a.cycles);
        assert_eq!(b.total_macs, 2 * a.total_macs);
    }

    #[test]
    fn more_multipliers_fewer_cycles() {
        let mut cfg = AcceleratorConfig::default();
        let g = resnet_layer();
        let base = simulate_conv(&g, 1.0, &cfg).cycles;
        cfg.mult_switches = 1024;
        cfg.rd_ports = 1024;
        cfg.wr_ports = 1024;
        let big = simulate_conv(&g, 1.0, &cfg).cycles;
        assert!(big < base);
    }

    #[test]
    fn energy_breakdown_sums() {
        let r = simulate_conv(&resnet_layer(), 0.35, &AcceleratorConfig::default());
        let sum = r.energy_compute + r.energy_network + r.energy_sram + r.energy_ctrl;
        assert!((sum - r.energy).abs() < 1e-6);
    }

    #[test]
    fn bitwidth_independence_note() {
        // supp. A: the reduction due to sparsity is not a function of
        // weight precision — our model has no bit-width term at all, so
        // the ratio is trivially invariant; assert the API reflects that.
        let cfg = AcceleratorConfig::default();
        let g = resnet_layer();
        let r1 = energy_reduction(&g, 0.65, &cfg);
        let r2 = energy_reduction(&g, 0.65, &cfg);
        assert_eq!(r1, r2);
    }
}
