//! Launcher configuration (S10): defaults + JSON config file + CLI flag
//! overrides, in that precedence order. Used by the `plum` binary so a
//! deployment can pin artifact paths, training budgets and bench
//! parameters in a checked-in file.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::cli::args::Args;
use crate::util::Json;

/// Global run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Artifact directory (HLO + manifests + params).
    pub artifacts: PathBuf,
    /// Checkpoint/output directory.
    pub out_dir: PathBuf,
    /// Default training steps for table harnesses.
    pub steps: u64,
    /// Eval batches per accuracy measurement.
    pub eval_batches: usize,
    /// Dataset seed.
    pub seed: u64,
    /// Synthetic-dataset pixel-noise std: tuned so accuracies sit below
    /// the ceiling and scheme differences are visible (cf. DESIGN.md
    /// accuracy-scaling note).
    pub data_noise: f32,
    /// Benchmark repetitions (paper runs 50, reports min).
    pub bench_reps: usize,
    /// Worker-pool width (0 = auto: `PLUM_THREADS` env, else all
    /// cores). Non-zero pins the process-wide pool before first use —
    /// the `--threads` CLI flag.
    pub threads: usize,
    /// Serving: worker replicas.
    pub replicas: usize,
    /// Serving: device batch size per replica.
    pub max_batch: usize,
    /// Serving: batcher deadline in milliseconds.
    pub max_wait_ms: u64,
    /// Serving: bounded per-replica admission queue depth (requests
    /// beyond it are shed with `ServeError::Overloaded`).
    pub queue_depth: usize,
    /// Serving: default request deadline in milliseconds.
    pub deadline_ms: u64,
    /// Serving: consecutive replica failures that trip the circuit
    /// breaker (until then the supervisor respawns the replica).
    pub breaker_threshold: usize,
    /// Serving: graceful-drain budget in milliseconds at a hot swap /
    /// retirement / shutdown (stragglers past it are answered typed).
    pub drain_timeout_ms: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("out"),
            steps: 200,
            eval_batches: 6,
            seed: 7,
            data_noise: 0.55,
            bench_reps: 20,
            threads: 0,
            replicas: 1,
            max_batch: 8,
            max_wait_ms: 2,
            queue_depth: 256,
            deadline_ms: 1000,
            breaker_threshold: 3,
            drain_timeout_ms: 5000,
        }
    }
}

impl RunConfig {
    /// Load from a JSON file (all fields optional).
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let mut cfg = RunConfig::default();
        cfg.apply_json(&j);
        Ok(cfg)
    }

    fn apply_json(&mut self, j: &Json) {
        if let Some(v) = j.get("artifacts").and_then(Json::as_str) {
            self.artifacts = PathBuf::from(v);
        }
        if let Some(v) = j.get("out_dir").and_then(Json::as_str) {
            self.out_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("steps").and_then(Json::as_usize) {
            self.steps = v as u64;
        }
        if let Some(v) = j.get("eval_batches").and_then(Json::as_usize) {
            self.eval_batches = v;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_usize) {
            self.seed = v as u64;
        }
        if let Some(v) = j.get("data_noise").and_then(Json::as_f64) {
            self.data_noise = v as f32;
        }
        if let Some(v) = j.get("bench_reps").and_then(Json::as_usize) {
            self.bench_reps = v;
        }
        if let Some(v) = j.get("threads").and_then(Json::as_usize) {
            self.threads = v;
        }
        if let Some(v) = j.get("replicas").and_then(Json::as_usize) {
            self.replicas = v;
        }
        if let Some(v) = j.get("max_batch").and_then(Json::as_usize) {
            self.max_batch = v;
        }
        if let Some(v) = j.get("max_wait_ms").and_then(Json::as_usize) {
            self.max_wait_ms = v as u64;
        }
        if let Some(v) = j.get("queue_depth").and_then(Json::as_usize) {
            self.queue_depth = v;
        }
        if let Some(v) = j.get("deadline_ms").and_then(Json::as_usize) {
            self.deadline_ms = v as u64;
        }
        if let Some(v) = j.get("breaker_threshold").and_then(Json::as_usize) {
            self.breaker_threshold = v;
        }
        if let Some(v) = j.get("drain_timeout_ms").and_then(Json::as_usize) {
            self.drain_timeout_ms = v as u64;
        }
    }

    /// Resolve: defaults -> optional `--config file` -> CLI flags.
    pub fn resolve(args: &Args) -> Result<RunConfig> {
        let mut cfg = match args.get("config") {
            Some(p) => RunConfig::from_file(Path::new(p))?,
            None => RunConfig::default(),
        };
        if let Some(v) = args.get("artifacts") {
            cfg.artifacts = PathBuf::from(v);
        }
        if let Some(v) = args.get("out-dir") {
            cfg.out_dir = PathBuf::from(v);
        }
        cfg.steps = args.get_u64("steps", cfg.steps);
        cfg.eval_batches = args.get_usize("eval-batches", cfg.eval_batches);
        cfg.seed = args.get_u64("seed", cfg.seed);
        cfg.data_noise = args.get_f32("data-noise", cfg.data_noise);
        cfg.bench_reps = args.get_usize("reps", cfg.bench_reps);
        cfg.threads = args.get_usize("threads", cfg.threads);
        cfg.replicas = args.get_usize("replicas", cfg.replicas);
        cfg.max_batch = args.get_usize("max-batch", cfg.max_batch);
        cfg.max_wait_ms = args.get_u64("max-wait-ms", cfg.max_wait_ms);
        cfg.queue_depth = args.get_usize("queue-depth", cfg.queue_depth);
        cfg.deadline_ms = args.get_u64("deadline-ms", cfg.deadline_ms);
        cfg.breaker_threshold = args.get_usize("breaker-threshold", cfg.breaker_threshold);
        cfg.drain_timeout_ms = args.get_u64("drain-timeout-ms", cfg.drain_timeout_ms);
        Ok(cfg)
    }

    /// The serving policy these knobs describe (backoff timing is fixed;
    /// everything else is file/flag-tunable).
    pub fn serve_policy(&self) -> crate::coordinator::ServePolicy {
        crate::coordinator::ServePolicy {
            batch: crate::coordinator::BatchPolicy {
                max_batch: self.max_batch.max(1),
                max_wait: std::time::Duration::from_millis(self.max_wait_ms),
            },
            queue_depth: self.queue_depth.max(1),
            default_deadline: std::time::Duration::from_millis(self.deadline_ms.max(1)),
            breaker_threshold: self.breaker_threshold.max(1),
            backoff_base: std::time::Duration::from_millis(10),
            backoff_cap: std::time::Duration::from_millis(500),
            drain_timeout: std::time::Duration::from_millis(self.drain_timeout_ms.max(1)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_then_flags_precedence() {
        let dir = std::env::temp_dir().join("plum_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"steps": 50, "seed": 3, "artifacts": "/a"}"#).unwrap();
        let args = Args::parse(
            ["--config", p.to_str().unwrap(), "--steps", "99"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = RunConfig::resolve(&args).unwrap();
        assert_eq!(cfg.steps, 99); // flag wins
        assert_eq!(cfg.seed, 3); // file wins over default
        assert_eq!(cfg.artifacts, PathBuf::from("/a"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn defaults_without_anything() {
        let cfg = RunConfig::resolve(&Args::default()).unwrap();
        assert_eq!(cfg.steps, 200);
        assert_eq!(cfg.queue_depth, 256);
        assert_eq!(cfg.deadline_ms, 1000);
        assert_eq!(cfg.breaker_threshold, 3);
    }

    #[test]
    fn serving_knobs_resolve_into_a_policy() {
        let args = Args::parse(
            [
                "--queue-depth",
                "32",
                "--deadline-ms",
                "250",
                "--breaker-threshold",
                "5",
                "--drain-timeout-ms",
                "750",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let cfg = RunConfig::resolve(&args).unwrap();
        let p = cfg.serve_policy();
        assert_eq!(p.queue_depth, 32);
        assert_eq!(p.default_deadline, std::time::Duration::from_millis(250));
        assert_eq!(p.breaker_threshold, 5);
        assert_eq!(p.batch.max_batch, cfg.max_batch);
        assert_eq!(p.drain_timeout, std::time::Duration::from_millis(750));
    }
}
