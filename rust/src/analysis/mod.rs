//! Static plan-soundness verifier — `plum audit` and the debug-build
//! compile gate.
//!
//! Every hot-path speedup in this crate (pixel-major gathers, fused
//! blocked edges, elided spans, batch-prefix arenas) rides on a small
//! set of `unsafe` sites whose preconditions are *plan* properties: the
//! executor writes through [`UnsafeSlice`](crate::util::UnsafeSlice)
//! without synchronization because tiles own disjoint output ranges,
//! the activation arena hands layers overlapping buffers because slot
//! live ranges never intersect, the CSR walk skips bounds checks
//! because every span/combine index was placed in bounds at plan build.
//! This module proves those preconditions **statically, by symbolic
//! range analysis over the plan data structures, without executing a
//! forward** — each check reasons about index *formulas* and interval
//! algebra rather than running the kernel and observing it.
//!
//! Five check families, each naming the unsafe code it justifies:
//!
//! 1. **Arena CSR invariants** ([`audit_layer_plan`]): spans tile
//!    `cols` back to back, every column is inside the patch matrix
//!    (`< C*R*S`), `table_base` is monotone and ends at `spans.len()`,
//!    every combine slot lands in its own sub-tile's span range (or
//!    the shared no-op), the elided no-op span at slot 0 is well-formed
//!    and [`DensityStats`] agrees with the spans — the preconditions of
//!    the executor's unchecked `cols`/`psums`/`combine` indexing.
//! 2. **Tile-disjoint writes**: for every layer and runtime batch, the
//!    exact set of output indices each pool job writes is derived from
//!    the scatter formulas (`(ni*K + fi)*plane + pix` NCHW,
//!    `(gb*K + fi)*PB + b` blocked) as closed intervals; the whole
//!    layer schedule is then checked pairwise-disjoint, in bounds, and
//!    *gap-free* (full coverage — stale data is never left unwritten).
//!    This is the justification for `unsafe impl Sync for UnsafeSlice`.
//! 3. **Slot live-range non-aliasing**: live ranges are re-derived from
//!    the wiring (independently of `allocate_slots`) and no two
//!    overlapping-live activations may share an arena slot; a layer's
//!    output slot must differ from its input and residual slots — the
//!    precondition of `arena_views`' disjoint reborrows.
//! 4. **PB-alignment of blocked tiles**: any layer with blocked patch
//!    I/O requires the execution tile to be a multiple of
//!    [`PIXEL_BLOCK`] (blocks must not straddle jobs, or two jobs would
//!    write one block's interval).
//! 5. **Batch-prefix bounds**: `act_buf_elems_at(a, b)` must fit the
//!    compile-time slot capacity for **every** `1 <= b <= bmax`, so a
//!    partial-batch forward can never write past its arena slot.
//!
//! Findings are typed ([`AuditFinding`]) with layer/span/range
//! provenance. [`NetworkPlan`] compiles run [`audit_network_plan`] in
//! debug builds (every `cargo test` exercises the gate); the
//! `plum audit` CLI runs it in release across the whole zoo and exits
//! nonzero on any finding.
//!
//! Determinism contract: the audit itself is deterministic and
//! thread-count-independent — it runs on the calling thread only,
//! iterates plan data in fixed order, and depends on nothing but the
//! plan bytes and the tile, so two audits of the same plan always
//! produce the identical finding list.

use std::fmt;

use crate::network::NetworkPlan;
use crate::repetition::{DensityStats, LayerPlan, PIXEL_BLOCK};

/// One statically-proven violation of an executor precondition, with
/// enough provenance (layer, span, index, range) to locate the corrupt
/// plan data. An empty finding list is the soundness certificate the
/// unsafe code relies on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AuditFinding {
    /// A plan-side buffer does not have the length its indexing scheme
    /// assumes (`what` names the buffer).
    ShapeMismatch {
        /// layer index in the network schedule
        layer: usize,
        /// which buffer is misshapen
        what: &'static str,
        /// length the indexing scheme requires
        expected: usize,
        /// length actually found
        found: usize,
    },
    /// A span's `start` does not continue where the previous span's run
    /// ended — the CSR arena is not contiguous.
    SpanNotContiguous {
        /// layer index
        layer: usize,
        /// global span slot
        span: usize,
        /// expected start offset (end of the previous run)
        expected: u32,
        /// start offset recorded on the span
        found: u32,
    },
    /// A span's column run extends past the end of `cols`.
    SpanOutOfBounds {
        /// layer index
        layer: usize,
        /// global span slot
        span: usize,
        /// one-past-the-end offset the span claims
        end: usize,
        /// actual `cols` length
        cols: usize,
    },
    /// An arena column index is outside the patch matrix.
    ColumnOutOfRange {
        /// layer index
        layer: usize,
        /// global span slot owning the column
        span: usize,
        /// offending column index
        col: u32,
        /// patch-matrix column count (`C*R*S`)
        limit: usize,
    },
    /// A span inside a sub-tile does not cover that sub-tile's length.
    SpanLenMismatch {
        /// layer index
        layer: usize,
        /// sub-tile index
        table: usize,
        /// global span slot
        span: usize,
        /// span's total column count
        span_len: usize,
        /// sub-tile length it must equal
        table_len: usize,
    },
    /// `table_base` decreases between adjacent sub-tiles.
    TableBaseNotMonotone {
        /// layer index
        layer: usize,
        /// sub-tile whose base exceeds its successor
        table: usize,
        /// base of `table`
        base: u32,
        /// base of `table + 1`
        next: u32,
    },
    /// A `table_base` entry points outside `spans` (or the row pointers
    /// do not start/end where the arena layout requires).
    TableBaseOutOfBounds {
        /// layer index
        layer: usize,
        /// offending row-pointer value
        base: u32,
        /// number of spans it must stay within
        num_spans: usize,
    },
    /// The elided arena's shared no-op slot is missing or malformed
    /// (`reason` says how).
    NoopSlotMalformed {
        /// layer index
        layer: usize,
        /// what exactly is wrong with the no-op bookkeeping
        reason: &'static str,
    },
    /// An all-zero span other than the shared no-op owns a real slot in
    /// an elided arena (elision failed to fold it).
    IneffectualSpanKept {
        /// layer index
        layer: usize,
        /// global span slot of the ineffectual pattern
        span: usize,
    },
    /// `unique_of_filter` maps a filter to a nonexistent unique slot.
    FilterMapOutOfBounds {
        /// layer index
        layer: usize,
        /// original filter index
        filter: usize,
        /// unique-filter slot it names
        unique: u32,
        /// number of unique filters that exist
        num_unique: usize,
    },
    /// A combine-table entry names a nonexistent pattern span.
    CombineSlotOutOfBounds {
        /// layer index
        layer: usize,
        /// unique filter
        unique_filter: usize,
        /// sub-tile index
        table: usize,
        /// offending global span slot
        slot: u32,
        /// number of spans that exist
        num_patterns: usize,
    },
    /// A combine-table entry points at a span outside its own sub-tile
    /// (and it is not the shared no-op).
    CombineSlotOutsideTable {
        /// layer index
        layer: usize,
        /// unique filter
        unique_filter: usize,
        /// sub-tile index
        table: usize,
        /// global span slot that belongs to another sub-tile
        slot: u32,
    },
    /// Recorded [`DensityStats`] disagree with what the spans and
    /// combine table actually encode.
    DensityStatsMismatch {
        /// layer index
        layer: usize,
        /// which stats field disagrees
        field: &'static str,
        /// value recorded at plan build
        recorded: u64,
        /// value derived from the arena
        derived: u64,
    },
    /// Two pool jobs of one layer dispatch would write the same output
    /// index — the `UnsafeSlice` disjointness contract is broken.
    WriteOverlap {
        /// layer index
        layer: usize,
        /// runtime batch the schedule was derived for
        batch: usize,
        /// first overlapping output index
        index: usize,
        /// the two jobs whose write ranges collide
        jobs: (usize, usize),
    },
    /// A job's write range extends past the layer's output buffer.
    WriteOutOfBounds {
        /// layer index
        layer: usize,
        /// runtime batch
        batch: usize,
        /// one-past-the-end index of the offending range
        end: usize,
        /// output buffer length
        buf: usize,
    },
    /// An output index is written by no job at all — a forward would
    /// leave stale data for the next consumer.
    WriteGap {
        /// layer index
        layer: usize,
        /// runtime batch
        batch: usize,
        /// first uncovered output index
        index: usize,
    },
    /// A layer with blocked patch I/O is scheduled with a tile that is
    /// not a multiple of [`PIXEL_BLOCK`] — jobs would split lane blocks
    /// and the blocked write intervals above would interleave.
    MisalignedBlockedTile {
        /// layer index
        layer: usize,
        /// offending execution tile
        tile: usize,
    },
    /// An activation's arena slot index does not exist.
    SlotIndexOutOfBounds {
        /// activation index
        act: usize,
        /// slot it names
        slot: usize,
        /// number of slots that exist
        num_slots: usize,
    },
    /// Two activations with overlapping live ranges share an arena
    /// slot: writing the later one destroys the earlier one while it is
    /// still read.
    SlotLiveRangeOverlap {
        /// shared arena slot
        slot: usize,
        /// earlier activation (still live)
        earlier: usize,
        /// later activation whose write clobbers it
        later: usize,
        /// layer that still reads `earlier`
        last_read: usize,
    },
    /// A layer's output slot aliases one of the buffers it reads
    /// (`which` names the edge) — `arena_views` requires them disjoint.
    OutputSlotAliased {
        /// layer index
        layer: usize,
        /// aliased arena slot
        slot: usize,
        /// `"input"` or `"residual"`
        which: &'static str,
    },
    /// Recorded per-activation sizing disagrees with the shape-derived
    /// value (`what` names the table).
    ActSizeMismatch {
        /// activation index
        act: usize,
        /// which sizing table disagrees
        what: &'static str,
        /// value recorded at compile
        recorded: usize,
        /// value derived from `act_shape`
        derived: usize,
    },
    /// At some runtime batch `1 <= b <= bmax` an activation's buffer
    /// prefix exceeds its slot capacity — a partial-batch forward would
    /// write past the arena slot.
    BatchPrefixOverflow {
        /// activation index
        act: usize,
        /// runtime batch at which the prefix first overflows
        batch: usize,
        /// elements the activation needs at that batch
        needed: usize,
        /// arena slot it lives in
        slot: usize,
        /// compile-time capacity of that slot
        capacity: usize,
    },
}

impl fmt::Display for AuditFinding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use AuditFinding::*;
        match self {
            ShapeMismatch { layer, what, expected, found } => {
                write!(f, "layer {layer}: {what} has {found} entries, indexing needs {expected}")
            }
            SpanNotContiguous { layer, span, expected, found } => {
                write!(
                    f,
                    "layer {layer}: span {span} starts at {found}, previous run ends at \
                     {expected}"
                )
            }
            SpanOutOfBounds { layer, span, end, cols } => {
                write!(f, "layer {layer}: span {span} runs to {end}, cols has {cols}")
            }
            ColumnOutOfRange { layer, span, col, limit } => {
                write!(
                    f,
                    "layer {layer}: span {span} column {col} outside patch matrix \
                     (C*R*S = {limit})"
                )
            }
            SpanLenMismatch { layer, table, span, span_len, table_len } => {
                write!(
                    f,
                    "layer {layer}: span {span} covers {span_len} columns, sub-tile {table} \
                     is {table_len} wide"
                )
            }
            TableBaseNotMonotone { layer, table, base, next } => {
                write!(
                    f,
                    "layer {layer}: table_base[{table}] = {base} > table_base[{}] = {next}",
                    table + 1
                )
            }
            TableBaseOutOfBounds { layer, base, num_spans } => {
                write!(f, "layer {layer}: table_base entry {base} outside {num_spans} spans")
            }
            NoopSlotMalformed { layer, reason } => {
                write!(f, "layer {layer}: no-op slot malformed: {reason}")
            }
            IneffectualSpanKept { layer, span } => {
                write!(
                    f,
                    "layer {layer}: all-zero span {span} owns arena storage in an elided plan"
                )
            }
            FilterMapOutOfBounds { layer, filter, unique, num_unique } => {
                write!(
                    f,
                    "layer {layer}: filter {filter} maps to unique slot {unique} of {num_unique}"
                )
            }
            CombineSlotOutOfBounds { layer, unique_filter, table, slot, num_patterns } => {
                write!(
                    f,
                    "layer {layer}: combine[{unique_filter}][{table}] names span {slot} of \
                     {num_patterns}"
                )
            }
            CombineSlotOutsideTable { layer, unique_filter, table, slot } => {
                write!(
                    f,
                    "layer {layer}: combine[{unique_filter}][{table}] names span {slot} \
                     outside sub-tile {table}"
                )
            }
            DensityStatsMismatch { layer, field, recorded, derived } => {
                write!(
                    f,
                    "layer {layer}: DensityStats.{field} records {recorded}, arena encodes \
                     {derived}"
                )
            }
            WriteOverlap { layer, batch, index, jobs } => {
                write!(
                    f,
                    "layer {layer} (b={batch}): jobs {} and {} both write output index {index}",
                    jobs.0, jobs.1
                )
            }
            WriteOutOfBounds { layer, batch, end, buf } => {
                write!(
                    f,
                    "layer {layer} (b={batch}): write range runs to {end}, buffer holds {buf}"
                )
            }
            WriteGap { layer, batch, index } => {
                write!(f, "layer {layer} (b={batch}): output index {index} is written by no job")
            }
            MisalignedBlockedTile { layer, tile } => {
                write!(
                    f,
                    "layer {layer}: blocked patch I/O with tile {tile} not a multiple of \
                     {PIXEL_BLOCK}"
                )
            }
            SlotIndexOutOfBounds { act, slot, num_slots } => {
                write!(f, "activation {act} assigned slot {slot} of {num_slots}")
            }
            SlotLiveRangeOverlap { slot, earlier, later, last_read } => {
                write!(
                    f,
                    "slot {slot}: activation {later} is written at layer {} while activation \
                     {earlier} is still read at layer {last_read}",
                    later - 1
                )
            }
            OutputSlotAliased { layer, slot, which } => {
                write!(f, "layer {layer}: output slot {slot} aliases its {which} slot")
            }
            ActSizeMismatch { act, what, recorded, derived } => {
                write!(f, "activation {act}: {what} records {recorded}, shape derives {derived}")
            }
            BatchPrefixOverflow { act, batch, needed, slot, capacity } => {
                write!(
                    f,
                    "activation {act} needs {needed} elements at batch {batch}, slot {slot} \
                     holds {capacity}"
                )
            }
        }
    }
}

/// Audit one layer plan's CSR arena (check family 1): contiguity,
/// column bounds, `table_base` row pointers, no-op well-formedness,
/// combine-table range discipline and [`DensityStats`] consistency.
/// `layer` is only provenance for the findings.
pub fn audit_layer_plan(layer: usize, plan: &LayerPlan) -> Vec<AuditFinding> {
    let mut out = Vec::new();
    let a = &plan.arena;
    let e = plan.geom.c * plan.geom.r * plan.geom.s;
    let k = plan.geom.k;
    let nt = plan.num_tables;
    let nu = plan.num_unique_filters;

    // shape discipline first: everything below indexes by these lengths
    let shape = |what: &'static str, expected: usize, found: usize, out: &mut Vec<_>| {
        if expected != found {
            out.push(AuditFinding::ShapeMismatch { layer, what, expected, found });
        }
    };
    shape("table_base", nt + 1, a.table_base.len(), &mut out);
    shape("table_len", nt, plan.table_len.len(), &mut out);
    shape("alpha", k, plan.alpha.len(), &mut out);
    shape("unique_of_filter", k, plan.unique_of_filter.len(), &mut out);
    shape("combine", nu * nt, plan.combine.len(), &mut out);
    shape("sub-tile lengths (sum)", e, plan.table_len.iter().sum::<usize>(), &mut out);
    if !out.is_empty() {
        return out; // indexing below would read past the short buffers
    }

    // no-op bookkeeping: elided arenas share slot 0, materialized
    // arenas must not carry one
    let expected_first = match (a.zeros_materialized, a.noop_slot) {
        (false, Some(slot)) => {
            if slot != 0 {
                out.push(AuditFinding::NoopSlotMalformed {
                    layer,
                    reason: "shared no-op span must sit at global slot 0",
                });
            } else if a.spans.is_empty() || !a.spans[0].is_all_zero() || a.spans[0].len() != 0 {
                out.push(AuditFinding::NoopSlotMalformed {
                    layer,
                    reason: "slot 0 must be an empty all-zero span",
                });
            }
            1
        }
        (false, None) => {
            out.push(AuditFinding::NoopSlotMalformed {
                layer,
                reason: "elided arena carries no shared no-op slot",
            });
            0
        }
        (true, Some(_)) => {
            out.push(AuditFinding::NoopSlotMalformed {
                layer,
                reason: "materialized arena must not carry a no-op slot",
            });
            0
        }
        (true, None) => 0,
    };
    if a.table_base[0] != expected_first {
        out.push(AuditFinding::TableBaseOutOfBounds {
            layer,
            base: a.table_base[0],
            num_spans: a.num_patterns(),
        });
    }

    // row pointers: monotone, ending exactly at spans.len()
    let mut bases_ok = true;
    for ti in 0..nt {
        if a.table_base[ti] > a.table_base[ti + 1] {
            out.push(AuditFinding::TableBaseNotMonotone {
                layer,
                table: ti,
                base: a.table_base[ti],
                next: a.table_base[ti + 1],
            });
            bases_ok = false;
        }
    }
    if a.table_base[nt] as usize != a.num_patterns() {
        out.push(AuditFinding::TableBaseOutOfBounds {
            layer,
            base: a.table_base[nt],
            num_spans: a.num_patterns(),
        });
        bases_ok = false;
    }

    // span contiguity + column bounds: spans tile `cols` back to back
    // by their materialized runs (pos|neg, plus zero when materialized)
    let mut cursor = 0u32;
    for (gp, sp) in a.spans.iter().enumerate() {
        if sp.start != cursor {
            out.push(AuditFinding::SpanNotContiguous {
                layer,
                span: gp,
                expected: cursor,
                found: sp.start,
            });
        }
        let width = sp.pos + sp.neg + if a.zeros_materialized { sp.zero } else { 0 };
        let end = sp.start as usize + width as usize;
        cursor = sp.start + width;
        if end > a.cols.len() {
            out.push(AuditFinding::SpanOutOfBounds { layer, span: gp, end, cols: a.cols.len() });
            break;
        }
        for &col in &a.cols[sp.start as usize..end] {
            if col as usize >= e {
                out.push(AuditFinding::ColumnOutOfRange { layer, span: gp, col, limit: e });
                break; // one finding per span is enough provenance
            }
        }
    }
    if cursor as usize != a.cols.len() {
        out.push(AuditFinding::ShapeMismatch {
            layer,
            what: "cols",
            expected: cursor as usize,
            found: a.cols.len(),
        });
    }

    // per-table span discipline: every in-table span covers the whole
    // sub-tile, and elided arenas keep no ineffectual span but the no-op
    if bases_ok {
        for ti in 0..nt {
            for gp in a.table_base[ti] as usize..a.table_base[ti + 1] as usize {
                if a.spans[gp].len() != plan.table_len[ti] {
                    out.push(AuditFinding::SpanLenMismatch {
                        layer,
                        table: ti,
                        span: gp,
                        span_len: a.spans[gp].len(),
                        table_len: plan.table_len[ti],
                    });
                }
                if !a.zeros_materialized && a.spans[gp].is_all_zero() {
                    out.push(AuditFinding::IneffectualSpanKept { layer, span: gp });
                }
            }
        }
    }

    // filter map + combine table range discipline
    let mut indices_ok = bases_ok;
    for (fi, &ui) in plan.unique_of_filter.iter().enumerate() {
        if ui as usize >= nu {
            out.push(AuditFinding::FilterMapOutOfBounds {
                layer,
                filter: fi,
                unique: ui,
                num_unique: nu,
            });
            indices_ok = false;
        }
    }
    for ui in 0..nu {
        for ti in 0..nt {
            let gp = plan.combine[ui * nt + ti];
            if gp as usize >= a.num_patterns() {
                out.push(AuditFinding::CombineSlotOutOfBounds {
                    layer,
                    unique_filter: ui,
                    table: ti,
                    slot: gp,
                    num_patterns: a.num_patterns(),
                });
                indices_ok = false;
            } else if bases_ok {
                let in_table = gp >= a.table_base[ti] && gp < a.table_base[ti + 1];
                if !in_table && a.noop_slot != Some(gp) {
                    out.push(AuditFinding::CombineSlotOutsideTable {
                        layer,
                        unique_filter: ui,
                        table: ti,
                        slot: gp,
                    });
                }
            }
        }
    }

    // density accounting: derive the stats the spans actually encode
    // (weighted by original-filter usage, like the build) and compare
    if indices_ok {
        let derived = derive_density(plan, k, e, nt);
        let fields: [(&'static str, u64, u64); 3] = [
            ("total_cols", plan.stats.total_cols, derived.total_cols),
            ("effectual_cols", plan.stats.effectual_cols, derived.effectual_cols),
            ("elided_spans", plan.stats.elided_spans, derived.elided_spans),
        ];
        for (field, recorded, derived) in fields {
            if recorded != derived {
                out.push(AuditFinding::DensityStatsMismatch { layer, field, recorded, derived });
            }
        }
    }
    out
}

/// Re-derive [`DensityStats`] from the arena: each original filter
/// covers each column of the patch matrix exactly once, so the
/// effectual count is the filter-weighted sum of span `nnz`s and the
/// elided count is one folded pattern per sub-tile that routes any
/// filter through the no-op.
fn derive_density(plan: &LayerPlan, k: usize, e: usize, nt: usize) -> DensityStats {
    let a = &plan.arena;
    let mut effectual = 0u64;
    debug_assert_eq!(plan.unique_of_filter.len(), k);
    for &ui in &plan.unique_of_filter {
        let ui = ui as usize;
        for ti in 0..nt {
            effectual += a.spans[plan.combine[ui * nt + ti] as usize].nnz();
        }
    }
    let mut elided = 0u64;
    if let Some(noop) = a.noop_slot {
        for ti in 0..nt {
            let folded = (0..plan.num_unique_filters)
                .any(|ui| plan.combine[ui * nt + ti] == noop);
            elided += folded as u64;
        }
    }
    DensityStats { total_cols: (k * e) as u64, effectual_cols: effectual, elided_spans: elided }
}

/// One pool job's write range over a layer's output buffer, derived
/// symbolically from the scatter index formula.
#[derive(Clone, Copy)]
struct WriteRange {
    start: usize,
    end: usize,
    job: usize,
}

/// Audit a whole compiled network against the execution `tile` (check
/// families 2–5 plus [`audit_layer_plan`] per engine layer). Returns
/// every finding; an empty vector is the certificate the executor's
/// unsafe code assumes. Deterministic and single-threaded — see the
/// module docs.
pub fn audit_network_plan(plan: &NetworkPlan, tile: usize) -> Vec<AuditFinding> {
    let mut out = Vec::new();
    let bmax = plan.batch();
    let n_layers = plan.num_layers();
    let n_acts = n_layers + 1;

    // ---- family 1: per-layer arena invariants -------------------------
    for (li, l) in plan.layers.iter().enumerate() {
        if let Some(lp) = &l.plan {
            out.extend(audit_layer_plan(li, lp));
        }
    }

    // ---- family 3: slot live-range non-aliasing -----------------------
    let mut slots_ok = true;
    for (act, &slot) in plan.slot_of_act.iter().enumerate() {
        if slot >= plan.slot_elems.len() {
            out.push(AuditFinding::SlotIndexOutOfBounds {
                act,
                slot,
                num_slots: plan.slot_elems.len(),
            });
            slots_ok = false;
        }
    }
    // re-derive live ranges from the wiring, independently of
    // allocate_slots: activation a is read until last_use[a]; the
    // network output is pinned past the final layer
    let mut last_use = vec![0usize; n_acts];
    last_use[n_acts - 1] = n_layers;
    for (li, l) in plan.layers.iter().enumerate() {
        last_use[l.input] = last_use[l.input].max(li);
        if let Some(ai) = l.residual_from {
            last_use[ai] = last_use[ai].max(li);
        }
    }
    // activation j is written during layer j - 1; any same-slot
    // activation i < j must have taken its last read strictly before
    for j in 1..n_acts {
        for i in 0..j {
            if plan.slot_of_act[i] == plan.slot_of_act[j] && last_use[i] >= j - 1 {
                out.push(AuditFinding::SlotLiveRangeOverlap {
                    slot: plan.slot_of_act[i],
                    earlier: i,
                    later: j,
                    last_read: last_use[i],
                });
            }
        }
    }
    for (li, l) in plan.layers.iter().enumerate() {
        let out_slot = plan.slot_of_act[li + 1];
        if out_slot == plan.slot_of_act[l.input] {
            out.push(AuditFinding::OutputSlotAliased { layer: li, slot: out_slot, which: "input" });
        }
        if let Some(ai) = l.residual_from {
            if out_slot == plan.slot_of_act[ai] {
                out.push(AuditFinding::OutputSlotAliased {
                    layer: li,
                    slot: out_slot,
                    which: "residual",
                });
            }
        }
    }

    // ---- family 5: recorded sizes + batch-prefix bounds ---------------
    for act in 0..n_acts {
        let derived_full = plan.act_elems_at(act, bmax);
        if plan.act_elems[act] != derived_full {
            out.push(AuditFinding::ActSizeMismatch {
                act,
                what: "act_elems",
                recorded: plan.act_elems[act],
                derived: derived_full,
            });
        }
        let derived_buf = plan.act_buf_elems_at(act, bmax);
        if plan.act_buf_elems[act] != derived_buf {
            out.push(AuditFinding::ActSizeMismatch {
                act,
                what: "act_buf_elems",
                recorded: plan.act_buf_elems[act],
                derived: derived_buf,
            });
        }
        if !slots_ok {
            continue;
        }
        let slot = plan.slot_of_act[act];
        let capacity = plan.slot_elems[slot];
        for b in 1..=bmax {
            let needed = plan.act_buf_elems_at(act, b);
            if needed > capacity {
                out.push(AuditFinding::BatchPrefixOverflow {
                    act,
                    batch: b,
                    needed,
                    slot,
                    capacity,
                });
                break; // the smallest overflowing batch is the provenance
            }
        }
    }

    // ---- families 2 + 4: per-layer write schedules --------------------
    // the write-index formulas are affine in the batch index, so the
    // extreme batches certify every prefix in between
    let mut batches = vec![1, bmax];
    batches.dedup();
    for (li, l) in plan.layers.iter().enumerate() {
        if (l.in_blocked || l.out_blocked) && tile % PIXEL_BLOCK != 0 {
            out.push(AuditFinding::MisalignedBlockedTile { layer: li, tile });
            continue; // the schedule below is undefined on split blocks
        }
        for &b in &batches {
            audit_layer_writes(plan, li, b, tile, &mut out);
        }
    }
    out
}

/// Derive every pool job's output write range for layer `li` at runtime
/// batch `b` from the scatter formulas, then prove the whole dispatch
/// pairwise-disjoint, in bounds, and gap-free. No forward is executed —
/// the ranges come from the same index arithmetic the executor uses.
fn audit_layer_writes(
    plan: &NetworkPlan,
    li: usize,
    b: usize,
    tile: usize,
    out: &mut Vec<AuditFinding>,
) {
    const PB: usize = PIXEL_BLOCK;
    let l = &plan.layers[li];
    let g = l.geom;
    let (oh, ow) = (g.out_h(), g.out_w());
    let plane = oh * ow;
    let pixels = b * plane;
    let k = g.k;
    let buf = plan.act_buf_elems_at(li + 1, b);
    if pixels == 0 {
        return;
    }
    let jobs = pixels.div_ceil(tile);
    let mut ranges: Vec<WriteRange> = Vec::new();
    for job in 0..jobs {
        let px0 = job * tile;
        let tp = tile.min(pixels - px0);
        if l.out_blocked {
            // blocked scatter: obase = ((px0/PB + blk)*K + fi)*PB + lane.
            // Tiles are PB-aligned (checked by the caller), so a job owns
            // blocks [px0/PB, px0/PB + ceil(tp/PB)) and, with fi and lane
            // exhaustive, exactly one contiguous interval of the buffer.
            let gb0 = px0 / PB;
            let nb = tp.div_ceil(PB);
            ranges.push(WriteRange { start: gb0 * k * PB, end: (gb0 + nb) * k * PB, job });
        } else {
            // NCHW scatter: (ni*K + fi)*plane + pix. A job's pixel range
            // [px0, px0+tp) splits per image; for each (image, filter)
            // pair the pix sub-range is one contiguous interval.
            let ni1 = (px0 + tp - 1) / plane;
            for ni in px0 / plane..=ni1 {
                let lo = px0.max(ni * plane) - ni * plane;
                let hi = (px0 + tp).min((ni + 1) * plane) - ni * plane;
                for fi in 0..k {
                    let base = (ni * k + fi) * plane;
                    ranges.push(WriteRange { start: base + lo, end: base + hi, job });
                }
            }
        }
    }
    // interval sweep: sorted ranges must tile [0, buf) exactly
    ranges.sort_unstable_by_key(|r| (r.start, r.end));
    let mut covered = 0usize;
    let mut prev_job = 0usize;
    for r in &ranges {
        if r.start < covered {
            out.push(AuditFinding::WriteOverlap {
                layer: li,
                batch: b,
                index: r.start,
                jobs: (prev_job, r.job),
            });
            return; // one overlap per layer/batch is enough provenance
        }
        if r.start > covered {
            out.push(AuditFinding::WriteGap { layer: li, batch: b, index: covered });
            return;
        }
        covered = r.end;
        prev_job = r.job;
    }
    if covered > buf {
        out.push(AuditFinding::WriteOutOfBounds { layer: li, batch: b, end: covered, buf });
    } else if covered < buf {
        out.push(AuditFinding::WriteGap { layer: li, batch: b, index: covered });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::network::NetworkPlan;
    use crate::quant::Scheme;
    use crate::repetition::{EngineConfig, DEFAULT_TILE};

    fn compiled(batch: usize) -> NetworkPlan {
        let descs = models::cifar_resnet_layers(8, 0.5, 16, batch);
        NetworkPlan::compile(&descs, EngineConfig::default(), Scheme::sb_default()).unwrap()
    }

    #[test]
    fn green_plan_audits_clean_at_every_probe() {
        let plan = compiled(4);
        assert_eq!(audit_network_plan(&plan, DEFAULT_TILE), vec![]);
        // unfused twin and a small aligned tile audit clean too
        assert_eq!(audit_network_plan(&plan.without_patch_fusion(), DEFAULT_TILE), vec![]);
        assert_eq!(audit_network_plan(&plan, 8), vec![]);
        // unfused plans may run unaligned tiles: NCHW scatter needs no
        // block alignment, and the interval proof must still close
        assert_eq!(audit_network_plan(&plan.without_patch_fusion(), 5), vec![]);
    }

    #[test]
    fn overlapping_slot_live_ranges_are_caught() {
        let mut plan = compiled(1);
        // act 1 (residual source into layer 2) and act 2 are both live
        // across layer 1's write; forcing them into one slot must trip
        // the live-range check
        let s1 = plan.slot_of_act[1];
        plan.slot_of_act[2] = s1;
        let findings = audit_network_plan(&plan, DEFAULT_TILE);
        assert!(
            findings.iter().any(|f| matches!(
                f,
                AuditFinding::SlotLiveRangeOverlap { earlier: 1, later: 2, .. }
            )),
            "expected a live-range overlap, got {findings:?}"
        );
        // the same corruption also aliases layer 1's output with its
        // input slot — the arena_views precondition
        assert!(findings
            .iter()
            .any(|f| matches!(f, AuditFinding::OutputSlotAliased { layer: 1, .. })));
    }

    #[test]
    fn oversized_batch_prefix_is_caught() {
        let mut plan = compiled(4);
        // shrink one slot below its largest activation: some batch
        // prefix must overflow, and the audit names the smallest one
        let act = plan
            .act_buf_elems
            .iter()
            .enumerate()
            .max_by_key(|(_, &e)| e)
            .map(|(a, _)| a)
            .unwrap();
        let slot = plan.slot_of_act[act];
        plan.slot_elems[slot] = plan.act_buf_elems[act] / 2;
        let findings = audit_network_plan(&plan, DEFAULT_TILE);
        assert!(
            findings
                .iter()
                .any(|f| matches!(f, AuditFinding::BatchPrefixOverflow { .. })),
            "expected a batch-prefix overflow, got {findings:?}"
        );
    }

    #[test]
    fn dangling_slot_index_is_caught() {
        let mut plan = compiled(1);
        plan.slot_of_act[1] = plan.slot_elems.len() + 3;
        let findings = audit_network_plan(&plan, DEFAULT_TILE);
        assert!(findings
            .iter()
            .any(|f| matches!(f, AuditFinding::SlotIndexOutOfBounds { act: 1, .. })));
    }

    #[test]
    fn act_size_bookkeeping_is_cross_checked() {
        let mut plan = compiled(2);
        plan.act_elems[1] += 1;
        let findings = audit_network_plan(&plan, DEFAULT_TILE);
        assert!(findings
            .iter()
            .any(|f| matches!(f, AuditFinding::ActSizeMismatch { act: 1, what: "act_elems", .. })));
    }

    #[test]
    fn findings_are_deterministic() {
        let mut plan = compiled(2);
        plan.slot_of_act[2] = plan.slot_of_act[1];
        let a = audit_network_plan(&plan, DEFAULT_TILE);
        let b = audit_network_plan(&plan, DEFAULT_TILE);
        assert!(!a.is_empty());
        assert_eq!(a, b, "audit findings must be reproducible");
    }
}
