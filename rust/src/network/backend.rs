//! Engine-native serving backend: implements the coordinator's
//! [`InferBackend`] over a [`NetworkExecutor`], so the batcher / router /
//! server stack serves real repetition-engine traffic on plain CPU — no
//! `pjrt` feature, no artifacts.
//!
//! One [`NetworkPlan`] is compiled once and shared (`Arc`) across every
//! replica; each worker thread builds its own executor (its own
//! activation arena) via [`EngineBackend::factory`], mirroring the
//! one-backend-per-worker deployment shape of the PJRT path. The model
//! head is a global average pool over the final conv feature map —
//! `out_elems == K` of the last layer — which keeps the backend fully
//! determined by the conv descriptors the model zoo provides.

use std::cell::RefCell;
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::coordinator::InferBackend;
use crate::util::Pool;

use super::{NetworkExecutor, NetworkPlan};

/// [`InferBackend`] over the network executor. Deliberately not `Sync`
/// (the arena is single-threaded state); the coordinator constructs one
/// per worker thread, like every other backend.
pub struct EngineBackend {
    exec: RefCell<NetworkExecutor>,
    batch: usize,
    sample: usize,
    classes: usize,
    plane: usize,
}

impl EngineBackend {
    /// Backend over one compiled plan: allocates this replica's private
    /// activation arena.
    pub fn new(plan: Arc<NetworkPlan>) -> EngineBackend {
        let g = plan.out_geom();
        EngineBackend {
            batch: plan.batch(),
            sample: plan.sample_elems(),
            classes: g.k,
            plane: g.out_h() * g.out_w(),
            exec: RefCell::new(NetworkExecutor::new(plan)),
        }
    }

    /// Worker factory for `spawn_worker` / `Router::spawn`: every
    /// replica shares the compiled plan and owns a private activation
    /// arena. Re-callable (`Fn`) so the supervisor can rebuild a crashed
    /// replica's backend from the same plan.
    pub fn factory(
        plan: Arc<NetworkPlan>,
    ) -> impl Fn() -> Result<EngineBackend> + Send + Sync + 'static {
        move || Ok(EngineBackend::new(Arc::clone(&plan)))
    }
}

impl InferBackend for EngineBackend {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn sample_elems(&self) -> usize {
        self.sample
    }

    fn out_elems(&self) -> usize {
        self.classes
    }

    fn infer_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        ensure!(
            x.len() == self.batch * self.sample,
            "batch buffer {} != {} x {}",
            x.len(),
            self.batch,
            self.sample
        );
        self.infer_n(x, self.batch)
    }

    /// Batch-native override: an admitted batch of `n` live requests is
    /// ONE engine forward over exactly `n` images — no per-request
    /// loop, no zero-padding to the compiled batch. The plan compiles
    /// at the device batch (`batch_size()`), so any `n <= batch_size()`
    /// runs through a prefix of the same arena and, by the executor's
    /// batch bit-contract, yields logits identical to `n` single-image
    /// forwards.
    fn infer_n(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        ensure!(
            n >= 1 && n <= self.batch,
            "live batch {n} outside 1..={} (compiled batch)",
            self.batch
        );
        ensure!(x.len() == n * self.sample, "batch buffer {} != {n} x {}", x.len(), self.sample);
        let mut exec = self.exec.borrow_mut();
        let feat = exec.forward_batch_pool(x, n, Pool::global());
        // head: global average pool over the final feature planes
        let mut logits = vec![0.0f32; n * self.classes];
        let inv = 1.0 / self.plane as f32;
        for b in 0..n {
            for kf in 0..self.classes {
                let base = (b * self.classes + kf) * self.plane;
                let s: f32 = feat[base..base + self.plane].iter().sum();
                logits[b * self.classes + kf] = s * inv;
            }
        }
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::quant::Scheme;
    use crate::repetition::EngineConfig;

    fn tiny_plan(batch: usize) -> Arc<NetworkPlan> {
        let descs = models::cifar_resnet_layers(8, 0.5, 8, batch);
        let plan = NetworkPlan::compile(&descs, EngineConfig::default(), Scheme::sb_default());
        Arc::new(plan.unwrap())
    }

    #[test]
    fn backend_shapes_follow_the_plan() {
        let plan = tiny_plan(3);
        let be = EngineBackend::new(Arc::clone(&plan));
        assert_eq!(be.batch_size(), 3);
        assert_eq!(be.sample_elems(), 3 * 8 * 8);
        assert_eq!(be.out_elems(), plan.out_geom().k);
    }

    #[test]
    fn infer_batch_is_deterministic_and_per_sample_independent() {
        let plan = tiny_plan(2);
        let be = EngineBackend::new(Arc::clone(&plan));
        let sample = be.sample_elems();
        let mut rng = crate::util::Rng::new(50);
        let mut a = vec![0.0f32; sample];
        let mut b = vec![0.0f32; sample];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let mut batch_ab = a.clone();
        batch_ab.extend_from_slice(&b);
        let mut batch_a0 = a.clone();
        batch_a0.extend_from_slice(&vec![0.0; sample]);
        let la = be.infer_batch(&batch_ab).unwrap();
        let lb = be.infer_batch(&batch_a0).unwrap();
        let classes = be.out_elems();
        // sample 0's logits do not depend on what shares its batch
        assert!(la[..classes] == lb[..classes], "batch slots are not independent");
        // deterministic across repeated calls
        let lc = be.infer_batch(&batch_ab).unwrap();
        assert!(la == lc);
    }

    #[test]
    fn wrong_batch_len_errors() {
        let be = EngineBackend::new(tiny_plan(2));
        assert!(be.infer_batch(&[0.0; 3]).is_err());
    }

    #[test]
    fn infer_n_bit_matches_per_request_singles() {
        // the batch-native path must return, for every live slot, the
        // exact logits a lone single-sample call would
        let plan = tiny_plan(4);
        let be = EngineBackend::new(Arc::clone(&plan));
        let sample = be.sample_elems();
        let classes = be.out_elems();
        let mut rng = crate::util::Rng::new(51);
        let mut xs = vec![0.0f32; 3 * sample];
        rng.fill_normal(&mut xs, 1.0);
        let got = be.infer_n(&xs, 3).unwrap();
        assert_eq!(got.len(), 3 * classes);
        for i in 0..3 {
            let one = be.infer_n(&xs[i * sample..(i + 1) * sample], 1).unwrap();
            assert!(
                one[..] == got[i * classes..(i + 1) * classes],
                "slot {i} differs from its single-sample forward"
            );
        }
        // n beyond the compiled batch is a typed error, not a panic
        assert!(be.infer_n(&vec![0.0; 5 * sample], 5).is_err());
    }
}
