//! Network-level compilation & execution: whole models on the
//! repetition engine.
//!
//! Everything below `repetition::` executes one conv at a time; this
//! module is the co-design closure the paper argues for — the
//! repetition-sparsity trade-off is a *model-level* property, so the
//! engine should serve whole networks. Two pieces:
//!
//! * [`NetworkPlan::compile`] takes the model zoo's geometry descriptors
//!   (`models::ConvLayerDesc`), quantizes every quantized layer's
//!   weights under one [`Scheme`], and builds all per-layer
//!   [`LayerPlan`]s **once**, fanning layers over the persistent worker
//!   pool (each layer's sub-tile memoization then runs inline on its
//!   worker). Unquantized layers (the fp stem) compile to a transposed
//!   dense weight block executed by the same tile-fused machinery.
//!   Inter-layer wiring is explicit ([`LayerWiring`]: input activation,
//!   fused ReLU, residual source) and supports **branching**: a layer
//!   may read any earlier activation, so a residual edge can carry a
//!   1x1 *projection* conv (option-B / resnet18-style shortcuts) next
//!   to the option-A identity view. Compile also marks **fusable
//!   edges** for cross-layer patch reuse: when an activation's producer
//!   has an engine plan and every consumer is an engine layer, the
//!   producer scatters straight into pixel-major patch blocks and the
//!   consumers read them instead of NCHW — 1x1 / stride-1 / pad-0
//!   consumers in place, 3x3 and strided consumers through a per-tile
//!   blocked gather (SparseDNN's lesson: fuse the layout transform
//!   across layers instead of re-packing per layer). Residual-source
//!   activations and the network output stay NCHW — the fused
//!   `Residual` epilogue indexes its source NCHW in the hot scatter
//!   loop, and callers read logits NCHW.
//! * [`NetworkExecutor`] runs a full forward pass through
//!   `execute_conv2d_layout` using a preallocated **live-range-allocated
//!   activation arena**: compile assigns every activation a buffer slot
//!   by linear-scan over its live range, so plain chains use two
//!   buffers, residual topologies (identity or projection) three, and
//!   arbitrary branching wirings however many they truly need. No
//!   per-layer `Tensor` is allocated, per-worker scratch is
//!   thread-cached (`util::scratch`), and ReLU/residual-add are fused
//!   into each layer's output scatter — a steady-state forward pass
//!   performs no heap allocation of activations at all.
//!
//! Determinism contract: like the single-layer executor, the forward
//! pass is **bit-identical for every pool width** (fusion is
//! elementwise; tile partitioning depends only on tile size) *and*
//! bit-identical with patch fusion on or off (reuse changes where
//! values live, never the values or their accumulation order), asserted
//! end-to-end by `tests/integration_network.rs` and re-checked by
//! `plum bench network`. Batching joins the same contract:
//! [`NetworkExecutor::forward_batch`] over `b` images is bit-identical
//! to `b` independent single-image forwards (per-lane accumulation
//! never crosses an image), asserted by `tests/proptest_batch.rs` and
//! the `bench network` batch ladder.
//!
//! # Compile and execute a model
//!
//! ```
//! use plum::models::ConvLayerDesc;
//! use plum::network::{NetworkExecutor, NetworkPlan};
//! use plum::quant::Scheme;
//! use plum::repetition::EngineConfig;
//! use plum::tensor::Conv2dGeometry;
//! use std::sync::Arc;
//!
//! let g = Conv2dGeometry { n: 1, c: 3, h: 6, w: 6, k: 4, r: 3, s: 3, stride: 1, padding: 1 };
//! let descs = vec![ConvLayerDesc { name: "conv0".into(), geom: g, quantized: true }];
//! let plan = NetworkPlan::compile(&descs, EngineConfig::default(), Scheme::sb_default()).unwrap();
//! assert_eq!(plan.num_layers(), 1);
//!
//! let mut exec = NetworkExecutor::new(Arc::new(plan));
//! let input = vec![0.5f32; 3 * 6 * 6];
//! let out = exec.forward(&input);
//! assert_eq!(out.len(), 4 * 6 * 6);
//! ```

mod backend;

pub use backend::EngineBackend;

use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Result};

use crate::models::ConvLayerDesc;
use crate::quant::{quantize_pruned, QuantizedWeights, Scheme, SparsityPattern};
use crate::repetition::{
    execute_conv2d_layout_batch, option_a_stride, plan_layer_auto_pool, tile_supports_blocked_io,
    EngineConfig, LayerPlan, OpCounts, PostOp, Residual, TileIo, DEFAULT_TILE, PIXEL_BLOCK,
};
use crate::tensor::{im2col_rows_into, Conv2dGeometry, Tensor};
use crate::util::{Pool, Rng, ScratchVec, UnsafeSlice};

/// Weight seed for [`NetworkPlan::compile`] when the caller does not
/// provide one — the supp. G synthetic-latents methodology shared by the
/// figure harnesses.
pub const DEFAULT_WEIGHT_SEED: u64 = 0x9e37;

/// Deterministic per-layer gaussian latents (supp. G methodology):
/// layer `i` draws from an independent RNG stream, so one layer's
/// weights never depend on how many layers precede it.
pub fn seeded_latents(layers: &[ConvLayerDesc], seed: u64) -> Vec<Tensor> {
    layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let mut rng = Rng::new(seed).fork(i as u64 + 1);
            Tensor::rand_normal(&[l.geom.k, l.geom.c, l.geom.r, l.geom.s], 0.5, &mut rng)
        })
        .collect()
}

/// Wiring of one layer inside a [`NetworkPlan`]. Activation `0` is the
/// network input; activation `j` (for `j >= 1`) is the output of layer
/// `j - 1`; the network output is the last layer's activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerWiring {
    /// activation index this layer convolves — any already-computed
    /// activation, so residual edges can branch (`<=` the layer index)
    pub input: usize,
    /// apply ReLU in the fused epilogue (after the residual add)
    pub relu: bool,
    /// activation added into the output before ReLU: an option-A view
    /// (stride subsample + zero channel pad) of a raw activation, or —
    /// when it names a projection layer's output — an exact-shape add
    pub residual_from: Option<usize>,
}

impl LayerWiring {
    /// Plain chain step for layer `i`: read the previous activation,
    /// ReLU, no shortcut.
    pub fn chain(i: usize) -> LayerWiring {
        LayerWiring { input: i, relu: true, residual_from: None }
    }
}

/// Plain-chain wiring for `n` layers (ReLU everywhere, no shortcuts).
pub fn chain_wiring(n: usize) -> Vec<LayerWiring> {
    (0..n).map(LayerWiring::chain).collect()
}

/// One compiled layer of a [`NetworkPlan`].
#[derive(Debug, Clone)]
pub struct NetworkLayer {
    /// descriptor name (diagnostics)
    pub name: String,
    /// conv geometry of this layer
    pub geom: Conv2dGeometry,
    /// engine plan (quantized layers); `None` = dense fp fallback
    pub plan: Option<LayerPlan>,
    /// fp fallback weights, transposed to `[C*R*S, K]` at compile time
    dense_wt: Option<Vec<f32>>,
    /// the dense weights this layer executes (quantized values for
    /// engine layers, latents for fp layers) — reference checks/reports
    pub weights: Tensor,
    /// activation index this layer reads ([`LayerWiring::input`])
    pub input: usize,
    /// apply ReLU in the fused epilogue
    pub relu: bool,
    /// activation whose shortcut is added before ReLU (option-A view of
    /// a raw activation, or a projection layer's exact-shape output)
    pub residual_from: Option<usize>,
    /// consume the input as pre-transposed pixel-major patch blocks
    /// (cross-layer patch reuse; the producer scattered them)
    pub in_blocked: bool,
    /// scatter the output as pixel-major patch blocks for the next
    /// layer(s) instead of NCHW
    pub out_blocked: bool,
}

/// A whole model compiled onto the repetition engine: per-layer plans
/// built once, wiring, arena slots and fusable edges decided at compile
/// time.
#[derive(Debug, Clone)]
pub struct NetworkPlan {
    /// compiled layers, in execution order
    pub layers: Vec<NetworkLayer>,
    /// quantization scheme every quantized layer was compiled under
    pub scheme: Scheme,
    /// logical element count of activation `a[i]` (`a[0]` = input);
    /// crate-visible so the [`crate::analysis`] auditor can cross-check
    /// the recorded sizing against the shapes
    pub(crate) act_elems: Vec<usize>,
    /// arena bytes-worth of activation `a[i]`: equals `act_elems[i]`
    /// for NCHW activations, the PIXEL_BLOCK-padded block size for
    /// fused (blocked) activations
    pub(crate) act_buf_elems: Vec<usize>,
    /// `(c, h, w)` of activation `a[i]` (batch excluded)
    pub(crate) act_shape: Vec<(usize, usize, usize)>,
    /// arena slot of activation `a[i]` (live-range linear scan)
    pub(crate) slot_of_act: Vec<usize>,
    /// arena slot sizes (max buf elems over the slot's activations)
    pub(crate) slot_elems: Vec<usize>,
    /// §6 deployment footprint of all weights under `scheme`
    pub weight_bits: usize,
    /// structured-sparsity pattern the quantized layers were pruned
    /// with before planning ([`SparsityPattern::Unstructured`] = none)
    pub pattern: SparsityPattern,
    /// total weight parameters across every layer (fp stem included)
    pub total_params: usize,
    /// effectual (nonzero after quantization) weight parameters; fp
    /// layers count every parameter as effectual
    pub effectual_params: usize,
}

impl NetworkPlan {
    /// Compile with deterministic seeded latents ([`DEFAULT_WEIGHT_SEED`])
    /// on the process-wide pool.
    pub fn compile(
        layers: &[ConvLayerDesc],
        cfg: EngineConfig,
        scheme: Scheme,
    ) -> Result<NetworkPlan> {
        Self::compile_seeded(layers, cfg, scheme, DEFAULT_WEIGHT_SEED)
    }

    /// Compile with seeded latents drawn from `seed`.
    pub fn compile_seeded(
        layers: &[ConvLayerDesc],
        cfg: EngineConfig,
        scheme: Scheme,
        seed: u64,
    ) -> Result<NetworkPlan> {
        Self::compile_seeded_pruned(layers, cfg, scheme, SparsityPattern::Unstructured, seed)
    }

    /// Compile with seeded latents and a structured-sparsity `pattern`
    /// applied to every quantized layer before the alpha fit — the
    /// density knob of the repetition-sparsity trade-off sweep.
    pub fn compile_seeded_pruned(
        layers: &[ConvLayerDesc],
        cfg: EngineConfig,
        scheme: Scheme,
        pattern: SparsityPattern,
        seed: u64,
    ) -> Result<NetworkPlan> {
        let latents = seeded_latents(layers, seed);
        Self::compile_with_wiring_pruned(
            layers,
            &latents,
            &derive_wiring(layers)?,
            cfg,
            scheme,
            pattern,
            Pool::global(),
        )
    }

    /// Compile from explicit latent weights with derived wiring
    /// ([`derive_wiring`]): contiguous chains get [`resnet_wiring`]'s
    /// ReLU chain + option-A pair heuristic; lists carrying inline 1x1
    /// projection layers are parsed as resnet18-style blocks
    /// ([`resnet18_wiring`]). Custom topologies that happen to
    /// shape-match but must wire differently should use
    /// [`NetworkPlan::compile_with_wiring`] and pass their wiring
    /// explicitly.
    pub fn compile_with_weights(
        descs: &[ConvLayerDesc],
        latents: &[Tensor],
        cfg: EngineConfig,
        scheme: Scheme,
        pool: &Pool,
    ) -> Result<NetworkPlan> {
        Self::compile_with_wiring(descs, latents, &derive_wiring(descs)?, cfg, scheme, pool)
    }

    /// Core compile: quantize + plan every layer from explicit latent
    /// weights and explicit wiring (one [`LayerWiring`] per layer).
    /// Validates that every wired edge is geometrically sound (inputs
    /// chain from already-computed activations, residual sources are
    /// option-A-compatible with their consumer's output, every
    /// intermediate activation is consumed), assigns arena slots by
    /// live range, and marks fusable edges for cross-layer patch
    /// reuse. Layers are fanned over `pool`; `cfg.subtile == 0`
    /// auto-tunes the sub-tile size per layer (paper §6), a fixed value
    /// pins it.
    pub fn compile_with_wiring(
        descs: &[ConvLayerDesc],
        latents: &[Tensor],
        wiring: &[LayerWiring],
        cfg: EngineConfig,
        scheme: Scheme,
        pool: &Pool,
    ) -> Result<NetworkPlan> {
        Self::compile_with_wiring_pruned(
            descs,
            latents,
            wiring,
            cfg,
            scheme,
            SparsityPattern::Unstructured,
            pool,
        )
    }

    /// [`NetworkPlan::compile_with_wiring`] with a structured-sparsity
    /// `pattern` threaded into quantization: each quantized layer runs
    /// [`quantize_pruned`] so its smallest-magnitude latents are forced
    /// to zero before the scale fit, and the layer plans then elide
    /// those zeros entirely (when `cfg.sparsity_support` is on).
    #[allow(clippy::too_many_arguments)]
    pub fn compile_with_wiring_pruned(
        descs: &[ConvLayerDesc],
        latents: &[Tensor],
        wiring: &[LayerWiring],
        cfg: EngineConfig,
        scheme: Scheme,
        pattern: SparsityPattern,
        pool: &Pool,
    ) -> Result<NetworkPlan> {
        let n = descs.len();
        ensure!(n > 0, "cannot compile an empty network");
        ensure!(wiring.len() == n, "{} wiring entries for {n} layers", wiring.len());
        ensure!(latents.len() == n, "{} weight tensors for {n} layers", latents.len());
        if matches!(scheme, Scheme::Fp) {
            bail!("the repetition engine executes quantized networks — pick a non-fp scheme");
        }
        let batch = descs[0].geom.n;

        // ---- wiring + geometry validation over the activation graph ----
        // act_shape[j] is (c, h, w) of activation j; act 0 is defined by
        // layer 0's input geometry, act j+1 by layer j's output.
        let mut act_shape = Vec::with_capacity(n + 1);
        act_shape.push((descs[0].geom.c, descs[0].geom.h, descs[0].geom.w));
        for (li, d) in descs.iter().enumerate() {
            let g = d.geom;
            let w = wiring[li];
            ensure!(g.n == batch, "layer {li} batch {} != network batch {batch}", g.n);
            let ws = latents[li].shape();
            let want = [g.k, g.c, g.r, g.s];
            ensure!(ws == &want[..], "layer {li} weights {ws:?} do not match its geometry");
            ensure!(
                w.input <= li,
                "layer {li} reads activation {}, which is not computed yet",
                w.input
            );
            let (sc, sh, sw) = act_shape[w.input];
            ensure!(
                g.c == sc && g.h == sh && g.w == sw,
                "layer {li} ({}) input {}x{}x{} does not match activation {} ({sc}x{sh}x{sw})",
                d.name,
                g.c,
                g.h,
                g.w,
                w.input
            );
            if let Some(ai) = w.residual_from {
                ensure!(
                    ai <= li,
                    "layer {li} shortcut reads activation {ai}, which is not computed yet"
                );
                let (rc, rh, rw) = act_shape[ai];
                let (oh, ow) = (g.out_h(), g.out_w());
                ensure!(rh >= oh && rw >= ow, "layer {li} shortcut source smaller than output");
                // option-A soundness: one stride must map the source
                // plane onto the output on both axes. The subsample
                // covers the source rather than dividing it exactly, so
                // odd sizes (7 -> 4 at stride 2) are legitimate.
                let st = option_a_stride(rh, oh);
                ensure!(
                    (rh - 1) / st + 1 == oh && (rw - 1) / st + 1 == ow && rc <= g.k,
                    "layer {li} shortcut from activation {ai} ({rc}x{rh}x{rw}) is not an \
                     option-A view of its {}x{oh}x{ow} output",
                    g.k
                );
            }
            act_shape.push((g.k, g.out_h(), g.out_w()));
        }
        // every intermediate activation must feed something: a dead layer
        // output is a wiring bug, not a feature
        for j in 1..n {
            let consumed = wiring
                .iter()
                .any(|w| w.input == j || w.residual_from == Some(j));
            ensure!(
                consumed,
                "activation {j} (output of layer {}) is never consumed by any later layer",
                j - 1
            );
        }

        // ---- quantize + plan, one layer per pool job (a layer's own
        // sub-tile fan-out then runs inline on its worker) --------------
        let slots: Vec<Mutex<Option<NetworkLayer>>> = (0..n).map(|_| Mutex::new(None)).collect();
        pool.run(n, |li| {
            let d = &descs[li];
            let w = &latents[li];
            let (plan, dense_wt, weights) = if d.quantized {
                let q = quantize_pruned(w, scheme, None, pattern);
                let plan = if cfg.subtile == 0 {
                    plan_layer_auto_pool(&q, d.geom, cfg.sparsity_support, pool)
                } else {
                    LayerPlan::build_pool(&q, d.geom, cfg, pool)
                };
                (Some(plan), None, q.values)
            } else {
                // fp fallback: transpose OIHW -> [C*R*S, K] once here
                let e = d.geom.c * d.geom.r * d.geom.s;
                let k = d.geom.k;
                let mut wt = vec![0.0f32; e * k];
                for ki in 0..k {
                    for ei in 0..e {
                        wt[ei * k + ki] = w.data()[ki * e + ei];
                    }
                }
                (None, Some(wt), w.clone())
            };
            let wire = wiring[li];
            *slots[li].lock().unwrap() = Some(NetworkLayer {
                name: d.name.clone(),
                geom: d.geom,
                plan,
                dense_wt,
                weights,
                input: wire.input,
                relu: wire.relu,
                residual_from: wire.residual_from,
                in_blocked: false,
                out_blocked: false,
            });
        });
        let mut layers: Vec<NetworkLayer> = slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("every layer compiled by the pool run"))
            .collect();

        // ---- cross-layer patch reuse: mark fusable edges ---------------
        // Activation a can live as pixel-major patch blocks when its
        // producer has an engine plan and every consumer is an engine
        // layer: 1x1/stride-1/pad-0 consumers read the blocks in place
        // (they ARE that patch matrix), every other geometry gathers its
        // patch blocks out of the block layout per tile — either way the
        // NCHW round-trip disappears. Exclusions, and why:
        //   * the network output — callers read logits NCHW;
        //   * residual sources — the fused `Residual` epilogue indexes
        //     its source NCHW inside the per-element scatter; reading
        //     block layout there would put a div/mod on the hottest
        //     loop, so those activations deliberately stay NCHW;
        //   * fp consumers (the dense stem kernel is row-major).
        for a in 1..n {
            if layers[a - 1].plan.is_none() {
                continue;
            }
            if wiring.iter().any(|w| w.residual_from == Some(a)) {
                continue;
            }
            let consumers: Vec<usize> = (0..n).filter(|&j| wiring[j].input == a).collect();
            let all_fusable =
                !consumers.is_empty() && consumers.iter().all(|&j| layers[j].plan.is_some());
            if all_fusable {
                layers[a - 1].out_blocked = true;
                for &j in &consumers {
                    layers[j].in_blocked = true;
                }
            }
        }

        // ---- activation sizes + live-range arena slot assignment -------
        let act_elems: Vec<usize> = act_shape.iter().map(|&(c, h, w)| batch * c * h * w).collect();
        let mut act_buf_elems = act_elems.clone();
        for (li, l) in layers.iter().enumerate() {
            if l.out_blocked {
                let (c, h, w) = act_shape[li + 1];
                act_buf_elems[li + 1] = blocked_elems(batch * h * w, c);
            }
        }
        let slot_of_act = allocate_slots(n, wiring);
        let slot_elems = slot_sizes(&slot_of_act, &act_buf_elems);

        let weight_bits = descs.iter().map(|d| layer_weight_bits(d, scheme)).sum();
        let total_params: usize = layers.iter().map(|l| l.weights.len()).sum();
        let effectual_params: usize = layers
            .iter()
            .map(|l| {
                if l.plan.is_some() {
                    l.weights.count_nonzero()
                } else {
                    l.weights.len()
                }
            })
            .sum();
        let plan = NetworkPlan {
            layers,
            scheme,
            act_elems,
            act_buf_elems,
            act_shape,
            slot_of_act,
            slot_elems,
            weight_bits,
            pattern,
            total_params,
            effectual_params,
        };
        // Debug builds gate every compile behind the static soundness
        // audit (crate::analysis) — each `cargo test` run proves the
        // unsafe-code preconditions for every plan it compiles. Release
        // builds skip it; `plum audit` runs the same checks on demand.
        #[cfg(debug_assertions)]
        {
            let findings = crate::analysis::audit_network_plan(&plan, DEFAULT_TILE);
            assert!(findings.is_empty(), "compiled plan failed the soundness audit: {findings:?}");
        }
        Ok(plan)
    }

    /// Number of conv layers in the compiled network.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Network batch size (every layer shares it).
    pub fn batch(&self) -> usize {
        self.layers[0].geom.n
    }

    /// Elements of the network input activation (batch included).
    pub fn input_elems(&self) -> usize {
        self.act_elems[0]
    }

    /// Elements of the network output activation (batch included).
    pub fn output_elems(&self) -> usize {
        *self.act_elems.last().unwrap()
    }

    /// Input elements per sample (C*H*W).
    pub fn sample_elems(&self) -> usize {
        self.input_elems() / self.batch()
    }

    /// Geometry of the final conv (its `k`/`out_h`/`out_w` shape the
    /// network output `[n, k, oh, ow]`).
    pub fn out_geom(&self) -> Conv2dGeometry {
        self.layers.last().unwrap().geom
    }

    /// Largest activation the arena must hold.
    pub fn max_act_elems(&self) -> usize {
        *self.act_buf_elems.iter().max().unwrap()
    }

    /// Elements of activation `a[i]`.
    pub fn act_elems(&self, i: usize) -> usize {
        self.act_elems[i]
    }

    /// NCHW elements of activation `a[i]` at runtime batch `b`.
    pub(crate) fn act_elems_at(&self, i: usize, b: usize) -> usize {
        let (c, h, w) = self.act_shape[i];
        b * c * h * w
    }

    /// Arena elements activation `a[i]` occupies at runtime batch
    /// `b <= batch()`: NCHW activations shrink linearly with the batch,
    /// blocked activations re-pad the ragged `PIXEL_BLOCK` tail at
    /// `b * h * w` pixels. At `b == batch()` this equals the
    /// compile-time `act_buf_elems[i]`, so a full-batch forward is the
    /// degenerate case of the batched one.
    pub(crate) fn act_buf_elems_at(&self, i: usize, b: usize) -> usize {
        let (c, h, w) = self.act_shape[i];
        if i > 0 && self.layers[i - 1].out_blocked {
            blocked_elems(b * h * w, c)
        } else {
            b * c * h * w
        }
    }

    /// Activation-arena buffers the executor allocates (live-range
    /// assignment: 2 for plain chains, 3 for residual topologies).
    pub fn num_arena_slots(&self) -> usize {
        self.slot_elems.len()
    }

    /// Edges fused for cross-layer patch reuse (producers scattering
    /// pixel-major patch blocks instead of NCHW).
    pub fn patch_fused_edges(&self) -> usize {
        self.layers.iter().filter(|l| l.out_blocked).count()
    }

    /// A copy of this plan with cross-layer patch reuse disabled (every
    /// handoff through NCHW) — the executor then re-runs im2col per
    /// layer. Used by benchmarks and tests as the baseline the fused
    /// path must bit-match.
    pub fn without_patch_fusion(&self) -> NetworkPlan {
        let mut p = self.clone();
        for l in &mut p.layers {
            l.in_blocked = false;
            l.out_blocked = false;
        }
        p.act_buf_elems = p.act_elems.clone();
        p.slot_elems = slot_sizes(&p.slot_of_act, &p.act_buf_elems);
        p
    }

    /// Dense MACs of one full forward pass (arithmetic-reduction
    /// denominator, supp. G).
    pub fn dense_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.geom.dense_macs()).sum()
    }

    /// Accounted engine operations of one full forward pass; fp layers
    /// count their dense MACs as one add + one mul each.
    pub fn op_counts(&self) -> OpCounts {
        let mut total = OpCounts::default();
        for l in &self.layers {
            let c = match &l.plan {
                Some(p) => p.op_counts(),
                None => OpCounts { adds: l.geom.dense_macs(), muls: l.geom.dense_macs() },
            };
            total.adds += c.adds;
            total.muls += c.muls;
        }
        total
    }

    /// Whole-network effectual density: effectual / total parameters
    /// (1.0 when fully dense).
    pub fn effectual_density(&self) -> f64 {
        if self.total_params == 0 {
            return 1.0;
        }
        self.effectual_params as f64 / self.total_params as f64
    }

    /// Per-layer `(name, effectual, total)` parameter counts, in
    /// execution order. Engine layers report their plan's
    /// [`DensityStats`](crate::repetition::DensityStats); fp layers
    /// count every parameter as effectual.
    pub fn layer_densities(&self) -> Vec<(&str, usize, usize)> {
        self.layers
            .iter()
            .map(|l| {
                let total = l.weights.len();
                let eff = match &l.plan {
                    Some(p) => p.stats.effectual_cols as usize,
                    None => total,
                };
                (l.name.as_str(), eff, total)
            })
            .collect()
    }

    /// One-line density summary for compile banners: whole-network
    /// effectual fraction plus the per-layer density ladder.
    pub fn density_report(&self) -> String {
        let per_layer: Vec<String> = self
            .layer_densities()
            .iter()
            .map(|(_, eff, total)| {
                if *total == 0 {
                    "1.00".to_string()
                } else {
                    format!("{:.2}", *eff as f64 / *total as f64)
                }
            })
            .collect();
        format!(
            "effectual {}/{} params ({:.1}%), per-layer density [{}]",
            self.effectual_params,
            self.total_params,
            100.0 * self.effectual_density(),
            per_layer.join(" ")
        )
    }

    /// A copy of this plan whose engine layers are rebuilt through the
    /// unelided reference builder ([`LayerPlan::build_pool_unelided`]):
    /// zero runs materialized in the arena, all-zero patterns owning
    /// real spans. Sparsity-on execution never reads zero columns, so
    /// this twin's forward must bit-match the elided plan's — the
    /// density sweep and the engine proptests assert exactly that.
    pub fn without_elision(&self, pool: &Pool) -> NetworkPlan {
        let mut p = self.clone();
        for l in &mut p.layers {
            if let Some(lp) = &l.plan {
                let q = QuantizedWeights {
                    values: l.weights.clone(),
                    alpha: vec![],
                    beta: vec![],
                    scheme: self.scheme,
                };
                l.plan = Some(LayerPlan::build_pool_unelided(&q, lp.geom, lp.cfg, pool));
            }
        }
        p
    }
}

/// Elements a pixel-major blocked activation occupies: whole
/// `PIXEL_BLOCK`-wide lane blocks, ragged tail padded.
fn blocked_elems(pixels: usize, channels: usize) -> usize {
    pixels.div_ceil(PIXEL_BLOCK) * PIXEL_BLOCK * channels
}

/// Live-range linear scan: assign every activation an arena slot such
/// that no two simultaneously-live activations share one. Activation
/// `j` is live from the layer that produces it (`j - 1`; the network
/// input from before layer 0) through its last reader; the network
/// output is pinned past the final layer. Deterministic: always picks
/// the lowest free slot.
fn allocate_slots(n_layers: usize, wiring: &[LayerWiring]) -> Vec<usize> {
    let n_acts = n_layers + 1;
    let mut last_use = vec![0usize; n_acts];
    last_use[n_acts - 1] = n_layers;
    for (li, w) in wiring.iter().enumerate() {
        last_use[w.input] = last_use[w.input].max(li);
        if let Some(ai) = w.residual_from {
            last_use[ai] = last_use[ai].max(li);
        }
    }
    let mut slot_of_act = vec![0usize; n_acts];
    // slot_act[s] = activation currently occupying slot s
    let mut slot_act: Vec<usize> = vec![0];
    for li in 0..n_layers {
        let out_act = li + 1;
        // a slot is free for layer li's output when its occupant was
        // last read strictly before li (the write overlaps the reads)
        let slot = match (0..slot_act.len()).find(|&s| last_use[slot_act[s]] < li) {
            Some(s) => s,
            None => {
                slot_act.push(out_act);
                slot_act.len() - 1
            }
        };
        slot_act[slot] = out_act;
        slot_of_act[out_act] = slot;
    }
    slot_of_act
}

/// Per-slot buffer size: the largest activation buffer assigned to it.
fn slot_sizes(slot_of_act: &[usize], act_buf_elems: &[usize]) -> Vec<usize> {
    let num_slots = slot_of_act.iter().max().map(|m| m + 1).unwrap_or(0);
    let mut sizes = vec![0usize; num_slots];
    for (a, &s) in slot_of_act.iter().enumerate() {
        sizes[s] = sizes[s].max(act_buf_elems[a]);
    }
    sizes
}

/// §6 deployment bit accounting per layer: sb = 1-bit bitmap + one sign
/// bit per region; binary = 1 bit/weight; ternary = 2; fp layers 32.
fn layer_weight_bits(desc: &ConvLayerDesc, scheme: Scheme) -> usize {
    let wc = desc.geom.weight_count();
    if !desc.quantized {
        return 32 * wc;
    }
    match scheme {
        Scheme::Fp => 32 * wc,
        Scheme::Binary => wc,
        Scheme::Ternary { .. } => 2 * wc,
        Scheme::SignedBinary { regions_per_filter, .. } => wc + desc.geom.k * regions_per_filter,
    }
}

/// Default wiring derivation used by
/// [`NetworkPlan::compile_with_weights`]: descriptor lists that chain
/// contiguously (every layer's input is exactly the previous layer's
/// output shape) get [`resnet_wiring`]; lists broken by inline 1x1
/// branch layers are parsed as projection-shortcut blocks via
/// [`resnet18_wiring`]. Anything else (pooled trunks, arbitrary
/// branches) is an error — pass explicit wiring to
/// [`NetworkPlan::compile_with_wiring`] instead.
pub fn derive_wiring(descs: &[ConvLayerDesc]) -> Result<Vec<LayerWiring>> {
    ensure!(!descs.is_empty(), "cannot derive wiring for an empty network");
    let chains = (1..descs.len()).all(|i| {
        let (k, oh, ow) = descs[i - 1].out_shape();
        let g = descs[i].geom;
        g.c == k && g.h == oh && g.w == ow
    });
    if chains {
        Ok(resnet_wiring(descs))
    } else {
        resnet18_wiring(descs)
    }
}

/// Derive the default inter-layer wiring from a *contiguously chaining*
/// descriptor list: ReLU after every conv; when the list has the CIFAR
/// ResNet shape (stem + 2-conv blocks of spatial convs whose second
/// conv keeps channels and stride 1), each block's second conv gains an
/// option-A shortcut from the block input. 1x1 pairs never match —
/// chains of pointwise convs (the patch-reuse workloads) are chains,
/// not residual blocks. This is a *shape heuristic* — chains that
/// match it but are not residual networks should build their wiring by
/// hand and compile via [`NetworkPlan::compile_with_wiring`].
pub fn resnet_wiring(descs: &[ConvLayerDesc]) -> Vec<LayerWiring> {
    let n = descs.len();
    let mut wiring = chain_wiring(n);
    if n >= 3 && (n - 1) % 2 == 0 {
        let paired = (1..n).step_by(2).all(|i| {
            let a = descs[i].geom;
            let b = descs[i + 1].geom;
            b.c == a.k && b.k == a.k && b.stride == 1 && b.r == a.r && b.s == a.s && a.r > 1
        });
        if paired {
            for i in (1..n).step_by(2) {
                // activation i is the input of block conv i; it shortcuts
                // into the second conv's output
                wiring[i + 1].residual_from = Some(i);
            }
        }
    }
    wiring
}

/// Derive projection-shortcut (resnet18-style, option-B) wiring from a
/// descriptor list shaped `stem, block, block, ...` where each block is
/// either `[conv, conv]` (identity shortcut) or `[conv, proj 1x1,
/// conv]` — the 1x1 projection reading the *same* activation as the
/// block's first conv and riding the residual edge
/// (`models::cifar_resnet18_layers` emits this order). The projection
/// is linear (no ReLU of its own); the block's second conv adds the
/// projection output before its ReLU. Like [`resnet_wiring`] this is a
/// shape heuristic; lists that match it but mean something else must
/// pass explicit wiring to [`NetworkPlan::compile_with_wiring`].
pub fn resnet18_wiring(descs: &[ConvLayerDesc]) -> Result<Vec<LayerWiring>> {
    ensure!(!descs.is_empty(), "cannot wire an empty network");
    let mut wiring = vec![LayerWiring::chain(0)];
    let mut i = 1;
    while i < descs.len() {
        let a = descs[i].geom;
        let is_proj_block = i + 2 < descs.len() && {
            let p = descs[i + 1].geom;
            p.r == 1
                && p.s == 1
                && p.c == a.c
                && p.h == a.h
                && p.w == a.w
                && p.stride == a.stride
                && p.k == a.k
        };
        if is_proj_block {
            let (ak, ah, aw) = descs[i].out_shape();
            let b = descs[i + 2].geom;
            ensure!(
                b.c == ak && b.h == ah && b.w == aw && b.stride == 1,
                "layer {} does not chain from its block's first conv",
                i + 2
            );
            ensure!(
                descs[i + 1].out_shape() == descs[i + 2].out_shape(),
                "projection at layer {} does not match its block's output shape",
                i + 1
            );
            wiring.push(LayerWiring::chain(i));
            wiring.push(LayerWiring { input: i, relu: false, residual_from: None });
            wiring.push(LayerWiring { input: i + 1, relu: true, residual_from: Some(i + 2) });
            i += 3;
        } else if i + 1 < descs.len() && {
            let b = descs[i + 1].geom;
            let (ak, ah, aw) = descs[i].out_shape();
            // like resnet_wiring, 1x1 pairs are chains, never identity
            // residual blocks (a.r > 1 keeps patch-reuse chains plain)
            b.c == ak && b.h == ah && b.w == aw && b.k == ak && b.stride == 1 && a.r > 1
        } {
            wiring.push(LayerWiring::chain(i));
            wiring.push(LayerWiring { input: i + 1, relu: true, residual_from: Some(i) });
            i += 2;
        } else {
            bail!(
                "layer {i} ({}) does not start a recognizable residual block — pass explicit \
                 wiring via NetworkPlan::compile_with_wiring",
                descs[i].name
            );
        }
    }
    Ok(wiring)
}

/// Tile-fused dense conv for fp layers (the unquantized stem): per pixel
/// tile, im2col rows into thread-cached scratch, then a direct product
/// in ascending C*R*S order — the same accumulation order as
/// `conv2d_naive`, with the same fused [`PostOp`] epilogue as the engine
/// path. Per-pixel accumulation never crosses a tile, so N-thread output
/// is bit-identical to 1-thread.
fn dense_conv_into(
    g: Conv2dGeometry,
    wt: &[f32],
    x: &[f32],
    out: &mut [f32],
    pool: &Pool,
    tile: usize,
    post: PostOp<'_>,
) {
    let e = g.c * g.r * g.s;
    let (oh, ow) = (g.out_h(), g.out_w());
    let plane = oh * ow;
    let pixels = g.n * plane;
    assert_eq!(wt.len(), e * g.k, "transposed weights do not match geometry");
    assert_eq!(x.len(), g.n * g.c * g.h * g.w, "input does not match geometry");
    assert_eq!(out.len(), g.n * g.k * plane, "output buffer does not match geometry");
    post.validate(g.n, g.k, oh, ow);
    if pixels == 0 {
        return;
    }
    let od = UnsafeSlice::new(out);
    let jobs = pixels.div_ceil(tile);
    pool.run_with(
        jobs,
        || ScratchVec::take(tile * e),
        |patch, job| {
            let px0 = job * tile;
            let tp = tile.min(pixels - px0);
            im2col_rows_into(x, &g, px0, tp, patch);
            for row in 0..tp {
                let px = px0 + row;
                let ni = px / plane;
                let pix = px % plane;
                let prow = &patch[row * e..(row + 1) * e];
                for ki in 0..g.k {
                    let mut acc = 0.0f32;
                    for (ei, pv) in prow.iter().enumerate() {
                        acc += pv * wt[ei * g.k + ki];
                    }
                    let v = post.apply(acc, ni, ki, pix, ow);
                    // SAFETY: this job owns output pixels [px0, px0+tp),
                    // so (ni*K + ki)*plane + pix is written by no other
                    // job and stays < n*K*plane == out.len(). Proven
                    // statically per layer schedule by the NCHW
                    // write-interval check in analysis::audit_network_plan
                    // (WriteOverlap / WriteOutOfBounds findings).
                    unsafe { od.write((ni * g.k + ki) * plane + pix, v) };
                }
            }
        },
    );
}

/// Disjoint views of the arena slots a layer touches: mutable output,
/// shared input, optionally the shared residual source (which may alias
/// the input when a layer adds its own input — both are shared reads).
fn arena_views<'a>(
    bufs: &'a mut [Vec<f32>],
    out: usize,
    input: usize,
    res: Option<usize>,
) -> (&'a mut Vec<f32>, &'a Vec<f32>, Option<&'a Vec<f32>>) {
    debug_assert!(out != input && Some(out) != res, "output slot must be free");
    let mut ov = None;
    let mut xv = None;
    let mut hv = None;
    for (i, b) in bufs.iter_mut().enumerate() {
        if i == out {
            ov = Some(b);
        } else {
            let view: &Vec<f32> = b;
            if i == input {
                xv = Some(view);
            }
            if res == Some(i) {
                hv = Some(view);
            }
        }
    }
    (ov.expect("output slot"), xv.expect("input slot"), hv)
}

/// Runs full forward passes of one [`NetworkPlan`] through a reusable
/// live-range-allocated activation arena. Construct once per serving
/// replica; `forward` never allocates activations.
#[derive(Debug)]
pub struct NetworkExecutor {
    plan: Arc<NetworkPlan>,
    bufs: Vec<Vec<f32>>,
    tile: usize,
}

impl NetworkExecutor {
    /// Allocate the activation arena for `plan` (one buffer per compile-
    /// time slot, sized to the largest activation assigned to it).
    pub fn new(plan: Arc<NetworkPlan>) -> NetworkExecutor {
        let bufs = plan.slot_elems.iter().map(|&m| vec![0.0; m]).collect();
        NetworkExecutor { plan, bufs, tile: DEFAULT_TILE }
    }

    /// Like [`NetworkExecutor::new`] with a caller-chosen execution
    /// tile (output pixels per work item; the default is
    /// `repetition::DEFAULT_TILE`).
    ///
    /// Documented constraint, checked **up front**: when the plan
    /// carries patch-fused edges, every tile must start on a
    /// `PIXEL_BLOCK` boundary (blocked patch I/O is defined on whole
    /// lane blocks), so `tile` must be a multiple of `PIXEL_BLOCK`.
    /// Failing here beats the same condition asserting deep inside
    /// `execute_conv2d_layout` mid-forward. Unfused plans accept any
    /// positive tile.
    pub fn with_tile(plan: Arc<NetworkPlan>, tile: usize) -> Result<NetworkExecutor> {
        ensure!(tile > 0, "execution tile must be positive");
        if plan.patch_fused_edges() > 0 && !tile_supports_blocked_io(tile) {
            bail!(
                "this plan has {} patch-fused edge(s): the execution tile must be a multiple \
                 of PIXEL_BLOCK ({PIXEL_BLOCK}), got {tile} — pick an aligned tile or compile \
                 with without_patch_fusion()",
                plan.patch_fused_edges()
            );
        }
        let mut exec = NetworkExecutor::new(plan);
        exec.tile = tile;
        Ok(exec)
    }

    /// The compiled plan this executor runs.
    pub fn plan(&self) -> &NetworkPlan {
        &self.plan
    }

    /// Full forward pass on the process-wide pool. Returns the final
    /// activation `[n, k, oh, ow]`, borrowed from the arena.
    pub fn forward(&mut self, input: &[f32]) -> &[f32] {
        self.forward_pool(input, Pool::global())
    }

    /// Full forward pass on an explicit pool (benchmarks pin widths).
    pub fn forward_pool(&mut self, input: &[f32], pool: &Pool) -> &[f32] {
        let b = self.plan.batch();
        self.forward_batch_pool(input, b, pool)
    }

    /// Forward the first `b` images of a batch on the process-wide
    /// pool — see [`NetworkExecutor::forward_batch_pool`].
    pub fn forward_batch(&mut self, input: &[f32], b: usize) -> &[f32] {
        self.forward_batch_pool(input, b, Pool::global())
    }

    /// Forward a runtime batch of `b <= plan.batch()` images
    /// (`input.len() == b * sample_elems()`, batch-major NCHW) on an
    /// explicit pool. Per-layer plans are batch-agnostic — a
    /// `LayerPlan` depends on the quantized weights and the geometry
    /// *shape*, never on `geom.n` — so the executor overrides every
    /// layer's batch at dispatch and a partial batch just uses a prefix
    /// of each compile-time arena slot (blocked activations re-pad
    /// their ragged `PIXEL_BLOCK` tail at `b * oh * ow` pixels).
    ///
    /// Bit-contract: the returned `[b, k, oh, ow]` activation is
    /// bitwise-identical to concatenating `b` independent single-image
    /// forwards through the same plan — at every pool width, with patch
    /// fusion on or off, and with sparsity elision on or off (per-lane
    /// f32 accumulation never crosses an image). `tests/
    /// proptest_batch.rs` and the `bench network` batch ladder enforce
    /// exactly this.
    pub fn forward_batch_pool(&mut self, input: &[f32], b: usize, pool: &Pool) -> &[f32] {
        let plan = Arc::clone(&self.plan);
        assert!(b >= 1, "runtime batch must be positive");
        assert!(
            b <= plan.batch(),
            "runtime batch {b} exceeds compiled batch {} — compile the plan at the largest \
             batch it must serve",
            plan.batch()
        );
        assert_eq!(
            input.len(),
            b * plan.sample_elems(),
            "input does not match network geometry at batch {b}"
        );
        self.bufs[plan.slot_of_act[0]][..input.len()].copy_from_slice(input);
        for (li, layer) in plan.layers.iter().enumerate() {
            let in_slot = plan.slot_of_act[layer.input];
            let out_slot = plan.slot_of_act[li + 1];
            let res_slot = layer.residual_from.map(|ai| plan.slot_of_act[ai]);
            let in_len = plan.act_buf_elems_at(layer.input, b);
            let out_len = plan.act_buf_elems_at(li + 1, b);
            let (ov, xv, hv) = arena_views(&mut self.bufs, out_slot, in_slot, res_slot);
            let residual = layer.residual_from.map(|ai| {
                let (sc, sh, sw) = plan.act_shape[ai];
                let st = option_a_stride(sh, layer.geom.out_h());
                Residual {
                    src: &hv.expect("residual slot view")[..plan.act_elems_at(ai, b)],
                    c: sc,
                    h: sh,
                    w: sw,
                    stride: st,
                }
            });
            let post = PostOp { relu: layer.relu, residual };
            match &layer.plan {
                Some(lp) => execute_conv2d_layout_batch(
                    lp,
                    b,
                    &xv[..in_len],
                    &mut ov[..out_len],
                    pool,
                    self.tile,
                    post,
                    TileIo {
                        input_blocked: layer.in_blocked,
                        output_blocked: layer.out_blocked,
                    },
                ),
                None => {
                    debug_assert!(
                        !layer.in_blocked && !layer.out_blocked,
                        "fp layers never fuse patch layouts"
                    );
                    dense_conv_into(
                        Conv2dGeometry { n: b, ..layer.geom },
                        layer.dense_wt.as_ref().expect("fp layer keeps dense weights"),
                        &xv[..in_len],
                        &mut ov[..out_len],
                        pool,
                        self.tile,
                        post,
                    )
                }
            }
        }
        let out_slot = plan.slot_of_act[plan.num_layers()];
        &self.bufs[out_slot][..plan.act_elems_at(plan.num_layers(), b)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::quant::quantize;
    use crate::repetition::{execute_conv2d_pool, plan_layer};

    fn sb() -> Scheme {
        Scheme::sb_default()
    }

    /// Option-A reference add over raw slices (stride subsample + zero
    /// channel pad), matching `PostOp::apply`'s index math.
    #[allow(clippy::too_many_arguments)]
    fn add_option_a(
        out: &mut [f32],
        src: &[f32],
        n: usize,
        k: usize,
        oh: usize,
        ow: usize,
        sc: usize,
        sh: usize,
        sw: usize,
    ) {
        let st = option_a_stride(sh, oh);
        for ni in 0..n {
            for ci in 0..sc.min(k) {
                for oy in 0..oh {
                    for ox in 0..ow {
                        out[((ni * k + ci) * oh + oy) * ow + ox] +=
                            src[((ni * sc + ci) * sh + oy * st) * sw + ox * st];
                    }
                }
            }
        }
    }

    #[test]
    fn resnet8_wiring_and_layer_kinds() {
        let descs = models::cifar_resnet_layers(8, 0.5, 16, 1);
        let plan = NetworkPlan::compile(&descs, EngineConfig::default(), sb()).unwrap();
        assert_eq!(plan.num_layers(), 7);
        // fp stem executes dense; every block conv has an engine plan
        assert!(plan.layers[0].plan.is_none());
        assert!(plan.layers[1..].iter().all(|l| l.plan.is_some()));
        // every layer chains from the previous activation
        assert!(plan.layers.iter().enumerate().all(|(i, l)| l.input == i));
        // option-A shortcut on each block's second conv, from block input
        assert_eq!(plan.layers[2].residual_from, Some(1));
        assert_eq!(plan.layers[4].residual_from, Some(3));
        assert_eq!(plan.layers[6].residual_from, Some(5));
        assert!(plan.layers.iter().all(|l| l.relu));
        // residual topology -> three arena slots; every block-internal
        // edge (conv1 -> conv2, 3 blocks) fuses via the blocked gather,
        // while block inputs (residual sources) and the output stay NCHW
        assert_eq!(plan.num_arena_slots(), 3);
        assert_eq!(plan.patch_fused_edges(), 3);
        // arena must fit the widest activation
        assert!(plan.max_act_elems() >= plan.input_elems());
        assert!(plan.op_counts().total() > 0);
        assert!(plan.weight_bits > 0);
    }

    #[test]
    fn pooled_topologies_are_rejected() {
        let descs = models::vgg_small_layers(0.5, 32, 1);
        let err = NetworkPlan::compile(&descs, EngineConfig::default(), sb());
        assert!(err.is_err(), "pooling gaps must not compile");
    }

    #[test]
    fn fp_scheme_is_rejected() {
        let descs = models::cifar_resnet_layers(8, 0.5, 16, 1);
        assert!(NetworkPlan::compile(&descs, EngineConfig::default(), Scheme::Fp).is_err());
    }

    #[test]
    fn plain_chain_matches_layer_by_layer_engine() {
        // two quantized convs, no residual pattern: forward must
        // bit-match unfused per-layer execution + ReLU
        let g1 = Conv2dGeometry { n: 2, c: 3, h: 8, w: 8, k: 4, r: 3, s: 3, stride: 1, padding: 1 };
        let g2 = Conv2dGeometry { n: 2, c: 4, h: 8, w: 8, k: 6, r: 3, s: 3, stride: 1, padding: 1 };
        let descs = vec![
            ConvLayerDesc { name: "a".into(), geom: g1, quantized: true },
            ConvLayerDesc { name: "b".into(), geom: g2, quantized: true },
        ];
        let latents = seeded_latents(&descs, 7);
        let cfg = EngineConfig::default();
        let pool = Pool::new(2);
        let plan = NetworkPlan::compile_with_weights(&descs, &latents, cfg, sb(), &pool).unwrap();
        let plan = Arc::new(plan);
        assert!(plan.layers.iter().all(|l| l.residual_from.is_none()));
        // the inner 3x3 edge fuses (blocked gather); plain chain -> two
        // slots either way
        assert_eq!(plan.patch_fused_edges(), 1);
        assert_eq!(plan.num_arena_slots(), 2);

        let mut rng = Rng::new(41);
        let x = Tensor::rand_normal(&[2, 3, 8, 8], 1.0, &mut rng);
        let mut exec = NetworkExecutor::new(Arc::clone(&plan));
        let out = exec.forward_pool(x.data(), &pool).to_vec();

        let q1 = quantize(&latents[0], sb(), None);
        let q2 = quantize(&latents[1], sb(), None);
        let mut y1 = execute_conv2d_pool(&plan_layer(&q1, g1, cfg), &x, &pool);
        y1.data_mut().iter_mut().for_each(|v| *v = v.max(0.0));
        let mut y2 = execute_conv2d_pool(&plan_layer(&q2, g2, cfg), &y1, &pool);
        y2.data_mut().iter_mut().for_each(|v| *v = v.max(0.0));
        assert!(out == y2.data(), "network forward differs from layer-by-layer reference");
    }

    #[test]
    fn explicit_wiring_overrides_the_resnet_heuristic() {
        let g1 = Conv2dGeometry { n: 1, c: 3, h: 6, w: 6, k: 4, r: 3, s: 3, stride: 1, padding: 1 };
        let g2 = Conv2dGeometry { n: 1, c: 4, h: 6, w: 6, k: 4, r: 3, s: 3, stride: 1, padding: 1 };
        let descs = vec![
            ConvLayerDesc { name: "a".into(), geom: g1, quantized: true },
            ConvLayerDesc { name: "b".into(), geom: g2, quantized: true },
            ConvLayerDesc { name: "c".into(), geom: g2, quantized: true },
        ];
        let latents = seeded_latents(&descs, 9);
        let pool = Pool::new(1);
        let cfg = EngineConfig::default();
        // the heuristic wires a shortcut into this pair-matching 3-chain
        let auto = NetworkPlan::compile_with_weights(&descs, &latents, cfg, sb(), &pool).unwrap();
        assert_eq!(auto.layers[2].residual_from, Some(1));
        // explicit all-None wiring keeps it a plain chain
        let plain = chain_wiring(3);
        let p = NetworkPlan::compile_with_wiring(&descs, &latents, &plain, cfg, sb(), &pool);
        assert!(p.unwrap().layers.iter().all(|l| l.residual_from.is_none()));
        // future-activation shortcuts are rejected
        let mut bad = chain_wiring(3);
        bad[1].residual_from = Some(2);
        let err = NetworkPlan::compile_with_wiring(&descs, &latents, &bad, cfg, sb(), &pool);
        assert!(err.is_err());
    }

    #[test]
    fn overlapping_shortcuts_run_on_the_live_range_arena() {
        // two overlapping residual edges (a[0] -> layer 1, a[1] ->
        // layer 2) — the old single-pin ping-pong rejected this shape;
        // the live-range arena executes it and must match a
        // layer-by-layer reference bit for bit
        let g1 = Conv2dGeometry { n: 1, c: 3, h: 6, w: 6, k: 4, r: 3, s: 3, stride: 1, padding: 1 };
        let g2 = Conv2dGeometry { n: 1, c: 4, h: 6, w: 6, k: 4, r: 3, s: 3, stride: 1, padding: 1 };
        let descs = vec![
            ConvLayerDesc { name: "a".into(), geom: g1, quantized: true },
            ConvLayerDesc { name: "b".into(), geom: g2, quantized: true },
            ConvLayerDesc { name: "c".into(), geom: g2, quantized: true },
        ];
        let latents = seeded_latents(&descs, 11);
        let pool = Pool::new(2);
        let cfg = EngineConfig::default();
        let mut wiring = chain_wiring(3);
        wiring[1].residual_from = Some(0);
        wiring[2].residual_from = Some(1);
        let plan = Arc::new(
            NetworkPlan::compile_with_wiring(&descs, &latents, &wiring, cfg, sb(), &pool).unwrap(),
        );

        let mut rng = Rng::new(43);
        let x = Tensor::rand_normal(&[1, 3, 6, 6], 1.0, &mut rng);
        let mut exec = NetworkExecutor::new(Arc::clone(&plan));
        let out = exec.forward_pool(x.data(), &pool).to_vec();

        // layer-by-layer reference with separate residual/ReLU passes
        let qs: Vec<_> = latents.iter().map(|w| quantize(w, sb(), None)).collect();
        let y1r = execute_conv2d_pool(&plan_layer(&qs[0], g1, cfg), &x, &pool);
        let mut y1 = y1r.data().to_vec();
        y1.iter_mut().for_each(|v| *v = v.max(0.0));
        let y1t = Tensor::new(&[1, 4, 6, 6], y1.clone());
        let y2r = execute_conv2d_pool(&plan_layer(&qs[1], g2, cfg), &y1t, &pool);
        let mut y2 = y2r.data().to_vec();
        add_option_a(&mut y2, x.data(), 1, 4, 6, 6, 3, 6, 6);
        y2.iter_mut().for_each(|v| *v = v.max(0.0));
        let y2t = Tensor::new(&[1, 4, 6, 6], y2);
        let y3r = execute_conv2d_pool(&plan_layer(&qs[2], g2, cfg), &y2t, &pool);
        let mut y3 = y3r.data().to_vec();
        add_option_a(&mut y3, &y1, 1, 4, 6, 6, 4, 6, 6);
        y3.iter_mut().for_each(|v| *v = v.max(0.0));
        assert!(out == y3, "overlapping shortcuts differ from the reference");
    }

    #[test]
    fn dead_layer_outputs_are_rejected() {
        let g = Conv2dGeometry { n: 1, c: 3, h: 6, w: 6, k: 3, r: 3, s: 3, stride: 1, padding: 1 };
        let descs = vec![
            ConvLayerDesc { name: "a".into(), geom: g, quantized: true },
            ConvLayerDesc { name: "b".into(), geom: g, quantized: true },
        ];
        let latents = seeded_latents(&descs, 13);
        let pool = Pool::new(1);
        // layer 1 re-reads the network input, so layer 0's output dies
        let wiring = vec![LayerWiring::chain(0), LayerWiring::chain(0)];
        let err = NetworkPlan::compile_with_wiring(
            &descs,
            &latents,
            &wiring,
            EngineConfig::default(),
            sb(),
            &pool,
        );
        assert!(err.is_err(), "dead intermediate activations must not compile");
    }

    #[test]
    fn patch_fusion_edge_decision() {
        // 3x3 -> 1x1 -> 1x1 -> 3x3 chain: EVERY inter-layer edge fuses
        // (the 1x1s read blocks in place, the final 3x3 gathers from
        // them); only the network output stays NCHW
        let g0 = Conv2dGeometry { n: 1, c: 3, h: 8, w: 8, k: 8, r: 3, s: 3, stride: 1, padding: 1 };
        let p1 = Conv2dGeometry { n: 1, c: 8, h: 8, w: 8, k: 8, r: 1, s: 1, stride: 1, padding: 0 };
        let g3 = Conv2dGeometry { n: 1, c: 8, h: 8, w: 8, k: 6, r: 3, s: 3, stride: 1, padding: 1 };
        let descs = vec![
            ConvLayerDesc { name: "a".into(), geom: g0, quantized: true },
            ConvLayerDesc { name: "b".into(), geom: p1, quantized: true },
            ConvLayerDesc { name: "c".into(), geom: p1, quantized: true },
            ConvLayerDesc { name: "d".into(), geom: g3, quantized: true },
        ];
        let latents = seeded_latents(&descs, 15);
        let pool = Pool::new(1);
        let cfg = EngineConfig::default();
        let plan = NetworkPlan::compile_with_weights(&descs, &latents, cfg, sb(), &pool).unwrap();
        assert!(plan.layers[0].out_blocked && !plan.layers[0].in_blocked);
        assert!(plan.layers[1].in_blocked && plan.layers[1].out_blocked);
        assert!(plan.layers[2].in_blocked && plan.layers[2].out_blocked);
        assert!(plan.layers[3].in_blocked && !plan.layers[3].out_blocked);
        assert_eq!(plan.patch_fused_edges(), 3);

        // a consumer whose input also feeds a residual edge must NOT
        // fuse (the fused Residual epilogue reads its source NCHW)
        let mut wiring = chain_wiring(4);
        wiring[2].residual_from = Some(1); // a[1] read as residual by layer 2
        let plan =
            NetworkPlan::compile_with_wiring(&descs, &latents, &wiring, cfg, sb(), &pool).unwrap();
        assert!(!plan.layers[0].out_blocked && !plan.layers[1].in_blocked);
        // the 1x1 -> 1x1 and 1x1 -> 3x3 edges still fuse
        assert!(plan.layers[1].out_blocked && plan.layers[2].in_blocked);
        assert!(plan.layers[2].out_blocked && plan.layers[3].in_blocked);
        assert_eq!(plan.patch_fused_edges(), 2);

        // an fp producer never fuses, even into a 1x1 consumer
        let descs_fp = vec![
            ConvLayerDesc { name: "a".into(), geom: g0, quantized: false },
            ConvLayerDesc { name: "b".into(), geom: p1, quantized: true },
        ];
        let latents_fp = seeded_latents(&descs_fp, 17);
        let plan =
            NetworkPlan::compile_with_weights(&descs_fp, &latents_fp, cfg, sb(), &pool).unwrap();
        assert_eq!(plan.patch_fused_edges(), 0);

        // strided 1x1 and downstream 3x3 consumers fuse too now: the
        // blocked gather subsamples / re-windows the producer's blocks
        let p2 = Conv2dGeometry { n: 1, c: 8, h: 8, w: 8, k: 8, r: 1, s: 1, stride: 2, padding: 0 };
        let g4 = Conv2dGeometry { n: 1, c: 8, h: 4, w: 4, k: 6, r: 3, s: 3, stride: 1, padding: 1 };
        let descs_st = vec![
            ConvLayerDesc { name: "a".into(), geom: g0, quantized: true },
            ConvLayerDesc { name: "b".into(), geom: p2, quantized: true },
            ConvLayerDesc { name: "c".into(), geom: g4, quantized: true },
        ];
        let latents_st = seeded_latents(&descs_st, 19);
        let plan =
            NetworkPlan::compile_with_weights(&descs_st, &latents_st, cfg, sb(), &pool).unwrap();
        assert!(plan.layers[1].in_blocked, "strided 1x1 consumers fuse via the gather");
        assert!(plan.layers[2].in_blocked, "3x3 consumers fuse via the gather");
        assert_eq!(plan.patch_fused_edges(), 2);
    }

    #[test]
    fn patch_fused_forward_bit_matches_unfused() {
        let g0 = Conv2dGeometry { n: 2, c: 3, h: 7, w: 7, k: 8, r: 3, s: 3, stride: 1, padding: 1 };
        let p1 = Conv2dGeometry { n: 2, c: 8, h: 7, w: 7, k: 8, r: 1, s: 1, stride: 1, padding: 0 };
        let g3 = Conv2dGeometry { n: 2, c: 8, h: 7, w: 7, k: 5, r: 3, s: 3, stride: 1, padding: 1 };
        let descs = vec![
            ConvLayerDesc { name: "a".into(), geom: g0, quantized: true },
            ConvLayerDesc { name: "b".into(), geom: p1, quantized: true },
            ConvLayerDesc { name: "c".into(), geom: p1, quantized: true },
            ConvLayerDesc { name: "d".into(), geom: g3, quantized: true },
        ];
        let latents = seeded_latents(&descs, 21);
        let cfg = EngineConfig::default();
        let pool1 = Pool::new(1);
        let fused = Arc::new(
            NetworkPlan::compile_with_weights(&descs, &latents, cfg, sb(), &pool1).unwrap(),
        );
        assert_eq!(fused.patch_fused_edges(), 3);
        let unfused = Arc::new(fused.without_patch_fusion());
        assert_eq!(unfused.patch_fused_edges(), 0);
        assert!(unfused.layers.iter().all(|l| !l.in_blocked && !l.out_blocked));

        let mut rng = Rng::new(45);
        let mut input = vec![0.0f32; fused.input_elems()];
        rng.fill_normal(&mut input, 1.0);
        let base = {
            let mut exec = NetworkExecutor::new(Arc::clone(&unfused));
            exec.forward_pool(&input, &pool1).to_vec()
        };
        for threads in [1, 2] {
            let pool = Pool::new(threads);
            let mut exec = NetworkExecutor::new(Arc::clone(&fused));
            let out = exec.forward_pool(&input, &pool);
            assert!(out == base, "{threads}-thread fused forward differs from unfused");
        }
    }

    #[test]
    fn resnet18c_wiring_and_projection_layers() {
        let descs = models::cifar_resnet18_layers(0.5, 16, 1);
        let wiring = derive_wiring(&descs).unwrap();
        let plan = NetworkPlan::compile(&descs, EngineConfig::default(), sb()).unwrap();
        // projection layers: 1x1, linear, branching from the block input
        let projs: Vec<usize> = descs
            .iter()
            .enumerate()
            .filter(|(_, d)| d.geom.r == 1)
            .map(|(i, _)| i)
            .collect();
        assert!(!projs.is_empty(), "resnet18c must carry projection shortcuts");
        for &p in &projs {
            assert!(!wiring[p].relu, "projections are linear");
            assert_eq!(wiring[p].input, wiring[p - 1].input, "projection branches");
            assert_eq!(
                wiring[p + 1].residual_from,
                Some(p + 1),
                "the block's second conv adds the projection output"
            );
            assert!(plan.layers[p].plan.is_some(), "projections are quantized");
        }
        // branching residual topology still fits three arena buffers
        assert_eq!(plan.num_arena_slots(), 3);
        // generalized reuse: every block-internal conv1 -> conv2 edge (8
        // blocks) fuses, and each projection block's input feeds only
        // engine consumers (conv1 + proj) so it fuses too (3 stage
        // boundaries); identity-block inputs are residual sources and
        // the stem/output stay NCHW
        assert_eq!(plan.patch_fused_edges(), 8 + 3);
    }

    /// resnet20 must report fused edges too (the acceptance gate for the
    /// generalized predicate): every block-internal edge, one per block.
    #[test]
    fn resnet20_reports_fused_edges() {
        let descs = models::cifar_resnet_layers(20, 1.0, 32, 1);
        let plan = NetworkPlan::compile(&descs, EngineConfig::default(), sb()).unwrap();
        assert_eq!(plan.patch_fused_edges(), 9);
    }

    /// Satellite regression: resnet-style models over ODD spatial sizes
    /// (image 7 -> stride-2 stages produce 4 and 2) used to fail twice —
    /// compile rejected the shortcut as "not an option-A view" and
    /// `PostOp::validate` panicked on `res.h != oh * stride`. They must
    /// compile through `compile_with_wiring` and run, fused and unfused,
    /// bit-identically.
    #[test]
    fn odd_size_resnet_compiles_and_runs() {
        let descs = models::cifar_resnet_layers(8, 1.0, 7, 2);
        let latents = seeded_latents(&descs, 23);
        let pool = Pool::new(2);
        let cfg = EngineConfig::default();
        let wiring = resnet_wiring(&descs);
        assert!(
            wiring.iter().any(|w| w.residual_from.is_some()),
            "the odd-size model must still carry option-A shortcuts"
        );
        let plan = Arc::new(
            NetworkPlan::compile_with_wiring(&descs, &latents, &wiring, cfg, sb(), &pool)
                .unwrap(),
        );
        // stage 2 input is 7x7, its strided conv outputs 4x4: 4*2 != 7
        assert!(plan.layers.iter().any(|l| l.geom.h == 7 && l.geom.stride == 2));
        let mut rng = Rng::new(47);
        let mut input = vec![0.0f32; plan.input_elems()];
        rng.fill_normal(&mut input, 1.0);
        let base = {
            let unfused = Arc::new(plan.without_patch_fusion());
            let mut exec = NetworkExecutor::new(unfused);
            exec.forward_pool(&input, &pool).to_vec()
        };
        assert!(base.iter().all(|v| v.is_finite()));
        for threads in [1, 2] {
            let p = Pool::new(threads);
            let mut exec = NetworkExecutor::new(Arc::clone(&plan));
            let out = exec.forward_pool(&input, &p);
            assert!(out == base, "{threads}-thread odd-size fused forward differs");
        }
    }

    #[test]
    fn with_tile_checks_blocked_alignment_up_front() {
        let descs = models::conv1x1_chain_layers(4, 8, 8, 1);
        let plan = Arc::new(NetworkPlan::compile(&descs, EngineConfig::default(), sb()).unwrap());
        assert!(plan.patch_fused_edges() > 0);
        // misaligned tile on a fused plan: early error, not a deep panic
        let err = NetworkExecutor::with_tile(Arc::clone(&plan), 12);
        assert!(err.is_err(), "misaligned tile must be rejected at construction");
        assert!(NetworkExecutor::with_tile(Arc::clone(&plan), 16).is_ok());
        assert!(NetworkExecutor::with_tile(Arc::clone(&plan), 0).is_err());
        // the fusion-disabled twin accepts any positive tile
        let unfused = Arc::new(plan.without_patch_fusion());
        let mut a = NetworkExecutor::with_tile(Arc::clone(&unfused), 12).unwrap();
        let mut b = NetworkExecutor::new(unfused);
        let input = vec![0.25f32; plan.input_elems()];
        let pool = Pool::new(1);
        let oa = a.forward_pool(&input, &pool).to_vec();
        assert!(oa == b.forward_pool(&input, &pool), "tile choice must not change bits");
    }

    #[test]
    fn forward_reuses_the_arena_and_is_deterministic() {
        let descs = models::cifar_resnet_layers(8, 0.5, 8, 1);
        let plan = Arc::new(NetworkPlan::compile(&descs, EngineConfig::default(), sb()).unwrap());
        let pool = Pool::new(2);
        let mut exec = NetworkExecutor::new(Arc::clone(&plan));
        let mut rng = Rng::new(42);
        let mut input = vec![0.0f32; plan.input_elems()];
        rng.fill_normal(&mut input, 1.0);
        let (p1, o1) = {
            let o = exec.forward_pool(&input, &pool);
            (o.as_ptr(), o.to_vec())
        };
        let (p2, o2) = {
            let o = exec.forward_pool(&input, &pool);
            (o.as_ptr(), o.to_vec())
        };
        assert_eq!(p1, p2, "second forward must land in the same arena slot");
        assert!(o1 == o2, "repeated forwards must be bit-identical");
        assert_eq!(o1.len(), plan.output_elems());
    }

    #[test]
    fn pruned_compile_reports_density_and_bit_matches_unelided() {
        let descs = models::cifar_resnet_layers(8, 0.5, 8, 1);
        let cfg = EngineConfig::default();
        let dense = NetworkPlan::compile(&descs, cfg, sb()).unwrap();
        let nm = SparsityPattern::NM { n: 1, m: 4 };
        let pruned = Arc::new(
            NetworkPlan::compile_seeded_pruned(&descs, cfg, sb(), nm, DEFAULT_WEIGHT_SEED)
                .unwrap(),
        );
        assert_eq!(dense.pattern, SparsityPattern::Unstructured);
        assert_eq!(pruned.pattern, nm);
        assert_eq!(pruned.total_params, dense.total_params);
        assert!(pruned.effectual_params < dense.effectual_params);
        assert!(pruned.effectual_density() < dense.effectual_density());
        // engine layers' plan stats must agree with their weight tensors
        for (li, l) in pruned.layers.iter().enumerate() {
            if let Some(p) = &l.plan {
                let eff = l.weights.count_nonzero();
                assert_eq!(p.stats.effectual_cols as usize, eff, "layer {li}");
                assert_eq!(p.stats.total_cols as usize, l.weights.len(), "layer {li}");
            }
        }
        assert!(pruned.density_report().contains("effectual"));
        assert_eq!(pruned.layer_densities().len(), pruned.num_layers());
        // elided plan forwards bit-match the unelided reference twin
        let pool = Pool::new(2);
        let reference = Arc::new(pruned.without_elision(&pool));
        let mut rng = Rng::new(7);
        let mut input = vec![0.0f32; pruned.input_elems()];
        rng.fill_normal(&mut input, 1.0);
        let mut ref_exec = NetworkExecutor::new(reference);
        let want = ref_exec.forward_pool(&input, &pool).to_vec();
        let mut exec = NetworkExecutor::new(Arc::clone(&pruned));
        let got = exec.forward_pool(&input, &pool);
        assert!(got == want, "elided forward must bit-match the unelided reference");
    }

    #[test]
    fn seeded_latents_are_per_layer_stable() {
        let d20 = models::cifar_resnet_layers(20, 1.0, 32, 1);
        let d8 = models::cifar_resnet_layers(8, 1.0, 32, 1);
        let l20 = seeded_latents(&d20, 3);
        let l8 = seeded_latents(&d8, 3);
        // shared prefix geometry -> identical weights per layer index
        assert_eq!(l20[0].data(), l8[0].data());
        assert_eq!(l20[1].data(), l8[1].data());
    }
}
