//! Network-level compilation & execution: whole models on the
//! repetition engine.
//!
//! Everything below `repetition::` executes one conv at a time; this
//! module is the co-design closure the paper argues for — the
//! repetition-sparsity trade-off is a *model-level* property, so the
//! engine should serve whole networks. Two pieces:
//!
//! * [`NetworkPlan::compile`] takes the model zoo's geometry descriptors
//!   (`models::ConvLayerDesc`), quantizes every quantized layer's
//!   weights under one [`Scheme`], and builds all per-layer
//!   [`LayerPlan`]s **once**, fanning layers over the persistent worker
//!   pool (each layer's sub-tile memoization then runs inline on its
//!   worker). Unquantized layers (the fp stem) compile to a transposed
//!   dense weight block executed by the same tile-fused machinery.
//!   Inter-layer wiring (ReLU after every conv; option-A residual
//!   shortcuts for the CIFAR ResNet stem + 2-conv-block shape) is
//!   derived from the descriptor list, SparseDNN-style: whole-network
//!   code generation with buffer reuse decided at compile time.
//! * [`NetworkExecutor`] runs a full forward pass through
//!   `execute_conv2d_into` using a preallocated **ping-pong activation
//!   arena** (three buffers: input, output, and a pinned residual
//!   source). No per-layer `Tensor` is allocated, per-worker scratch is
//!   thread-cached (`util::scratch`), and ReLU/residual-add are fused
//!   into each layer's output scatter — a steady-state forward pass
//!   performs no heap allocation of activations at all.
//!
//! Determinism contract: like the single-layer executor, the forward
//! pass is **bit-identical for every pool width** (fusion is
//! elementwise; tile partitioning depends only on tile size), asserted
//! end-to-end by `tests/integration_network.rs` and re-checked by
//! `plum bench network`.

mod backend;

pub use backend::EngineBackend;

use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Result};

use crate::models::ConvLayerDesc;
use crate::quant::{quantize, Scheme};
use crate::repetition::{
    execute_conv2d_into, plan_layer_auto_pool, EngineConfig, LayerPlan, OpCounts, PostOp,
    Residual, DEFAULT_TILE,
};
use crate::tensor::{im2col_rows_into, Conv2dGeometry, Tensor};
use crate::util::{Pool, Rng, ScratchVec, UnsafeSlice};

/// Weight seed for [`NetworkPlan::compile`] when the caller does not
/// provide one — the supp. G synthetic-latents methodology shared by the
/// figure harnesses.
pub const DEFAULT_WEIGHT_SEED: u64 = 0x9e37;

/// Deterministic per-layer gaussian latents (supp. G methodology):
/// layer `i` draws from an independent RNG stream, so one layer's
/// weights never depend on how many layers precede it.
pub fn seeded_latents(layers: &[ConvLayerDesc], seed: u64) -> Vec<Tensor> {
    layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let mut rng = Rng::new(seed).fork(i as u64 + 1);
            Tensor::rand_normal(&[l.geom.k, l.geom.c, l.geom.r, l.geom.s], 0.5, &mut rng)
        })
        .collect()
}

/// One compiled layer of a [`NetworkPlan`].
#[derive(Debug, Clone)]
pub struct NetworkLayer {
    pub name: String,
    pub geom: Conv2dGeometry,
    /// engine plan (quantized layers); `None` = dense fp fallback
    pub plan: Option<LayerPlan>,
    /// fp fallback weights, transposed to `[C*R*S, K]` at compile time
    dense_wt: Option<Vec<f32>>,
    /// the dense weights this layer executes (quantized values for
    /// engine layers, latents for fp layers) — reference checks/reports
    pub weights: Tensor,
    /// apply ReLU in the fused epilogue
    pub relu: bool,
    /// activation index whose option-A shortcut is added before ReLU
    /// (activation `i` is the *input* of layer `i`; `0` = network input)
    pub residual_from: Option<usize>,
}

/// A whole model compiled onto the repetition engine: per-layer plans
/// built once, wiring and arena sizing decided at compile time.
#[derive(Debug, Clone)]
pub struct NetworkPlan {
    pub layers: Vec<NetworkLayer>,
    pub scheme: Scheme,
    /// element count of activation `a[i]` (`a[0]` = input, `a[L]` = output)
    act_elems: Vec<usize>,
    /// `residual_needed[i]`: some later layer reads activation `a[i]`
    residual_needed: Vec<bool>,
    /// §6 deployment footprint of all weights under `scheme`
    pub weight_bits: usize,
}

impl NetworkPlan {
    /// Compile with deterministic seeded latents ([`DEFAULT_WEIGHT_SEED`])
    /// on the process-wide pool.
    pub fn compile(
        layers: &[ConvLayerDesc],
        cfg: EngineConfig,
        scheme: Scheme,
    ) -> Result<NetworkPlan> {
        Self::compile_seeded(layers, cfg, scheme, DEFAULT_WEIGHT_SEED)
    }

    /// Compile with seeded latents drawn from `seed`.
    pub fn compile_seeded(
        layers: &[ConvLayerDesc],
        cfg: EngineConfig,
        scheme: Scheme,
        seed: u64,
    ) -> Result<NetworkPlan> {
        let latents = seeded_latents(layers, seed);
        Self::compile_with_weights(layers, &latents, cfg, scheme, Pool::global())
    }

    /// Compile from explicit latent weights with the default wiring:
    /// ReLU after every conv, plus [`resnet_wiring`]'s option-A
    /// shortcuts **when the descriptor list has the CIFAR ResNet
    /// shape** (stem + 2-conv blocks). Custom topologies that happen to
    /// pair-match but must *not* get shortcuts should use
    /// [`NetworkPlan::compile_with_wiring`] and pass their wiring
    /// explicitly.
    pub fn compile_with_weights(
        descs: &[ConvLayerDesc],
        latents: &[Tensor],
        cfg: EngineConfig,
        scheme: Scheme,
        pool: &Pool,
    ) -> Result<NetworkPlan> {
        Self::compile_with_wiring(descs, latents, &resnet_wiring(descs), cfg, scheme, pool)
    }

    /// Core compile: quantize + plan every layer from explicit latent
    /// weights and explicit wiring — one `(relu, residual_from)` pair
    /// per layer, `residual_from` naming the activation index (`i` =
    /// input of layer `i`, `0` = network input) whose option-A shortcut
    /// is added before that layer's ReLU. Layers are fanned over `pool`;
    /// `cfg.subtile == 0` auto-tunes the sub-tile size per layer (paper
    /// §6), a fixed value pins it.
    pub fn compile_with_wiring(
        descs: &[ConvLayerDesc],
        latents: &[Tensor],
        wiring: &[(bool, Option<usize>)],
        cfg: EngineConfig,
        scheme: Scheme,
        pool: &Pool,
    ) -> Result<NetworkPlan> {
        ensure!(!descs.is_empty(), "cannot compile an empty network");
        ensure!(
            wiring.len() == descs.len(),
            "{} wiring entries for {} layers",
            wiring.len(),
            descs.len()
        );
        for (li, (_, rf)) in wiring.iter().enumerate() {
            if let Some(ai) = rf {
                ensure!(
                    *ai <= li,
                    "layer {li} shortcut reads activation {ai}, which is not computed yet"
                );
            }
        }
        // the executor pins at most ONE shortcut source in its arena at a
        // time: each activation may feed one shortcut, and pin live
        // ranges [source, consumer] must be strictly disjoint — reject
        // anything else here rather than corrupt the arena at run time
        let mut shortcuts: Vec<(usize, usize)> = wiring
            .iter()
            .enumerate()
            .filter_map(|(li, (_, rf))| rf.map(|ai| (ai, li)))
            .collect();
        shortcuts.sort_unstable();
        for pair in shortcuts.windows(2) {
            let (a0, c0) = pair[0];
            let (a1, c1) = pair[1];
            ensure!(
                a1 > c0,
                "shortcut a[{a1}]->layer {c1} overlaps shortcut a[{a0}]->layer {c0}: the \
                 executor holds one pinned residual source at a time"
            );
        }
        ensure!(
            latents.len() == descs.len(),
            "{} weight tensors for {} layers",
            latents.len(),
            descs.len()
        );
        if matches!(scheme, Scheme::Fp) {
            bail!("the repetition engine executes quantized networks — pick a non-fp scheme");
        }
        let batch = descs[0].geom.n;
        for (i, d) in descs.iter().enumerate() {
            ensure!(d.geom.n == batch, "layer {i} batch {} != network batch {batch}", d.geom.n);
            let ws = latents[i].shape();
            let want = [d.geom.k, d.geom.c, d.geom.r, d.geom.s];
            ensure!(ws == &want[..], "layer {i} weights {ws:?} do not match its geometry");
            if i > 0 {
                let (pk, ph, pw) = descs[i - 1].out_shape();
                let g = d.geom;
                ensure!(
                    g.c == pk && g.h == ph && g.w == pw,
                    "layer {i} ({}) input {}x{}x{} does not chain from layer {} output \
                     {pk}x{ph}x{pw} — pooled or branching topologies are not supported",
                    descs[i].name,
                    g.c,
                    g.h,
                    g.w,
                    i - 1
                );
            }
        }
        // quantize + plan, one layer per pool job (a layer's own
        // sub-tile fan-out then runs inline on its worker)
        let slots: Vec<Mutex<Option<NetworkLayer>>> =
            (0..descs.len()).map(|_| Mutex::new(None)).collect();
        pool.run(descs.len(), |li| {
            let d = &descs[li];
            let w = &latents[li];
            let (plan, dense_wt, weights) = if d.quantized {
                let q = quantize(w, scheme, None);
                let plan = if cfg.subtile == 0 {
                    plan_layer_auto_pool(&q, d.geom, cfg.sparsity_support, pool)
                } else {
                    LayerPlan::build_pool(&q, d.geom, cfg, pool)
                };
                (Some(plan), None, q.values)
            } else {
                // fp fallback: transpose OIHW -> [C*R*S, K] once here
                let e = d.geom.c * d.geom.r * d.geom.s;
                let k = d.geom.k;
                let mut wt = vec![0.0f32; e * k];
                for ki in 0..k {
                    for ei in 0..e {
                        wt[ei * k + ki] = w.data()[ki * e + ei];
                    }
                }
                (None, Some(wt), w.clone())
            };
            let (relu, residual_from) = wiring[li];
            *slots[li].lock().unwrap() = Some(NetworkLayer {
                name: d.name.clone(),
                geom: d.geom,
                plan,
                dense_wt,
                weights,
                relu,
                residual_from,
            });
        });
        let layers: Vec<NetworkLayer> = slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("every layer compiled by the pool run"))
            .collect();

        let mut act_elems = Vec::with_capacity(descs.len() + 1);
        act_elems.push(batch * descs[0].geom.c * descs[0].geom.h * descs[0].geom.w);
        for d in descs {
            act_elems.push(batch * d.geom.k * d.geom.out_h() * d.geom.out_w());
        }
        let mut residual_needed = vec![false; descs.len() + 1];
        for l in &layers {
            if let Some(ai) = l.residual_from {
                residual_needed[ai] = true;
            }
        }
        let weight_bits = descs.iter().map(|d| layer_weight_bits(d, scheme)).sum();
        Ok(NetworkPlan { layers, scheme, act_elems, residual_needed, weight_bits })
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Network batch size (every layer shares it).
    pub fn batch(&self) -> usize {
        self.layers[0].geom.n
    }

    pub fn input_elems(&self) -> usize {
        self.act_elems[0]
    }

    pub fn output_elems(&self) -> usize {
        *self.act_elems.last().unwrap()
    }

    /// Input elements per sample (C*H*W).
    pub fn sample_elems(&self) -> usize {
        self.input_elems() / self.batch()
    }

    /// Geometry of the final conv (its `k`/`out_h`/`out_w` shape the
    /// network output `[n, k, oh, ow]`).
    pub fn out_geom(&self) -> Conv2dGeometry {
        self.layers.last().unwrap().geom
    }

    /// Largest activation the arena must hold.
    pub fn max_act_elems(&self) -> usize {
        *self.act_elems.iter().max().unwrap()
    }

    /// Elements of activation `a[i]`.
    pub fn act_elems(&self, i: usize) -> usize {
        self.act_elems[i]
    }

    /// Dense MACs of one full forward pass (arithmetic-reduction
    /// denominator, supp. G).
    pub fn dense_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.geom.dense_macs()).sum()
    }

    /// Accounted engine operations of one full forward pass; fp layers
    /// count their dense MACs as one add + one mul each.
    pub fn op_counts(&self) -> OpCounts {
        let mut total = OpCounts::default();
        for l in &self.layers {
            let c = match &l.plan {
                Some(p) => p.op_counts(),
                None => OpCounts { adds: l.geom.dense_macs(), muls: l.geom.dense_macs() },
            };
            total.adds += c.adds;
            total.muls += c.muls;
        }
        total
    }
}

/// §6 deployment bit accounting per layer: sb = 1-bit bitmap + one sign
/// bit per region; binary = 1 bit/weight; ternary = 2; fp layers 32.
fn layer_weight_bits(desc: &ConvLayerDesc, scheme: Scheme) -> usize {
    let wc = desc.geom.weight_count();
    if !desc.quantized {
        return 32 * wc;
    }
    match scheme {
        Scheme::Fp => 32 * wc,
        Scheme::Binary => wc,
        Scheme::Ternary { .. } => 2 * wc,
        Scheme::SignedBinary { regions_per_filter, .. } => wc + desc.geom.k * regions_per_filter,
    }
}

/// Derive the default inter-layer wiring from a descriptor list: ReLU
/// after every conv; when the list has the CIFAR ResNet shape (stem +
/// 2-conv blocks whose second conv keeps channels and stride 1), each
/// block's second conv gains an option-A shortcut from the block input.
/// This is a *shape heuristic* — chains that match it but are not
/// residual networks should build their wiring by hand and compile via
/// [`NetworkPlan::compile_with_wiring`].
pub fn resnet_wiring(descs: &[ConvLayerDesc]) -> Vec<(bool, Option<usize>)> {
    let n = descs.len();
    let mut wiring = vec![(true, None); n];
    if n >= 3 && (n - 1) % 2 == 0 {
        let paired = (1..n).step_by(2).all(|i| {
            let a = descs[i].geom;
            let b = descs[i + 1].geom;
            b.c == a.k && b.k == a.k && b.stride == 1 && b.r == a.r && b.s == a.s
        });
        if paired {
            for i in (1..n).step_by(2) {
                // activation i is the input of block conv i; it shortcuts
                // into the second conv's output
                wiring[i + 1].1 = Some(i);
            }
        }
    }
    wiring
}

/// Tile-fused dense conv for fp layers (the unquantized stem): per pixel
/// tile, im2col rows into thread-cached scratch, then a direct product
/// in ascending C*R*S order — the same accumulation order as
/// `conv2d_naive`, with the same fused [`PostOp`] epilogue as the engine
/// path. Per-pixel accumulation never crosses a tile, so N-thread output
/// is bit-identical to 1-thread.
fn dense_conv_into(
    g: Conv2dGeometry,
    wt: &[f32],
    x: &[f32],
    out: &mut [f32],
    pool: &Pool,
    tile: usize,
    post: PostOp<'_>,
) {
    let e = g.c * g.r * g.s;
    let (oh, ow) = (g.out_h(), g.out_w());
    let plane = oh * ow;
    let pixels = g.n * plane;
    assert_eq!(wt.len(), e * g.k, "transposed weights do not match geometry");
    assert_eq!(x.len(), g.n * g.c * g.h * g.w, "input does not match geometry");
    assert_eq!(out.len(), g.n * g.k * plane, "output buffer does not match geometry");
    post.validate(g.n, g.k, oh, ow);
    if pixels == 0 {
        return;
    }
    let od = UnsafeSlice::new(out);
    let jobs = pixels.div_ceil(tile);
    pool.run_with(
        jobs,
        || ScratchVec::take(tile * e),
        |patch, job| {
            let px0 = job * tile;
            let tp = tile.min(pixels - px0);
            im2col_rows_into(x, &g, px0, tp, patch);
            for row in 0..tp {
                let px = px0 + row;
                let ni = px / plane;
                let pix = px % plane;
                let prow = &patch[row * e..(row + 1) * e];
                for ki in 0..g.k {
                    let mut acc = 0.0f32;
                    for (ei, pv) in prow.iter().enumerate() {
                        acc += pv * wt[ei * g.k + ki];
                    }
                    let v = post.apply(acc, ni, ki, pix, ow);
                    unsafe { od.write((ni * g.k + ki) * plane + pix, v) };
                }
            }
        },
    );
}

/// Disjoint views of the three arena slots: mutable output, shared
/// current input, optionally the pinned residual source (which may alias
/// the input while a block's first conv runs — both are shared reads).
fn arena_views(
    bufs: &mut [Vec<f32>; 3],
    out: usize,
    cur: usize,
    held: Option<usize>,
) -> (&mut Vec<f32>, &Vec<f32>, Option<&Vec<f32>>) {
    debug_assert!(out != cur && Some(out) != held, "output slot must be free");
    let mut ov = None;
    let mut xv = None;
    let mut hv = None;
    for (i, b) in bufs.iter_mut().enumerate() {
        if i == out {
            ov = Some(b);
        } else {
            let view: &Vec<f32> = b;
            if i == cur {
                xv = Some(view);
            }
            if held == Some(i) {
                hv = Some(view);
            }
        }
    }
    (ov.expect("output slot"), xv.expect("input slot"), hv)
}

/// Runs full forward passes of one [`NetworkPlan`] through a reusable
/// three-buffer activation arena. Construct once per serving replica;
/// `forward` never allocates activations.
#[derive(Debug)]
pub struct NetworkExecutor {
    plan: Arc<NetworkPlan>,
    bufs: [Vec<f32>; 3],
    tile: usize,
}

impl NetworkExecutor {
    pub fn new(plan: Arc<NetworkPlan>) -> NetworkExecutor {
        let m = plan.max_act_elems();
        NetworkExecutor {
            plan,
            bufs: [vec![0.0; m], vec![0.0; m], vec![0.0; m]],
            tile: DEFAULT_TILE,
        }
    }

    pub fn plan(&self) -> &NetworkPlan {
        &self.plan
    }

    /// Full forward pass on the process-wide pool. Returns the final
    /// activation `[n, k, oh, ow]`, borrowed from the arena.
    pub fn forward(&mut self, input: &[f32]) -> &[f32] {
        self.forward_pool(input, Pool::global())
    }

    /// Full forward pass on an explicit pool (benchmarks pin widths).
    pub fn forward_pool(&mut self, input: &[f32], pool: &Pool) -> &[f32] {
        let plan = Arc::clone(&self.plan);
        assert_eq!(input.len(), plan.input_elems(), "input does not match network geometry");
        let mut cur = 0usize;
        self.bufs[cur][..input.len()].copy_from_slice(input);
        // (arena slot, activation index) pinned for a pending shortcut
        let mut held: Option<(usize, usize)> = None;
        for (li, layer) in plan.layers.iter().enumerate() {
            if plan.residual_needed[li] {
                held = Some((cur, li));
            }
            let held_buf = held.map(|(hb, _)| hb);
            let out_idx = (0..3usize)
                .find(|b| *b != cur && Some(*b) != held_buf)
                .expect("three buffers always leave a free slot");
            let in_len = plan.act_elems[li];
            let out_len = plan.act_elems[li + 1];
            let (ov, xv, hv) = arena_views(&mut self.bufs, out_idx, cur, held_buf);
            let residual = layer.residual_from.map(|ai| {
                let (_, ha) = held.expect("shortcut source pinned in the arena");
                debug_assert_eq!(ha, ai, "hold/wiring mismatch");
                let sg = plan.layers[ai].geom;
                let st = (sg.h / layer.geom.out_h()).max(1);
                Residual {
                    src: &hv.expect("held arena view")[..plan.act_elems[ai]],
                    c: sg.c,
                    h: sg.h,
                    w: sg.w,
                    stride: st,
                }
            });
            let post = PostOp { relu: layer.relu, residual };
            match &layer.plan {
                Some(lp) => execute_conv2d_into(
                    lp,
                    &xv[..in_len],
                    &mut ov[..out_len],
                    pool,
                    self.tile,
                    post,
                ),
                None => dense_conv_into(
                    layer.geom,
                    layer.dense_wt.as_ref().expect("fp layer keeps dense weights"),
                    &xv[..in_len],
                    &mut ov[..out_len],
                    pool,
                    self.tile,
                    post,
                ),
            }
            cur = out_idx;
            if layer.residual_from.is_some() {
                held = None;
            }
        }
        &self.bufs[cur][..plan.output_elems()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::repetition::{execute_conv2d_pool, plan_layer};

    fn sb() -> Scheme {
        Scheme::sb_default()
    }

    #[test]
    fn resnet8_wiring_and_layer_kinds() {
        let descs = models::cifar_resnet_layers(8, 0.5, 16, 1);
        let plan = NetworkPlan::compile(&descs, EngineConfig::default(), sb()).unwrap();
        assert_eq!(plan.num_layers(), 7);
        // fp stem executes dense; every block conv has an engine plan
        assert!(plan.layers[0].plan.is_none());
        assert!(plan.layers[1..].iter().all(|l| l.plan.is_some()));
        // option-A shortcut on each block's second conv, from block input
        assert_eq!(plan.layers[2].residual_from, Some(1));
        assert_eq!(plan.layers[4].residual_from, Some(3));
        assert_eq!(plan.layers[6].residual_from, Some(5));
        assert!(plan.layers.iter().all(|l| l.relu));
        // arena must fit the widest activation
        assert!(plan.max_act_elems() >= plan.input_elems());
        assert!(plan.op_counts().total() > 0);
        assert!(plan.weight_bits > 0);
    }

    #[test]
    fn pooled_topologies_are_rejected() {
        let descs = models::vgg_small_layers(0.5, 32, 1);
        let err = NetworkPlan::compile(&descs, EngineConfig::default(), sb());
        assert!(err.is_err(), "pooling gaps must not compile");
    }

    #[test]
    fn fp_scheme_is_rejected() {
        let descs = models::cifar_resnet_layers(8, 0.5, 16, 1);
        assert!(NetworkPlan::compile(&descs, EngineConfig::default(), Scheme::Fp).is_err());
    }

    #[test]
    fn plain_chain_matches_layer_by_layer_engine() {
        // two quantized convs, no residual pattern: forward must
        // bit-match unfused per-layer execution + ReLU
        let g1 = Conv2dGeometry { n: 2, c: 3, h: 8, w: 8, k: 4, r: 3, s: 3, stride: 1, padding: 1 };
        let g2 = Conv2dGeometry { n: 2, c: 4, h: 8, w: 8, k: 6, r: 3, s: 3, stride: 1, padding: 1 };
        let descs = vec![
            ConvLayerDesc { name: "a".into(), geom: g1, quantized: true },
            ConvLayerDesc { name: "b".into(), geom: g2, quantized: true },
        ];
        let latents = seeded_latents(&descs, 7);
        let cfg = EngineConfig::default();
        let pool = Pool::new(2);
        let plan = NetworkPlan::compile_with_weights(&descs, &latents, cfg, sb(), &pool).unwrap();
        let plan = Arc::new(plan);
        assert!(plan.layers.iter().all(|l| l.residual_from.is_none()));

        let mut rng = Rng::new(41);
        let x = Tensor::rand_normal(&[2, 3, 8, 8], 1.0, &mut rng);
        let mut exec = NetworkExecutor::new(Arc::clone(&plan));
        let out = exec.forward_pool(x.data(), &pool).to_vec();

        let q1 = quantize(&latents[0], sb(), None);
        let q2 = quantize(&latents[1], sb(), None);
        let mut y1 = execute_conv2d_pool(&plan_layer(&q1, g1, cfg), &x, &pool);
        y1.data_mut().iter_mut().for_each(|v| *v = v.max(0.0));
        let mut y2 = execute_conv2d_pool(&plan_layer(&q2, g2, cfg), &y1, &pool);
        y2.data_mut().iter_mut().for_each(|v| *v = v.max(0.0));
        assert!(out == y2.data(), "network forward differs from layer-by-layer reference");
    }

    #[test]
    fn explicit_wiring_overrides_the_resnet_heuristic() {
        let g1 = Conv2dGeometry { n: 1, c: 3, h: 6, w: 6, k: 4, r: 3, s: 3, stride: 1, padding: 1 };
        let g2 = Conv2dGeometry { n: 1, c: 4, h: 6, w: 6, k: 4, r: 3, s: 3, stride: 1, padding: 1 };
        let descs = vec![
            ConvLayerDesc { name: "a".into(), geom: g1, quantized: true },
            ConvLayerDesc { name: "b".into(), geom: g2, quantized: true },
            ConvLayerDesc { name: "c".into(), geom: g2, quantized: true },
        ];
        let latents = seeded_latents(&descs, 9);
        let pool = Pool::new(1);
        let cfg = EngineConfig::default();
        // the heuristic wires a shortcut into this pair-matching 3-chain
        let auto = NetworkPlan::compile_with_weights(&descs, &latents, cfg, sb(), &pool).unwrap();
        assert_eq!(auto.layers[2].residual_from, Some(1));
        // explicit all-None wiring keeps it a plain chain
        let plain = vec![(true, None); 3];
        let p = NetworkPlan::compile_with_wiring(&descs, &latents, &plain, cfg, sb(), &pool);
        assert!(p.unwrap().layers.iter().all(|l| l.residual_from.is_none()));
        // future-activation shortcuts are rejected
        let bad = vec![(true, None), (true, Some(2)), (true, None)];
        let err = NetworkPlan::compile_with_wiring(&descs, &latents, &bad, cfg, sb(), &pool);
        assert!(err.is_err());
        // overlapping pin ranges (two pending shortcut sources at once,
        // or one activation feeding two shortcuts) are rejected: the
        // executor pins a single residual source
        let overlap = vec![(true, None), (true, Some(0)), (true, Some(1))];
        let err = NetworkPlan::compile_with_wiring(&descs, &latents, &overlap, cfg, sb(), &pool);
        assert!(err.is_err());
        let dup = vec![(true, None), (true, Some(0)), (true, Some(0))];
        let err = NetworkPlan::compile_with_wiring(&descs, &latents, &dup, cfg, sb(), &pool);
        assert!(err.is_err());
    }

    #[test]
    fn forward_reuses_the_arena_and_is_deterministic() {
        let descs = models::cifar_resnet_layers(8, 0.5, 8, 1);
        let plan = Arc::new(NetworkPlan::compile(&descs, EngineConfig::default(), sb()).unwrap());
        let pool = Pool::new(2);
        let mut exec = NetworkExecutor::new(Arc::clone(&plan));
        let mut rng = Rng::new(42);
        let mut input = vec![0.0f32; plan.input_elems()];
        rng.fill_normal(&mut input, 1.0);
        let (p1, o1) = {
            let o = exec.forward_pool(&input, &pool);
            (o.as_ptr(), o.to_vec())
        };
        let (p2, o2) = {
            let o = exec.forward_pool(&input, &pool);
            (o.as_ptr(), o.to_vec())
        };
        assert_eq!(p1, p2, "second forward must land in the same arena slot");
        assert!(o1 == o2, "repeated forwards must be bit-identical");
        assert_eq!(o1.len(), plan.output_elems());
    }

    #[test]
    fn seeded_latents_are_per_layer_stable() {
        let d20 = models::cifar_resnet_layers(20, 1.0, 32, 1);
        let d8 = models::cifar_resnet_layers(8, 1.0, 32, 1);
        let l20 = seeded_latents(&d20, 3);
        let l8 = seeded_latents(&d8, 3);
        // shared prefix geometry -> identical weights per layer index
        assert_eq!(l20[0].data(), l8[0].data());
        assert_eq!(l20[1].data(), l8[1].data());
    }
}
