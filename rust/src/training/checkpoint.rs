//! Checkpoint format: a JSON header line (specs + step) followed by raw
//! little-endian f32 tensor data in header order.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::runtime::{Dtype, TensorSpec};
use crate::util::json::{self, Json};

const MAGIC: &str = "plum-ckpt-v1";

/// Write `state` (specs + f32 data) and the step counter to `path`.
pub fn save_checkpoint(
    path: &Path,
    step: u64,
    state: &[(TensorSpec, Vec<f32>)],
) -> Result<()> {
    let header = json::obj(vec![
        ("magic", json::s(MAGIC)),
        ("step", json::num(step as f64)),
        (
            "tensors",
            Json::Arr(
                state
                    .iter()
                    .map(|(spec, _)| {
                        json::obj(vec![
                            ("group", json::s(&spec.group)),
                            ("name", json::s(&spec.name)),
                            (
                                "shape",
                                Json::Arr(
                                    spec.shape.iter().map(|d| json::num(*d as f64)).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "{}", header.to_string())?;
    for (_, data) in state {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&bytes)?;
    }
    Ok(())
}

/// Read a checkpoint written by [`save_checkpoint`]: returns the step
/// counter and the state tensors in header order.
pub fn load_checkpoint(path: &Path) -> Result<(u64, Vec<(TensorSpec, Vec<f32>)>)> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    let nl = bytes
        .iter()
        .position(|b| *b == b'\n')
        .ok_or_else(|| anyhow!("no header line"))?;
    let header = Json::parse(std::str::from_utf8(&bytes[..nl])?)
        .map_err(|e| anyhow!("bad header: {e}"))?;
    if header.req_str("magic")? != MAGIC {
        return Err(anyhow!("not a plum checkpoint"));
    }
    let step = header.req_usize("step")? as u64;
    let mut state = Vec::new();
    let mut off = nl + 1;
    for t in header.req_arr("tensors")? {
        let shape: Vec<usize> = t
            .req_arr("shape")?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect();
        let n: usize = shape.iter().product();
        if off + 4 * n > bytes.len() {
            return Err(anyhow!("checkpoint truncated"));
        }
        let mut data = vec![0.0f32; n];
        for (i, ch) in bytes[off..off + 4 * n].chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
        }
        off += 4 * n;
        state.push((
            TensorSpec {
                group: t.req_str("group")?.to_string(),
                name: t.req_str("name")?.to_string(),
                shape,
                dtype: Dtype::F32,
            },
            data,
        ));
    }
    if off != bytes.len() {
        return Err(anyhow!("checkpoint has trailing bytes"));
    }
    Ok((step, state))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(group: &str, name: &str, shape: &[usize]) -> TensorSpec {
        TensorSpec {
            group: group.into(),
            name: name.into(),
            shape: shape.to_vec(),
            dtype: Dtype::F32,
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("plum_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        let state = vec![
            (spec("params", "000.conv.w", &[2, 3]), vec![1.0, -2.0, 3.5, 0.0, 7.0, -0.25]),
            (spec("bn", "001.bn.mean", &[4]), vec![0.1, 0.2, 0.3, 0.4]),
        ];
        save_checkpoint(&path, 42, &state).unwrap();
        let (step, loaded) = load_checkpoint(&path).unwrap();
        assert_eq!(step, 42);
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].1, state[0].1);
        assert_eq!(loaded[1].0.name, "001.bn.mean");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("plum_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"{\"magic\":\"nope\"}\n").unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
