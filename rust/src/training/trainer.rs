//! The PJRT training driver (`pjrt` feature): owns a compiled model and
//! its resident device state.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::data::SyntheticDataset;
use crate::quant::{self, QuantizedWeights};
use crate::runtime::{
    literal_f32, literal_i32, literal_to_f32, ConvLayerInfo, ModelHandle, Runtime, TensorSpec,
};
use crate::tensor::Tensor;

use super::{scheme_from_config, CurvePoint, Schedule, TrainLog};

/// Driver owning a compiled model + resident state.
pub struct Trainer {
    /// the compiled model (manifest + executables)
    pub model: ModelHandle,
    params: Vec<xla::Literal>,
    bn: Vec<xla::Literal>,
    consts: Vec<xla::Literal>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    param_specs: Vec<TensorSpec>,
    bn_specs: Vec<TensorSpec>,
    const_specs: Vec<TensorSpec>,
    /// optimizer steps taken so far
    pub step: u64,
}

impl Trainer {
    /// Load + compile `name` from `dir` and stage its initial state as
    /// device literals.
    pub fn new(rt: &Runtime, dir: &Path, name: &str) -> Result<Trainer> {
        let model = ModelHandle::load(rt, dir, name, true)?;
        let init = model.manifest.load_initial_state()?;
        let mut params = Vec::new();
        let mut bn = Vec::new();
        let mut consts = Vec::new();
        let mut param_specs = Vec::new();
        let mut bn_specs = Vec::new();
        let mut const_specs = Vec::new();
        for (spec, data) in init {
            let lit = literal_f32(&spec.shape, &data)?;
            match spec.group.as_str() {
                "params" => {
                    params.push(lit);
                    param_specs.push(spec);
                }
                "bn" => {
                    bn.push(lit);
                    bn_specs.push(spec);
                }
                "consts" => {
                    consts.push(lit);
                    const_specs.push(spec);
                }
                g => return Err(anyhow!("unexpected state group {g}")),
            }
        }
        let m = param_specs
            .iter()
            .map(|s| literal_f32(&s.shape, &vec![0.0; s.elements()]))
            .collect::<Result<Vec<_>>>()?;
        let v = param_specs
            .iter()
            .map(|s| literal_f32(&s.shape, &vec![0.0; s.elements()]))
            .collect::<Result<Vec<_>>>()?;
        Ok(Trainer {
            model,
            params,
            bn,
            consts,
            m,
            v,
            param_specs,
            bn_specs,
            const_specs,
            step: 0,
        })
    }

    /// Batch size the artifact was lowered at.
    pub fn batch_size(&self) -> usize {
        self.model.manifest.config.batch_size
    }

    /// Square input image side.
    pub fn image_size(&self) -> usize {
        self.model.manifest.config.image_size
    }

    /// Classifier classes.
    pub fn num_classes(&self) -> usize {
        self.model.manifest.config.num_classes
    }

    /// One optimizer step. `progress` in [0,1] drives the EDE schedule.
    pub fn train_step(
        &mut self,
        x: &[f32],
        y: &[i32],
        lr: f32,
        progress: f32,
    ) -> Result<(f32, f32)> {
        let cfg = &self.model.manifest.config;
        let bs = cfg.batch_size;
        let px = cfg.image_size;
        assert_eq!(x.len(), bs * cfg.in_channels * px * px, "bad batch x");
        assert_eq!(y.len(), bs, "bad batch y");
        self.step += 1;

        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(
            self.params.len() * 3 + self.bn.len() + self.consts.len() + 5,
        );
        inputs.extend(self.params.iter());
        inputs.extend(self.bn.iter());
        inputs.extend(self.consts.iter());
        inputs.extend(self.m.iter());
        inputs.extend(self.v.iter());
        let xl = literal_f32(&[bs, cfg.in_channels, px, px], x)?;
        let yl = literal_i32(&[bs], y)?;
        let lrl = literal_f32(&[], &[lr])?;
        let stepl = literal_f32(&[], &[self.step as f32])?;
        let progl = literal_f32(&[], &[progress])?;
        inputs.push(&xl);
        inputs.push(&yl);
        inputs.push(&lrl);
        inputs.push(&stepl);
        inputs.push(&progl);

        let mut out = self.model.train_step(&inputs)?;
        let np = self.params.len();
        let nb = self.bn.len();
        let expect = 2 + np + nb + np + np;
        if out.len() != expect {
            return Err(anyhow!("train step returned {} outputs, expected {expect}", out.len()));
        }
        // consume back-to-front to move literals out without reindexing
        let v_new: Vec<_> = out.split_off(2 + np + nb + np);
        let m_new: Vec<_> = out.split_off(2 + np + nb);
        let bn_new: Vec<_> = out.split_off(2 + np);
        let p_new: Vec<_> = out.split_off(2);
        let acc = literal_to_f32(&out[1])?[0];
        let loss = literal_to_f32(&out[0])?[0];
        self.params = p_new;
        self.bn = bn_new;
        self.m = m_new;
        self.v = v_new;
        Ok((loss, acc))
    }

    /// Full training loop over a dataset.
    #[allow(clippy::too_many_arguments)]
    pub fn train(
        &mut self,
        ds: &SyntheticDataset,
        steps: u64,
        schedule: &Schedule,
        log_every: u64,
        eval_batches: usize,
        quiet: bool,
    ) -> Result<TrainLog> {
        let t0 = std::time::Instant::now();
        let bs = self.batch_size();
        let mut curve = Vec::new();
        let mut last_loss = f32::NAN;
        for i in 0..steps {
            let progress = i as f32 / steps.max(1) as f32;
            let lr = schedule.lr(progress);
            let (xs, ys) = ds.batch((i as usize) * bs, bs);
            let (loss, acc) = self.train_step(&xs, &ys, lr, progress)?;
            last_loss = loss;
            if i % log_every == 0 || i + 1 == steps {
                curve.push(CurvePoint { step: i, loss, acc });
                if !quiet {
                    println!(
                        "step {i:>5}  loss {loss:<8.4} acc {acc:<6.3} lr {lr:.2e}"
                    );
                }
            }
        }
        let eval_acc = self.evaluate(ds, eval_batches)?;
        Ok(TrainLog {
            curve,
            final_train_loss: last_loss,
            eval_acc,
            steps,
            wall_secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Held-out accuracy through the infer executable (eval-mode BN,
    /// Pallas hot path for sb models).
    pub fn evaluate(&self, ds: &SyntheticDataset, batches: usize) -> Result<f32> {
        let cfg = &self.model.manifest.config;
        let bs = cfg.batch_size;
        let eval_offset = 1_000_000; // disjoint from any training index
        let mut correct = 0usize;
        let mut total = 0usize;
        for b in 0..batches {
            let (xs, ys) = ds.eval_batch(eval_offset, b * bs, bs);
            let logits = self.infer_logits(&xs)?;
            let ncls = cfg.num_classes;
            for (bi, y) in ys.iter().enumerate() {
                let row = &logits[bi * ncls..(bi + 1) * ncls];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == *y as usize {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f32 / total.max(1) as f32)
    }

    /// Run the infer executable on one batch; returns flat logits.
    pub fn infer_logits(&self, x: &[f32]) -> Result<Vec<f32>> {
        let cfg = &self.model.manifest.config;
        let bs = cfg.batch_size;
        let px = cfg.image_size;
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(self.params.len() + self.bn.len() + self.consts.len() + 1);
        inputs.extend(self.params.iter());
        inputs.extend(self.bn.iter());
        inputs.extend(self.consts.iter());
        let xl = literal_f32(&[bs, cfg.in_channels, px, px], x)?;
        inputs.push(&xl);
        let out = self.model.infer(&inputs)?;
        literal_to_f32(&out[0])
    }

    /// Host copy of the full state (params ++ bn ++ consts) for
    /// checkpointing; order matches the manifest.
    pub fn state_to_host(&self) -> Result<Vec<(TensorSpec, Vec<f32>)>> {
        let mut out = Vec::new();
        for (spec, lit) in self
            .param_specs
            .iter()
            .zip(&self.params)
            .chain(self.bn_specs.iter().zip(&self.bn))
            .chain(self.const_specs.iter().zip(&self.consts))
        {
            out.push((spec.clone(), literal_to_f32(lit)?));
        }
        Ok(out)
    }

    /// Restore state from host values (inverse of `state_to_host`).
    pub fn state_from_host(&mut self, state: &[(TensorSpec, Vec<f32>)]) -> Result<()> {
        let np = self.param_specs.len();
        let nb = self.bn_specs.len();
        let nc = self.const_specs.len();
        if state.len() != np + nb + nc {
            return Err(anyhow!("state has {} tensors, expected {}", state.len(), np + nb + nc));
        }
        for (i, (spec, data)) in state.iter().enumerate() {
            let lit = literal_f32(&spec.shape, data)?;
            if i < np {
                self.params[i] = lit;
            } else if i < np + nb {
                self.bn[i - np] = lit;
            } else {
                self.consts[i - np - nb] = lit;
            }
        }
        Ok(())
    }

    /// Quantize the current latent weights host-side (S2), yielding per
    /// quantized conv layer the dense quantized weights for the
    /// repetition engine and reports. The manifest's beta consts are used
    /// for sb so the assignment matches training exactly.
    pub fn export_quantized(&self) -> Result<Vec<(ConvLayerInfo, QuantizedWeights)>> {
        let man = &self.model.manifest;
        let cfg = &man.config;
        let scheme = scheme_from_config(&cfg.scheme, cfg.delta_frac, cfg.regions_per_filter);
        let mut out = Vec::new();
        for layer in man.conv_layers.iter().filter(|l| l.quantized) {
            let wname = format!("{}.w", layer.name);
            let idx = self
                .param_specs
                .iter()
                .position(|s| s.name == wname)
                .ok_or_else(|| anyhow!("weight {wname} not in params"))?;
            let w = Tensor::new(
                &self.param_specs[idx].shape,
                literal_to_f32(&self.params[idx])?,
            );
            let beta_name = format!("{}.beta", layer.name);
            let beta = self
                .const_specs
                .iter()
                .position(|s| s.name == beta_name)
                .map(|ci| literal_to_f32(&self.consts[ci]))
                .transpose()?;
            let q = quant::quantize(&w, scheme, beta.as_deref());
            out.push((layer.clone(), q));
        }
        Ok(out)
    }

    /// Aggregate density over all quantized layers (paper §5.2: counts
    /// zero-valued quantized weights / total quantized weights).
    pub fn quantized_density(&self) -> Result<f64> {
        let layers = self.export_quantized()?;
        let (mut nnz, mut tot) = (0usize, 0usize);
        for (_, q) in &layers {
            nnz += q.effectual();
            tot += q.values.len();
        }
        Ok(nnz as f64 / tot.max(1) as f64)
    }
}
