//! Learning-rate schedules (paper supp. C).

/// LR as a function of training progress in [0, 1].
#[derive(Debug, Clone)]
pub enum Schedule {
    /// CIFAR recipe: init lr divided by 10 at fractional milestones
    /// (paper: epochs 150/200/320 of 350 -> ~0.43/0.57/0.91).
    Step { init: f32, milestones: Vec<f32> },
    /// ImageNet recipe: first-order polynomial (linear) anneal from
    /// `init` to `end`.
    Poly { init: f32, end: f32 },
    /// Fixed learning rate.
    Constant { lr: f32 },
}

impl Schedule {
    /// The CIFAR recipe (step drops at the paper's milestones).
    pub fn cifar_default() -> Schedule {
        Schedule::Step { init: 1e-2, milestones: vec![0.43, 0.57, 0.91] }
    }

    /// The ImageNet recipe (linear anneal).
    pub fn imagenet_default() -> Schedule {
        Schedule::Poly { init: 2e-4, end: 2e-8 }
    }

    /// Learning rate at training progress `[0, 1]` (clamped).
    pub fn lr(&self, progress: f32) -> f32 {
        let p = progress.clamp(0.0, 1.0);
        match self {
            Schedule::Step { init, milestones } => {
                let drops = milestones.iter().filter(|m| p >= **m).count() as i32;
                init * 0.1f32.powi(drops)
            }
            Schedule::Poly { init, end } => init + (end - init) * p,
            Schedule::Constant { lr } => *lr,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_drops_by_ten() {
        let s = Schedule::cifar_default();
        assert!((s.lr(0.0) - 1e-2).abs() < 1e-9);
        assert!((s.lr(0.5) - 1e-3).abs() < 1e-9);
        assert!((s.lr(0.6) - 1e-4).abs() < 1e-9);
        assert!((s.lr(0.95) - 1e-5).abs() < 1e-9);
    }

    #[test]
    fn poly_is_linear() {
        let s = Schedule::Poly { init: 1.0, end: 0.0 };
        assert!((s.lr(0.25) - 0.75).abs() < 1e-6);
    }

    #[test]
    fn progress_clamped() {
        let s = Schedule::Constant { lr: 0.1 };
        assert_eq!(s.lr(-1.0), 0.1);
        assert_eq!(s.lr(2.0), 0.1);
    }
}
