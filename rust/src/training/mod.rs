//! Training driver (S6): runs the AOT-lowered train-step executable in a
//! loop, feeding synthetic data batches and an LR/EDE schedule, keeping
//! all model state as device literals between steps (host copies only
//! for metrics, checkpoints and quantized export).
//!
//! The driver itself ([`Trainer`]) executes through PJRT and is gated on
//! the `pjrt` feature; checkpoints, schedules and the scheme mapping are
//! plain-CPU and always available.

mod checkpoint;
mod schedule;
#[cfg(feature = "pjrt")]
mod trainer;

pub use checkpoint::{load_checkpoint, save_checkpoint};
pub use schedule::Schedule;
#[cfg(feature = "pjrt")]
pub use trainer::Trainer;

use crate::quant::Scheme;

/// Scheme echo -> engine scheme.
pub fn scheme_from_config(scheme: &str, delta_frac: f64, regions: usize) -> Scheme {
    match scheme {
        "fp" => Scheme::Fp,
        "binary" => Scheme::Binary,
        "ternary" => Scheme::Ternary { delta_frac: delta_frac as f32 },
        "sb" => Scheme::SignedBinary {
            delta_frac: delta_frac as f32,
            regions_per_filter: regions,
        },
        other => panic!("unknown scheme {other}"),
    }
}

/// One (step, loss, acc) sample of the training curve.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    /// training step the sample was taken at
    pub step: u64,
    /// train loss at the step
    pub loss: f32,
    /// eval accuracy at the step
    pub acc: f32,
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainLog {
    /// sampled training curve
    pub curve: Vec<CurvePoint>,
    /// loss at the final step
    pub final_train_loss: f32,
    /// final eval accuracy
    pub eval_acc: f32,
    /// steps run
    pub steps: u64,
    /// wall-clock seconds of the run
    pub wall_secs: f64,
}
