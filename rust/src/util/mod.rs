//! Small shared substrates: JSON codec, deterministic RNG, bench
//! harness, persistent worker pool ([`pool`]), and the thread-cached
//! scratch buffers ([`scratch`]) the executors draw per-tile arenas
//! from.

pub mod bench;
pub mod json;
pub mod pool;
pub mod rng;
pub mod scratch;

pub use json::Json;
pub use pool::{Pool, UnsafeSlice};
pub use rng::Rng;
pub use scratch::ScratchVec;
