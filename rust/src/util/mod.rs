//! Small shared substrates: JSON codec, deterministic RNG, bench harness.

pub mod bench;
pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
