//! Thread-cached f32 scratch buffers.
//!
//! The tiled executors allocate per-worker scratch (patch tiles, partial
//! sums) inside every pool dispatch. On the multi-layer serving path
//! that would mean fresh heap allocations for every layer of every
//! request, so dropped [`ScratchVec`]s park their backing storage in a
//! thread-local cache instead: the pool's workers are persistent, and a
//! steady-state forward pass reuses the same capacity dispatch after
//! dispatch. Contents are *not* cleared between uses — callers must
//! fully overwrite (or zero) what they read, exactly like the executor
//! scratch contract.

use std::cell::RefCell;

thread_local! {
    static CACHE: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

/// Buffers parked per thread; the executors hold at most three at once,
/// so a small cap bounds memory on long-lived worker threads.
const MAX_CACHED: usize = 8;

/// An owned `Vec<f32>` whose storage returns to the thread-local cache
/// on drop. Dereferences to `[f32]` at exactly the requested length.
pub struct ScratchVec(Vec<f32>);

impl ScratchVec {
    /// Take a buffer of exactly `len` elements, reusing cached storage
    /// when available. New elements are zero-filled; recycled elements
    /// keep their previous contents (see module docs).
    pub fn take(len: usize) -> ScratchVec {
        let mut v = CACHE
            .with(|c| c.borrow_mut().pop())
            .unwrap_or_default();
        v.resize(len, 0.0);
        ScratchVec(v)
    }
}

impl Drop for ScratchVec {
    fn drop(&mut self) {
        let v = std::mem::take(&mut self.0);
        CACHE.with(|c| {
            let mut cache = c.borrow_mut();
            if cache.len() < MAX_CACHED {
                cache.push(v);
            }
        });
    }
}

impl std::ops::Deref for ScratchVec {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.0
    }
}

impl std::ops::DerefMut for ScratchVec {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_has_requested_len_and_zeroed_growth() {
        let s = ScratchVec::take(16);
        assert_eq!(s.len(), 16);
        // a fresh buffer is all zeros
        assert!(s.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn storage_is_reused_across_take_drop_cycles() {
        let ptr = {
            let mut s = ScratchVec::take(32);
            s[0] = 7.0;
            s.as_ptr()
        };
        // same length -> resize cannot reallocate -> same storage
        let s = ScratchVec::take(32);
        assert_eq!(s.as_ptr(), ptr, "scratch storage was not recycled");
        // recycled contents are stale by contract
        assert_eq!(s[0], 7.0);
    }

    #[test]
    fn shrinking_keeps_capacity_growing_zero_fills() {
        {
            let mut big = ScratchVec::take(64);
            big.iter_mut().for_each(|v| *v = 1.0);
        }
        let small = ScratchVec::take(8);
        assert_eq!(small.len(), 8);
        drop(small);
        let grown = ScratchVec::take(20);
        assert_eq!(grown.len(), 20);
        // resize truncated to 8, so regrowth past that point zero-fills
        assert!(grown[8..].iter().all(|v| *v == 0.0));
    }
}
