//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Follows the paper's methodology (supp. A): each measurement is repeated
//! `reps` times on an unloaded machine and the *minimum* wall time is
//! reported, plus median/mean for context. Used by `cargo bench` targets
//! (which are `harness = false` binaries) and the CLI bench subcommands.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Minimum over reps — the paper's reported statistic.
    pub min_ns: u64,
    pub median_ns: u64,
    pub mean_ns: u64,
    pub reps: usize,
}

impl BenchResult {
    pub fn min_ms(&self) -> f64 {
        self.min_ns as f64 / 1e6
    }

    pub fn row(&self) -> String {
        format!(
            "{:<40} min {:>10.3} ms   median {:>10.3} ms   mean {:>10.3} ms   ({} reps)",
            self.name,
            self.min_ns as f64 / 1e6,
            self.median_ns as f64 / 1e6,
            self.mean_ns as f64 / 1e6,
            self.reps
        )
    }
}

/// Run `f` `reps` times after `warmup` unmeasured calls; report min/median/mean.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<u64> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as u64);
    }
    times.sort_unstable();
    let min_ns = times[0];
    let median_ns = times[times.len() / 2];
    let mean_ns = times.iter().sum::<u64>() / times.len() as u64;
    BenchResult { name: name.to_string(), min_ns, median_ns, mean_ns, reps }
}

/// Black-box to keep the optimizer from eliding benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordering() {
        let r = bench("noop", 1, 16, || {
            black_box(1 + 1);
        });
        assert!(r.min_ns <= r.median_ns);
        assert!(r.reps == 16);
    }
}
