//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Follows the paper's methodology (supp. A): each measurement is repeated
//! `reps` times on an unloaded machine and the *minimum* wall time is
//! reported, plus median/mean for context. Used by `cargo bench` targets
//! (which are `harness = false` binaries) and the CLI bench subcommands.
//!
//! Bench binaries persist their key series as machine-readable JSON
//! (`BENCH_<target>.json`, see [`write_bench_json`]) so the perf
//! trajectory can be tracked across commits.

use std::path::Path;
use std::time::Instant;

use super::json::{self, Json};

/// Timing summary of one benchmarked closure.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// label the measurement was taken under
    pub name: String,
    /// Minimum over reps — the paper's reported statistic.
    pub min_ns: u64,
    /// median over reps
    pub median_ns: u64,
    /// mean over reps
    pub mean_ns: u64,
    /// measured repetitions (warmup excluded)
    pub reps: usize,
}

impl BenchResult {
    /// Minimum time in milliseconds.
    pub fn min_ms(&self) -> f64 {
        self.min_ns as f64 / 1e6
    }

    /// One formatted report line (min / median / mean).
    pub fn row(&self) -> String {
        format!(
            "{:<40} min {:>10.3} ms   median {:>10.3} ms   mean {:>10.3} ms   ({} reps)",
            self.name,
            self.min_ns as f64 / 1e6,
            self.median_ns as f64 / 1e6,
            self.mean_ns as f64 / 1e6,
            self.reps
        )
    }
}

/// Run `f` `reps` times after `warmup` unmeasured calls; report min/median/mean.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<u64> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as u64);
    }
    times.sort_unstable();
    let min_ns = times[0];
    let median_ns = times[times.len() / 2];
    let mean_ns = times.iter().sum::<u64>() / times.len() as u64;
    BenchResult { name: name.to_string(), min_ns, median_ns, mean_ns, reps }
}

/// Black-box to keep the optimizer from eliding benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One machine-readable measurement of a bench series.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    /// operation id, e.g. "engine_sb", "dense_gemm"
    pub op: String,
    /// workload shape, e.g. "64x64x28x28 3x3"
    pub shape: String,
    /// pool width the measurement ran at
    pub threads: usize,
    /// minimum wall time over reps
    pub min_ns: u64,
    /// dense-equivalent GFLOP/s
    pub gflops: f64,
}

impl BenchRecord {
    /// The persisted JSON form ([`write_bench_json`]).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("op", json::s(&self.op)),
            ("shape", json::s(&self.shape)),
            ("threads", json::num(self.threads as f64)),
            ("min_ns", json::num(self.min_ns as f64)),
            ("gflops", json::num(self.gflops)),
        ])
    }

    /// Parse one record back from its persisted JSON form.
    pub fn from_json(j: &Json) -> anyhow::Result<BenchRecord> {
        Ok(BenchRecord {
            op: j.req_str("op")?.to_string(),
            shape: j.req_str("shape")?.to_string(),
            threads: j.req_usize("threads")?,
            min_ns: j.req_usize("min_ns")? as u64,
            gflops: j.req_f64("gflops")?,
        })
    }
}

/// Persist a bench series as `{"records": [...]}` — the format tooling
/// and EXPERIMENTS.md diffs consume (one file per bench target, e.g.
/// `BENCH_repetition.json`).
pub fn write_bench_json(path: &Path, records: &[BenchRecord]) -> std::io::Result<()> {
    let j = json::obj(vec![(
        "records",
        Json::Arr(records.iter().map(BenchRecord::to_json).collect()),
    )]);
    std::fs::write(path, j.to_string())
}

/// Load a bench series written by [`write_bench_json`].
pub fn read_bench_json(path: &Path) -> anyhow::Result<Vec<BenchRecord>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
    j.req_arr("records")?.iter().map(BenchRecord::from_json).collect()
}

/// Compare a fresh bench series against a committed baseline and return
/// one human-readable line per regression (empty = pass). Records are
/// matched by `(op, shape, threads)`; a record that regresses by more
/// than `tolerance` (fractional, e.g. `0.25` = 25%) fails:
///
/// * throughput records (`gflops > 0` in the baseline) fail when
///   current GFLOP/s drops below `baseline * (1 - tolerance)`;
/// * time-only records fail when current min time exceeds
///   `baseline * (1 + tolerance)`.
///
/// Baseline records missing from the current series are regressions too
/// (a silently dropped series must not pass CI); *extra* current
/// records are ignored so new studies can land before their baseline.
///
/// ```
/// use plum::util::bench::{compare_bench, BenchRecord};
///
/// let base = vec![BenchRecord {
///     op: "engine_sb".into(),
///     shape: "64x64x28x28 3x3".into(),
///     threads: 1,
///     min_ns: 1_000_000,
///     gflops: 4.0,
/// }];
/// let mut cur = base.clone();
/// cur[0].gflops = 3.5; // within 25% of baseline -> passes
/// assert!(compare_bench(&base, &cur, 0.25).is_empty());
/// cur[0].gflops = 1.0; // collapse -> flagged
/// assert_eq!(compare_bench(&base, &cur, 0.25).len(), 1);
/// ```
pub fn compare_bench(
    baseline: &[BenchRecord],
    current: &[BenchRecord],
    tolerance: f64,
) -> Vec<String> {
    let mut regressions = Vec::new();
    for b in baseline {
        let Some(c) = current
            .iter()
            .find(|c| c.op == b.op && c.shape == b.shape && c.threads == b.threads)
        else {
            regressions.push(format!(
                "{} [{}] t{}: record missing from current series",
                b.op, b.shape, b.threads
            ));
            continue;
        };
        if b.gflops > 0.0 {
            let floor = b.gflops * (1.0 - tolerance);
            if c.gflops < floor {
                regressions.push(format!(
                    "{} [{}] t{}: {:.3} GFLOP/s < baseline {:.3} - {:.0}% = {:.3}",
                    b.op,
                    b.shape,
                    b.threads,
                    c.gflops,
                    b.gflops,
                    tolerance * 100.0,
                    floor
                ));
            }
        } else {
            let ceil = b.min_ns as f64 * (1.0 + tolerance);
            if c.min_ns as f64 > ceil {
                regressions.push(format!(
                    "{} [{}] t{}: {} ns > baseline {} ns + {:.0}%",
                    b.op,
                    b.shape,
                    b.threads,
                    c.min_ns,
                    b.min_ns,
                    tolerance * 100.0
                ));
            }
        }
    }
    regressions
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordering() {
        let r = bench("noop", 1, 16, || {
            black_box(1 + 1);
        });
        assert!(r.min_ns <= r.median_ns);
        assert!(r.reps == 16);
    }

    #[test]
    fn compare_flags_regressions_and_missing_records() {
        let rec = |op: &str, threads: usize, min_ns: u64, gflops: f64| BenchRecord {
            op: op.into(),
            shape: "64x64x28x28 3x3".into(),
            threads,
            min_ns,
            gflops,
        };
        let baseline = vec![
            rec("engine_sb", 1, 1_000_000, 4.0),
            rec("engine_sb", 4, 300_000, 13.0),
            rec("plan_build", 1, 2_000_000, 0.0),
        ];
        // within tolerance: slightly slower engine, slightly slower build
        let ok = vec![
            rec("engine_sb", 1, 1_200_000, 3.4),
            rec("engine_sb", 4, 320_000, 12.0),
            rec("plan_build", 1, 2_300_000, 0.0),
        ];
        assert!(compare_bench(&baseline, &ok, 0.25).is_empty());
        // 50% gflops drop on one record + missing another + slow build
        let bad = vec![
            rec("engine_sb", 1, 2_000_000, 2.0),
            rec("plan_build", 1, 3_000_000, 0.0),
        ];
        let regs = compare_bench(&baseline, &bad, 0.25);
        assert_eq!(regs.len(), 3, "{regs:?}");
        // extra current records never fail the gate
        let extra = vec![
            rec("engine_sb", 1, 1_000_000, 4.0),
            rec("engine_sb", 4, 300_000, 13.0),
            rec("plan_build", 1, 2_000_000, 0.0),
            rec("new_study", 8, 1, 100.0),
        ];
        assert!(compare_bench(&baseline, &extra, 0.25).is_empty());
    }

    #[test]
    fn bench_json_read_roundtrip() {
        let recs = vec![BenchRecord {
            op: "plan_build".into(),
            shape: "resnet18 16x3x3 layers".into(),
            threads: 2,
            min_ns: 5_000_000,
            gflops: 1.25,
        }];
        let path = std::env::temp_dir().join("plum_bench_read_test.json");
        write_bench_json(&path, &recs).unwrap();
        assert_eq!(read_bench_json(&path).unwrap(), recs);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bench_record_json_roundtrip() {
        let recs = vec![
            BenchRecord {
                op: "engine_sb".into(),
                shape: "64x64x28x28 3x3".into(),
                threads: 4,
                min_ns: 1_250_000,
                gflops: 3.5,
            },
            BenchRecord {
                op: "dense_gemm".into(),
                shape: "64x64x28x28 3x3".into(),
                threads: 1,
                min_ns: 9_000_000,
                gflops: 0.5,
            },
        ];
        let path = std::env::temp_dir().join("plum_bench_json_test.json");
        write_bench_json(&path, &recs).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let back: Vec<BenchRecord> = j
            .req_arr("records")
            .unwrap()
            .iter()
            .map(|r| BenchRecord::from_json(r).unwrap())
            .collect();
        assert_eq!(back, recs);
        std::fs::remove_file(&path).ok();
    }
}
