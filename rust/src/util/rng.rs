//! Deterministic PRNG (SplitMix64 core + helpers).
//!
//! The `rand` crate is not in the offline vendor set; everything in this
//! repo that needs randomness (synthetic datasets, workload generators,
//! property tests) uses this small generator so results are reproducible
//! from a single u64 seed.

/// SplitMix64: tiny, fast, passes BigCrush; ideal for seeding/streams.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator; equal seeds produce equal streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (e.g. per-sample, per-layer).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut r = Rng::new(self.state ^ stream.wrapping_mul(0xA24BAED4963EE407));
        r.next_u64(); // decorrelate
        r
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-7);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fill a slice with N(0, std).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Bernoulli with probability p.
    pub fn coin(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let r = Rng::new(1);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
