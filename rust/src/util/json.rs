//! Minimal JSON codec (parser + writer).
//!
//! serde is not available in the offline vendor set, and the artifact
//! manifests are plain JSON, so the repo carries its own small, strict
//! RFC 8259 subset implementation: objects, arrays, strings (with the
//! standard escapes incl. \uXXXX — surrogate pairs decode to their
//! astral code point, lone surrogates are rejected), f64 numbers, bool,
//! null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are ordered (BTreeMap) so output is
/// deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// every JSON number, kept as f64
    Num(f64),
    /// a string (escapes already decoded)
    Str(String),
    /// an array
    Arr(Vec<Json>),
    /// an object, keys sorted
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset it occurred at.
#[derive(Debug)]
pub struct JsonError {
    /// what went wrong
    pub msg: String,
    /// byte offset into the input
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse one complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors ----------------------------------------------------

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key-value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required string field, with a decent error message.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field '{key}'"))
    }

    /// Required non-negative integer field.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }

    /// Required numeric field.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }

    /// Required array field.
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing array field '{key}'"))
    }

    // ---- writer ---------------------------------------------------------------

    /// Serialize to compact JSON text (deterministic: object keys are
    /// sorted).
    #[allow(clippy::inherent_to_string)] // serialization, not Display formatting
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience object constructor for report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience number constructor for report writers.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// Convenience string constructor for report writers.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1; // past 'u'; hex4 consumes the digits
                            let cp = self.unicode_escape()?;
                            out.push(cp);
                            // unicode_escape leaves `i` past its last hex
                            // digit; skip the shared `+ 1` below
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let rest = &self.b[self.i..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("bad utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    /// Four hex digits at the cursor (`u32::from_str_radix` alone would
    /// also admit signs like `+1f0`); advances past them.
    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let raw = &self.b[self.i..self.i + 4];
        if !raw.iter().all(u8::is_ascii_hexdigit) {
            return Err(self.err("bad \\u escape"));
        }
        let hex = std::str::from_utf8(raw).map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    /// Decode the code point of a `\uXXXX` escape whose `\u` has been
    /// consumed. BMP scalars decode directly; a high surrogate must be
    /// followed by `\uDC00..=\uDFFF` and the pair combines into the
    /// astral scalar (RFC 8259 §7 — strings may carry any code point via
    /// UTF-16 escapes, and bench/manifest JSON can name models with
    /// emoji). Lone surrogates in either order are rejected instead of
    /// being silently replaced.
    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        let hi = self.hex4()?;
        let cp = match hi {
            0xD800..=0xDBFF => {
                // high surrogate: a \uXXXX low surrogate must follow
                if self.b.get(self.i) != Some(&b'\\') || self.b.get(self.i + 1) != Some(&b'u') {
                    return Err(self.err("unpaired high surrogate"));
                }
                self.i += 2;
                let lo = self.hex4()?;
                if !(0xDC00..=0xDFFF).contains(&lo) {
                    return Err(self.err("unpaired high surrogate"));
                }
                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
            }
            0xDC00..=0xDFFF => return Err(self.err("unpaired low surrogate")),
            cp => cp,
        };
        // surrogate ranges handled above, so this cannot fail
        char::from_u32(cp).ok_or_else(|| self.err("bad \\u escape"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"k":[1,2.5,"s",true,null],"z":{"q":-3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn surrogate_pairs_decode_to_astral_code_points() {
        // U+1F600 GRINNING FACE as a UTF-16 escape pair
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("😀".into())
        );
        // pair embedded between other content
        assert_eq!(
            Json::parse("\"a\\ud83d\\ude00b\"").unwrap(),
            Json::Str("a😀b".into())
        );
        // raw (unescaped) astral scalars round-trip through the writer
        let v = Json::Str("model-😀-v2".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        // and an escaped pair survives a full parse -> write -> parse trip
        let w = Json::parse("{\"name\":\"\\ud83d\\ude00\"}").unwrap();
        assert_eq!(Json::parse(&w.to_string()).unwrap(), w);
        assert_eq!(w.req_str("name").unwrap(), "😀");
    }

    #[test]
    fn lone_surrogates_are_rejected() {
        // high surrogate at end of string
        assert!(Json::parse("\"\\ud83d\"").is_err());
        // high surrogate followed by a non-escape
        assert!(Json::parse("\"\\ud83dx\"").is_err());
        // high surrogate followed by a non-surrogate escape
        assert!(Json::parse("\"\\ud83d\\u0041\"").is_err());
        // low surrogate first
        assert!(Json::parse("\"\\ude00\"").is_err());
        // signs are not hex digits (from_str_radix alone accepts "+...")
        assert!(Json::parse("\"\\u+041\"").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
    }
}
