//! Dependency-free scoped-thread worker pool.
//!
//! rayon/crossbeam are not in the offline vendor set, so the parallel
//! hot paths (tiled repetition executor, blocked GEMM) share this small
//! pool built on `std::thread::scope`:
//!
//! * work is expressed as `jobs` indexed items; workers pull the next
//!   index from a shared atomic counter (self-balancing — a slow tile
//!   does not stall the other workers);
//! * each worker builds its scratch state once via `init` and reuses it
//!   across every job it claims (`run_with`), so per-tile arenas are
//!   allocated `threads` times, not `jobs` times;
//! * what gets computed for job `j` depends only on `j`, never on which
//!   worker claims it, so results are bit-identical for every thread
//!   count — the engine's N-thread output equals its 1-thread output.
//!
//! The default pool size is `std::thread::available_parallelism`,
//! overridable with `PLUM_THREADS` (e.g. `PLUM_THREADS=1` to force the
//! serial path for A/B timing).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// A fixed-width scoped-thread pool. Threads live only for the duration
/// of each `run*` call (scoped), so the pool itself is just a width.
#[derive(Debug, Clone)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool with an explicit width (clamped to >= 1).
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// Process-wide pool: `PLUM_THREADS` env override, else
    /// `available_parallelism`, else 1.
    pub fn global() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        POOL.get_or_init(|| {
            let threads = std::env::var("PLUM_THREADS")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|t| *t > 0)
                .unwrap_or_else(|| {
                    std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(1)
                });
            Pool::new(threads)
        })
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run jobs `0..jobs` across the pool. Each worker calls `init` once
    /// for its private scratch, then claims job indices off a shared
    /// counter until none remain. With one thread (or one job) everything
    /// runs inline on the caller's thread — no spawn overhead.
    pub fn run_with<S, I, F>(&self, jobs: usize, init: I, f: F)
    where
        I: Fn() -> S + Sync,
        F: Fn(&mut S, usize) + Sync,
    {
        if jobs == 0 {
            return;
        }
        let workers = self.threads.min(jobs);
        if workers <= 1 {
            let mut scratch = init();
            for j in 0..jobs {
                f(&mut scratch, j);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    let mut scratch = init();
                    loop {
                        let j = next.fetch_add(1, Ordering::Relaxed);
                        if j >= jobs {
                            break;
                        }
                        f(&mut scratch, j);
                    }
                });
            }
        });
    }

    /// Scratch-free variant of [`Pool::run_with`].
    pub fn run<F>(&self, jobs: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.run_with(jobs, || (), |_, j| f(j));
    }
}

/// Shared mutable view of an `f32` buffer for workers that write
/// *disjoint* index sets (the conv executor's output scatter is strided
/// across filter planes, so per-job regions are disjoint but not
/// contiguous — they cannot be handed out as `split_at_mut` slices).
///
/// All methods are `unsafe`: the caller must guarantee that no index is
/// written by two jobs and nothing reads the buffer until the pool run
/// returns. Both executors uphold this by partitioning over output
/// pixels (executor) or row blocks (GEMM).
#[derive(Clone, Copy)]
pub struct UnsafeSlice<'a> {
    ptr: *mut f32,
    len: usize,
    marker: std::marker::PhantomData<&'a mut [f32]>,
}

unsafe impl Send for UnsafeSlice<'_> {}
unsafe impl Sync for UnsafeSlice<'_> {}

impl<'a> UnsafeSlice<'a> {
    pub fn new(data: &'a mut [f32]) -> UnsafeSlice<'a> {
        UnsafeSlice {
            ptr: data.as_mut_ptr(),
            len: data.len(),
            marker: std::marker::PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write one element.
    ///
    /// # Safety
    /// `i` must be in bounds and not concurrently written by any other
    /// job of the same pool run.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: f32) {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) = v }
    }

    /// Reborrow a contiguous sub-range as `&mut [f32]`.
    ///
    /// # Safety
    /// Ranges handed to concurrently-running jobs must not overlap.
    #[inline]
    #[allow(clippy::mut_from_ref)] // aliasing contract is the Safety section
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &'a mut [f32] {
        debug_assert!(start + len <= self.len);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), len) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_covers_every_job_exactly_once() {
        for threads in [1, 2, 4] {
            let pool = Pool::new(threads);
            let hits: Vec<AtomicUsize> = (0..57).map(|_| AtomicUsize::new(0)).collect();
            pool.run(hits.len(), |j| {
                hits[j].fetch_add(1, Ordering::SeqCst);
            });
            for (j, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "job {j} at {threads} threads");
            }
        }
    }

    #[test]
    fn run_with_reuses_scratch_per_worker() {
        let pool = Pool::new(3);
        let inits = AtomicUsize::new(0);
        pool.run_with(
            64,
            || {
                inits.fetch_add(1, Ordering::SeqCst);
                0usize
            },
            |s, _| *s += 1,
        );
        let n = inits.load(Ordering::SeqCst);
        assert!(n <= 3, "scratch built {n} times for a 3-thread pool");
    }

    #[test]
    fn zero_jobs_is_a_noop() {
        Pool::new(4).run(0, |_| panic!("no jobs to run"));
    }

    #[test]
    fn unsafe_slice_disjoint_writes() {
        let mut buf = vec![0.0f32; 100];
        let pool = Pool::new(4);
        let out = UnsafeSlice::new(&mut buf);
        pool.run(100, |j| unsafe { out.write(j, j as f32) });
        for (j, v) in buf.iter().enumerate() {
            assert_eq!(*v, j as f32);
        }
    }

    #[test]
    fn global_pool_is_usable() {
        let pool = Pool::global();
        assert!(pool.threads() >= 1);
        let sum = AtomicUsize::new(0);
        pool.run(10, |j| {
            sum.fetch_add(j, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
    }
}
