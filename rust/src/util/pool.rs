//! Dependency-free persistent worker pool.
//!
//! rayon/crossbeam are not in the offline vendor set, so the parallel
//! hot paths (tiled repetition executor, blocked GEMM, parallel plan
//! build) share this small pool. Workers are spawned **once per
//! `Pool`** and parked on a condvar between dispatches: a `run_with`
//! call publishes one type-erased task, enlists `min(jobs, threads) - 1`
//! workers (a tiny dispatch never stalls on the whole pool cycling),
//! participates in the work itself, then waits for the stragglers.
//! Small-layer and serving-path dispatches therefore pay a condvar
//! wakeup, not a thread spawn (the scoped spawn-per-call pool this
//! replaces paid `threads` spawns + joins on every layer).
//!
//! The execution contract is unchanged:
//!
//! * work is `jobs` indexed items; participants pull the next index
//!   from a shared atomic counter (self-balancing — a slow tile does
//!   not stall the other workers);
//! * each participant builds its scratch lazily via `init` on its first
//!   claimed job and reuses it across every job it claims (`run_with`),
//!   so per-tile arenas are allocated at most `threads` times, not
//!   `jobs` times;
//! * what gets computed for job `j` depends only on `j`, never on which
//!   worker claims it, so results are bit-identical for every thread
//!   count — the engine's N-thread output equals its 1-thread output;
//! * a panic inside a job (or `init`) cancels the remaining jobs and is
//!   re-raised on the dispatching thread once every worker has
//!   quiesced; the pool stays usable afterwards;
//! * concurrent `run*` calls from different threads serialize on the
//!   pool (one CPU's worth of workers — overlapping them would only
//!   oversubscribe); a re-entrant call from inside a pool job runs
//!   inline on the calling worker.
//!
//! The default pool size is `std::thread::available_parallelism`,
//! overridable with `PLUM_THREADS` (e.g. `PLUM_THREADS=1` to force the
//! serial path for A/B timing) or programmatically via
//! [`Pool::init_global`] (the CLI's `--threads` flag).

use std::any::Any;
use std::cell::Cell;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;

thread_local! {
    /// True while this thread is executing a pool job — used to run
    /// re-entrant dispatches inline instead of deadlocking on the
    /// (busy) workers.
    static IN_POOL_JOB: Cell<bool> = const { Cell::new(false) };
}

/// Lock that shrugs off poisoning: jobs panic inside `catch_unwind`, so
/// a poisoned mutex only ever means "a previous dispatch panicked", not
/// "the protected state is torn".
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One published dispatch: a type-erased pointer to the dispatching
/// thread's stack-held [`RunState`] plus the monomorphized entry point
/// that claims job indices from it.
///
/// The pointer is only dereferenced by workers between the dispatch
/// being published and `active` reaching zero — and the dispatching
/// thread does not drop the `RunState` (or return) until it has
/// observed `active == 0`, so the pointer never dangles while visible.
#[derive(Clone, Copy)]
struct Task {
    data: *const (),
    // SAFETY invariant: callers of `run` must pass the `data` pointer of
    // the same `Task`, which points at the live `RunState` the
    // monomorphized trampoline expects (see `run_erased`).
    run: unsafe fn(*const ()),
}

// SAFETY: `data` points at a `RunState` whose shared parts are only the
// atomic job counter, `Sync` closures, and a mutex — see `Task` docs
// for the lifetime argument.
unsafe impl Send for Task {}

/// Worker-visible dispatch state, guarded by `Inner::state`.
struct Dispatch {
    /// Bumped once per published task. A worker acts on a generation at
    /// most once (it can never lag a full generation behind, because
    /// the dispatcher waits for the generation to quiesce before
    /// publishing the next one).
    generation: u64,
    task: Option<Task>,
    /// Worker participation slots left in the current generation — a
    /// dispatch involves only `min(jobs, threads) - 1` workers, so a
    /// 2-job dispatch on a wide pool does not stall on the whole pool
    /// cycling through the mutex.
    slots: usize,
    /// Workers still executing the current generation.
    active: usize,
    shutdown: bool,
}

struct Inner {
    state: Mutex<Dispatch>,
    /// Workers park here waiting for a new generation (or shutdown).
    work_cv: Condvar,
    /// The dispatching thread parks here waiting for `active == 0`.
    done_cv: Condvar,
}

fn worker_main(inner: Arc<Inner>) {
    let mut seen = 0u64;
    loop {
        let task = {
            let mut st = lock(&inner.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != seen {
                    seen = st.generation;
                    if st.slots > 0 {
                        st.slots -= 1;
                        break st.task.expect("task published for active generation");
                    }
                    // generation already has its full complement of
                    // participants — sit this one out
                }
                st = inner.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        IN_POOL_JOB.with(|f| f.set(true));
        // SAFETY: the dispatcher keeps the RunState alive until this
        // worker decrements `active` below, and `task.data` is the
        // pointer `task.run` was monomorphized for.
        unsafe { (task.run)(task.data) };
        IN_POOL_JOB.with(|f| f.set(false));
        let mut st = lock(&inner.state);
        st.active -= 1;
        if st.active == 0 {
            inner.done_cv.notify_all();
        }
    }
}

/// Shared state of one `run_with` dispatch, held on the dispatching
/// thread's stack and handed to workers as a type-erased pointer.
struct RunState<S, I, F> {
    next: AtomicUsize,
    jobs: usize,
    init: *const I,
    f: *const F,
    /// First panic payload from any participant, re-raised by the
    /// dispatcher after the run quiesces.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    _scratch: PhantomData<fn() -> S>,
}

impl<S, I, F> RunState<S, I, F>
where
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) + Sync,
{
    /// Claim and run job indices until none remain. Scratch is built
    /// lazily so workers that lose the race for a short job list never
    /// pay `init`. Panics are captured, cancel the remaining jobs, and
    /// are re-raised by the dispatcher.
    fn execute(&self) {
        // SAFETY: `init`/`f` outlive the dispatch (they live in the
        // `run_with` frame that waits for all participants).
        let (init, f) = unsafe { (&*self.init, &*self.f) };
        let res = catch_unwind(AssertUnwindSafe(|| {
            let mut scratch: Option<S> = None;
            loop {
                let j = self.next.fetch_add(1, Ordering::Relaxed);
                if j >= self.jobs {
                    break;
                }
                let s = scratch.get_or_insert_with(init);
                f(s, j);
            }
        }));
        if let Err(payload) = res {
            // cancel the remaining jobs; keep only the first payload
            self.next.store(self.jobs, Ordering::Relaxed);
            let mut slot = lock(&self.panic);
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
    }
}

/// Monomorphized trampoline stored in [`Task::run`].
///
/// # Safety
/// `data` must point at a live `RunState<S, I, F>` of exactly these
/// type parameters.
unsafe fn run_erased<S, I, F>(data: *const ())
where
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) + Sync,
{
    // SAFETY: guaranteed by this function's contract — `data` is the
    // `Task::data` pointer published alongside this very trampoline, so
    // the type parameters match and the `RunState` is kept alive by the
    // dispatching `run_with` frame.
    let run = unsafe { &*(data as *const RunState<S, I, F>) };
    run.execute();
}

/// A fixed-width pool of persistent worker threads. `threads - 1`
/// workers are spawned at construction and parked between dispatches;
/// the dispatching thread acts as the final worker. Width-1 pools spawn
/// nothing and always run inline.
pub struct Pool {
    threads: usize,
    inner: Option<Arc<Inner>>,
    handles: Vec<JoinHandle<()>>,
    /// Serializes dispatches from different caller threads.
    run_lock: Mutex<()>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("threads", &self.threads).finish()
    }
}

impl Pool {
    /// Pool with an explicit width (clamped to >= 1). Spawns its
    /// `threads - 1` persistent workers immediately.
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let mut pool = Pool {
            threads,
            inner: None,
            handles: Vec::new(),
            run_lock: Mutex::new(()),
        };
        if threads > 1 {
            let inner = Arc::new(Inner {
                state: Mutex::new(Dispatch {
                    generation: 0,
                    task: None,
                    slots: 0,
                    active: 0,
                    shutdown: false,
                }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
            });
            for _ in 0..threads - 1 {
                let inner = Arc::clone(&inner);
                pool.handles.push(std::thread::spawn(move || worker_main(inner)));
            }
            pool.inner = Some(inner);
        }
        pool
    }

    /// Process-wide pool: `PLUM_THREADS` env override, else
    /// `available_parallelism`, else 1. Built lazily on first use;
    /// [`Pool::init_global`] can pin the width before that.
    pub fn global() -> &'static Pool {
        GLOBAL_POOL.get_or_init(|| Pool::new(default_global_threads()))
    }

    /// Pin the process-wide pool width (the CLI's `--threads` flag; the
    /// programmatic equivalent of `PLUM_THREADS`). Must run before the
    /// first [`Pool::global`] dispatch: once the global pool exists with
    /// a different width this fails, because resizing a live pool would
    /// invalidate in-flight timing comparisons.
    pub fn init_global(threads: usize) -> Result<(), String> {
        let want = threads.max(1);
        let pool = GLOBAL_POOL.get_or_init(|| Pool::new(want));
        if pool.threads() == want {
            Ok(())
        } else {
            Err(format!(
                "global pool already initialized with {} threads (wanted {want})",
                pool.threads()
            ))
        }
    }

    /// Fixed width of this pool (dispatcher included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run jobs `0..jobs` across the pool. Each participant calls
    /// `init` once (lazily, before its first job) for its private
    /// scratch, then claims job indices off a shared counter until none
    /// remain. Width-1 pools, single jobs, and re-entrant calls from
    /// inside a pool job all run inline on the caller's thread.
    pub fn run_with<S, I, F>(&self, jobs: usize, init: I, f: F)
    where
        I: Fn() -> S + Sync,
        F: Fn(&mut S, usize) + Sync,
    {
        if jobs == 0 {
            return;
        }
        let inner = match &self.inner {
            Some(inner) if jobs > 1 && !IN_POOL_JOB.with(Cell::get) => inner,
            _ => {
                let mut scratch = init();
                for j in 0..jobs {
                    f(&mut scratch, j);
                }
                return;
            }
        };

        let run = RunState::<S, I, F> {
            next: AtomicUsize::new(0),
            jobs,
            init: &init,
            f: &f,
            panic: Mutex::new(None),
            _scratch: PhantomData,
        };
        let task = Task {
            data: &run as *const RunState<S, I, F> as *const (),
            run: run_erased::<S, I, F>,
        };

        // the dispatcher is one participant; only enough workers to
        // cover the remaining jobs are enlisted
        let helpers = self.threads.min(jobs) - 1;
        let _dispatch = lock(&self.run_lock);
        {
            let mut st = lock(&inner.state);
            st.generation = st.generation.wrapping_add(1);
            st.task = Some(task);
            st.slots = helpers;
            st.active = helpers;
            if 2 * helpers >= self.handles.len() {
                inner.work_cv.notify_all();
            } else {
                for _ in 0..helpers {
                    inner.work_cv.notify_one();
                }
            }
        }
        // the dispatching thread is the final worker; mark it as inside
        // a pool job so nested dispatches run inline
        let was_in_job = IN_POOL_JOB.with(|c| c.replace(true));
        run.execute();
        IN_POOL_JOB.with(|c| c.set(was_in_job));
        {
            let mut st = lock(&inner.state);
            while st.active > 0 {
                st = inner.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            st.task = None;
        }
        // `run` is only dropped (and `run_with` only returns) after
        // every worker has quiesced — the Task pointer never dangles
        if let Some(payload) = lock(&run.panic).take() {
            resume_unwind(payload);
        }
    }

    /// Scratch-free variant of [`Pool::run_with`].
    pub fn run<F>(&self, jobs: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.run_with(jobs, || (), |_, j| f(j));
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        if let Some(inner) = &self.inner {
            {
                let mut st = lock(&inner.state);
                st.shutdown = true;
                inner.work_cv.notify_all();
            }
            for h in self.handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

static GLOBAL_POOL: OnceLock<Pool> = OnceLock::new();

fn default_global_threads() -> usize {
    std::env::var("PLUM_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|t| *t > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Shared mutable view of an `f32` buffer for workers that write
/// *disjoint* index sets (the conv executor's output scatter is strided
/// across filter planes, so per-job regions are disjoint but not
/// contiguous — they cannot be handed out as `split_at_mut` slices).
///
/// All methods are `unsafe`: the caller must guarantee that no index is
/// written by two jobs and nothing reads the buffer until the pool run
/// returns. Both executors uphold this by partitioning over output
/// pixels (executor) or row blocks (GEMM).
#[derive(Clone, Copy)]
pub struct UnsafeSlice<'a> {
    ptr: *mut f32,
    len: usize,
    marker: std::marker::PhantomData<&'a mut [f32]>,
}

// SAFETY: the raw pointer is the only non-auto-Send/Sync field, and
// every dereference goes through the `unsafe` methods below whose
// contract demands disjoint index sets per concurrent job. For plan
// execution that disjointness is proven statically per layer schedule
// by the write-interval checks in `analysis::audit_network_plan`
// (WriteOverlap / WriteOutOfBounds findings); Miri and TSan cover the
// same contract dynamically in CI.
unsafe impl Send for UnsafeSlice<'_> {}
unsafe impl Sync for UnsafeSlice<'_> {}

impl<'a> UnsafeSlice<'a> {
    /// Wrap a mutable buffer for disjoint parallel writes.
    pub fn new(data: &'a mut [f32]) -> UnsafeSlice<'a> {
        UnsafeSlice {
            ptr: data.as_mut_ptr(),
            len: data.len(),
            marker: std::marker::PhantomData,
        }
    }

    /// Length of the wrapped buffer.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the wrapped buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write one element.
    ///
    /// # Safety
    /// `i` must be in bounds and not concurrently written by any other
    /// job of the same pool run.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: f32) {
        debug_assert!(i < self.len);
        // SAFETY: guaranteed by this method's contract — `i` is in
        // bounds of the wrapped buffer and no other job writes it.
        unsafe { *self.ptr.add(i) = v }
    }

    /// Reborrow a contiguous sub-range as `&mut [f32]`.
    ///
    /// # Safety
    /// Ranges handed to concurrently-running jobs must not overlap.
    #[inline]
    #[allow(clippy::mut_from_ref)] // aliasing contract is the Safety section
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &'a mut [f32] {
        debug_assert!(start + len <= self.len);
        // SAFETY: guaranteed by this method's contract — the range is in
        // bounds and disjoint from every concurrently handed-out range.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), len) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests are the unsafe core's dynamic proof surface: CI runs
    // them under Miri (`cargo miri test --lib util::pool`) and TSan.
    // Miri interprets every instruction and models every thread, so
    // under `cfg(miri)` the sweeps shrink — pool widths {1, 2}, smaller
    // job counts — while the assertions stay byte-identical. Pattern:
    // route every width/job literal through these helpers.
    fn widths() -> &'static [usize] {
        if cfg!(miri) {
            &[1, 2]
        } else {
            &[1, 2, 4]
        }
    }

    fn jobs(full: usize, miri: usize) -> usize {
        if cfg!(miri) {
            miri
        } else {
            full
        }
    }

    #[test]
    fn run_covers_every_job_exactly_once() {
        for &threads in widths() {
            let pool = Pool::new(threads);
            let hits: Vec<AtomicUsize> = (0..jobs(57, 13)).map(|_| AtomicUsize::new(0)).collect();
            pool.run(hits.len(), |j| {
                hits[j].fetch_add(1, Ordering::SeqCst);
            });
            for (j, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "job {j} at {threads} threads");
            }
        }
    }

    #[test]
    fn run_with_reuses_scratch_per_worker() {
        let pool = Pool::new(if cfg!(miri) { 2 } else { 3 });
        let inits = AtomicUsize::new(0);
        pool.run_with(
            jobs(64, 16),
            || {
                inits.fetch_add(1, Ordering::SeqCst);
                0usize
            },
            |s, _| *s += 1,
        );
        let n = inits.load(Ordering::SeqCst);
        assert!(n <= 3, "scratch built {n} times for a <= 3-thread pool");
    }

    #[test]
    fn zero_jobs_is_a_noop() {
        Pool::new(4).run(0, |_| panic!("no jobs to run"));
    }

    #[test]
    fn unsafe_slice_disjoint_writes() {
        let mut buf = vec![0.0f32; jobs(100, 24)];
        let pool = Pool::new(if cfg!(miri) { 2 } else { 4 });
        let out = UnsafeSlice::new(&mut buf);
        let n = out.len();
        // SAFETY: each job writes only its own index `j` — one writer
        // per element, all indices < len.
        pool.run(n, |j| unsafe { out.write(j, j as f32) });
        for (j, v) in buf.iter().enumerate() {
            assert_eq!(*v, j as f32);
        }
    }

    #[test]
    fn global_pool_is_usable() {
        let pool = Pool::global();
        assert!(pool.threads() >= 1);
        let sum = AtomicUsize::new(0);
        pool.run(10, |j| {
            sum.fetch_add(j, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
    }

    #[test]
    fn workers_are_persistent_across_dispatches() {
        use std::collections::HashSet;
        let width = if cfg!(miri) { 2 } else { 4 };
        let pool = Pool::new(width);
        let ids = Mutex::new(HashSet::new());
        for _ in 0..jobs(10, 4) {
            pool.run(jobs(64, 16), |_| {
                ids.lock().unwrap().insert(std::thread::current().id());
            });
        }
        // width-1 persistent workers + the dispatching thread; the
        // scoped spawn-per-call pool would have shown far more ids here
        let n = ids.lock().unwrap().len();
        assert!(n <= width, "dispatches touched {n} distinct threads — workers not reused");
    }

    #[test]
    fn panic_in_job_propagates_and_pool_survives() {
        for threads in if cfg!(miri) { [1, 2] } else { [1, 3] } {
            let pool = Pool::new(threads);
            let res = catch_unwind(AssertUnwindSafe(|| {
                pool.run(16, |j| {
                    if j == 5 {
                        panic!("job 5 exploded");
                    }
                });
            }));
            assert!(res.is_err(), "panic must reach the dispatcher ({threads} threads)");
            // the pool stays fully usable after a panicked dispatch
            let sum = AtomicUsize::new(0);
            pool.run(10, |j| {
                sum.fetch_add(j, Ordering::SeqCst);
            });
            assert_eq!(sum.load(Ordering::SeqCst), 45, "{threads} threads");
        }
    }

    #[test]
    fn panic_in_init_propagates() {
        let pool = Pool::new(2);
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.run_with(8, || panic!("init exploded"), |_: &mut (), _| {});
        }));
        assert!(res.is_err());
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        let pool = Pool::new(if cfg!(miri) { 2 } else { 4 });
        let hits = AtomicUsize::new(0);
        let n = jobs(32, 12);
        pool.run(n, |_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), n);
        drop(pool); // must neither hang nor leave detached workers spinning
    }

    #[test]
    fn reentrant_dispatch_runs_inline() {
        let pool = Pool::new(2);
        let count = AtomicUsize::new(0);
        pool.run(4, |_| {
            // nested dispatch on the busy pool must not deadlock
            pool.run(3, |_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(count.load(Ordering::SeqCst), 12);
    }

    #[test]
    fn concurrent_dispatchers_serialize_safely() {
        let pool = Pool::new(2);
        let total = AtomicUsize::new(0);
        let (dispatchers, rounds, per_run) = if cfg!(miri) { (2, 2, 8) } else { (4, 8, 16) };
        std::thread::scope(|sc| {
            for _ in 0..dispatchers {
                sc.spawn(|| {
                    for _ in 0..rounds {
                        pool.run(per_run, |_| {
                            total.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), dispatchers * rounds * per_run);
    }

    #[test]
    fn init_global_pins_only_before_first_use() {
        let width = Pool::global().threads();
        assert!(Pool::init_global(width).is_ok(), "same width is idempotent");
        assert!(Pool::init_global(width + 1).is_err(), "live pool cannot be resized");
    }
}
