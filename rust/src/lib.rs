//! # PLUM-RS
//!
//! Reproduction of **"PLUM: Improving Inference Efficiency By Leveraging
//! Repetition-Sparsity Trade-Off"** (Kuhar, Jain & Tumanov, 2023) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * L1/L2 (build-time python): Pallas signed-binary kernels + JAX ResNet
//!   fwd/bwd, AOT-lowered to HLO text (`make artifacts`).
//! * L3 (this crate): PJRT runtime, training driver, repetition-sparsity
//!   inference engine, the network-level executor that compiles whole
//!   models onto it (`network` — residual and projection-shortcut
//!   topologies, cross-layer patch reuse), sparse-accelerator energy
//!   simulator, serving coordinator, benchmark harnesses for every paper
//!   table/figure.
//!
//! See ARCHITECTURE.md for the top-to-bottom tour (quant → plan →
//! executor → network → serving) and DESIGN.md for the system inventory
//! and experiment index.

// The public API carries docs; CI escalates this to an error (clippy
// `-D warnings` and the `cargo doc` job's `RUSTDOCFLAGS="-D warnings"`),
// so the gate lives in CI rather than failing local builds outright.
#![warn(missing_docs)]
// Every `unsafe` operation must sit in an explicit `unsafe {}` block
// carrying its own `// SAFETY:` argument (enforced by
// tests/safety_comments.rs), even inside `unsafe fn` — the analysis
// module's audit checks are the other half of each argument.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod models;
pub mod network;
pub mod quant;
pub mod repetition;
pub mod runtime;
pub mod simulator;
pub mod tensor;
pub mod training;
pub mod util;
