//! # PLUM-RS
//!
//! Reproduction of **"PLUM: Improving Inference Efficiency By Leveraging
//! Repetition-Sparsity Trade-Off"** (Kuhar, Jain & Tumanov, 2023) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * L1/L2 (build-time python): Pallas signed-binary kernels + JAX ResNet
//!   fwd/bwd, AOT-lowered to HLO text (`make artifacts`).
//! * L3 (this crate): PJRT runtime, training driver, repetition-sparsity
//!   inference engine, the network-level executor that compiles whole
//!   models onto it (`network`), sparse-accelerator energy simulator,
//!   serving coordinator, benchmark harnesses for every paper
//!   table/figure.
//!
//! See DESIGN.md for the system inventory and experiment index.
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod models;
pub mod network;
pub mod quant;
pub mod repetition;
pub mod runtime;
pub mod simulator;
pub mod tensor;
pub mod training;
pub mod util;
