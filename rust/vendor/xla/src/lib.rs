//! Compile-only stub of the `xla` crate (xla_extension bindings).
//!
//! Exists so `cargo check --features pjrt` (and the pjrt-gated targets)
//! build on machines without the xla_extension shared library. The
//! surface mirrors what plum's `runtime/pjrt.rs` uses:
//!
//! * [`Literal`] construction, reshape and host readback are fully
//!   functional (plain CPU buffers), so literal round-trip tests pass;
//! * everything that would touch PJRT ([`PjRtClient::cpu`],
//!   `compile`, `execute`) returns [`Error::Unavailable`] pointing at
//!   the real bindings — swap the path dependency in rust/Cargo.toml
//!   for a real xla-rs checkout to actually execute HLO.

use std::borrow::Borrow;
use std::path::Path;

/// Stub error. The real crate's error is also surfaced with `{:?}` by
/// plum, so a Debug-able enum is all the callers need.
#[derive(Debug, Clone)]
pub enum Error {
    /// The operation needs the real xla_extension bindings.
    Unavailable(&'static str),
    /// Literal-shape misuse that the stub can detect host-side.
    Shape(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what} is unavailable in the vendored xla stub — point the `xla` \
                 path dependency at a real xla-rs/xla_extension checkout (see \
                 rust/README.md build matrix)"
            ),
            Error::Shape(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types plum reads back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Backing storage of a [`Literal`]. Public only because the
/// [`NativeType`] trait mentions it; treat as an implementation detail.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum LiteralData {
    F32(Vec<f32>),
    S32(Vec<i32>),
    #[allow(dead_code)] // constructed only by real executions
    Tuple(Vec<Literal>),
}

/// Host-side literal: the one part of the xla surface the stub
/// implements for real (construction, reshape, readback).
#[derive(Debug, Clone)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

/// Scalar/vector element types [`Literal`]s are built from and read
/// back into.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> LiteralData;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> LiteralData {
        LiteralData::F32(data)
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            LiteralData::F32(v) => Ok(v.clone()),
            other => Err(Error::Shape(format!("literal is not f32: {other:?}"))),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> LiteralData {
        LiteralData::S32(data)
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            LiteralData::S32(v) => Ok(v.clone()),
            other => Err(Error::Shape(format!("literal is not s32: {other:?}"))),
        }
    }
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::wrap(vec![v]), dims: Vec::new() }
    }

    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { data: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error::Shape(format!(
                "cannot reshape {have} elements to {dims:?}"
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::S32(v) => v.len(),
            LiteralData::Tuple(ts) => ts.iter().map(Literal::element_count).sum(),
        }
    }

    pub fn ty(&self) -> Result<ElementType> {
        match &self.data {
            LiteralData::F32(_) => Ok(ElementType::F32),
            LiteralData::S32(_) => Ok(ElementType::S32),
            LiteralData::Tuple(_) => Err(Error::Shape("tuple literal has no element type".into())),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            LiteralData::Tuple(ts) => Ok(ts),
            other => Err(Error::Shape(format!("literal is not a tuple: {other:?}"))),
        }
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper around a parsed proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer returned by executions.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle. `cpu()` always errors in the stub: the process
/// has no xla_extension runtime to attach to.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.ty().unwrap(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn pjrt_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
