//! End-to-end validation (DESIGN.md E2E): train the signed-binary
//! ResNet-20 for a few hundred steps on the synthetic CIFAR-like dataset
//! through the full three-layer stack —
//!
//!   rust driver -> PJRT CPU executable <- HLO text <- jax fwd/bwd <-
//!   Pallas signed-binary kernels (quantize + GEMM)
//!
//! — logging the loss curve, then evaluating held-out accuracy through
//! the *inference* artifact (whose hot path is the Pallas sb GEMM), then
//! exporting the trained quantized weights into the rust repetition
//! engine and reporting density + arithmetic reduction.
//!
//! Run: `make artifacts && cargo run --release --example train_e2e`
//! (flags: --model resnet20_sb --steps 300 --artifacts DIR)

use plum::cli::args::Args;
use plum::data::SyntheticDataset;
use plum::repetition::{arithmetic_reduction, plan_layer, EngineConfig};
use plum::runtime::Runtime;
use plum::training::{save_checkpoint, Schedule, Trainer};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let artifacts = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let model = args.get_or("model", "resnet20_sb");
    let steps = args.get_u64("steps", 300);

    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());
    let mut tr = Trainer::new(&rt, &artifacts, model)?;
    let man = tr.model.manifest.clone();
    println!(
        "model {model}: arch={} scheme={} params={} ({} conv layers, {} quantized)",
        man.config.arch,
        man.config.scheme,
        man.param_count,
        man.conv_layers.len(),
        man.conv_layers.iter().filter(|l| l.quantized).count(),
    );

    let ds = SyntheticDataset::new("cifar", man.config.num_classes, man.config.in_channels, man.config.image_size, 7);
    let schedule = Schedule::Step { init: 5e-3, milestones: vec![0.5, 0.8] };

    println!("\ntraining {steps} steps (bs {}) — loss curve:", tr.batch_size());
    let log = tr.train(&ds, steps, &schedule, (steps / 20).max(1), 0, false)?;

    let acc = tr.evaluate(&ds, 8)?;
    println!(
        "\nheld-out accuracy (Pallas sb-GEMM infer path): {:.3} ({}-class chance = {:.3})",
        acc,
        man.config.num_classes,
        1.0 / man.config.num_classes as f32
    );
    println!(
        "training wall time {:.1}s ({:.0} ms/step)",
        log.wall_secs,
        1e3 * log.wall_secs / steps as f64
    );

    // deploy-side: quantize the trained latents and hand them to the
    // repetition engine
    let layers = tr.export_quantized()?;
    let (mut eff, mut tot) = (0usize, 0usize);
    let mut red_sum = 0.0;
    for (info, q) in &layers {
        eff += q.effectual();
        tot += q.values.len();
        red_sum += arithmetic_reduction(&plan_layer(q, info.geom, EngineConfig::default()));
    }
    println!(
        "\ntrained quantized model: density {:.2} (paper: ~0.35-0.5), mean arithmetic reduction {:.1}x over {} layers",
        eff as f64 / tot as f64,
        red_sum / layers.len() as f64,
        layers.len()
    );

    std::fs::create_dir_all("out").ok();
    let ckpt = std::path::Path::new("out").join(format!("{model}.ckpt"));
    save_checkpoint(&ckpt, tr.step, &tr.state_to_host()?)?;
    println!("checkpoint saved: {} (reuse with examples/serve_quantized)", ckpt.display());
    Ok(())
}
