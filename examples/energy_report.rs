//! §5.2 energy/throughput study on the SIGMA-like simulator, plus the
//! repetition-engine op analysis — the "benefits of sparsity" story for a
//! signed-binary ResNet-18 without needing any artifacts.
//!
//! Run: `cargo run --release --example energy_report -- --sparsity 0.65`

use plum::cli::args::Args;
use plum::config::RunConfig;
use plum::experiments::figures;
use plum::models;
use plum::simulator::{simulate_conv, AcceleratorConfig};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = RunConfig::resolve(&args)?;
    let sparsity = args.get_f32("sparsity", 0.65) as f64;

    println!("SIGMA-like config: 256 multiplier switches, 256 rd/wr SDMemory ports (paper supp. A)\n");
    figures::energy(&cfg, sparsity)?;

    // density -> potential throughput (paper: 35% density -> 2.86x)
    println!("\npotential throughput by density (paper §5.2, x = 1/density):");
    let layer = &models::resnet18_layers(1.0, 64, 1)[10];
    let acc = AcceleratorConfig::default();
    for density in [1.0, 0.75, 0.5, 0.35, 0.2] {
        let dense = simulate_conv(&layer.geom, 1.0, &acc);
        let sparse = simulate_conv(&layer.geom, density, &acc);
        println!(
            "  density {density:.2}: ideal {:.2}x, simulated cycles {:.2}x, simulated energy {:.2}x",
            1.0 / density,
            dense.cycles as f64 / sparse.cycles as f64,
            dense.energy / sparse.energy
        );
    }
    Ok(())
}
