//! Quickstart: the PLUM pipeline on one conv layer, no artifacts needed.
//!
//! 1. quantize a latent weight tensor three ways (binary / ternary /
//!    signed-binary);
//! 2. inspect the repetition-sparsity trade-off (density, unique values,
//!    distinct sub-tile patterns);
//! 3. build repetition-aware inference plans and compare operation counts
//!    and measured runtime — the paper's core claim in ~1 second.
//!
//! Run: `cargo run --release --example quickstart`

use plum::quant::{self, filter_repetition_stats, PackedSignedBinary, Scheme};
use plum::repetition::{arithmetic_reduction, execute_conv2d, plan_layer, EngineConfig};
use plum::tensor::{conv2d_gemm, Conv2dGeometry, Tensor};
use plum::util::bench::bench;
use plum::util::Rng;

fn main() {
    // a mid-size conv layer: 128 filters, 64 channels, 3x3, 16x16 input
    let geom = Conv2dGeometry {
        n: 1, c: 64, h: 16, w: 16, k: 128, r: 3, s: 3, stride: 1, padding: 1,
    };
    let mut rng = Rng::new(42);
    let latent = Tensor::rand_normal(&[geom.k, geom.c, geom.r, geom.s], 0.5, &mut rng);
    let x = Tensor::rand_normal(&[geom.n, geom.c, geom.h, geom.w], 1.0, &mut rng);

    println!("PLUM quickstart — conv {}x{}x{}x{} on {}x{} input\n", geom.k, geom.c, geom.r, geom.s, geom.h, geom.w);
    println!(
        "{:<14} {:>8} {:>12} {:>14} {:>12} {:>10} {:>10}",
        "scheme", "density", "uniq/filter", "arith-reduct", "ops(M)", "time(ms)", "max|err|"
    );

    for scheme in [Scheme::Binary, Scheme::ternary_default(), Scheme::sb_default()] {
        let q = quant::quantize(&latent, scheme, None);
        let stats = filter_repetition_stats(&q.values, geom.k);
        let plan = plan_layer(&q, geom, EngineConfig::default());
        let dense = conv2d_gemm(&x, &q.values, geom.stride, geom.padding);
        let out = execute_conv2d(&plan, &x);
        let err = dense.max_abs_diff(&out);
        let t = bench("conv", 1, 10, || {
            std::hint::black_box(execute_conv2d(&plan, &x));
        });
        println!(
            "{:<14} {:>8.2} {:>12.2} {:>13.1}x {:>12.2} {:>10.2} {:>10.2e}",
            scheme.name(),
            stats.density,
            stats.mean_unique_values,
            arithmetic_reduction(&plan),
            plan.op_counts().total() as f64 / 1e6,
            t.min_ms(),
            err,
        );
    }

    // the paper's §6 bit-accounting: signed-binary stores one bit per
    // weight plus one sign bit per filter
    let q = quant::quantize(&latent, Scheme::sb_default(), None);
    let packed = PackedSignedBinary::pack(&q);
    println!(
        "\nsigned-binary packed footprint: {} bits = R*S*C*K + K = {} (paper §6); {} of {} weights effectual",
        packed.weight_bits(),
        geom.r * geom.s * geom.c * geom.k + geom.k,
        packed.effectual(),
        geom.weight_count(),
    );
    println!("\nnext: `make artifacts` then `cargo run --release --example train_e2e`");
}
