//! Serving validation: load a signed-binary model artifact into the
//! coordinator (router + dynamic batcher + PJRT workers) and serve a
//! synthetic request stream, reporting latency and throughput.
//!
//! Run: `make artifacts && cargo run --release --example serve_quantized`
//! Flags: --model resnet20_sb --requests 256 --replicas 2 --max-batch 8
//!        --ckpt out/resnet20_sb.ckpt   (serve trained weights)

use plum::cli::args::Args;
use plum::config::RunConfig;
use plum::coordinator::ModelRegistry;
use plum::experiments::serving;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = RunConfig::resolve(&args)?;
    let model = args.get_or("model", "resnet20_sb").to_string();
    let requests = args.get_usize("requests", 256);
    let ckpt = args.get("ckpt").map(std::path::PathBuf::from);

    // registry: what are we deploying and how big is it on the wire?
    let reg = ModelRegistry::scan(&cfg.artifacts)?;
    if let Some(e) = reg.by_name(&model) {
        println!(
            "deploying {}: scheme={} params={:.2}M packed-weight footprint={} KiB (paper §6 one-bit accounting)",
            e.name,
            e.scheme,
            e.param_count as f64 / 1e6,
            e.weight_bits / 8 / 1024
        );
    }

    let report = serving::drive(&cfg, &model, requests, ckpt)?;
    println!(
        "\n{} requests, {} replica(s), batch<= {} wait<={}ms:",
        report.requests, report.replicas, cfg.max_batch, cfg.max_wait_ms
    );
    println!(
        "  throughput {:.1} req/s | latency mean {:.1} ms p95 {:.1} ms | wall {:.2}s",
        report.throughput_rps, report.mean_ms, report.p95_ms, report.wall_secs
    );
    Ok(())
}
